# Convenience targets; everything here is also runnable by hand (see README).

.PHONY: build test bench artifacts fmt lint doc pytest

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench --bench kernels

# Export the AOT artifact set (HLO text + manifest + goldens) with the
# Python toolchain.  Needed only for the PJRT-executing benches/tests.
artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

fmt:
	cargo fmt --check

lint:
	cargo clippy -- -D warnings

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

pytest:
	cd python && python -m pytest tests/ -q
