# Convenience targets; everything here is also runnable by hand (see README).

.PHONY: build test bench bench-json bench-baseline artifacts fmt lint doc pytest

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench --bench kernels

# Machine-readable BENCH_<name>.json from every bench, short sample
# budgets (the benches that need artifacts skip gracefully).  Compare two
# reports with `padst bench-compare <old> <new>` (see README §Perf
# tracking).
bench-json:
	cargo bench --bench kernels -- --short
	cargo bench --bench fig3_inference -- --short
	cargo bench --bench table1_nlr -- --short
	cargo bench --bench fig3_training -- --short
	cargo bench --bench table5_overhead -- --short

# Produce and install the committed kernels-bench baseline for the CI perf
# gate.  Two short runs back to back must agree on p50 within the
# stability threshold (run-to-run noise check via the same bench-compare
# gate CI uses); only then does the second run land in ci/baselines/.
# Run this on a quiet, trusted machine; see README §Perf tracking for
# flipping the CI compare step from warn-only to blocking afterwards.
BASELINE_TMP := target/bench-baseline
BASELINE_STABILITY_PCT := 15
bench-baseline:
	cargo build --release
	mkdir -p $(BASELINE_TMP) ci/baselines
	cargo bench --bench kernels -- --short --json $(BASELINE_TMP)/run1.json
	cargo bench --bench kernels -- --short --json $(BASELINE_TMP)/run2.json
	cargo run --release -- bench-compare $(BASELINE_TMP)/run1.json $(BASELINE_TMP)/run2.json \
		--threshold $(BASELINE_STABILITY_PCT)
	cp $(BASELINE_TMP)/run2.json ci/baselines/BENCH_kernels.json
	@echo "installed ci/baselines/BENCH_kernels.json (stable within $(BASELINE_STABILITY_PCT)% p50)"

# Export the AOT artifact set (HLO text + manifest + goldens) with the
# Python toolchain.  Needed only for the PJRT-executing benches/tests.
artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

fmt:
	cargo fmt --check

lint:
	cargo clippy -- -D warnings
	cargo run --release -- lint

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

pytest:
	cd python && python -m pytest tests/ -q
