# Convenience targets; everything here is also runnable by hand (see README).

.PHONY: build test bench bench-json artifacts fmt lint doc pytest

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench --bench kernels

# Machine-readable BENCH_<name>.json from every bench, short sample
# budgets (the benches that need artifacts skip gracefully).  Compare two
# reports with `padst bench-compare <old> <new>` (see README §Perf
# tracking).
bench-json:
	cargo bench --bench kernels -- --short
	cargo bench --bench fig3_inference -- --short
	cargo bench --bench table1_nlr -- --short
	cargo bench --bench fig3_training -- --short
	cargo bench --bench table5_overhead -- --short

# Export the AOT artifact set (HLO text + manifest + goldens) with the
# Python toolchain.  Needed only for the PJRT-executing benches/tests.
artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

fmt:
	cargo fmt --check

lint:
	cargo clippy -- -D warnings

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

pytest:
	cd python && python -m pytest tests/ -q
