//! End-to-end driver (DESIGN.md "End-to-end validation"): train the
//! gpt_small transformer (~7 M params — the largest this single-core CPU
//! testbed trains in-budget, standing in for GPT-2 Small) with DynaDiag
//! diagonal sparsity + PA-DST learned permutations at 80 % sparsity on the
//! synthetic Markov corpus, for a few hundred steps, logging the loss
//! curve and perplexity.
//!
//! This proves all layers compose at scale: the AOT train_step (fwd/bwd +
//! Adam + Sinkhorn + penalty), the dst_update (diagonal prune/grow), the
//! hardening controller, and eval — all driven from Rust with Python
//! nowhere on the path.
//!
//! Run: `cargo run --release --example train_gpt -- [steps] [sparsity]`
//! Recorded run: EXPERIMENTS.md §E2E.

use padst::coordinator::{RunConfig, Trainer};
use padst::perm::model::resolve_perm;
use padst::runtime::Runtime;
use padst::sparsity::pattern::resolve_pattern;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let sparsity: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(0.8);

    let dir = std::path::Path::new("artifacts");
    let mut rt = Runtime::open(dir)?;
    let entry = &rt.manifest.models["gpt_small"];
    println!(
        "== gpt_small: d={} L={} heads={} seq={} vocab={} (~{:.1}M params) ==",
        entry.d_model,
        entry.n_layers,
        entry.n_heads,
        entry.seq_len,
        entry.vocab,
        entry.n_params() as f64 / 1e6
    );

    let cfg = RunConfig {
        model: "gpt_small".into(),
        pattern: resolve_pattern("diag")?,
        density: 1.0 - sparsity,
        perm: resolve_perm("learned")?,
        steps,
        lr: 3e-4,
        dst_every: 50,
        eval_every: 50,
        verbose: true,
        ..Default::default()
    };
    let mut trainer = Trainer::new(&mut rt, cfg);
    let res = trainer.run()?;

    println!("\nloss curve:");
    for (step, loss) in res
        .losses
        .iter()
        .enumerate()
        .filter(|(i, _)| i % 20 == 0 || *i == res.losses.len() - 1)
    {
        println!("  step {:>5}  train_loss {:.4}  ppl {:.2}", step, loss, loss.exp());
    }
    println!("\neval checkpoints:");
    for ((s, l), (_, _a)) in res.eval_losses.iter().zip(&res.eval_accs) {
        println!("  step {:>5}  eval_loss {:.4}  eval_ppl {:.2}", s, l, l.exp());
    }
    println!(
        "\nfinal: eval_ppl={:.2} hardened {}/{} sites, {:.1}s total ({:.0} ms/step)",
        res.final_ppl,
        res.harden_step.iter().filter(|h| h.is_some()).count(),
        res.harden_step.len(),
        res.train_seconds,
        res.train_seconds * 1000.0 / res.losses.len() as f64
    );
    // Sanity: training must actually have reduced the loss.
    let head: f32 = res.losses[..10.min(res.losses.len())].iter().sum::<f32>() / 10.0;
    let tail: f32 =
        res.losses[res.losses.len().saturating_sub(10)..].iter().sum::<f32>() / 10.0;
    assert!(tail < head, "loss did not decrease: {head} -> {tail}");
    println!("loss decreased {head:.3} -> {tail:.3}  OK");
    Ok(())
}
