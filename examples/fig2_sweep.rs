//! Fig. 2 / Tbl. 11–12 regenerator: accuracy (vision) or perplexity (LM)
//! vs sparsity for unstructured DST, structured DST, structured + random
//! perm, and structured + PA-DST, on the synthetic tasks.
//!
//! Default is a reduced grid that finishes in minutes on one core; pass
//! `--full` for the whole method zoo and all five sparsities (budget ~1 h)
//! and `--model gpt_tiny` / `mixer_tiny` for the other panels.
//! `--patterns block:4,nm:1:4` appends one structured-DST grid row per
//! pattern spec — the recommended Fig. 2 extension for sweeping pattern
//! hyper-parameters (block size, M-group) as first-class axes.
//! `--perms learned,none,random` crosses every grid row with each perm
//! spec (rows named `method+spec`), so the structure-granularity axis and
//! the permutation axis sweep together in one journal-compatible grid.
//! `--workers N` shards the grid across N runtimes (~N x wall-clock cut);
//! `--journal PATH` checkpoints completed cells so a killed sweep resumes;
//! `--shard i/n` runs one cluster shard of the grid (combine the per-shard
//! journals with `padst journal-merge`); `--backend scalar|tiled|simd`
//! selects the native-kernel microkernel backend.
//!
//! Run: `cargo run --release --example fig2_sweep -- [--full] [--model M]
//!       [--steps N] [--csv PATH] [--threads N] [--workers N]
//!       [--journal PATH] [--shard i/n] [--backend B]`

use padst::coordinator::sweep::{
    cross_perms, method_by_name, methods, print_table, resolve_method, run_sweep_auto, write_csv,
    Method, SweepShardOpts,
};
use padst::harness::bench::backend_knob_in;
use padst::harness::shard::parse_shard;
use padst::util::cli::{arg_value_in, has_flag_in};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let full = has_flag_in(&args, "--full");
    let get = |k: &str, d: &str| arg_value_in(&args, k).unwrap_or_else(|| d.to_string());
    let model = get("--model", "vit_tiny");
    let steps: usize = get("--steps", if full { "400" } else { "250" }).parse()?;

    let threads: usize = get("--threads", "0").parse()?; // 0 = auto
    let workers: usize = get("--workers", "1").parse()?; // 1 = sequential
    let backend = backend_knob_in(&args);
    let journal = arg_value_in(&args, "--journal").map(std::path::PathBuf::from);
    let shard = match arg_value_in(&args, "--shard") {
        Some(s) => Some(parse_shard(&s)?),
        None => None,
    };
    let dir = std::path::Path::new("artifacts");

    let (mut grid_methods, sparsities): (Vec<Method>, Vec<f64>) = if full {
        (methods().to_vec(), vec![0.6, 0.7, 0.8, 0.9, 0.95])
    } else {
        (
            ["RigL", "DynaDiag", "DynaDiag+Rand", "DynaDiag+PA", "SRigL", "SRigL+PA", "Dense"]
                .iter()
                .map(|n| method_by_name(n).unwrap())
                .collect(),
            vec![0.8, 0.95],
        )
    };
    // Extra grid rows from pattern specs: `--patterns block:4,nm:1:4` adds
    // one structured-DST method per spec — the pattern-hyper-param axis.
    if let Some(specs) = arg_value_in(&args, "--patterns") {
        for spec in specs.split(',').filter(|s| !s.is_empty()) {
            grid_methods.push(resolve_method(spec)?);
        }
    }
    // The permutation axis: `--perms learned,none` crosses every row with
    // each perm spec, completing the Fig. 2 structure x perm grid.
    if let Some(specs) = arg_value_in(&args, "--perms") {
        let perms: Vec<String> =
            specs.split(',').filter(|s| !s.is_empty()).map(str::to_string).collect();
        grid_methods = cross_perms(&grid_methods, &perms)?;
    }

    eprintln!(
        "[fig2] model={model} methods={} sparsities={:?} steps={steps} workers={workers}",
        grid_methods.len(),
        sparsities
    );
    let opts = SweepShardOpts { workers, threads, backend, shard, journal, verbose: true };
    let (cells, kind) = run_sweep_auto(dir, &model, &grid_methods, &sparsities, steps, 0, &opts)?;
    print_table(&model, &kind, &cells, &sparsities);

    // The paper's qualitative claims, checked programmatically where the
    // grid contains the needed cells (reduced grid does):
    let acc = |m: &str, s: f64| {
        cells
            .iter()
            .find(|c| c.method == m && (c.sparsity - s).abs() < 1e-9)
            .map(|c| {
                if kind == "gpt" {
                    -c.result.final_ppl // higher-is-better sign convention
                } else {
                    c.result.final_eval_acc
                }
            })
    };
    if let (Some(pa), Some(noperm)) = (acc("DynaDiag+PA", 0.95), acc("DynaDiag", 0.95)) {
        println!(
            "\nclaim check @95%: DynaDiag+PA ({pa:.3}) vs DynaDiag ({noperm:.3}) -> {}",
            if pa >= noperm { "PA >= no-perm  ✓ (paper Fig. 2)" } else { "ordering NOT reproduced" }
        );
    }
    let csv = get("--csv", "");
    if !csv.is_empty() {
        write_csv(std::path::Path::new(&csv), &cells)?;
        eprintln!("[fig2] wrote {csv}");
    }
    Ok(())
}
