//! Fig. 4 + Fig. 5 + Fig. 6 regenerator: train a ViT-tiny with DynaDiag at
//! 90 % sparsity and PA-DST, then report
//!
//!   Fig. 4 — delta(P) identity distance of each learned permutation, by
//!            depth and site type (A: attention out-proj, F: FFN linears);
//!   Fig. 5 — the per-layer AutoShuffle penalty trajectory (knee curves);
//!   Fig. 6 — the step at which each layer crossed the hardening
//!            threshold delta and switched to re-indexing.
//!
//! Run: `cargo run --release --example perm_analysis -- [steps] [threshold]
//!       [perm-spec]`
//! (the third positional is a perm spec — default `learned`, e.g.
//! `learned:sinkhorn=24:tau=0.5` to analyse a tempered projection).
//! CSVs land in artifacts/analysis/ for plotting.

use padst::coordinator::{RunConfig, Trainer};
use padst::perm::model::resolve_perm;
use padst::runtime::Runtime;
use padst::sparsity::pattern::resolve_pattern;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(400);
    let threshold: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(0.22);
    let perm_spec = args.get(3).cloned().unwrap_or_else(|| "learned".to_string());

    let dir = std::path::Path::new("artifacts");
    let mut rt = Runtime::open(dir)?;
    let cfg = RunConfig {
        model: "vit_tiny".into(),
        pattern: resolve_pattern("diag")?,
        density: 0.10,
        perm: resolve_perm(&perm_spec)?,
        steps,
        harden_threshold: threshold,
        eval_every: 0,
        verbose: true,
        ..Default::default()
    };
    let entry = rt.manifest.models["vit_tiny"].clone();
    let mut trainer = Trainer::new(&mut rt, cfg);
    let res = trainer.run()?;

    // ---- Fig. 4: identity distance by layer -----------------------------
    println!("\n[Fig. 4] delta(P) = 1 - ||P-I||_F / sqrt(2N)  (1 = identity)");
    for (i, name) in res.site_names.iter().enumerate() {
        let tag = if name.contains("attn") { "A" } else if name.contains("fc") { "F" } else { "P" };
        println!(
            "  {tag} {:<18} delta={:.3} {}",
            name,
            res.identity_distance[i],
            bar(res.identity_distance[i], 40)
        );
    }

    // ---- Fig. 5: penalty trajectories -----------------------------------
    println!("\n[Fig. 5] normalised penalty P(M)/N every {} steps:", steps.max(10) / 10);
    print!("  {:<18}", "site");
    for t in (0..steps).step_by(steps.max(10) / 10) {
        print!("{:>8}", t);
    }
    println!();
    for (i, name) in res.site_names.iter().enumerate() {
        let n = entry.sites[i].cols as f32;
        print!("  {:<18}", name);
        for t in (0..steps).step_by(steps.max(10) / 10) {
            let p = res.penalties[i].get(t).copied().unwrap_or(0.0) / n;
            print!("{:>8.3}", p);
        }
        println!();
    }

    // ---- Fig. 6: hardening steps -----------------------------------------
    println!("\n[Fig. 6] hardening step per site (threshold delta={threshold}):");
    for (i, name) in res.site_names.iter().enumerate() {
        println!(
            "  {:<18} -> {}",
            name,
            res.harden_step[i]
                .map(|s| format!("step {s}"))
                .unwrap_or_else(|| "never".into())
        );
    }

    // ---- CSV dumps --------------------------------------------------------
    let out = dir.join("analysis");
    std::fs::create_dir_all(&out)?;
    let mut fig5 = String::from("site,step,penalty\n");
    for (i, name) in res.site_names.iter().enumerate() {
        for (t, p) in res.penalties[i].iter().enumerate() {
            fig5.push_str(&format!("{name},{t},{p}\n"));
        }
    }
    std::fs::write(out.join("fig5_penalties.csv"), fig5)?;
    let mut fig46 = String::from("site,identity_distance,harden_step\n");
    for (i, name) in res.site_names.iter().enumerate() {
        fig46.push_str(&format!(
            "{name},{},{}\n",
            res.identity_distance[i],
            res.harden_step[i].map(|s| s as i64).unwrap_or(-1)
        ));
    }
    std::fs::write(out.join("fig4_fig6_permutations.csv"), fig46)?;
    println!("\nwrote artifacts/analysis/fig5_penalties.csv, fig4_fig6_permutations.csv");
    Ok(())
}

fn bar(v: f64, width: usize) -> String {
    let n = (v.clamp(0.0, 1.0) * width as f64) as usize;
    format!("|{}{}|", "#".repeat(n), " ".repeat(width - n))
}
