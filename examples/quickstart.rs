//! Quickstart: train a tiny ViT with DynaDiag + PA-DST at 90 % sparsity on
//! the synthetic shuffled-mixture task, watch the permutation penalties
//! fall, the hardening controller fire, and the loss drop — the whole
//! three-layer stack (Pallas kernel -> JAX AOT -> Rust coordinator) in
//! ~100 lines.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use padst::coordinator::{RunConfig, Trainer};
use padst::perm::model::resolve_perm;
use padst::runtime::Runtime;
use padst::sparsity::pattern::resolve_pattern;

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    let mut rt = Runtime::open(dir)?;

    let cfg = RunConfig {
        model: "vit_tiny".into(),
        pattern: resolve_pattern("diag")?, // DynaDiag-style dynamic diagonals
        density: 0.10,              // 90 % sparsity
        perm: resolve_perm("learned")?,
        steps: 300,
        eval_every: 100,
        verbose: true,
        ..Default::default()
    };
    println!("== PA-DST quickstart: ViT-tiny, diag @ 90% sparsity, learned perms ==");
    let mut trainer = Trainer::new(&mut rt, cfg);
    let res = trainer.run()?;

    println!("\nloss curve (every 25 steps):");
    for (i, chunk) in res.losses.chunks(25).enumerate() {
        let avg: f32 = chunk.iter().sum::<f32>() / chunk.len() as f32;
        println!("  step {:>4}: {:.4}", i * 25, avg);
    }

    println!("\npermutation state at the end:");
    for (i, name) in res.site_names.iter().enumerate() {
        println!(
            "  {:<18} delta(P)={:.3}  hardened at step {:?}",
            name,
            res.identity_distance[i],
            res.harden_step[i]
        );
    }
    println!(
        "\nfinal eval: loss={:.4} acc={:.3} ({} steps in {:.1}s)",
        res.final_eval_loss,
        res.final_eval_acc,
        res.losses.len(),
        res.train_seconds
    );
    Ok(())
}
