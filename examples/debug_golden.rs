// Debug helper: run one golden artifact by name and print per-output diffs.
use padst::runtime::Runtime;
use padst::tensor::read_tnz;

fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or("vit_tiny_eval".into());
    let dir = std::path::Path::new("artifacts");
    let mut rt = Runtime::open(dir)?;
    let t0 = std::time::Instant::now();
    let prog = rt.program(&name)?;
    println!("compile {:?}: {:.1}s", name, t0.elapsed().as_secs_f64());
    let bundle = read_tnz(&rt.golden_path(&name))?;
    let inputs: Vec<_> = prog.spec.inputs.iter()
        .map(|s| bundle[&format!("in.{}", s.name)].clone()).collect();
    let t1 = std::time::Instant::now();
    let outputs = prog.run(&inputs)?;
    println!("run: {:.3}s", t1.elapsed().as_secs_f64());
    for (out, spec) in outputs.iter().zip(&prog.spec.outputs) {
        let want = &bundle[&format!("out.{}", spec.name)];
        let err = out.max_abs_diff(want);
        if err > 1e-4 { println!("  DIFF {} = {err}", spec.name); }
        if spec.name.starts_with("mask.") {
            let got: f32 = out.f32s().iter().sum();
            let exp: f32 = want.f32s().iter().sum();
            if got != exp { println!("  NNZ {} got {got} want {exp}", spec.name); }
        }
    }
    println!("done");
    Ok(())
}
