//! Expressivity walkthrough (Sec. 3, Table 1, Apdx A/B/C.1): prints every
//! worked example in the paper's theory section with both the exact and
//! the log-space arithmetic, so the combinatorial claims can be audited
//! line by line.
//!
//! Run: `cargo run --release --example expressivity`

use padst::nlr::*;
use padst::sparsity::density_to_params;

fn main() {
    println!("==================================================================");
    println!(" PA-DST expressivity via linear regions — paper Sec. 3 + appendix");
    println!("==================================================================");

    // ---- Apdx A: density -> pattern parameters -------------------------
    println!("\n[Apdx A] density->pattern mapping at delta=0.05 (ViT-L surrogate):");
    for n_in in [1024usize, 4096] {
        let p = density_to_params(0.05, n_in, 20);
        println!(
            "  n_in={n_in:<5} K=B={:<4} band={:<4} tied N:M = {}:{}",
            p.k, p.band, p.n, p.m
        );
    }

    // ---- Apdx C.1: exact worked example ---------------------------------
    println!("\n[Apdx C.1] d0=4, widths (8,8,8):");
    let widths = [8usize, 8, 8];
    let rows = [
        ("Dense / Unstructured", nlr_bound_u128(Setting::Dense, 4, &widths)),
        ("Block-2, no perm", nlr_bound_u128(Setting::StructNoPerm { r: 2 }, 4, &widths)),
        ("Block-2 + learned perm", nlr_bound_u128(Setting::StructPerm { r: 2 }, 4, &widths)),
    ];
    for (name, v) in rows {
        println!("  {name:<24} NLR >= {v}");
    }
    println!("  paper: 163^3 = {}, 37^3 = {}, 37*163^2 = {}",
        163u64.pow(3), 37u64.pow(3), 37u64 * 163 * 163);

    // ---- per-layer effective dimensions, ViT-L surrogate ---------------
    println!("\n[Apdx B] span budget u_l, ViT-L surrogate (d0=1024, caps 51/205):");
    let widths: Vec<usize> = (0..48).map(|i| if i % 2 == 0 { 4096 } else { 1024 }).collect();
    let caps: Vec<usize> = (0..48).map(|i| if i % 2 == 0 { 51 } else { 205 }).collect();
    let dims = effective_dims_var(1024, &widths, &caps);
    for l in 0..10 {
        println!("  layer {:>2}: k_l = {:>4}{}", l + 1, dims[l],
            if dims[l] == 1024 { "   <- dense-like factors resume (4 blocks)" } else { "" });
    }

    // ---- Table 1 at three scales ----------------------------------------
    for (d0, w, dens, label) in [
        (1024usize, vec![4096usize, 1024].repeat(24), 0.05, "ViT-L surrogate, 95% sparse"),
        (768, vec![3072usize, 768].repeat(12), 0.10, "ViT-B surrogate, 90% sparse"),
        (128, vec![256usize, 128].repeat(4), 0.10, "vit_tiny (this repo), 90% sparse"),
    ] {
        println!("\n[Table 1] {label} (d0={d0}, L={}):", w.len());
        println!("  {:<38} {:>12} {:>12}", "setting", "log10 NLR", "overhead");
        for row in table1_rows(d0, &w, dens) {
            println!(
                "  {:<38} {:>12.1} {:>12}",
                row.setting,
                row.log10_nlr,
                match row.depth_overhead {
                    Some(0) => "0".into(),
                    Some(l) => format!("{l} layers"),
                    None => "stalls".into(),
                }
            );
        }
    }

    println!("\nReading: 'stalls' rows never recover dense-like region growth;");
    println!("the '+ permutation' row pays ceil(d0/r) warm-up layers and then");
    println!("matches the dense per-layer factor — the paper's central claim.");
}
