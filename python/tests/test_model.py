"""L2 model: shapes, gradients, permutation equivalences, and program
builders (train/dst/eval/infer) for all three architectures."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import programs as P
from compile.kernels import ref


def make_batch(cfg, batch=2, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.kind == "gpt":
        x = jnp.asarray(rng.integers(0, cfg.vocab, (batch, cfg.seq_len)), jnp.int32)
        y = jnp.asarray(rng.integers(0, cfg.vocab, (batch, cfg.seq_len)), jnp.int32)
    else:
        x = jnp.asarray(rng.standard_normal((batch, cfg.image, cfg.image, 3)), jnp.float32)
        y = jnp.asarray(rng.integers(0, cfg.n_classes, (batch,)), jnp.int32)
    return x, y


def state_for(cfg):
    params = {k: jnp.asarray(v) for k, v in M.init_params(cfg).items()}
    masks = {k: jnp.asarray(v) for k, v in M.init_masks(cfg).items()}
    logits, idx, flags = M.init_perm_state(cfg)
    logits = {k: jnp.asarray(v) for k, v in logits.items()}
    idx = {k: jnp.asarray(v) for k, v in idx.items()}
    return params, masks, logits, idx, jnp.asarray(flags)


@pytest.mark.parametrize("kind", ["vit_tiny", "gpt_tiny", "mixer_tiny"])
def test_forward_shapes(kind):
    cfg = M.CONFIGS[kind](perm_mode="learned")
    params, masks, logits, idx, flags = state_for(cfg)
    x, _ = make_batch(cfg)
    ctx = M.SparseCtx(cfg, masks, logits, idx, flags)
    out = M.forward(cfg, params, ctx, x)
    if cfg.kind == "gpt":
        assert out.shape == (2, cfg.seq_len, cfg.vocab)
    else:
        assert out.shape == (2, cfg.n_classes)
    assert np.isfinite(np.array(out)).all()


@pytest.mark.parametrize("kind", ["vit_tiny", "gpt_tiny"])
def test_gradients_finite_and_masked(kind):
    """Grads must be finite everywhere and *zero outside the mask* for
    sparse-site weights (masked-dense parameterisation)."""
    cfg = M.CONFIGS[kind](perm_mode="learned", density=0.2)
    params, masks, logits, idx, flags = state_for(cfg)
    x, y = make_batch(cfg)

    def loss(p):
        t, _ = M.task_loss(cfg, p, masks, logits, idx, flags, x, y, jnp.float32(0.01))
        return t

    g = jax.grad(loss)(params)
    for k, v in g.items():
        assert np.isfinite(np.array(v)).all(), k
    site = M.site_names(cfg)[0]
    gw = np.array(g[f"{site}.w"])
    m = np.array(masks[site])
    assert (np.abs(gw[m < 0.5]) < 1e-8).all(), "gradient leaked outside mask"


def test_hard_identity_equals_noperm():
    """flags=1 with identity idx must equal the no-permutation model."""
    cfg_l = M.CONFIGS["vit_tiny"](perm_mode="learned")
    cfg_n = M.CONFIGS["vit_tiny"](perm_mode="none")
    params, masks, logits, idx, _ = state_for(cfg_l)
    x, y = make_batch(cfg_l)
    ones = jnp.ones((len(M.site_names(cfg_l)),), jnp.float32)
    ctx_h = M.SparseCtx(cfg_l, masks, logits, idx, ones)
    out_h = M.forward(cfg_l, params, ctx_h, x)
    ctx_n = M.SparseCtx(cfg_n, masks, {}, {}, ones)
    out_n = M.forward(cfg_n, params, ctx_n, x)
    np.testing.assert_allclose(np.array(out_h), np.array(out_n), atol=1e-5)


def test_random_hard_perm_changes_output():
    cfg = M.CONFIGS["vit_tiny"](perm_mode="random", seed=3)
    params, masks, logits, idx, flags = state_for(cfg)
    x, _ = make_batch(cfg)
    ctx = M.SparseCtx(cfg, masks, logits, idx, flags)
    out_r = M.forward(cfg, params, ctx, x)
    ident = {k: jnp.arange(v.shape[0], dtype=jnp.int32) for k, v in idx.items()}
    ctx_i = M.SparseCtx(cfg, masks, logits, ident, flags)
    out_i = M.forward(cfg, params, ctx_i, x)
    assert np.abs(np.array(out_r) - np.array(out_i)).max() > 1e-3


def test_row_perm_ablation_runs():
    """Tbl. 10: row-permutation formulation must be trainable too."""
    cfg = M.CONFIGS["vit_tiny"](perm_mode="learned", perm_side="row")
    params, masks, logits0, idx0, flags = state_for(cfg)
    # Row perms act on layer *outputs*: dims = rows.
    logits, idx = {}, {}
    for name, rows, cols in M.sparse_sites(cfg):
        logits[name] = jnp.zeros((rows, rows), jnp.float32)
        idx[name] = jnp.arange(rows, dtype=jnp.int32)
    x, y = make_batch(cfg)
    total, (loss, _, pen) = M.task_loss(
        cfg, params, masks, logits, idx, jnp.zeros_like(flags), x, y, jnp.float32(0.01)
    )
    assert np.isfinite(float(total)) and float(pen.sum()) > 0


def test_train_step_reduces_loss_all_models():
    for kind in ["vit_tiny", "gpt_tiny", "mixer_tiny"]:
        cfg = M.CONFIGS[kind](perm_mode="learned", density=0.3)
        fn, args, spec = P.make_train_step(cfg, batch=4)
        jfn = jax.jit(fn)
        names = [n for n, _, _ in spec.inputs]
        onames = [n for n, _, _ in spec.outputs]
        args = list(args)
        x, y = make_batch(cfg, batch=4, seed=1)
        args[names.index("batch_x")] = x
        args[names.index("batch_y")] = y
        first = None
        for _ in range(6):
            outs = jfn(*args)
            od = dict(zip(onames, outs))
            if first is None:
                first = float(od["loss"])
            for i, n in enumerate(names):
                if n in od:
                    args[i] = od[n]
        assert float(od["loss"]) < first, f"{kind}: loss did not decrease"


def test_dst_update_budget_and_moment_reset():
    cfg = M.CONFIGS["vit_tiny"](structure="diag", density=0.2)
    fn, args, spec = P.make_dst_update(cfg, batch=4)
    names = [n for n, _, _ in spec.inputs]
    onames = [n for n, _, _ in spec.outputs]
    args = list(args)
    x, y = make_batch(cfg, batch=4, seed=2)
    args[names.index("batch_x")] = x
    args[names.index("batch_y")] = y
    # Seed Adam moments with ones to observe the reset.
    for i, n in enumerate(names):
        if n.startswith("adam_m."):
            args[i] = jnp.ones_like(args[i])
    outs = dict(zip(onames, jax.jit(fn)(*args)))
    ins = dict(zip(names, args))
    for site in M.site_names(cfg)[:4]:
        m0, m1 = np.array(ins[f"mask.{site}"]), np.array(outs[f"mask.{site}"])
        assert m0.sum() == m1.sum(), "nnz budget changed"
        newly = (m1 > 0.5) & (m0 < 0.5)
        if newly.any():
            w1 = np.array(outs[f"param.{site}.w"])
            am1 = np.array(outs[f"adam_m.{site}.w"])
            assert (np.abs(w1[newly]) < 1e-8).all(), "grown weights not zeroed"
            assert (np.abs(am1[newly]) < 1e-8).all(), "grown moments not reset"


def test_infer_matches_eval_path():
    cfg = M.CONFIGS["gpt_tiny"](structure="diag", density=0.1, perm_mode="learned")
    fn, args, spec = P.make_infer(cfg, batch=2)
    names = [n for n, _, _ in spec.inputs]
    args = list(args)
    x, _ = make_batch(cfg, batch=2, seed=3)
    args[names.index("batch_x")] = x
    p0, masks0 = M.init_params(cfg), M.init_masks(cfg)
    for n, r, c in M.sparse_sites(cfg):
        k = P.row_nnz_budget(cfg, r, c)
        vals, idx = ref.compress_mask(p0[f"{n}.w"], masks0[n], k)
        args[names.index(f"vals.{n}")] = jnp.asarray(vals)
        args[names.index(f"idx.{n}")] = jnp.asarray(idx)
    (logits,) = jax.jit(fn)(*args)
    cfg_n = M.CONFIGS["gpt_tiny"](structure="diag", density=0.1, perm_mode="none")
    ctx = M.SparseCtx(
        cfg_n,
        {k: jnp.asarray(v) for k, v in masks0.items()},
        {}, {}, jnp.ones((len(M.site_names(cfg)),)),
    )
    want = M.forward(cfg_n, {k: jnp.asarray(v) for k, v in p0.items()}, ctx, x)
    np.testing.assert_allclose(np.array(logits), np.array(want), rtol=1e-4, atol=1e-4)


def test_penalty_excluded_when_hardened():
    cfg = M.CONFIGS["vit_tiny"](perm_mode="learned")
    params, masks, logits, idx, flags = state_for(cfg)
    x, y = make_batch(cfg)
    ones = jnp.ones_like(flags)
    total_h, (loss_h, _, pen_h) = M.task_loss(
        cfg, params, masks, logits, idx, ones, x, y, jnp.float32(1.0)
    )
    assert float(np.abs(np.array(pen_h)).sum()) == 0.0, "hardened penalty must be 0"
    assert float(total_h) == pytest.approx(float(loss_h), rel=1e-6)
