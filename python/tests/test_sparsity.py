"""Structure-family invariants of the mask builders and DST update rules —
the Python half of the property suite (the Rust mirror checks the same
invariants with proptest-style generators)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import sparsity

SET = settings(max_examples=10, deadline=None)

STRUCTS = ["diag", "banded", "block", "nm", "butterfly", "unstructured"]


@given(st.sampled_from(STRUCTS), st.integers(0, 10_000),
       st.sampled_from([0.05, 0.1, 0.3]))
@SET
def test_mask_density_near_target(structure, seed, density):
    m = sparsity.make_mask(structure, 128, 128, density, seed=seed)
    got = m.mean()
    # Block granularity floors density at one 16x16 block per block-row.
    floor = 16.0 / 128.0 if structure == "block" else 0.0
    target = max(density, floor)
    assert abs(got - target) < 0.06, f"{structure}: {got} vs {target}"


@given(st.integers(0, 10_000))
@SET
def test_diag_mask_exact_row_nnz(seed):
    m = sparsity.make_mask("diag", 96, 64, 0.1, seed=seed)
    k = round(0.1 * 64)
    assert (m.sum(axis=1) == k).all()


@given(st.integers(0, 10_000))
@SET
def test_nm_mask_per_group(seed):
    m = sparsity.make_mask("nm", 32, 64, 0.25, seed=seed)
    groups = m.reshape(32, 4, 16)
    assert (groups.sum(axis=-1) == 4).all()  # N = 0.25*16


def test_butterfly_static_and_deterministic():
    a = sparsity.make_mask("butterfly", 64, 64, 0.1)
    b = sparsity.make_mask("butterfly", 64, 64, 0.1, seed=99)
    assert (a == b).all()


@given(st.integers(0, 10_000), st.sampled_from([0.1, 0.3, 0.5]))
@SET
def test_unstructured_prune_grow_budget(seed, frac):
    rng = np.random.default_rng(seed)
    mask = jnp.array(sparsity.make_mask("unstructured", 32, 32, 0.2, seed=seed))
    w = jnp.array(rng.standard_normal((32, 32)).astype(np.float32))
    g = jnp.array(rng.standard_normal((32, 32)).astype(np.float32))
    new = sparsity.unstructured_prune_grow(w, mask, g, jnp.float32(frac))
    assert float(new.sum()) == float(mask.sum())
    assert set(np.unique(np.array(new))) <= {0.0, 1.0}


@given(st.integers(0, 10_000))
@SET
def test_diag_prune_grow_stays_diagonal(seed):
    rng = np.random.default_rng(seed)
    mask = jnp.array(sparsity.make_mask("diag", 32, 32, 0.15, seed=seed))
    w = jnp.array(rng.standard_normal((32, 32)).astype(np.float32))
    g = jnp.array(rng.standard_normal((32, 32)).astype(np.float32))
    new = np.array(sparsity.diag_prune_grow(w, mask, g, jnp.float32(0.4)))
    assert new.sum() == float(mask.sum())
    # Row-independent offset sets: every row has nnz at the same offsets.
    base = (np.arange(32) * 32) // 32
    offs = [frozenset((np.nonzero(new[i])[0] - base[i]) % 32) for i in range(32)]
    assert all(o == offs[0] for o in offs)


@given(st.integers(0, 10_000))
@SET
def test_block_prune_grow_stays_blocky(seed):
    rng = np.random.default_rng(seed)
    mask = jnp.array(sparsity.make_mask("block", 32, 64, 0.25, seed=seed))
    w = jnp.array(rng.standard_normal((32, 64)).astype(np.float32))
    g = jnp.array(rng.standard_normal((32, 64)).astype(np.float32))
    new = np.array(sparsity.block_prune_grow(w, mask, g, 16, jnp.float32(0.5)))
    assert new.sum() == float(np.array(mask).sum())
    blocks = new.reshape(2, 16, 4, 16).mean(axis=(1, 3))
    assert np.isin(blocks, [0.0, 1.0]).all()


@given(st.integers(0, 10_000))
@SET
def test_nm_prune_grow_preserves_group_counts(seed):
    rng = np.random.default_rng(seed)
    mask = jnp.array(sparsity.make_mask("nm", 16, 64, 0.25, seed=seed))
    w = jnp.array(rng.standard_normal((16, 64)).astype(np.float32))
    g = jnp.array(rng.standard_normal((16, 64)).astype(np.float32))
    new = np.array(sparsity.nm_prune_grow(w, mask, g, 16))
    groups = new.reshape(16, 4, 16)
    assert (groups.sum(axis=-1) == 4).all()


def test_grow_targets_hot_gradient():
    """RigL property: with zero weights, the grown positions are exactly
    the top-|grad| inactive positions."""
    mask = jnp.array(sparsity.make_mask("unstructured", 8, 8, 0.25, seed=1))
    w = jnp.zeros((8, 8), jnp.float32)
    g = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    new = np.array(sparsity.unstructured_prune_grow(w, mask, g, jnp.float32(0.5)))
    nnz = int(np.array(mask).sum())
    n_move = nnz // 2
    grown = (new > 0.5) & (np.array(mask) < 0.5)
    # Grown positions must be the highest-gradient inactive cells.
    inactive_grads = np.where(np.array(mask) < 0.5, np.array(g), -np.inf)
    top = np.argsort(-inactive_grads.ravel())[:n_move]
    assert set(np.nonzero(grown.ravel())[0]) == set(top.tolist())


def test_cosine_schedule():
    assert float(sparsity.cosine_update_frac(jnp.float32(0), 100)) == pytest.approx(0.3)
    assert float(sparsity.cosine_update_frac(jnp.float32(100), 100)) == pytest.approx(0.0, abs=1e-6)


def test_density_param_mapping_apdx_a():
    p = sparsity.make_mask  # smoke: the numeric mapping lives in common.py
    from compile.common import density_to_pattern_params
    d = density_to_pattern_params(0.05, 1024)
    assert d["K"] == 51 and d["band"] == 51
    d2 = density_to_pattern_params(0.05, 4096)
    assert d2["K"] == 205
    with pytest.raises(ValueError):
        density_to_pattern_params(0.0, 128)
