"""L1 kernel correctness: hypothesis sweeps of every Pallas kernel against
the pure-jnp oracles in ref.py — the core correctness signal of the
compile path (kernels run interpret=True, so these numerics are exactly
what the AOT artifacts compute)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import sparsity
from compile.kernels import block_spmm, gather_spmm, ref, softperm_matmul
from compile.kernels.gather_spmm import gather_spmm_ad

SET = settings(max_examples=10, deadline=None)


@st.composite
def gather_case(draw):
    batch = draw(st.integers(1, 6))
    rows = draw(st.sampled_from([8, 32, 64, 96]))
    cols = draw(st.sampled_from([16, 48, 64, 128]))
    k = draw(st.integers(1, min(cols, 12)))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((batch, cols)).astype(np.float32)
    vals = rng.standard_normal((rows, k)).astype(np.float32)
    idx = rng.integers(0, cols, (rows, k)).astype(np.int32)
    return x, vals, idx


@given(gather_case())
@SET
def test_gather_spmm_matches_ref(case):
    x, vals, idx = case
    y = gather_spmm(jnp.array(x), jnp.array(vals), jnp.array(idx))
    want = ref.gather_spmm_ref(jnp.array(x), jnp.array(vals), jnp.array(idx))
    np.testing.assert_allclose(np.array(y), np.array(want), rtol=1e-5, atol=1e-5)


@given(st.sampled_from(["diag", "nm", "butterfly"]),
       st.integers(0, 10_000),
       st.sampled_from([0.05, 0.1, 0.25, 0.5]))
@SET
def test_gather_spmm_covers_structures(structure, seed, density):
    """The compressed kernel form reproduces masked-dense for every
    fixed-row-nnz structure family."""
    rows, cols = 64, 64
    rng = np.random.default_rng(seed)
    mask = sparsity.make_mask(structure, rows, cols, density, seed=seed)
    w = rng.standard_normal((rows, cols)).astype(np.float32)
    x = rng.standard_normal((4, cols)).astype(np.float32)
    k = int(mask.sum(axis=1).max())
    vals, idx = ref.compress_mask(w, mask, k)
    y = gather_spmm(jnp.array(x), jnp.array(vals), jnp.array(idx))
    want = ref.masked_matmul_ref(jnp.array(x), jnp.array(w), jnp.array(mask))
    np.testing.assert_allclose(np.array(y), np.array(want), rtol=1e-4, atol=1e-4)


@given(st.integers(0, 10_000))
@SET
def test_gather_spmm_permutation_fusion(seed):
    """Folding a permutation into idx == shuffling x then running plain
    (Eqn. 16/18 re-indexing equivalence)."""
    rows, cols = 32, 48
    rng = np.random.default_rng(seed)
    mask = sparsity.make_mask("diag", rows, cols, 0.15, seed=seed)
    w = rng.standard_normal((rows, cols)).astype(np.float32)
    x = rng.standard_normal((3, cols)).astype(np.float32)
    perm = rng.permutation(cols)
    k = int(mask.sum(axis=1).max())
    vals, idx = ref.compress_mask(w, mask, k)
    fused = gather_spmm(jnp.array(x), jnp.array(vals), jnp.array(perm[idx].astype(np.int32)))
    shuffled = gather_spmm(jnp.array(x[:, perm]), jnp.array(vals), jnp.array(idx))
    np.testing.assert_allclose(np.array(fused), np.array(shuffled), rtol=1e-5, atol=1e-5)


@given(st.integers(0, 10_000), st.sampled_from([0.1, 0.25, 0.5]))
@SET
def test_block_spmm_matches_masked_dense(seed, density):
    rows, cols, bs = 64, 96, 16
    rng = np.random.default_rng(seed)
    mask = sparsity.make_mask("block", rows, cols, density, seed=seed, bs=bs)
    w = rng.standard_normal((rows, cols)).astype(np.float32)
    x = rng.standard_normal((4, cols)).astype(np.float32)
    blocks, bcols = ref.compress_blocks(w, mask, bs)
    y = block_spmm(jnp.array(x), jnp.array(blocks), jnp.array(bcols))
    want = ref.masked_matmul_ref(jnp.array(x), jnp.array(w), jnp.array(mask))
    np.testing.assert_allclose(np.array(y), np.array(want), rtol=1e-4, atol=1e-4)


@given(st.integers(0, 10_000), st.sampled_from([(4, 64), (8, 128), (3, 48)]))
@SET
def test_softperm_matmul_matches_ref(seed, shape):
    b, n = shape
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((b, n)).astype(np.float32)
    m = rng.standard_normal((n, n)).astype(np.float32)
    y = softperm_matmul(jnp.array(x), jnp.array(m))
    want = ref.softperm_matmul_ref(jnp.array(x), jnp.array(m))
    np.testing.assert_allclose(np.array(y), np.array(want), rtol=1e-4, atol=1e-4)


def test_gather_spmm_custom_vjp_matches_autodiff():
    """The sparse-to-sparse backward (transposition closure, Sec. 1) must
    equal autodiff of the dense reference."""
    rows, cols, k, batch = 16, 24, 4, 3
    rng = np.random.default_rng(0)
    x = jnp.array(rng.standard_normal((batch, cols)).astype(np.float32))
    vals = jnp.array(rng.standard_normal((rows, k)).astype(np.float32))
    # distinct indices per row so dense equivalence is exact
    idx = jnp.array(
        np.stack([rng.choice(cols, k, replace=False) for _ in range(rows)]).astype(np.int32)
    )

    def f_kernel(x, v):
        return jnp.sum(jnp.sin(gather_spmm_ad(x, v, idx, cols)))

    def f_ref(x, v):
        w = ref.dense_from_gather(v, idx, cols)
        return jnp.sum(jnp.sin(x @ w.T))

    gx1, gv1 = jax.grad(f_kernel, argnums=(0, 1))(x, vals)
    gx2, gv2 = jax.grad(f_ref, argnums=(0, 1))(x, vals)
    np.testing.assert_allclose(np.array(gx1), np.array(gx2), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.array(gv1), np.array(gv2), rtol=1e-4, atol=1e-5)


def test_gather_spmm_zero_padding_is_inert():
    """Padded (zero-value) slots must not contribute even with idx 0."""
    x = jnp.ones((2, 8), jnp.float32)
    vals = jnp.array([[1.0, 0.0], [2.0, 0.0]], jnp.float32)
    idx = jnp.array([[3, 0], [5, 0]], jnp.int32)
    y = gather_spmm(x, vals, idx)
    np.testing.assert_allclose(np.array(y), [[1.0, 2.0], [1.0, 2.0]])
