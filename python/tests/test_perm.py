"""Permutation-learning substrate: Sinkhorn / penalty / decode properties
(Sec. 4.2 + Sec. 6.3 metric)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import perm

SET = settings(max_examples=10, deadline=None)


@given(st.integers(0, 10_000), st.sampled_from([4, 16, 48]))
@SET
def test_sinkhorn_doubly_stochastic(seed, n):
    rng = np.random.default_rng(seed)
    m = perm.sinkhorn(jnp.array(rng.uniform(0.1, 1.0, (n, n)).astype(np.float32)), iters=20)
    np.testing.assert_allclose(np.array(m).sum(axis=1), 1.0, atol=1e-4)
    np.testing.assert_allclose(np.array(m).sum(axis=0), 1.0, atol=1e-2)
    assert (np.array(m) >= 0).all()


@given(st.integers(0, 10_000), st.sampled_from([4, 8, 32]))
@SET
def test_penalty_zero_iff_permutation(seed, n):
    rng = np.random.default_rng(seed)
    p = np.zeros((n, n), np.float32)
    p[np.arange(n), rng.permutation(n)] = 1.0
    assert float(perm.autoshuffle_penalty(jnp.array(p))) < 1e-3
    u = jnp.full((n, n), 1.0 / n)
    assert float(perm.autoshuffle_penalty(u)) > 0.5


def test_penalty_decreases_toward_vertex():
    """Interpolating from uniform to a permutation vertex monotonically
    reduces the penalty — the property gradient descent exploits."""
    n = 8
    p = np.eye(n, dtype=np.float32)
    u = np.full((n, n), 1.0 / n, np.float32)
    pens = [
        float(perm.autoshuffle_penalty(jnp.array(t * p + (1 - t) * u)))
        for t in np.linspace(0, 1, 8)
    ]
    assert all(a >= b - 1e-5 for a, b in zip(pens, pens[1:]))


@given(st.integers(0, 10_000), st.sampled_from([4, 12, 24]))
@SET
def test_greedy_decode_recovers_planted(seed, n):
    rng = np.random.default_rng(seed)
    planted = rng.permutation(n)
    m = rng.uniform(0, 0.05, (n, n))
    m[np.arange(n), planted] = 0.9
    idx = perm.greedy_decode(m)
    assert (idx == planted).all()


def test_identity_distance_metric():
    n = 16
    eye = jnp.eye(n)
    assert float(perm.identity_distance(eye)) == pytest.approx(1.0)
    rot = perm.perm_matrix_from_index(np.roll(np.arange(n), 1))
    assert float(perm.identity_distance(jnp.array(rot))) == pytest.approx(0.0, abs=1e-6)


@given(st.integers(0, 10_000))
@SET
def test_apply_perm_index_is_gather(seed):
    rng = np.random.default_rng(seed)
    n = 24
    x = rng.standard_normal((3, n)).astype(np.float32)
    idx = rng.permutation(n)
    got = np.array(perm.apply_perm_index(jnp.array(x), jnp.array(idx)))
    pmat = perm.perm_matrix_from_index(idx)
    want = x @ pmat.T
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_kaleidoscope_orthogonal_at_zero():
    """Zero angles give... the identity (cos 0 = 1 factors)."""
    n = 16
    lev = perm.n_kaleidoscope_levels(n)
    k = perm.kaleidoscope_perm(jnp.zeros((lev, n)), n)
    np.testing.assert_allclose(np.array(k), np.eye(n), atol=1e-6)


def test_soft_perm_gradient_flows():
    import jax

    n = 8
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.standard_normal((n, n)).astype(np.float32))

    def loss(l):
        m = perm.soft_perm(l)
        return perm.autoshuffle_penalty(m)

    g = jax.grad(loss)(logits)
    assert np.isfinite(np.array(g)).all()
    assert float(jnp.abs(g).sum()) > 0
