"""Adam optimizer (L2, traced into the train_step AOT program).

Matches the paper's training setup (AdamW-style decoupled weight decay,
Tbl. 7/9 hyper-parameters scaled to the tiny variants).  State is a
(m, v, step) triple of the same layout as the params so the Rust
coordinator shuttles it as opaque buffers.
"""

from __future__ import annotations

import jax.numpy as jnp

B1, B2, EPS = 0.9, 0.999, 1e-8


def adam_update(p, g, m, v, step, lr, weight_decay=0.0):
    """One Adam step for a single tensor.  ``step`` is the *post-increment*
    step count (1-based) used for bias correction."""
    m = B1 * m + (1.0 - B1) * g
    v = B2 * v + (1.0 - B2) * g * g
    mhat = m / (1.0 - B1 ** step)
    vhat = v / (1.0 - B2 ** step)
    p = p - lr * (mhat / (jnp.sqrt(vhat) + EPS) + weight_decay * p)
    return p, m, v


def tree_adam(params: dict, grads: dict, ms: dict, vs: dict, step, lr,
              weight_decay=0.0, decay_skip=("b", "g")):
    """Adam over name-keyed dicts.  Weight decay skips biases / LN gains
    (names ending in .b / .g), matching standard transformer recipes."""
    out_p, out_m, out_v = {}, {}, {}
    for k in params:
        wd = 0.0 if k.rsplit(".", 1)[-1] in decay_skip else weight_decay
        out_p[k], out_m[k], out_v[k] = adam_update(
            params[k], grads[k], ms[k], vs[k], step, lr, wd
        )
    return out_p, out_m, out_v
