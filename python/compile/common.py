"""Shared helpers for the PA-DST compile path (L1 + L2).

Everything in ``python/compile`` runs at *build time only*: it authors the
JAX/Pallas programs, checks them against pure-jnp oracles, and AOT-lowers
them to HLO text for the Rust coordinator.  Nothing here is imported on the
request path.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

DTYPE = jnp.float32


def cdiv(a: int, b: int) -> int:
    """Ceiling division."""
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    """Round ``a`` up to the next multiple of ``b``."""
    return cdiv(a, b) * b


def density_to_pattern_params(density: float, n_in: int, m: int = 16) -> dict:
    """Apdx A: map a per-layer density to structural parameters.

    Returns the diagonal count K, block per-row budget B, band half-width b
    (2b+1 nearest odd), and the tied N:M pair with N/M ~= density.
    """
    if not 0.0 < density <= 1.0:
        raise ValueError(f"density must be in (0, 1], got {density}")
    k = max(1, round(density * n_in))
    band = max(1, round(density * n_in))
    if band % 2 == 0:  # 2b+1 must be odd
        band = band + 1 if band + 1 <= n_in else band - 1
    n = max(1, round(density * m))
    return {"K": k, "B": k, "band": band, "N": n, "M": m}


@dataclasses.dataclass(frozen=True)
class LayerShape:
    """Shape of one sparsified linear layer: y = W @ (P x), W in R^{rows x cols}."""

    name: str
    rows: int
    cols: int

    @property
    def perm_dim(self) -> int:
        # One column permutation per layer permutes the layer *input*.
        return self.cols


def tree_size(tree) -> int:
    """Total number of scalars in a pytree of arrays."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def flatten_names(prefix: str, names: Sequence[str]) -> list[str]:
    return [f"{prefix}.{n}" for n in names]


def uniform_init(key, shape, scale=None):
    """LeCun-uniform style init matching what the paper's baselines use."""
    fan_in = shape[-1] if len(shape) > 1 else shape[0]
    if scale is None:
        scale = 1.0 / math.sqrt(fan_in)
    return jax.random.uniform(key, shape, DTYPE, -scale, scale)
