"""L2 program builders: the exact functions AOT-lowered to HLO artifacts.

Every program takes and returns *flat positional* arrays so the Rust
coordinator can marshal buffers without a pytree library; the ordering is
captured by ``ProgramSpec`` and serialised into ``artifacts/manifest.json``
by aot.py.

Programs per model variant:

* ``train_step``   — fwd/bwd + Adam + Eqn. 13 penalty; per-layer soft/hard
                     permutation selected at runtime via ``hard_flags``.
* ``dst_update``   — RigL/SET/MEST-style prune-and-grow *within the
                     structure family* (sparsity.py); recomputes a dense
                     gradient wrt the effective weights on the given batch
                     (exactly RigL's grow signal), returns new masks with
                     newly-grown weights and their Adam moments zeroed.
* ``eval_step``    — loss + correct-count on an eval batch.
* ``infer``        — the hardened inference graph: every sparse site runs
                     the L1 ``gather_spmm`` Pallas kernel on compressed
                     (vals, idx) weights with the learned permutation
                     pre-composed into idx (re-indexing, Eqn. 16/18).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from . import optim, sparsity
from .common import DTYPE
from .kernels.gather_spmm import gather_spmm

# ---------------------------------------------------------------------------
# Flat <-> dict marshalling
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ProgramSpec:
    """Input/output layout of one AOT program (serialised to the manifest)."""

    name: str
    inputs: list[tuple[str, list[int], str]]   # (name, shape, dtype)
    outputs: list[tuple[str, list[int], str]]

    def to_json(self):
        return {
            "name": self.name,
            "inputs": [
                {"name": n, "shape": s, "dtype": d} for n, s, d in self.inputs
            ],
            "outputs": [
                {"name": n, "shape": s, "dtype": d} for n, s, d in self.outputs
            ],
        }


def param_names(cfg: M.ModelConfig) -> list[str]:
    return list(M.init_params(cfg).keys())


def row_nnz_budget(cfg: M.ModelConfig, rows: int, cols: int,
                   bs: int = 16, m: int = 16) -> int:
    """Deterministic per-row nnz of the compressed inference form, agreed
    between aot.py (shape baking) and the Rust compressor."""
    s = cfg.structure
    if s in ("diag", "banded", "butterfly"):
        k = max(1, round(cfg.density * cols))
        if s == "banded":
            k += (k + 1) % 2
        return min(k, cols)
    if s == "nm":
        return (cols // m) * max(1, round(cfg.density * m))
    if s == "block":
        return min(cols, max(1, round(cfg.density * (cols // bs))) * bs)
    if s == "unstructured":
        # Global budget; rows vary.  Pad to 2x the mean (clipped rows lose
        # their smallest-|w| tail — documented in DESIGN.md).
        return min(cols, max(1, int(np.ceil(cfg.density * cols * 2))))
    if s == "dense":
        return cols
    raise ValueError(s)


def batch_spec(cfg: M.ModelConfig, batch: int):
    if cfg.kind == "gpt":
        x = ("batch_x", [batch, cfg.seq_len], "i32")
        y = ("batch_y", [batch, cfg.seq_len], "i32")
    else:
        x = ("batch_x", [batch, cfg.image, cfg.image, 3], "f32")
        y = ("batch_y", [batch], "i32")
    return x, y


def _dict_from(names, arrays):
    return dict(zip(names, arrays))


# ---------------------------------------------------------------------------
# train_step
# ---------------------------------------------------------------------------


def make_train_step(cfg: M.ModelConfig, batch: int):
    """Returns (fn, example_args, ProgramSpec) for AOT lowering."""
    pnames = param_names(cfg)
    snames = M.site_names(cfg)
    p0 = M.init_params(cfg)
    masks0 = M.init_masks(cfg)
    logits0, idx0, flags0 = M.init_perm_state(cfg)
    n_sites = len(snames)
    has_perm = cfg.perm_mode in ("learned", "kaleidoscope", "random")

    def fn(*args):
        it = iter(args)
        params = _dict_from(pnames, [next(it) for _ in pnames])
        ms = _dict_from(pnames, [next(it) for _ in pnames])
        vs = _dict_from(pnames, [next(it) for _ in pnames])
        step = next(it)
        masks = _dict_from(snames, [next(it) for _ in snames])
        if has_perm:
            plog = _dict_from(snames, [next(it) for _ in snames])
            pidx = _dict_from(snames, [next(it) for _ in snames])
            flags = next(it)
        else:
            plog, pidx, flags = {}, {}, jnp.ones((n_sites,), DTYPE)
        bx, by = next(it), next(it)
        lr, lam = next(it), next(it)

        trainable = dict(params)
        if cfg.perm_mode in ("learned", "kaleidoscope"):
            for n in snames:
                trainable[f"__perm__{n}"] = plog[n]

        def loss_fn(tr):
            pr = {k: v for k, v in tr.items() if not k.startswith("__perm__")}
            pl = {n: tr[f"__perm__{n}"] for n in snames} \
                if cfg.perm_mode in ("learned", "kaleidoscope") else plog
            return M.task_loss(cfg, pr, masks, pl, pidx, flags, bx, by, lam)

        grads, (loss, correct, pen) = jax.grad(loss_fn, has_aux=True)(trainable)
        step1 = step + 1.0
        # Perm logits use the same Adam state layout appended after params?
        # No: perm logits carry their own SGD-style update (AutoShuffleNet
        # uses plain projected gradient on the soft matrix) — simpler state,
        # and hardened layers get exactly-zero updates via the cond grad.
        new_p, new_m, new_v = optim.tree_adam(
            {k: params[k] for k in pnames},
            {k: grads[k] for k in pnames},
            ms, vs, step1, lr, weight_decay=1e-4,
        )
        outs = [new_p[k] for k in pnames] + [new_m[k] for k in pnames] + \
               [new_v[k] for k in pnames] + [step1]
        if cfg.perm_mode in ("learned", "kaleidoscope"):
            perm_lr = 10.0 * lr  # permutations need a hotter LR (Lyu et al.)
            outs += [plog[n] - perm_lr * grads[f"__perm__{n}"] for n in snames]
        outs += [loss, correct, pen]
        return tuple(outs)

    # Example args (concrete shapes for lowering) + spec.
    bx_spec, by_spec = batch_spec(cfg, batch)
    inputs, args = [], []

    def add(name, arr, dtype="f32"):
        inputs.append((name, list(arr.shape), dtype))
        args.append(jnp.asarray(arr))

    for k in pnames:
        add(f"param.{k}", p0[k])
    for k in pnames:
        add(f"adam_m.{k}", np.zeros_like(p0[k]))
    for k in pnames:
        add(f"adam_v.{k}", np.zeros_like(p0[k]))
    add("step", np.zeros((), np.float32))
    for n in snames:
        add(f"mask.{n}", masks0[n])
    if has_perm:
        for n in snames:
            add(f"perm_logits.{n}", logits0[n])
        for n in snames:
            add(f"perm_idx.{n}", idx0[n], "i32")
        add("hard_flags", flags0)
    if cfg.kind == "gpt":
        add("batch_x", np.zeros(bx_spec[1], np.int32), "i32")
        add("batch_y", np.zeros(by_spec[1], np.int32), "i32")
    else:
        add("batch_x", np.zeros(bx_spec[1], np.float32))
        add("batch_y", np.zeros(by_spec[1], np.int32), "i32")
    add("lr", np.asarray(1e-3, np.float32))
    add("lambda", np.asarray(0.1, np.float32))

    outputs = [(f"param.{k}", list(p0[k].shape), "f32") for k in pnames]
    outputs += [(f"adam_m.{k}", list(p0[k].shape), "f32") for k in pnames]
    outputs += [(f"adam_v.{k}", list(p0[k].shape), "f32") for k in pnames]
    outputs += [("step", [], "f32")]
    if cfg.perm_mode in ("learned", "kaleidoscope"):
        outputs += [(f"perm_logits.{n}", list(logits0[n].shape), "f32")
                    for n in snames]
    outputs += [("loss", [], "f32"), ("correct", [], "f32"),
                ("penalties", [n_sites], "f32")]
    return fn, args, ProgramSpec("train_step", inputs, outputs)


# ---------------------------------------------------------------------------
# dst_update
# ---------------------------------------------------------------------------


def make_dst_update(cfg: M.ModelConfig, batch: int):
    """Prune-and-grow program.  grow_mode: 0=RigL(|grad|), 1=SET(random),
    2=MEST(|grad| + 0.3|w|) — only meaningful for unstructured; structured
    families use their own unit-level rules."""
    pnames = param_names(cfg)
    snames = M.site_names(cfg)
    sites = {n: (r, c) for n, r, c in M.sparse_sites(cfg)}
    p0 = M.init_params(cfg)
    masks0 = M.init_masks(cfg)
    logits0, idx0, flags0 = M.init_perm_state(cfg)
    has_perm = cfg.perm_mode in ("learned", "kaleidoscope", "random")

    def fn(*args):
        it = iter(args)
        params = _dict_from(pnames, [next(it) for _ in pnames])
        ms = _dict_from(pnames, [next(it) for _ in pnames])
        vs = _dict_from(pnames, [next(it) for _ in pnames])
        masks = _dict_from(snames, [next(it) for _ in snames])
        if has_perm:
            plog = _dict_from(snames, [next(it) for _ in snames])
            pidx = _dict_from(snames, [next(it) for _ in snames])
            flags = next(it)
        else:
            plog, pidx, flags = {}, {}, jnp.ones((len(snames),), DTYPE)
        bx, by = next(it), next(it)
        frac, grow_mode, seed = next(it), next(it), next(it)

        # Dense grow signal: differentiate wrt the *effective* (masked)
        # weights so inactive coordinates get real gradients (RigL Sec. 3).
        eff = {n: params[f"{n}.w"] * masks[n] for n in snames}

        def loss_fn(eff_d):
            pr = dict(params)
            mk = dict(masks)
            for n in snames:
                pr[f"{n}.w"] = eff_d[n]
                mk[n] = jnp.ones_like(masks[n])
            total, _ = M.task_loss(cfg, pr, mk, plog, pidx, flags, bx, by,
                                   jnp.zeros((), DTYPE))
            return total

        dense_grads = jax.grad(loss_fn)(eff)

        key = jax.random.PRNGKey(seed)
        new_masks, new_p, new_m, new_v = {}, dict(params), dict(ms), dict(vs)
        for i, n in enumerate(snames):
            w, mask, g = params[f"{n}.w"], masks[n], dense_grads[n]
            if cfg.structure == "unstructured":
                k1 = jax.random.fold_in(key, i)
                rand = jax.random.uniform(k1, w.shape, DTYPE)
                gs = jax.lax.switch(
                    grow_mode,
                    [lambda: jnp.abs(g),                       # RigL
                     lambda: rand,                              # SET
                     lambda: jnp.abs(g) + 0.3 * jnp.abs(w)],    # MEST
                )
                nm = sparsity.unstructured_prune_grow(w, mask, g, frac, gs)
            else:
                nm = sparsity.dst_update_for(cfg.structure, w, mask, g, frac)
            newly = nm * (1.0 - mask)
            keep = 1.0 - newly
            new_masks[n] = nm
            new_p[f"{n}.w"] = w * keep        # new connections start at 0
            new_m[f"{n}.w"] = ms[f"{n}.w"] * keep
            new_v[f"{n}.w"] = vs[f"{n}.w"] * keep

        return tuple([new_p[k] for k in pnames] + [new_m[k] for k in pnames] +
                     [new_v[k] for k in pnames] + [new_masks[n] for n in snames])

    bx_spec, by_spec = batch_spec(cfg, batch)
    inputs, args = [], []

    def add(name, arr, dtype="f32"):
        inputs.append((name, list(arr.shape), dtype))
        args.append(jnp.asarray(arr))

    for k in pnames:
        add(f"param.{k}", p0[k])
    for k in pnames:
        add(f"adam_m.{k}", np.zeros_like(p0[k]))
    for k in pnames:
        add(f"adam_v.{k}", np.zeros_like(p0[k]))
    for n in snames:
        add(f"mask.{n}", masks0[n])
    if has_perm:
        for n in snames:
            add(f"perm_logits.{n}", logits0[n])
        for n in snames:
            add(f"perm_idx.{n}", idx0[n], "i32")
        add("hard_flags", flags0)
    add("batch_x", np.zeros(bx_spec[1], np.int32 if cfg.kind == "gpt" else np.float32),
        "i32" if cfg.kind == "gpt" else "f32")
    add("batch_y", np.zeros(by_spec[1], np.int32), "i32")
    add("frac", np.asarray(0.3, np.float32))
    inputs.append(("grow_mode", [], "i32"))
    args.append(jnp.asarray(0, jnp.int32))
    inputs.append(("seed", [], "i32"))
    args.append(jnp.asarray(0, jnp.int32))

    outputs = [(f"param.{k}", list(p0[k].shape), "f32") for k in pnames]
    outputs += [(f"adam_m.{k}", list(p0[k].shape), "f32") for k in pnames]
    outputs += [(f"adam_v.{k}", list(p0[k].shape), "f32") for k in pnames]
    outputs += [(f"mask.{n}", list(masks0[n].shape), "f32") for n in snames]
    return fn, args, ProgramSpec("dst_update", inputs, outputs)


# ---------------------------------------------------------------------------
# eval_step
# ---------------------------------------------------------------------------


def make_eval_step(cfg: M.ModelConfig, batch: int):
    pnames = param_names(cfg)
    snames = M.site_names(cfg)
    p0 = M.init_params(cfg)
    masks0 = M.init_masks(cfg)
    logits0, idx0, flags0 = M.init_perm_state(cfg)
    has_perm = cfg.perm_mode in ("learned", "kaleidoscope", "random")

    def fn(*args):
        it = iter(args)
        params = _dict_from(pnames, [next(it) for _ in pnames])
        masks = _dict_from(snames, [next(it) for _ in snames])
        if has_perm:
            plog = _dict_from(snames, [next(it) for _ in snames])
            pidx = _dict_from(snames, [next(it) for _ in snames])
            flags = next(it)
        else:
            plog, pidx, flags = {}, {}, jnp.ones((len(snames),), DTYPE)
        bx, by = next(it), next(it)
        _, (loss, correct, pen) = M.task_loss(
            cfg, params, masks, plog, pidx, flags, bx, by, jnp.zeros((), DTYPE)
        )
        return loss, correct, pen

    bx_spec, by_spec = batch_spec(cfg, batch)
    inputs, args = [], []

    def add(name, arr, dtype="f32"):
        inputs.append((name, list(arr.shape), dtype))
        args.append(jnp.asarray(arr))

    for k in pnames:
        add(f"param.{k}", p0[k])
    for n in snames:
        add(f"mask.{n}", masks0[n])
    if has_perm:
        for n in snames:
            add(f"perm_logits.{n}", logits0[n])
        for n in snames:
            add(f"perm_idx.{n}", idx0[n], "i32")
        add("hard_flags", flags0)
    add("batch_x", np.zeros(bx_spec[1], np.int32 if cfg.kind == "gpt" else np.float32),
        "i32" if cfg.kind == "gpt" else "f32")
    add("batch_y", np.zeros(by_spec[1], np.int32), "i32")

    outputs = [("loss", [], "f32"), ("correct", [], "f32"),
               ("penalties", [len(snames)], "f32")]
    return fn, args, ProgramSpec("eval_step", inputs, outputs)


# ---------------------------------------------------------------------------
# infer — hardened graph on L1 Pallas kernels
# ---------------------------------------------------------------------------


def make_infer(cfg: M.ModelConfig, batch: int):
    """Inference with every sparse site compressed to (vals, idx) and the
    permutation folded into idx.  idx therefore maps output-row slot k to
    the *pre-permutation* input coordinate: idx'[i,k] = perm[idx[i,k]],
    exactly the re-indexed sparse GEMM of Eqn. 16/18, and the site executes
    as the gather_spmm Pallas kernel."""
    pnames = param_names(cfg)
    snames = M.site_names(cfg)
    sites = {n: (r, c) for n, r, c in M.sparse_sites(cfg)}
    p0 = M.init_params(cfg)

    class KernelCtx(M.SparseCtx):
        def __init__(self, cfg, vals, idx):
            super().__init__(cfg, {}, {}, {}, jnp.ones((len(snames),), DTYPE))
            self.vals, self.kidx = vals, idx

    def kernel_sparse_linear(ctx, params, name, x):
        vals, idx = ctx.vals[name], ctx.kidx[name]
        shape = x.shape
        x2 = x.reshape(-1, shape[-1])
        y = gather_spmm(x2, vals, idx)
        y = y + params[f"{name}.b"]
        return y.reshape(*shape[:-1], vals.shape[0])

    def fn(*args):
        it = iter(args)
        vals = _dict_from(snames, [next(it) for _ in snames])
        idx = _dict_from(snames, [next(it) for _ in snames])
        params = _dict_from(pnames, [next(it) for _ in pnames])
        bx = next(it)
        ctx = KernelCtx(cfg, vals, idx)
        orig = M.sparse_linear
        M.sparse_linear = kernel_sparse_linear  # route sites to the kernel
        try:
            logits = M.forward(cfg, params, ctx, bx)
        finally:
            M.sparse_linear = orig
        return (logits,)

    bx_spec, _ = batch_spec(cfg, batch)
    inputs, args = [], []

    def add(name, arr, dtype="f32"):
        inputs.append((name, list(arr.shape), dtype))
        args.append(jnp.asarray(arr))

    for n in snames:
        r, c = sites[n]
        k = row_nnz_budget(cfg, r, c)
        add(f"vals.{n}", np.zeros((r, k), np.float32))
    for n in snames:
        r, c = sites[n]
        k = row_nnz_budget(cfg, r, c)
        add(f"idx.{n}", np.zeros((r, k), np.int32), "i32")
    for k2 in pnames:
        add(f"param.{k2}", p0[k2])
    add("batch_x", np.zeros(bx_spec[1], np.int32 if cfg.kind == "gpt" else np.float32),
        "i32" if cfg.kind == "gpt" else "f32")

    if cfg.kind == "gpt":
        out_shape = [batch, cfg.seq_len, cfg.vocab]
    else:
        out_shape = [batch, cfg.n_classes]
    outputs = [("logits", out_shape, "f32")]
    return fn, args, ProgramSpec("infer", inputs, outputs)
