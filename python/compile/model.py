"""L2: PA-DST transformer family (ViT / GPT-2 / MLP-Mixer) in JAX.

Implements the paper's layer formulation (Sec. 4.1/4.3): every sparsified
linear is

    y = (W * mask) @ (P x) + b          (column permutation, default)
    y = P @ ((W * mask) x) + b          (row permutation, Tbl. 10 ablation)

where ``mask`` obeys a structure family (sparsity.py) and P is either
absent, a fixed random permutation, a learned soft permutation
M = sinkhorn(softplus(logits)) with the AutoShuffle penalty (perm.py), or a
hardened permutation applied by *re-indexing* (a gather — Eqn. 16/18).

Hardening is a per-layer runtime decision made by the Rust coordinator
(Apdx C.2): the training graph takes a ``hard_flags`` vector and uses
``lax.cond`` per sparse site, so a hardened layer pays a gather instead of
the N x N soft-perm matmul without recompiling.

Sparsified sites follow Apdx C.5: ViT — patch projection, MHA output
projection, both FFN linears; GPT — all attention (QKV + output) and MLP
linears; Mixer — channel-MLP linears.

Parameters are name-keyed dicts with a deterministic ordering captured in
the AOT manifest so the Rust side can lay out its buffers identically.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import perm as perm_lib
from . import sparsity
from .common import DTYPE

# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    kind: str  # "vit" | "gpt" | "mixer"
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int            # tokens (vit/mixer: patches; gpt: context)
    vocab: int = 0          # gpt only
    n_classes: int = 0      # vit/mixer only
    image: int = 16         # vit/mixer input image side
    patch: int = 4
    tok_hidden: int = 64    # mixer token-mixing hidden
    # sparsity + permutation setup
    structure: str = "diag"
    density: float = 0.1
    perm_mode: str = "learned"  # none | random | learned | kaleidoscope
    perm_side: str = "col"      # col | row (Tbl. 10 ablation)
    sinkhorn_iters: int = 8
    seed: int = 0

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def n_patches(self) -> int:
        return (self.image // self.patch) ** 2

    @property
    def patch_dim(self) -> int:
        return self.patch * self.patch * 3


def vit_tiny(**kw) -> ModelConfig:
    return ModelConfig(kind="vit", name="vit_tiny", d_model=128, n_layers=4,
                       n_heads=4, d_ff=256, seq_len=17, n_classes=16,
                       image=16, patch=4, **kw)


def gpt_tiny(**kw) -> ModelConfig:
    return ModelConfig(kind="gpt", name="gpt_tiny", d_model=128, n_layers=4,
                       n_heads=4, d_ff=256, seq_len=64, vocab=256, **kw)


def mixer_tiny(**kw) -> ModelConfig:
    return ModelConfig(kind="mixer", name="mixer_tiny", d_model=128,
                       n_layers=4, n_heads=1, d_ff=256, seq_len=16,
                       n_classes=16, image=16, patch=4, tok_hidden=64, **kw)


def gpt_small(**kw) -> ModelConfig:
    """Scaled-up GPT config for the end-to-end example (examples/train_gpt.rs).
    ~7 M params — the largest a single-core CPU trains a few hundred steps
    of in-budget; stands in for the paper's GPT-2 Small (Tbl. 12)."""
    return ModelConfig(kind="gpt", name="gpt_small", d_model=256, n_layers=8,
                       n_heads=8, d_ff=512, seq_len=128, vocab=512, **kw)


CONFIGS: dict[str, Callable[..., ModelConfig]] = {
    "vit_tiny": vit_tiny,
    "gpt_tiny": gpt_tiny,
    "mixer_tiny": mixer_tiny,
    "gpt_small": gpt_small,
}


# ---------------------------------------------------------------------------
# Sparse site enumeration
# ---------------------------------------------------------------------------


def sparse_sites(cfg: ModelConfig) -> list[tuple[str, int, int]]:
    """Ordered (name, rows, cols) of every sparsified linear (Apdx C.5)."""
    d, ff = cfg.d_model, cfg.d_ff
    sites: list[tuple[str, int, int]] = []
    if cfg.kind == "vit":
        sites.append(("patch_proj", d, cfg.patch_dim))
        for i in range(cfg.n_layers):
            sites += [
                (f"blk{i}.attn_out", d, d),
                (f"blk{i}.fc1", ff, d),
                (f"blk{i}.fc2", d, ff),
            ]
    elif cfg.kind == "gpt":
        for i in range(cfg.n_layers):
            sites += [
                (f"blk{i}.qkv", 3 * d, d),
                (f"blk{i}.attn_out", d, d),
                (f"blk{i}.fc1", ff, d),
                (f"blk{i}.fc2", d, ff),
            ]
    elif cfg.kind == "mixer":
        for i in range(cfg.n_layers):
            sites += [
                (f"blk{i}.chan_fc1", ff, d),
                (f"blk{i}.chan_fc2", d, ff),
            ]
    else:
        raise ValueError(cfg.kind)
    return sites


def site_names(cfg: ModelConfig) -> list[str]:
    return [s[0] for s in sparse_sites(cfg)]


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, seed: int | None = None) -> dict[str, np.ndarray]:
    """Deterministic name->array parameter dict (numpy, build-time)."""
    rng = np.random.default_rng(cfg.seed if seed is None else seed)
    d, ff = cfg.d_model, cfg.d_ff
    p: dict[str, np.ndarray] = {}

    def lin(name, rows, cols):
        scale = 1.0 / math.sqrt(cols)
        p[f"{name}.w"] = rng.uniform(-scale, scale, (rows, cols)).astype(np.float32)
        p[f"{name}.b"] = np.zeros((rows,), np.float32)

    def ln(name, dim):
        p[f"{name}.g"] = np.ones((dim,), np.float32)
        p[f"{name}.b"] = np.zeros((dim,), np.float32)

    if cfg.kind == "vit":
        lin("patch_proj", d, cfg.patch_dim)
        p["cls"] = (rng.standard_normal((d,)) * 0.02).astype(np.float32)
        p["pos"] = (rng.standard_normal((cfg.n_patches + 1, d)) * 0.02).astype(np.float32)
        for i in range(cfg.n_layers):
            ln(f"blk{i}.ln1", d)
            lin(f"blk{i}.qkv", 3 * d, d)
            lin(f"blk{i}.attn_out", d, d)
            ln(f"blk{i}.ln2", d)
            lin(f"blk{i}.fc1", ff, d)
            lin(f"blk{i}.fc2", d, ff)
        ln("ln_f", d)
        lin("head", cfg.n_classes, d)
    elif cfg.kind == "gpt":
        p["tok_emb"] = (rng.standard_normal((cfg.vocab, d)) * 0.02).astype(np.float32)
        p["pos_emb"] = (rng.standard_normal((cfg.seq_len, d)) * 0.02).astype(np.float32)
        for i in range(cfg.n_layers):
            ln(f"blk{i}.ln1", d)
            lin(f"blk{i}.qkv", 3 * d, d)
            lin(f"blk{i}.attn_out", d, d)
            ln(f"blk{i}.ln2", d)
            lin(f"blk{i}.fc1", ff, d)
            lin(f"blk{i}.fc2", d, ff)
        ln("ln_f", d)
        lin("head", cfg.vocab, d)
    elif cfg.kind == "mixer":
        lin("patch_proj", d, cfg.patch_dim)
        for i in range(cfg.n_layers):
            ln(f"blk{i}.ln1", d)
            lin(f"blk{i}.tok_fc1", cfg.tok_hidden, cfg.seq_len)
            lin(f"blk{i}.tok_fc2", cfg.seq_len, cfg.tok_hidden)
            ln(f"blk{i}.ln2", d)
            lin(f"blk{i}.chan_fc1", ff, d)
            lin(f"blk{i}.chan_fc2", d, ff)
        ln("ln_f", d)
        lin("head", cfg.n_classes, d)
    return p


def init_masks(cfg: ModelConfig, seed: int | None = None) -> dict[str, np.ndarray]:
    base = cfg.seed if seed is None else seed
    return {
        name: sparsity.make_mask(cfg.structure, rows, cols, cfg.density,
                                 seed=base * 1000 + i)
        for i, (name, rows, cols) in enumerate(sparse_sites(cfg))
    }


def init_perm_state(cfg: ModelConfig, seed: int | None = None):
    """(perm_logits, perm_idx, hard_flags) initial state.

    * ``none``: identity idx, flags=1 (hard path, identity gather ~ no-op).
    * ``random``: fixed random idx, flags=1 from step 0 (Tbl. 11 'Random').
    * ``learned``: logits near-uniform with a small identity bias, flags=0.
    * ``kaleidoscope``: butterfly angles instead of N x N logits.
    """
    base = cfg.seed if seed is None else seed
    rng = np.random.default_rng(base + 7)
    logits, idx = {}, {}
    flags = []
    for name, rows, cols in sparse_sites(cfg):
        n = cols if cfg.perm_side == "col" else rows
        if cfg.perm_mode == "kaleidoscope":
            lev = perm_lib.n_kaleidoscope_levels(n)
            logits[name] = (rng.standard_normal((lev, n)) * 0.01).astype(np.float32)
        else:
            logits[name] = (0.01 * rng.standard_normal((n, n)) + np.eye(n) * 5.0
                            ).astype(np.float32)
        if cfg.perm_mode == "random":
            idx[name] = perm_lib.random_perm_index(n, base * 31 + len(idx)).astype(np.int32)
        else:
            idx[name] = np.arange(n, dtype=np.int32)
        flags.append(0.0 if cfg.perm_mode in ("learned", "kaleidoscope") else 1.0)
    return logits, idx, np.array(flags, np.float32)


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


class SparseCtx:
    """Carries masks / permutation state / penalty accumulator through the
    forward pass.  ``penalties`` lines up with ``site_names(cfg)``."""

    def __init__(self, cfg: ModelConfig, masks, perm_logits, perm_idx, hard_flags):
        self.cfg = cfg
        self.masks = masks
        self.logits = perm_logits
        self.idx = perm_idx
        self.flags = hard_flags
        self.order = site_names(cfg)
        self.penalties: dict[str, jnp.ndarray] = {}

    def penalty_vector(self) -> jnp.ndarray:
        zero = jnp.zeros((), DTYPE)
        return jnp.stack([self.penalties.get(n, zero) for n in self.order])


def _apply_perm(ctx: SparseCtx, name: str, x: jnp.ndarray) -> jnp.ndarray:
    """Apply this site's input permutation along the last axis of x."""
    cfg = ctx.cfg
    if cfg.perm_mode == "none":
        return x
    i = ctx.order.index(name)
    flag = ctx.flags[i]
    idx = ctx.idx[name]

    def hard(xv):
        # Re-indexing (Eqn. 16/18): a gather, zero penalty, no Sinkhorn.
        return jnp.take(xv, idx, axis=-1), jnp.zeros((), DTYPE)

    if cfg.perm_mode == "random":
        ctx.penalties[name] = jnp.zeros((), DTYPE)
        return hard(x)[0]

    def soft(xv):
        # The soft matrix and its penalty are traced *inside* the branch so
        # a hardened layer skips the whole Sinkhorn + N x N matmul cost —
        # this is where the early-stopping training speedup of Apdx C.2
        # comes from.
        if cfg.perm_mode == "kaleidoscope":
            m = perm_lib.kaleidoscope_perm(ctx.logits[name], xv.shape[-1])
        else:
            m = perm_lib.soft_perm(ctx.logits[name], cfg.sinkhorn_iters)
        return xv @ m.T, perm_lib.autoshuffle_penalty(m)

    out, pen = jax.lax.cond(flag > 0.5, hard, soft, x)
    ctx.penalties[name] = pen
    return out


def sparse_linear(ctx: SparseCtx, params, name: str, x: jnp.ndarray) -> jnp.ndarray:
    """y = (W*mask)(P x) + b  (col perm)  or  P((W*mask) x) + b  (row perm)."""
    w = params[f"{name}.w"] * ctx.masks[name]
    b = params[f"{name}.b"]
    if ctx.cfg.perm_side == "col":
        x = _apply_perm(ctx, name, x)
        return x @ w.T + b
    y = x @ w.T
    return _apply_perm(ctx, name, y) + b


def _dense_linear(params, name, x):
    return x @ params[f"{name}.w"].T + params[f"{name}.b"]


def _attention(cfg: ModelConfig, params, ctx: SparseCtx, name: str,
               x: jnp.ndarray, causal: bool, qkv_sparse: bool) -> jnp.ndarray:
    b, t, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    if qkv_sparse:
        qkv = sparse_linear(ctx, params, f"{name}.qkv", x)
    else:
        qkv = _dense_linear(params, f"{name}.qkv", x)
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(z):
        return z.reshape(b, t, h, hd).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(hd)
    if causal:
        neg = jnp.full((t, t), -1e30, DTYPE)
        att = att + jnp.triu(neg, k=1)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, d)
    # MHA output projection — always a sparse site (Sec. 4.3).
    return sparse_linear(ctx, params, f"{name}.attn_out", out)


def _vit_forward(cfg, params, ctx, images):
    """images: (B, image, image, 3) -> logits (B, n_classes)."""
    b = images.shape[0]
    p = cfg.patch
    n = cfg.image // p
    patches = images.reshape(b, n, p, n, p, 3).transpose(0, 1, 3, 2, 4, 5)
    patches = patches.reshape(b, n * n, cfg.patch_dim)
    x = sparse_linear(ctx, params, "patch_proj", patches)
    cls = jnp.broadcast_to(params["cls"], (b, 1, cfg.d_model))
    x = jnp.concatenate([cls, x], axis=1) + params["pos"]
    for i in range(cfg.n_layers):
        nm = f"blk{i}"
        a = _layer_norm(x, params[f"{nm}.ln1.g"], params[f"{nm}.ln1.b"])
        x = x + _attention(cfg, params, ctx, nm, a, causal=False, qkv_sparse=False)
        a = _layer_norm(x, params[f"{nm}.ln2.g"], params[f"{nm}.ln2.b"])
        hdn = jax.nn.gelu(sparse_linear(ctx, params, f"{nm}.fc1", a))
        x = x + sparse_linear(ctx, params, f"{nm}.fc2", hdn)
    x = _layer_norm(x, params["ln_f.g"], params["ln_f.b"])
    return _dense_linear(params, "head", x[:, 0])


def _gpt_forward(cfg, params, ctx, tokens):
    """tokens: (B, T) int32 -> logits (B, T, vocab)."""
    x = params["tok_emb"][tokens] + params["pos_emb"][None, :, :]
    for i in range(cfg.n_layers):
        nm = f"blk{i}"
        a = _layer_norm(x, params[f"{nm}.ln1.g"], params[f"{nm}.ln1.b"])
        x = x + _attention(cfg, params, ctx, nm, a, causal=True, qkv_sparse=True)
        a = _layer_norm(x, params[f"{nm}.ln2.g"], params[f"{nm}.ln2.b"])
        hdn = jax.nn.gelu(sparse_linear(ctx, params, f"{nm}.fc1", a))
        x = x + sparse_linear(ctx, params, f"{nm}.fc2", hdn)
    x = _layer_norm(x, params["ln_f.g"], params["ln_f.b"])
    return _dense_linear(params, "head", x)


def _mixer_forward(cfg, params, ctx, images):
    b = images.shape[0]
    p = cfg.patch
    n = cfg.image // p
    patches = images.reshape(b, n, p, n, p, 3).transpose(0, 1, 3, 2, 4, 5)
    patches = patches.reshape(b, n * n, cfg.patch_dim)
    x = _dense_linear(params, "patch_proj", patches)
    for i in range(cfg.n_layers):
        nm = f"blk{i}"
        a = _layer_norm(x, params[f"{nm}.ln1.g"], params[f"{nm}.ln1.b"])
        a = a.transpose(0, 2, 1)  # (B, d, tokens)
        a = jax.nn.gelu(_dense_linear(params, f"{nm}.tok_fc1", a))
        a = _dense_linear(params, f"{nm}.tok_fc2", a)
        x = x + a.transpose(0, 2, 1)
        a = _layer_norm(x, params[f"{nm}.ln2.g"], params[f"{nm}.ln2.b"])
        hdn = jax.nn.gelu(sparse_linear(ctx, params, f"{nm}.chan_fc1", a))
        x = x + sparse_linear(ctx, params, f"{nm}.chan_fc2", hdn)
    x = _layer_norm(x, params["ln_f.g"], params["ln_f.b"])
    return _dense_linear(params, "head", jnp.mean(x, axis=1))


def forward(cfg: ModelConfig, params, ctx: SparseCtx, batch_x):
    if cfg.kind == "vit":
        return _vit_forward(cfg, params, ctx, batch_x)
    if cfg.kind == "gpt":
        return _gpt_forward(cfg, params, ctx, batch_x)
    if cfg.kind == "mixer":
        return _mixer_forward(cfg, params, ctx, batch_x)
    raise ValueError(cfg.kind)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def loss_and_metrics(cfg: ModelConfig, logits, batch_y):
    """(mean task loss, #correct).  Vision: CE over classes; LM: next-token
    CE (targets are the pre-shifted batch_y from the data pipeline)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    if cfg.kind == "gpt":
        ll = jnp.take_along_axis(logp, batch_y[..., None], axis=-1)[..., 0]
        loss = -jnp.mean(ll)
        correct = jnp.sum((jnp.argmax(logits, -1) == batch_y).astype(DTYPE))
        return loss, correct
    ll = jnp.take_along_axis(logp, batch_y[:, None], axis=-1)[:, 0]
    loss = -jnp.mean(ll)
    correct = jnp.sum((jnp.argmax(logits, -1) == batch_y).astype(DTYPE))
    return loss, correct


def task_loss(cfg: ModelConfig, params, masks, perm_logits, perm_idx,
              hard_flags, batch_x, batch_y, lam):
    """Eqn. 13: L_task + lambda * sum_l P(M_l).  Returns (total, aux)."""
    ctx = SparseCtx(cfg, masks, perm_logits, perm_idx, hard_flags)
    logits = forward(cfg, params, ctx, batch_x)
    loss, correct = loss_and_metrics(cfg, logits, batch_y)
    pen = ctx.penalty_vector()
    total = loss + lam * jnp.sum(pen * (1.0 - hard_flags))
    return total, (loss, correct, pen)
