"""Structured sparsity substrate (L2): mask builders and DST update rules.

The paper studies three canonical accelerator-friendly structures plus the
unstructured baselines:

* **Diagonal-K** (DynaDiag, Tyagi et al. 2025): the mask is the union of K
  cyclic (wrap-around) diagonals of the R x C weight.  The *set of active
  diagonal offsets* is what DST updates.
* **Block-B** (DSB / Pixelated-Butterfly block term): the matrix is tiled
  into bs x bs blocks and a fixed number of blocks is active; DST moves
  whole blocks.
* **N:M** (SRigL): each group of M consecutive input positions keeps exactly
  N non-zeros; DST re-selects the N survivors per group.
* **Banded-b**: static band of half-width b around the (scaled) main
  diagonal — used by the expressivity theory (Table 1).
* **Butterfly**: Pixelated-Butterfly style *static* support built from
  power-of-two stride diagonals; never updated (SST baseline).
* **Unstructured**: free support with a global nnz budget (RigL / SET /
  MEST baselines).

Masks are dense 0/1 float32 arrays of the weight's shape so they compose
with the masked-dense training graph; the *compressed* forms used by the L1
kernels (per-row value/index arrays) are derived from the same builders.

All DST update rules preserve the layer nnz budget exactly and keep the
mask inside its structure family — properties the test-suites (hypothesis
here, proptest on the Rust mirror) check.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .common import DTYPE, cdiv

# ---------------------------------------------------------------------------
# Offset geometry shared by the diagonal family
# ---------------------------------------------------------------------------


def row_col_base(rows: int, cols: int) -> np.ndarray:
    """For rectangular layers, the column the 'main diagonal' passes through
    at each row: floor(i * cols / rows).  Square matrices reduce to i."""
    return (np.arange(rows) * cols) // rows


def diag_mask_from_offsets(rows: int, cols: int, offsets: np.ndarray) -> np.ndarray:
    """Dense 0/1 mask that is the union of cyclic diagonals at ``offsets``."""
    base = row_col_base(rows, cols)[:, None]  # (rows, 1)
    cols_idx = (base + np.asarray(offsets)[None, :]) % cols  # (rows, K)
    mask = np.zeros((rows, cols), dtype=np.float32)
    mask[np.repeat(np.arange(rows), len(offsets)), cols_idx.reshape(-1)] = 1.0
    return mask


def diag_offsets_init(cols: int, k: int, seed: int = 0) -> np.ndarray:
    """K distinct initial diagonal offsets, evenly spread over [0, cols)."""
    if k > cols:
        raise ValueError(f"K={k} exceeds cols={cols}")
    rng = np.random.default_rng(seed)
    # Evenly spaced offsets with a random rotation: spread coverage while
    # keeping runs distinct across layers/seeds.
    start = int(rng.integers(0, cols))
    return (start + (np.arange(k) * cols) // k) % cols


# ---------------------------------------------------------------------------
# Mask builders (numpy, build-time) — one per structure family
# ---------------------------------------------------------------------------


def make_diag_mask(rows: int, cols: int, k: int, seed: int = 0) -> np.ndarray:
    return diag_mask_from_offsets(rows, cols, diag_offsets_init(cols, k, seed))


def make_banded_mask(rows: int, cols: int, band: int) -> np.ndarray:
    """Band of width ``band`` (odd) centred on the scaled main diagonal,
    with wrap-around so every row has exactly ``band`` nnz (Apdx A)."""
    half = band // 2
    offsets = np.arange(-half, half + 1) % cols
    return diag_mask_from_offsets(rows, cols, np.unique(offsets))


def make_block_mask(
    rows: int, cols: int, density: float, bs: int = 16, seed: int = 0
) -> np.ndarray:
    """Block mask with ceil(density * nblocks) active bs x bs blocks, chosen
    uniformly at random but balanced across block-rows (each block-row gets
    the same budget, matching DSB's per-row-group layout)."""
    br, bc = cdiv(rows, bs), cdiv(cols, bs)
    per_row = max(1, round(density * bc))
    rng = np.random.default_rng(seed)
    mask = np.zeros((rows, cols), dtype=np.float32)
    for i in range(br):
        picks = rng.choice(bc, size=min(per_row, bc), replace=False)
        for j in picks:
            mask[i * bs : (i + 1) * bs, j * bs : (j + 1) * bs] = 1.0
    return mask[:rows, :cols]


def make_nm_mask(rows: int, cols: int, n: int, m: int, seed: int = 0) -> np.ndarray:
    """N:M mask: each group of M consecutive columns keeps N random nnz."""
    if cols % m != 0:
        raise ValueError(f"cols={cols} not divisible by M={m}")
    rng = np.random.default_rng(seed)
    groups = cols // m
    mask = np.zeros((rows, groups, m), dtype=np.float32)
    for i in range(rows):
        for g in range(groups):
            mask[i, g, rng.choice(m, size=n, replace=False)] = 1.0
    return mask.reshape(rows, cols)


def make_butterfly_mask(rows: int, cols: int, density: float) -> np.ndarray:
    """Pixelated-Butterfly style static support: union of power-of-two
    stride diagonals (the 'flat butterfly' of Dao et al. 2021) up to the
    nnz budget.  Static — never updated by DST."""
    budget = max(1, round(density * cols))
    offsets = [0]
    stride = 1
    while len(offsets) < budget and stride < cols:
        for off in (stride, cols - stride):
            if len(offsets) < budget and off % cols not in offsets:
                offsets.append(off % cols)
        stride *= 2
    # Fill any remainder with evenly spaced offsets.
    extra = 1
    while len(offsets) < budget:
        if extra not in offsets:
            offsets.append(extra)
        extra += 1
    return diag_mask_from_offsets(rows, cols, np.array(sorted(set(offsets))[:budget]))


def make_unstructured_mask(rows: int, cols: int, density: float, seed: int = 0) -> np.ndarray:
    """Free support with per-layer nnz budget = round(density * rows * cols),
    drawn as an Erdos–Renyi mask (SET-style initialisation)."""
    rng = np.random.default_rng(seed)
    nnz = max(1, round(density * rows * cols))
    flat = np.zeros(rows * cols, dtype=np.float32)
    flat[rng.choice(rows * cols, size=nnz, replace=False)] = 1.0
    return flat.reshape(rows, cols)


def make_mask(structure: str, rows: int, cols: int, density: float, seed: int = 0,
              bs: int = 16, m: int = 16) -> np.ndarray:
    """Dispatch on the structure family name used throughout the repo."""
    if structure == "diag":
        return make_diag_mask(rows, cols, max(1, round(density * cols)), seed)
    if structure == "banded":
        band = max(1, round(density * cols))
        band += (band + 1) % 2  # nearest odd
        return make_banded_mask(rows, cols, min(band, cols))
    if structure == "block":
        return make_block_mask(rows, cols, density, bs, seed)
    if structure == "nm":
        return make_nm_mask(rows, cols, max(1, round(density * m)), m, seed)
    if structure == "butterfly":
        return make_butterfly_mask(rows, cols, density)
    if structure == "unstructured":
        return make_unstructured_mask(rows, cols, density, seed)
    if structure == "dense":
        return np.ones((rows, cols), dtype=np.float32)
    raise ValueError(f"unknown structure {structure!r}")


# ---------------------------------------------------------------------------
# DST update rules (jnp, traced into the dst_update AOT program)
#
# All rules follow the prune-and-grow template of RigL (Evci et al. 2020):
# drop the ``frac`` lowest-|w| *structural units* among the active set and
# grow the same number of inactive units by the grow criterion (|grad| for
# RigL/SRigL/DSB/DynaDiag, random for SET, |w|+|grad| mix for MEST).  The
# structural unit is the weight (unstructured, N:M), the block (block) or
# the whole diagonal (diag).
# ---------------------------------------------------------------------------


def _topk_mask(scores: jnp.ndarray, k: jnp.ndarray | int) -> jnp.ndarray:
    """0/1 mask (same shape as ``scores``) selecting the k largest entries.

    ``k`` may be a traced scalar.  Implemented as sort + threshold against
    the k-th order statistic rather than the argsort/rank-scatter idiom:
    the scatter form miscompiles under the xla_extension 0.5.1 runtime the
    Rust side executes (masks silently densify), while sort + dynamic take
    lowers to well-supported primitives.  Assumes the top-k boundary value
    is unique among *candidate* scores (score construction in the callers
    separates candidates from the -1e30 sentinels), which holds w.p. 1 for
    the |w| / |grad| sums being ranked.
    """
    flat = scores.reshape(-1)
    desc = -jnp.sort(-flat)  # descending
    kk = jnp.asarray(k)
    idx = jnp.clip(kk - 1, 0, flat.shape[0] - 1).astype(jnp.int32)
    kth = jnp.take(desc, idx)
    sel = (flat >= kth) & (kk > 0)
    return sel.astype(DTYPE).reshape(scores.shape)


def unstructured_prune_grow(
    w: jnp.ndarray,
    mask: jnp.ndarray,
    grad: jnp.ndarray,
    frac: jnp.ndarray,
    grow_scores: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """RigL-style unstructured update.  ``grow_scores`` defaults to |grad|
    (RigL); pass uniform random numbers for SET or a |w|,|grad| mix for MEST.
    The nnz budget is preserved exactly.
    """
    nnz = jnp.sum(mask)
    n_inactive = mask.size - nnz
    n_move = jnp.minimum(jnp.floor(frac * nnz), n_inactive)
    # Keep the (nnz - n_move) largest-|w| active weights...
    keep_scores = jnp.abs(w) * mask - (1.0 - mask) * 1e30
    keep = _topk_mask(keep_scores, nnz - n_move)
    # ...and grow n_move inactive positions by the grow criterion.
    gs = jnp.abs(grad) if grow_scores is None else grow_scores
    grow_scores_masked = gs * (1.0 - keep) * (1.0 - mask) - (keep + mask) * 1e30
    grow = _topk_mask(grow_scores_masked, n_move)
    return jnp.clip(keep + grow, 0.0, 1.0)


def nm_prune_grow(
    w: jnp.ndarray, mask: jnp.ndarray, grad: jnp.ndarray, m: int, gamma: float = 0.3
) -> jnp.ndarray:
    """SRigL-style N:M update: within every group of M input positions,
    re-select the N survivors by score = |w| (active) vs gamma*|grad|
    (inactive candidates).  N is inferred from the incoming mask so the
    budget is preserved per group."""
    rows, cols = w.shape
    groups = cols // m
    wg = jnp.abs(w).reshape(rows, groups, m)
    gg = jnp.abs(grad).reshape(rows, groups, m)
    mg = mask.reshape(rows, groups, m)
    n = jnp.sum(mg, axis=-1, keepdims=True)  # (rows, groups, 1) — N per group
    scores = wg * mg + gamma * gg * (1.0 - mg)
    # Keep the top-N per group: sort + threshold on the N-th order
    # statistic (see _topk_mask for why not the rank-scatter idiom).
    desc = -jnp.sort(-scores, axis=-1)
    idx = jnp.clip(n - 1, 0, m - 1).astype(jnp.int32)
    kth = jnp.take_along_axis(desc, idx, axis=-1)
    new = ((scores >= kth) & (n > 0)).astype(DTYPE)
    return new.reshape(rows, cols)


def block_prune_grow(
    w: jnp.ndarray, mask: jnp.ndarray, grad: jnp.ndarray, bs: int, frac: jnp.ndarray
) -> jnp.ndarray:
    """DSB-style block update: score active blocks by sum|w| and inactive
    blocks by sum|grad|; move ``frac`` of the active blocks."""
    rows, cols = w.shape
    br, bc = rows // bs, cols // bs

    def block_reduce(x):
        return jnp.abs(x).reshape(br, bs, bc, bs).sum(axis=(1, 3))

    bmask = (mask.reshape(br, bs, bc, bs).mean(axis=(1, 3)) > 0.5).astype(DTYPE)
    nblk = jnp.sum(bmask)
    n_move = jnp.minimum(jnp.floor(frac * nblk), bmask.size - nblk)
    keep_scores = block_reduce(w * mask) - (1.0 - bmask) * 1e30
    keep = _topk_mask(keep_scores, nblk - n_move)
    grow_sc = block_reduce(grad) * (1.0 - bmask) * (1.0 - keep) - (bmask + keep) * 1e30
    grow = _topk_mask(grow_sc, n_move)
    bnew = jnp.clip(keep + grow, 0.0, 1.0)
    return jnp.repeat(jnp.repeat(bnew, bs, axis=0), bs, axis=1)


def diag_prune_grow(
    w: jnp.ndarray, mask: jnp.ndarray, grad: jnp.ndarray, frac: jnp.ndarray
) -> jnp.ndarray:
    """DynaDiag-style diagonal update: the structural unit is the whole
    cyclic diagonal.  Active diagonals are scored by sum|w| along the
    diagonal, inactive ones by sum|grad|; ``frac`` of the K active
    diagonals are moved per update."""
    rows, cols = w.shape
    base = jnp.asarray(row_col_base(rows, cols))[:, None]  # (rows,1)
    # offset of entry (i,j) = (j - base_i) mod cols.
    off = (jnp.arange(cols)[None, :] - base) % cols  # (rows, cols)
    # Column of offset o in row i: (base_i + o) mod cols — used to reduce
    # per-offset via *gather* (take_along_axis) rather than scatter-add:
    # the scatter lowering miscompiles under the xla_extension 0.5.1
    # runtime (every offset reports mass, densifying the mask; see
    # EXPERIMENTS.md bug log), while gathers round-trip correctly.
    gidx = (base + jnp.arange(cols)[None, :]) % cols  # (rows, offsets)

    def per_offset(x):
        g = jnp.take_along_axis(jnp.abs(x), gidx, axis=1)  # col o = offset o
        return jnp.sum(g, axis=0)

    dmask = (per_offset(mask) > 0.5).astype(DTYPE)  # active offsets
    k = jnp.sum(dmask)
    n_move = jnp.minimum(jnp.floor(frac * k), cols - k)
    keep_scores = per_offset(w * mask) - (1.0 - dmask) * 1e30
    keep = _topk_mask(keep_scores, k - n_move)
    grow_sc = per_offset(grad) * (1.0 - dmask) * (1.0 - keep) - (dmask + keep) * 1e30
    grow = _topk_mask(grow_sc, n_move)
    dnew = jnp.clip(keep + grow, 0.0, 1.0)
    # Rebuild the dense mask from the new offset set.
    return dnew[off]


def dst_update_for(
    structure: str, w, mask, grad, frac, *, m: int = 16, bs: int = 16,
    grow_scores=None,
):
    """Dispatch a single-layer DST update by structure family.  ``butterfly``
    and ``banded`` are static (SST) — they return the mask unchanged, as does
    ``dense``."""
    if structure in ("butterfly", "banded", "dense"):
        return mask
    if structure == "unstructured":
        return unstructured_prune_grow(w, mask, grad, frac, grow_scores)
    if structure == "nm":
        return nm_prune_grow(w, mask, grad, m)
    if structure == "block":
        return block_prune_grow(w, mask, grad, bs, frac)
    if structure == "diag":
        return diag_prune_grow(w, mask, grad, frac)
    raise ValueError(f"unknown structure {structure!r}")


def cosine_update_frac(step: jnp.ndarray, total_steps: int, frac0: float = 0.3) -> jnp.ndarray:
    """RigL's cosine-decayed drop fraction alpha_t = frac0/2 (1 + cos(pi t/T))."""
    t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
    return frac0 * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
