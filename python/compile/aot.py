"""AOT exporter: lower every L2 program to HLO *text* + write the manifest.

Interchange is HLO text, NOT a serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which the xla crate's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs under artifacts/:

    <artifact>.hlo.txt      one per program variant (see ARTIFACTS below)
    manifest.json           program input/output layouts + model configs
    golden/<name>.tnz       input/output dumps for Rust integration tests

The artifact matrix exploits the fact that masks are *runtime inputs*:
train/eval graphs are independent of the structure family and density, so
only dst_update (structure-specific update rule) and infer (compressed
shapes) fan out per structure.

Usage:  python -m compile.aot --out-dir ../artifacts [--only NAME] [--force]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import programs as P

BATCH = 8

# (artifact_name, model, structure, density, perm_mode, program)
# Structure/density only matter where noted above; they are recorded in the
# manifest so the Rust side builds matching masks / compressed buffers.
def artifact_matrix() -> list[dict]:
    arts = []
    for mk in ["vit_tiny", "gpt_tiny", "mixer_tiny"]:
        arts.append(dict(name=f"{mk}_train", model=mk, program="train_step",
                         perm_mode="learned"))
        arts.append(dict(name=f"{mk}_train_noperm", model=mk,
                         program="train_step", perm_mode="none"))
        arts.append(dict(name=f"{mk}_eval", model=mk, program="eval_step",
                         perm_mode="learned"))
        arts.append(dict(name=f"{mk}_infer_diag90", model=mk, program="infer",
                         structure="diag", density=0.1, perm_mode="learned"))
        for st in ["diag", "block", "nm", "unstructured"]:
            arts.append(dict(name=f"{mk}_dst_{st}", model=mk,
                             program="dst_update", structure=st,
                             perm_mode="learned"))
    # Kaleidoscope overhead comparators (Tbl. 2–5)
    for mk in ["vit_tiny", "gpt_tiny"]:
        arts.append(dict(name=f"{mk}_train_kperm", model=mk,
                         program="train_step", perm_mode="kaleidoscope"))
    # Scaled GPT for the end-to-end example
    arts.append(dict(name="gpt_small_train", model="gpt_small",
                     program="train_step", perm_mode="learned"))
    arts.append(dict(name="gpt_small_eval", model="gpt_small",
                     program="eval_step", perm_mode="learned"))
    arts.append(dict(name="gpt_small_dst_diag", model="gpt_small",
                     program="dst_update", structure="diag",
                     perm_mode="learned"))
    return arts


def build_cfg(art: dict) -> M.ModelConfig:
    return M.CONFIGS[art["model"]](
        structure=art.get("structure", "diag"),
        density=art.get("density", 0.1),
        perm_mode=art.get("perm_mode", "learned"),
    )


def make_program(art: dict, cfg: M.ModelConfig):
    prog = art["program"]
    if prog == "train_step":
        return P.make_train_step(cfg, BATCH)
    if prog == "dst_update":
        return P.make_dst_update(cfg, BATCH)
    if prog == "eval_step":
        return P.make_eval_step(cfg, BATCH)
    if prog == "infer":
        return P.make_infer(cfg, BATCH)
    raise ValueError(prog)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# .tnz tensor bundles (goldens / init dumps): header-length u64 LE, JSON
# header [{name, shape, dtype, offset}], raw LE payload.  Reader lives in
# rust/src/runtime/tnz.rs.
# ---------------------------------------------------------------------------


def write_tnz(path: str, tensors: list[tuple[str, np.ndarray]]):
    metas, payload = [], bytearray()
    for name, arr in tensors:
        shape = list(np.asarray(arr).shape)  # before ascontiguousarray: it
        arr = np.ascontiguousarray(arr)      # promotes 0-d to 1-d
        dt = {"float32": "f32", "int32": "i32"}[str(arr.dtype)]
        metas.append({"name": name, "shape": shape, "dtype": dt,
                      "offset": len(payload), "nbytes": arr.nbytes})
        payload += arr.tobytes()
    header = json.dumps(metas).encode()
    with open(path, "wb") as f:
        f.write(len(header).to_bytes(8, "little"))
        f.write(header)
        f.write(bytes(payload))


def dump_golden(art: dict, cfg, fn, args, spec, out_dir: str):
    """Run the program eagerly on a deterministic batch and dump
    inputs+outputs for the Rust integration test."""
    rng = np.random.default_rng(42)
    names = [n for n, _, _ in spec.inputs]
    args = list(args)
    if "batch_x" in names:
        i = names.index("batch_x")
        if cfg.kind == "gpt":
            args[i] = jnp.asarray(rng.integers(0, cfg.vocab, (BATCH, cfg.seq_len)),
                                  jnp.int32)
        else:
            args[i] = jnp.asarray(
                rng.standard_normal((BATCH, cfg.image, cfg.image, 3)), jnp.float32)
    if "batch_y" in names:
        i = names.index("batch_y")
        if cfg.kind == "gpt":
            args[i] = jnp.asarray(rng.integers(0, cfg.vocab, (BATCH, cfg.seq_len)),
                                  jnp.int32)
        else:
            args[i] = jnp.asarray(rng.integers(0, max(cfg.n_classes, 1), (BATCH,)),
                                  jnp.int32)
    outs = jax.jit(fn)(*args)
    tensors = [(f"in.{n}", np.asarray(a)) for n, a in zip(names, args)]
    tensors += [(f"out.{n}", np.asarray(o))
                for (n, _, _), o in zip(spec.outputs, outs)]
    write_tnz(os.path.join(out_dir, "golden", f"{art['name']}.tnz"), tensors)
    return args


GOLDEN_FOR = {"vit_tiny_train", "vit_tiny_eval", "vit_tiny_infer_diag90",
              "gpt_tiny_train", "vit_tiny_dst_diag"}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter")
    ap.add_argument("--force", action="store_true")
    ns = ap.parse_args()
    out_dir = ns.out_dir
    os.makedirs(out_dir, exist_ok=True)
    os.makedirs(os.path.join(out_dir, "golden"), exist_ok=True)

    manifest = {"batch": BATCH, "programs": {}, "models": {}}
    t_all = time.time()
    for art in artifact_matrix():
        if ns.only and ns.only not in art["name"]:
            continue
        cfg = build_cfg(art)
        path = os.path.join(out_dir, f"{art['name']}.hlo.txt")
        t0 = time.time()
        fn, args, spec = make_program(art, cfg)
        if art["name"] in GOLDEN_FOR:
            args = dump_golden(art, cfg, fn, args, spec, out_dir)
        if ns.force or not os.path.exists(path):
            lowered = jax.jit(fn, keep_unused=True).lower(*args)
            text = to_hlo_text(lowered)
            with open(path, "w") as f:
                f.write(text)
            status = "lowered"
        else:
            status = "cached"
        manifest["programs"][art["name"]] = {
            "file": f"{art['name']}.hlo.txt",
            "model": art["model"],
            "program": art["program"],
            "structure": art.get("structure", "diag"),
            "density": art.get("density", 0.1),
            "perm_mode": art.get("perm_mode", "learned"),
            "batch": BATCH,
            "golden": art["name"] in GOLDEN_FOR,
            "spec": spec.to_json(),
        }
        if art["model"] not in manifest["models"]:
            p0 = M.init_params(cfg)
            manifest["models"][art["model"]] = {
                "kind": cfg.kind,
                "d_model": cfg.d_model, "n_layers": cfg.n_layers,
                "n_heads": cfg.n_heads, "d_ff": cfg.d_ff,
                "seq_len": cfg.seq_len, "vocab": cfg.vocab,
                "n_classes": cfg.n_classes, "image": cfg.image,
                "patch": cfg.patch, "tok_hidden": cfg.tok_hidden,
                "params": [{"name": k, "shape": list(v.shape)}
                           for k, v in p0.items()],
                "sites": [{"name": n, "rows": r, "cols": c}
                          for n, r, c in M.sparse_sites(cfg)],
            }
        print(f"[aot] {art['name']:<28} {status:>7}  {time.time()-t0:6.1f}s",
              flush=True)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] total {time.time()-t_all:.1f}s -> {out_dir}/manifest.json")
    return 0


if __name__ == "__main__":
    sys.exit(main())
