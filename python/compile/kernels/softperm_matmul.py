"""L1 Pallas kernel: tiled dense matmul for the training-time soft
permutation apply, a = M x (Sec. 4.2) with doubly-stochastic M.

During training the permutation is a dense N x N doubly-stochastic matrix,
so the apply is a plain GEMM — but it is *the* extra cost PA-DST pays over
its no-permutation baseline (Fig. 3 / Tbl. 5 overhead rows), so it gets a
properly tiled kernel rather than riding on XLA's default.

TPU mapping: classic (TM, TK) x (TK, TN) MXU tiling with a float32
accumulator revisited across the K grid axis; tiles default to 128 to match
the 128x128 systolic array.  interpret=True for CPU-PJRT numerics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, m_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], m_ref[...].T, preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("tm", "tn", "tk", "interpret"))
def softperm_matmul(
    x: jnp.ndarray,
    m: jnp.ndarray,
    *,
    tm: int = 8,
    tn: int = 128,
    tk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """(M x) along the feature axis: x (B, N), m (N, N) -> (B, N),
    out[b, i] = sum_j m[i, j] x[b, j]."""
    b, n = x.shape
    tm = min(tm, b)
    tn = min(tn, n)
    tk = min(tk, n)
    if b % tm or n % tn or n % tk:  # odd test shapes: single tile
        tm, tn, tk = b, n, n
    grid = (b // tm, n // tn, n // tk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, k: (i, k)),
            pl.BlockSpec((tn, tk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
    )(x, m)
