"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Every kernel in this package has an oracle here with the same signature;
``python/tests/test_kernels.py`` sweeps shapes/densities/permutations with
hypothesis and asserts allclose.  The oracles are also what the L2 training
graph uses directly (masked-dense math), so kernel == oracle means the
AOT'd inference graph computes exactly what training optimised.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def dense_from_gather(vals: jnp.ndarray, idx: jnp.ndarray, cols: int) -> jnp.ndarray:
    """Reconstruct the dense R x C weight from the compressed row-gather form
    (vals[i,k] at column idx[i,k]).  Duplicate indices accumulate, matching
    the kernel's sum semantics."""
    rows, k = vals.shape
    w = jnp.zeros((rows, cols), vals.dtype)
    return w.at[jnp.repeat(jnp.arange(rows), k), idx.reshape(-1)].add(vals.reshape(-1))


def gather_spmm_ref(x: jnp.ndarray, vals: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """y[b, i] = sum_k vals[i, k] * x[b, idx[i, k]].

    The compressed form covers Diagonal-K, N:M and fixed-nnz unstructured
    rows; a learned permutation is *fused* by composing it into ``idx``
    (Eqn. 16/18 — re-indexing instead of a permutation matmul).
    """
    return jnp.einsum("ik,bik->bi", vals, x[:, idx])


def block_spmm_ref(
    x: jnp.ndarray, blocks: jnp.ndarray, block_cols: jnp.ndarray, bs: int, rows: int
) -> jnp.ndarray:
    """Block-sparse y = x @ W^T with W stored as active blocks.

    blocks:      (br, nab, bs, bs)  — per block-row, ``nab`` active blocks
    block_cols:  (br, nab) int32    — column-block index of each (-1 = pad)
    """
    br, nab = block_cols.shape
    batch = x.shape[0]
    y = jnp.zeros((batch, br * bs), x.dtype)
    for i in range(br):
        acc = jnp.zeros((batch, bs), x.dtype)
        for a in range(nab):
            j = block_cols[i, a]
            valid = (j >= 0).astype(x.dtype)
            xj = jnp.take(
                x, (jnp.clip(j, 0) * bs + jnp.arange(bs)) % x.shape[1], axis=1
            )
            acc = acc + valid * (xj @ blocks[i, a].T)
        y = y.at[:, i * bs : (i + 1) * bs].set(acc)
    return y[:, :rows]


def masked_matmul_ref(x: jnp.ndarray, w: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """The L2 training form: y = x @ (W * mask)^T."""
    return x @ (w * mask).T


def softperm_matmul_ref(x: jnp.ndarray, m: jnp.ndarray) -> jnp.ndarray:
    """Training-time soft permutation: (M x) along the feature axis."""
    return x @ m.T


def compress_mask(w: np.ndarray, mask: np.ndarray, k: int):
    """Convert a dense (W, mask) pair with <=k-nnz rows to the compressed
    row-gather form.  Rows with fewer nnz are padded with zero-valued
    entries pointing at column 0."""
    rows, cols = w.shape
    vals = np.zeros((rows, k), dtype=np.float32)
    idx = np.zeros((rows, k), dtype=np.int32)
    for i in range(rows):
        nz = np.nonzero(mask[i])[0][:k]
        vals[i, : len(nz)] = w[i, nz]
        idx[i, : len(nz)] = nz
    return vals, idx


def compress_blocks(w: np.ndarray, mask: np.ndarray, bs: int):
    """Convert a dense block-masked (W, mask) to the block compressed form
    used by block_spmm: (blocks, block_cols).  Pads ragged block-rows."""
    rows, cols = w.shape
    br, bc = rows // bs, cols // bs
    active = [
        [j for j in range(bc) if mask[i * bs, j * bs] > 0.5] for i in range(br)
    ]
    nab = max(1, max(len(a) for a in active))
    blocks = np.zeros((br, nab, bs, bs), dtype=np.float32)
    block_cols = np.full((br, nab), -1, dtype=np.int32)
    for i, cols_i in enumerate(active):
        for a, j in enumerate(cols_i):
            blocks[i, a] = w[i * bs : (i + 1) * bs, j * bs : (j + 1) * bs]
            block_cols[i, a] = j
    return blocks, block_cols
