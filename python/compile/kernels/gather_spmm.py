"""L1 Pallas kernel: permuted row-gather sparse matmul.

This is the paper's inference hot-spot (Eqn. 16/18).  A structured-sparse
weight with a fixed per-row nnz budget k — Diagonal-K, tied N:M, or any
fixed-nnz row layout — is stored compressed as

    vals: (R, k) f32      value of the k nnz of each output row
    idx:  (R, k) i32      input coordinate each value multiplies

and the learned permutation is *pre-composed into idx* at hardening time
(idx' = perm_index[idx]), so the kernel itself never touches a permutation
matrix: re-indexing replaces the permutation matmul, which is the paper's
2.9x-at-90 % trick.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles output rows;
each program instance holds a (TILE_R, k) value/index panel and the full
activation tile in VMEM, performing k fused multiply-accumulates per output
element.  On a real TPU idx-gathers lower to dynamic-slice streams from
VMEM; here we run interpret=True (CPU PJRT cannot execute Mosaic
custom-calls) and validate numerics against ``ref.gather_spmm_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_R = 64


def _kernel(x_ref, vals_ref, idx_ref, o_ref):
    """One grid step computes a (batch, TILE_R) output panel."""
    x = x_ref[...]          # (batch, C)   — full activation panel in VMEM
    vals = vals_ref[...]    # (TILE_R, k)
    idx = idx_ref[...]      # (TILE_R, k)
    # Gather the needed activations: (batch, TILE_R, k) then contract k.
    gathered = x[:, idx]    # interpret-mode gather; dynamic-slice on TPU
    o_ref[...] = jnp.einsum(
        "ik,bik->bi", vals, gathered, preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("tile_r", "interpret"))
def gather_spmm(
    x: jnp.ndarray,
    vals: jnp.ndarray,
    idx: jnp.ndarray,
    *,
    tile_r: int = DEFAULT_TILE_R,
    interpret: bool = True,
) -> jnp.ndarray:
    """y[b, i] = sum_k vals[i, k] * x[b, idx[i, k]].

    Shapes: x (B, C), vals (R, k), idx (R, k) -> y (B, R).
    R must be divisible by tile_r (callers pad; model dims are multiples
    of 64 throughout this repo).
    """
    batch, c = x.shape
    rows, k = vals.shape
    if rows % tile_r != 0:
        tile_r = rows  # degenerate single-tile fallback for odd test shapes
    grid = (rows // tile_r,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((batch, c), lambda i: (0, 0)),        # x: replicated
            pl.BlockSpec((tile_r, k), lambda i: (i, 0)),        # vals: row tile
            pl.BlockSpec((tile_r, k), lambda i: (i, 0)),        # idx: row tile
        ],
        out_specs=pl.BlockSpec((batch, tile_r), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((batch, rows), jnp.float32),
        interpret=interpret,
    )(x, vals, idx)


# ---------------------------------------------------------------------------
# Custom VJP: the compressed layout is closed under transposition
# ((S P)^T = P^T S^T, Sec. 1), so the backward pass is *also* a gather-spmm
# plus a segment-sum — sparse-to-sparse in both directions, which is the
# property the paper credits for DynaDiag's training speed (Sec. 6.2).
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def gather_spmm_ad(x, vals, idx, cols: int):
    return gather_spmm(x, vals, idx)


def _fwd(x, vals, idx, cols):
    return gather_spmm(x, vals, idx), (x, vals, idx)


def _bwd(cols, res, g):
    x, vals, idx = res
    rows, k = vals.shape
    # dvals[i, k] = sum_b g[b, i] * x[b, idx[i, k]]
    gathered = x[:, idx]                      # (B, R, k)
    dvals = jnp.einsum("bi,bik->ik", g, gathered)
    # dx[b, j] = sum_{(i,k): idx[i,k]=j} vals[i,k] * g[b, i]  (scatter-add)
    contrib = g[:, :, None] * vals[None, :, :]        # (B, R, k)
    dx = jnp.zeros((x.shape[0], cols), x.dtype).at[:, idx.reshape(-1)].add(
        contrib.reshape(x.shape[0], rows * k)
    )
    return dx, dvals, None


gather_spmm_ad.defvjp(_fwd, _bwd)
