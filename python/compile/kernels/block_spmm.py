"""L1 Pallas kernel: block-sparse matmul (DSB / Pixelated-Butterfly layout).

W is stored as per-block-row panels of active bs x bs blocks:

    blocks:     (br, nab, bs, bs) f32
    block_cols: (br, nab) i32   — column-block of each active block, -1 pad

TPU mapping: grid over (block-row); each program instance keeps its ``nab``
weight blocks resident in VMEM (nab * bs^2 * 4 bytes — at the paper's
ViT-B/16 geometry, 90 % sparsity, bs=16 that is ~20 KiB, far under the
~16 MiB VMEM budget) and streams the needed activation column panels.
The inner 2D dot hits the MXU with (batch x bs) @ (bs x bs) tiles; bs is
chosen as a multiple of 8 so tiles align with the 8x128 vector registers.
interpret=True for CPU-PJRT numerics (see gather_spmm.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, blocks_ref, cols_ref, o_ref):
    x = x_ref[...]              # (batch, C)
    blocks = blocks_ref[...]    # (1, nab, bs, bs) — this block-row's panel
    bcols = cols_ref[...]       # (1, nab)
    nab, bs = blocks.shape[1], blocks.shape[2]
    batch = x.shape[0]
    acc = jnp.zeros((batch, bs), jnp.float32)
    for a in range(nab):  # static unroll: nab is a compile-time constant
        j = bcols[0, a]
        valid = (j >= 0).astype(jnp.float32)
        start = jnp.clip(j, 0) * bs
        xj = jax.lax.dynamic_slice(x, (0, start), (batch, bs))
        acc = acc + valid * jnp.dot(
            xj, blocks[0, a].T, preferred_element_type=jnp.float32
        )
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_spmm(
    x: jnp.ndarray,
    blocks: jnp.ndarray,
    block_cols: jnp.ndarray,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """y = x @ W^T for block-sparse W.  Shapes:
    x (B, C), blocks (br, nab, bs, bs), block_cols (br, nab) -> y (B, br*bs).
    """
    batch, c = x.shape
    br, nab, bs, _ = blocks.shape
    return pl.pallas_call(
        _kernel,
        grid=(br,),
        in_specs=[
            pl.BlockSpec((batch, c), lambda i: (0, 0)),
            pl.BlockSpec((1, nab, bs, bs), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, nab), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((batch, bs), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((batch, br * bs), jnp.float32),
        interpret=interpret,
    )(x, blocks, block_cols)
