"""L1 Pallas kernels (interpret=True) + pure-jnp oracles (ref.py)."""
from .gather_spmm import gather_spmm, gather_spmm_ad
from .block_spmm import block_spmm
from .softperm_matmul import softperm_matmul
from . import ref

__all__ = ["gather_spmm", "gather_spmm_ad", "block_spmm", "softperm_matmul", "ref"]
