"""Build-time compile path for PA-DST: L1 Pallas kernels + L2 JAX model.

Never imported at runtime; `aot.py` lowers everything to HLO text under
artifacts/ once, and the Rust coordinator takes over.
"""
