"""Permutation-learning substrate (L2): Sinkhorn, AutoShuffle penalty, decode.

The paper (Sec. 4.2) follows AutoShuffleNet (Lyu et al. 2020): instead of a
discrete permutation P, learn a soft matrix M constrained to the Birkhoff
polytope (doubly stochastic) and drive it to a vertex with the exact
Lipschitz-continuous l1-l2 penalty

    P(M) = sum_i (||M_i:||_1 - ||M_i:||_2) + sum_j (||M_:j||_1 - ||M_:j||_2)

which is zero iff M is a permutation (for doubly-stochastic M).

We parameterise M = sinkhorn(softplus(logits)) so the doubly-stochastic
constraint holds by construction; the penalty is added to the task loss
with weight lambda (Eqn. 13).  At hardening time the coordinator decodes a
hard permutation with a Hungarian assignment (mirrored in Rust) and the
layer switches from a matmul to re-indexing (Sec. 4.3).

A Kaleidoscope-style alternative (``kaleidoscope_perm``) — a product of
log2(N) butterfly factors — is provided for the Tbl. 2–5 overhead
comparisons.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import DTYPE

EPS = 1e-6


def sinkhorn(x: jnp.ndarray, iters: int = 8) -> jnp.ndarray:
    """Project a positive matrix onto (near-)doubly-stochastic by iterated
    row/column normalisation.  8 iterations suffice for the penalty to be
    meaningful; the hard decode at the end of training removes any residual
    slack."""
    m = x + EPS
    for _ in range(iters):
        m = m / jnp.sum(m, axis=1, keepdims=True)
        m = m / jnp.sum(m, axis=0, keepdims=True)
    return m


def soft_perm(logits: jnp.ndarray, iters: int = 8, tau: float = 1.0) -> jnp.ndarray:
    """Doubly-stochastic soft permutation from unconstrained logits.

    Gumbel-Sinkhorn style positive map: row-stabilised exp (equivalent to
    softmax rows, then Sinkhorn column balancing).  Unlike softplus, exp can
    concentrate a row's mass on one column at any width N — softplus caps
    the diagonal/off-diagonal ratio so M stays near-uniform for large N,
    which destroys the layer input at init and blocks training.  Gradients
    are multiplicative in the entry value (log-space dynamics), matching
    how Gumbel-Sinkhorn learns latent permutations.
    """
    z = logits / tau
    z = z - jnp.max(z, axis=1, keepdims=True)  # row-stabilise; sinkhorn
    return sinkhorn(jnp.exp(z), iters)         # absorbs the row scaling


def autoshuffle_penalty(m: jnp.ndarray) -> jnp.ndarray:
    """Eqn. 14: exact l1-l2 row+column penalty.  Non-negative on the
    Birkhoff polytope; zero iff M is a permutation matrix."""
    row = jnp.sum(jnp.abs(m), axis=1) - jnp.sqrt(jnp.sum(m * m, axis=1) + EPS * EPS)
    col = jnp.sum(jnp.abs(m), axis=0) - jnp.sqrt(jnp.sum(m * m, axis=0) + EPS * EPS)
    return jnp.sum(row) + jnp.sum(col)


def identity_distance(p: jnp.ndarray) -> jnp.ndarray:
    """Sec. 6.3 width-invariant metric delta(P) = 1 - ||P - I||_F / sqrt(2N).

    delta = 1 for the identity; delta = 0 for a full derangement.
    """
    n = p.shape[0]
    eye = jnp.eye(n, dtype=p.dtype)
    return 1.0 - jnp.linalg.norm(p - eye) / jnp.sqrt(2.0 * n)


def greedy_decode(m: np.ndarray) -> np.ndarray:
    """Greedy assignment decode (build-time helper; the production decode is
    the Hungarian implementation in rust/src/perm/hungarian.rs).  Returns
    idx with (P x)_i = x[idx[i]], i.e. P[i, idx[i]] = 1."""
    m = np.asarray(m, dtype=np.float64).copy()
    n = m.shape[0]
    idx = np.full(n, -1, dtype=np.int64)
    used = np.zeros(n, dtype=bool)
    order = np.argsort(-m.max(axis=1))  # most confident rows first
    for i in order:
        row = m[i].copy()
        row[used] = -np.inf
        j = int(np.argmax(row))
        idx[i] = j
        used[j] = True
    return idx


def perm_matrix_from_index(idx: np.ndarray) -> np.ndarray:
    """Dense permutation matrix P with P[i, idx[i]] = 1."""
    n = len(idx)
    p = np.zeros((n, n), dtype=np.float32)
    p[np.arange(n), idx] = 1.0
    return p


def random_perm_index(n: int, seed: int) -> np.ndarray:
    """Fixed random permutation (the 'Random' rows in Tbl. 11/12)."""
    return np.random.default_rng(seed).permutation(n)


def apply_perm_index(x: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """(P x)_i = x[idx[i]] applied along the last axis — the re-indexing
    form used at inference (Eqn. 16/18): a gather, not a matmul."""
    return jnp.take(x, idx, axis=-1)


# ---------------------------------------------------------------------------
# Kaleidoscope-style alternative (overhead baseline for Tbl. 2–5)
# ---------------------------------------------------------------------------


def butterfly_factor(params: jnp.ndarray, stride: int, n: int) -> jnp.ndarray:
    """One butterfly factor B_s as a dense n x n matrix: 2x2 rotations
    between lanes i and i^stride.  ``params`` has shape (n,) of angles."""
    i = jnp.arange(n)
    j = i ^ stride
    c, s = jnp.cos(params), jnp.sin(params)
    mat = jnp.zeros((n, n), DTYPE)
    mat = mat.at[i, i].set(c)
    mat = mat.at[i, j].add(jnp.where(i < j, s, -s))
    return mat


def kaleidoscope_perm(angles: jnp.ndarray, n: int) -> jnp.ndarray:
    """Product of log2(n) butterfly factors — the K-matrix parameterisation
    of a (soft) permutation (Dao et al. 2020).  ``angles``: (log2 n, n)."""
    out = jnp.eye(n, dtype=DTYPE)
    stride, level = 1, 0
    while stride < n:
        out = butterfly_factor(angles[level], stride, n) @ out
        stride *= 2
        level += 1
    return out


def n_kaleidoscope_levels(n: int) -> int:
    lev = 0
    s = 1
    while s < n:
        s *= 2
        lev += 1
    return lev
