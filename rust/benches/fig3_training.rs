//! Fig. 3 (training half) + Tbl. 5 (time columns): wall-clock per training
//! step through the *AOT artifacts* for the permutation treatments —
//!
//!   noperm      {model}_train_noperm   (structured DST baseline)
//!   PA-DST      {model}_train          soft perms on every site (flags=0)
//!   PA-hardened {model}_train          all sites hardened (flags=1) — the
//!                                      end-state after Apdx C.2 early stop
//!   Kaleido     {model}_train_kperm    K-matrix comparator (Tbl. 5)
//!
//! The overhead columns are the paper's "learning permutations costs extra
//! training time; hardening claws it back" story, measured end-to-end
//! through PJRT (compile excluded, first call warmed).
//!
//! Writes `BENCH_fig3_training.json` alongside the table (skipped, like
//! the table, when artifacts are absent).

use std::collections::HashMap;

use padst::coordinator::{make_batch_buffers, RunConfig, Trainer};
use padst::harness::telemetry::{BenchRecord, BenchReport};
use padst::perm::model::resolve_perm;
use padst::runtime::Runtime;
use padst::sparsity::pattern::resolve_pattern;
use padst::tensor::Tensor;
use padst::harness::bench::BenchOpts;
use padst::util::stats::{bench, fmt_time, Summary};

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("run `make artifacts` first");
        return Ok(());
    }
    let opts = BenchOpts::parse("fig3_training");
    let threads = opts.threads;
    // Artifact execution is backend-blind, but the report records the knob
    // for provenance like every other bench.
    let mut report = BenchReport::new("fig3_training", threads).with_backend(opts.backend);
    let mut rt = Runtime::open_with_threads(dir, threads)?;
    println!("# Fig. 3 (training) / Tbl. 5: seconds per train step via PJRT (threads={threads})");
    println!(
        "{:<12} {:<14} {:>12} {:>10}",
        "model", "variant", "p50/step", "overhead"
    );

    for model in ["vit_tiny", "gpt_tiny"] {
        let variants: &[(&str, &str, f32)] = &[
            ("noperm", &format!("{model}_train_noperm"), 0.0),
            ("PA-DST", &format!("{model}_train"), 0.0),
            ("PA-hardened", &format!("{model}_train"), 1.0),
            ("Kaleido", &format!("{model}_train_kperm"), 0.0),
        ];
        let mut base = f64::NAN;
        for (label, artifact, flags) in variants {
            let (s, perm_spec) = time_variant(&mut rt, &opts, model, artifact, *flags)?;
            if *label == "noperm" {
                base = s.p50;
            }
            let overhead_pct = (s.p50 / base - 1.0) * 100.0;
            println!(
                "{:<12} {:<14} {:>12} {:>9.1}%",
                model,
                label,
                fmt_time(s.p50),
                overhead_pct
            );
            report.push(
                BenchRecord::from_summary("train_step", &format!("{model}/{label}"), &s)
                    .with_perm(&perm_spec)
                    .with_metric("overhead_pct", overhead_pct),
            );
        }
    }
    report.write(&opts.json_path)?;
    println!("# wrote {}", opts.json_path.display());
    println!("\n# done (recorded in EXPERIMENTS.md §Fig3-training)");
    Ok(())
}

/// Time one variant's steady-state step.  Uses the Trainer's own state
/// initialisation so buffers are exactly what production runs feed.
/// Returns the summary plus the perm spec the variant ran under (report
/// provenance).
fn time_variant(
    rt: &mut Runtime,
    opts: &BenchOpts,
    model: &str,
    artifact: &str,
    hard_flags: f32,
) -> anyhow::Result<(Summary, String)> {
    let perm_spec = if artifact.ends_with("noperm") {
        "none"
    } else if artifact.ends_with("kperm") {
        "kaleidoscope"
    } else {
        "learned"
    };
    let cfg = RunConfig {
        model: model.to_string(),
        pattern: resolve_pattern("diag")?,
        density: 0.1,
        perm: resolve_perm(perm_spec)?,
        steps: 0,
        threads: rt.threads,
        ..Default::default()
    };
    let entry = rt.manifest.models[model].clone();
    let batch = rt.manifest.batch;
    let prog = rt.program(artifact)?;
    let mut trainer = Trainer::new(rt, cfg);
    let mut state = trainer.init_state()?;
    if let Some(f) = state.vals.get_mut("hard_flags") {
        f.f32s_mut().fill(hard_flags);
    }

    let (bx, by) = make_batch_buffers(&entry, batch);
    let mut extras: HashMap<&str, Tensor> = HashMap::new();
    extras.insert("batch_x", bx);
    extras.insert("batch_y", by);
    extras.insert("lr", Tensor::scalar(1e-3));
    extras.insert("lambda", Tensor::scalar(5e-3));
    let inputs: Vec<Tensor> = prog
        .spec
        .inputs
        .iter()
        .map(|s| {
            extras
                .get(s.name.as_str())
                .or_else(|| state.vals.get(&s.name))
                .cloned()
                .ok_or_else(|| anyhow::anyhow!("missing {}", s.name))
        })
        .collect::<anyhow::Result<_>>()?;

    let (bw, bi, bt) = opts.budget(2, 5, 1.0);
    let s = bench(|| { let _ = prog.run(&inputs).unwrap(); }, bw, bi, bt);
    Ok((s, perm_spec.to_string()))
}
