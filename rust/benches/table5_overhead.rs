//! Tbl. 2–5: memory (and state) overhead of permutation methods per model,
//! computed from the exact buffer inventory a run holds (params + Adam +
//! masks + permutation state), relative to the no-permutation baseline of
//! the same structured method — mirroring the paper's "% overhead relative
//! to DynaDiag/SRigL" columns.
//!
//! Writes `BENCH_table5_overhead.json` with value-only records (metrics
//! `state_mb` / `overhead_pct`); the bench-compare gate skips them, but
//! the trajectory is tracked like any timed bench.

use padst::harness::telemetry::{BenchRecord, BenchReport};
use padst::models::memory_footprint;
use padst::runtime::manifest::Manifest;
use padst::sparsity::pattern::resolve_pattern;
use padst::util::cli::BenchOpts;

fn main() -> anyhow::Result<()> {
    let path = std::path::Path::new("artifacts/manifest.json");
    if !path.exists() {
        eprintln!("run `make artifacts` first");
        return Ok(());
    }
    let opts = BenchOpts::parse("table5_overhead");
    let mut report = BenchReport::new("table5_overhead", opts.threads).with_backend(opts.backend);
    let manifest = Manifest::load(path)?;

    println!("# Tbl. 2-5 analogue: training-state memory by permutation method");
    println!(
        "{:<12} {:<16} {:>12} {:>10}",
        "model", "method", "state (MB)", "overhead"
    );
    // The mask term is the family's own accounting (every family stores
    // the dense f32 mask tensor during training, so the reference pattern
    // here is representative; the trait hook exists for families that
    // later specialise it).
    let pattern = resolve_pattern("diag")?;
    for (model, entry) in &manifest.models {
        let base = memory_footprint(entry, pattern.as_ref(), "none", false) as f64;
        for (label, mode, hardened) in [
            ("baseline", "none", false),
            ("+FixedRandPerm", "random", false),
            ("+PA-DST", "learned", false),
            ("+PA-DST(hard)", "learned", true),
            ("+Kaleidoscope", "kaleidoscope", false),
        ] {
            let m = memory_footprint(entry, pattern.as_ref(), mode, hardened) as f64;
            let state_mb = m / (1024.0 * 1024.0);
            let overhead_pct = (m / base - 1.0) * 100.0;
            println!(
                "{:<12} {:<16} {:>12.2} {:>9.2}%",
                model, label, state_mb, overhead_pct
            );
            report.push(
                BenchRecord::value("memory", &format!("{model}/{label}"))
                    .with_pattern(&pattern.spec())
                    .with_metric("state_mb", state_mb)
                    .with_metric("overhead_pct", overhead_pct),
            );
        }
        println!();
    }
    report.write(&opts.json_path)?;
    println!("# wrote {}", opts.json_path.display());
    println!("# time columns of Tbl. 5 come from `cargo bench --bench fig3_training`");
    Ok(())
}
