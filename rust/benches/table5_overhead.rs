//! Tbl. 2–5: memory (and state) overhead of permutation methods per model,
//! computed from the exact buffer inventory a run holds (params + Adam +
//! masks + permutation state), relative to the no-permutation baseline of
//! the same structured method — mirroring the paper's "% overhead relative
//! to DynaDiag/SRigL" columns.  The per-mode byte accounting is the
//! `PermModel::memory_bytes` trait hook, so rows can never drift from the
//! mode impls.
//!
//! Also times the host Sinkhorn projection before/after the
//! reusable-buffer refactor: `perm::soft_perm` (allocates a fresh n*n
//! matrix per call) vs `SinkhornScratch::soft_perm` (buffers reused
//! across calls — the `buffer_reused` metric is 1 only if the scratch's
//! allocation fingerprint never changed over the timed loop, i.e. the
//! path allocates nothing per step), plus the f32 path dispatched
//! through the `Backend` microkernels.
//!
//! Writes `BENCH_table5_overhead.json`; the memory rows are value-only
//! (metrics `state_mb` / `overhead_pct`, skipped by the bench-compare
//! gate), the sinkhorn rows are timed like any other bench.

use padst::harness::telemetry::{BenchRecord, BenchReport};
use padst::models::memory_footprint;
use padst::perm::{self, model::resolve_perm, SinkhornScratch};
use padst::runtime::manifest::Manifest;
use padst::sparsity::pattern::resolve_pattern;
use padst::harness::bench::BenchOpts;
use padst::util::stats::{bench, fmt_time};
use padst::util::Rng;

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts::parse("table5_overhead");
    let mut report = BenchReport::new("table5_overhead", opts.threads).with_backend(opts.backend);

    let path = std::path::Path::new("artifacts/manifest.json");
    if path.exists() {
        memory_rows(&Manifest::load(path)?, &mut report)?;
    } else {
        eprintln!("no artifacts/manifest.json — skipping the memory table (run `make artifacts`)");
    }
    sinkhorn_rows(&opts, &mut report);

    report.write(&opts.json_path)?;
    println!("# wrote {}", opts.json_path.display());
    println!("# time columns of Tbl. 5 come from `cargo bench --bench fig3_training`");
    Ok(())
}

fn memory_rows(manifest: &Manifest, report: &mut BenchReport) -> anyhow::Result<()> {
    println!("# Tbl. 2-5 analogue: training-state memory by permutation method");
    println!(
        "{:<12} {:<16} {:>12} {:>10}",
        "model", "method", "state (MB)", "overhead"
    );
    // The mask term is the family's own accounting (every family stores
    // the dense f32 mask tensor during training, so the reference pattern
    // here is representative; the trait hook exists for families that
    // later specialise it).
    let pattern = resolve_pattern("diag")?;
    let base_perm = resolve_perm("none")?;
    for (model, entry) in &manifest.models {
        let base = memory_footprint(entry, pattern.as_ref(), base_perm.as_ref(), false) as f64;
        for (label, spec, hardened) in [
            ("baseline", "none", false),
            ("+FixedRandPerm", "random", false),
            ("+PA-DST", "learned", false),
            ("+PA-DST(hard)", "learned", true),
            ("+Kaleidoscope", "kaleidoscope", false),
        ] {
            let pm = resolve_perm(spec)?;
            let m = memory_footprint(entry, pattern.as_ref(), pm.as_ref(), hardened) as f64;
            let state_mb = m / (1024.0 * 1024.0);
            let overhead_pct = (m / base - 1.0) * 100.0;
            println!(
                "{:<12} {:<16} {:>12.2} {:>9.2}%",
                model, label, state_mb, overhead_pct
            );
            report.push(
                BenchRecord::value("memory", &format!("{model}/{label}"))
                    .with_pattern(&pattern.spec())
                    .with_perm(&pm.spec())
                    .with_metric("state_mb", state_mb)
                    .with_metric("overhead_pct", overhead_pct),
            );
        }
        println!();
    }
    Ok(())
}

/// Before/after rows for the host Sinkhorn projection (the hottest
/// non-kernel loop: it runs per hardening decision per site, and the
/// analysis paths project every site).  N = 768 is the paper's ViT-B/16 /
/// GPT-2 Small permutation dimension.
fn sinkhorn_rows(opts: &BenchOpts, report: &mut BenchReport) {
    let n = 768usize;
    let iters = 12usize;
    let mut rng = Rng::new(17);
    let logits: Vec<f32> = (0..n * n).map(|_| rng.normal()).collect();
    let (bw, bi, bt) = opts.budget(2, 5, 0.3);

    println!("# Sinkhorn projection (N={n}, {iters} iters): before/after the scratch refactor");
    println!("{:<26} {:>12} {:>14}", "path", "p50/call", "buffer_reused");

    let before = bench(
        || {
            let _ = perm::soft_perm(&logits, n, iters);
        },
        bw,
        bi,
        bt,
    );
    println!("{:<26} {:>12} {:>14}", "before (alloc per call)", fmt_time(before.p50), "-");
    report.push(
        BenchRecord::from_summary("sinkhorn", &format!("soft_perm(N={n}) alloc"), &before)
            .with_perm("learned")
            .with_metric("buffer_reused", 0.0),
    );

    let mut scratch = SinkhornScratch::new();
    scratch.soft_perm(&logits, n, iters, 1.0); // warm: buffers sized once
    let fp = scratch.buffer_fingerprint();
    let after = bench(
        || {
            let _ = scratch.soft_perm(&logits, n, iters, 1.0);
        },
        bw,
        bi,
        bt,
    );
    let reused = scratch.buffer_fingerprint() == fp;
    assert!(reused, "SinkhornScratch reallocated during the timed loop");
    println!(
        "{:<26} {:>12} {:>14}",
        "after (scratch, f64)",
        fmt_time(after.p50),
        if reused { "yes" } else { "NO" }
    );
    report.push(
        BenchRecord::from_summary("sinkhorn", &format!("soft_perm(N={n}) scratch"), &after)
            .with_perm("learned")
            .with_metric("buffer_reused", if reused { 1.0 } else { 0.0 })
            .with_metric("speedup_vs_alloc", before.p50 / after.p50),
    );

    let backend = opts.backend;
    scratch.soft_perm_f32(&logits, n, iters, 1.0, backend); // warm f32 buffers
    let after32 = bench(
        || {
            let _ = scratch.soft_perm_f32(&logits, n, iters, 1.0, backend);
        },
        bw,
        bi,
        bt,
    );
    println!(
        "{:<26} {:>12} {:>14}",
        format!("after (scratch, f32 {})", backend.name()),
        fmt_time(after32.p50),
        "yes"
    );
    report.push(
        BenchRecord::from_summary("sinkhorn", &format!("soft_perm(N={n}) scratch f32"), &after32)
            .with_perm("learned")
            .with_backend(backend)
            .with_metric("buffer_reused", 1.0)
            .with_metric("speedup_vs_alloc", before.p50 / after32.p50),
    );
    println!();
}
