//! Tbl. 2–5: memory (and state) overhead of permutation methods per model,
//! computed from the exact buffer inventory a run holds (params + Adam +
//! masks + permutation state), relative to the no-permutation baseline of
//! the same structured method — mirroring the paper's "% overhead relative
//! to DynaDiag/SRigL" columns.

use padst::models::memory_footprint;
use padst::runtime::manifest::Manifest;

fn main() -> anyhow::Result<()> {
    let path = std::path::Path::new("artifacts/manifest.json");
    if !path.exists() {
        eprintln!("run `make artifacts` first");
        return Ok(());
    }
    let manifest = Manifest::load(path)?;

    println!("# Tbl. 2-5 analogue: training-state memory by permutation method");
    println!(
        "{:<12} {:<16} {:>12} {:>10}",
        "model", "method", "state (MB)", "overhead"
    );
    for (model, entry) in &manifest.models {
        let base = memory_footprint(entry, "none", false) as f64;
        for (label, mode, hardened) in [
            ("baseline", "none", false),
            ("+FixedRandPerm", "random", false),
            ("+PA-DST", "learned", false),
            ("+PA-DST(hard)", "learned", true),
            ("+Kaleidoscope", "kaleidoscope", false),
        ] {
            let m = memory_footprint(entry, mode, hardened) as f64;
            println!(
                "{:<12} {:<16} {:>12.2} {:>9.2}%",
                model,
                label,
                m / (1024.0 * 1024.0),
                (m / base - 1.0) * 100.0
            );
        }
        println!();
    }
    println!("# time columns of Tbl. 5 come from `cargo bench --bench fig3_training`");
    Ok(())
}
