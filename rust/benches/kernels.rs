//! Kernel micro-benchmarks: GFLOP/s of every native kernel across shapes
//! and densities — the profiling substrate for the §Perf iteration loop
//! (EXPERIMENTS.md).  Run with `cargo bench --bench kernels`.
//!
//! Three sections:
//! 1. the per-kernel microbench on the selected backend (`--backend` after
//!    `--`, or `PADST_BACKEND`, default tiled) — record names are
//!    backend-free, so two runs under different backends diff cleanly with
//!    `padst bench-compare`;
//! 2. the backend matrix: gather/block/dense at the headline geometry for
//!    *every* backend compiled into this binary, single thread — the
//!    tiled-beats-scalar evidence in one report;
//! 3. serial-vs-parallel scaling for the scoped-thread execution layer:
//!    each kernel at 1/2/4/max threads, speedup relative to its own serial
//!    path.  Thread ceiling: `--threads N` after `--`, or `PADST_THREADS`,
//!    else available parallelism.
//!
//! Alongside the human tables the run writes `BENCH_kernels.json`
//! (schema: `padst::harness::telemetry`; the report and every record carry
//! the backend); `padst bench-compare` diffs two such reports for the CI
//! perf gate.  `--short` (or `PADST_BENCH_SHORT=1`) shrinks sample budgets
//! to CI size.

use padst::harness::telemetry::{BenchRecord, BenchReport};
use padst::kernels::micro::Backend;
use padst::kernels::parallel::available_threads;
use padst::kernels::tune::{self, TuneBudget};
use padst::kernels::{
    block_matmul_mt_with, block_matmul_with, csr_from_mask, csr_matmul_mt_with, csr_matmul_with,
    dense_matmul, dense_matmul_blocked_mt_with, dense_matmul_blocked_with,
    gather_matmul_batched_with, gather_matmul_mt_with, gather_matmul_with, run_plan_mt,
    run_plan_mt_tuned, spmm_flops,
};
use padst::sparsity::compress::{compress_blocks, compress_rows};
use padst::sparsity::pattern::{resolve_pattern, KernelPlan};
use padst::harness::bench::BenchOpts;
use padst::util::stats::{bench, fmt_time, Summary};
use padst::util::Rng;

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts::parse("kernels");
    let backend = opts.backend;
    let (bw, bi, bt) = opts.budget(1, 3, 0.3);
    let mut report = BenchReport::new("kernels", opts.threads).with_backend(backend);

    let shapes = [(64usize, 768usize, 768usize), (64, 3072, 768), (8, 256, 256)];
    println!("# kernel microbench: p50 / GFLOPs (backend {})", backend.name());
    println!(
        "{:<26} {:>12} {:>9} {:>10}",
        "kernel(batch,rows,cols)", "p50", "GFLOP/s", "vs naive"
    );
    for (batch, rows, cols) in shapes {
        let shape = format!("({batch},{rows},{cols})");
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..batch * cols).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
        let mut y = vec![0.0f32; batch * rows];
        let dense_flops = 2 * batch * rows * cols;

        // One row: print the human line and record the telemetry.
        let mut row = |name: &str, s: &Summary, flops: usize, naive_p50: f64| {
            println!(
                "{:<26} {:>12} {:>9.2} {:>9.2}x",
                name,
                fmt_time(s.p50),
                flops as f64 / s.p50 / 1e9,
                naive_p50 / s.p50
            );
            report.push(
                BenchRecord::from_summary("microbench", name, s)
                    .with_metric("gflops", flops as f64 / s.p50 / 1e9)
                    .with_metric("vs_naive", naive_p50 / s.p50),
            );
        };

        let naive = bench(|| dense_matmul(&x, &w, batch, rows, cols, &mut y), bw, bi, bt);
        let blocked = bench(
            || dense_matmul_blocked_with(&x, &w, batch, rows, cols, &mut y, backend),
            bw,
            bi,
            bt,
        );
        row(&format!("dense_naive{shape}"), &naive, dense_flops, naive.p50);
        row(&format!("dense_blocked{shape}"), &blocked, dense_flops, naive.p50);

        for density in [0.1f64, 0.05] {
            let mask =
                resolve_pattern("diag")?.init_mask(rows, cols, density, &mut rng)?;
            let k = (0..mask.rows).map(|i| mask.row_nnz(i)).max().unwrap();
            let rc = compress_rows(&w, &mask, k, None);
            let flops = spmm_flops(batch, mask.nnz());
            let g1 = bench(|| gather_matmul_with(&x, &rc, batch, &mut y, backend), bw, bi, bt);
            let g2 = bench(
                || gather_matmul_batched_with(&x, &rc, batch, &mut y, backend),
                bw,
                bi,
                bt,
            );
            row(&format!("gather{shape} d={density}"), &g1, flops, naive.p50);
            row(&format!("gather_batched{shape} d={density}"), &g2, flops, naive.p50);

            let bmask =
                resolve_pattern("block")?.init_mask(rows, cols, density, &mut rng)?;
            let bc = compress_blocks(&w, &bmask, 16);
            let bflops = spmm_flops(batch, bmask.nnz());
            let b = bench(|| block_matmul_with(&x, &bc, batch, &mut y, backend), bw, bi, bt);
            row(&format!("block{shape} d={density}"), &b, bflops, naive.p50);

            let umask =
                resolve_pattern("unstructured")?.init_mask(rows, cols, density, &mut rng)?;
            let csr = csr_from_mask(&w, &umask);
            let uflops = spmm_flops(batch, umask.nnz());
            let c = bench(|| csr_matmul_with(&x, &csr, batch, &mut y, backend), bw, bi, bt);
            row(&format!("csr{shape} d={density}"), &c, uflops, naive.p50);
        }
        println!();
    }

    backend_matrix(&opts, &mut report);
    parallel_scaling(&opts, &mut report);
    tuned_section(&opts, &mut report);

    report.write(&opts.json_path)?;
    println!("# wrote {}", opts.json_path.display());
    Ok(())
}

/// Every compiled backend on the headline layer (ViT-B/16 FFN geometry),
/// single thread: the scalar-vs-tiled(-vs-simd) GFLOP/s comparison the
/// microkernel refactor exists for, in one report.  Record names carry the
/// backend (stable across runs, so `bench-compare` still matches them).
fn backend_matrix(opts: &BenchOpts, report: &mut BenchReport) {
    let (bw, bi, bt) = opts.budget(1, 3, 0.3);
    let (batch, rows, cols) = (64usize, 3072usize, 768usize);
    let density = 0.1;
    let mut rng = Rng::new(5);
    let x: Vec<f32> = (0..batch * cols).map(|_| rng.normal()).collect();
    let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
    let mut y = vec![0.0f32; batch * rows];

    let dmask =
        resolve_pattern("diag").unwrap().init_mask(rows, cols, density, &mut rng).unwrap();
    let k = (0..dmask.rows).map(|i| dmask.row_nnz(i)).max().unwrap();
    let rc = compress_rows(&w, &dmask, k, None);
    let gflops = spmm_flops(batch, dmask.nnz());
    let bmask =
        resolve_pattern("block").unwrap().init_mask(rows, cols, density, &mut rng).unwrap();
    let bc = compress_blocks(&w, &bmask, 16);
    let bflops = spmm_flops(batch, bmask.nnz());
    let dflops = 2 * batch * rows * cols;

    println!("# backend matrix ({batch},{rows},{cols}) d={density}, single thread");
    println!("{:<26} {:>8} {:>12} {:>9}", "kernel", "backend", "p50", "GFLOP/s");
    for &b in Backend::all() {
        let mut row = |name: &str, s: &Summary, flops: usize| {
            println!(
                "{:<26} {:>8} {:>12} {:>9.2}",
                name,
                b.name(),
                fmt_time(s.p50),
                flops as f64 / s.p50 / 1e9
            );
            report.push(
                BenchRecord::from_summary("backend_matrix", &format!("{name} [{}]", b.name()), s)
                    .with_backend(b)
                    .with_metric("gflops", flops as f64 / s.p50 / 1e9),
            );
        };
        let g = bench(|| gather_matmul_with(&x, &rc, batch, &mut y, b), bw, bi, bt);
        row("gather", &g, gflops);
        let bl = bench(|| block_matmul_with(&x, &bc, batch, &mut y, b), bw, bi, bt);
        row("block", &bl, bflops);
        let d = bench(
            || dense_matmul_blocked_with(&x, &w, batch, rows, cols, &mut y, b),
            bw,
            bi,
            bt,
        );
        row("dense_blocked", &d, dflops);
    }
    println!();
}

/// Serial vs parallel at the ViT-B/16 FFN geometry (the Fig. 3 headline
/// layer): every `_mt` kernel across thread counts on the selected
/// backend, speedup vs its own serial path.  The gather/block paths should
/// clear 1x comfortably from 4 threads up; CSR is indirection-bound and
/// scales worst — which is the paper's structured >> unstructured
/// ordering, now with a thread axis.
fn parallel_scaling(opts: &BenchOpts, report: &mut BenchReport) {
    let max_threads = opts.threads;
    let backend = opts.backend;
    let (bw, bi, bt) = opts.budget(1, 3, 0.3);
    let mut counts = vec![1usize, 2, 4];
    counts.retain(|&t| t <= max_threads);
    if !counts.contains(&max_threads) {
        counts.push(max_threads);
    }

    let (batch, rows, cols) = (64usize, 3072usize, 768usize);
    let density = 0.1;
    let mut rng = Rng::new(9);
    let x: Vec<f32> = (0..batch * cols).map(|_| rng.normal()).collect();
    let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
    let mut y = vec![0.0f32; batch * rows];

    let dmask =
        resolve_pattern("diag").unwrap().init_mask(rows, cols, density, &mut rng).unwrap();
    let k = (0..dmask.rows).map(|i| dmask.row_nnz(i)).max().unwrap();
    let rc = compress_rows(&w, &dmask, k, None);
    let bmask =
        resolve_pattern("block").unwrap().init_mask(rows, cols, density, &mut rng).unwrap();
    let bc = compress_blocks(&w, &bmask, 16);
    let umask =
        resolve_pattern("unstructured").unwrap().init_mask(rows, cols, density, &mut rng).unwrap();
    let csr = csr_from_mask(&w, &umask);

    println!(
        "# parallel scaling ({batch},{rows},{cols}) d={density}, ceiling {max_threads} threads, \
         backend {}",
        backend.name()
    );
    println!("{:<26} {:>8} {:>12} {:>10}", "kernel", "threads", "p50", "vs serial");

    let mut row = |name: &str, t: usize, s: &Summary, serial_p50: f64| {
        println!(
            "{:<26} {:>8} {:>12} {:>9.2}x",
            name,
            t,
            fmt_time(s.p50),
            serial_p50 / s.p50
        );
        report.push(
            BenchRecord::from_summary("parallel_scaling", &format!("{name} t={t}"), s)
                .with_metric("threads", t as f64)
                .with_metric("speedup_vs_serial", serial_p50 / s.p50),
        );
    };

    let mut serial = 0.0f64;
    for &t in &counts {
        let s = bench(|| gather_matmul_mt_with(&x, &rc, batch, &mut y, t, backend), bw, bi, bt);
        if t == 1 {
            serial = s.p50;
        }
        row("gather", t, &s, serial);
    }
    for &t in &counts {
        let s = bench(|| block_matmul_mt_with(&x, &bc, batch, &mut y, t, backend), bw, bi, bt);
        if t == 1 {
            serial = s.p50;
        }
        row("block", t, &s, serial);
    }
    for &t in &counts {
        let s = bench(|| csr_matmul_mt_with(&x, &csr, batch, &mut y, t, backend), bw, bi, bt);
        if t == 1 {
            serial = s.p50;
        }
        row("csr", t, &s, serial);
    }
    for &t in &counts {
        let s = bench(
            || dense_matmul_blocked_mt_with(&x, &w, batch, rows, cols, &mut y, t, backend),
            bw,
            bi,
            bt,
        );
        if t == 1 {
            serial = s.p50;
        }
        row("dense_blocked", t, &s, serial);
    }
    println!("# (available parallelism on this machine: {})", available_threads());
}

/// Tuned vs default dispatch at the headline geometry: time the autotuner's
/// candidate grid for the diag plan, then bench the default `run_plan_mt`
/// path against `run_plan_mt_tuned` with the winning choice.  The speedup
/// metric is informational — CI treats it as warn-only (timing variance on
/// shared runners), the identity guarantees live in `tests/tune.rs`.
fn tuned_section(opts: &BenchOpts, report: &mut BenchReport) {
    let (bw, bi, bt) = opts.budget(1, 3, 0.3);
    let threads = opts.threads;
    let backend = opts.backend;
    let (batch, rows, cols) = (64usize, 3072usize, 768usize);
    let density = 0.1;
    let mut rng = Rng::new(11);
    let x: Vec<f32> = (0..batch * cols).map(|_| rng.normal()).collect();
    let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
    let mut y = vec![0.0f32; batch * rows];

    let dmask =
        resolve_pattern("diag").unwrap().init_mask(rows, cols, density, &mut rng).unwrap();
    let k = (0..dmask.rows).map(|i| dmask.row_nnz(i)).max().unwrap();
    let plan = KernelPlan::Rows(compress_rows(&w, &dmask, k, None));

    let mut budget = TuneBudget::default();
    if opts.short {
        budget.budget_ns = 2_000_000;
    }
    let (key, entry) = tune::tune_plan(&plan, &x, batch, &mut y, threads, &budget);
    let choice = entry.choice;
    println!(
        "# tuned vs default ({batch},{rows},{cols}) d={density}, t={threads}: {} -> backend={} \
         batched={} cap={}",
        key.spec(),
        choice.backend.name(),
        u8::from(choice.batched),
        choice.max_threads
    );

    let dflt = bench(|| run_plan_mt(&plan, &x, batch, &mut y, threads, backend), bw, bi, bt);
    let tuned = bench(
        || run_plan_mt_tuned(&plan, &x, batch, &mut y, threads, &choice),
        bw,
        bi,
        bt,
    );
    let speedup = dflt.p50 / tuned.p50;
    println!(
        "{:<26} {:>12}\n{:<26} {:>12} ({:.2}x vs default)",
        "run_plan_mt default",
        fmt_time(dflt.p50),
        "run_plan_mt tuned",
        fmt_time(tuned.p50),
        speedup
    );
    report.push(BenchRecord::from_summary("tuned", "run_plan_mt default", &dflt));
    report.push(
        BenchRecord::from_summary("tuned", "run_plan_mt tuned", &tuned)
            .with_tuned(true)
            .with_metric("speedup_tuned_vs_default", speedup),
    );
    println!();
}
