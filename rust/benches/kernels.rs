//! Kernel micro-benchmarks: GFLOP/s of every native kernel across shapes
//! and densities — the profiling substrate for the §Perf iteration loop
//! (EXPERIMENTS.md).  Run with `cargo bench --bench kernels`.

use padst::kernels::{
    block_matmul, csr_from_mask, csr_matmul, dense_matmul, dense_matmul_blocked,
    gather_matmul, gather_matmul_batched, spmm_flops,
};
use padst::sparsity::compress::{compress_blocks, compress_rows};
use padst::sparsity::patterns::{make_mask, Structure};
use padst::util::stats::{bench, fmt_time};
use padst::util::Rng;

fn main() {
    let shapes = [(64usize, 768usize, 768usize), (64, 3072, 768), (8, 256, 256)];
    println!("# kernel microbench: p50 / GFLOPs");
    println!(
        "{:<26} {:>12} {:>9} {:>10}",
        "kernel(batch,rows,cols)", "p50", "GFLOP/s", "vs naive"
    );
    for (batch, rows, cols) in shapes {
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..batch * cols).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
        let mut y = vec![0.0f32; batch * rows];
        let dense_flops = 2 * batch * rows * cols;

        let naive = bench(|| dense_matmul(&x, &w, batch, rows, cols, &mut y), 1, 3, 0.3);
        let blocked = bench(
            || dense_matmul_blocked(&x, &w, batch, rows, cols, &mut y),
            1,
            3,
            0.3,
        );
        println!(
            "{:<26} {:>12} {:>9.2} {:>9.2}x",
            format!("dense_naive({batch},{rows},{cols})"),
            fmt_time(naive.p50),
            dense_flops as f64 / naive.p50 / 1e9,
            1.0
        );
        println!(
            "{:<26} {:>12} {:>9.2} {:>9.2}x",
            format!("dense_blocked({batch},{rows},{cols})"),
            fmt_time(blocked.p50),
            dense_flops as f64 / blocked.p50 / 1e9,
            naive.p50 / blocked.p50
        );

        for density in [0.1f64, 0.05] {
            let mask = make_mask(Structure::Diag, rows, cols, density, &mut rng);
            let k = (0..mask.rows).map(|i| mask.row_nnz(i)).max().unwrap();
            let rc = compress_rows(&w, &mask, k, None);
            let flops = spmm_flops(batch, mask.nnz());
            let g1 = bench(|| gather_matmul(&x, &rc, batch, &mut y), 1, 3, 0.3);
            let g2 = bench(|| gather_matmul_batched(&x, &rc, batch, &mut y), 1, 3, 0.3);
            println!(
                "{:<26} {:>12} {:>9.2} {:>9.2}x",
                format!("gather d={density}"),
                fmt_time(g1.p50),
                flops as f64 / g1.p50 / 1e9,
                naive.p50 / g1.p50
            );
            println!(
                "{:<26} {:>12} {:>9.2} {:>9.2}x",
                format!("gather_batched d={density}"),
                fmt_time(g2.p50),
                flops as f64 / g2.p50 / 1e9,
                naive.p50 / g2.p50
            );

            let bmask = make_mask(Structure::Block, rows, cols, density, &mut rng);
            let bc = compress_blocks(&w, &bmask, 16);
            let bflops = spmm_flops(batch, bmask.nnz());
            let b = bench(|| block_matmul(&x, &bc, batch, &mut y), 1, 3, 0.3);
            println!(
                "{:<26} {:>12} {:>9.2} {:>9.2}x",
                format!("block d={density}"),
                fmt_time(b.p50),
                bflops as f64 / b.p50 / 1e9,
                naive.p50 / b.p50
            );

            let umask = make_mask(Structure::Unstructured, rows, cols, density, &mut rng);
            let csr = csr_from_mask(&w, &umask);
            let uflops = spmm_flops(batch, umask.nnz());
            let c = bench(|| csr_matmul(&x, &csr, batch, &mut y), 1, 3, 0.3);
            println!(
                "{:<26} {:>12} {:>9.2} {:>9.2}x",
                format!("csr d={density}"),
                fmt_time(c.p50),
                uflops as f64 / c.p50 / 1e9,
                naive.p50 / c.p50
            );
        }
        println!();
    }
}
