//! Fig. 3 (inference half): per-layer inference time vs sparsity at the
//! paper's ViT-B/16 and GPT-2 Small layer geometries, for every structure
//! family, with three permutation treatments:
//!
//!   none      — plain structured sparse GEMM
//!   reindex   — learned permutation folded into the index stream
//!               (the paper's Eqn. 16/18 trick; expected overhead <= ~9 %)
//!   shuffle   — explicit permutation pass + GEMM (the strawman)
//!
//! Prints speedup-vs-dense per sparsity so the 2.9x-at-90 % headline and
//! the structured >> unstructured(CSR) ordering can be checked directly.
//! Run: `cargo bench --bench fig3_inference` (offline criterion stand-in).
//!
//! Every path — dense baseline included — runs through the scoped-thread
//! execution layer under the same worker budget (`--threads N` after `--`,
//! or `PADST_THREADS`, default available parallelism), so the speedup
//! ratios stay like-for-like at any thread count.  Methodology note: the
//! gather paths use the sharded row-gather kernel at *every* thread count,
//! not the serial batch-amortised `gather_matmul_batched` this bench used
//! before the parallel layer landed — so `--threads 1` absolute times for
//! diag/N:M/butterfly differ slightly from previously recorded runs (the
//! batched serial variant is still timed in `cargo bench --bench kernels`).

use padst::harness::telemetry::{BenchRecord, BenchReport};
use padst::kernels::{
    block_matmul_mt_with, csr_from_mask, csr_matmul_mt_with, dense_matmul_blocked_mt_with,
    gather_matmul_mt_with, shuffle_rows,
};
use padst::models::PAPER_LAYERS;
use padst::sparsity::compress::{compress_blocks, compress_rows};
use padst::sparsity::patterns::{make_mask, Structure};
use padst::util::cli::BenchOpts;
use padst::util::stats::{bench, fmt_time};
use padst::util::Rng;

const BATCH: usize = 64; // tokens in flight, ~ViT-B/16 sequence dimension

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts::parse("fig3_inference");
    let threads = opts.threads;
    let backend = opts.backend;
    let mut report = BenchReport::new("fig3_inference", threads).with_backend(backend);
    let sparsities = [0.6, 0.7, 0.8, 0.9, 0.95];
    let structures = [
        Structure::Diag,
        Structure::NM,
        Structure::Block,
        Structure::Butterfly,
        Structure::Unstructured,
    ];
    println!(
        "# Fig. 3 (inference): y = x@W^T, batch={BATCH}, threads={threads}, backend {}, \
         times per call",
        backend.name()
    );
    println!("# speedup = dense_time / variant_time at the same geometry");

    // Representative layer: ViT-B/16 FFN up-projection (3072 x 768) — the
    // dominant GEMM of the model; the full set is swept afterwards.
    for layer in PAPER_LAYERS {
        // Full structure x sparsity sweep on the headline layer (ViT-B/16
        // FFN up-projection); a diag@90% spot-check on the rest.
        let full = layer.model == "vit_b16" && layer.site == "fc1";
        let structures: &[Structure] = if full { &structures } else { &[Structure::Diag] };
        let sparsities: &[f64] = if full { &sparsities } else { &[0.9] };
        let (rows, cols) = (layer.rows, layer.cols);
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..BATCH * cols).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
        let mut y = vec![0.0f32; BATCH * rows];

        let (bw, bi, bt) = opts.budget(2, 5, 0.4);
        let dense = bench(
            || dense_matmul_blocked_mt_with(&x, &w, BATCH, rows, cols, &mut y, threads, backend),
            bw,
            bi,
            bt,
        );
        println!(
            "\n## {}/{} ({rows}x{cols})  dense: {}",
            layer.model,
            layer.site,
            fmt_time(dense.p50)
        );
        let site_id = format!("{}/{}", layer.model, layer.site);
        report.push(BenchRecord::from_summary("inference", &format!("{site_id} dense"), &dense));
        let (bw, bi, bt) = opts.budget(2, 5, 0.25);
        println!(
            "{:<14} {:>5} {:>12} {:>9} {:>12} {:>9} {:>12} {:>9}",
            "structure", "s%", "none", "spdup", "reindex", "spdup", "shuffle", "spdup"
        );

        for &st in structures {
            for &sp in sparsities {
                let density = 1.0 - sp;
                let mut mrng = Rng::new(7);
                let mask = make_mask(st, rows, cols, density, &mut mrng);
                let k = mask_k(&mask);
                let perm: Vec<i32> =
                    mrng.permutation(cols).iter().map(|&p| p as i32).collect();

                // none
                let t_none = match st {
                    Structure::Block => {
                        let bc = compress_blocks(&w, &mask, 16);
                        bench(
                            || block_matmul_mt_with(&x, &bc, BATCH, &mut y, threads, backend),
                            bw,
                            bi,
                            bt,
                        )
                    }
                    Structure::Unstructured => {
                        let csr = csr_from_mask(&w, &mask);
                        bench(
                            || csr_matmul_mt_with(&x, &csr, BATCH, &mut y, threads, backend),
                            bw,
                            bi,
                            bt,
                        )
                    }
                    _ => {
                        let rc = compress_rows(&w, &mask, k, None);
                        bench(
                            || gather_matmul_mt_with(&x, &rc, BATCH, &mut y, threads, backend),
                            bw,
                            bi,
                            bt,
                        )
                    }
                };

                // reindex: permutation folded into the index stream (for
                // block structure the permutation cannot fold into dense
                // blocks, so blocks fall back to row-gather form there).
                let t_reindex = match st {
                    Structure::Unstructured => {
                        // Fold the permutation into CSR column indices.
                        let csr = {
                            let mut c = csr_from_mask(&w, &mask);
                            for ci in c.col_idx.iter_mut() {
                                *ci = perm[*ci as usize];
                            }
                            c
                        };
                        bench(
                            || csr_matmul_mt_with(&x, &csr, BATCH, &mut y, threads, backend),
                            bw,
                            bi,
                            bt,
                        )
                    }
                    _ => {
                        let rc = compress_rows(&w, &mask, k, Some(&perm));
                        bench(
                            || gather_matmul_mt_with(&x, &rc, BATCH, &mut y, threads, backend),
                            bw,
                            bi,
                            bt,
                        )
                    }
                };

                // shuffle: explicit permutation pass, then the same kernel.
                let mut xp = vec![0.0f32; BATCH * cols];
                let t_shuffle = match st {
                    Structure::Block => {
                        let bc = compress_blocks(&w, &mask, 16);
                        bench(
                            || {
                                shuffle_rows(&x, &perm, BATCH, cols, &mut xp);
                                block_matmul_mt_with(&xp, &bc, BATCH, &mut y, threads, backend);
                            },
                            bw,
                            bi,
                            bt,
                        )
                    }
                    Structure::Unstructured => {
                        let csr = csr_from_mask(&w, &mask);
                        bench(
                            || {
                                shuffle_rows(&x, &perm, BATCH, cols, &mut xp);
                                csr_matmul_mt_with(&xp, &csr, BATCH, &mut y, threads, backend);
                            },
                            bw,
                            bi,
                            bt,
                        )
                    }
                    _ => {
                        let rc = compress_rows(&w, &mask, k, None);
                        bench(
                            || {
                                shuffle_rows(&x, &perm, BATCH, cols, &mut xp);
                                gather_matmul_mt_with(&xp, &rc, BATCH, &mut y, threads, backend);
                            },
                            bw,
                            bi,
                            bt,
                        )
                    }
                };

                println!(
                    "{:<14} {:>5.0} {:>12} {:>8.2}x {:>12} {:>8.2}x {:>12} {:>8.2}x",
                    st.name(),
                    sp * 100.0,
                    fmt_time(t_none.p50),
                    dense.p50 / t_none.p50,
                    fmt_time(t_reindex.p50),
                    dense.p50 / t_reindex.p50,
                    fmt_time(t_shuffle.p50),
                    dense.p50 / t_shuffle.p50,
                );
                for (variant, s) in
                    [("none", &t_none), ("reindex", &t_reindex), ("shuffle", &t_shuffle)]
                {
                    report.push(
                        BenchRecord::from_summary(
                            "inference",
                            &format!("{site_id} {} s{sp} {variant}", st.name()),
                            s,
                        )
                        .with_metric("speedup_vs_dense", dense.p50 / s.p50),
                    );
                }
            }
        }
    }
    report.write(&opts.json_path)?;
    println!("# wrote {}", opts.json_path.display());
    println!("\n# done (see EXPERIMENTS.md §Fig3 for the recorded run)");
    Ok(())
}

fn mask_k(mask: &padst::sparsity::patterns::Mask) -> usize {
    (0..mask.rows).map(|i| mask.row_nnz(i)).max().unwrap_or(1)
}
