//! Fig. 3 (inference half): per-layer inference time vs sparsity at the
//! paper's ViT-B/16 and GPT-2 Small layer geometries, for every structure
//! family, with three permutation treatments:
//!
//!   none      — plain structured sparse GEMM
//!   reindex   — learned permutation folded into the index stream
//!               (the paper's Eqn. 16/18 trick; expected overhead <= ~9 %)
//!   shuffle   — explicit permutation pass + GEMM (the strawman)
//!
//! Prints speedup-vs-dense per sparsity so the 2.9x-at-90 % headline and
//! the structured >> unstructured(CSR) ordering can be checked directly.
//! Run: `cargo bench --bench fig3_inference` (offline criterion stand-in).
//!
//! Structure families resolve through the `PatternRegistry`, and each
//! family's [`SparsePattern::compress`] picks its kernel plan — the bench
//! dispatches on the *plan* (gather/block/CSR/dense drivers), never on the
//! family, so `PADST_FIG3_STRUCTURES` can name any registered spec
//! (`diag`, `block:8`, `nm:1:4`, ...) and new families need no bench
//! changes.  Each telemetry record carries its spec string.
//!
//! Every path — dense baseline included — runs through the scoped-thread
//! execution layer under the same worker budget (`--threads N` after `--`,
//! or `PADST_THREADS`, default available parallelism), so the speedup
//! ratios stay like-for-like at any thread count.  Methodology notes: the
//! gather paths use the sharded row-gather kernel at *every* thread count
//! (the batch-amortised serial variant is timed in `cargo bench --bench
//! kernels`), and for block structure the permutation cannot fold into
//! dense panels, so its reindex treatment falls back to the row-gather
//! form (that fallback now lives in `BlockPattern::compress`).

use std::collections::HashMap;

use padst::coordinator::TrainState;
use padst::harness::telemetry::{BenchRecord, BenchReport};
use padst::kernels::tune::{self, TuneBudget};
use padst::kernels::{dense_matmul_blocked_mt_with, run_plan_mt, run_plan_mt_tuned, shuffle_rows};
use padst::models::PAPER_LAYERS;
use padst::perm::model::resolve_perm;
use padst::serve::{decode_binary_body, encode_binary_infer_response, Response, SessionCtx};
use padst::sparsity::pattern::resolve_pattern;
use padst::tensor::Tensor;
use padst::harness::bench::BenchOpts;
use padst::util::stats::{bench, fmt_time, Summary};
use padst::util::Rng;

const BATCH: usize = 64; // tokens in flight, ~ViT-B/16 sequence dimension

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts::parse("fig3_inference");
    let threads = opts.threads;
    let backend = opts.backend;
    let mut report = BenchReport::new("fig3_inference", threads).with_backend(backend);
    let sparsities = [0.6, 0.7, 0.8, 0.9, 0.95];
    let default_specs = "diag,nm,block,butterfly,unstructured".to_string();
    let specs_csv =
        std::env::var("PADST_FIG3_STRUCTURES").unwrap_or(default_specs);
    let specs: Vec<&str> = specs_csv.split(',').filter(|s| !s.is_empty()).collect();
    println!(
        "# Fig. 3 (inference): y = x@W^T, batch={BATCH}, threads={threads}, backend {}, \
         times per call",
        backend.name()
    );
    println!("# speedup = dense_time / variant_time at the same geometry");

    // Representative layer: ViT-B/16 FFN up-projection (3072 x 768) — the
    // dominant GEMM of the model; the full set is swept afterwards.
    for layer in PAPER_LAYERS {
        // Full structure x sparsity sweep on the headline layer (ViT-B/16
        // FFN up-projection); a diag@90% spot-check on the rest.
        let full = layer.model == "vit_b16" && layer.site == "fc1";
        let specs: &[&str] = if full { &specs } else { &["diag"] };
        let sparsities: &[f64] = if full { &sparsities } else { &[0.9] };
        let (rows, cols) = (layer.rows, layer.cols);
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..BATCH * cols).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
        let mut y = vec![0.0f32; BATCH * rows];

        let (bw, bi, bt) = opts.budget(2, 5, 0.4);
        let dense = bench(
            || dense_matmul_blocked_mt_with(&x, &w, BATCH, rows, cols, &mut y, threads, backend),
            bw,
            bi,
            bt,
        );
        println!(
            "\n## {}/{} ({rows}x{cols})  dense: {}",
            layer.model,
            layer.site,
            fmt_time(dense.p50)
        );
        let site_id = format!("{}/{}", layer.model, layer.site);
        report.push(BenchRecord::from_summary("inference", &format!("{site_id} dense"), &dense));
        let (bw, bi, bt) = opts.budget(2, 5, 0.25);
        println!(
            "{:<14} {:>5} {:>12} {:>9} {:>12} {:>9} {:>12} {:>9}",
            "structure", "s%", "none", "spdup", "reindex", "spdup", "shuffle", "spdup"
        );

        for &spec in specs {
            let pattern = resolve_pattern(spec)?;
            for &sp in sparsities {
                let density = 1.0 - sp;
                let mut mrng = Rng::new(7);
                let mask = pattern.init_mask(rows, cols, density, &mut mrng)?;
                let perm: Vec<i32> =
                    mrng.permutation(cols).iter().map(|&p| p as i32).collect();

                // none: the family's own kernel plan.
                let plan_none = pattern.compress(&w, &mask, None);
                let t_none = bench(
                    || run_plan_mt(&plan_none, &x, BATCH, &mut y, threads, backend),
                    bw,
                    bi,
                    bt,
                );

                // reindex: permutation folded into the index stream.
                let plan_reindex = pattern.compress(&w, &mask, Some(&perm));
                let t_reindex = bench(
                    || run_plan_mt(&plan_reindex, &x, BATCH, &mut y, threads, backend),
                    bw,
                    bi,
                    bt,
                );

                // shuffle: explicit permutation pass, then the plain plan.
                let mut xp = vec![0.0f32; BATCH * cols];
                let t_shuffle = bench(
                    || {
                        shuffle_rows(&x, &perm, BATCH, cols, &mut xp);
                        run_plan_mt(&plan_none, &xp, BATCH, &mut y, threads, backend);
                    },
                    bw,
                    bi,
                    bt,
                );

                println!(
                    "{:<14} {:>5.0} {:>12} {:>8.2}x {:>12} {:>8.2}x {:>12} {:>8.2}x",
                    pattern.spec(),
                    sp * 100.0,
                    fmt_time(t_none.p50),
                    dense.p50 / t_none.p50,
                    fmt_time(t_reindex.p50),
                    dense.p50 / t_reindex.p50,
                    fmt_time(t_shuffle.p50),
                    dense.p50 / t_shuffle.p50,
                );
                // Perm provenance: the reindex/shuffle treatments fold or
                // apply a sampled random permutation; "none" has none.
                let variants: [(&str, &str, &Summary); 3] = [
                    ("none", "none", &t_none),
                    ("reindex", "random", &t_reindex),
                    ("shuffle", "random", &t_shuffle),
                ];
                for (variant, perm_spec, s) in variants {
                    report.push(
                        BenchRecord::from_summary(
                            "inference",
                            &format!("{site_id} {} s{sp} {variant}", pattern.spec()),
                            s,
                        )
                        .with_pattern(&pattern.spec())
                        .with_perm(perm_spec)
                        .with_metric("speedup_vs_dense", dense.p50 / s.p50),
                    );
                }
            }
        }
    }
    // ----- SessionCtx (padst serve): cached plans/scratch vs rebuild -----
    // Serving compiles each layer's KernelPlan once per session and reuses
    // one grow-only activation scratch across requests.  Time a warm
    // cached request against the rebuild-per-call path it replaces, at
    // the headline geometry (ViT-B/16 fc1, diag @ 90 % sparsity, hard
    // random perm), and fingerprint-assert the warm path's
    // zero-allocation contract while we are here.
    {
        let (rows, cols) = (3072usize, 768usize);
        let pattern = resolve_pattern("diag")?;
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..BATCH * cols).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
        let mask = pattern.init_mask(rows, cols, 0.1, &mut rng)?;
        let perm: Vec<i32> = rng.permutation(cols).iter().map(|&p| p as i32).collect();

        let mut vals = HashMap::new();
        vals.insert("mask.fc1".to_string(), Tensor::from_f32(&[rows, cols], mask.bits.clone()));
        vals.insert("param.fc1.w".to_string(), Tensor::from_f32(&[rows, cols], w.clone()));
        vals.insert("perm_idx.fc1".to_string(), Tensor::from_i32(&[cols], perm.clone()));
        vals.insert("hard_flags".to_string(), Tensor::from_f32(&[1], vec![1.0]));
        let state =
            TrainState { vals, site_names: vec!["fc1".to_string()], budgets: vec![mask.nnz()] };
        let mut ctx = SessionCtx::from_state(
            "fig3",
            &state,
            pattern.clone(),
            resolve_perm("random")?,
            threads,
            backend,
        )?;

        let (bw, bi, bt) = opts.budget(2, 5, 0.25);
        ctx.run("fc1", &x, BATCH)?; // cold call: plans compiled, scratch sized
        let fp = ctx.fingerprint();
        let t_cached = bench(
            || {
                ctx.run("fc1", &x, BATCH).unwrap();
            },
            bw,
            bi,
            bt,
        );
        assert_eq!(fp, ctx.fingerprint(), "warm serve path must not allocate");

        let mut y = vec![0.0f32; BATCH * rows];
        let t_rebuilt = bench(
            || {
                let plan = pattern.compress(&w, &mask, Some(&perm));
                run_plan_mt(&plan, &x, BATCH, &mut y, threads, backend);
            },
            bw,
            bi,
            bt,
        );
        println!(
            "\n## SessionCtx (padst serve) on vit_b16/fc1, diag @ 90%: cached {} vs rebuilt {} \
             ({:.2}x)",
            fmt_time(t_cached.p50),
            fmt_time(t_rebuilt.p50),
            t_rebuilt.p50 / t_cached.p50
        );
        report.push(
            BenchRecord::from_summary("serve", "session cached", &t_cached)
                .with_pattern("diag")
                .with_perm("random")
                .with_metric("speedup_cached_vs_rebuilt", t_rebuilt.p50 / t_cached.p50),
        );
        report.push(
            BenchRecord::from_summary("serve", "session rebuilt", &t_rebuilt)
                .with_pattern("diag")
                .with_perm("random"),
        );
        // Obs-sourced record: the same warm calls, quantiles read back
        // from the session's per-site infer histogram instead of the
        // sorted-sample harness (provenance stamped via obs_schema) —
        // keeps the histogram math honest against the oracle path.
        let infer = ctx.obs().histogram("serve.infer_ns.fc1").snapshot();
        if infer.count > 0 {
            report.push(
                BenchRecord::from_hist("serve", "session infer_ns (obs)", &infer)
                    .with_pattern("diag")
                    .with_perm("random"),
            );
        }
        report = report.with_obs(ctx.obs_snapshot().to_json());
    }

    // ----- Tuned vs default dispatch (kernels::tune) -----
    // Autotune the headline plan (ViT-B/16 fc1, diag @ 90 % sparsity),
    // then bench the default `run_plan_mt` path against the tuned entry
    // point with the winning choice.  The speedup metric is informational
    // (CI treats timing variance as warn-only); the bit-identity
    // guarantees live in `tests/tune.rs`.
    {
        let (rows, cols) = (3072usize, 768usize);
        let pattern = resolve_pattern("diag")?;
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..BATCH * cols).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
        let mut y = vec![0.0f32; BATCH * rows];
        let mask = pattern.init_mask(rows, cols, 0.1, &mut rng)?;
        let plan = pattern.compress(&w, &mask, None);

        let mut budget = TuneBudget::default();
        if opts.short {
            budget.budget_ns = 2_000_000;
        }
        let (key, entry) = tune::tune_plan(&plan, &x, BATCH, &mut y, threads, &budget);
        let choice = entry.choice;
        let (bw, bi, bt) = opts.budget(2, 5, 0.25);
        let t_default =
            bench(|| run_plan_mt(&plan, &x, BATCH, &mut y, threads, backend), bw, bi, bt);
        let t_tuned = bench(
            || run_plan_mt_tuned(&plan, &x, BATCH, &mut y, threads, &choice),
            bw,
            bi,
            bt,
        );
        let speedup = t_default.p50 / t_tuned.p50;
        println!(
            "\n## tuned dispatch on vit_b16/fc1, diag @ 90% ({}): default {} vs tuned {} \
             ({speedup:.2}x)",
            key.spec(),
            fmt_time(t_default.p50),
            fmt_time(t_tuned.p50),
        );
        report.push(
            BenchRecord::from_summary("tuned", "run_plan_mt default", &t_default)
                .with_pattern("diag"),
        );
        report.push(
            BenchRecord::from_summary("tuned", "run_plan_mt tuned", &t_tuned)
                .with_pattern("diag")
                .with_tuned(true)
                .with_metric("speedup_tuned_vs_default", speedup),
        );
    }

    // ----- Wire formats (padst serve protocol v2): NDJSON vs binary -----
    // One infer response worth of activations at the headline width
    // (cols=768 x BATCH=64 = 49152 f32 values), round-tripped through
    // both wire formats: NDJSON text (serialize + parse) vs the v2
    // length-prefixed binary frame (encode + decode, `to_bits`-exact).
    // `bytes_per_value` is the payload efficiency the binary wire buys
    // (4 B payload + fixed header vs ~13-20 text chars per value); the
    // speedup is informational (CI treats timing variance as warn-only).
    {
        let cols = 768usize;
        let mut rng = Rng::new(1);
        let y: Vec<f32> = (0..BATCH * cols).map(|_| rng.normal()).collect();
        let nvals = y.len() as f64;
        let resp = Response::Infer { id: "w".to_string(), batch: BATCH, y: y.clone() };

        let (bw, bi, bt) = opts.budget(2, 5, 0.25);
        let text_line = resp.to_line();
        let t_text = bench(
            || {
                let line = resp.to_line();
                let parsed = Response::parse_line(&line).unwrap();
                std::hint::black_box(parsed);
            },
            bw,
            bi,
            bt,
        );
        let bin_frame = encode_binary_infer_response("w", BATCH, &y)?;
        let t_bin = bench(
            || {
                let frame = encode_binary_infer_response("w", BATCH, &y).unwrap();
                let body = decode_binary_body(&frame[8..]).unwrap();
                std::hint::black_box(body);
            },
            bw,
            bi,
            bt,
        );
        let text_bpv = (text_line.len() + 1) as f64 / nvals; // +1: the newline delimiter
        let bin_bpv = bin_frame.len() as f64 / nvals;
        let speedup = t_text.p50 / t_bin.p50;
        println!(
            "\n## wire formats on {BATCH}x{cols} activations: ndjson {} ({text_bpv:.1} B/val) vs \
             binary {} ({bin_bpv:.2} B/val, {speedup:.2}x)",
            fmt_time(t_text.p50),
            fmt_time(t_bin.p50),
        );
        report.push(
            BenchRecord::from_summary("wire", "ndjson round-trip", &t_text)
                .with_metric("bytes_per_value", text_bpv),
        );
        report.push(
            BenchRecord::from_summary("wire", "binary round-trip", &t_bin)
                .with_metric("bytes_per_value", bin_bpv)
                .with_metric("speedup_binary_vs_ndjson", speedup),
        );
    }

    report.write(&opts.json_path)?;
    println!("# wrote {}", opts.json_path.display());
    println!("\n# done (see EXPERIMENTS.md §Fig3 for the recorded run)");
    Ok(())
}
