//! Table 1 + Apdx B/C.1: regenerate the expressivity lower-bound summary
//! and the worked examples, and time the bound evaluation itself (the NLR
//! calculator is also library API, so it gets a perf row).
//!
//! Writes `BENCH_table1_nlr.json`: the Table-1 rows as value-only records
//! (metric `log10_nlr`) plus the timed bound-evaluation row.

use padst::harness::telemetry::{BenchRecord, BenchReport};
use padst::nlr::{
    effective_dims_var, layer_factor_u128, log10_nlr_bound, nlr_bound_u128, pattern_rows,
    table1_rows_mt, Setting,
};
use padst::sparsity::pattern::resolve_pattern;
use padst::harness::bench::BenchOpts;
use padst::util::stats::{bench, fmt_time};

fn main() -> anyhow::Result<()> {
    // --- Table 1 at the paper's ViT-L/16 surrogate geometry -------------
    let opts = BenchOpts::parse("table1_nlr");
    let threads = opts.threads;
    let mut report = BenchReport::new("table1_nlr", threads).with_backend(opts.backend);
    let d0 = 1024;
    let widths: Vec<usize> = (0..48).map(|i| if i % 2 == 0 { 4096 } else { 1024 }).collect();
    println!(
        "# Table 1: NLR lower bounds, ViT-L surrogate (d0=1024, 48 layers, density 5%, threads={threads})"
    );
    println!("{:<40} {:>14} {:>12}", "setting", "log10 NLR", "overhead");
    for row in table1_rows_mt(d0, &widths, 0.05, threads) {
        println!(
            "{:<40} {:>14.1} {:>12}",
            row.setting,
            row.log10_nlr,
            match row.depth_overhead {
                Some(0) => "0".into(),
                Some(l) => format!("{l} layers"),
                None => "stalls".into(),
            }
        );
        report.push(
            BenchRecord::value("table1", &row.setting).with_metric("log10_nlr", row.log10_nlr),
        );
    }

    // --- registry-derived rows: caps from typed pattern params ----------
    println!("\n# pattern-spec rows (r from SparsePattern::rank_cap, not the density guess):");
    for spec in ["diag:51", "nm:1:20"] {
        let p = resolve_pattern(spec)?;
        for row in pattern_rows(p.as_ref(), d0, &widths, 0.05) {
            println!("{:<40} {:>14.1}", row.setting, row.log10_nlr);
            report.push(
                BenchRecord::value("table1_pattern", &row.setting)
                    .with_pattern(spec)
                    .with_metric("log10_nlr", row.log10_nlr),
            );
        }
    }

    // --- Apdx B: alternating caps 51/205, catch-up at 4 blocks ----------
    let r: Vec<usize> = (0..48).map(|i| if i % 2 == 0 { 51 } else { 205 }).collect();
    let dims = effective_dims_var(d0, &widths, &r);
    let catchup = dims.iter().position(|&k| k == d0).unwrap();
    println!("\n# Apdx B: span budget saturates at layer {} (paper: 8 = 4 blocks)", catchup + 1);
    assert_eq!(catchup + 1, 8);

    // --- Apdx C.1: exact worked example ---------------------------------
    println!("\n# Apdx C.1 exact (d0=4, widths 8x3):");
    println!("  dense layer factor        = {} (paper: 163)", layer_factor_u128(8, 4));
    println!("  block-2 layer factor      = {} (paper: 37)", layer_factor_u128(8, 2));
    println!(
        "  dense NLR >= {} | block-2 >= {} | block-2+perm >= {}",
        nlr_bound_u128(Setting::Dense, 4, &[8, 8, 8]),
        nlr_bound_u128(Setting::StructNoPerm { r: 2 }, 4, &[8, 8, 8]),
        nlr_bound_u128(Setting::StructPerm { r: 2 }, 4, &[8, 8, 8]),
    );

    // --- timing ----------------------------------------------------------
    let (bw, bi, bt) = opts.budget(3, 20, 0.3);
    let s = bench(
        || {
            let _ = log10_nlr_bound(Setting::StructPerm { r: 51 }, d0, &widths);
        },
        bw,
        bi,
        bt,
    );
    println!("\n# bound evaluation: {} per 48-layer network", fmt_time(s.p50));
    report.push(BenchRecord::from_summary("nlr", "bound_eval(48-layer)", &s));

    report.write(&opts.json_path)?;
    println!("# wrote {}", opts.json_path.display());
    Ok(())
}
