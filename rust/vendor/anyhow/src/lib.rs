//! Offline stand-in for the `anyhow` crate.
//!
//! The PA-DST build runs with no network and no registry cache, so the
//! error-handling surface the crate actually uses is vendored here:
//! [`Error`], [`Result`], the [`anyhow!`] and [`bail!`] macros, and the
//! [`Context`] extension trait.  Errors carry a single flattened message
//! string — context wraps as `"context: cause"` — which is all the
//! coordinator, CLI, and tests rely on.
//!
//! Not implemented (and not used anywhere in the workspace): downcasting,
//! backtraces, `std::error::Error` source chains.  `Error` deliberately
//! does **not** implement `std::error::Error`, which is what makes the
//! blanket `From<E: std::error::Error>` conversion (powering `?`) coherent.

use std::fmt;

/// A flattened error message with `"context: cause"` nesting.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

/// `?` on std errors (io, utf8, parse, ...) converts into [`Error`].
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// Drop-in for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context extension for results, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap the error with a lazily built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// `return Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/padst")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_wraps_outermost_first() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.with_context(|| "opening artifact").unwrap_err();
        assert!(e.to_string().starts_with("opening artifact: "));
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad density {}", 1.5);
        assert_eq!(e.to_string(), "bad density 1.5");
        let name = "fc1";
        let e2 = anyhow!("missing site {name:?}");
        assert_eq!(e2.to_string(), "missing site \"fc1\"");
        fn bails() -> Result<()> {
            bail!("stop at {}", 3);
        }
        assert_eq!(bails().unwrap_err().to_string(), "stop at 3");
    }
}
