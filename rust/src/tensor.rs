//! Host-side tensors + the `.tnz` bundle format shared with the Python
//! compile path (see `python/compile/aot.py::write_tnz`).
//!
//! `.tnz` layout: `u64 LE header_len | JSON header | raw LE payload` where
//! the header is `[{name, shape, dtype, offset, nbytes}, ...]`.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// Element type of a tensor — the pipeline only uses f32 and i32.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            _ => bail!("unsupported dtype {s:?}"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
        }
    }
}

/// A host tensor: shape + either f32 or i32 storage.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

#[derive(Clone, Debug)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: Data::F32(vec![0.0; numel(shape)]) }
    }

    pub fn zeros_i32(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: Data::I32(vec![0; numel(shape)]) }
    }

    pub fn from_f32(shape: &[usize], v: Vec<f32>) -> Tensor {
        assert_eq!(numel(shape), v.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data: Data::F32(v) }
    }

    pub fn from_i32(shape: &[usize], v: Vec<i32>) -> Tensor {
        assert_eq!(numel(shape), v.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data: Data::I32(v) }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: Data::F32(vec![v]) }
    }

    pub fn scalar_i32(v: i32) -> Tensor {
        Tensor { shape: vec![], data: Data::I32(vec![v]) }
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            Data::F32(_) => DType::F32,
            Data::I32(_) => DType::I32,
        }
    }

    pub fn numel(&self) -> usize {
        numel(&self.shape)
    }

    pub fn nbytes(&self) -> usize {
        self.numel() * 4
    }

    pub fn f32s(&self) -> &[f32] {
        match &self.data {
            Data::F32(v) => v,
            _ => panic!("tensor is i32, expected f32"),
        }
    }

    pub fn f32s_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            Data::F32(v) => v,
            _ => panic!("tensor is i32, expected f32"),
        }
    }

    pub fn i32s(&self) -> &[i32] {
        match &self.data {
            Data::I32(v) => v,
            _ => panic!("tensor is f32, expected i32"),
        }
    }

    pub fn i32s_mut(&mut self) -> &mut [i32] {
        match &mut self.data {
            Data::I32(v) => v,
            _ => panic!("tensor is f32, expected i32"),
        }
    }

    /// 2-D accessor, row-major.
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.f32s()[i * self.shape[1] + j]
    }

    /// Max |a - b| over two same-shaped f32 tensors.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        match (&self.data, &other.data) {
            (Data::F32(a), Data::F32(b)) => a
                .iter()
                .zip(b)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max),
            (Data::I32(a), Data::I32(b)) => a
                .iter()
                .zip(b)
                .map(|(x, y)| (x - y).abs() as f32)
                .fold(0.0f32, f32::max),
            _ => panic!("dtype mismatch"),
        }
    }
}

pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product::<usize>().max(1)
}

// ---------------------------------------------------------------------------
// .tnz bundles
// ---------------------------------------------------------------------------

/// Read a `.tnz` bundle into an ordered name->tensor map.
pub fn read_tnz(path: &Path) -> Result<BTreeMap<String, Tensor>> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut len_buf = [0u8; 8];
    f.read_exact(&mut len_buf)?;
    let hlen = u64::from_le_bytes(len_buf) as usize;
    let mut hdr = vec![0u8; hlen];
    f.read_exact(&mut hdr)?;
    let metas = Json::parse(std::str::from_utf8(&hdr)?)?;
    let mut payload = Vec::new();
    f.read_to_end(&mut payload)?;

    let mut out = BTreeMap::new();
    for m in metas.as_arr().ok_or_else(|| anyhow!("tnz header not an array"))? {
        let name = m.at(&["name"])?.as_str().unwrap().to_string();
        let shape: Vec<usize> = m
            .at(&["shape"])?
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap())
            .collect();
        let dtype = DType::parse(m.at(&["dtype"])?.as_str().unwrap())?;
        let off = m.at(&["offset"])?.as_usize().unwrap();
        let nbytes = m.at(&["nbytes"])?.as_usize().unwrap();
        let bytes = &payload[off..off + nbytes];
        let t = match dtype {
            DType::F32 => Tensor::from_f32(
                &shape,
                bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            DType::I32 => Tensor::from_i32(
                &shape,
                bytes
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
        };
        out.insert(name, t);
    }
    Ok(out)
}

/// Write a `.tnz` bundle (used for checkpoints).
pub fn write_tnz(path: &Path, tensors: &[(String, &Tensor)]) -> Result<()> {
    let mut metas = Vec::new();
    let mut payload: Vec<u8> = Vec::new();
    for (name, t) in tensors {
        let offset = payload.len();
        match &t.data {
            Data::F32(v) => {
                for x in v {
                    payload.extend_from_slice(&x.to_le_bytes());
                }
            }
            Data::I32(v) => {
                for x in v {
                    payload.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        metas.push(json::obj(vec![
            ("name", json::s(name)),
            ("shape", json::arr(t.shape.iter().map(|&d| json::num(d as f64)))),
            ("dtype", json::s(t.dtype().name())),
            ("offset", json::num(offset as f64)),
            ("nbytes", json::num((payload.len() - offset) as f64)),
        ]));
    }
    let hdr = Json::Arr(metas).to_string_pretty();
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    f.write_all(&(hdr.len() as u64).to_le_bytes())?;
    f.write_all(hdr.as_bytes())?;
    f.write_all(&payload)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tnz_roundtrip() {
        let dir = std::env::temp_dir().join("padst_tnz_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.tnz");
        let a = Tensor::from_f32(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = Tensor::from_i32(&[4], vec![7, -8, 9, 10]);
        write_tnz(&p, &[("a".into(), &a), ("b".into(), &b)]).unwrap();
        let m = read_tnz(&p).unwrap();
        assert_eq!(m["a"].shape, vec![2, 3]);
        assert_eq!(m["a"].f32s(), a.f32s());
        assert_eq!(m["b"].i32s(), b.i32s());
    }

    #[test]
    fn accessors() {
        let t = Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.at2(1, 0), 3.0);
        assert_eq!(t.numel(), 4);
        assert_eq!(t.dtype(), DType::F32);
    }
}
