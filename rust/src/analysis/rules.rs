//! The lint rules, L1-L6.  Each rule is a pure function over the
//! [`LintCtx`] producing [`Diagnostic`]s; nothing here touches the
//! filesystem, so every rule is testable on fixture snippets.
//!
//! | id | name               | what it enforces                                       |
//! |----|--------------------|--------------------------------------------------------|
//! | L1 | layering           | `crate::` edges obey the `ci/lint/layers.toml` DAG     |
//! | L2 | no-alloc           | `// lint: no-alloc` fn bodies never allocate           |
//! | L3 | atomic-ordering    | non-Relaxed `Ordering::` sites carry `// ordering:`    |
//! | L4 | no-panic           | `// lint: no-panic` fn bodies never unwrap/panic       |
//! | L5 | schema-literals    | schema versions: one const, no adjacent literals, README agrees |
//! | L6 | forbid-unsafe      | `#![forbid(unsafe_code)]` stays in `rust/src/lib.rs`   |
//!
//! Scope decisions (deliberate, documented here because they shape what
//! the rules can and cannot see):
//!
//! - Rules scan `rust/src/` only; benches/tests/examples are dev-side.
//! - `#[cfg(test)]` regions are exempt from L1/L3/L5 — a test may import
//!   upward or use SeqCst without ceremony.
//! - L2/L4 are *lexical*: they check the annotated body's own tokens,
//!   not its callees.  That is the point — the rule pins the warm-path
//!   *entry* free of banned constructs, and every helper it calls is
//!   either annotated itself or covered by the runtime fingerprints.
//! - Any finding can be waived in place with `// lint: allow(<id>)
//!   <reason>` on the site's line or the line above.

use std::collections::BTreeMap;

use super::layers::LayerManifest;
use super::report::{Diagnostic, Severity};
use super::source::SourceFile;

/// Static rule metadata (drives `--rules`, the README table, and the
/// report's `rules` field).
pub struct RuleInfo {
    pub id: &'static str,
    pub name: &'static str,
    pub severity: Severity,
    pub description: &'static str,
}

/// All known rules, id order.
pub const RULES: [RuleInfo; 6] = [
    RuleInfo {
        id: "L1",
        name: "layering",
        severity: Severity::Error,
        description: "use crate:: edges must obey the declared module DAG (ci/lint/layers.toml)",
    },
    RuleInfo {
        id: "L2",
        name: "no-alloc",
        severity: Severity::Error,
        description: "fns annotated `// lint: no-alloc` must not allocate (push/collect/format!/...)",
    },
    RuleInfo {
        id: "L3",
        name: "atomic-ordering",
        severity: Severity::Error,
        description: "Ordering:: stricter than Relaxed needs an adjacent `// ordering:` justification",
    },
    RuleInfo {
        id: "L4",
        name: "no-panic",
        severity: Severity::Error,
        description: "fns annotated `// lint: no-panic` must not unwrap/expect/panic!/todo!",
    },
    RuleInfo {
        id: "L5",
        name: "schema-literals",
        severity: Severity::Error,
        description: "schema version constants: declared once, no adjacent hardcoded literals, README tables agree",
    },
    RuleInfo {
        id: "L6",
        name: "forbid-unsafe",
        severity: Severity::Error,
        description: "rust/src/lib.rs must keep #![forbid(unsafe_code)]",
    },
];

pub fn rule_info(id: &str) -> Option<&'static RuleInfo> {
    RULES.iter().find(|r| r.id == id)
}

/// Everything the rules read.
pub struct LintCtx<'a> {
    pub files: &'a [SourceFile],
    /// Required when L1 runs.
    pub manifest: Option<&'a LayerManifest>,
    /// README text for the L5 doc-table check (absent on fixture trees).
    pub readme: Option<&'a str>,
}

/// Run one rule by id.
pub fn run_rule(id: &str, ctx: &LintCtx) -> Vec<Diagnostic> {
    match id {
        "L1" => l1_layering(ctx),
        "L2" => l2_l4_annotated(ctx, "no-alloc", "L2", &l2_banned_site),
        "L3" => l3_atomic_ordering(ctx),
        "L4" => l2_l4_annotated(ctx, "no-panic", "L4", &l4_banned_site),
        "L5" => l5_schema_literals(ctx),
        "L6" => l6_forbid_unsafe(ctx),
        _ => Vec::new(),
    }
}

fn diag(rule: &str, file: &SourceFile, line: u32, msg: String) -> Diagnostic {
    let severity = rule_info(rule).map(|r| r.severity).unwrap_or(Severity::Error);
    Diagnostic { rule: rule.to_string(), severity, file: file.rel.clone(), line, msg }
}

// ------------------------------------------------------------------- L1

/// Parse `crate::` paths out of the code-token stream and check each
/// resulting module edge against the manifest.  Handles both `use`
/// declarations and inline paths (`crate::kernels::tune::f()`), plus
/// one level of `use crate::{a::x, b::y}` grouping.
fn l1_layering(ctx: &LintCtx) -> Vec<Diagnostic> {
    let Some(manifest) = ctx.manifest else {
        return Vec::new(); // run_lint refuses earlier; belt and braces
    };
    let mut out = Vec::new();
    for f in ctx.files {
        let Some(from) = manifest.node_for(&f.module_path) else {
            out.push(diag(
                "L1",
                f,
                1,
                format!(
                    "module `{}` ({}) is not declared in the layers manifest",
                    f.module_path, f.rel
                ),
            ));
            continue;
        };
        let mut ci = 0;
        while ci + 1 < f.code.len() {
            if !(f.at(ci).is_ident("crate") && is_path_sep(f, ci + 1)) {
                ci += 1;
                continue;
            }
            // `foo::crate` is impossible; a leading `crate` token is
            // always a crate-root path.
            let line = f.at(ci).line;
            if f.in_test_region(line) {
                ci += 1;
                continue;
            }
            let after = ci + 3; // first ident (or `{`) after `crate::`
            if after >= f.code.len() {
                break;
            }
            if f.at(after).is_punct('{') {
                // use crate::{a::x, b::y};
                let mut j = after + 1;
                let mut depth = 1usize;
                let mut expect_path = true;
                while j < f.code.len() && depth > 0 {
                    if f.at(j).is_punct('{') {
                        depth += 1;
                        expect_path = true;
                    } else if f.at(j).is_punct('}') {
                        depth -= 1;
                    } else if f.at(j).is_punct(',') && depth == 1 {
                        expect_path = true;
                    } else if expect_path && f.at(j).kind == super::lexer::TokenKind::Ident {
                        check_edge(manifest, f, from, j, &mut out);
                        expect_path = false;
                    }
                    j += 1;
                }
                ci = j;
            } else {
                check_edge(manifest, f, from, after, &mut out);
                ci = after;
            }
        }
    }
    out
}

fn is_path_sep(f: &SourceFile, ci: usize) -> bool {
    ci + 1 < f.code.len() && f.at(ci).is_punct(':') && f.at(ci + 1).is_punct(':')
}

/// Check one edge whose target path starts at code-index `start`.
fn check_edge(
    manifest: &LayerManifest,
    f: &SourceFile,
    from: &str,
    start: usize,
    out: &mut Vec<Diagnostic>,
) {
    use super::lexer::TokenKind;
    if f.at(start).kind != TokenKind::Ident {
        return;
    }
    let seg0 = f.at(start).text.clone();
    if seg0 == "self" || seg0 == "super" {
        return;
    }
    // Capture an optional second segment so `[split]` nodes like
    // `kernels::micro` resolve to their own node.
    let mut path = seg0;
    if start + 3 < f.code.len()
        && is_path_sep(f, start + 1)
        && f.at(start + 3).kind == TokenKind::Ident
    {
        path = format!("{path}::{}", f.at(start + 3).text);
    }
    let line = f.at(start).line;
    if f.allow_covers("L1", line) {
        return;
    }
    match manifest.node_for(&path) {
        None => out.push(diag(
            "L1",
            f,
            line,
            format!("edge {from} -> crate::{path}: target module is not declared in the layers manifest"),
        )),
        Some(to) => {
            if !manifest.allows(from, to) {
                out.push(diag(
                    "L1",
                    f,
                    line,
                    format!("layering violation: {from} may not depend on {to} (crate::{path})"),
                ));
            }
        }
    }
}

// --------------------------------------------------------------- L2 / L4

/// Shared driver for the annotation-scoped rules: find every
/// `// lint: <directive>` fn, scan its body tokens, and let the
/// rule-specific `banned` callback flag sites.
fn l2_l4_annotated(
    ctx: &LintCtx,
    directive: &str,
    rule: &str,
    banned: &dyn Fn(&SourceFile, usize) -> Option<String>,
) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for f in ctx.files {
        for ann in f.fn_annotations() {
            if ann.directive != directive {
                continue;
            }
            let (a, b) = ann.body;
            for ci in a..b {
                let Some(what) = banned(f, ci) else { continue };
                let line = f.at(ci).line;
                if f.allow_covers(rule, line) {
                    continue;
                }
                out.push(diag(
                    rule,
                    f,
                    line,
                    format!("{what} in `{directive}` fn `{}`", ann.fn_name),
                ));
            }
        }
    }
    out
}

/// Allocation sites for L2.  Exact-token matching: `unwrap_or_else`,
/// `resize` (the sanctioned grow-only scratch idiom), `copy_from_slice`
/// never match.
fn l2_banned_site(f: &SourceFile, ci: usize) -> Option<String> {
    const METHODS: [&str; 9] = [
        "push",
        "extend",
        "extend_from_slice",
        "append",
        "to_vec",
        "collect",
        "clone",
        "to_string",
        "to_owned",
    ];
    const MACROS: [&str; 2] = ["format", "vec"];
    const TYPES: [&str; 3] = ["Vec", "String", "Box"];
    const CTORS: [&str; 4] = ["new", "from", "with_capacity", "default"];
    let t = f.at(ci);
    if t.kind != super::lexer::TokenKind::Ident {
        return None;
    }
    let next_is = |c: char| ci + 1 < f.code.len() && f.at(ci + 1).is_punct(c);
    let name = t.text.as_str();
    if MACROS.contains(&name) && next_is('!') {
        return Some(format!("`{name}!` allocation"));
    }
    if METHODS.contains(&name) && (next_is('(') || is_path_sep(f, ci + 1)) {
        return Some(format!("`{name}()` call"));
    }
    if TYPES.contains(&name) && is_path_sep(f, ci + 1) {
        // Walk past `::` (and any `::<...>` turbofish) to the ctor name.
        let mut j = ci + 3;
        if j < f.code.len() && f.at(j).is_punct('<') {
            let mut depth = 1usize;
            j += 1;
            while j < f.code.len() && depth > 0 {
                if f.at(j).is_punct('<') {
                    depth += 1;
                } else if f.at(j).is_punct('>') {
                    depth -= 1;
                }
                j += 1;
            }
            if j + 1 < f.code.len() && is_path_sep(f, j) {
                j += 2;
            }
        }
        if j < f.code.len()
            && f.at(j).kind == super::lexer::TokenKind::Ident
            && CTORS.contains(&f.at(j).text.as_str())
        {
            return Some(format!("`{name}::{}` allocation", f.at(j).text));
        }
    }
    None
}

/// Panic sites for L4.
fn l4_banned_site(f: &SourceFile, ci: usize) -> Option<String> {
    const CALLS: [&str; 2] = ["unwrap", "expect"];
    const MACROS: [&str; 4] = ["panic", "todo", "unimplemented", "unreachable"];
    let t = f.at(ci);
    if t.kind != super::lexer::TokenKind::Ident {
        return None;
    }
    let next_is = |c: char| ci + 1 < f.code.len() && f.at(ci + 1).is_punct(c);
    let name = t.text.as_str();
    if CALLS.contains(&name) && next_is('(') {
        return Some(format!("`{name}()` call"));
    }
    if MACROS.contains(&name) && next_is('!') {
        return Some(format!("`{name}!`"));
    }
    None
}

// ------------------------------------------------------------------- L3

const STRICT_ORDERINGS: [&str; 4] = ["Acquire", "Release", "AcqRel", "SeqCst"];

/// Every `Ordering::{Acquire,Release,AcqRel,SeqCst}` site outside test
/// regions needs a `// ordering:` comment on its line or within the two
/// lines above.  (`std::cmp::Ordering` variants never match the list.)
fn l3_atomic_ordering(ctx: &LintCtx) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for f in ctx.files {
        for ci in 0..f.code.len() {
            if !f.at(ci).is_ident("Ordering") || !is_path_sep(f, ci + 1) {
                continue;
            }
            let vi = ci + 3;
            if vi >= f.code.len() {
                continue;
            }
            let variant = f.at(vi).text.as_str();
            if !STRICT_ORDERINGS.contains(&variant) {
                continue;
            }
            let line = f.at(vi).line;
            if f.in_test_region(line) || f.allow_covers("L3", line) {
                continue;
            }
            if f.comment_near("ordering:", line, 2) {
                continue;
            }
            out.push(diag(
                "L3",
                f,
                line,
                format!("Ordering::{variant} without an adjacent `// ordering:` justification"),
            ));
        }
    }
    out
}

// ------------------------------------------------------------------- L5

/// The crate's schema-versioned wire formats: constant name <-> the JSON
/// key it is stamped under.  L5 keeps the three legs consistent —
/// declaration (exactly one const), usage (no integer literal parked
/// next to the wire key in place of the const), documentation (README
/// mentions of `key`:N agree with the const).
const SCHEMAS: [(&str, &str); 5] = [
    ("OBS_SCHEMA_VERSION", "obs_schema"),
    ("SCHEMA_VERSION", "schema_version"),
    ("TUNE_SCHEMA_VERSION", "tune_schema"),
    ("LINT_SCHEMA_VERSION", "lint_schema"),
    ("PROTOCOL_VERSION", "v"),
];

/// Tokens scanned ahead of a wire-key string literal before giving up;
/// an intervening `schema`/`version` ident justifies the site.
const L5_WINDOW: usize = 8;

fn l5_schema_literals(ctx: &LintCtx) -> Vec<Diagnostic> {
    use super::lexer::TokenKind;
    let mut out = Vec::new();

    // Leg 1: each constant declared exactly once, capture its value.
    let mut decls: BTreeMap<&str, Vec<(usize, u32, u64)>> = BTreeMap::new(); // name -> (file idx, line, value)
    for (fi, f) in ctx.files.iter().enumerate() {
        for ci in 0..f.code.len() {
            if !f.at(ci).is_ident("const") {
                continue;
            }
            let Some(&(name, _)) = SCHEMAS
                .iter()
                .find(|(n, _)| ci + 1 < f.code.len() && f.at(ci + 1).is_ident(n))
            else {
                continue;
            };
            // `const NAME: u32 = <value>;`
            let val = (ci..f.code.len().min(ci + 8))
                .find(|&j| f.at(j).kind == TokenKind::Num)
                .and_then(|j| f.at(j).text.parse::<u64>().ok());
            if let Some(v) = val {
                decls.entry(name).or_default().push((fi, f.at(ci).line, v));
            }
        }
    }
    for (name, sites) in &decls {
        if sites.len() > 1 {
            for &(fi, line, _) in &sites[1..] {
                out.push(diag(
                    "L5",
                    &ctx.files[fi],
                    line,
                    format!("schema constant {name} declared more than once"),
                ));
            }
        }
    }
    let value_of =
        |name: &str| decls.get(name).and_then(|s| s.first()).map(|&(_, _, v)| v);

    // Leg 2: wire-key string literals followed by a bare integer literal
    // (instead of the constant) — writer or parser hardcoding a version.
    for f in ctx.files {
        for ci in 0..f.code.len() {
            let t = f.at(ci);
            if t.kind != TokenKind::Str {
                continue;
            }
            let Some((cname, key)) = SCHEMAS.iter().find(|(_, k)| t.text == *k) else {
                continue;
            };
            let line = t.line;
            if f.in_test_region(line) || f.allow_covers("L5", line) {
                continue;
            }
            for j in ci + 1..f.code.len().min(ci + 1 + L5_WINDOW) {
                let u = f.at(j);
                if u.is_punct(';') {
                    break;
                }
                if u.kind == TokenKind::Ident {
                    let lower = u.text.to_ascii_lowercase();
                    if lower.contains("schema") || lower.contains("version") {
                        break; // the const (or a field mirroring it) is in play
                    }
                }
                if u.kind == TokenKind::Num {
                    out.push(diag(
                        "L5",
                        f,
                        line,
                        format!(
                            "hardcoded version literal next to wire key \"{key}\" (use {cname})"
                        ),
                    ));
                    break;
                }
            }
        }
    }

    // Leg 3: README mentions of `key … N` must agree with the constant.
    if let Some(readme) = ctx.readme {
        for (cname, key) in SCHEMAS {
            let Some(expect) = value_of(cname) else { continue };
            for (line, found) in readme_version_mentions(readme, key) {
                if found != expect {
                    out.push(Diagnostic {
                        rule: "L5".into(),
                        severity: Severity::Error,
                        file: "README.md".into(),
                        line,
                        msg: format!(
                            "README says {key} = {found}, but {cname} = {expect}"
                        ),
                    });
                }
            }
        }
    }
    out
}

/// Find `key":1` / `key | 1 |`-style numeric mentions of a wire key in
/// prose: after a word-boundary occurrence of `key`, skip up to six
/// separator chars (quote, backtick, colon, equals, pipe, space) and
/// parse any digits found.
fn readme_version_mentions(text: &str, key: &str) -> Vec<(u32, u64)> {
    let mut out = Vec::new();
    for (li, line) in text.lines().enumerate() {
        let bytes = line.as_bytes();
        let mut start = 0usize;
        while let Some(pos) = line[start..].find(key) {
            let i = start + pos;
            start = i + key.len();
            let before_ok = i == 0 || !(bytes[i - 1].is_ascii_alphanumeric() || bytes[i - 1] == b'_');
            let after = i + key.len();
            let after_ok = after >= bytes.len()
                || !(bytes[after].is_ascii_alphanumeric() || bytes[after] == b'_');
            if !before_ok || !after_ok {
                continue;
            }
            let mut j = after;
            let mut skipped = 0usize;
            while j < bytes.len()
                && skipped < 6
                && matches!(bytes[j], b'"' | b'\'' | b'`' | b':' | b'=' | b'|' | b' ' | b'\t')
            {
                j += 1;
                skipped += 1;
            }
            let d0 = j;
            while j < bytes.len() && bytes[j].is_ascii_digit() {
                j += 1;
            }
            if j > d0 {
                if let Ok(v) = line[d0..j].parse::<u64>() {
                    out.push((li as u32 + 1, v));
                }
            }
        }
    }
    out
}

// ------------------------------------------------------------------- L6

/// `rust/src/lib.rs` must carry `#![forbid(unsafe_code)]`.
fn l6_forbid_unsafe(ctx: &LintCtx) -> Vec<Diagnostic> {
    let Some(lib) = ctx.files.iter().find(|f| f.rel == "rust/src/lib.rs") else {
        return vec![Diagnostic {
            rule: "L6".into(),
            severity: Severity::Error,
            file: "rust/src/lib.rs".into(),
            line: 1,
            msg: "rust/src/lib.rs not found (cannot verify #![forbid(unsafe_code)])".into(),
        }];
    };
    let has = (0..lib.code.len().saturating_sub(3)).any(|ci| {
        lib.at(ci).is_ident("forbid")
            && lib.at(ci + 1).is_punct('(')
            && lib.at(ci + 2).is_ident("unsafe_code")
            && lib.at(ci + 3).is_punct(')')
    });
    if has {
        Vec::new()
    } else {
        vec![diag(
            "L6",
            lib,
            1,
            "missing #![forbid(unsafe_code)] crate attribute".to_string(),
        )]
    }
}
