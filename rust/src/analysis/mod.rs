//! Static analysis (`padst lint`): a dependency-free invariant checker
//! for this repo's own sources.
//!
//! Layout:
//! - [`lexer`]  — hand-rolled Rust lexer (comments, strings, raw strings)
//! - [`source`] — per-file model: module path, test regions, annotations
//! - [`layers`] — the `ci/lint/layers.toml` module-DAG manifest for L1
//! - [`rules`]  — the rules themselves (L1-L6)
//! - [`report`] — diagnostics, JSON report, committed baseline
//!
//! The checker exists because the invariants it enforces are exactly the
//! ones `rustc` and clippy cannot see: *which* module may import which
//! (layering), *which* functions sit on the serve/tuned warm path and
//! must stay allocation-free, and *which* atomic sites carry a written
//! justification for their memory ordering.  Everything is std-only —
//! the lexer is ~300 lines, the manifest parser a TOML subset — so the
//! lint runs in the same offline build as the rest of the crate.
//!
//! Entry point: [`run_lint`].  `padst lint` (see `main.rs`) wraps it
//! with flag parsing, `--fix-baseline`, and exit-code mapping.

pub mod layers;
pub mod lexer;
pub mod report;
pub mod rules;
pub mod source;

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use layers::LayerManifest;
use report::{sort_diagnostics, Baseline, Diagnostic, LintReport};
use rules::{LintCtx, RULES};
use source::SourceFile;

/// What to lint and how.
pub struct LintOptions {
    /// Repo root (the directory holding `rust/`, `ci/`, `README.md`).
    pub root: PathBuf,
    /// Rule ids to run, sorted.  Empty set = all rules.
    pub rules: BTreeSet<String>,
    /// Layering manifest path, relative to root unless absolute.
    pub manifest_path: PathBuf,
    /// Baseline path, relative to root unless absolute.
    pub baseline_path: PathBuf,
}

impl LintOptions {
    pub fn new(root: PathBuf) -> LintOptions {
        LintOptions {
            root,
            rules: BTreeSet::new(),
            manifest_path: PathBuf::from("ci/lint/layers.toml"),
            baseline_path: PathBuf::from("ci/lint/baseline.json"),
        }
    }

    fn resolve(&self, p: &Path) -> PathBuf {
        if p.is_absolute() {
            p.to_path_buf()
        } else {
            self.root.join(p)
        }
    }

    /// The effective rule list (defaults to all), validated and sorted.
    pub fn effective_rules(&self) -> Result<Vec<String>> {
        if self.rules.is_empty() {
            return Ok(RULES.iter().map(|r| r.id.to_string()).collect());
        }
        for id in &self.rules {
            if rules::rule_info(id).is_none() {
                bail!(
                    "unknown lint rule {id:?} (known: {})",
                    RULES.iter().map(|r| r.id).collect::<Vec<_>>().join(", ")
                );
            }
        }
        Ok(self.rules.iter().cloned().collect())
    }
}

/// The result of a lint run.
pub struct LintOutcome {
    /// Baseline-filtered report (what `--format json` prints).
    pub report: LintReport,
    /// Every finding pre-baseline, canonically sorted (what
    /// `--fix-baseline` snapshots).
    pub all: Vec<Diagnostic>,
}

/// Run the configured rules over `<root>/rust/src/**/*.rs`.
pub fn run_lint(opts: &LintOptions) -> Result<LintOutcome> {
    let rule_ids = opts.effective_rules()?;

    let src_root = opts.root.join("rust/src");
    if !src_root.is_dir() {
        bail!("lint root {} has no rust/src directory", opts.root.display());
    }
    let mut paths = Vec::new();
    collect_rs_files(&src_root, &mut paths)?;
    paths.sort();

    let mut files = Vec::with_capacity(paths.len());
    for p in &paths {
        let src = std::fs::read_to_string(p)
            .with_context(|| format!("reading {}", p.display()))?;
        let rel = p
            .strip_prefix(&opts.root)
            .unwrap_or(p)
            .to_string_lossy()
            .replace('\\', "/");
        files.push(SourceFile::parse(&rel, &src));
    }

    let manifest = if rule_ids.iter().any(|r| r == "L1") {
        let mp = opts.resolve(&opts.manifest_path);
        let text = std::fs::read_to_string(&mp).with_context(|| {
            format!("rule L1 needs the layering manifest at {}", mp.display())
        })?;
        Some(LayerManifest::parse(&text)?)
    } else {
        None
    };

    let readme = std::fs::read_to_string(opts.root.join("README.md")).ok();

    let ctx = LintCtx {
        files: &files,
        manifest: manifest.as_ref(),
        readme: readme.as_deref(),
    };
    let mut all = Vec::new();
    for id in &rule_ids {
        all.extend(rules::run_rule(id, &ctx));
    }
    sort_diagnostics(&mut all);

    let baseline = Baseline::load(&opts.resolve(&opts.baseline_path))?;
    let mut diagnostics = Vec::new();
    let mut suppressed = 0usize;
    for d in &all {
        if baseline.covers(d) {
            suppressed += 1;
        } else {
            diagnostics.push(d.clone());
        }
    }

    Ok(LintOutcome {
        report: LintReport { rules: rule_ids, diagnostics, suppressed },
        all,
    })
}

/// Recursively gather `.rs` files under `dir` (sorted later by caller).
fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("listing {}", dir.display()))?;
    for e in entries {
        let e = e?;
        let p = e.path();
        if p.is_dir() {
            collect_rs_files(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}
