//! Per-file source model derived from the token stream: module identity,
//! `#[cfg(test)]` regions, `// lint:` annotations, and function-body
//! extents — the shared substrate every rule walks.

use super::lexer::{lex, Token, TokenKind};

/// One lexed source file plus the derived structure the rules need.
pub struct SourceFile {
    /// Repo-relative path with forward slashes (`rust/src/obs/watch.rs`).
    pub rel: String,
    /// Module path under the crate root (`obs::watch`, `main`, `lib`).
    pub module_path: String,
    /// Full token stream, comments included.
    pub tokens: Vec<Token>,
    /// Indices into `tokens` of the non-comment tokens, in order.
    pub code: Vec<usize>,
    /// Line ranges (inclusive) covered by `#[cfg(test)]` blocks.
    test_regions: Vec<(u32, u32)>,
    /// `// lint: allow(<rule>)` suppressions: (rule id, comment line).
    allows: Vec<(String, u32)>,
}

/// A `// lint: no-alloc` / `// lint: no-panic` annotation bound to the
/// function that follows it.
pub struct FnAnnotation {
    /// `no-alloc` or `no-panic`.
    pub directive: String,
    /// Name of the annotated fn (for messages).
    pub fn_name: String,
    /// Exclusive range of *code indices* covering the fn body.
    pub body: (usize, usize),
    /// Line of the `fn` token.
    pub line: u32,
}

impl SourceFile {
    pub fn parse(rel: &str, src: &str) -> SourceFile {
        let tokens = lex(src);
        let code: Vec<usize> =
            tokens.iter().enumerate().filter(|(_, t)| !t.is_comment()).map(|(i, _)| i).collect();
        let test_regions = find_test_regions(&tokens, &code);
        let allows = find_allows(&tokens);
        SourceFile {
            rel: rel.to_string(),
            module_path: module_path_of(rel),
            tokens,
            code,
            test_regions,
            allows,
        }
    }

    /// Whether a source line falls inside a `#[cfg(test)]` block.
    pub fn in_test_region(&self, line: u32) -> bool {
        self.test_regions.iter().any(|&(a, b)| line >= a && line <= b)
    }

    /// Whether a `// lint: allow(<rule>)` comment covers this line (the
    /// comment's own line, or the line directly above the site).
    pub fn allow_covers(&self, rule: &str, line: u32) -> bool {
        self.allows.iter().any(|(r, l)| r == rule && (*l == line || *l + 1 == line))
    }

    /// The code token at code-index `ci`.
    pub fn at(&self, ci: usize) -> &Token {
        &self.tokens[self.code[ci]]
    }

    /// All `// lint: no-alloc` / `no-panic` annotations with their
    /// resolved fn bodies.
    pub fn fn_annotations(&self) -> Vec<FnAnnotation> {
        let mut out = Vec::new();
        for t in &self.tokens {
            if t.kind != TokenKind::LineComment {
                continue;
            }
            let Some(directives) = parse_lint_comment(&t.text) else { continue };
            for d in directives {
                if d != "no-alloc" && d != "no-panic" {
                    continue;
                }
                if let Some(ann) = self.bind_to_fn(&d, t.line) {
                    out.push(ann);
                }
            }
        }
        out
    }

    /// Bind an annotation on `line` to the first `fn` at or after it.
    fn bind_to_fn(&self, directive: &str, line: u32) -> Option<FnAnnotation> {
        let fn_ci = (0..self.code.len())
            .find(|&ci| self.at(ci).line >= line && self.at(ci).is_ident("fn"))?;
        let fn_name = if fn_ci + 1 < self.code.len() && self.at(fn_ci + 1).kind == TokenKind::Ident
        {
            self.at(fn_ci + 1).text.clone()
        } else {
            String::new()
        };
        // First `{` after the fn keyword opens the body (signatures in
        // this codebase never contain braces before it).
        let open = (fn_ci..self.code.len()).find(|&ci| self.at(ci).is_punct('{'))?;
        let close = self.match_brace(open)?;
        Some(FnAnnotation {
            directive: directive.to_string(),
            fn_name,
            body: (open + 1, close),
            line: self.at(fn_ci).line,
        })
    }

    /// Code-index of the `}` matching the `{` at code-index `open`.
    pub fn match_brace(&self, open: usize) -> Option<usize> {
        let mut depth = 1usize;
        for ci in open + 1..self.code.len() {
            if self.at(ci).is_punct('{') {
                depth += 1;
            } else if self.at(ci).is_punct('}') {
                depth -= 1;
                if depth == 0 {
                    return Some(ci);
                }
            }
        }
        None
    }

    /// Whether any comment whose text contains `needle` sits on a line in
    /// `[line - above, line]` — the adjacency test for `// ordering:`
    /// justifications.
    pub fn comment_near(&self, needle: &str, line: u32, above: u32) -> bool {
        let lo = line.saturating_sub(above);
        self.tokens
            .iter()
            .filter(|t| t.is_comment())
            .any(|t| t.line >= lo && t.line <= line && t.text.contains(needle))
    }
}

/// `rust/src/kernels/micro.rs` -> `kernels::micro`; `rust/src/main.rs`
/// -> `main`; `perm/mod.rs` -> `perm`.
fn module_path_of(rel: &str) -> String {
    let p = rel.strip_prefix("rust/src/").unwrap_or(rel);
    let p = p.strip_suffix(".rs").unwrap_or(p);
    let p = p.strip_suffix("/mod").unwrap_or(p);
    p.replace('/', "::")
}

/// Find `#[cfg(test)]` attributes and the brace block that follows each.
fn find_test_regions(tokens: &[Token], code: &[usize]) -> Vec<(u32, u32)> {
    let at = |ci: usize| &tokens[code[ci]];
    let mut out = Vec::new();
    let mut ci = 0;
    while ci + 5 < code.len() {
        let is_attr = at(ci).is_punct('#')
            && at(ci + 1).is_punct('[')
            && at(ci + 2).is_ident("cfg")
            && at(ci + 3).is_punct('(')
            && at(ci + 4).is_ident("test")
            && at(ci + 5).is_punct(')');
        if !is_attr {
            ci += 1;
            continue;
        }
        let start_line = at(ci).line;
        // Skip to the block the attribute gates (`mod tests {`, or any
        // single item with a brace body).
        let mut j = ci + 6;
        while j < code.len() && !at(j).is_punct('{') {
            // A `;` first means the attribute gated a braceless item.
            if at(j).is_punct(';') {
                break;
            }
            j += 1;
        }
        if j < code.len() && at(j).is_punct('{') {
            let mut depth = 1usize;
            let mut k = j + 1;
            while k < code.len() && depth > 0 {
                if at(k).is_punct('{') {
                    depth += 1;
                } else if at(k).is_punct('}') {
                    depth -= 1;
                }
                k += 1;
            }
            let end_line = if k > 0 { at(k - 1).line } else { start_line };
            out.push((start_line, end_line));
            ci = k;
        } else {
            ci = j + 1;
        }
    }
    out
}

/// Parse a `lint:` comment body into its comma-separated directives.
/// Returns `None` when the comment is not a lint directive at all.
pub fn parse_lint_comment(text: &str) -> Option<Vec<String>> {
    let t = text.trim();
    let rest = t.strip_prefix("lint:")?;
    Some(
        rest.split(',')
            .map(|d| d.trim())
            .filter(|d| !d.is_empty())
            // `allow(L3) reason prose` — keep only the directive head.
            .map(|d| d.split_whitespace().next().unwrap_or("").to_string())
            .collect(),
    )
}

/// Collect `lint: allow(<rule>)` suppression comments.
fn find_allows(tokens: &[Token]) -> Vec<(String, u32)> {
    let mut out = Vec::new();
    for t in tokens {
        if !t.is_comment() {
            continue;
        }
        let Some(directives) = parse_lint_comment(&t.text) else { continue };
        for d in directives {
            if let Some(rule) = d.strip_prefix("allow(").and_then(|s| s.strip_suffix(')')) {
                out.push((rule.to_string(), t.line));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn module_paths() {
        assert_eq!(module_path_of("rust/src/kernels/micro.rs"), "kernels::micro");
        assert_eq!(module_path_of("rust/src/perm/mod.rs"), "perm");
        assert_eq!(module_path_of("rust/src/main.rs"), "main");
        assert_eq!(module_path_of("rust/src/lib.rs"), "lib");
        assert_eq!(module_path_of("rust/src/tensor.rs"), "tensor");
    }

    #[test]
    fn test_regions_cover_mod_tests() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\n";
        let f = SourceFile::parse("rust/src/x.rs", src);
        assert!(!f.in_test_region(1));
        assert!(f.in_test_region(4));
    }

    #[test]
    fn annotation_binds_to_next_fn_body() {
        let src = "// lint: no-alloc\nfn hot(v: &mut Vec<u8>) {\n    v.push(1);\n}\nfn cold() { Vec::<u8>::new(); }\n";
        let f = SourceFile::parse("rust/src/x.rs", src);
        let anns = f.fn_annotations();
        assert_eq!(anns.len(), 1);
        assert_eq!(anns[0].fn_name, "hot");
        // Body covers push but not the second fn.
        let (a, b) = anns[0].body;
        let body_idents: Vec<&str> = (a..b)
            .map(|ci| f.at(ci))
            .filter(|t| t.kind == TokenKind::Ident)
            .map(|t| t.text.as_str())
            .collect();
        assert!(body_idents.contains(&"push"));
        assert!(!body_idents.contains(&"cold"));
    }

    #[test]
    fn allow_comments_cover_adjacent_lines() {
        let src = "// lint: allow(L3) startup-only flag\nlet x = 1;\n";
        let f = SourceFile::parse("rust/src/x.rs", src);
        assert!(f.allow_covers("L3", 1));
        assert!(f.allow_covers("L3", 2));
        assert!(!f.allow_covers("L3", 3));
        assert!(!f.allow_covers("L2", 2));
    }

    #[test]
    fn comment_near_window() {
        let src = "// ordering: gate publishes table\nx.store(1, Ordering::Release);\n";
        let f = SourceFile::parse("rust/src/x.rs", src);
        assert!(f.comment_near("ordering:", 2, 2));
        assert!(!f.comment_near("ordering:", 1 + 4, 2));
    }
}
