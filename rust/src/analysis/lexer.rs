//! A lightweight hand-rolled Rust lexer — just enough token structure for
//! the lint rules, dependency-free like the rest of the crate.
//!
//! This is deliberately *not* a full Rust lexer: no keyword table, no
//! numeric-suffix validation, no shebang handling.  What the rules need —
//! and what this delivers exactly — is a token stream where comments,
//! string/char literals, identifiers, numbers, and punctuation are
//! separated with correct line numbers, so that:
//!
//! - `crate::foo` paths inside doc comments or string literals are *not*
//!   layering edges (L1),
//! - `unwrap_or_else` never matches a banned `unwrap` (L2/L4 match whole
//!   identifier tokens, not substrings),
//! - `// ordering:` / `// lint:` comments are first-class tokens the
//!   rules can associate with adjacent code lines (L2-L4),
//! - raw strings containing `"tune_schema":99` (the parser's own
//!   negative tests) produce no string-key tokens of their own (L5).
//!
//! Handled: line comments (`//`, `///`, `//!`), nested block comments,
//! plain/raw/byte string literals (`"…"`, `r"…"`, `r#"…"#` at any hash
//! depth, `b"…"`, `br#"…"#`), char and byte-char literals vs. lifetimes,
//! raw identifiers (`r#fn`), and multi-char number forms well enough to
//! keep them out of the identifier stream.

/// What a token is; `text` carries the exact source slice (for comments
/// and string literals, *without* the delimiters).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TokenKind {
    /// Identifier or keyword (`fn`, `push`, `Ordering`, ...).
    Ident,
    /// Integer or float literal (text kept verbatim, suffix included).
    Num,
    /// String literal; `text` is the raw *content* between the quotes.
    Str,
    /// Char or byte literal (content not needed by any rule).
    Char,
    /// Lifetime or loop label (`'a`, `'outer`).
    Lifetime,
    /// One punctuation byte (`::` arrives as two `:` tokens).
    Punct(char),
    /// `//`-style comment; `text` is everything after the slashes.
    LineComment,
    /// `/* ... */` comment (nesting folded in); `text` is the body.
    BlockComment,
}

/// One lexed token with its 1-based source line.
#[derive(Clone, Debug)]
pub struct Token {
    pub kind: TokenKind,
    pub text: String,
    pub line: u32,
}

impl Token {
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokenKind::Ident && self.text == s
    }

    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokenKind::Punct(c)
    }

    pub fn is_comment(&self) -> bool {
        matches!(self.kind, TokenKind::LineComment | TokenKind::BlockComment)
    }
}

/// Tokenize Rust source.  Never fails: unterminated constructs are
/// swallowed to EOF (the compiler owns syntax errors; the linter only
/// needs a best-effort stream for files that already build).
pub fn lex(src: &str) -> Vec<Token> {
    Lexer { b: src.as_bytes(), i: 0, line: 1, out: Vec::new() }.run()
}

struct Lexer<'a> {
    b: &'a [u8],
    i: usize,
    line: u32,
    out: Vec<Token>,
}

impl<'a> Lexer<'a> {
    fn run(mut self) -> Vec<Token> {
        while self.i < self.b.len() {
            let c = self.b[self.i];
            match c {
                b'\n' => {
                    self.line += 1;
                    self.i += 1;
                }
                c if c.is_ascii_whitespace() => self.i += 1,
                b'/' if self.peek(1) == Some(b'/') => self.line_comment(),
                b'/' if self.peek(1) == Some(b'*') => self.block_comment(),
                b'"' => self.string(0),
                b'\'' => self.char_or_lifetime(),
                b'r' | b'b' => {
                    if !self.raw_or_byte_prefix() {
                        self.ident();
                    }
                }
                c if c.is_ascii_alphabetic() || c == b'_' => self.ident(),
                c if c.is_ascii_digit() => self.number(),
                _ => {
                    self.push(TokenKind::Punct(c as char), String::new());
                    self.i += 1;
                }
            }
        }
        self.out
    }

    fn peek(&self, ahead: usize) -> Option<u8> {
        self.b.get(self.i + ahead).copied()
    }

    fn push(&mut self, kind: TokenKind, text: String) {
        self.out.push(Token { kind, text, line: self.line });
    }

    fn line_comment(&mut self) {
        let start = self.i + 2;
        let mut j = start;
        while j < self.b.len() && self.b[j] != b'\n' {
            j += 1;
        }
        // Strip any doc-comment extra slash/bang; the rules only look at
        // the prose.
        let mut s = start;
        while s < j && matches!(self.b[s], b'/' | b'!') {
            s += 1;
        }
        let text = String::from_utf8_lossy(&self.b[s..j]).into_owned();
        self.push(TokenKind::LineComment, text);
        self.i = j;
    }

    fn block_comment(&mut self) {
        let line0 = self.line;
        let start = self.i + 2;
        let mut depth = 1usize;
        let mut j = start;
        while j < self.b.len() && depth > 0 {
            match (self.b[j], self.b.get(j + 1).copied()) {
                (b'/', Some(b'*')) => {
                    depth += 1;
                    j += 2;
                }
                (b'*', Some(b'/')) => {
                    depth -= 1;
                    j += 2;
                }
                (b'\n', _) => {
                    self.line += 1;
                    j += 1;
                }
                _ => j += 1,
            }
        }
        let end = j.saturating_sub(2).max(start);
        let text = String::from_utf8_lossy(&self.b[start..end]).into_owned();
        self.out.push(Token { kind: TokenKind::BlockComment, text, line: line0 });
        self.i = j;
    }

    /// `r"…"` / `r#"…"#` / `b"…"` / `br#"…"#` / `r#ident`; false if the
    /// leading `r`/`b` begins a plain identifier instead.
    fn raw_or_byte_prefix(&mut self) -> bool {
        let mut j = self.i + 1;
        if self.b[self.i] == b'b' && self.b.get(j) == Some(&b'r') {
            j += 1;
        }
        if self.b[self.i] == b'b' && self.b.get(j) == Some(&b'\'') {
            // Byte-char literal b'x'.
            self.i = j;
            self.char_literal();
            return true;
        }
        let mut hashes = 0usize;
        while self.b.get(j + hashes) == Some(&b'#') {
            hashes += 1;
        }
        match self.b.get(j + hashes) {
            Some(&b'"') => {
                self.i = j + hashes;
                self.string(hashes);
                true
            }
            // `r#ident` raw identifier: skip the prefix, lex the ident.
            _ if self.b[self.i] == b'r' && hashes == 1 => {
                self.i += 2;
                if self.i < self.b.len()
                    && (self.b[self.i].is_ascii_alphabetic() || self.b[self.i] == b'_')
                {
                    self.ident();
                } else {
                    self.push(TokenKind::Punct('#'), String::new());
                }
                true
            }
            _ => false,
        }
    }

    /// Lex a string starting at the opening quote; `hashes` > 0 means raw
    /// (no escapes, closed by `"` + that many `#`).
    fn string(&mut self, hashes: usize) {
        let line0 = self.line;
        let start = self.i + 1;
        let mut j = start;
        while j < self.b.len() {
            match self.b[j] {
                b'\\' if hashes == 0 => j += 2,
                b'\n' => {
                    self.line += 1;
                    j += 1;
                }
                b'"' => {
                    let close = (1..=hashes).all(|k| self.b.get(j + k) == Some(&b'#'));
                    if close {
                        let text = String::from_utf8_lossy(&self.b[start..j]).into_owned();
                        self.out.push(Token { kind: TokenKind::Str, text, line: line0 });
                        self.i = j + 1 + hashes;
                        return;
                    }
                    j += 1;
                }
                _ => j += 1,
            }
        }
        // Unterminated: swallow to EOF.
        let text = String::from_utf8_lossy(&self.b[start..]).into_owned();
        self.out.push(Token { kind: TokenKind::Str, text, line: line0 });
        self.i = self.b.len();
    }

    /// At a `'`: char literal (`'a'`, `'\n'`, `'\u{1F600}'`) or lifetime
    /// (`'static`).  A quote followed by ident chars and no closing quote
    /// within the escape-free forms is a lifetime.
    fn char_or_lifetime(&mut self) {
        // Escaped char is unambiguous.
        if self.peek(1) == Some(b'\\') {
            self.char_literal();
            return;
        }
        // 'x' with a closing quote right after one scalar = char literal.
        // Lifetimes are ASCII ident chars with *no* closing quote.
        let mut j = self.i + 1;
        while j < self.b.len() && (self.b[j].is_ascii_alphanumeric() || self.b[j] == b'_') {
            j += 1;
        }
        if self.b.get(j) == Some(&b'"') || j == self.i + 1 {
            // `'"` can't start a lifetime; treat as char-ish and resync.
            self.char_literal();
        } else if self.b.get(j) == Some(&b'\'') && j == self.i + 2 {
            self.char_literal();
        } else {
            let text = String::from_utf8_lossy(&self.b[self.i + 1..j]).into_owned();
            self.push(TokenKind::Lifetime, text);
            self.i = j;
        }
    }

    fn char_literal(&mut self) {
        // self.i at the opening quote.
        let mut j = self.i + 1;
        while j < self.b.len() {
            match self.b[j] {
                b'\\' => j += 2,
                b'\'' => {
                    j += 1;
                    break;
                }
                b'\n' => break,
                _ => j += 1,
            }
        }
        self.push(TokenKind::Char, String::new());
        self.i = j;
    }

    fn ident(&mut self) {
        let start = self.i;
        let mut j = self.i;
        while j < self.b.len() && (self.b[j].is_ascii_alphanumeric() || self.b[j] == b'_') {
            j += 1;
        }
        let text = String::from_utf8_lossy(&self.b[start..j]).into_owned();
        self.push(TokenKind::Ident, text);
        self.i = j;
    }

    fn number(&mut self) {
        let start = self.i;
        let mut j = self.i;
        while j < self.b.len() && (self.b[j].is_ascii_alphanumeric() || self.b[j] == b'_') {
            j += 1;
        }
        // Fractional part — but not `..` range syntax.
        if self.b.get(j) == Some(&b'.') && self.b.get(j + 1).is_some_and(|c| c.is_ascii_digit()) {
            j += 1;
            while j < self.b.len() && (self.b[j].is_ascii_alphanumeric() || self.b[j] == b'_') {
                j += 1;
            }
        }
        let text = String::from_utf8_lossy(&self.b[start..j]).into_owned();
        self.push(TokenKind::Num, text);
        self.i = j;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<TokenKind> {
        lex(src).into_iter().map(|t| t.kind).collect()
    }

    #[test]
    fn idents_and_puncts() {
        let toks = lex("use crate::kernels::micro;");
        let idents: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == TokenKind::Ident)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(idents, ["use", "crate", "kernels", "micro"]);
    }

    #[test]
    fn comments_are_not_code() {
        let toks = lex("// crate::foo\n/* crate::bar */ x");
        assert_eq!(toks[0].kind, TokenKind::LineComment);
        assert_eq!(toks[0].text.trim(), "crate::foo");
        assert_eq!(toks[1].kind, TokenKind::BlockComment);
        assert!(toks[2].is_ident("x"));
    }

    #[test]
    fn doc_comment_markers_are_stripped() {
        let toks = lex("/// lint: no-alloc\n//! module doc");
        assert_eq!(toks[0].text.trim(), "lint: no-alloc");
        assert_eq!(toks[1].text.trim(), "module doc");
    }

    #[test]
    fn nested_block_comment() {
        let toks = lex("/* a /* b */ c */ y");
        assert_eq!(toks[0].kind, TokenKind::BlockComment);
        assert!(toks[1].is_ident("y"));
    }

    #[test]
    fn strings_swallow_their_content() {
        let toks = lex(r#"let s = "crate::foo .unwrap()";"#);
        assert!(toks.iter().any(|t| t.kind == TokenKind::Str));
        assert!(!toks.iter().any(|t| t.is_ident("unwrap")));
    }

    #[test]
    fn raw_strings_at_hash_depth() {
        let toks = lex(r##"let s = r#"{"tune_schema":99}"#;"##);
        let strs: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == TokenKind::Str)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(strs, [r#"{"tune_schema":99}"#]);
    }

    #[test]
    fn char_vs_lifetime() {
        let toks = lex("let c = 'x'; fn f<'a>(v: &'a str) {}");
        assert!(toks.iter().any(|t| t.kind == TokenKind::Char));
        assert_eq!(toks.iter().filter(|t| t.kind == TokenKind::Lifetime).count(), 2);
        // Escaped char.
        assert!(lex(r"'\n'").iter().any(|t| t.kind == TokenKind::Char));
    }

    #[test]
    fn numbers_do_not_merge_with_ranges() {
        let k = kinds("0..10");
        assert_eq!(
            k,
            vec![TokenKind::Num, TokenKind::Punct('.'), TokenKind::Punct('.'), TokenKind::Num]
        );
        assert_eq!(kinds("1.5e-3").len(), 3); // 1.5e, -, 3 — still not idents
    }

    #[test]
    fn line_numbers_advance_through_everything() {
        let toks = lex("a\n\"x\ny\"\n/* z\nw */\nb");
        let a = toks.iter().find(|t| t.is_ident("a")).map(|t| t.line);
        let b = toks.iter().find(|t| t.is_ident("b")).map(|t| t.line);
        assert_eq!(a, Some(1));
        assert_eq!(b, Some(6));
    }

    #[test]
    fn unwrap_or_else_is_one_token() {
        let toks = lex("x.unwrap_or_else(|e| e.into_inner())");
        assert!(toks.iter().any(|t| t.is_ident("unwrap_or_else")));
        assert!(!toks.iter().any(|t| t.is_ident("unwrap")));
    }
}
