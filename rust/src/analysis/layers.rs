//! The module-DAG manifest behind rule L1 — `ci/lint/layers.toml`.
//!
//! The manifest is the single source of truth for the crate's layering
//! (ARCHITECTURE.md §Layering refers here instead of restating the rules
//! in prose).  Format: a tiny TOML subset parsed by hand (the build is
//! offline; no toml crate), two tables:
//!
//! ```toml
//! [modules]
//! util   = []               # imports nothing
//! kernels = ["kernels_micro", "obs", "sparsity", "util"]
//! main   = ["*"]            # the CLI may import any module
//!
//! [split]
//! "kernels::micro" = "kernels_micro"   # sub-module that is its own node
//! ```
//!
//! Every top-level module must be declared; an undeclared module (or an
//! edge to one) is itself an L1 diagnostic, so adding a module forces a
//! deliberate manifest decision.  `[split]` carves a sub-module out as an
//! independent node — used for `kernels::micro`, the std-only leaf that
//! low layers (`perm`) may call without gaining access to the rest of
//! `kernels`.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed layering manifest.
pub struct LayerManifest {
    /// node -> allowed dependency nodes (`*` = anything).
    nodes: BTreeMap<String, Vec<String>>,
    /// module-path prefix (e.g. `kernels::micro`) -> node name.
    splits: BTreeMap<String, String>,
}

impl LayerManifest {
    pub fn parse(text: &str) -> Result<LayerManifest> {
        let mut nodes = BTreeMap::new();
        let mut splits = BTreeMap::new();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("layers manifest line {}: expected `key = value`, got {raw:?}", ln + 1);
            };
            let key = unquote(k.trim());
            let val = v.trim();
            match section.as_str() {
                "modules" => {
                    nodes.insert(key, parse_string_array(val, ln + 1)?);
                }
                "split" => {
                    splits.insert(key, unquote(val));
                }
                "" => {} // top-level scalars (schema stamp etc.) — ignored
                other => bail!("layers manifest line {}: unknown section [{other}]", ln + 1),
            }
        }
        if nodes.is_empty() {
            bail!("layers manifest declares no [modules]");
        }
        for (node, deps) in &nodes {
            for d in deps {
                if d != "*" && !nodes.contains_key(d) {
                    bail!("layers manifest: {node} allows undeclared module {d:?}");
                }
            }
        }
        for split_node in splits.values() {
            if !nodes.contains_key(split_node) {
                bail!("layers manifest: [split] target {split_node:?} not declared in [modules]");
            }
        }
        Ok(LayerManifest { nodes, splits })
    }

    /// Map a module path (`kernels::micro`, `obs::watch`, `main`) to its
    /// manifest node: longest `[split]` prefix wins, else the top-level
    /// segment if declared.
    pub fn node_for(&self, module_path: &str) -> Option<&str> {
        let mut best: Option<&str> = None;
        let mut best_len = 0usize;
        for (prefix, node) in &self.splits {
            let hit = module_path == prefix
                || module_path.strip_prefix(prefix.as_str()).is_some_and(|r| r.starts_with("::"));
            if hit && prefix.len() > best_len {
                best = Some(node);
                best_len = prefix.len();
            }
        }
        if let Some(n) = best {
            return Some(n);
        }
        let top = module_path.split("::").next().unwrap_or(module_path);
        self.nodes.get_key_value(top).map(|(k, _)| k.as_str())
    }

    /// Whether `from` may depend on `to` (intra-node edges are always
    /// allowed).
    pub fn allows(&self, from: &str, to: &str) -> bool {
        if from == to {
            return true;
        }
        match self.nodes.get(from) {
            Some(deps) => deps.iter().any(|d| d == "*" || d == to),
            None => false,
        }
    }

    pub fn is_declared(&self, node: &str) -> bool {
        self.nodes.contains_key(node)
    }
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote(s: &str) -> String {
    s.trim().trim_matches('"').to_string()
}

fn parse_string_array(v: &str, line: usize) -> Result<Vec<String>> {
    let Some(body) = v.strip_prefix('[').and_then(|s| s.strip_suffix(']')) else {
        bail!("layers manifest line {line}: expected a [\"...\"] array, got {v:?}");
    };
    Ok(body
        .split(',')
        .map(|p| unquote(p.trim()))
        .filter(|p| !p.is_empty())
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    const M: &str = r#"
# demo manifest
schema = 1

[modules]
util = []
kernels_micro = []
kernels = ["kernels_micro", "util"]
perm = ["kernels_micro", "util"]   # leaf access only
main = ["*"]

[split]
"kernels::micro" = "kernels_micro"
"#;

    #[test]
    fn parses_and_answers_edges() {
        let m = LayerManifest::parse(M).unwrap();
        assert!(m.allows("kernels", "util"));
        assert!(m.allows("kernels", "kernels"));
        assert!(!m.allows("util", "kernels"));
        assert!(m.allows("main", "perm"));
        assert!(!m.allows("perm", "kernels"));
        assert!(m.allows("perm", "kernels_micro"));
    }

    #[test]
    fn split_prefix_maps_submodule_to_leaf_node() {
        let m = LayerManifest::parse(M).unwrap();
        assert_eq!(m.node_for("kernels::micro"), Some("kernels_micro"));
        assert_eq!(m.node_for("kernels::micro::dot"), Some("kernels_micro"));
        assert_eq!(m.node_for("kernels::tune"), Some("kernels"));
        assert_eq!(m.node_for("kernels"), Some("kernels"));
        assert_eq!(m.node_for("nope"), None);
    }

    #[test]
    fn rejects_undeclared_deps() {
        let bad = "[modules]\na = [\"ghost\"]\n";
        assert!(LayerManifest::parse(bad).is_err());
        assert!(LayerManifest::parse("").is_err());
    }
}
