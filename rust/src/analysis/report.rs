//! Lint diagnostics, the machine-readable report, and the committed
//! baseline — all serialised through [`crate::util::json`] so the report
//! is byte-deterministic (BTreeMap key order, no timestamps, sorted
//! diagnostics) and diffable as a CI golden, the same discipline as
//! `obs_schema` / `tune_schema` snapshots.

use std::collections::BTreeSet;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

/// Field-set version stamped into reports and baselines; readers reject
/// a mismatch rather than guessing.
pub const LINT_SCHEMA_VERSION: u32 = 1;

/// How bad a finding is.  `Error` findings gate (non-zero exit / CI
/// failure); `Warning` findings are reported but never gate.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    pub fn as_str(&self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }

    pub fn parse(s: &str) -> Result<Severity> {
        match s {
            "warning" => Ok(Severity::Warning),
            "error" => Ok(Severity::Error),
            other => bail!("unknown severity {other:?}"),
        }
    }
}

/// One finding, anchored to a `file:line`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Diagnostic {
    /// Rule id (`L1` .. `L6`).
    pub rule: String,
    pub severity: Severity,
    /// Repo-relative path, forward slashes.
    pub file: String,
    pub line: u32,
    pub msg: String,
}

impl Diagnostic {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("file", json::s(&self.file)),
            ("line", json::num(self.line as f64)),
            ("msg", json::s(&self.msg)),
            ("rule", json::s(&self.rule)),
            ("severity", json::s(self.severity.as_str())),
        ])
    }

    pub fn parse(v: &Json) -> Result<Diagnostic> {
        let field = |k: &str| {
            v.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .with_context(|| format!("diagnostic: missing {k}"))
        };
        Ok(Diagnostic {
            rule: field("rule")?,
            severity: Severity::parse(&field("severity")?)?,
            file: field("file")?,
            line: v.get("line").and_then(Json::as_usize).unwrap_or(0) as u32,
            msg: field("msg")?,
        })
    }

    /// `file:line: [rule/severity] msg` — the human-readable form.
    pub fn render(&self) -> String {
        format!(
            "{}:{}: [{}/{}] {}",
            self.file,
            self.line,
            self.rule,
            self.severity.as_str(),
            self.msg
        )
    }

    /// Baseline identity: rule + file + message, *not* the line number,
    /// so unrelated edits above an accepted finding don't un-suppress it.
    pub fn baseline_key(&self) -> (String, String, String) {
        (self.rule.clone(), self.file.clone(), self.msg.clone())
    }
}

/// Sort diagnostics into their canonical (deterministic) order.
pub fn sort_diagnostics(diags: &mut [Diagnostic]) {
    diags.sort_by(|a, b| {
        (&a.file, a.line, &a.rule, &a.msg).cmp(&(&b.file, b.line, &b.rule, &b.msg))
    });
}

/// The machine-readable lint report (`padst lint --format json`).
/// Deliberately free of per-tree volatile fields (no file counts, no
/// timings): on a clean tree the serialised report is byte-stable across
/// commits, which is what lets CI diff it against a golden.
#[derive(Debug, PartialEq)]
pub struct LintReport {
    /// Rule ids that ran, sorted.
    pub rules: Vec<String>,
    /// Findings not covered by the baseline, canonically sorted.
    pub diagnostics: Vec<Diagnostic>,
    /// Findings matched (and hidden) by the baseline.
    pub suppressed: usize,
}

impl LintReport {
    /// Gating findings present?  (`Error` severity only.)
    pub fn failed(&self) -> bool {
        self.diagnostics.iter().any(|d| d.severity == Severity::Error)
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            (
                "diagnostics",
                Json::Arr(self.diagnostics.iter().map(Diagnostic::to_json).collect()),
            ),
            ("lint_schema", json::num(LINT_SCHEMA_VERSION as f64)),
            ("rules", Json::Arr(self.rules.iter().map(|r| json::s(r)).collect())),
            ("suppressed", json::num(self.suppressed as f64)),
            ("total", json::num(self.diagnostics.len() as f64)),
        ])
    }

    pub fn parse(v: &Json) -> Result<LintReport> {
        let schema = v.get("lint_schema").and_then(Json::as_usize).unwrap_or(0);
        if schema != LINT_SCHEMA_VERSION as usize {
            bail!("unsupported lint_schema {schema} (this build reads {LINT_SCHEMA_VERSION})");
        }
        let rules = v
            .get("rules")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(Json::as_str).map(str::to_string).collect())
            .unwrap_or_default();
        let diagnostics = v
            .get("diagnostics")
            .and_then(Json::as_arr)
            .map(|a| a.iter().map(Diagnostic::parse).collect::<Result<Vec<_>>>())
            .transpose()?
            .unwrap_or_default();
        let suppressed = v.get("suppressed").and_then(Json::as_usize).unwrap_or(0);
        Ok(LintReport { rules, diagnostics, suppressed })
    }
}

/// The committed suppression file (`ci/lint/baseline.json`): accepted
/// pre-existing findings that should not gate.  Kept empty on this tree;
/// regenerate deliberately with `padst lint --fix-baseline`.
#[derive(Debug, Default)]
pub struct Baseline {
    entries: BTreeSet<(String, String, String)>,
}

impl Baseline {
    /// Load from disk; a missing file is an empty baseline.
    pub fn load(path: &Path) -> Result<Baseline> {
        if !path.exists() {
            return Ok(Baseline::default());
        }
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading baseline {}", path.display()))?;
        Baseline::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Baseline> {
        let v = Json::parse(text).context("parsing lint baseline")?;
        let schema = v.get("lint_schema").and_then(Json::as_usize).unwrap_or(0);
        if schema != LINT_SCHEMA_VERSION as usize {
            bail!("unsupported baseline lint_schema {schema}");
        }
        let mut entries = BTreeSet::new();
        for e in v.get("entries").and_then(Json::as_arr).unwrap_or(&[]) {
            let d = Diagnostic::parse(e).context("baseline entry")?;
            entries.insert(d.baseline_key());
        }
        Ok(Baseline { entries })
    }

    pub fn covers(&self, d: &Diagnostic) -> bool {
        self.entries.contains(&d.baseline_key())
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Serialise a diagnostic set as baseline text (what `--fix-baseline`
    /// writes).  Entries keep their line numbers for human readers, but
    /// matching ignores them.
    pub fn render(diags: &[Diagnostic]) -> String {
        let mut sorted = diags.to_vec();
        sort_diagnostics(&mut sorted);
        let v = json::obj(vec![
            ("entries", Json::Arr(sorted.iter().map(Diagnostic::to_json).collect())),
            ("lint_schema", json::num(LINT_SCHEMA_VERSION as f64)),
        ]);
        let mut s = v.to_string_pretty();
        s.push('\n');
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diag(rule: &str, file: &str, line: u32, msg: &str) -> Diagnostic {
        Diagnostic {
            rule: rule.into(),
            severity: Severity::Error,
            file: file.into(),
            line,
            msg: msg.into(),
        }
    }

    #[test]
    fn report_round_trips_through_util_json() {
        let report = LintReport {
            rules: vec!["L1".into(), "L3".into()],
            diagnostics: vec![diag("L3", "rust/src/a.rs", 7, "undocumented SeqCst")],
            suppressed: 2,
        };
        let text = report.to_json().to_string_pretty();
        let re = LintReport::parse(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(report, re);
    }

    #[test]
    fn parse_rejects_unknown_schema() {
        let v = json::obj(vec![("lint_schema", json::num(99.0))]);
        assert!(LintReport::parse(&v).is_err());
        assert!(Baseline::parse("{\"lint_schema\":99,\"entries\":[]}").is_err());
    }

    #[test]
    fn baseline_matches_on_rule_file_msg_not_line() {
        let accepted = diag("L2", "rust/src/a.rs", 10, "push() in no-alloc fn hot");
        let text = Baseline::render(std::slice::from_ref(&accepted));
        let base = Baseline::parse(&text).unwrap();
        let mut moved = accepted.clone();
        moved.line = 99; // the finding drifted down the file
        assert!(base.covers(&moved));
        let mut other = accepted;
        other.msg = "collect() in no-alloc fn hot".into();
        assert!(!base.covers(&other));
    }

    #[test]
    fn render_is_file_line_rule_form() {
        let d = diag("L1", "rust/src/util/cli.rs", 17, "util -> kernels not allowed");
        assert_eq!(d.render(), "rust/src/util/cli.rs:17: [L1/error] util -> kernels not allowed");
    }

    #[test]
    fn empty_baseline_loads_from_missing_file() {
        let b = Baseline::load(Path::new("/nonexistent/baseline.json")).unwrap();
        assert!(b.is_empty());
        assert_eq!(b.len(), 0);
    }
}
