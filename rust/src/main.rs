//! `padst` CLI — the leader entrypoint of the L3 coordinator.
//!
//! Subcommands:
//!   train          — one PA-DST training run (model/structure/density/perm flags;
//!                    `--structure` takes a pattern spec, e.g. `block:8`)
//!   sweep          — method x sparsity grid (Fig. 2 / Tbl. 11-12 analogue);
//!                    `--methods` accepts pattern specs as grid axes,
//!                    `--workers N` shards cells across per-worker runtimes,
//!                    `--shard i/n` runs one process-level shard of the grid
//!   patterns       — list the registered structure families with their spec
//!                    grammar, defaults, dynamic/static flag, and rank cap
//!   perms          — list the registered permutation modes with their spec
//!                    grammar, defaults, hardening behaviour, and artifact
//!   journal-merge  — combine per-shard sweep journals into one resumable
//!                    journal (cluster fan-out of Fig. 2 regeneration)
//!   nlr            — expressivity bound tables (Table 1, Apdx B/C.1);
//!                    `--structure SPEC` adds registry-derived cap rows
//!   list           — artifacts available in the manifest
//!   serve          — long-running batched inference node: loads a checkpoint
//!                    once (plans compiled, perms decoded), answers NDJSON
//!                    frames on stdin or a Unix socket until EOF
//!   watch          — live terminal status view over a sweep journal
//!                    (progress bar, per-worker heartbeat age, ETA)
//!   bench-compare  — diff two BENCH_*.json reports; exits non-zero on a
//!                    p50 regression beyond the threshold (the CI perf gate);
//!                    p90 movements print as warnings but never gate
//!   tune           — offline kernel autotune sweep: times the candidate
//!                    dispatch variants per (plan kind, geometry, threads)
//!                    key and persists the winners in a tuning table that
//!                    `run_plan`/`run_plan_mt` consult (`--dry-run` prints
//!                    the key grid without timing anything)
//!   lint           — dependency-free static analysis over the repo's own
//!                    Rust sources: layering vs ci/lint/layers.toml, warm-
//!                    path no-alloc, atomic-ordering justifications,
//!                    frame-loop panic freedom, schema-literal consistency,
//!                    forbid(unsafe_code) (README §Static analysis)
//!
//! Benches (Fig. 3, Tbl. 5) live under `cargo bench`; analysis examples
//! (Fig. 4-6) under `cargo run --example`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use padst::coordinator::{sweep, GrowMode, RunConfig, Trainer};
use padst::harness::{baseline, shard, telemetry::BenchReport};
use padst::kernels::micro::Backend;
use padst::kernels::resolve_threads;
use padst::kernels::tune::{self, TuneBudget, TuneKey, TuningTable};
use padst::nlr;
use padst::obs;
use padst::perm::model::{perm_registry, resolve_perm};
use padst::runtime::Runtime;
use padst::serve::{NodeOpts, SessionCtx, SocketOpts};
use padst::sparsity::pattern::{registry, resolve_pattern, KernelPlan, Structure};
use padst::util::Rng;

/// Minimal flag parser: `--key value` pairs after the subcommand.
struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                bail!("unexpected argument {a:?}");
            }
        }
        Ok(Args { flags })
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            Some(v) => v.parse().map_err(|e| anyhow!("--{key}: {e}")),
            None => Ok(default),
        }
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            Some(v) => v.parse().map_err(|e| anyhow!("--{key}: {e}")),
            None => Ok(default),
        }
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get("artifacts", "artifacts"))
}

/// Strict `--backend` parse: an explicit bad value is a CLI error (the
/// env knob `PADST_BACKEND` stays lenient via [`Backend::from_env`]).
/// A Simd request in a build without `nightly-simd` degrades to Tiled —
/// loudly, so nobody believes they trained under simd when they did not.
fn backend_flag(args: &Args) -> Result<Backend> {
    match args.flags.get("backend") {
        Some(s) => {
            let b = Backend::parse(s)
                .ok_or_else(|| anyhow!("bad --backend {s:?} (scalar|tiled|simd)"))?;
            let eff = b.effective();
            if eff != b {
                eprintln!(
                    "[padst] --backend {s}: this build lacks --features nightly-simd; using {}",
                    eff.name()
                );
            }
            // An explicit flag pins the backend: the tuning table may
            // still pick bit-preserving variants, never another backend
            // (resolution order: --backend > spec > PADST_BACKEND >
            // tuning table > default).
            tune::note_backend_pinned();
            Ok(eff)
        }
        None => Ok(Backend::from_env()),
    }
}

fn usage() -> ! {
    eprintln!(
        "padst — Permutation-Augmented Dynamic Structured Sparse Training

USAGE: padst <train|sweep|serve|tune|lint|patterns|perms|nlr|list> [--flag value ...]
       padst watch <journal.jsonl> [--once] [--interval SECS] [--stale SECS]
       padst bench-compare <old.json> <new.json> [--threshold PCT]
       padst journal-merge <a.jsonl> <b.jsonl> ... -o <out.jsonl>

train:
  --model vit_tiny|gpt_tiny|mixer_tiny|gpt_small   (default vit_tiny)
  --structure SPEC        pattern spec: a family name (diag|banded|block|nm|
                          butterfly|unstructured|dense, default diag) or a
                          parameterised form — diag:K, banded:B, block:BS,
                          nm:N:M (see `padst patterns` for the grammar)
  --sparsity 0.9          target sparsity (density = 1 - sparsity)
  --perm SPEC             perm spec: a mode name (none|random|learned|
                          kaleidoscope, default learned) or a parameterised
                          form — learned:sinkhorn=24:tau=0.5, random:seed=7
                          (see `padst perms` for the grammar)
  --steps 200  --lr 1e-3  --lambda 5e-3  --seed 0
  --dst-every 25  --harden-threshold 0.22  --harden-patience 3
                          (a patience=/threshold= param on --perm wins)
  --grow rigl|set|mest    unstructured grow rule
  --artifacts DIR         artifact directory (default artifacts)
  --threads N             worker threads (default: available parallelism)
  --backend scalar|tiled|simd   native-kernel microkernel backend
                          (default: PADST_BACKEND, else tiled)

sweep:
  --model ...  --steps N  --sparsities 0.6,0.9  --methods RigL,DynaDiag+PA
  --methods ...           zoo names and/or pattern specs — a spec like
                          block:4 or nm:1:4 becomes a structured-DST grid
                          row of its own (pattern hyper-params as axes)
  --perms learned,none    cross every method with these perm specs: each
                          (method, perm) pair becomes one grid row named
                          method+spec (the permutation axis of Fig. 2)
  --dry-run               plan the grid and print each cell's fingerprint
                          without opening a runtime (no artifacts needed);
                          with --journal, seeds the journal's header + plan
                          record so `padst watch` shows done/total upfront
  --csv PATH              dump results as CSV (atomic write)
  --threads N             global native-kernel budget, divided across workers
  --backend B             microkernel backend for every cell
  --workers N             sweep cells in parallel, one runtime per worker
                          (default 1 = sequential; 0 = auto)
  --journal PATH          JSONL checkpoint; an interrupted sweep resumes
                          from it without re-running completed cells
  --shard i/n             run only grid slots with slot % n == i (cluster
                          fan-out; give each shard its own --journal and
                          combine them with `padst journal-merge`)

serve:
  long-running batched inference node: loads a checkpoint once (every
  layer's kernel plan compiled, hard perms decoded at startup), then
  answers request frames on stdin until EOF — NDJSON control frames
  plus, since protocol v2, length-prefixed binary activation frames
  (~4 bytes/value, hello-negotiated) — protocol in README §Serving,
  suite in tests/serve_protocol.rs + tests/serve_concurrent.rs
  --checkpoint PATH       trained-state .tnz to serve
  --structure SPEC        pattern spec the run trained with (default diag)
  --perm SPEC             perm spec the run trained with (default learned)
  --synthetic SPEC        serve a one-site all-ones demo layer instead of
                          a checkpoint (CI smoke; --rows/--cols/--density)
  --rows 8 --cols 8 --density 0.5   synthetic site geometry
  --max-batch 32          coalescing cap in rows (default 4 panels x 8 lanes)
  --socket PATH           accept connections on a Unix socket instead of
                          stdin (concurrent; unix only)
  --max-conns 4           concurrent connection cap for --socket; the
                          --threads budget is split across connections
  --watch-checkpoint      hot-reload the checkpoint when its mtime
                          changes (plans recompile once, shared; every
                          live connection picks them up next burst)
  --tune-table PATH       install a tuning table at startup (else the
                          PADST_TUNE_TABLE env); each site's dispatch
                          variant is resolved once at plan-compile time
  --threads N --backend B as in train

tune:
  offline kernel autotune sweep (README §Autotuning): compiles one plan
  per (--specs x --geoms) cell, times the candidate dispatch variants
  (backend x batched row driver x mt thread cap) per thread level, and
  merges the winners into a schema-versioned JSON table consulted by
  run_plan/run_plan_mt (PADST_TUNE_TABLE / serve --tune-table;
  PADST_TUNE=off disables consultation)
  --specs diag,block,unstructured,dense    pattern specs to compile
  --geoms 256x256,1024x256,3072x768        RxC geometry grid
  --batch 64 --density 0.1                 plan compile inputs
  --threads N             tune at levels [1, N] (0 = auto; 1 = serial only)
  --budget 10             total timing budget in seconds, split evenly
                          across candidates (clamped 1-200 ms each)
  --out PATH              table to merge winners into (alias --tune-table;
                          default PADST_TUNE_TABLE, else tune_table.json)
  --dry-run               print the key grid (spec, geometry, thread
                          level, tuning key, candidate count, whether the
                          table already covers it) and exit

lint:
  static-analysis pass over rust/src (README §Static analysis): exits 1
  when any error-severity finding is not covered by the baseline
  --root DIR              repo root to lint (default .)
  --rules L1,L3           run a subset (default: all of L1-L6)
  --format text|json      text = file:line diagnostics; json = the
                          schema-versioned byte-deterministic report
                          that CI diffs against ci/golden/lint_smoke.out
  --manifest PATH         layering manifest (default ci/lint/layers.toml)
  --baseline PATH         suppression file (default ci/lint/baseline.json)
  --fix-baseline          rewrite the baseline to accept every current
                          finding (deliberate act; the committed file
                          stays empty on a clean tree)

journal-merge:
  padst journal-merge shard0.jsonl shard1.jsonl ... -o merged.jsonl
  inputs must come from the same sweep (identical journal headers); a
  final `padst sweep --journal merged.jsonl` resumes with every cell done

patterns:
  list the registered structure families: spec grammar, bare-name
  defaults, dynamic/static flag, and rank-cap formula (from the registry)

perms:
  list the registered permutation modes: spec grammar, bare-name
  defaults, hardening behaviour, and train artifact (from the registry)

nlr:
  --d0 1024 --widths 4096,1024x24 --density 0.05   Table-1 style bounds
  --structure SPEC        also print rows whose structural cap r comes
                          from the pattern's typed params (e.g. diag:51)
  --threads N             parallel bound evaluation (default: auto)

watch:
  padst watch sweep.jsonl       live view, re-rendered every --interval
  --once                  render one frame and exit (scripts, CI goldens)
  --interval 2            refresh period in seconds
  --stale 120             seconds of heartbeat silence before a worker is
                          flagged STALE (dead-shard warning)
  --now T                 pin the clock to unix time T (deterministic
                          output for tests/goldens)

bench-compare:
  padst bench-compare BENCH_old.json BENCH_new.json [--threshold 10]
  exits 1 if any record's p50 regressed more than the threshold percent;
  p90 movements past the threshold print as warnings and never gate
"
    );
    std::process::exit(2);
}

fn cmd_train(args: &Args) -> Result<()> {
    let threads = args.get_usize("threads", 0)?; // 0 = auto
    let backend = backend_flag(args)?;
    let mut rt = Runtime::open_with_threads(&artifacts_dir(args), threads)?;
    let sparsity = args.get_f64("sparsity", 0.9)?;
    let pattern = resolve_pattern(&args.get("structure", "diag"))?;
    let grow_mode = match args.get("grow", "rigl").as_str() {
        "rigl" => GrowMode::RigL,
        "set" => GrowMode::Set,
        "mest" => GrowMode::Mest,
        g => bail!("bad --grow {g:?}"),
    };
    let density = if pattern.family() == Structure::Dense { 1.0 } else { 1.0 - sparsity };
    let cfg = RunConfig {
        model: args.get("model", "vit_tiny"),
        pattern,
        density,
        perm: resolve_perm(&args.get("perm", "learned"))?,
        steps: args.get_usize("steps", 200)?,
        lr: args.get_f64("lr", 1e-3)? as f32,
        lambda: args.get_f64("lambda", 5e-3)? as f32,
        dst_every: args.get_usize("dst-every", 25)?,
        eval_every: args.get_usize("eval-every", 50)?,
        harden_threshold: args.get_f64("harden-threshold", 0.22)?,
        harden_patience: args.get_usize("harden-patience", 3)?,
        grow_mode,
        seed: args.get_usize("seed", 0)? as u64,
        verbose: true,
        threads,
        backend,
        ..Default::default()
    };
    eprintln!("[padst] {cfg:?}");
    let mut tr = Trainer::new(&mut rt, cfg);
    let res = tr.run()?;
    println!(
        "final: eval_loss={:.4} eval_acc={:.3} ppl={:.2} train={:.1}s hardened={}/{}",
        res.final_eval_loss,
        res.final_eval_acc,
        res.final_ppl,
        res.train_seconds,
        res.harden_step.iter().filter(|h| h.is_some()).count(),
        res.harden_step.len()
    );
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    // Sweeps are macro-scale: kernel dispatch metrics cost nothing
    // relative to a training cell, so observability is always on here.
    obs::set_enabled(true);
    let threads = args.get_usize("threads", 0)?; // 0 = auto
    let workers = args.get_usize("workers", 1)?; // 1 = sequential, 0 = auto
    let backend = backend_flag(args)?;
    let journal = args.flags.get("journal").map(PathBuf::from);
    let shard_spec = match args.flags.get("shard") {
        Some(s) => Some(shard::parse_shard(s)?),
        None => None,
    };
    let dir = artifacts_dir(args);
    let model = args.get("model", "vit_tiny");
    let steps = args.get_usize("steps", 150)?;
    let seed = args.get_usize("seed", 0)? as u64;
    let sparsities: Vec<f64> = args
        .get("sparsities", "0.6,0.9")
        .split(',')
        .map(|s| s.parse().unwrap())
        .collect();
    let method_names = args.get("methods", "RigL,DynaDiag,DynaDiag+PA,SRigL,SRigL+PA");
    let mut methods: Vec<sweep::Method> = method_names
        .split(',')
        .map(sweep::resolve_method)
        .collect::<Result<_>>()?;
    // The permutation grid axis: cross every method with each perm spec.
    if let Some(perm_specs) = args.flags.get("perms") {
        let perms: Vec<String> =
            perm_specs.split(',').filter(|s| !s.is_empty()).map(str::to_string).collect();
        methods = sweep::cross_perms(&methods, &perms)?;
    }
    if args.flags.contains_key("dry-run") {
        // Plan-only: resolve every method/spec, expand the grid, and show
        // the cell fingerprints the journal would carry.  No runtime (and
        // no artifacts) needed — this is the CI smoke path for
        // parameterised specs, including the perm axis.
        let cells = sweep::plan_grid(&methods, &sparsities);
        println!("# sweep dry run: model={model} steps={steps} seed={seed} ({} cells)", cells.len());
        println!(
            "{:<22} {:<18} {:<14} {:>9}  fingerprint",
            "method", "pattern", "perm", "sparsity"
        );
        for (m, sp) in &cells {
            println!(
                "{:<22} {:<18} {:<14} {:>8.0}%  {}",
                m.name,
                m.pattern,
                m.perm,
                sp * 100.0,
                sweep::method_fingerprint(m)
            );
        }
        // Seed the journal's header + plan record so `padst watch` has a
        // progress denominator before the real sweep starts.
        if let Some(path) = &journal {
            let keys: Vec<shard::CellKey> = cells
                .iter()
                .map(|(m, sp)| shard::CellKey { method: m.name.clone(), sparsity: *sp })
                .collect();
            sweep::seed_dry_run_journal(path, &model, steps, seed, &keys)?;
            eprintln!(
                "[padst] seeded journal {} ({} cells planned)",
                path.display(),
                keys.len()
            );
        }
        return Ok(());
    }
    let opts = sweep::SweepShardOpts {
        workers,
        threads,
        backend,
        shard: shard_spec,
        journal,
        verbose: true,
    };
    let (cells, kind) =
        sweep::run_sweep_auto(&dir, &model, &methods, &sparsities, steps, seed, &opts)?;
    sweep::print_table(&model, &kind, &cells, &sparsities);
    if let Some((i, n)) = shard_spec {
        eprintln!(
            "[padst] shard {i}/{n}: table covers this shard's (+ journaled) cells only; \
             merge shard journals with `padst journal-merge` for the full grid"
        );
    }
    if let Some(csv) = args.flags.get("csv") {
        sweep::write_csv(Path::new(csv), &cells)?;
        eprintln!("[padst] wrote {csv}");
    }
    Ok(())
}

/// Combine per-shard sweep journals into one resumable journal.
fn cmd_journal_merge(argv: &[String]) -> Result<()> {
    let mut inputs: Vec<PathBuf> = Vec::new();
    let mut out: Option<PathBuf> = None;
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "-o" | "--out" => {
                out = Some(PathBuf::from(
                    argv.get(i + 1).ok_or_else(|| anyhow!("{} needs a path", argv[i]))?,
                ));
                i += 2;
            }
            a if a.starts_with('-') => {
                bail!("unexpected flag {a:?} (journal-merge takes input paths and -o OUT)")
            }
            a => {
                inputs.push(PathBuf::from(a));
                i += 1;
            }
        }
    }
    let out = out.ok_or_else(|| anyhow!("journal-merge needs -o <out.jsonl>"))?;
    let n = shard::merge_journals(&inputs, &out)?;
    eprintln!("[padst] merged {} journals -> {} ({n} cells)", inputs.len(), out.display());
    Ok(())
}

/// Diff two bench reports; exit 1 on a gating p50 regression.
fn cmd_bench_compare(old: &str, new: &str, args: &Args) -> Result<()> {
    let threshold = args.get_f64("threshold", 10.0)?;
    let old_report = BenchReport::load(Path::new(old))?;
    let new_report = BenchReport::load(Path::new(new))?;
    let cmp = baseline::compare(&old_report, &new_report, threshold);
    baseline::print_comparison(&cmp);
    if cmp.regressed() {
        std::process::exit(1);
    }
    Ok(())
}

/// List the registered structure families — the table is rendered from
/// the `PatternRegistry` itself, so it can never drift from the impls.
fn cmd_patterns(_args: &Args) -> Result<()> {
    println!(
        "{:<14} {:<14} {:<34} {:<8} {}",
        "family", "spec grammar", "bare-name defaults", "dst", "rank cap r_struct"
    );
    for f in registry().families() {
        println!(
            "{:<14} {:<14} {:<34} {:<8} {}",
            f.name,
            f.grammar,
            f.defaults,
            if f.dynamic { "dynamic" } else { "static" },
            f.rank_cap
        );
    }
    println!("\nexamples: --structure block:8 | nm:2:8 | diag:4 | banded:16");
    println!("bare names keep the historical density-derived defaults.");
    Ok(())
}

/// List the registered permutation modes — rendered from the
/// `PermRegistry` itself, so the table can never drift from the impls.
fn cmd_perms(_args: &Args) -> Result<()> {
    println!(
        "{:<14} {:<56} {:<36} {:<44} {}",
        "mode", "spec grammar", "bare-name defaults", "hardening", "train artifact"
    );
    for m in perm_registry().modes() {
        println!(
            "{:<14} {:<56} {:<36} {:<44} {}",
            m.name, m.grammar, m.defaults, m.hardening, m.artifact
        );
    }
    println!("\nexamples: --perm learned:sinkhorn=24:tau=0.5 | random:seed=7 | none");
    println!("bare names keep the historical defaults (seed-run bit-identical).");
    println!("hardening defaults come from --harden-threshold / --harden-patience;");
    println!("a threshold=/patience= param on the spec wins.");
    Ok(())
}

fn cmd_nlr(args: &Args) -> Result<()> {
    let threads = args.get_usize("threads", 0)?; // 0 = auto
    let d0 = args.get_usize("d0", 1024)?;
    let density = args.get_f64("density", 0.05)?;
    // widths syntax: "4096,1024x24" = (4096, 1024) repeated 24 times.
    let spec = args.get("widths", "4096,1024x24");
    let (pat, reps) = match spec.split_once('x') {
        Some((p, r)) => (p, r.parse::<usize>()?),
        None => (spec.as_str(), 1),
    };
    let base: Vec<usize> = pat.split(',').map(|s| s.parse().unwrap()).collect();
    let widths: Vec<usize> = (0..reps).flat_map(|_| base.iter().copied()).collect();
    println!("NLR lower bounds (log10), d0={d0}, density={density}, L={}:", widths.len());
    println!("{:<36} {:>14} {:>12}", "setting", "log10 NLR", "overhead");
    let mut rows = nlr::table1_rows_mt(d0, &widths, density, threads);
    if let Some(spec) = args.flags.get("structure") {
        // Registry-derived rows: the structural cap r comes from the
        // pattern's typed params instead of the uniform density guess.
        let pattern = resolve_pattern(spec)?;
        rows.extend(nlr::pattern_rows(pattern.as_ref(), d0, &widths, density));
    }
    for row in rows {
        println!(
            "{:<36} {:>14.1} {:>12}",
            row.setting,
            row.log10_nlr,
            match row.depth_overhead {
                Some(0) => "0".to_string(),
                Some(l) => format!("{l} layers"),
                None => "stalls".to_string(),
            }
        );
    }
    Ok(())
}

fn cmd_list(args: &Args) -> Result<()> {
    let rt = Runtime::open(&artifacts_dir(args))?;
    println!("batch={}", rt.manifest.batch);
    for (name, e) in &rt.manifest.programs {
        println!(
            "{:<28} {:<10} model={:<10} structure={:<12} perm={:<12} in/out={}/{}",
            name,
            e.program,
            e.model,
            e.structure,
            e.perm,
            e.spec.inputs.len(),
            e.spec.outputs.len()
        );
    }
    Ok(())
}

/// Live terminal status view over a sweep journal.
fn cmd_watch(path: &str, args: &Args) -> Result<()> {
    let once = args.flags.contains_key("once");
    let interval = args.get_f64("interval", 2.0)?;
    let stale = args.get_f64("stale", 120.0)?;
    let now = match args.flags.get("now") {
        Some(v) => Some(v.parse().map_err(|e| anyhow!("--now: {e}"))?),
        None => None,
    };
    obs::watch::watch(Path::new(path), once, interval, stale, now)
}

/// Long-running batched inference node over stdin/a Unix socket.
fn cmd_serve(args: &Args) -> Result<()> {
    // Serving is frame-scale (µs+): always-on metrics back the `stats`
    // wire frame and the shutdown latency summary.
    obs::set_enabled(true);
    let threads = args.get_usize("threads", 0)?; // 0 = auto
    let backend = backend_flag(args)?;
    // Install the tuning table (if any) before plans compile: each site's
    // dispatch variant is resolved once inside SessionCtx::rebuild, so
    // the table must be in place first.
    if let Some(path) = args.flags.get("tune-table") {
        let table = TuningTable::load_lenient(Path::new(path));
        eprintln!("[padst serve] tuning table {path}: {} entries", table.len());
        tune::tuner().install(table);
    }
    let mut ctx = if let Some(spec) = args.flags.get("synthetic") {
        let rows = args.get_usize("rows", 8)?;
        let cols = args.get_usize("cols", 8)?;
        let density = args.get_f64("density", 0.5)?;
        SessionCtx::synthetic(spec, rows, cols, density, threads, backend)?
    } else {
        let ckpt = args
            .flags
            .get("checkpoint")
            .ok_or_else(|| anyhow!("serve needs --checkpoint PATH (or --synthetic SPEC)"))?;
        let pattern = resolve_pattern(&args.get("structure", "diag"))?;
        let perm = resolve_perm(&args.get("perm", "learned"))?;
        SessionCtx::load_checkpoint(Path::new(ckpt), pattern, perm, threads, backend)?
    };
    eprintln!(
        "[padst serve] {} | protocol v{} | threads={} backend={}",
        ctx.label(),
        padst::serve::PROTOCOL_VERSION,
        ctx.threads(),
        ctx.backend().name()
    );
    for s in ctx.sites() {
        eprintln!(
            "[padst serve]   {:<20} {}x{} nnz={} driver={} permuted={} tuned={}",
            s.name,
            s.rows,
            s.cols,
            s.nnz,
            s.plan.driver(),
            s.permuted,
            s.tuned
        );
    }
    let opts = NodeOpts { max_batch: args.get_usize("max-batch", NodeOpts::default().max_batch)? };
    let sopts = SocketOpts {
        max_conns: args.get_usize("max-conns", SocketOpts::default().max_conns)?,
        watch_checkpoint: args.flags.contains_key("watch-checkpoint"),
        ..SocketOpts::default()
    };
    if let Some(sock) = args.flags.get("socket") {
        #[cfg(unix)]
        {
            return padst::serve::serve_unix_socket(&ctx, Path::new(sock), &opts, &sopts);
        }
        #[cfg(not(unix))]
        {
            bail!("--socket {sock:?} needs a unix platform; pipe NDJSON over stdin instead");
        }
    }
    let stdin = std::io::stdin();
    let mut stdout = std::io::stdout().lock();
    let stats = if sopts.watch_checkpoint {
        padst::serve::serve_with_watch(
            &mut ctx,
            stdin.lock(),
            &mut stdout,
            &opts,
            sopts.watch_interval_ms,
        )?
    } else {
        padst::serve::serve(&mut ctx, stdin.lock(), &mut stdout, &opts)?
    };
    eprintln!(
        "[padst serve] eof: {} requests -> {} responses ({} errors), {} batches (widest {})",
        stats.requests, stats.responses, stats.errors, stats.batches, stats.widest_batch
    );
    eprintln!("[padst serve] {}", padst::serve::latency_summary(&ctx));
    Ok(())
}

/// Offline kernel autotune sweep: compile one plan per (spec, geometry),
/// time the candidate dispatch variants at each thread level, and merge
/// the winners into the persistent tuning table.  `--dry-run` prints the
/// key grid without timing anything (the CI `tune-smoke` golden).
fn cmd_tune(args: &Args) -> Result<()> {
    let specs_csv = args.get("specs", "diag,block,unstructured,dense");
    let geoms_csv = args.get("geoms", "256x256,1024x256,3072x768");
    let batch = args.get_usize("batch", 64)?;
    let density = args.get_f64("density", 0.1)?;
    let threads = args.get_usize("threads", 0)?; // 0 = auto
    let budget_secs = args.get_f64("budget", 10.0)?;
    let out = args
        .flags
        .get("out")
        .or_else(|| args.flags.get("tune-table"))
        .cloned()
        .or_else(|| std::env::var("PADST_TUNE_TABLE").ok().filter(|p| !p.is_empty()))
        .unwrap_or_else(|| "tune_table.json".to_string());
    let out = PathBuf::from(out);

    let specs: Vec<&str> = specs_csv.split(',').filter(|s| !s.is_empty()).collect();
    let mut geoms: Vec<(usize, usize)> = Vec::new();
    for g in geoms_csv.split(',').filter(|s| !s.is_empty()) {
        let (r, c) = g
            .split_once('x')
            .ok_or_else(|| anyhow!("bad --geoms entry {g:?} (expected RxC, e.g. 3072x768)"))?;
        let rows: usize = r.parse().map_err(|e| anyhow!("bad rows in {g:?}: {e}"))?;
        let cols: usize = c.parse().map_err(|e| anyhow!("bad cols in {g:?}: {e}"))?;
        geoms.push((rows, cols));
    }
    // Thread levels: the serial key always, plus the parallel key when the
    // budget allows more than one worker (run_plan keys at t=1,
    // run_plan_mt at the resolved count).
    let top = resolve_threads(threads);
    let mut levels = vec![1usize];
    if top > 1 {
        levels.push(top);
    }
    let levels_csv = levels.iter().map(ToString::to_string).collect::<Vec<_>>().join(",");

    // Compile the key grid once; the dry run prints it, the real run
    // tunes it.  Deterministic seeds keep the grid (and its golden)
    // byte-stable.
    struct Cell {
        spec: String,
        rows: usize,
        cols: usize,
        threads: usize,
        plan: KernelPlan,
        key: TuneKey,
        n_cands: usize,
    }
    let mut cells: Vec<Cell> = Vec::new();
    for spec in &specs {
        let pattern = resolve_pattern(spec)?;
        for &(rows, cols) in &geoms {
            let mut rng = Rng::new(1);
            let mask = pattern.init_mask(rows, cols, density, &mut rng)?;
            let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
            let plan = pattern.compress(&w, &mask, None);
            for &t in &levels {
                let key = TuneKey::of_plan(&plan, t);
                let n_cands = tune::candidates(key.kind, t).len();
                cells.push(Cell {
                    spec: spec.to_string(),
                    rows,
                    cols,
                    threads: t,
                    plan: plan.clone(),
                    key,
                    n_cands,
                });
            }
        }
    }

    let existing = TuningTable::load_lenient(&out);
    if args.flags.contains_key("dry-run") {
        println!(
            "# padst tune dry-run: specs={specs_csv} geoms={geoms_csv} batch={batch} \
             density={density} threads={levels_csv} simd={}",
            u8::from(Backend::simd_compiled())
        );
        let mut tuned_n = 0usize;
        for cell in &cells {
            let tuned = existing.get(&cell.key).is_some();
            tuned_n += usize::from(tuned);
            println!(
                "{} {}x{} t={} {} candidates={} tuned={}",
                cell.spec,
                cell.rows,
                cell.cols,
                cell.threads,
                cell.key.spec(),
                cell.n_cands,
                if tuned { "yes" } else { "no" }
            );
        }
        println!("# {} keys, {tuned_n} already tuned, table={}", cells.len(), out.display());
        return Ok(());
    }

    // Split the wall budget evenly across every candidate everywhere, so
    // --budget bounds the whole sweep regardless of grid size.
    let total_cands: usize = cells.iter().map(|c| c.n_cands).sum();
    let per_cand_ns =
        ((budget_secs * 1e9) as u64 / total_cands.max(1) as u64).clamp(1_000_000, 200_000_000);
    let budget = TuneBudget { budget_ns: per_cand_ns, ..TuneBudget::default() };
    println!(
        "# padst tune: {} keys, {total_cands} candidates (~{} ms each), table={}",
        cells.len(),
        per_cand_ns / 1_000_000,
        out.display()
    );
    let mut table = existing;
    let mut rng = Rng::new(2);
    for cell in &cells {
        let x: Vec<f32> = (0..batch * cell.cols).map(|_| rng.normal()).collect();
        let mut y = vec![0.0f32; batch * cell.rows];
        let (key, entry) = tune::tune_plan(&cell.plan, &x, batch, &mut y, cell.threads, &budget);
        println!(
            "{} {}x{} t={} {} -> backend={} batched={} cap={} p50={}ns reps={}",
            cell.spec,
            cell.rows,
            cell.cols,
            cell.threads,
            key.spec(),
            entry.choice.backend.name(),
            u8::from(entry.choice.batched),
            entry.choice.max_threads,
            entry.best_ns,
            entry.reps
        );
        table.insert(key, entry);
    }
    table.save(&out)?;
    eprintln!("[padst] wrote tuning table {} ({} entries)", out.display(), table.len());
    Ok(())
}

fn cmd_lint(args: &Args) -> Result<()> {
    use padst::analysis::report::Baseline;
    use padst::analysis::{run_lint, LintOptions};
    use padst::util::fs::write_atomic;

    let mut opts = LintOptions::new(PathBuf::from(args.get("root", ".")));
    if let Some(rules) = args.flags.get("rules") {
        opts.rules = rules
            .split(',')
            .map(|r| r.trim().to_string())
            .filter(|r| !r.is_empty())
            .collect();
    }
    if let Some(m) = args.flags.get("manifest") {
        opts.manifest_path = PathBuf::from(m);
    }
    if let Some(b) = args.flags.get("baseline") {
        opts.baseline_path = PathBuf::from(b);
    }
    let outcome = run_lint(&opts)?;

    if args.get("fix-baseline", "false") == "true" {
        // Snapshot every pre-baseline finding as the new accepted set.
        let path = if opts.baseline_path.is_absolute() {
            opts.baseline_path.clone()
        } else {
            opts.root.join(&opts.baseline_path)
        };
        write_atomic(&path, &Baseline::render(&outcome.all))?;
        eprintln!(
            "[padst lint] wrote baseline with {} entr{} to {}",
            outcome.all.len(),
            if outcome.all.len() == 1 { "y" } else { "ies" },
            path.display()
        );
        return Ok(());
    }

    match args.get("format", "text").as_str() {
        "json" => println!("{}", outcome.report.to_json().to_string_pretty()),
        "text" => {
            for d in &outcome.report.diagnostics {
                println!("{}", d.render());
            }
            eprintln!(
                "[padst lint] rules {} -> {} finding{}, {} suppressed by baseline",
                outcome.report.rules.join(","),
                outcome.report.diagnostics.len(),
                if outcome.report.diagnostics.len() == 1 { "" } else { "s" },
                outcome.report.suppressed
            );
        }
        f => bail!("bad --format {f:?} (text|json)"),
    }
    if outcome.report.failed() {
        std::process::exit(1);
    }
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    obs::init_from_env();
    if argv.is_empty() {
        usage();
    }
    if argv[0] == "watch" {
        // Positional form: watch <journal.jsonl> [--once] [--interval S].
        if argv.len() < 2 || argv[1].starts_with("--") {
            usage();
        }
        let args = Args::parse(&argv[2..])?;
        return cmd_watch(&argv[1], &args);
    }
    if argv[0] == "bench-compare" {
        // Positional form: bench-compare <old.json> <new.json> [--flags].
        if argv.len() < 3 || argv[1].starts_with("--") || argv[2].starts_with("--") {
            usage();
        }
        let args = Args::parse(&argv[3..])?;
        return cmd_bench_compare(&argv[1], &argv[2], &args);
    }
    if argv[0] == "journal-merge" {
        // Positional form: journal-merge <in.jsonl> ... -o <out.jsonl>.
        return cmd_journal_merge(&argv[1..]);
    }
    let args = Args::parse(&argv[1..])?;
    match argv[0].as_str() {
        "train" => cmd_train(&args),
        "sweep" => cmd_sweep(&args),
        "patterns" => cmd_patterns(&args),
        "perms" => cmd_perms(&args),
        "nlr" => cmd_nlr(&args),
        "list" => cmd_list(&args),
        "serve" => cmd_serve(&args),
        "tune" => cmd_tune(&args),
        "lint" => cmd_lint(&args),
        _ => usage(),
    }
}
