//! Combinatorial expressivity via linear regions (Sec. 3 + Apdx B/C).
//!
//! Implements the paper's master lower bound (Eqn. 1):
//! `NLR(f) >= prod_l sum_{j=0}^{k_l} C(n_l, j)`
//!
//! with the span-budget recursions of Table 1 determining the effective
//! dimension k_l per setting, in both exact (u128, small widths) and
//! log10 (f64, paper-scale widths) arithmetic.  Reproduces the worked
//! examples of Apdx B (ViT-L surrogate) and Apdx C.1 (163^3 vs 37^3 vs
//! 37*163^2) in unit tests and powers `examples/expressivity.rs` +
//! `benches/table1_nlr.rs`.

/// The settings of Table 1.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Setting {
    Dense,
    /// Unstructured DST (free masks) — same recursion as dense.
    Unstructured,
    /// N:M with free per-group supports — dense-like.
    NMFree,
    /// N:M tied template: k_l = min(n_l, alpha * u_{l-1}), span stalls.
    NMTied { alpha: f64 },
    /// Diagonal-K / Banded-b / Block-B without permutation: stalls at r.
    StructNoPerm { r: usize },
    /// Structured + per-layer permutation: span grows by r per layer.
    StructPerm { r: usize },
}

impl Setting {
    pub fn name(&self) -> String {
        match self {
            Setting::Dense => "Dense".into(),
            Setting::Unstructured => "Unstructured DST (free masks)".into(),
            Setting::NMFree => "N:M (free supports)".into(),
            Setting::NMTied { alpha } => format!("N:M (tied, alpha={alpha})"),
            Setting::StructNoPerm { r } => format!("Struct r={r} (no perm)"),
            Setting::StructPerm { r } => format!("Struct r={r} + permutation"),
        }
    }

    /// Depth overhead before dense-like factors resume (Table 1 last col).
    /// `None` = stalls forever; `Some(0)` = no overhead.
    pub fn depth_overhead(&self, d0: usize) -> Option<usize> {
        match self {
            Setting::Dense | Setting::Unstructured | Setting::NMFree => Some(0),
            Setting::NMTied { .. } | Setting::StructNoPerm { .. } => None,
            Setting::StructPerm { r } => Some(d0.div_ceil(*r)),
        }
    }
}

/// Effective dimensions k_l for a network with input dim `d0` and layer
/// widths `widths`, under `setting` (Eqn. 2–3 / Table 1 recursions).
///
/// For [`Setting::StructPerm`], `r` may be width-dependent in the paper's
/// worked example; use [`effective_dims_var`] for per-layer caps.
pub fn effective_dims(setting: Setting, d0: usize, widths: &[usize]) -> Vec<usize> {
    match setting {
        Setting::Dense | Setting::Unstructured | Setting::NMFree => {
            widths.iter().map(|&n| n.min(d0)).collect()
        }
        Setting::NMTied { alpha } => {
            // u stalls at u_0 = d0 but k is alpha-capped each layer.
            widths
                .iter()
                .map(|&n| n.min((alpha * d0 as f64).floor() as usize))
                .collect()
        }
        Setting::StructNoPerm { r } => {
            let s = r.min(d0);
            widths.iter().map(|&n| n.min(s)).collect()
        }
        Setting::StructPerm { r } => {
            effective_dims_var(d0, widths, &vec![r; widths.len()])
        }
    }
}

/// Structured + permutation with a per-layer structural cap r_l (e.g. the
/// alternating 51/205 caps of the ViT-L surrogate, Apdx B):
/// u_l = min(d0, u_{l-1} + r_l), k_l = min(n_l, u_l).
pub fn effective_dims_var(d0: usize, widths: &[usize], r: &[usize]) -> Vec<usize> {
    assert_eq!(widths.len(), r.len());
    let mut u = 0usize;
    widths
        .iter()
        .zip(r)
        .map(|(&n, &rl)| {
            u = d0.min(u + rl);
            n.min(u)
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Arithmetic: exact (u128) and log-space (f64)
// ---------------------------------------------------------------------------

/// Exact binomial coefficient; panics on overflow (use for small widths).
pub fn binom_u128(n: usize, k: usize) -> u128 {
    if k > n {
        return 0;
    }
    let k = k.min(n - k);
    let mut r: u128 = 1;
    for i in 0..k {
        r = r.checked_mul((n - i) as u128).expect("binom overflow");
        r /= (i + 1) as u128;
    }
    r
}

/// Per-layer factor sum_{j=0}^{k} C(n, j), exact.
pub fn layer_factor_u128(n: usize, k: usize) -> u128 {
    (0..=k.min(n)).map(|j| binom_u128(n, j)).sum()
}

/// Exact NLR lower bound (Eqn. 1); panics on overflow.
pub fn nlr_bound_u128(setting: Setting, d0: usize, widths: &[usize]) -> u128 {
    effective_dims(setting, d0, widths)
        .iter()
        .zip(widths)
        .map(|(&k, &n)| layer_factor_u128(n, k))
        .product()
}

/// ln Gamma via Lanczos (g=7, n=9), |err| < 1e-13 for x > 0.
pub fn ln_gamma(x: f64) -> f64 {
    const G: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1259.139_216_722_402_8,
        771.323_428_777_653_1,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        return std::f64::consts::PI.ln()
            - (std::f64::consts::PI * x).sin().ln()
            - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = G[0];
    let t = x + 7.5;
    for (i, &g) in G.iter().enumerate().skip(1) {
        a += g / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// log10 of C(n, k).
pub fn log10_binom(n: usize, k: usize) -> f64 {
    if k > n {
        return f64::NEG_INFINITY;
    }
    (ln_gamma(n as f64 + 1.0) - ln_gamma(k as f64 + 1.0) - ln_gamma((n - k) as f64 + 1.0))
        / std::f64::consts::LN_10
}

/// log10 of sum_{j=0}^{k} C(n, j) via log-sum-exp.
pub fn log10_layer_factor(n: usize, k: usize) -> f64 {
    let terms: Vec<f64> = (0..=k.min(n)).map(|j| log10_binom(n, j)).collect();
    let mx = terms.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    mx + terms
        .iter()
        .map(|t| 10f64.powf(t - mx))
        .sum::<f64>()
        .log10()
}

/// log10 of the NLR lower bound with a width-varying structural cap.
pub fn log10_nlr_bound_var(d0: usize, widths: &[usize], r: &[usize]) -> f64 {
    effective_dims_var(d0, widths, r)
        .iter()
        .zip(widths)
        .map(|(&k, &n)| log10_layer_factor(n, k))
        .sum()
}

/// log10 of the NLR lower bound (Eqn. 1) for a uniform setting.
pub fn log10_nlr_bound(setting: Setting, d0: usize, widths: &[usize]) -> f64 {
    effective_dims(setting, d0, widths)
        .iter()
        .zip(widths)
        .map(|(&k, &n)| log10_layer_factor(n, k))
        .sum()
}

/// One row of the Table-1 style report produced by the bench/example.
#[derive(Clone, Debug)]
pub struct BoundRow {
    pub setting: String,
    pub ks: Vec<usize>,
    pub log10_nlr: f64,
    pub depth_overhead: Option<usize>,
}

fn table1_settings(d0: usize, density: f64) -> Vec<Setting> {
    let r = ((density * d0 as f64).round() as usize).max(1);
    vec![
        Setting::Dense,
        Setting::Unstructured,
        Setting::NMFree,
        Setting::NMTied { alpha: density },
        Setting::StructNoPerm { r },
        Setting::StructPerm { r },
    ]
}

fn bound_row(s: Setting, d0: usize, widths: &[usize]) -> BoundRow {
    BoundRow {
        setting: s.name(),
        ks: effective_dims(s, d0, widths),
        log10_nlr: log10_nlr_bound(s, d0, widths),
        depth_overhead: s.depth_overhead(d0),
    }
}

pub fn table1_rows(d0: usize, widths: &[usize], density: f64) -> Vec<BoundRow> {
    table1_settings(d0, density)
        .into_iter()
        .map(|s| bound_row(s, d0, widths))
        .collect()
}

/// Table-1 rows for one *registered pattern*: the structural cap r comes
/// from [`rank_cap`](crate::sparsity::pattern::SparsePattern::rank_cap) —
/// i.e. the family's typed params (`diag:51`, `nm:1:20`) — instead of the
/// uniform `round(density * d0)` guess.  Two rows per pattern: without
/// and with the learned permutation.
pub fn pattern_rows(
    pattern: &dyn crate::sparsity::pattern::SparsePattern,
    d0: usize,
    widths: &[usize],
    density: f64,
) -> Vec<BoundRow> {
    let r = pattern.rank_cap(density, d0).clamp(1, d0);
    [Setting::StructNoPerm { r }, Setting::StructPerm { r }]
        .into_iter()
        .map(|s| {
            let mut row = bound_row(s, d0, widths);
            row.setting = format!("{} [{}]", row.setting, pattern.spec());
            row
        })
        .collect()
}

/// [`table1_rows`] with the per-setting bound evaluations fanned out
/// across worker threads (0 = auto).  Each row is an independent log-space
/// sum over the layer stack, so this is a pure fork-join; row order is
/// preserved.  At paper-scale widths (48 layers x 4096) the table drops
/// from ~100 ms to the slowest single row.
pub fn table1_rows_mt(d0: usize, widths: &[usize], density: f64, threads: usize) -> Vec<BoundRow> {
    crate::kernels::parallel::parallel_map(table1_settings(d0, density), threads, |s| {
        bound_row(s, d0, widths)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binom_small() {
        assert_eq!(binom_u128(8, 0), 1);
        assert_eq!(binom_u128(8, 2), 28);
        assert_eq!(binom_u128(8, 3), 56);
        assert_eq!(binom_u128(8, 4), 70);
    }

    /// Apdx C.1 worked example, exactly.
    #[test]
    fn apdx_c1_worked_example() {
        let d0 = 4;
        let widths = [8, 8, 8];
        // Dense: per-layer factor 163, NLR >= 163^3.
        assert_eq!(layer_factor_u128(8, 4), 163);
        assert_eq!(
            nlr_bound_u128(Setting::Dense, d0, &widths),
            163u128.pow(3)
        );
        // Unstructured matches dense.
        assert_eq!(
            nlr_bound_u128(Setting::Unstructured, d0, &widths),
            163u128.pow(3)
        );
        // Block-2 without permutation: factor 37 per layer.
        assert_eq!(layer_factor_u128(8, 2), 37);
        assert_eq!(
            nlr_bound_u128(Setting::StructNoPerm { r: 2 }, d0, &widths),
            37u128.pow(3)
        );
        // Block-2 with permutation: u = 2, 4, 4 -> 37 * 163 * 163.
        assert_eq!(
            nlr_bound_u128(Setting::StructPerm { r: 2 }, d0, &widths),
            37 * 163 * 163
        );
    }

    /// Apdx B: ViT-L surrogate catch-up point = 4 blocks (8 layers).
    #[test]
    fn apdx_b_vitl_surrogate() {
        let d0 = 1024;
        // 24 blocks of (1024 -> 4096 -> 1024): widths alternate 4096, 1024.
        let widths: Vec<usize> = (0..48)
            .map(|i| if i % 2 == 0 { 4096 } else { 1024 })
            .collect();
        let r: Vec<usize> = (0..48).map(|i| if i % 2 == 0 { 51 } else { 205 }).collect();
        let dims = effective_dims_var(d0, &widths, &r);
        // Per-block gain r_pair = 51 + 205 = 256 => u_{2t} = min(1024, 256 t);
        // saturation after t = 4 blocks = 8 layers.
        assert_eq!(dims[0], 51);
        assert_eq!(dims[1], 256);
        assert_eq!(dims[7], 1024, "u must saturate at layer 8 (4 blocks)");
        assert!(dims[6] < 1024);
        // Without mixing the cap stays at 51 forever.
        let no_perm = effective_dims(Setting::StructNoPerm { r: 51 }, d0, &widths);
        assert!(no_perm.iter().all(|&k| k == 51));
    }

    #[test]
    fn ln_gamma_matches_factorials() {
        for n in 1..20u64 {
            let f: f64 = (1..=n).map(|i| i as f64).product::<f64>().ln();
            assert!((ln_gamma(n as f64 + 1.0) - f).abs() < 1e-9, "n={n}");
        }
    }

    #[test]
    fn log_space_matches_exact() {
        for (n, k) in [(8, 4), (16, 7), (32, 10), (64, 3)] {
            let exact = layer_factor_u128(n, k) as f64;
            let got = 10f64.powf(log10_layer_factor(n, k));
            assert!(
                (got / exact - 1.0).abs() < 1e-9,
                "n={n} k={k}: {got} vs {exact}"
            );
        }
    }

    #[test]
    fn perm_bound_dominates_noperm_at_depth() {
        // The paper's central ordering: dense >= struct+perm >> struct.
        let d0 = 256;
        let widths = vec![512; 12];
        let r = 16;
        let dense = log10_nlr_bound(Setting::Dense, d0, &widths);
        let perm = log10_nlr_bound(Setting::StructPerm { r }, d0, &widths);
        let noperm = log10_nlr_bound(Setting::StructNoPerm { r }, d0, &widths);
        assert!(dense >= perm && perm > noperm + 50.0,
            "dense={dense:.1} perm={perm:.1} noperm={noperm:.1}");
    }

    #[test]
    fn table1_rows_mt_matches_serial() {
        let widths = vec![64usize; 6];
        let a = table1_rows(32, &widths, 0.1);
        for threads in [1usize, 2, 8] {
            let b = table1_rows_mt(32, &widths, 0.1, threads);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.setting, y.setting, "threads={threads}");
                assert_eq!(x.ks, y.ks);
                assert_eq!(x.log10_nlr.to_bits(), y.log10_nlr.to_bits());
                assert_eq!(x.depth_overhead, y.depth_overhead);
            }
        }
    }

    #[test]
    fn pattern_rows_use_typed_caps() {
        // diag:51 at any density must pin r = 51 — the Apdx B ViT-L cap.
        let p = crate::sparsity::pattern::resolve_pattern("diag:51").unwrap();
        let widths = vec![4096usize, 1024];
        let rows = pattern_rows(p.as_ref(), 1024, &widths, 0.5);
        assert_eq!(rows.len(), 2);
        assert!(rows[0].setting.contains("r=51") && rows[0].setting.contains("diag:51"));
        assert_eq!(rows[0].ks, vec![51, 51], "no-perm stalls at the cap");
        assert_eq!(rows[1].ks, vec![51, 102], "perm grows the span by r per layer");
    }

    #[test]
    fn overheads_match_table1() {
        assert_eq!(Setting::Dense.depth_overhead(1024), Some(0));
        assert_eq!(Setting::StructPerm { r: 51 }.depth_overhead(1024), Some(21));
        assert_eq!(Setting::StructPerm { r: 256 }.depth_overhead(1024), Some(4));
        assert_eq!(Setting::StructNoPerm { r: 51 }.depth_overhead(1024), None);
    }
}
