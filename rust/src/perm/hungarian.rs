//! Hungarian (Kuhn–Munkres) algorithm, O(n^3), maximum-weight perfect
//! matching on a dense square profit matrix.  This is the production hard
//! decode for soft permutations at hardening time (Apdx C.2): the learned
//! doubly-stochastic M is snapped to the permutation vertex maximising
//! sum_i M[i, idx[i]].
//!
//! Implementation: the classic shortest-augmenting-path formulation with
//! potentials over the *cost* matrix (we negate profits), which is the
//! standard numerically-robust variant.

/// Maximum-weight assignment.  `m` is row-major n x n; returns `idx` with
/// row i assigned to column idx[i].
pub fn hungarian_max(m: &[f64], n: usize) -> Vec<usize> {
    assert_eq!(m.len(), n * n);
    if n == 0 {
        return vec![];
    }
    // Convert to minimisation: cost = max - profit (keeps costs >= 0).
    let maxv = m.iter().cloned().fold(f64::MIN, f64::max);
    let cost = |i: usize, j: usize| maxv - m[i * n + j];

    // Potentials and matching, 1-indexed internally (0 is a sentinel).
    let mut u = vec![0.0f64; n + 1];
    let mut v = vec![0.0f64; n + 1];
    let mut p = vec![0usize; n + 1]; // p[j] = row matched to column j
    let mut way = vec![0usize; n + 1];

    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![f64::INFINITY; n + 1];
        let mut used = vec![false; n + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = f64::INFINITY;
            let mut j1 = 0usize;
            for j in 1..=n {
                if !used[j] {
                    let cur = cost(i0 - 1, j - 1) - u[i0] - v[j];
                    if cur < minv[j] {
                        minv[j] = cur;
                        way[j] = j0;
                    }
                    if minv[j] < delta {
                        delta = minv[j];
                        j1 = j;
                    }
                }
            }
            for j in 0..=n {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }

    let mut idx = vec![0usize; n];
    for j in 1..=n {
        if p[j] != 0 {
            idx[p[j] - 1] = j - 1;
        }
    }
    idx
}

/// Brute-force maximum assignment for testing (n <= 8).
#[cfg(test)]
pub fn brute_force_max(m: &[f64], n: usize) -> (f64, Vec<usize>) {
    fn perms(n: usize) -> Vec<Vec<usize>> {
        if n == 1 {
            return vec![vec![0]];
        }
        let mut out = Vec::new();
        for p in perms(n - 1) {
            for pos in 0..n {
                let mut q: Vec<usize> = p.iter().map(|&x| x).collect();
                q.insert(pos, n - 1);
                out.push(q);
            }
        }
        out
    }
    let mut best = (f64::MIN, vec![]);
    for p in perms(n) {
        let s: f64 = (0..n).map(|i| m[i * n + p[i]]).sum();
        if s > best.0 {
            best = (s, p);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn matches_brute_force() {
        let mut rng = Rng::new(10);
        for n in 2..=7 {
            for _ in 0..20 {
                let m: Vec<f64> = (0..n * n).map(|_| rng.f32() as f64).collect();
                let idx = hungarian_max(&m, n);
                let got: f64 = (0..n).map(|i| m[i * n + idx[i]]).sum();
                let (want, _) = brute_force_max(&m, n);
                assert!(
                    (got - want).abs() < 1e-9,
                    "n={n}: hungarian {got} != brute {want}"
                );
            }
        }
    }

    #[test]
    fn output_is_permutation() {
        let mut rng = Rng::new(11);
        let n = 64;
        let m: Vec<f64> = (0..n * n).map(|_| rng.f32() as f64).collect();
        let idx = hungarian_max(&m, n);
        let mut seen = vec![false; n];
        for &j in &idx {
            assert!(!seen[j], "column {j} assigned twice");
            seen[j] = true;
        }
    }

    #[test]
    fn identity_profit_gives_identity() {
        let n = 32;
        let mut m = vec![0.0f64; n * n];
        for i in 0..n {
            m[i * n + i] = 1.0;
        }
        assert_eq!(hungarian_max(&m, n), (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn handles_negative_profits() {
        let m = vec![-5.0, -1.0, -2.0, -4.0];
        let idx = hungarian_max(&m, 2);
        // Best: (0,1) + (1,0) = -1 + -2 = -3 vs (0,0)+(1,1) = -9.
        assert_eq!(idx, vec![1, 0]);
    }
}
