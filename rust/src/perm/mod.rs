//! Permutation substrate (Rust mirror of `python/compile/perm.py` plus the
//! production decode path): Sinkhorn projection, the AutoShuffle l1-l2
//! penalty (Eqn. 14), Hungarian assignment for hard decode, the
//! identity-distance metric of Sec. 6.3, and index-map algebra for
//! re-indexed inference.
//!
//! Two projection paths share one numeric core ([`sinkhorn`] /
//! [`SinkhornScratch`], bit-identical): the legacy free functions allocate
//! per call, the scratch reuses its buffers across calls — the hot path
//! for per-step multi-site projection (`table5_overhead` times both).
//! The typed mode objects (state machine, spec registry, hardening
//! controller) live in [`model`].

pub mod hungarian;
pub mod model;

pub use hungarian::hungarian_max;
pub use model::{resolve_perm, PermHandle, PermModel};

use crate::kernels::micro::{self, Backend};

/// Numerical floor of the Sinkhorn projection: entries below this are
/// raised to it before iterating (guarding the `exp` underflow to exact
/// zero).  The floor is *guarded* — applied only to entries already below
/// it — so re-projecting a (near-)doubly-stochastic matrix is idempotent;
/// the old unconditional `+= EPS` drifted every entry on every call
/// (regression-tested below).
const EPS: f64 = 1e-6;

/// One normalisation core shared by every projection path.  Per
/// iteration: one pass that row-normalises while accumulating the column
/// sums (fused — the column sums come for free during the row pass, in
/// the same i-ascending order the unfused loop summed them), then one
/// pass dividing by them.  `col` is caller-provided scratch of length n.
fn sinkhorn_core(m: &mut [f64], col: &mut [f64], n: usize, iters: usize) {
    debug_assert_eq!(m.len(), n * n);
    debug_assert_eq!(col.len(), n);
    for v in m.iter_mut() {
        if *v < EPS {
            *v = EPS;
        }
    }
    for _ in 0..iters {
        col.fill(0.0);
        for i in 0..n {
            let row = &mut m[i * n..(i + 1) * n];
            let s: f64 = row.iter().sum();
            for (j, v) in row.iter_mut().enumerate() {
                *v /= s;
                col[j] += *v;
            }
        }
        for i in 0..n {
            for (j, c) in col.iter().enumerate() {
                m[i * n + j] /= c;
            }
        }
    }
}

/// Sinkhorn projection of a positive matrix onto (near-)doubly-stochastic.
/// Allocating entry point; the hot path is [`SinkhornScratch::project`].
pub fn sinkhorn(m: &mut [f64], n: usize, iters: usize) {
    let mut col = vec![0.0f64; n];
    sinkhorn_core(m, &mut col, n, iters);
}

/// Reusable-buffer Sinkhorn projection: no per-call `Vec` allocations
/// once warm (buffers grow monotonically to the largest site seen), the
/// row/col sums of each iteration fused into one pass, and an optional
/// f32 path whose row reductions dispatch through the [`Backend`]
/// microkernels.  Results are bit-identical to the allocating
/// [`soft_perm`]/[`sinkhorn`] path (same core, pinned by test); the f32
/// path is tolerance-level (advisory — analysis/benching, not the decode
/// contract).
#[derive(Default)]
pub struct SinkhornScratch {
    m: Vec<f64>,
    col: Vec<f64>,
    m32: Vec<f32>,
    col32: Vec<f32>,
    ones32: Vec<f32>,
}

impl SinkhornScratch {
    pub fn new() -> SinkhornScratch {
        SinkhornScratch::default()
    }

    fn ensure(&mut self, n: usize) {
        if self.m.len() < n * n {
            self.m.resize(n * n, 0.0);
        }
        if self.col.len() < n {
            self.col.resize(n, 0.0);
        }
    }

    /// The soft permutation M = sinkhorn(exp((logits - rowmax)/tau)) into
    /// the reusable buffer; returns the n*n slice (valid until the next
    /// call).  `tau = 1` reproduces the historical un-tempered map
    /// bit-for-bit (x/1.0 is exact in IEEE arithmetic).
    pub fn soft_perm(&mut self, logits: &[f32], n: usize, iters: usize, tau: f64) -> &[f64] {
        assert_eq!(logits.len(), n * n, "logits must be n x n");
        self.ensure(n);
        for i in 0..n {
            let row = &logits[i * n..(i + 1) * n];
            let mx = row.iter().cloned().fold(f32::MIN, f32::max) as f64;
            for j in 0..n {
                self.m[i * n + j] = (((row[j] as f64) - mx) / tau).exp();
            }
        }
        sinkhorn_core(&mut self.m[..n * n], &mut self.col[..n], n, iters);
        &self.m[..n * n]
    }

    /// Project a caller-held matrix in place through the reusable column
    /// buffer (same numerics as [`sinkhorn`], no allocation once warm).
    // lint: no-alloc
    pub fn project(&mut self, m: &mut [f64], n: usize, iters: usize) {
        assert_eq!(m.len(), n * n);
        self.ensure(n);
        sinkhorn_core(m, &mut self.col[..n], n, iters);
    }

    /// f32 soft permutation with the per-row reductions dispatched through
    /// the [`Backend`] microkernels (`micro::dot` against a ones vector —
    /// the tiled/simd lane summation).  Half the memory traffic of the f64
    /// path; tolerance-level agreement (~1e-4), so it serves analysis and
    /// benching while the f64 path remains the decode contract.
    pub fn soft_perm_f32(
        &mut self,
        logits: &[f32],
        n: usize,
        iters: usize,
        tau: f64,
        backend: Backend,
    ) -> &[f32] {
        assert_eq!(logits.len(), n * n, "logits must be n x n");
        if self.m32.len() < n * n {
            self.m32.resize(n * n, 0.0);
        }
        if self.col32.len() < n {
            self.col32.resize(n, 0.0);
        }
        if self.ones32.len() < n {
            self.ones32.resize(n, 1.0);
        }
        let tau = tau as f32;
        for i in 0..n {
            let row = &logits[i * n..(i + 1) * n];
            let mx = row.iter().cloned().fold(f32::MIN, f32::max);
            for j in 0..n {
                self.m32[i * n + j] = ((row[j] - mx) / tau).exp();
            }
        }
        let eps = EPS as f32;
        for v in self.m32[..n * n].iter_mut() {
            if *v < eps {
                *v = eps;
            }
        }
        for _ in 0..iters {
            self.col32[..n].fill(0.0);
            for i in 0..n {
                let s = micro::dot(&self.m32[i * n..(i + 1) * n], &self.ones32[..n], backend);
                let row = &mut self.m32[i * n..(i + 1) * n];
                for (j, v) in row.iter_mut().enumerate() {
                    *v /= s;
                    self.col32[j] += *v;
                }
            }
            for i in 0..n {
                for j in 0..n {
                    self.m32[i * n + j] /= self.col32[j];
                }
            }
        }
        &self.m32[..n * n]
    }

    /// Allocation fingerprint (base pointer + capacity of the f64 matrix
    /// buffer): unchanged across same-size calls once warm — the no-alloc
    /// contract `table5_overhead` reports and the perm model tests pin.
    pub fn buffer_fingerprint(&self) -> (usize, usize) {
        (self.m.as_ptr() as usize, self.m.capacity())
    }
}

/// Softplus, matching `jax.nn.softplus`.
pub fn softplus(x: f64) -> f64 {
    if x > 30.0 {
        x
    } else {
        (1.0 + x.exp()).ln()
    }
}

/// The soft permutation M = sinkhorn(exp(logits - rowmax)) (Sec. 4.2) —
/// the Gumbel-Sinkhorn positive map (see python/compile/perm.py for why
/// exp rather than softplus: exp can concentrate a row at any width).
pub fn soft_perm(logits: &[f32], n: usize, iters: usize) -> Vec<f64> {
    let mut m = vec![0.0f64; n * n];
    for i in 0..n {
        let row = &logits[i * n..(i + 1) * n];
        let mx = row.iter().cloned().fold(f32::MIN, f32::max) as f64;
        for j in 0..n {
            m[i * n + j] = ((row[j] as f64) - mx).exp();
        }
    }
    sinkhorn(&mut m, n, iters);
    m
}

/// Eqn. 14: P(M) = sum_i (||M_i:||_1 - ||M_i:||_2) + sum_j (cols).
/// Zero iff M is a permutation (for doubly-stochastic M).
pub fn autoshuffle_penalty(m: &[f64], n: usize) -> f64 {
    let mut p = 0.0;
    for i in 0..n {
        let row = &m[i * n..(i + 1) * n];
        let l1: f64 = row.iter().map(|x| x.abs()).sum();
        let l2: f64 = row.iter().map(|x| x * x).sum::<f64>().sqrt();
        p += l1 - l2;
    }
    for j in 0..n {
        let mut l1 = 0.0;
        let mut l2 = 0.0;
        for i in 0..n {
            let v = m[i * n + j];
            l1 += v.abs();
            l2 += v * v;
        }
        p += l1 - l2.sqrt();
    }
    p
}

/// Sec. 6.3: delta(P) = 1 - ||P - I||_F / sqrt(2N) in [0, 1];
/// 1 = identity, 0 = full derangement.
pub fn identity_distance(perm_idx: &[usize]) -> f64 {
    let n = perm_idx.len();
    // ||P - I||_F^2 = 2 * (# rows where idx[i] != i).
    let moved = perm_idx.iter().enumerate().filter(|(i, &p)| *i != p).count();
    1.0 - ((2.0 * moved as f64).sqrt() / (2.0 * n as f64).sqrt())
}

/// Hard decode: maximum-weight assignment over the soft matrix, i.e. the
/// permutation vertex of the Birkhoff polytope nearest in the linear sense.
/// Returns idx with (P x)_i = x[idx[i]].
pub fn decode(m: &[f64], n: usize) -> Vec<usize> {
    hungarian_max(m, n)
}

/// Inverse index map: inv[idx[i]] = i.
pub fn invert(idx: &[usize]) -> Vec<usize> {
    let mut inv = vec![0usize; idx.len()];
    for (i, &p) in idx.iter().enumerate() {
        inv[p] = i;
    }
    inv
}

/// Compose two index maps: (P_a ∘ P_b) x = P_a (P_b x); out[i] = b[a[i]].
pub fn compose(a: &[usize], b: &[usize]) -> Vec<usize> {
    a.iter().map(|&i| b[i]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn sinkhorn_doubly_stochastic() {
        let mut rng = Rng::new(1);
        let n = 16;
        let mut m: Vec<f64> = (0..n * n).map(|_| rng.f32() as f64 + 0.1).collect();
        sinkhorn(&mut m, n, 20);
        for i in 0..n {
            let rs: f64 = m[i * n..(i + 1) * n].iter().sum();
            assert!((rs - 1.0).abs() < 1e-6, "row {i} sums to {rs}");
        }
        for j in 0..n {
            let cs: f64 = (0..n).map(|i| m[i * n + j]).sum();
            assert!((cs - 1.0).abs() < 1e-3, "col {j} sums to {cs}");
        }
    }

    #[test]
    fn penalty_zero_iff_permutation() {
        let n = 8;
        let mut rng = Rng::new(2);
        let p = rng.permutation(n);
        let mut m = vec![0.0f64; n * n];
        for (i, &j) in p.iter().enumerate() {
            m[i * n + j] = 1.0;
        }
        assert!(autoshuffle_penalty(&m, n) < 1e-12);
        // Uniform doubly-stochastic matrix has maximal penalty 2n(sqrt(n)-1)/sqrt(n)... just > 0.
        let u = vec![1.0 / n as f64; n * n];
        assert!(autoshuffle_penalty(&u, n) > 1.0);
    }

    #[test]
    fn identity_distance_endpoints() {
        let id: Vec<usize> = (0..16).collect();
        assert!((identity_distance(&id) - 1.0).abs() < 1e-12);
        let rot: Vec<usize> = (0..16).map(|i| (i + 1) % 16).collect(); // derangement
        assert!(identity_distance(&rot).abs() < 1e-12);
    }

    #[test]
    fn decode_recovers_planted_permutation() {
        let n = 12;
        let mut rng = Rng::new(3);
        let p = rng.permutation(n);
        // Soft matrix: 0.9 at the planted positions + noise elsewhere.
        let mut m = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                m[i * n + j] = 0.05 * rng.f32() as f64;
            }
            m[i * n + p[i]] = 0.9;
        }
        assert_eq!(decode(&m, n), p);
    }

    #[test]
    fn soft_perm_near_identity_logits() {
        // Strong identity-biased logits should decode to the identity.
        let n = 8;
        let mut logits = vec![0.0f32; n * n];
        for i in 0..n {
            logits[i * n + i] = 8.0;
        }
        let m = soft_perm(&logits, n, 10);
        assert_eq!(decode(&m, n), (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn sinkhorn_projection_is_idempotent() {
        // Regression: the old unconditional `+= EPS` drifted every entry
        // of an already doubly-stochastic matrix on re-projection.  The
        // guarded floor leaves a converged projection fixed.
        let mut rng = Rng::new(9);
        let n = 16;
        let mut m: Vec<f64> = (0..n * n).map(|_| rng.f32() as f64 + 0.1).collect();
        sinkhorn(&mut m, n, 30);
        let once = m.clone();
        sinkhorn(&mut m, n, 30);
        let drift = m
            .iter()
            .zip(&once)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(drift < 1e-9, "re-projection drifted by {drift}");
    }

    #[test]
    fn scratch_matches_allocating_path_bitwise() {
        let mut rng = Rng::new(10);
        let n = 24;
        let logits: Vec<f32> = (0..n * n).map(|_| rng.normal()).collect();
        let legacy = soft_perm(&logits, n, 12);
        let mut scratch = SinkhornScratch::new();
        let fast = scratch.soft_perm(&logits, n, 12, 1.0);
        assert_eq!(legacy.len(), fast.len());
        for (i, (a, b)) in legacy.iter().zip(fast.iter()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "entry {i}: {a} != {b}");
        }
        // project() on a caller buffer matches sinkhorn() too.
        let mut a: Vec<f64> = (0..n * n).map(|_| rng.f32() as f64 + 0.05).collect();
        let mut b = a.clone();
        sinkhorn(&mut a, n, 8);
        scratch.project(&mut b, n, 8);
        assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn scratch_reuses_buffers_across_calls() {
        let mut rng = Rng::new(11);
        let n = 32;
        let logits: Vec<f32> = (0..n * n).map(|_| rng.normal()).collect();
        let mut scratch = SinkhornScratch::new();
        scratch.soft_perm(&logits, n, 4, 1.0); // warm
        let fp = scratch.buffer_fingerprint();
        for _ in 0..5 {
            scratch.soft_perm(&logits, n, 4, 1.0);
            assert_eq!(scratch.buffer_fingerprint(), fp, "scratch reallocated");
        }
        // Smaller sites reuse the same buffer as well.
        let small: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
        scratch.soft_perm(&small, 8, 4, 1.0);
        assert_eq!(scratch.buffer_fingerprint(), fp);
    }

    #[test]
    fn f32_path_agrees_with_f64_within_tolerance() {
        let mut rng = Rng::new(12);
        let n = 16;
        let logits: Vec<f32> = (0..n * n).map(|_| rng.normal()).collect();
        let mut scratch = SinkhornScratch::new();
        let m64: Vec<f64> = scratch.soft_perm(&logits, n, 12, 1.0).to_vec();
        for &backend in crate::kernels::micro::Backend::all() {
            let m32 = scratch.soft_perm_f32(&logits, n, 12, 1.0, backend);
            let diff = m64
                .iter()
                .zip(m32.iter())
                .map(|(a, b)| (a - *b as f64).abs())
                .fold(0.0f64, f64::max);
            assert!(diff < 1e-4, "[{:?}] f32 path diverged by {diff}", backend);
            // And it must decode to the same permutation.
            let as64: Vec<f64> = m32.iter().map(|&x| x as f64).collect();
            assert_eq!(decode(&as64, n), decode(&m64, n), "[{:?}]", backend);
        }
    }

    #[test]
    fn tempered_soft_perm_sharpens() {
        // tau < 1 sharpens the map toward its decoded vertex: the planted
        // entries' mass grows.
        let n = 8;
        let mut rng = Rng::new(13);
        let mut logits = vec![0.0f32; n * n];
        for v in logits.iter_mut() {
            *v = 0.2 * rng.normal();
        }
        for i in 0..n {
            logits[i * n + i] += 1.0;
        }
        let mut scratch = SinkhornScratch::new();
        let warm: f64 = {
            let m = scratch.soft_perm(&logits, n, 12, 1.0);
            (0..n).map(|i| m[i * n + i]).sum()
        };
        let sharp: f64 = {
            let m = scratch.soft_perm(&logits, n, 12, 0.25);
            (0..n).map(|i| m[i * n + i]).sum()
        };
        assert!(sharp > warm, "tau=0.25 diagonal mass {sharp} <= tau=1 mass {warm}");
    }

    #[test]
    fn compose_and_invert() {
        let mut rng = Rng::new(4);
        let a = rng.permutation(10);
        let inv = invert(&a);
        let id = compose(&a, &inv);
        // (P_a then P_a^-1) — composing a with inv: out[i] = inv[a[i]]... ==
        // i only if a[inv[x]] = x; check identity.
        assert_eq!(compose(&inv, &a), (0..10).collect::<Vec<_>>());
        let _ = id;
    }
}
