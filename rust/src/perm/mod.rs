//! Permutation substrate (Rust mirror of `python/compile/perm.py` plus the
//! production decode path): Sinkhorn projection, the AutoShuffle l1-l2
//! penalty (Eqn. 14), Hungarian assignment for hard decode, the
//! identity-distance metric of Sec. 6.3, and index-map algebra for
//! re-indexed inference.

pub mod hungarian;

pub use hungarian::hungarian_max;

/// Sinkhorn projection of a positive matrix onto (near-)doubly-stochastic.
pub fn sinkhorn(m: &mut [f64], n: usize, iters: usize) {
    const EPS: f64 = 1e-6;
    for v in m.iter_mut() {
        *v += EPS;
    }
    for _ in 0..iters {
        for i in 0..n {
            let s: f64 = m[i * n..(i + 1) * n].iter().sum();
            for j in 0..n {
                m[i * n + j] /= s;
            }
        }
        for j in 0..n {
            let mut s = 0.0;
            for i in 0..n {
                s += m[i * n + j];
            }
            for i in 0..n {
                m[i * n + j] /= s;
            }
        }
    }
}

/// Softplus, matching `jax.nn.softplus`.
pub fn softplus(x: f64) -> f64 {
    if x > 30.0 {
        x
    } else {
        (1.0 + x.exp()).ln()
    }
}

/// The soft permutation M = sinkhorn(exp(logits - rowmax)) (Sec. 4.2) —
/// the Gumbel-Sinkhorn positive map (see python/compile/perm.py for why
/// exp rather than softplus: exp can concentrate a row at any width).
pub fn soft_perm(logits: &[f32], n: usize, iters: usize) -> Vec<f64> {
    let mut m = vec![0.0f64; n * n];
    for i in 0..n {
        let row = &logits[i * n..(i + 1) * n];
        let mx = row.iter().cloned().fold(f32::MIN, f32::max) as f64;
        for j in 0..n {
            m[i * n + j] = ((row[j] as f64) - mx).exp();
        }
    }
    sinkhorn(&mut m, n, iters);
    m
}

/// Eqn. 14: P(M) = sum_i (||M_i:||_1 - ||M_i:||_2) + sum_j (cols).
/// Zero iff M is a permutation (for doubly-stochastic M).
pub fn autoshuffle_penalty(m: &[f64], n: usize) -> f64 {
    let mut p = 0.0;
    for i in 0..n {
        let row = &m[i * n..(i + 1) * n];
        let l1: f64 = row.iter().map(|x| x.abs()).sum();
        let l2: f64 = row.iter().map(|x| x * x).sum::<f64>().sqrt();
        p += l1 - l2;
    }
    for j in 0..n {
        let mut l1 = 0.0;
        let mut l2 = 0.0;
        for i in 0..n {
            let v = m[i * n + j];
            l1 += v.abs();
            l2 += v * v;
        }
        p += l1 - l2.sqrt();
    }
    p
}

/// Sec. 6.3: delta(P) = 1 - ||P - I||_F / sqrt(2N) in [0, 1];
/// 1 = identity, 0 = full derangement.
pub fn identity_distance(perm_idx: &[usize]) -> f64 {
    let n = perm_idx.len();
    // ||P - I||_F^2 = 2 * (# rows where idx[i] != i).
    let moved = perm_idx.iter().enumerate().filter(|(i, &p)| *i != p).count();
    1.0 - ((2.0 * moved as f64).sqrt() / (2.0 * n as f64).sqrt())
}

/// Hard decode: maximum-weight assignment over the soft matrix, i.e. the
/// permutation vertex of the Birkhoff polytope nearest in the linear sense.
/// Returns idx with (P x)_i = x[idx[i]].
pub fn decode(m: &[f64], n: usize) -> Vec<usize> {
    hungarian_max(m, n)
}

/// Inverse index map: inv[idx[i]] = i.
pub fn invert(idx: &[usize]) -> Vec<usize> {
    let mut inv = vec![0usize; idx.len()];
    for (i, &p) in idx.iter().enumerate() {
        inv[p] = i;
    }
    inv
}

/// Compose two index maps: (P_a ∘ P_b) x = P_a (P_b x); out[i] = b[a[i]].
pub fn compose(a: &[usize], b: &[usize]) -> Vec<usize> {
    a.iter().map(|&i| b[i]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn sinkhorn_doubly_stochastic() {
        let mut rng = Rng::new(1);
        let n = 16;
        let mut m: Vec<f64> = (0..n * n).map(|_| rng.f32() as f64 + 0.1).collect();
        sinkhorn(&mut m, n, 20);
        for i in 0..n {
            let rs: f64 = m[i * n..(i + 1) * n].iter().sum();
            assert!((rs - 1.0).abs() < 1e-6, "row {i} sums to {rs}");
        }
        for j in 0..n {
            let cs: f64 = (0..n).map(|i| m[i * n + j]).sum();
            assert!((cs - 1.0).abs() < 1e-3, "col {j} sums to {cs}");
        }
    }

    #[test]
    fn penalty_zero_iff_permutation() {
        let n = 8;
        let mut rng = Rng::new(2);
        let p = rng.permutation(n);
        let mut m = vec![0.0f64; n * n];
        for (i, &j) in p.iter().enumerate() {
            m[i * n + j] = 1.0;
        }
        assert!(autoshuffle_penalty(&m, n) < 1e-12);
        // Uniform doubly-stochastic matrix has maximal penalty 2n(sqrt(n)-1)/sqrt(n)... just > 0.
        let u = vec![1.0 / n as f64; n * n];
        assert!(autoshuffle_penalty(&u, n) > 1.0);
    }

    #[test]
    fn identity_distance_endpoints() {
        let id: Vec<usize> = (0..16).collect();
        assert!((identity_distance(&id) - 1.0).abs() < 1e-12);
        let rot: Vec<usize> = (0..16).map(|i| (i + 1) % 16).collect(); // derangement
        assert!(identity_distance(&rot).abs() < 1e-12);
    }

    #[test]
    fn decode_recovers_planted_permutation() {
        let n = 12;
        let mut rng = Rng::new(3);
        let p = rng.permutation(n);
        // Soft matrix: 0.9 at the planted positions + noise elsewhere.
        let mut m = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                m[i * n + j] = 0.05 * rng.f32() as f64;
            }
            m[i * n + p[i]] = 0.9;
        }
        assert_eq!(decode(&m, n), p);
    }

    #[test]
    fn soft_perm_near_identity_logits() {
        // Strong identity-biased logits should decode to the identity.
        let n = 8;
        let mut logits = vec![0.0f32; n * n];
        for i in 0..n {
            logits[i * n + i] = 8.0;
        }
        let m = soft_perm(&logits, n, 10);
        assert_eq!(decode(&m, n), (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn compose_and_invert() {
        let mut rng = Rng::new(4);
        let a = rng.permutation(10);
        let inv = invert(&a);
        let id = compose(&a, &inv);
        // (P_a then P_a^-1) — composing a with inv: out[i] = inv[a[i]]... ==
        // i only if a[inv[x]] = x; check identity.
        assert_eq!(compose(&inv, &a), (0..10).collect::<Vec<_>>());
        let _ = id;
    }
}
