//! The permutation layer: one first-class object per permutation mode.
//!
//! The paper's central contribution is the *learned shuffle* (Sec. 4.2):
//! a per-layer permutation trained jointly with the structured weights,
//! softened via Gumbel-Sinkhorn and hardened to an index map once its
//! AutoShuffle penalty (Eqn. 14) crosses delta (Apdx C.2).  This module
//! makes that lifecycle typed, mirroring the pattern registry in
//! `sparsity::pattern`:
//!
//! * [`PermState`] — the per-site state machine
//!   (`Identity` → frozen, `Soft` → learning, `Hard` → re-indexing);
//! * [`PermSite`] — one site's typed state plus its export into the
//!   artifact input tensors (`perm_logits.*` / `perm_idx.*` /
//!   `hard_flags`, the names the AOT programs consume — old checkpoints
//!   carrying those keys load unchanged);
//! * [`PermModel`] — the mode trait (init, hardening params, Sinkhorn +
//!   Hungarian decode, memory accounting), one impl per mode:
//!   [`LearnedPerm`], [`KaleidoscopePerm`], [`RandomPerm`], [`NoPerm`];
//! * [`PermRegistry`] — parameterised spec strings (`"learned"`,
//!   `"learned:sinkhorn=24:tau=0.5"`, `"random:seed=7"`, `"none"`)
//!   resolved into trait objects.  Bare names keep today's defaults and
//!   reproduce seed-run state bit-identically (pinned by test).
//!
//! All mode dispatch lives here.  The coordinator, sweep grid, CLI,
//! benches, and examples hold a [`PermHandle`] and call trait methods;
//! none of them match on a mode string.

use std::collections::HashMap;
use std::fmt;
use std::sync::{Arc, OnceLock};

use anyhow::{anyhow, bail, Result};

use super::{decode, SinkhornScratch};
use crate::tensor::Tensor;
use crate::util::Rng;

/// Back-compat key name: manifests written by `python/compile/aot.py`
/// (and the historical Rust structs) call the permutation treatment
/// `perm_mode`.  The parser in `runtime::manifest` reads this key; no
/// other module spells the legacy name.
pub const MANIFEST_PERM_KEY: &str = "perm_mode";

/// Historical defaults a bare spec resolves to (and canonicalises back
/// to): the Sinkhorn iteration count of the host decode path, the
/// softmax temperature (1 = the historical un-tempered exp), the
/// hardening debounce, and the frozen-random seed base
/// (`rng.fork(1000 + site)` in the pre-registry init).
pub const DEFAULT_SINKHORN_ITERS: usize = 12;
pub const DEFAULT_TAU: f64 = 1.0;
pub const DEFAULT_PATIENCE: usize = 3;
pub const DEFAULT_RANDOM_SEED: u64 = 1000;

/// Mode tag — one variant per [`PermModel`] impl.  String forms match the
/// historical `perm_mode` values (manifest, old journals, CLI).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PermMode {
    NoPerm,
    Random,
    Learned,
    Kaleidoscope,
}

impl PermMode {
    pub fn parse(s: &str) -> Option<PermMode> {
        Some(match s {
            "none" => PermMode::NoPerm,
            "random" => PermMode::Random,
            "learned" => PermMode::Learned,
            "kaleidoscope" => PermMode::Kaleidoscope,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            PermMode::NoPerm => "none",
            PermMode::Random => "random",
            PermMode::Learned => "learned",
            PermMode::Kaleidoscope => "kaleidoscope",
        }
    }
}

/// The per-site permutation state machine (Sec. 4.2 / Apdx C.2):
///
/// ```text
///   Identity ──────────────────────────────┐ (frozen modes: none)
///       │ init (learned/kaleidoscope)      │
///       ▼                                  ▼
///     Soft ── penalty < delta for        Hard  (random inits here;
///              `patience` steps ─────────▶      re-indexing, never revisited)
/// ```
///
/// `Soft` carries the trained logits plus the projection parameters the
/// spec fixed; `Hard` carries the decoded index map the kernels fold into
/// their index streams.
#[derive(Clone, Debug)]
pub enum PermState {
    /// No permutation: the identity index map, never trained.
    Identity,
    /// Soft regime: logits updated by the train artifact every step,
    /// Sinkhorn-projected with these parameters at decode time.
    Soft { logits: Tensor, sinkhorn_iters: usize, temperature: f64 },
    /// Hardened: a frozen index map; the layer runs re-indexing
    /// (`(P x)_i = x[index_map[i]]`) folded into the kernel index stream.
    Hard { index_map: Vec<usize> },
}

impl PermState {
    pub fn is_hard(&self) -> bool {
        !matches!(self, PermState::Soft { .. })
    }

    /// The hard index map, when one exists (`Identity` is implicit).
    pub fn index_map(&self) -> Option<&[usize]> {
        match self {
            PermState::Hard { index_map } => Some(index_map),
            _ => None,
        }
    }
}

/// One site's typed permutation state plus the inert logits frozen modes
/// still export (the train artifacts take `perm_logits.*` as input for
/// every mode; the historical init drew them from the run RNG even when
/// nothing trains them, and seed parity requires the same draws).
#[derive(Clone, Debug)]
pub struct PermSite {
    pub name: String,
    /// Permutation dimension N (the site's input width).
    pub n: usize,
    pub state: PermState,
    frozen_logits: Option<Tensor>,
}

impl PermSite {
    pub fn new(name: &str, n: usize, state: PermState, frozen_logits: Option<Tensor>) -> PermSite {
        PermSite { name: name.to_string(), n, state, frozen_logits }
    }

    /// The `hard_flags` entry this site contributes: 1 = the artifact's
    /// re-indexing branch, 0 = the soft N x N matmul branch.
    pub fn hard_flag(&self) -> f32 {
        if self.state.is_hard() {
            1.0
        } else {
            0.0
        }
    }

    /// The logits tensor exported as `perm_logits.{name}` (soft sites own
    /// theirs; frozen sites export the inert init draw).
    pub fn logits(&self) -> Option<&Tensor> {
        match &self.state {
            PermState::Soft { logits, .. } => Some(logits),
            _ => self.frozen_logits.as_ref(),
        }
    }

    /// The index map exported as `perm_idx.{name}` (identity unless Hard).
    pub fn index_tensor(&self) -> Tensor {
        let idx: Vec<i32> = match self.state.index_map() {
            Some(map) => map.iter().map(|&i| i as i32).collect(),
            None => (0..self.n as i32).collect(),
        };
        Tensor::from_i32(&[self.n], idx)
    }

    /// Write this site's artifact inputs into a `TrainState`-style vals
    /// map (the names every AOT program consumes).
    pub fn export_into(&self, vals: &mut HashMap<String, Tensor>) {
        if let Some(l) = self.logits() {
            vals.insert(format!("perm_logits.{}", self.name), l.clone());
        }
        vals.insert(format!("perm_idx.{}", self.name), self.index_tensor());
    }

    /// The Soft → Hard transition (monotone; asserted, since re-softening
    /// a hardened site would corrupt the Apdx C.2 early-stop contract).
    pub fn harden(&mut self, index_map: Vec<usize>) {
        debug_assert_eq!(index_map.len(), self.n);
        self.state = PermState::Hard { index_map };
    }
}

/// Spec-level hardening overrides.  `None` fields fall back to the run
/// config (`--harden-threshold` / `--harden-patience`); a mode that
/// returns `None` from [`PermModel::hardening`] never hardens.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PermHardening {
    pub threshold: Option<f64>,
    pub patience: Option<usize>,
}

/// Everything a permutation mode knows, as one object.
///
/// Contract shared by all impls:
/// * `init_site` consumes the RNG exactly as the historical
///   `Trainer::init_state` did for its mode, so seed checkpoints are
///   bit-identical (pinned by `tests/perm_model.rs`).
/// * `spec` round-trips through [`resolve_perm`]; modes at defaults print
///   the bare name, so journals/fingerprints written pre-registry still
///   match.
/// * `decode_logits` returns `Some` only for modes with an N x N soft
///   matrix to decode (Learned); Kaleidoscope hardens to the identity map
///   (its K-matrix is not a pure permutation — the comparator only
///   measures overhead).
pub trait PermModel: fmt::Debug + Send + Sync {
    /// Mode tag (one per impl).
    fn mode(&self) -> PermMode;

    /// Canonical spec string; [`resolve_perm`] parses it back to an equal
    /// model.
    fn spec(&self) -> String;

    /// Does this mode train logits (penalties flow, hardening applies)?
    fn learns(&self) -> bool {
        matches!(self.mode(), PermMode::Learned | PermMode::Kaleidoscope)
    }

    /// Suffix selecting the AOT train artifact: `"{model}_train{suffix}"`.
    fn artifact_suffix(&self) -> &'static str;

    /// Build site `site_i`'s initial typed state for permutation dimension
    /// `n`, consuming `rng` exactly as the historical init did.
    fn init_site(&self, site_i: usize, name: &str, n: usize, rng: &mut Rng) -> PermSite;

    /// Projection parameters of the soft state — (Sinkhorn iterations,
    /// temperature) — used when `Soft` states rebind on checkpoint resume
    /// and by the decode path.  Modes whose soft state never host-decodes
    /// keep the defaults.
    fn projection(&self) -> (usize, f64) {
        (DEFAULT_SINKHORN_ITERS, DEFAULT_TAU)
    }

    /// Hardening parameters; `None` = this mode never hardens.
    fn hardening(&self) -> Option<PermHardening>;

    /// Sinkhorn + Hungarian decode of a soft site's current logits into a
    /// hard index map, using the spec's projection parameters.  `None`
    /// for modes without an N x N soft matrix.
    fn decode_logits(
        &self,
        logits: &[f32],
        n: usize,
        scratch: &mut SinkhornScratch,
    ) -> Option<Vec<usize>>;

    /// Bytes of permutation state one training run holds per site of
    /// width `n` (Tbl. 2–5 accounting).
    fn memory_bytes(&self, n: usize, hardened: bool) -> usize;
}

/// Shared, cheaply clonable permutation handle — what `RunConfig` and the
/// sweep grid carry.
pub type PermHandle = Arc<dyn PermModel>;

/// Resolve a spec string against the global registry.
pub fn resolve_perm(spec: &str) -> Result<PermHandle> {
    perm_registry().resolve(spec)
}

/// Reconstruct typed per-site state from a `TrainState`-style vals map
/// (checkpoint resume): hardened sites come back as `Hard` with their
/// saved index maps, soft sites rebind the saved logits under the model's
/// projection parameters, frozen modes classify as at init.
pub fn sites_from_vals(
    model: &dyn PermModel,
    site_names: &[String],
    widths: &[usize],
    vals: &HashMap<String, Tensor>,
) -> Result<Vec<PermSite>> {
    let flags = vals
        .get("hard_flags")
        .ok_or_else(|| anyhow!("state has no hard_flags tensor"))?
        .f32s();
    if flags.len() != site_names.len() {
        bail!("hard_flags has {} entries for {} sites", flags.len(), site_names.len());
    }
    site_names
        .iter()
        .zip(widths)
        .enumerate()
        .map(|(i, (name, &n))| {
            let logits = vals.get(&format!("perm_logits.{name}")).cloned();
            let hardened = flags[i] > 0.5;
            let state = if !hardened && model.learns() {
                let (iters, tau) = model.projection();
                PermState::Soft {
                    logits: logits
                        .clone()
                        .ok_or_else(|| anyhow!("soft site {name:?} has no perm_logits"))?,
                    sinkhorn_iters: iters,
                    temperature: tau,
                }
            } else if model.mode() == PermMode::NoPerm {
                PermState::Identity
            } else {
                let idx = vals
                    .get(&format!("perm_idx.{name}"))
                    .ok_or_else(|| anyhow!("hardened site {name:?} has no perm_idx"))?;
                PermState::Hard {
                    index_map: idx.i32s().iter().map(|&x| x as usize).collect(),
                }
            };
            Ok(PermSite { name: name.clone(), n, state, frozen_logits: logits })
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Shared init helpers
// ---------------------------------------------------------------------------

/// The historical identity-biased N x N logits draw — run for *every*
/// non-kaleidoscope mode at init (frozen modes keep the tensor inert),
/// which is what keeps the per-site RNG stream identical across modes.
fn identity_biased_logits(n: usize, rng: &mut Rng) -> Tensor {
    let mut t = Tensor::zeros(&[n, n]);
    let d = t.f32s_mut();
    for (p, v) in d.iter_mut().enumerate() {
        *v = 0.01 * rng.normal() + if p % (n + 1) == 0 { 5.0 } else { 0.0 };
    }
    t
}

// ---------------------------------------------------------------------------
// Mode impls
// ---------------------------------------------------------------------------

/// PA-DST's learned permutation: Gumbel-Sinkhorn soft training, Eqn. 14
/// penalty, Hungarian hard decode at the Apdx C.2 early stop.
#[derive(Clone, Debug, PartialEq)]
pub struct LearnedPerm {
    /// Sinkhorn projection iterations of the host decode path.
    pub sinkhorn_iters: usize,
    /// Softmax temperature (logits are divided by tau before exp);
    /// 1 = the historical un-tempered map, bit-identical to it.
    pub tau: f64,
    /// Hardening debounce override (`None` = `--harden-patience`).
    pub patience: Option<usize>,
    /// Normalised-penalty threshold override (`None` = `--harden-threshold`).
    pub threshold: Option<f64>,
}

impl Default for LearnedPerm {
    fn default() -> Self {
        LearnedPerm {
            sinkhorn_iters: DEFAULT_SINKHORN_ITERS,
            tau: DEFAULT_TAU,
            patience: None,
            threshold: None,
        }
    }
}

impl PermModel for LearnedPerm {
    fn mode(&self) -> PermMode {
        PermMode::Learned
    }

    fn spec(&self) -> String {
        let mut s = "learned".to_string();
        if self.sinkhorn_iters != DEFAULT_SINKHORN_ITERS {
            s.push_str(&format!(":sinkhorn={}", self.sinkhorn_iters));
        }
        if self.tau != DEFAULT_TAU {
            s.push_str(&format!(":tau={}", self.tau));
        }
        if let Some(p) = self.patience {
            s.push_str(&format!(":patience={p}"));
        }
        if let Some(t) = self.threshold {
            s.push_str(&format!(":threshold={t}"));
        }
        s
    }

    fn artifact_suffix(&self) -> &'static str {
        ""
    }

    fn init_site(&self, _site_i: usize, name: &str, n: usize, rng: &mut Rng) -> PermSite {
        let logits = identity_biased_logits(n, rng);
        PermSite::new(
            name,
            n,
            PermState::Soft {
                logits,
                sinkhorn_iters: self.sinkhorn_iters,
                temperature: self.tau,
            },
            None,
        )
    }

    fn projection(&self) -> (usize, f64) {
        (self.sinkhorn_iters, self.tau)
    }

    fn hardening(&self) -> Option<PermHardening> {
        Some(PermHardening { threshold: self.threshold, patience: self.patience })
    }

    fn decode_logits(
        &self,
        logits: &[f32],
        n: usize,
        scratch: &mut SinkhornScratch,
    ) -> Option<Vec<usize>> {
        let m = scratch.soft_perm(logits, n, self.sinkhorn_iters, self.tau);
        Some(decode(m, n))
    }

    fn memory_bytes(&self, n: usize, hardened: bool) -> usize {
        if hardened {
            n * 4 // index map only
        } else {
            n * n * 4 + n * 4 // logits matrix + index map
        }
    }
}

/// Kaleidoscope comparator: structured log2(N) x N butterfly-angle logits
/// (Tbl. 5).  Hardening keeps the identity index map — the K-matrix is
/// not a pure permutation, the comparator only measures overhead.
#[derive(Clone, Debug, PartialEq)]
pub struct KaleidoscopePerm {
    pub patience: Option<usize>,
    pub threshold: Option<f64>,
}

impl PermModel for KaleidoscopePerm {
    fn mode(&self) -> PermMode {
        PermMode::Kaleidoscope
    }

    fn spec(&self) -> String {
        let mut s = "kaleidoscope".to_string();
        if let Some(p) = self.patience {
            s.push_str(&format!(":patience={p}"));
        }
        if let Some(t) = self.threshold {
            s.push_str(&format!(":threshold={t}"));
        }
        s
    }

    fn artifact_suffix(&self) -> &'static str {
        "_kperm"
    }

    fn init_site(&self, _site_i: usize, name: &str, n: usize, rng: &mut Rng) -> PermSite {
        let levels = (usize::BITS - (n - 1).leading_zeros()) as usize;
        let mut logits = Tensor::zeros(&[levels, n]);
        for v in logits.f32s_mut() {
            *v = 0.01 * rng.normal();
        }
        PermSite::new(
            name,
            n,
            PermState::Soft {
                logits,
                sinkhorn_iters: DEFAULT_SINKHORN_ITERS,
                temperature: DEFAULT_TAU,
            },
            None,
        )
    }

    fn hardening(&self) -> Option<PermHardening> {
        Some(PermHardening { threshold: self.threshold, patience: self.patience })
    }

    fn decode_logits(
        &self,
        _logits: &[f32],
        _n: usize,
        _scratch: &mut SinkhornScratch,
    ) -> Option<Vec<usize>> {
        None
    }

    fn memory_bytes(&self, n: usize, hardened: bool) -> usize {
        if hardened {
            n * 4
        } else {
            let levels = (usize::BITS - (n - 1).leading_zeros()) as usize;
            levels * n * 4 + n * 4
        }
    }
}

/// Frozen random permutation (the Tbl. 11 'Random' rows): one map sampled
/// at init from `rng.fork(seed + site)`, hard from step 0.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RandomPerm {
    /// Fork base of the per-site sample (`seed + site_index`).
    pub seed: u64,
}

impl PermModel for RandomPerm {
    fn mode(&self) -> PermMode {
        PermMode::Random
    }

    fn spec(&self) -> String {
        if self.seed == DEFAULT_RANDOM_SEED {
            "random".into()
        } else {
            format!("random:seed={}", self.seed)
        }
    }

    fn artifact_suffix(&self) -> &'static str {
        ""
    }

    fn init_site(&self, site_i: usize, name: &str, n: usize, rng: &mut Rng) -> PermSite {
        let logits = identity_biased_logits(n, rng);
        let mut prng = rng.fork(self.seed + site_i as u64);
        let index_map = prng.permutation(n);
        PermSite::new(name, n, PermState::Hard { index_map }, Some(logits))
    }

    fn hardening(&self) -> Option<PermHardening> {
        None
    }

    fn decode_logits(
        &self,
        _logits: &[f32],
        _n: usize,
        _scratch: &mut SinkhornScratch,
    ) -> Option<Vec<usize>> {
        None
    }

    fn memory_bytes(&self, n: usize, _hardened: bool) -> usize {
        n * 4
    }
}

/// No permutation: the structured-DST baseline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NoPerm;

impl PermModel for NoPerm {
    fn mode(&self) -> PermMode {
        PermMode::NoPerm
    }

    fn spec(&self) -> String {
        "none".into()
    }

    fn artifact_suffix(&self) -> &'static str {
        "_noperm"
    }

    fn init_site(&self, _site_i: usize, name: &str, n: usize, rng: &mut Rng) -> PermSite {
        let logits = identity_biased_logits(n, rng);
        PermSite::new(name, n, PermState::Identity, Some(logits))
    }

    fn hardening(&self) -> Option<PermHardening> {
        None
    }

    fn decode_logits(
        &self,
        _logits: &[f32],
        _n: usize,
        _scratch: &mut SinkhornScratch,
    ) -> Option<Vec<usize>> {
        None
    }

    fn memory_bytes(&self, _n: usize, _hardened: bool) -> usize {
        0
    }
}

// ---------------------------------------------------------------------------
// Hardening controller (Apdx C.2) — absorbed from coordinator::perm_ctrl
// ---------------------------------------------------------------------------

/// Permutation-hardening controller.
///
/// The paper tracks each layer's soft-permutation penalty (Eqn. 14,
/// Fig. 5) and stops learning that layer's permutation — switching to
/// hard re-indexing — once the penalty crosses a threshold delta (Fig. 6
/// shows the per-layer crossing epochs).  The raw penalty is normalised
/// by the permutation dimension N so a single delta works across layer
/// widths (the raw penalty scales ~linearly in N for doubly-stochastic
/// matrices), and the decision is debounced over `patience` consecutive
/// observations so a single noisy step cannot harden a layer prematurely.
/// Both knobs are typed parameters now (perm spec `patience=`/`threshold=`
/// overrides, CLI `--harden-patience`/`--harden-threshold` defaults)
/// instead of the old hardcoded constants.
pub struct PermController {
    threshold: f64,
    patience: usize,
    widths: Vec<usize>,
    below: Vec<usize>,
    hardened: Vec<bool>,
}

impl PermController {
    /// `widths[i]` is site i's permutation dimension N (the normaliser).
    pub fn new(widths: &[usize], threshold: f64, patience: usize) -> PermController {
        PermController {
            threshold,
            patience: patience.max(1),
            widths: widths.to_vec(),
            below: vec![0; widths.len()],
            hardened: vec![false; widths.len()],
        }
    }

    /// Feed this step's raw per-site penalties; returns the sites to
    /// harden *now*.  Hardening is monotone: a hardened site is never
    /// revisited.
    pub fn observe(&mut self, _step: usize, penalties: &[f32]) -> Vec<usize> {
        assert_eq!(penalties.len(), self.widths.len());
        let mut fire = Vec::new();
        for (i, &p) in penalties.iter().enumerate() {
            if self.hardened[i] {
                continue;
            }
            let norm = p as f64 / self.widths[i] as f64;
            if norm < self.threshold {
                self.below[i] += 1;
                if self.below[i] >= self.patience {
                    self.hardened[i] = true;
                    fire.push(i);
                }
            } else {
                self.below[i] = 0;
            }
        }
        fire
    }

    pub fn is_hardened(&self, i: usize) -> bool {
        self.hardened[i]
    }

    pub fn n_hardened(&self) -> usize {
        self.hardened.iter().filter(|&&h| h).count()
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// One registered mode: spec grammar, defaults, hardening behaviour, and
/// the parser that turns spec arguments into a model object.  The
/// `padst perms` subcommand renders exactly this table.
pub struct PermEntry {
    pub name: &'static str,
    /// Spec grammar, e.g. `learned[:sinkhorn=I][:tau=T][:patience=P][:threshold=D]`.
    pub grammar: &'static str,
    /// Defaults a bare name resolves to.
    pub defaults: &'static str,
    /// Hardening behaviour rendered for the table.
    pub hardening: &'static str,
    /// Train artifact the mode selects.
    pub artifact: &'static str,
    parse: fn(&[&str]) -> Result<PermHandle>,
}

/// Named registry of every permutation mode.  `resolve` accepts both bare
/// mode names (historical defaults) and parameterised specs.
pub struct PermRegistry {
    modes: Vec<PermEntry>,
}

impl PermRegistry {
    pub fn modes(&self) -> &[PermEntry] {
        &self.modes
    }

    /// Resolve `"mode[:key=value[:key=value]]"` into a model object.
    pub fn resolve(&self, spec: &str) -> Result<PermHandle> {
        let mut parts = spec.split(':');
        let mode = parts.next().unwrap_or("");
        let args: Vec<&str> = parts.collect();
        let entry = self.modes.iter().find(|m| m.name == mode).ok_or_else(|| {
            anyhow!(
                "unknown permutation mode {mode:?} in spec {spec:?} (known: {})",
                self.modes.iter().map(|m| m.name).collect::<Vec<_>>().join("|")
            )
        })?;
        (entry.parse)(&args).map_err(|e| anyhow!("bad perm spec {spec:?}: {e}"))
    }
}

/// Split `key=value` spec arguments, rejecting malformed or duplicate keys.
fn parse_kv<'a>(args: &[&'a str], known: &[&str]) -> Result<Vec<(&'a str, &'a str)>> {
    let mut out: Vec<(&str, &str)> = Vec::new();
    for a in args {
        let (k, v) = a
            .split_once('=')
            .ok_or_else(|| anyhow!("expected key=value, got {a:?}"))?;
        if !known.contains(&k) {
            bail!("unknown parameter {k:?} (known: {})", known.join(", "));
        }
        if out.iter().any(|(seen, _)| *seen == k) {
            bail!("duplicate parameter {k:?}");
        }
        if v.is_empty() {
            bail!("parameter {k:?} has no value");
        }
        out.push((k, v));
    }
    Ok(out)
}

fn parse_usize_v(what: &str, s: &str) -> Result<usize> {
    s.parse::<usize>().map_err(|_| anyhow!("{what} must be a non-negative integer, got {s:?}"))
}

fn parse_f64_v(what: &str, s: &str) -> Result<f64> {
    let v: f64 = s.parse().map_err(|_| anyhow!("{what} must be a number, got {s:?}"))?;
    if !v.is_finite() {
        bail!("{what} must be finite, got {s:?}");
    }
    Ok(v)
}

fn parse_learned(args: &[&str]) -> Result<PermHandle> {
    let mut m = LearnedPerm::default();
    for (k, v) in parse_kv(args, &["sinkhorn", "tau", "patience", "threshold"])? {
        match k {
            "sinkhorn" => {
                m.sinkhorn_iters = parse_usize_v("sinkhorn", v)?;
                if m.sinkhorn_iters == 0 {
                    bail!("sinkhorn needs >= 1 iteration");
                }
            }
            "tau" => {
                m.tau = parse_f64_v("tau", v)?;
                if m.tau <= 0.0 {
                    bail!("tau must be > 0");
                }
            }
            "patience" => {
                let p = parse_usize_v("patience", v)?;
                if p == 0 {
                    bail!("patience must be >= 1");
                }
                m.patience = Some(p);
            }
            "threshold" => m.threshold = Some(parse_f64_v("threshold", v)?),
            _ => unreachable!(),
        }
    }
    Ok(Arc::new(m))
}

fn parse_kaleidoscope(args: &[&str]) -> Result<PermHandle> {
    let mut m = KaleidoscopePerm { patience: None, threshold: None };
    for (k, v) in parse_kv(args, &["patience", "threshold"])? {
        match k {
            "patience" => {
                let p = parse_usize_v("patience", v)?;
                if p == 0 {
                    bail!("patience must be >= 1");
                }
                m.patience = Some(p);
            }
            "threshold" => m.threshold = Some(parse_f64_v("threshold", v)?),
            _ => unreachable!(),
        }
    }
    Ok(Arc::new(m))
}

fn parse_random(args: &[&str]) -> Result<PermHandle> {
    let mut m = RandomPerm { seed: DEFAULT_RANDOM_SEED };
    for (k, v) in parse_kv(args, &["seed"])? {
        match k {
            "seed" => {
                m.seed = v
                    .parse::<u64>()
                    .map_err(|_| anyhow!("seed must be a non-negative integer, got {v:?}"))?;
            }
            _ => unreachable!(),
        }
    }
    Ok(Arc::new(m))
}

fn parse_none(args: &[&str]) -> Result<PermHandle> {
    if !args.is_empty() {
        bail!("none takes no parameters");
    }
    Ok(Arc::new(NoPerm))
}

/// The global registry (built once).
pub fn perm_registry() -> &'static PermRegistry {
    static REG: OnceLock<PermRegistry> = OnceLock::new();
    REG.get_or_init(|| PermRegistry {
        modes: vec![
            PermEntry {
                name: "learned",
                grammar: "learned[:sinkhorn=I][:tau=T][:patience=P][:threshold=D]",
                defaults: "sinkhorn=12 tau=1 (hardening from CLI)",
                hardening: "penalty/N < D for P steps -> Hungarian decode",
                artifact: "{model}_train",
                parse: parse_learned,
            },
            PermEntry {
                name: "kaleidoscope",
                grammar: "kaleidoscope[:patience=P][:threshold=D]",
                defaults: "log2(N) x N angle logits",
                hardening: "penalty/N < D for P steps -> identity idx",
                artifact: "{model}_train_kperm",
                parse: parse_kaleidoscope,
            },
            PermEntry {
                name: "random",
                grammar: "random[:seed=S]",
                defaults: "S = 1000 (map = fork(S + site))",
                hardening: "hard from step 0",
                artifact: "{model}_train",
                parse: parse_random,
            },
            PermEntry {
                name: "none",
                grammar: "none",
                defaults: "-",
                hardening: "never (identity)",
                artifact: "{model}_train_noperm",
                parse: parse_none,
            },
        ],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bare_names_resolve_and_roundtrip() {
        for name in ["learned", "kaleidoscope", "random", "none"] {
            let p = resolve_perm(name).unwrap();
            assert_eq!(p.spec(), name, "bare spec must print back as itself");
            assert_eq!(p.mode().name(), name);
            let q = resolve_perm(&p.spec()).unwrap();
            assert_eq!(q.spec(), p.spec());
        }
    }

    #[test]
    fn parameterised_specs_roundtrip() {
        for spec in [
            "learned:sinkhorn=24",
            "learned:tau=0.5",
            "learned:sinkhorn=24:tau=0.5",
            "learned:patience=5",
            "learned:threshold=0.1",
            "learned:sinkhorn=24:tau=0.5:patience=5:threshold=0.1",
            "kaleidoscope:patience=2",
            "random:seed=7",
        ] {
            let p = resolve_perm(spec).unwrap();
            assert_eq!(p.spec(), spec, "canonical spec must round-trip");
        }
        // Defaults canonicalise to the bare name.
        assert_eq!(resolve_perm("learned:sinkhorn=12").unwrap().spec(), "learned");
        assert_eq!(resolve_perm("learned:tau=1").unwrap().spec(), "learned");
        assert_eq!(resolve_perm("random:seed=1000").unwrap().spec(), "random");
    }

    #[test]
    fn bad_specs_are_descriptive_errors() {
        for bad in [
            "learned:sinkhorn=0",     // zero iterations
            "learned:tau=0",          // non-positive temperature
            "learned:tau=nan",        // non-finite
            "learned:patience=0",     // zero debounce
            "learned:sinkhorn",       // not key=value
            "learned:sinkhorn=2:sinkhorn=3", // duplicate
            "learned:bogus=1",        // unknown key
            "random:seed=-3",         // negative seed
            "none:x=1",               // mode takes no params
            "shuffled",               // unknown mode
        ] {
            let err = resolve_perm(bad).unwrap_err().to_string();
            assert!(!err.is_empty(), "{bad} must fail");
        }
    }

    #[test]
    fn artifact_suffixes_match_legacy_selection() {
        assert_eq!(resolve_perm("learned").unwrap().artifact_suffix(), "");
        assert_eq!(resolve_perm("random").unwrap().artifact_suffix(), "");
        assert_eq!(resolve_perm("none").unwrap().artifact_suffix(), "_noperm");
        assert_eq!(resolve_perm("kaleidoscope").unwrap().artifact_suffix(), "_kperm");
    }

    /// The historical `Trainer::init_state` permutation block, reproduced
    /// verbatim: every bare-name mode must consume the RNG identically and
    /// emit the same logits / index maps / hard flags.
    #[test]
    fn init_matches_legacy_bit_identically() {
        let n = 24usize;
        for mode in ["none", "random", "learned", "kaleidoscope"] {
            let model = resolve_perm(mode).unwrap();
            // Legacy path.
            let mut rng_a = Rng::new(99);
            let mut legacy_logits = Vec::new();
            let mut legacy_idx = Vec::new();
            let legacy_flag = if mode == "learned" || mode == "kaleidoscope" { 0.0 } else { 1.0 };
            for si in 0..3usize {
                let logits = if mode == "kaleidoscope" {
                    let levels = (usize::BITS - (n - 1).leading_zeros()) as usize;
                    let mut t = Tensor::zeros(&[levels, n]);
                    for v in t.f32s_mut() {
                        *v = 0.01 * rng_a.normal();
                    }
                    t
                } else {
                    let mut t = Tensor::zeros(&[n, n]);
                    let d = t.f32s_mut();
                    for (p, v) in d.iter_mut().enumerate() {
                        *v = 0.01 * rng_a.normal() + if p % (n + 1) == 0 { 5.0 } else { 0.0 };
                    }
                    t
                };
                legacy_logits.push(logits);
                let idx: Vec<i32> = if mode == "random" {
                    let mut prng = rng_a.fork(1000 + si as u64);
                    prng.permutation(n).iter().map(|&i| i as i32).collect()
                } else {
                    (0..n as i32).collect()
                };
                legacy_idx.push(idx);
            }
            // Typed path.
            let mut rng_b = Rng::new(99);
            for si in 0..3usize {
                let site = model.init_site(si, &format!("s{si}"), n, &mut rng_b);
                assert_eq!(site.hard_flag(), legacy_flag, "{mode} site {si} flag");
                assert_eq!(
                    site.logits().unwrap().f32s(),
                    legacy_logits[si].f32s(),
                    "{mode} site {si} logits"
                );
                assert_eq!(
                    site.index_tensor().i32s(),
                    &legacy_idx[si][..],
                    "{mode} site {si} idx"
                );
            }
            // And the streams must have advanced identically.
            assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "{mode}: rng stream diverged");
        }
    }

    #[test]
    fn export_writes_the_artifact_input_names() {
        let model = resolve_perm("random").unwrap();
        let mut rng = Rng::new(3);
        let site = model.init_site(0, "l0.fc1", 8, &mut rng);
        let mut vals = HashMap::new();
        site.export_into(&mut vals);
        assert!(vals.contains_key("perm_logits.l0.fc1"));
        let idx = vals["perm_idx.l0.fc1"].i32s().to_vec();
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..8).collect::<Vec<i32>>(), "a permutation of 0..n");
        assert_eq!(site.hard_flag(), 1.0);
    }

    #[test]
    fn learned_decode_uses_spec_params() {
        let n = 10;
        let model = resolve_perm("learned").unwrap();
        let mut scratch = SinkhornScratch::new();
        // Strong identity-biased logits decode to the identity.
        let mut logits = vec![0.0f32; n * n];
        for i in 0..n {
            logits[i * n + i] = 8.0;
        }
        let idx = model.decode_logits(&logits, n, &mut scratch).unwrap();
        assert_eq!(idx, (0..n).collect::<Vec<_>>());
        // Frozen modes have nothing to decode.
        for mode in ["none", "random", "kaleidoscope"] {
            assert!(resolve_perm(mode)
                .unwrap()
                .decode_logits(&logits, n, &mut scratch)
                .is_none());
        }
    }

    #[test]
    fn projection_params_flow_typed_from_spec() {
        // The trait accessor reads the typed fields — no spec re-parsing —
        // so resume rebinds Soft states under exactly the spec'd params.
        assert_eq!(resolve_perm("learned").unwrap().projection(), (12, 1.0));
        assert_eq!(
            resolve_perm("learned:sinkhorn=24:tau=0.5").unwrap().projection(),
            (24, 0.5)
        );
        assert_eq!(resolve_perm("none").unwrap().projection(), (12, 1.0));
    }

    #[test]
    fn hardening_overrides_flow_from_spec() {
        let m = resolve_perm("learned:patience=5:threshold=0.1").unwrap();
        let h = m.hardening().unwrap();
        assert_eq!(h.patience, Some(5));
        assert_eq!(h.threshold, Some(0.1));
        // Bare spec defers both to the run config.
        let h = resolve_perm("learned").unwrap().hardening().unwrap();
        assert_eq!(h, PermHardening::default());
        // Frozen modes never harden.
        assert!(resolve_perm("none").unwrap().hardening().is_none());
        assert!(resolve_perm("random").unwrap().hardening().is_none());
    }

    #[test]
    fn memory_accounting_matches_legacy_ordering() {
        // Tbl. 2–5 ordering at one site: learned > kaleidoscope > random >
        // none, and hardening collapses learned to the index map.
        let n = 64;
        let none = resolve_perm("none").unwrap().memory_bytes(n, false);
        let rand = resolve_perm("random").unwrap().memory_bytes(n, false);
        let kal = resolve_perm("kaleidoscope").unwrap().memory_bytes(n, false);
        let learned = resolve_perm("learned").unwrap().memory_bytes(n, false);
        let hard = resolve_perm("learned").unwrap().memory_bytes(n, true);
        assert!(none < rand && rand < kal && kal < learned);
        assert_eq!(hard, rand);
        assert_eq!(none, 0);
        assert_eq!(learned, n * n * 4 + n * 4);
    }

    #[test]
    fn controller_hardens_after_patience() {
        let widths = vec![100usize, 100];
        let mut c = PermController::new(&widths, 0.22, 3);
        // site 0 penalty below threshold (10/100 = 0.1), site 1 above.
        for step in 0..2 {
            assert!(c.observe(step, &[10.0, 80.0]).is_empty());
        }
        assert_eq!(c.observe(2, &[10.0, 80.0]), vec![0]);
        assert!(c.is_hardened(0) && !c.is_hardened(1));
        // Never fires twice.
        assert!(c.observe(3, &[10.0, 80.0]).is_empty());
        assert_eq!(c.n_hardened(), 1);
    }

    #[test]
    fn controller_noisy_spike_resets_debounce() {
        let mut c = PermController::new(&[100], 0.22, 3);
        assert!(c.observe(0, &[10.0]).is_empty());
        assert!(c.observe(1, &[10.0]).is_empty());
        assert!(c.observe(2, &[90.0]).is_empty()); // spike resets
        assert!(c.observe(3, &[10.0]).is_empty());
        assert!(c.observe(4, &[10.0]).is_empty());
        assert_eq!(c.observe(5, &[10.0]), vec![0]);
    }

    #[test]
    fn controller_respects_typed_patience() {
        let mut c = PermController::new(&[100], 0.22, 1);
        assert_eq!(c.observe(0, &[10.0]), vec![0], "patience=1 fires immediately");
        let mut c = PermController::new(&[100], -1.0, 3);
        for step in 0..10 {
            assert!(c.observe(step, &[0.0]).is_empty(), "negative threshold never fires");
        }
    }

    #[test]
    fn sites_from_vals_classifies_states() {
        let model = resolve_perm("learned").unwrap();
        let mut rng = Rng::new(5);
        let names = vec!["a".to_string(), "b".to_string()];
        let widths = vec![6usize, 6];
        let mut vals = HashMap::new();
        let mut flags = Vec::new();
        for (si, name) in names.iter().enumerate() {
            let mut site = model.init_site(si, name, 6, &mut rng);
            if si == 1 {
                site.harden(vec![5, 4, 3, 2, 1, 0]);
            }
            flags.push(site.hard_flag());
            site.export_into(&mut vals);
        }
        vals.insert("hard_flags".into(), Tensor::from_f32(&[2], flags));
        let sites = sites_from_vals(model.as_ref(), &names, &widths, &vals).unwrap();
        assert!(matches!(sites[0].state, PermState::Soft { .. }));
        assert_eq!(sites[1].state.index_map(), Some(&[5usize, 4, 3, 2, 1, 0][..]));
        // NoPerm classifies hardened flags as Identity.
        let none = resolve_perm("none").unwrap();
        let mut rng = Rng::new(5);
        let mut vals = HashMap::new();
        let site = none.init_site(0, "a", 6, &mut rng);
        site.export_into(&mut vals);
        vals.insert("hard_flags".into(), Tensor::from_f32(&[1], vec![site.hard_flag()]));
        let sites =
            sites_from_vals(none.as_ref(), &names[..1], &widths[..1], &vals).unwrap();
        assert!(matches!(sites[0].state, PermState::Identity));
    }

    #[test]
    fn registry_table_is_complete() {
        let reg = perm_registry();
        assert_eq!(reg.modes().len(), 4);
        for m in reg.modes() {
            let p = reg.resolve(m.name).unwrap();
            assert_eq!(p.mode().name(), m.name);
            assert!(!m.grammar.is_empty() && !m.hardening.is_empty() && !m.artifact.is_empty());
        }
    }
}
