//! `artifacts/manifest.json` schema — the contract between the Python
//! compile path and the Rust coordinator.  Program specs give the exact
//! flat ordering of inputs/outputs; model entries give parameter layouts
//! and sparse-site geometry.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::tensor::DType;
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

#[derive(Clone, Debug)]
pub struct ProgramSpec {
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct ProgramEntry {
    pub file: String,
    pub model: String,
    pub program: String,
    pub structure: String,
    pub density: f64,
    /// Permutation mode the artifact was compiled for (manifests spell
    /// the legacy key name; see `perm::model::MANIFEST_PERM_KEY`).
    pub perm: String,
    pub batch: usize,
    pub golden: bool,
    pub spec: ProgramSpec,
}

#[derive(Clone, Debug)]
pub struct SiteSpec {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
}

#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub kind: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub n_classes: usize,
    pub image: usize,
    pub patch: usize,
    pub params: Vec<(String, Vec<usize>)>,
    pub sites: Vec<SiteSpec>,
}

impl ModelEntry {
    pub fn site(&self, name: &str) -> Option<&SiteSpec> {
        self.sites.iter().find(|s| s.name == name)
    }

    /// Total parameter count (dense storage).
    pub fn n_params(&self) -> usize {
        self.params
            .iter()
            .map(|(_, s)| s.iter().product::<usize>().max(1))
            .sum()
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub batch: usize,
    pub programs: BTreeMap<String, ProgramEntry>,
    pub models: BTreeMap<String, ModelEntry>,
}

fn tensor_specs(v: &Json) -> Result<Vec<TensorSpec>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("spec list not an array"))?
        .iter()
        .map(|e| {
            Ok(TensorSpec {
                name: e.at(&["name"])?.as_str().unwrap().to_string(),
                shape: e
                    .at(&["shape"])?
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|x| x.as_usize().unwrap())
                    .collect(),
                dtype: DType::parse(e.at(&["dtype"])?.as_str().unwrap())?,
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("read {}: {e}", path.display()))?;
        let j = Json::parse(&text)?;
        let batch = j.at(&["batch"])?.as_usize().unwrap();

        let mut programs = BTreeMap::new();
        for (name, p) in j.at(&["programs"])?.as_obj().unwrap() {
            programs.insert(
                name.clone(),
                ProgramEntry {
                    file: p.at(&["file"])?.as_str().unwrap().to_string(),
                    model: p.at(&["model"])?.as_str().unwrap().to_string(),
                    program: p.at(&["program"])?.as_str().unwrap().to_string(),
                    structure: p.at(&["structure"])?.as_str().unwrap().to_string(),
                    density: p.at(&["density"])?.as_f64().unwrap(),
                    perm: p
                        .at(&[crate::perm::model::MANIFEST_PERM_KEY])?
                        .as_str()
                        .unwrap()
                        .to_string(),
                    batch: p.at(&["batch"])?.as_usize().unwrap(),
                    golden: matches!(p.get("golden"), Some(Json::Bool(true))),
                    spec: ProgramSpec {
                        inputs: tensor_specs(p.at(&["spec", "inputs"])?)?,
                        outputs: tensor_specs(p.at(&["spec", "outputs"])?)?,
                    },
                },
            );
        }

        let mut models = BTreeMap::new();
        for (name, m) in j.at(&["models"])?.as_obj().unwrap() {
            let geti = |k: &str| -> usize {
                m.get(k).and_then(|v| v.as_usize()).unwrap_or(0)
            };
            models.insert(
                name.clone(),
                ModelEntry {
                    kind: m.at(&["kind"])?.as_str().unwrap().to_string(),
                    d_model: geti("d_model"),
                    n_layers: geti("n_layers"),
                    n_heads: geti("n_heads"),
                    d_ff: geti("d_ff"),
                    seq_len: geti("seq_len"),
                    vocab: geti("vocab"),
                    n_classes: geti("n_classes"),
                    image: geti("image"),
                    patch: geti("patch"),
                    params: m
                        .at(&["params"])?
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(|e| {
                            (
                                e.at(&["name"]).unwrap().as_str().unwrap().to_string(),
                                e.at(&["shape"])
                                    .unwrap()
                                    .as_arr()
                                    .unwrap()
                                    .iter()
                                    .map(|x| x.as_usize().unwrap())
                                    .collect(),
                            )
                        })
                        .collect(),
                    sites: m
                        .at(&["sites"])?
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(|e| SiteSpec {
                            name: e.at(&["name"]).unwrap().as_str().unwrap().to_string(),
                            rows: e.at(&["rows"]).unwrap().as_usize().unwrap(),
                            cols: e.at(&["cols"]).unwrap().as_usize().unwrap(),
                        })
                        .collect(),
                },
            );
        }
        Ok(Manifest { batch, programs, models })
    }
}
