//! Stub PJRT backend, used when the `pjrt` feature is off.
//!
//! The real backend is the `xla` bindings crate from the rust_pallas
//! toolchain (xla_extension 0.5.1).  That crate links a multi-hundred-MB
//! native library and is not available in every build environment, so the
//! default build compiles against this API-compatible stub instead: every
//! type used by [`super`] exists with the same signatures, constructors
//! that only shuffle host data work, and anything that would need a real
//! PJRT client returns a descriptive error.
//!
//! The integration tests and benches that execute artifacts all skip when
//! `artifacts/manifest.json` is absent, and [`super::Runtime::program`]
//! fails before any executable is built, so the stub never silently
//! fabricates results — it only moves the failure from link time to the
//! first artifact compile.

/// Error type standing in for `xla::Error`; carried as a plain message.
#[derive(Debug, Clone)]
pub struct Error(pub String);

fn unavailable<T>(what: &str) -> Result<T, Error> {
    Err(Error(format!(
        "{what} requires the real PJRT backend; rebuild with `--features pjrt` \
         and the rust_pallas toolchain's `xla` crate (see docs/ARCHITECTURE.md)"
    )))
}

/// Stub of `xla::PjRtClient`.  Construction succeeds (so `Runtime::open`
/// and manifest-only consumers — `padst list`, memory accounting, sweep
/// setup — work without the real backend); the error surfaces at program
/// compile time, the first point that actually needs PJRT.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("compiling an HLO computation")
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("executing an AOT artifact")
    }
}

/// Stub of `xla::PjRtBuffer`.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("fetching a device buffer")
    }
}

/// Stub of `xla::HloModuleProto`.
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable("parsing HLO text")
    }
}

/// Stub of `xla::XlaComputation`.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// Element types the pipeline moves across the PJRT boundary.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// Stub of `xla::Literal`: host-side construction works (it is pure data
/// movement), device-side conversions error.
pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn vec1<T: NativeType>(_v: &[T]) -> Literal {
        Literal { _priv: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(Literal { _priv: () })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        unavailable("reading literal contents")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        unavailable("destructuring a tuple literal")
    }
}
