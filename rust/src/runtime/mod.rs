//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them on the CPU PJRT client.  This is the only module that
//! touches the `xla` crate; everything above it works in host [`Tensor`]s.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`) — the
//! image's xla_extension 0.5.1 rejects jax>=0.5 serialized protos with
//! 64-bit instruction ids, while the text parser reassigns ids cleanly.
//!
//! The backend is swappable at compile time: with the `pjrt` feature the
//! real `xla` bindings are used; without it the [`pjrt_stub`] module
//! provides the same API and fails with a descriptive error when artifact
//! execution is attempted (host-only paths — native kernels, NLR tables,
//! mask algebra — never touch it).

pub mod manifest;
#[cfg(not(feature = "pjrt"))]
pub mod pjrt_stub;

#[cfg(not(feature = "pjrt"))]
use self::pjrt_stub as xla;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::kernels::micro::Backend;
use crate::kernels::parallel::available_threads;
use crate::tensor::{DType, Data, Tensor};
use manifest::{Manifest, ProgramSpec};

/// A compiled AOT program plus its I/O spec.
pub struct Program {
    pub name: String,
    pub spec: ProgramSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// The runtime: one PJRT CPU client + an executable cache keyed by artifact
/// name.  Compilation happens lazily on first use and is cached for the
/// lifetime of the process (compiling a train_step takes ~100 ms–1 s).
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    /// Worker-thread budget advertised to consumers of this runtime.
    /// Honoured today by the native parallel-kernel paths
    /// ([`crate::kernels::parallel`]); artifact execution still runs under
    /// PJRT's own pool — wiring this into the client's intra-op
    /// parallelism is a ROADMAP open item.  Defaults to the machine's
    /// available parallelism; 1 means serial.
    pub threads: usize,
    /// Microkernel backend advertised to consumers of this runtime, next
    /// to the thread budget: honoured by the native kernel paths
    /// ([`crate::kernels::micro`]); artifact execution is backend-blind.
    /// Defaults to [`Backend::default_backend`] (`PADST_BACKEND`, else
    /// tiled).
    pub backend: Backend,
    dir: PathBuf,
    cache: HashMap<String, std::rc::Rc<Program>>,
}

impl Runtime {
    /// Open the artifact directory (usually `artifacts/`) and its manifest,
    /// with the default thread budget (available parallelism).
    pub fn open(dir: &Path) -> Result<Runtime> {
        Self::open_with_threads(dir, available_threads())
    }

    /// [`Runtime::open`] with an explicit worker-thread budget (0 = auto).
    pub fn open_with_threads(dir: &Path, threads: usize) -> Result<Runtime> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        let threads = if threads == 0 { available_threads() } else { threads };
        Ok(Runtime {
            client,
            manifest,
            threads,
            backend: Backend::default_backend(),
            dir: dir.to_path_buf(),
            cache: HashMap::new(),
        })
    }

    /// Re-budget the worker threads (0 = auto).  Takes effect for native
    /// kernel calls issued after this point; compiled programs are
    /// unaffected (PJRT pins its pool at client creation).
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = if threads == 0 { available_threads() } else { threads };
    }

    /// Re-select the microkernel backend advertised by this runtime (a
    /// Simd request degrades to Tiled in builds without `nightly-simd`).
    /// An explicit selection pins the backend process-wide: the kernel
    /// autotuner ([`crate::kernels::tune`]) may still pick bit-preserving
    /// dispatch variants, but never overrides a pinned backend.
    pub fn set_backend(&mut self, backend: Backend) {
        crate::kernels::tune::note_backend_pinned();
        self.backend = backend.effective();
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn program(&mut self, name: &str) -> Result<std::rc::Rc<Program>> {
        if let Some(p) = self.cache.get(name) {
            return Ok(p.clone());
        }
        let entry = self
            .manifest
            .programs
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))?;
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        let p = std::rc::Rc::new(Program { name: name.to_string(), spec: entry.spec.clone(), exe });
        self.cache.insert(name.to_string(), p.clone());
        Ok(p)
    }

    pub fn artifact_names(&self) -> Vec<String> {
        self.manifest.programs.keys().cloned().collect()
    }

    pub fn golden_path(&self, name: &str) -> PathBuf {
        self.dir.join("golden").join(format!("{name}.tnz"))
    }
}

impl Program {
    /// Execute with host tensors; validates count/shape/dtype against the
    /// spec and unpacks the 1-tuple the AOT path emits (return_tuple=True).
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let mut lits = Vec::with_capacity(inputs.len());
        for (t, spec) in inputs.iter().zip(&self.spec.inputs) {
            if t.shape != spec.shape {
                bail!(
                    "{}: input {:?} shape {:?} != spec {:?}",
                    self.name, spec.name, t.shape, spec.shape
                );
            }
            if t.dtype() != spec.dtype {
                bail!(
                    "{}: input {:?} dtype {:?} != spec {:?}",
                    self.name, spec.name, t.dtype(), spec.dtype
                );
            }
            lits.push(tensor_to_literal(t)?);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let elems = tuple.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        if elems.len() != self.spec.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.name,
                self.spec.outputs.len(),
                elems.len()
            );
        }
        elems
            .into_iter()
            .zip(&self.spec.outputs)
            .map(|(l, spec)| literal_to_tensor(&l, &spec.shape, spec.dtype))
            .collect()
    }

    /// Position of a named input in the flat argument list.
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.spec
            .inputs
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| anyhow!("{}: no input named {name:?}", self.name))
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.spec
            .outputs
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| anyhow!("{}: no output named {name:?}", self.name))
    }
}

pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    let lit = match &t.data {
        Data::F32(v) => xla::Literal::vec1(v),
        Data::I32(v) => xla::Literal::vec1(v),
    };
    lit.reshape(&dims).map_err(|e| anyhow!("reshape literal: {e:?}"))
}

pub fn literal_to_tensor(l: &xla::Literal, shape: &[usize], dtype: DType) -> Result<Tensor> {
    let t = match dtype {
        DType::F32 => Tensor::from_f32(
            shape,
            l.to_vec::<f32>().map_err(|e| anyhow!("literal->f32: {e:?}"))?,
        ),
        DType::I32 => Tensor::from_i32(
            shape,
            l.to_vec::<i32>().map_err(|e| anyhow!("literal->i32: {e:?}"))?,
        ),
    };
    Ok(t)
}
