//! Scoped timing spans with static labels and a thread-local span stack.
//!
//! A span is an RAII guard: entering pushes its `&'static str` label
//! onto a fixed-capacity thread-local stack (no allocation) and notes
//! the start time; dropping pops the label and records the elapsed
//! nanoseconds into an optional [`Histogram`].  Early returns and `?`
//! propagation unwind guards in LIFO order, so the stack always
//! balances — `depth()` is 0 between top-level operations.
//!
//! Labels must be `'static` string literals precisely so the hot path
//! stays allocation-free: pushing is an array store + depth bump.

use std::cell::Cell;
use std::sync::Arc;
use std::time::Instant;

use super::metrics::Histogram;

/// Maximum tracked nesting depth.  Deeper spans still time correctly;
/// only their labels are dropped from the stack.
pub const MAX_DEPTH: usize = 32;

thread_local! {
    static LABELS: Cell<[&'static str; MAX_DEPTH]> = const { Cell::new([""; MAX_DEPTH]) };
    static DEPTH: Cell<usize> = const { Cell::new(0) };
}

/// RAII guard returned by [`enter`] / [`timed`].
pub struct SpanGuard {
    start: Instant,
    hist: Option<Arc<Histogram>>,
}

/// Enter an untimed span: label-only, for attribution via [`path`].
pub fn enter(label: &'static str) -> SpanGuard {
    push(label);
    SpanGuard { start: Instant::now(), hist: None }
}

/// Enter a timed span: on drop, elapsed nanoseconds are recorded into
/// `hist`.  The `Arc` clone is a single atomic increment — no
/// allocation on the hot path.
pub fn timed(label: &'static str, hist: &Arc<Histogram>) -> SpanGuard {
    push(label);
    SpanGuard { start: Instant::now(), hist: Some(hist.clone()) }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        DEPTH.with(|d| d.set(d.get().saturating_sub(1)));
        if let Some(h) = &self.hist {
            h.record_ns(self.start.elapsed());
        }
    }
}

fn push(label: &'static str) {
    DEPTH.with(|d| {
        let depth = d.get();
        if depth < MAX_DEPTH {
            LABELS.with(|l| {
                let mut arr = l.get();
                arr[depth] = label;
                l.set(arr);
            });
        }
        d.set(depth + 1);
    });
}

/// Current nesting depth on this thread (0 when no span is active).
pub fn depth() -> usize {
    DEPTH.with(Cell::get)
}

/// `"outer/inner"`-style label path for the current thread.  Allocates;
/// intended for debugging and error context, not hot paths.
pub fn path() -> String {
    let depth = depth().min(MAX_DEPTH);
    LABELS.with(|l| l.get()[..depth].join("/"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nested_spans_balance() {
        assert_eq!(depth(), 0);
        {
            let _a = enter("a");
            assert_eq!(depth(), 1);
            {
                let _b = enter("b");
                assert_eq!(depth(), 2);
                assert_eq!(path(), "a/b");
            }
            assert_eq!(depth(), 1);
        }
        assert_eq!(depth(), 0);
        assert_eq!(path(), "");
    }

    #[test]
    fn early_return_unwinds() {
        fn inner(fail: bool) -> Result<(), ()> {
            let _s = enter("inner");
            if fail {
                return Err(());
            }
            Ok(())
        }
        assert!(inner(true).is_err());
        assert_eq!(depth(), 0);
        assert!(inner(false).is_ok());
        assert_eq!(depth(), 0);
    }

    #[test]
    fn timed_span_records() {
        let h = Arc::new(Histogram::default());
        {
            let _s = timed("t", &h);
        }
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn overflow_depth_still_balances() {
        let mut guards = Vec::new();
        for _ in 0..(MAX_DEPTH + 4) {
            guards.push(enter("deep"));
        }
        assert_eq!(depth(), MAX_DEPTH + 4);
        drop(guards);
        assert_eq!(depth(), 0);
    }
}
