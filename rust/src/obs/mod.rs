//! Observability: spans, metrics, snapshots, and the `padst watch` view.
//!
//! Always-available and dependency-free (the build is offline).  Layout:
//!
//! - [`metrics`] — `MetricRegistry` of counters / gauges / log-scale
//!   histograms; registration allocates, recording never does.
//! - [`span`] — RAII timing spans with static labels on a thread-local
//!   stack, recording into histograms on drop.
//! - [`export`] — schema-versioned, mergeable JSON snapshots
//!   (`obs_schema`), embedded in `stats` wire frames and
//!   `BenchReport` provenance.
//! - [`watch`] — journal heartbeat records + the `padst watch` terminal
//!   status view.
//!
//! Two recording disciplines, by cost of the instrumented operation:
//!
//! - Serve frames and harness cells are *macro* operations (µs–minutes);
//!   they record unconditionally.
//! - `kernels::run_plan{,_mt}` sits inside training inner loops where a
//!   single `Instant::now()` pair is measurable on tiny GEMMs, so kernel
//!   dispatch metrics hide behind [`enabled`] — one relaxed atomic load
//!   when off.  `padst serve` and `padst sweep` switch it on; tests and
//!   library users via [`set_enabled`] or `PADST_OBS=1`.

pub mod export;
pub mod metrics;
pub mod span;
pub mod watch;

pub use export::{HistSnapshot, ObsSnapshot, OBS_SCHEMA_VERSION};
pub use metrics::{Counter, Gauge, Histogram, MetricRegistry};

use std::sync::atomic::{AtomicBool, Ordering};

static ENABLED: AtomicBool = AtomicBool::new(false);

/// The process-wide registry backing the kernels and harness layers.
/// (The serve layer gives each `SessionCtx` its own registry instead,
/// so per-session stats stay isolated.)
pub fn global() -> &'static MetricRegistry {
    static GLOBAL: MetricRegistry = MetricRegistry::new();
    &GLOBAL
}

/// Cheap enabled-check guarding kernel-level (inner-loop) timing.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Honour `PADST_OBS=1` / `PADST_OBS=0` (called once from `main`).
pub fn init_from_env() {
    if let Ok(v) = std::env::var("PADST_OBS") {
        set_enabled(v == "1" || v.eq_ignore_ascii_case("true"));
    }
}
