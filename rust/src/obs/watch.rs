//! `padst watch` — a live terminal status view over a sweep journal.
//!
//! The JSONL resume journal (`harness::shard::Journal`) is an embryonic
//! event log: `{"cell":.., "key":..}` completion records plus a
//! `__meta__` header.  This PR adds two *tagged* record kinds that
//! pre-PR-7 readers skip (they key on `"key"`/`"cell"` presence):
//!
//! - `{"hb": {...}}` — a worker [`Heartbeat`] written by the sharded
//!   sweep executor at cell start/finish, carrying worker id, cell id,
//!   progress counters and (on `done`) the cell wall-clock.
//! - `{"plan": {"total": N, "cells": [...]}}` — the planned grid,
//!   seeded by `padst sweep --dry-run --journal <path>` so `watch` can
//!   show done/total before the first worker finishes a cell.
//!
//! `watch` tails that file and renders progress, per-worker
//! last-heartbeat age, an ETA from the cell-duration histogram, and a
//! stale-shard warning.  Rendering is a pure function of
//! `(view, now, stale_after)` so the CI golden and the unit tests are
//! byte-deterministic.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;
use std::time::{Duration, SystemTime, UNIX_EPOCH};

use anyhow::{anyhow, Context, Result};

use crate::util::json::{self, Json};

use super::metrics::Histogram;

// This module owns the journal record-tag namespace (`harness::shard`
// re-exports META_KEY): obs must stay importable from harness, not the
// other way round (lint rule L1).

/// Journal line holding the sweep parameters; a journal only resumes (or
/// merges with) a sweep whose metadata matches this header exactly.
pub const META_KEY: &str = "__meta__";
/// Journal key wrapping heartbeat events: `{"hb": {...}}`.
pub const HEARTBEAT_KEY: &str = "hb";
/// Journal key wrapping the planned-grid record: `{"plan": {...}}`.
pub const PLAN_KEY: &str = "plan";

/// Wall-clock seconds since the Unix epoch.
pub fn now_unix() -> f64 {
    SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_secs_f64()).unwrap_or(0.0)
}

/// One worker heartbeat: written at cell start (`event == "start"`) and
/// completion (`event == "done"`, with the cell wall-clock in `dur_s`).
#[derive(Clone, Debug, PartialEq)]
pub struct Heartbeat {
    pub worker: usize,
    /// `"start"` or `"done"`.
    pub event: String,
    /// Cell id (`method@sparsity`).
    pub cell: String,
    /// Cells completed across the whole run when this beat was written.
    pub done: usize,
    /// Total cells in the planned grid.
    pub total: usize,
    /// Unix timestamp (seconds).
    pub t: f64,
    /// Cell wall-clock seconds; only on `done` events.
    pub dur_s: Option<f64>,
}

impl Heartbeat {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("cell", json::s(&self.cell)),
            ("done", json::num(self.done as f64)),
            ("event", json::s(&self.event)),
            ("t", json::num(self.t)),
            ("total", json::num(self.total as f64)),
            ("worker", json::num(self.worker as f64)),
        ];
        if let Some(d) = self.dur_s {
            pairs.push(("dur_s", json::num(d)));
        }
        json::obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<Heartbeat> {
        Ok(Heartbeat {
            worker: v.get("worker").and_then(Json::as_usize).ok_or_else(|| {
                anyhow!("heartbeat record missing worker: {}", v.to_string_pretty())
            })?,
            event: v.get("event").and_then(Json::as_str).unwrap_or("?").to_string(),
            cell: v.get("cell").and_then(Json::as_str).unwrap_or("?").to_string(),
            done: v.get("done").and_then(Json::as_usize).unwrap_or(0),
            total: v.get("total").and_then(Json::as_usize).unwrap_or(0),
            t: v.get("t").and_then(Json::as_f64).unwrap_or(0.0),
            dur_s: v.get("dur_s").and_then(Json::as_f64),
        })
    }
}

/// Everything `watch` needs, parsed from one pass over the journal.
/// Unparseable lines are counted, never fatal: the file is being
/// appended to while we read it.
#[derive(Clone, Debug, Default)]
pub struct JournalView {
    pub path: String,
    /// The `__meta__` header payload (model / steps / seed), if present.
    pub meta: Option<Json>,
    /// Planned cell count from the newest `{"plan": ...}` record.
    pub plan_total: Option<usize>,
    /// Distinct completed cell ids.
    pub done: BTreeSet<String>,
    /// Per-cell training wall-clock from completion records (fallback
    /// ETA source when no heartbeat carries `dur_s`).
    pub cell_seconds: Vec<f64>,
    /// All heartbeats, in file order.
    pub heartbeats: Vec<Heartbeat>,
    /// Lines that parsed as neither meta, cell, heartbeat nor plan.
    pub skipped: usize,
}

impl JournalView {
    /// Total cells: the planned grid if seeded, else the widest total
    /// any heartbeat has claimed.
    pub fn total(&self) -> Option<usize> {
        self.plan_total
            .or_else(|| self.heartbeats.iter().map(|h| h.total).max().filter(|&t| t > 0))
    }

    /// Latest heartbeat per worker id.
    pub fn latest_by_worker(&self) -> BTreeMap<usize, &Heartbeat> {
        let mut m: BTreeMap<usize, &Heartbeat> = BTreeMap::new();
        for hb in &self.heartbeats {
            let e = m.entry(hb.worker).or_insert(hb);
            if hb.t >= e.t {
                *e = hb;
            }
        }
        m
    }

    /// Observed cell durations (heartbeat `dur_s` preferred, journal
    /// `train_seconds` otherwise), for the ETA histogram.
    pub fn durations_s(&self) -> Vec<f64> {
        let hb: Vec<f64> = self.heartbeats.iter().filter_map(|h| h.dur_s).collect();
        if hb.is_empty() {
            self.cell_seconds.clone()
        } else {
            hb
        }
    }
}

/// Parse journal text into a [`JournalView`] (see module docs for the
/// record kinds).  Tolerant by design: torn tails and unknown tagged
/// records are skipped, not errors.
pub fn parse_view(text: &str) -> JournalView {
    let mut view = JournalView::default();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let Ok(v) = Json::parse(line) else {
            view.skipped += 1;
            continue;
        };
        if let Some(key) = v.get("key").and_then(Json::as_str) {
            let Some(cell) = v.get("cell") else {
                view.skipped += 1;
                continue;
            };
            if key == META_KEY {
                view.meta = Some(cell.clone());
            } else if view.done.insert(key.to_string()) {
                if let Some(s) = cell.get("train_seconds").and_then(Json::as_f64) {
                    if s.is_finite() && s >= 0.0 {
                        view.cell_seconds.push(s);
                    }
                }
            }
        } else if let Some(hb) = v.get(HEARTBEAT_KEY) {
            match Heartbeat::from_json(hb) {
                Ok(h) => view.heartbeats.push(h),
                Err(_) => view.skipped += 1,
            }
        } else if let Some(plan) = v.get(PLAN_KEY) {
            view.plan_total = plan.get("total").and_then(Json::as_usize);
        } else {
            view.skipped += 1;
        }
    }
    view
}

/// Read and parse a journal file.
pub fn read_view(path: &Path) -> Result<JournalView> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("padst watch: cannot read journal {}", path.display()))?;
    let mut view = parse_view(&text);
    view.path = path.display().to_string();
    Ok(view)
}

fn fmt_age(secs: f64) -> String {
    let s = secs.max(0.0);
    if s < 100.0 {
        format!("{s:.0}s")
    } else if s < 3600.0 {
        format!("{:.1}m", s / 60.0)
    } else {
        format!("{:.1}h", s / 3600.0)
    }
}

const BAR_WIDTH: usize = 40;

/// Render the status view.  Pure: all wall-clock context comes in via
/// `now`, so goldens and tests are byte-deterministic.
pub fn render(view: &JournalView, now: f64, stale_after_s: f64) -> String {
    let mut out = String::new();
    let header = match &view.meta {
        Some(m) => {
            let f = |k: &str| match m.get(k) {
                Some(Json::Str(s)) => s.clone(),
                Some(v) => v.to_string_pretty(),
                None => "?".to_string(),
            };
            format!("model={} steps={} seed={}", f("model"), f("steps"), f("seed"))
        }
        None => "no sweep header yet".to_string(),
    };
    let _ = writeln!(out, "# padst watch — {header}");
    let _ = writeln!(
        out,
        "journal: {} ({} cells done, {} heartbeats)",
        view.path,
        view.done.len(),
        view.heartbeats.len()
    );

    let done = view.done.len();
    match view.total() {
        Some(total) if total > 0 => {
            let frac = (done as f64 / total as f64).clamp(0.0, 1.0);
            let filled = (frac * BAR_WIDTH as f64).round() as usize;
            let _ = writeln!(out, "cells:   {done}/{total} done ({:.1}%)", frac * 100.0);
            let _ = writeln!(
                out,
                "         [{}{}]",
                "#".repeat(filled),
                ".".repeat(BAR_WIDTH - filled)
            );
            let durs = view.durations_s();
            let latest = view.latest_by_worker();
            let active = latest.values().filter(|h| now - h.t <= stale_after_s).count();
            let pending = total.saturating_sub(done);
            if pending > 0 && !durs.is_empty() {
                // ETA from the cell-duration histogram (millisecond
                // resolution; the log buckets keep long cells honest).
                let h = Histogram::default();
                for &d in &durs {
                    h.record((d * 1e3).clamp(0.0, u64::MAX as f64) as u64);
                }
                let p50_s = h.snapshot().quantile(0.5) as f64 / 1e3;
                let eta_s = pending as f64 * p50_s / active.max(1) as f64;
                let _ = writeln!(
                    out,
                    "eta:     ~{} (p50 cell {}, {pending} pending, {active} active worker{})",
                    fmt_age(eta_s),
                    fmt_age(p50_s),
                    if active == 1 { "" } else { "s" }
                );
            }
        }
        _ => {
            let _ = writeln!(out, "cells:   {done}/? done (grid not seeded; no plan record)");
        }
    }

    let latest = view.latest_by_worker();
    if latest.is_empty() {
        let _ = writeln!(
            out,
            "no heartbeats yet — run `padst sweep --journal {}` to light this view up",
            view.path
        );
    } else {
        let mut stale = 0usize;
        for (i, (w, hb)) in latest.iter().enumerate() {
            let age = now - hb.t;
            let is_stale = age > stale_after_s;
            if is_stale {
                stale += 1;
            }
            let status = if hb.event == "start" {
                format!("running {}", hb.cell)
            } else {
                format!("idle (last {})", hb.cell)
            };
            let _ = writeln!(
                out,
                "{} w{:<3} {:<34} hb {} ago{}",
                if i == 0 { "workers:" } else { "        " },
                w,
                status,
                fmt_age(age),
                if is_stale { "  STALE" } else { "" }
            );
        }
        if stale > 0 {
            let _ = writeln!(
                out,
                "warning: {stale} worker{} silent for over {} — the shard may be dead; \
                 its cells will be re-run on resume",
                if stale == 1 { "" } else { "s" },
                fmt_age(stale_after_s)
            );
        }
    }
    if view.skipped > 0 {
        let _ = writeln!(out, "note:    {} unrecognised/torn journal line(s)", view.skipped);
    }
    out
}

/// The `padst watch` entry point: render once (`once == true`) or
/// re-render in place every `interval_s` until interrupted.
/// `now_override` pins the clock for deterministic output (CI goldens).
pub fn watch(
    path: &Path,
    once: bool,
    interval_s: f64,
    stale_after_s: f64,
    now_override: Option<f64>,
) -> Result<()> {
    loop {
        let view = read_view(path)?;
        let now = now_override.unwrap_or_else(now_unix);
        let frame = render(&view, now, stale_after_s);
        let mut stdout = std::io::stdout().lock();
        if once {
            stdout.write_all(frame.as_bytes())?;
            return Ok(());
        }
        // ANSI clear + home, then the frame — a flicker-free live view
        // without a TUI dependency.
        write!(stdout, "\x1b[2J\x1b[H{frame}")?;
        stdout.flush()?;
        drop(stdout);
        std::thread::sleep(Duration::from_secs_f64(interval_s.max(0.1)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heartbeat_json_round_trips() {
        let hb = Heartbeat {
            worker: 2,
            event: "done".to_string(),
            cell: "RigL@0.9".to_string(),
            done: 3,
            total: 8,
            t: 1723.5,
            dur_s: Some(12.25),
        };
        let back = Heartbeat::from_json(&Json::parse(&hb.to_json().to_string_pretty()).unwrap());
        assert_eq!(back.unwrap(), hb);
    }

    #[test]
    fn parse_view_sorts_record_kinds() {
        let text = [
            r#"{"cell":{"model":"vit_tiny","seed":0,"steps":5},"key":"__meta__"}"#,
            r#"{"cell":{"train_seconds":2.5},"key":"RigL@0.8"}"#,
            r#"{"hb":{"cell":"RigL@0.9","done":1,"event":"start","t":100,"total":4,"worker":0}}"#,
            r#"{"plan":{"cells":["RigL@0.8","RigL@0.9"],"total":4}}"#,
            r#"{"torn line"#,
        ]
        .join("\n");
        let v = parse_view(&text);
        assert!(v.meta.is_some());
        assert_eq!(v.done.len(), 1);
        assert_eq!(v.cell_seconds, vec![2.5]);
        assert_eq!(v.heartbeats.len(), 1);
        assert_eq!(v.plan_total, Some(4));
        assert_eq!(v.skipped, 1);
        assert_eq!(v.total(), Some(4));
    }

    #[test]
    fn render_is_deterministic_and_shows_progress() {
        let mut view = JournalView { path: "j.jsonl".to_string(), ..Default::default() };
        view.plan_total = Some(4);
        view.done.insert("a@0.8".to_string());
        view.done.insert("b@0.8".to_string());
        view.heartbeats.push(Heartbeat {
            worker: 0,
            event: "start".to_string(),
            cell: "c@0.8".to_string(),
            done: 2,
            total: 4,
            t: 995.0,
            dur_s: None,
        });
        view.heartbeats.push(Heartbeat {
            worker: 1,
            event: "done".to_string(),
            cell: "b@0.8".to_string(),
            done: 2,
            total: 4,
            t: 600.0,
            dur_s: Some(30.0),
        });
        let s = render(&view, 1000.0, 120.0);
        assert_eq!(s, render(&view, 1000.0, 120.0));
        assert!(s.contains("2/4 done (50.0%)"), "{s}");
        assert!(s.contains("####################...................."), "{s}");
        assert!(s.contains("eta:"), "{s}");
        assert!(s.contains("running c@0.8"), "{s}");
        assert!(s.contains("STALE"), "{s}");
        assert!(s.contains("warning: 1 worker silent"), "{s}");
    }

    #[test]
    fn render_without_heartbeats_is_time_independent() {
        let mut view = JournalView { path: "j.jsonl".to_string(), ..Default::default() };
        view.plan_total = Some(4);
        assert_eq!(render(&view, 0.0, 120.0), render(&view, 1e9, 120.0));
        assert!(render(&view, 0.0, 120.0).contains("no heartbeats yet"));
    }
}
