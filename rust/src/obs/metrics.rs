//! Process-wide metric registry: counters, gauges, and fixed-bucket
//! log-scale histograms.
//!
//! Design constraints (they shape everything here):
//!
//! - **Allocation-free on the hot path.** Registration (`counter()`,
//!   `gauge()`, `histogram()`) allocates and takes a lock; *recording*
//!   into a handle is a handful of relaxed atomic ops on pre-sized
//!   storage.  The serve warm-path fingerprint test runs with metrics
//!   enabled, so any allocation sneaking into `record()` shows up as a
//!   moved scratch pointer or a bumped registration count.
//! - **Dependency-free.** No prometheus/metrics crates — the build is
//!   offline.  Snapshots serialise through [`crate::util::json`].
//! - **Mergeable.** Shard A's snapshot + shard B's snapshot must equal
//!   the snapshot of a registry that saw both streams (counters add,
//!   gauges keep the max, histogram buckets add) — `journal-merge` and
//!   multi-worker sweeps rely on this.
//!
//! Histogram buckets are log-scale with 8 sub-buckets per octave:
//! values 0..16 get exact unit buckets, and every value `v >= 16` lands
//! in a bucket of width `2^(floor_log2(v) - 3)`, so the reconstructed
//! quantile is within 6.25 % of the true value while the whole table
//! stays a fixed 496 slots (good to `u64::MAX` nanoseconds).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::export::{HistSnapshot, ObsSnapshot};

/// Total number of histogram buckets: 16 exact unit buckets for 0..16,
/// then 8 sub-buckets for each of the 60 octaves `2^4 ..= 2^63`.
pub const NBUCKETS: usize = 16 + 60 * 8;

/// Bucket index for a recorded value (total order, monotone in `v`).
pub(crate) fn bucket_index(v: u64) -> usize {
    if v < 16 {
        return v as usize;
    }
    let lg = 63 - v.leading_zeros() as usize; // floor(log2 v), 4..=63
    let sub = ((v >> (lg - 3)) & 7) as usize;
    16 + (lg - 4) * 8 + sub
}

/// Representative value for a bucket (midpoint; exact below 16).
pub(crate) fn bucket_value(i: usize) -> u64 {
    if i < 16 {
        return i as u64;
    }
    let lg = (i - 16) / 8 + 4;
    let sub = ((i - 16) % 8) as u64;
    let width = 1u64 << (lg - 3);
    let lower = (1u64 << lg) + sub * width;
    lower.saturating_add(width / 2)
}

/// Monotonically increasing event count.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    // lint: no-alloc
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    // lint: no-alloc
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Last-written / high-water value.  Snapshots merge gauges by `max`,
/// so prefer [`Gauge::set_max`] for values that should survive merging
/// (queue depths, widest batch, ...).
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    // lint: no-alloc
    pub fn set(&self, n: u64) {
        self.v.store(n, Ordering::Relaxed);
    }

    // lint: no-alloc
    pub fn set_max(&self, n: u64) {
        self.v.fetch_max(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Fixed-bucket log-scale histogram of `u64` samples (typically
/// nanoseconds).  Recording is five relaxed atomic ops, no allocation.
pub struct Histogram {
    buckets: [AtomicU64; NBUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    // lint: no-alloc
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Record a duration in nanoseconds (saturating past ~584 years).
    // lint: no-alloc
    pub fn record_ns(&self, d: Duration) {
        self.record(u64::try_from(d.as_nanos()).unwrap_or(u64::MAX));
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Point-in-time copy of the bucket table (sparse), suitable for
    /// quantile queries, merging, and JSON export.
    pub fn snapshot(&self) -> HistSnapshot {
        let count = self.count.load(Ordering::Relaxed);
        let mut buckets = BTreeMap::new();
        for (i, b) in self.buckets.iter().enumerate() {
            let c = b.load(Ordering::Relaxed);
            if c > 0 {
                buckets.insert(i, c);
            }
        }
        HistSnapshot {
            count,
            sum: self.sum.load(Ordering::Relaxed),
            min: if count == 0 { 0 } else { self.min.load(Ordering::Relaxed) },
            max: self.max.load(Ordering::Relaxed),
            buckets,
        }
    }
}

/// Named metric handles, get-or-create.  One global registry backs the
/// kernels/harness layers ([`crate::obs::global`]); the serve layer
/// gives each `SessionCtx` its own instance so per-session counters
/// stay isolated (and deterministic under parallel `cargo test`).
pub struct MetricRegistry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    hists: Mutex<BTreeMap<String, Arc<Histogram>>>,
    registrations: AtomicUsize,
}

impl Default for MetricRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricRegistry {
    pub const fn new() -> MetricRegistry {
        MetricRegistry {
            counters: Mutex::new(BTreeMap::new()),
            gauges: Mutex::new(BTreeMap::new()),
            hists: Mutex::new(BTreeMap::new()),
            registrations: AtomicUsize::new(0),
        }
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.counters.lock().unwrap();
        if let Some(c) = m.get(name) {
            return c.clone();
        }
        let c = Arc::new(Counter::default());
        m.insert(name.to_string(), c.clone());
        self.registrations.fetch_add(1, Ordering::Relaxed);
        c
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.gauges.lock().unwrap();
        if let Some(g) = m.get(name) {
            return g.clone();
        }
        let g = Arc::new(Gauge::default());
        m.insert(name.to_string(), g.clone());
        self.registrations.fetch_add(1, Ordering::Relaxed);
        g
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut m = self.hists.lock().unwrap();
        if let Some(h) = m.get(name) {
            return h.clone();
        }
        let h = Arc::new(Histogram::default());
        m.insert(name.to_string(), h.clone());
        self.registrations.fetch_add(1, Ordering::Relaxed);
        h
    }

    /// Number of metrics ever created in this registry.  Part of the
    /// serve warm-path fingerprint: a warm request must not register.
    pub fn registrations(&self) -> usize {
        self.registrations.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> ObsSnapshot {
        let mut snap = ObsSnapshot::default();
        for (k, c) in self.counters.lock().unwrap().iter() {
            snap.counters.insert(k.clone(), c.get());
        }
        for (k, g) in self.gauges.lock().unwrap().iter() {
            snap.gauges.insert(k.clone(), g.get());
        }
        for (k, h) in self.hists.lock().unwrap().iter() {
            snap.hists.insert(k.clone(), h.snapshot());
        }
        snap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_monotone_and_in_range() {
        let mut prev = 0usize;
        for k in 0..64 {
            for v in [(1u64 << k), (1u64 << k) + 1, (1u64 << k) + (1u64 << k) / 2] {
                let i = bucket_index(v);
                assert!(i < NBUCKETS, "v={v} i={i}");
                assert!(i >= prev, "bucket index not monotone at v={v}");
                prev = i;
            }
        }
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(15), 15);
        assert!(bucket_index(u64::MAX) < NBUCKETS);
    }

    #[test]
    fn bucket_value_round_trips_within_error() {
        for v in [0u64, 1, 7, 15, 16, 17, 100, 1_000, 123_456, 1 << 40] {
            let rep = bucket_value(bucket_index(v));
            let err = rep.abs_diff(v) as f64;
            assert!(err <= 1.0 + 0.0625 * v as f64, "v={v} rep={rep} err={err}");
        }
    }

    #[test]
    fn counter_gauge_basics() {
        let r = MetricRegistry::new();
        let c = r.counter("c");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = r.gauge("g");
        g.set(3);
        g.set_max(10);
        g.set_max(2);
        assert_eq!(g.get(), 10);
        // get-or-create returns the same handle; no new registration.
        let before = r.registrations();
        assert_eq!(r.counter("c").get(), 5);
        assert_eq!(r.registrations(), before);
    }

    #[test]
    fn histogram_tracks_extremes() {
        let h = Histogram::default();
        for v in [5u64, 100, 3] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 3);
        assert_eq!(s.sum, 108);
        assert_eq!(s.min, 3);
        assert_eq!(s.max, 100);
    }
}
