//! Schema-versioned JSON snapshots of the metric registry.
//!
//! A snapshot is the wire/disk form of [`super::metrics::MetricRegistry`]:
//! plain counters/gauges plus sparse histogram bucket tables.  Snapshots
//! are *mergeable* — `a.merge(&b)` is associative and commutative and
//! equals the snapshot of a registry that saw both sample streams — so
//! per-shard snapshots can be combined exactly like journal shards.
//!
//! The schema is versioned independently of the bench-telemetry schema:
//! [`OBS_SCHEMA_VERSION`] is stamped into every exported object and into
//! `BenchRecord.obs_schema`, so downstream tooling can tell which bucket
//! layout produced a given quantile.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::util::json::{self, Json};

use super::metrics::bucket_value;

/// Bucket-layout / field-set version of exported snapshots.
pub const OBS_SCHEMA_VERSION: u32 = 1;

/// Point-in-time copy of one histogram: totals plus the sparse bucket
/// table (`index -> count`, indices from `metrics::bucket_index`).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: u64,
    pub min: u64,
    pub max: u64,
    pub buckets: BTreeMap<usize, u64>,
}

impl HistSnapshot {
    /// Quantile by the same rank convention as `util::stats::summarize`
    /// (`rank = round((n-1) * q)`), reconstructed from bucket midpoints:
    /// exact below 16, within 6.25 % above.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((self.count - 1) as f64 * q).round() as u64;
        let mut cum = 0u64;
        for (&i, &c) in &self.buckets {
            cum += c;
            if cum > target {
                return bucket_value(i);
            }
        }
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Combine with another snapshot of the same metric (bucket counts
    /// add, extremes widen).  Associative and commutative.
    pub fn merge(&mut self, other: &HistSnapshot) {
        if other.count > 0 {
            self.min = if self.count == 0 { other.min } else { self.min.min(other.min) };
            self.max = self.max.max(other.max);
        }
        self.count += other.count;
        self.sum += other.sum;
        for (&i, &c) in &other.buckets {
            *self.buckets.entry(i).or_insert(0) += c;
        }
    }

    pub fn to_json(&self) -> Json {
        let buckets = self
            .buckets
            .iter()
            .map(|(&i, &c)| json::arr([json::num(i as f64), json::num(c as f64)]))
            .collect::<Vec<_>>();
        json::obj(vec![
            ("count", json::num(self.count as f64)),
            ("sum", json::num(self.sum as f64)),
            ("min", json::num(self.min as f64)),
            ("max", json::num(self.max as f64)),
            // Derived quantiles, for humans and dashboards; `parse`
            // ignores them (buckets are the source of truth).
            ("p50", json::num(self.quantile(0.5) as f64)),
            ("p90", json::num(self.quantile(0.9) as f64)),
            ("p99", json::num(self.quantile(0.99) as f64)),
            ("buckets", Json::Arr(buckets)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<HistSnapshot> {
        let field = |k: &str| -> u64 { v.get(k).and_then(Json::as_f64).unwrap_or(0.0) as u64 };
        let mut buckets = BTreeMap::new();
        if let Some(arr) = v.get("buckets").and_then(Json::as_arr) {
            for pair in arr {
                let (Some(i), Some(c)) = (
                    pair.idx(0).and_then(Json::as_usize),
                    pair.idx(1).and_then(Json::as_f64),
                ) else {
                    bail!("bad histogram bucket entry: {}", pair.to_string_pretty());
                };
                buckets.insert(i, c as u64);
            }
        }
        Ok(HistSnapshot {
            count: field("count"),
            sum: field("sum"),
            min: field("min"),
            max: field("max"),
            buckets,
        })
    }
}

/// A full registry snapshot: every counter, gauge and histogram by name.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ObsSnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, u64>,
    pub hists: BTreeMap<String, HistSnapshot>,
}

impl ObsSnapshot {
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.hists.is_empty()
    }

    /// Merge rule per kind: counters add, gauges keep the max (they are
    /// high-water marks on the wire), histograms merge bucket-wise.
    pub fn merge(&mut self, other: &ObsSnapshot) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            let e = self.gauges.entry(k.clone()).or_insert(0);
            *e = (*e).max(*v);
        }
        for (k, h) in &other.hists {
            self.hists.entry(k.clone()).or_default().merge(h);
        }
    }

    pub fn to_json(&self) -> Json {
        let counters =
            self.counters.iter().map(|(k, &v)| (k.clone(), json::num(v as f64))).collect();
        let gauges = self.gauges.iter().map(|(k, &v)| (k.clone(), json::num(v as f64))).collect();
        let hists = self.hists.iter().map(|(k, h)| (k.clone(), h.to_json())).collect();
        json::obj(vec![
            ("obs_schema", json::num(OBS_SCHEMA_VERSION as f64)),
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("hists", Json::Obj(hists)),
        ])
    }

    pub fn parse(v: &Json) -> Result<ObsSnapshot> {
        let schema = v.get("obs_schema").and_then(Json::as_usize).unwrap_or(0);
        if schema != OBS_SCHEMA_VERSION as usize {
            bail!("unsupported obs_schema {schema} (this build reads {OBS_SCHEMA_VERSION})");
        }
        let mut snap = ObsSnapshot::default();
        if let Some(m) = v.get("counters").and_then(Json::as_obj) {
            for (k, c) in m {
                snap.counters.insert(k.clone(), c.as_f64().unwrap_or(0.0) as u64);
            }
        }
        if let Some(m) = v.get("gauges").and_then(Json::as_obj) {
            for (k, g) in m {
                snap.gauges.insert(k.clone(), g.as_f64().unwrap_or(0.0) as u64);
            }
        }
        if let Some(m) = v.get("hists").and_then(Json::as_obj) {
            for (k, h) in m {
                snap.hists.insert(k.clone(), HistSnapshot::from_json(h)?);
            }
        }
        Ok(snap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_json_round_trips() {
        let mut snap = ObsSnapshot::default();
        snap.counters.insert("c".into(), 7);
        snap.gauges.insert("g".into(), 3);
        let mut h = HistSnapshot { count: 2, sum: 30, min: 10, max: 20, ..Default::default() };
        h.buckets.insert(10, 1);
        h.buckets.insert(17, 1);
        snap.hists.insert("h".into(), h);
        let text = snap.to_json().to_string_pretty();
        let re = ObsSnapshot::parse(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(snap, re);
    }

    #[test]
    fn parse_rejects_unknown_schema() {
        let v = json::obj(vec![("obs_schema", json::num(99.0))]);
        assert!(ObsSnapshot::parse(&v).is_err());
    }

    #[test]
    fn quantile_of_empty_is_zero() {
        assert_eq!(HistSnapshot::default().quantile(0.5), 0);
    }
}
