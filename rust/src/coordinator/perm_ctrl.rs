//! Permutation-hardening controller (Apdx C.2).
//!
//! The paper tracks each layer's soft-permutation penalty (Eqn. 14, Fig. 5)
//! and stops learning that layer's permutation — switching to hard
//! re-indexing — once the penalty crosses a threshold delta (Fig. 6 shows
//! the per-layer crossing epochs).  We normalise the raw penalty by the
//! permutation dimension N so a single delta works across layer widths
//! (the raw penalty scales ~linearly in N for doubly-stochastic matrices),
//! and debounce the decision over `patience` consecutive observations so a
//! single noisy step cannot harden a layer prematurely.

use crate::runtime::manifest::ModelEntry;

pub struct PermController {
    threshold: f64,
    patience: usize,
    below: Vec<usize>,
    hardened: Vec<bool>,
    n_sites: usize,
}

impl PermController {
    pub fn new(site_names: &[String], threshold: f64) -> PermController {
        PermController {
            threshold,
            patience: 3,
            below: vec![0; site_names.len()],
            hardened: vec![false; site_names.len()],
            n_sites: site_names.len(),
        }
    }

    /// Feed this step's raw per-site penalties; returns the sites to harden
    /// *now*.  Hardening is monotone: a hardened site is never revisited.
    pub fn observe(&mut self, _step: usize, penalties: &[f32], entry: &ModelEntry) -> Vec<usize> {
        assert_eq!(penalties.len(), self.n_sites);
        let mut fire = Vec::new();
        for (i, &p) in penalties.iter().enumerate() {
            if self.hardened[i] {
                continue;
            }
            let n = entry.sites[i].cols as f64;
            let norm = p as f64 / n;
            if norm < self.threshold {
                self.below[i] += 1;
                if self.below[i] >= self.patience {
                    self.hardened[i] = true;
                    fire.push(i);
                }
            } else {
                self.below[i] = 0;
            }
        }
        fire
    }

    pub fn is_hardened(&self, i: usize) -> bool {
        self.hardened[i]
    }

    pub fn n_hardened(&self) -> usize {
        self.hardened.iter().filter(|&&h| h).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::SiteSpec;

    fn entry(n_sites: usize) -> ModelEntry {
        ModelEntry {
            kind: "vit".into(),
            d_model: 64,
            n_layers: 1,
            n_heads: 1,
            d_ff: 64,
            seq_len: 4,
            vocab: 0,
            n_classes: 2,
            image: 8,
            patch: 4,
            params: vec![],
            sites: (0..n_sites)
                .map(|i| SiteSpec { name: format!("s{i}"), rows: 64, cols: 100 })
                .collect(),
        }
    }

    #[test]
    fn hardens_after_patience() {
        let e = entry(2);
        let names = vec!["s0".to_string(), "s1".to_string()];
        let mut c = PermController::new(&names, 0.22);
        // site 0 penalty below threshold (0.1*100=10 raw), site 1 above.
        for step in 0..2 {
            assert!(c.observe(step, &[10.0, 80.0], &e).is_empty());
        }
        let fired = c.observe(2, &[10.0, 80.0], &e);
        assert_eq!(fired, vec![0]);
        assert!(c.is_hardened(0) && !c.is_hardened(1));
        // Never fires twice.
        assert!(c.observe(3, &[10.0, 80.0], &e).is_empty());
        assert_eq!(c.n_hardened(), 1);
    }

    #[test]
    fn noisy_spike_resets_debounce() {
        let e = entry(1);
        let names = vec!["s0".to_string()];
        let mut c = PermController::new(&names, 0.22);
        assert!(c.observe(0, &[10.0], &e).is_empty());
        assert!(c.observe(1, &[10.0], &e).is_empty());
        assert!(c.observe(2, &[90.0], &e).is_empty()); // spike resets
        assert!(c.observe(3, &[10.0], &e).is_empty());
        assert!(c.observe(4, &[10.0], &e).is_empty());
        assert_eq!(c.observe(5, &[10.0], &e), vec![0]);
    }

    #[test]
    fn negative_threshold_never_fires() {
        let e = entry(1);
        let names = vec!["s0".to_string()];
        let mut c = PermController::new(&names, -1.0);
        for step in 0..10 {
            assert!(c.observe(step, &[0.0], &e).is_empty());
        }
    }
}
