//! L3 coordinator — the training orchestrator.
//!
//! This is the system half of the reproduction: the Rust process owns the
//! run lifecycle end to end.  Per step it assembles the flat input list for
//! the AOT `train_step` artifact from the named [`TrainState`], executes it
//! on PJRT, writes the outputs back, and consults two controllers:
//!
//! * the **DST scheduler** — fires the `dst_update` artifact every
//!   `dst_every` steps with RigL's cosine-decayed update fraction until
//!   `dst_end_frac` of the run (Evci et al. 2020);
//! * the **permutation-hardening controller**
//!   ([`perm::model::PermController`]) — tracks the per-layer AutoShuffle
//!   penalty, and when a layer's normalised penalty crosses the threshold
//!   delta the run's [`PermModel`](crate::perm::model::PermModel) decodes
//!   the soft matrix to a hard permutation (Hungarian), flips that
//!   layer's `hard_flags` entry, and the layer switches from an N x N
//!   matmul to re-indexing *without recompilation* (Apdx C.2).
//!
//! Python never runs here: the artifacts are self-contained HLO.

pub mod checkpoint;
pub mod sweep;

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use crate::data::{TaskData, TextTask, VisionTask};
use crate::harness::executor;
use crate::kernels::micro::Backend;
use crate::models::init_params;
use crate::perm::{self, model::{resolve_perm, PermController, PermHandle}, SinkhornScratch};
use crate::runtime::{Program, Runtime};
use crate::sparsity::dst::cosine_update_frac;
use crate::sparsity::pattern::{resolve_pattern, PatternHandle};
use crate::tensor::Tensor;
use crate::util::Rng;

/// Grow-signal selector for the unstructured baselines (`dst_update`'s
/// `grow_mode` input): RigL = |grad|, SET = random, MEST = mixed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GrowMode {
    RigL = 0,
    Set = 1,
    Mest = 2,
}

/// Full configuration of one training run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub model: String,
    /// The structure family object (trait dispatch for mask init, DST
    /// rule, validation, compression).  Resolve one from a spec string —
    /// `"diag"`, `"block:8"`, `"nm:2:8"` — via [`resolve_pattern`].
    pub pattern: PatternHandle,
    pub density: f64,
    /// The permutation model object (trait dispatch for state init,
    /// artifact selection, hardening, hard decode).  Resolve one from a
    /// spec string — `"learned"`, `"learned:sinkhorn=24:tau=0.5"`,
    /// `"random:seed=7"`, `"none"` — via [`resolve_perm`].
    pub perm: PermHandle,
    pub steps: usize,
    pub lr: f32,
    /// Penalty weight lambda (Eqn. 13).
    pub lambda: f32,
    /// DST cadence (Delta T); 0 disables mask updates.
    pub dst_every: usize,
    /// Stop DST after this fraction of the run (RigL's T_end).
    pub dst_end_frac: f64,
    /// Initial drop fraction for the cosine schedule.
    pub dst_frac0: f64,
    pub grow_mode: GrowMode,
    /// Normalised-penalty threshold for hardening; <0 disables.  A
    /// `threshold=` param on the perm spec wins over this default.
    pub harden_threshold: f64,
    /// Hardening debounce: consecutive below-threshold observations
    /// before a site hardens.  A `patience=` param on the perm spec wins.
    pub harden_patience: usize,
    pub eval_every: usize,
    pub seed: u64,
    pub verbose: bool,
    /// Worker-thread budget (0 = auto, 1 = serial).  Propagated to the
    /// `Runtime` and honoured by the native parallel-kernel paths;
    /// artifact execution runs under PJRT's own pool until the intra-op
    /// wiring lands (ROADMAP).
    pub threads: usize,
    /// Microkernel backend for the native kernel paths.  Resolution order:
    /// CLI `--backend`, else a spec-level backend, else `PADST_BACKEND`,
    /// else a tuning-table choice ([`crate::kernels::tune`]), else tiled —
    /// the first three pin the backend so the tuner never overrides an
    /// explicit selection.  Propagated to the `Runtime` alongside
    /// `threads`; artifact execution is backend-blind.
    pub backend: Backend,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "vit_tiny".into(),
            pattern: resolve_pattern("diag").expect("default pattern spec"),
            density: 0.1,
            perm: resolve_perm("learned").expect("default perm spec"),
            steps: 200,
            lr: 1e-3,
            lambda: 5e-3,
            dst_every: 25,
            dst_end_frac: 0.75,
            dst_frac0: 0.3,
            grow_mode: GrowMode::RigL,
            harden_threshold: 0.22,
            harden_patience: perm::model::DEFAULT_PATIENCE,
            eval_every: 50,
            seed: 0,
            verbose: false,
            threads: 0,
            backend: Backend::default_backend(),
        }
    }
}

/// Metrics of one finished run (Fig. 2 points, Fig. 4–6 series).
#[derive(Clone, Debug, Default)]
pub struct RunResult {
    pub losses: Vec<f32>,
    pub eval_losses: Vec<(usize, f32)>,
    pub eval_accs: Vec<(usize, f32)>,
    /// Per-site penalty history, sampled every step: `[site][step]`.
    pub penalties: Vec<Vec<f32>>,
    /// Step at which each site hardened (None = never; Fig. 6).
    pub harden_step: Vec<Option<usize>>,
    /// delta(P) identity distance per site at the end (Fig. 4).
    pub identity_distance: Vec<f64>,
    pub site_names: Vec<String>,
    /// Compiled DST updates rejected (mask left the pattern's family or
    /// broke the budget) and rolled back.  Nonzero throughout a run means
    /// DST effectively never applied — expected when a parameterised spec
    /// (e.g. `nm:1:4`) runs against a family-default `dst_update` artifact.
    pub dst_rejected: usize,
    pub train_seconds: f64,
    pub final_eval_loss: f32,
    pub final_eval_acc: f32,
    /// exp(eval loss) — perplexity for LM runs.
    pub final_ppl: f32,
}

/// Named buffer store for the run: every artifact input that persists
/// across steps lives here, keyed by its manifest name.
pub struct TrainState {
    pub vals: HashMap<String, Tensor>,
    pub site_names: Vec<String>,
    /// Per-site nnz budget fixed at init; DST must preserve it exactly.
    pub budgets: Vec<usize>,
}

enum Task {
    Vision(VisionTask),
    Text(TextTask),
}

impl Task {
    fn next_train(&mut self, x: &mut Tensor, y: &mut Tensor) {
        match self {
            Task::Vision(t) => t.next_train(x, y),
            Task::Text(t) => t.next_train(x, y),
        }
    }
    fn eval_batch(&self, i: usize, x: &mut Tensor, y: &mut Tensor) {
        match self {
            Task::Vision(t) => t.eval_batch(i, x, y),
            Task::Text(t) => t.eval_batch(i, x, y),
        }
    }
    fn n_eval_batches(&self) -> usize {
        match self {
            Task::Vision(t) => t.n_eval_batches(),
            Task::Text(t) => t.n_eval_batches(),
        }
    }
}

/// The trainer: one run = one `Trainer::run` call.  Compiled programs are
/// cached in the shared [`Runtime`], so sweeps amortise compile time.
pub struct Trainer<'rt> {
    rt: &'rt mut Runtime,
    cfg: RunConfig,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt mut Runtime, cfg: RunConfig) -> Trainer<'rt> {
        // The run's thread budget and backend win over whatever the
        // runtime was opened with, so sweep cells with different
        // --threads/--backend behave as asked.
        rt.set_threads(cfg.threads);
        rt.set_backend(cfg.backend);
        Trainer { rt, cfg }
    }

    fn train_artifact(&self) -> String {
        format!("{}_train{}", self.cfg.model, self.cfg.perm.artifact_suffix())
    }

    /// DST artifacts are compiled per *family* with the default template
    /// (the AOT export predates parameterised specs), so a typed spec runs
    /// the family-default update: outputs that violate the typed geometry
    /// are rejected by `validate_masks` and rolled back (counted in
    /// [`RunResult::dst_rejected`]).  Warn up front so a sweep over e.g.
    /// `nm:1:4` is never silently mistaken for spec-true DST.
    fn dst_artifact(&self) -> Option<String> {
        if self.cfg.dst_every == 0 || !self.cfg.pattern.is_dynamic() {
            return None;
        }
        let family = self.cfg.pattern.family().name();
        if self.cfg.pattern.spec() != family {
            eprintln!(
                "[dst] pattern {} uses the family-default `{}_dst_{family}` artifact; \
                 updates that leave the {} geometry are rolled back (see dst_rejected)",
                self.cfg.pattern.spec(),
                self.cfg.model,
                self.cfg.pattern.spec()
            );
        }
        Some(format!("{}_dst_{family}", self.cfg.model))
    }

    /// Build the initial state: params (host init), Adam zeros, masks from
    /// the structure family, permutation state per mode.
    pub fn init_state(&mut self) -> Result<TrainState> {
        let cfg = &self.cfg;
        let entry = self
            .rt
            .manifest
            .models
            .get(&cfg.model)
            .ok_or_else(|| anyhow!("model {:?} not in manifest", cfg.model))?
            .clone();
        let mut vals = HashMap::new();
        let mut rng = Rng::new(cfg.seed);

        for (name, t) in init_params(&entry, cfg.seed) {
            vals.insert(format!("adam_m.{name}"), Tensor::zeros(&t.shape));
            vals.insert(format!("adam_v.{name}"), Tensor::zeros(&t.shape));
            vals.insert(format!("param.{name}"), t);
        }
        vals.insert("step".into(), Tensor::scalar(0.0));

        let mut site_names = Vec::new();
        let mut budgets = Vec::new();
        for site in &entry.sites {
            site_names.push(site.name.clone());
            let mut mrng = rng.fork(site_names.len() as u64);
            let mask = cfg
                .pattern
                .init_mask(site.rows, site.cols, cfg.density, &mut mrng)
                .map_err(|e| anyhow!("site {:?}: {e}", site.name))?;
            budgets.push(mask.nnz());
            vals.insert(
                format!("mask.{}", site.name),
                Tensor::from_f32(&[site.rows, site.cols], mask.bits),
            );
        }

        // Permutation state (present for every mode; the noperm train
        // artifact simply doesn't consume it, but eval/dst do).  The
        // typed per-site state machine owns init + export; bare-name
        // specs reproduce the historical RNG stream bit-identically
        // (pinned by the perm model test suite).
        let n_sites = entry.sites.len();
        let mut flags = Vec::with_capacity(n_sites);
        for (si, site) in entry.sites.iter().enumerate() {
            let ps = cfg.perm.init_site(si, &site.name, site.cols, &mut rng);
            flags.push(ps.hard_flag());
            ps.export_into(&mut vals);
        }
        vals.insert("hard_flags".into(), Tensor::from_f32(&[n_sites], flags));

        Ok(TrainState { vals, site_names, budgets })
    }

    fn make_task(&self) -> Result<Task> {
        let entry = &self.rt.manifest.models[&self.cfg.model];
        Ok(match entry.kind.as_str() {
            "gpt" => Task::Text(TextTask::new(entry.vocab, entry.seq_len, self.cfg.seed ^ 0xD)),
            "vit" | "mixer" => {
                Task::Vision(VisionTask::new(entry.image, entry.n_classes, self.cfg.seed ^ 0xD))
            }
            k => bail!("unknown model kind {k:?}"),
        })
    }

    /// Assemble the flat input list for `prog` from state + per-call extras.
    fn gather_inputs(
        prog: &Program,
        state: &TrainState,
        extras: &HashMap<&str, Tensor>,
    ) -> Result<Vec<Tensor>> {
        prog.spec
            .inputs
            .iter()
            .map(|spec| {
                if let Some(t) = extras.get(spec.name.as_str()) {
                    Ok(t.clone())
                } else if let Some(t) = state.vals.get(&spec.name) {
                    Ok(t.clone())
                } else {
                    Err(anyhow!("no value for input {:?}", spec.name))
                }
            })
            .collect()
    }

    /// Write a program's outputs back into the state (by matching names).
    fn scatter_outputs(prog: &Program, state: &mut TrainState, outs: Vec<Tensor>) {
        for (t, spec) in outs.into_iter().zip(&prog.spec.outputs) {
            if state.vals.contains_key(&spec.name) {
                state.vals.insert(spec.name.clone(), t);
            }
        }
    }

    /// Run the full training loop; returns metrics.
    pub fn run(&mut self) -> Result<RunResult> {
        let cfg = self.cfg.clone();
        let entry = self.rt.manifest.models[&cfg.model].clone();
        let batch = self.rt.manifest.batch;
        let train_prog = self.rt.program(&self.train_artifact())?;
        let eval_prog = self.rt.program(&format!("{}_eval", cfg.model))?;
        let dst_prog: Option<Rc<Program>> = match self.dst_artifact() {
            Some(name) => Some(self.rt.program(&name)?),
            None => None,
        };

        let mut state = self.init_state()?;
        let mut task = self.make_task()?;
        // Hardening knobs: the spec's typed params win over the config
        // defaults; a mode without hardening (none/random) never fires.
        let hardening = cfg.perm.hardening();
        let threshold = hardening
            .and_then(|h| h.threshold)
            .unwrap_or(cfg.harden_threshold);
        let patience = hardening
            .and_then(|h| h.patience)
            .unwrap_or(cfg.harden_patience);
        let widths: Vec<usize> = entry.sites.iter().map(|s| s.cols).collect();
        let mut ctrl = PermController::new(&widths, threshold, patience);
        let mut scratch = SinkhornScratch::new();

        let (mut bx, mut by) = make_batch_buffers(&entry, batch);
        let mut result = RunResult {
            penalties: vec![Vec::new(); state.site_names.len()],
            harden_step: vec![None; state.site_names.len()],
            site_names: state.site_names.clone(),
            ..Default::default()
        };

        let learned = cfg.perm.learns();
        let dst_until = (cfg.steps as f64 * cfg.dst_end_frac) as usize;
        let t0 = std::time::Instant::now();

        for step in 0..cfg.steps {
            task.next_train(&mut bx, &mut by);
            let mut extras: HashMap<&str, Tensor> = HashMap::new();
            extras.insert("batch_x", bx.clone());
            extras.insert("batch_y", by.clone());
            extras.insert("lr", Tensor::scalar(cfg.lr));
            extras.insert("lambda", Tensor::scalar(cfg.lambda));
            let inputs = Self::gather_inputs(&train_prog, &state, &extras)?;
            let outs = train_prog.run(&inputs)?;

            let loss = outs[train_prog.output_index("loss")?].f32s()[0];
            let pen_idx = train_prog.output_index("penalties").ok();
            if let Some(pi) = pen_idx {
                let pens = outs[pi].f32s().to_vec();
                for (s, &p) in pens.iter().enumerate() {
                    result.penalties[s].push(p);
                }
                // Hardening decisions (only when learning permutations).
                if learned && threshold >= 0.0 {
                    let decisions = ctrl.observe(step, &pens);
                    for site_i in decisions {
                        self.harden_site(&mut state, &entry, site_i, &mut scratch)?;
                        result.harden_step[site_i] = Some(step);
                        if cfg.verbose {
                            eprintln!(
                                "[harden] step {step}: {}",
                                state.site_names[site_i]
                            );
                        }
                    }
                }
            }
            result.losses.push(loss);
            Self::scatter_outputs(&train_prog, &mut state, outs);

            // DST prune-and-grow on the RigL cadence.
            if let Some(dp) = &dst_prog {
                if cfg.dst_every > 0
                    && step > 0
                    && step % cfg.dst_every == 0
                    && step <= dst_until
                {
                    let frac = cosine_update_frac(step, cfg.steps, cfg.dst_frac0);
                    task.next_train(&mut bx, &mut by);
                    let mut ex: HashMap<&str, Tensor> = HashMap::new();
                    ex.insert("batch_x", bx.clone());
                    ex.insert("batch_y", by.clone());
                    ex.insert("frac", Tensor::scalar(frac as f32));
                    ex.insert(
                        "grow_mode",
                        Tensor::scalar_i32(cfg.grow_mode as i32),
                    );
                    ex.insert("seed", Tensor::scalar_i32((cfg.seed as i32) ^ step as i32));
                    let inputs = Self::gather_inputs(dp, &state, &ex)?;
                    // Snapshot: the xla_extension 0.5.1 runtime is known to
                    // miscompile parts of the prune/grow graph for some
                    // layer geometries (EXPERIMENTS.md bug log).  If the
                    // returned masks violate the structure family or the
                    // nnz budget we roll the whole DST transaction back and
                    // continue training on the previous masks.
                    let snapshot: Vec<(String, Tensor)> = dp
                        .spec
                        .outputs
                        .iter()
                        .filter_map(|s| {
                            state.vals.get(&s.name).map(|t| (s.name.clone(), t.clone()))
                        })
                        .collect();
                    let outs = dp.run(&inputs)?;
                    Self::scatter_outputs(dp, &mut state, outs);
                    if let Err(e) = self.validate_masks(&state) {
                        result.dst_rejected += 1;
                        if cfg.verbose {
                            eprintln!(
                                "[dst] step {step}: rejected compiled update ({e}); rolled back"
                            );
                        }
                        for (k, t) in snapshot {
                            state.vals.insert(k, t);
                        }
                    }
                }
            }

            if cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0 {
                let (el, ea) = self.evaluate(&eval_prog, &state, &task, &entry, batch)?;
                result.eval_losses.push((step + 1, el));
                result.eval_accs.push((step + 1, ea));
                if cfg.verbose {
                    eprintln!(
                        "[train] step {:>5} loss {:.4} eval_loss {:.4} eval_acc {:.3}",
                        step + 1,
                        loss,
                        el,
                        ea
                    );
                }
            }
        }
        result.train_seconds = t0.elapsed().as_secs_f64();

        let (el, ea) = self.evaluate(&eval_prog, &state, &task, &entry, batch)?;
        result.final_eval_loss = el;
        result.final_eval_acc = ea;
        result.final_ppl = el.exp();

        // Fig. 4: identity distance of the final permutations.  For sites
        // still in the soft regime, decode the current soft matrix (what
        // hardening *would* produce) so the metric reflects the learned
        // shuffle rather than the untouched identity index map.  The
        // per-site Sinkhorn + Hungarian decodes are independent, so they
        // fan out over the harness executor under the run's `--threads`
        // budget, one reusable `SinkhornScratch` per worker; results merge
        // in site order, so the output is identical at any worker count.
        let site_ids: Vec<usize> = (0..state.site_names.len()).collect();
        let workers = executor::resolve_workers(cfg.threads, site_ids.len());
        let state_ref = &state;
        let entry_ref = &entry;
        let cfg_ref = &cfg;
        result.identity_distance = executor::execute_sharded(
            &site_ids,
            workers,
            |_wid| Ok(SinkhornScratch::new()),
            |scratch, _slot, &i| {
                let site = &state_ref.site_names[i];
                let hardened = state_ref.vals["hard_flags"].f32s()[i] > 0.5;
                let stored_idx = || -> Vec<usize> {
                    state_ref.vals[&format!("perm_idx.{site}")]
                        .i32s()
                        .iter()
                        .map(|&x| x as usize)
                        .collect()
                };
                let idx: Vec<usize> = if hardened {
                    stored_idx()
                } else {
                    let n = entry_ref.sites[i].cols;
                    let logits = state_ref.vals[&format!("perm_logits.{site}")].f32s();
                    cfg_ref
                        .perm
                        .decode_logits(logits, n, scratch)
                        .unwrap_or_else(stored_idx)
                };
                Ok(perm::identity_distance(&idx))
            },
        )?;
        Ok(result)
    }

    /// Decode site `site_i`'s soft permutation to a hard index map and flip
    /// its hard flag (the Apdx C.2 early-stop).  Modes without a decodable
    /// soft matrix (kaleidoscope: the K-matrix is not a pure permutation;
    /// the comparator only measures overhead) keep their identity index
    /// map and just flip the flag.
    fn harden_site(
        &self,
        state: &mut TrainState,
        entry: &crate::runtime::manifest::ModelEntry,
        site_i: usize,
        scratch: &mut SinkhornScratch,
    ) -> Result<()> {
        let site = &entry.sites[site_i];
        let name = state.site_names[site_i].clone();
        let n = site.cols;
        let decoded = {
            let logits = state.vals[&format!("perm_logits.{name}")].f32s();
            self.cfg.perm.decode_logits(logits, n, scratch)
        };
        if let Some(idx) = decoded {
            state.vals.insert(
                format!("perm_idx.{name}"),
                Tensor::from_i32(&[n], idx.iter().map(|&i| i as i32).collect()),
            );
        }
        let flags = state.vals.get_mut("hard_flags").unwrap();
        flags.f32s_mut()[site_i] = 1.0;
        Ok(())
    }

    fn validate_masks(&self, state: &TrainState) -> Result<()> {
        for (i, name) in state.site_names.iter().enumerate() {
            let t = &state.vals[&format!("mask.{name}")];
            let mask = crate::sparsity::patterns::Mask {
                rows: t.shape[0],
                cols: t.shape[1],
                bits: t.f32s().to_vec(),
            };
            self.cfg
                .pattern
                .validate(&mask)
                .map_err(|e| anyhow!("mask {name} left its family after DST: {e}"))?;
            // DST must preserve the nnz budget fixed at init exactly.
            let want = state.budgets[i];
            if mask.nnz() != want {
                bail!("mask {name} budget changed after DST: {} != {want}", mask.nnz());
            }
        }
        Ok(())
    }

    fn evaluate(
        &self,
        eval_prog: &Program,
        state: &TrainState,
        task: &Task,
        entry: &crate::runtime::manifest::ModelEntry,
        batch: usize,
    ) -> Result<(f32, f32)> {
        let (mut bx, mut by) = make_batch_buffers(entry, batch);
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut total = 0usize;
        for i in 0..task.n_eval_batches() {
            task.eval_batch(i, &mut bx, &mut by);
            let mut ex: HashMap<&str, Tensor> = HashMap::new();
            ex.insert("batch_x", bx.clone());
            ex.insert("batch_y", by.clone());
            let inputs = Self::gather_inputs(eval_prog, state, &ex)?;
            let outs = eval_prog.run(&inputs)?;
            loss_sum += outs[eval_prog.output_index("loss")?].f32s()[0] as f64;
            correct += outs[eval_prog.output_index("correct")?].f32s()[0] as f64;
            total += by.numel();
        }
        let n = task.n_eval_batches() as f64;
        Ok(((loss_sum / n) as f32, (correct / total as f64) as f32))
    }
}

/// Allocate (batch_x, batch_y) tensors of the right shape/dtype for a model.
pub fn make_batch_buffers(
    entry: &crate::runtime::manifest::ModelEntry,
    batch: usize,
) -> (Tensor, Tensor) {
    if entry.kind == "gpt" {
        (
            Tensor::zeros_i32(&[batch, entry.seq_len]),
            Tensor::zeros_i32(&[batch, entry.seq_len]),
        )
    } else {
        (
            Tensor::zeros(&[batch, entry.image, entry.image, 3]),
            Tensor::zeros_i32(&[batch]),
        )
    }
}
