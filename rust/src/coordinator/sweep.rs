//! Sweep runner — regenerates the accuracy-vs-sparsity grids (Fig. 2,
//! Tbl. 11/12) on the synthetic tasks, and the row-vs-col ablation
//! (Tbl. 10 is exercised at the artifact level: the L2 graph supports
//! both; the exported artifacts use column permutations, matching the
//! paper's main results).
//!
//! A "method" is (structure, perm_mode, grow_mode) — e.g. RigL is
//! (unstructured, none, RigL); DynaDiag+PA-DST is (diag, learned, RigL).
//! The same compiled artifacts are reused across every cell of the grid,
//! so one process sweeps the whole table paying each compile once.

use anyhow::Result;

use super::{GrowMode, RunConfig, RunResult, Trainer};
use crate::runtime::Runtime;
use crate::sparsity::patterns::Structure;

/// One method row of Fig. 2 / Tbl. 11–12.
#[derive(Clone, Debug)]
pub struct Method {
    pub name: &'static str,
    pub structure: Structure,
    pub perm_mode: &'static str,
    pub grow_mode: GrowMode,
}

/// The paper's method zoo, mapped onto this testbed.
pub const METHODS: &[Method] = &[
    // Unstructured DST baselines (upper accuracy bound).
    Method { name: "RigL", structure: Structure::Unstructured, perm_mode: "none", grow_mode: GrowMode::RigL },
    Method { name: "SET", structure: Structure::Unstructured, perm_mode: "none", grow_mode: GrowMode::Set },
    Method { name: "MEST", structure: Structure::Unstructured, perm_mode: "none", grow_mode: GrowMode::Mest },
    // Structured DST without permutations.
    Method { name: "DynaDiag", structure: Structure::Diag, perm_mode: "none", grow_mode: GrowMode::RigL },
    Method { name: "SRigL", structure: Structure::NM, perm_mode: "none", grow_mode: GrowMode::RigL },
    Method { name: "DSB", structure: Structure::Block, perm_mode: "none", grow_mode: GrowMode::RigL },
    Method { name: "PixelatedBFly", structure: Structure::Butterfly, perm_mode: "none", grow_mode: GrowMode::RigL },
    // + fixed random permutations (Tbl. 11 'Random' rows).
    Method { name: "DynaDiag+Rand", structure: Structure::Diag, perm_mode: "random", grow_mode: GrowMode::RigL },
    Method { name: "SRigL+Rand", structure: Structure::NM, perm_mode: "random", grow_mode: GrowMode::RigL },
    Method { name: "DSB+Rand", structure: Structure::Block, perm_mode: "random", grow_mode: GrowMode::RigL },
    // + learned permutations (PA-DST, the paper's contribution).
    Method { name: "DynaDiag+PA", structure: Structure::Diag, perm_mode: "learned", grow_mode: GrowMode::RigL },
    Method { name: "SRigL+PA", structure: Structure::NM, perm_mode: "learned", grow_mode: GrowMode::RigL },
    Method { name: "DSB+PA", structure: Structure::Block, perm_mode: "learned", grow_mode: GrowMode::RigL },
    Method { name: "PBFly+PA", structure: Structure::Butterfly, perm_mode: "learned", grow_mode: GrowMode::RigL },
    // Dense reference.
    Method { name: "Dense", structure: Structure::Dense, perm_mode: "none", grow_mode: GrowMode::RigL },
];

pub fn method_by_name(name: &str) -> Option<&'static Method> {
    METHODS.iter().find(|m| m.name == name)
}

#[derive(Clone, Debug)]
pub struct SweepCell {
    pub method: &'static str,
    pub sparsity: f64,
    pub result: RunResult,
}

/// Run `methods` x `sparsities` on `model`; returns all cells.  `threads`
/// is the per-run worker budget (0 = auto), recorded on every cell's
/// `RunConfig` and pushed to the shared `Runtime` so all cells advertise
/// the same budget.  Note: artifact execution currently runs under PJRT's
/// own thread pool (intra-op wiring is a ROADMAP item); today the knob
/// governs the native parallel-kernel paths.
#[allow(clippy::too_many_arguments)]
pub fn run_sweep(
    rt: &mut Runtime,
    model: &str,
    methods: &[&'static Method],
    sparsities: &[f64],
    steps: usize,
    seed: u64,
    verbose: bool,
    threads: usize,
) -> Result<Vec<SweepCell>> {
    let mut cells = Vec::new();
    for m in methods {
        for &sp in sparsities {
            let density = if m.structure == Structure::Dense { 1.0 } else { 1.0 - sp };
            let cfg = RunConfig {
                model: model.to_string(),
                structure: m.structure,
                density,
                perm_mode: m.perm_mode.to_string(),
                steps,
                grow_mode: m.grow_mode,
                seed,
                verbose,
                threads,
                ..Default::default()
            };
            let mut tr = Trainer::new(rt, cfg);
            let result = tr.run()?;
            if verbose {
                eprintln!(
                    "[sweep] {:<14} s={:.0}% loss={:.4} acc={:.3} ppl={:.2} ({:.1}s)",
                    m.name,
                    sp * 100.0,
                    result.final_eval_loss,
                    result.final_eval_acc,
                    result.final_ppl,
                    result.train_seconds
                );
            }
            cells.push(SweepCell { method: m.name, sparsity: sp, result });
            if m.structure == Structure::Dense {
                break; // dense has no sparsity axis
            }
        }
    }
    Ok(cells)
}

/// Print the Fig. 2 / Tbl. 11-style grid: rows = methods, cols = sparsity.
pub fn print_table(model: &str, kind: &str, cells: &[SweepCell], sparsities: &[f64]) {
    let metric = if kind == "gpt" { "ppl" } else { "acc" };
    println!("\n=== {model}: {metric} vs sparsity (paper Fig. 2 / Tbl. 11-12 analogue) ===");
    print!("{:<16}", "method");
    for &s in sparsities {
        print!("{:>10}", format!("{:.0}%", s * 100.0));
    }
    println!();
    let mut methods: Vec<&str> = Vec::new();
    for c in cells {
        if !methods.contains(&c.method) {
            methods.push(c.method);
        }
    }
    for m in methods {
        print!("{m:<16}");
        for &s in sparsities {
            let cell = cells
                .iter()
                .find(|c| c.method == m && (c.sparsity - s).abs() < 1e-9);
            match cell {
                Some(c) => {
                    let v = if kind == "gpt" { c.result.final_ppl } else { c.result.final_eval_acc };
                    print!("{v:>10.3}");
                }
                None => print!("{:>10}", "-"),
            }
        }
        println!();
    }
}

/// CSV dump of all cells for downstream plotting.
pub fn write_csv(path: &std::path::Path, cells: &[SweepCell]) -> Result<()> {
    let mut s = String::from("method,sparsity,final_eval_loss,final_eval_acc,final_ppl,train_seconds\n");
    for c in cells {
        s.push_str(&format!(
            "{},{},{},{},{},{}\n",
            c.method,
            c.sparsity,
            c.result.final_eval_loss,
            c.result.final_eval_acc,
            c.result.final_ppl,
            c.result.train_seconds
        ));
    }
    std::fs::write(path, s)?;
    Ok(())
}
