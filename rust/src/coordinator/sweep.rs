//! Sweep runner — regenerates the accuracy-vs-sparsity grids (Fig. 2,
//! Tbl. 11/12) on the synthetic tasks, and the row-vs-col ablation
//! (Tbl. 10 is exercised at the artifact level: the L2 graph supports
//! both; the exported artifacts use column permutations, matching the
//! paper's main results).
//!
//! A "method" is (pattern spec, perm spec, grow_mode) — e.g. RigL is
//! (unstructured, none, RigL); DynaDiag+PA-DST is (diag, learned, RigL).
//! Both spec axes resolve through their registries, so parameterised
//! forms (`block:4`, `learned:sinkhorn=24`) are first-class grid rows,
//! and [`cross_perms`] crosses a method list with a perm list
//! (`--perms learned,none,random`) into one journal-compatible grid.
//!
//! Two execution paths produce identical cells:
//!
//! * [`run_sweep`] — sequential against one shared `Runtime`, so every
//!   cell reuses the same compiled-program cache (one compile per
//!   artifact for the whole grid).
//! * [`run_sweep_sharded`] — the (method x sparsity) grid fanned out on
//!   the harness executor, **each worker owning its own `Runtime`**
//!   (cells are independent given separate runtimes; runtimes are not
//!   `Send`, so each is created inside its worker thread).  The global
//!   `threads` budget is divided across workers so total parallelism
//!   stays bounded, results merge back in grid order (bit-identical
//!   ordering to the sequential path), and completed cells checkpoint to
//!   a JSONL journal so an interrupted sweep resumes without
//!   recomputation.

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::{GrowMode, RunConfig, RunResult, Trainer};
use crate::harness::executor;
use crate::harness::shard::{in_shard, plan_cells, CellKey, Journal, META_KEY};
use crate::kernels::micro::Backend;
use crate::obs::watch::{now_unix, Heartbeat, HEARTBEAT_KEY, PLAN_KEY};
use crate::perm::model::resolve_perm;
use crate::runtime::Runtime;
use crate::sparsity::pattern::resolve_pattern;
use crate::util::cli::resolve_threads;
use crate::util::json::{self, Json};

/// One method row of Fig. 2 / Tbl. 11–12: a pattern spec (resolved through
/// the `PatternRegistry` — bare family names or parameterised forms like
/// `"block:8"`) plus the permutation spec (`PermRegistry`) and grow rule.
#[derive(Clone, Debug, PartialEq)]
pub struct Method {
    pub name: String,
    /// Pattern spec string — the structure axis of the grid.
    pub pattern: String,
    /// Perm spec string — the permutation axis of the grid.
    pub perm: String,
    pub grow_mode: GrowMode,
}

impl Method {
    fn zoo(name: &str, pattern: &str, perm: &str, grow_mode: GrowMode) -> Method {
        Method {
            name: name.to_string(),
            pattern: pattern.to_string(),
            perm: perm.to_string(),
            grow_mode,
        }
    }

    /// Dense reference cells collapse the sparsity axis.
    pub fn is_dense(&self) -> bool {
        self.pattern == "dense"
    }
}

/// The paper's method zoo, mapped onto this testbed.  Pattern specs are
/// the bare family names, so journals from before the registry still
/// fingerprint-match.
pub fn methods() -> &'static [Method] {
    static ZOO: OnceLock<Vec<Method>> = OnceLock::new();
    ZOO.get_or_init(|| {
        vec![
            // Unstructured DST baselines (upper accuracy bound).
            Method::zoo("RigL", "unstructured", "none", GrowMode::RigL),
            Method::zoo("SET", "unstructured", "none", GrowMode::Set),
            Method::zoo("MEST", "unstructured", "none", GrowMode::Mest),
            // Structured DST without permutations.
            Method::zoo("DynaDiag", "diag", "none", GrowMode::RigL),
            Method::zoo("SRigL", "nm", "none", GrowMode::RigL),
            Method::zoo("DSB", "block", "none", GrowMode::RigL),
            Method::zoo("PixelatedBFly", "butterfly", "none", GrowMode::RigL),
            // + fixed random permutations (Tbl. 11 'Random' rows).
            Method::zoo("DynaDiag+Rand", "diag", "random", GrowMode::RigL),
            Method::zoo("SRigL+Rand", "nm", "random", GrowMode::RigL),
            Method::zoo("DSB+Rand", "block", "random", GrowMode::RigL),
            // + learned permutations (PA-DST, the paper's contribution).
            Method::zoo("DynaDiag+PA", "diag", "learned", GrowMode::RigL),
            Method::zoo("SRigL+PA", "nm", "learned", GrowMode::RigL),
            Method::zoo("DSB+PA", "block", "learned", GrowMode::RigL),
            Method::zoo("PBFly+PA", "butterfly", "learned", GrowMode::RigL),
            // Dense reference.
            Method::zoo("Dense", "dense", "none", GrowMode::RigL),
        ]
    })
}

/// Resolve a method name — a zoo entry, a pattern spec (`"block:4"`,
/// `"nm:1:4"`, or any bare family name not shadowed by a zoo entry), which
/// synthesizes a structured-DST method (no permutation, RigL grow), or a
/// crossed form `"<method>+<perm spec>"` (what [`cross_perms`] names its
/// rows, so journaled crossed cells re-resolve on resume).  This is what
/// makes pattern and perm hyper-params first-class grid axes:
/// `--methods RigL,block:4,block:8` sweeps block sizes,
/// `--methods block:4+learned,block:4+none` sweeps perm treatments.  A
/// name that is none of these keeps the registry's descriptive parse
/// error (`nm:3:2` reports "N <= M", not just "unknown method").
pub fn resolve_method(name: &str) -> Result<Method> {
    if let Some(m) = methods().iter().find(|m| m.name == name) {
        return Ok(m.clone());
    }
    let pattern_err = match resolve_pattern(name) {
        Ok(p) => return Ok(Method::zoo(name, &p.spec(), "none", GrowMode::RigL)),
        Err(e) => e,
    };
    // Crossed form: split at the rightmost '+' whose left side is itself a
    // method and right side a perm spec (zoo names like "DynaDiag+PA" were
    // matched above, so this never shadows them).  A resolvable base with
    // a broken perm spec keeps the perm registry's descriptive error —
    // not the irrelevant pattern-parse error for the full string.
    if let Some((base, perm)) = name.rsplit_once('+') {
        if let Ok(mut m) = resolve_method(base) {
            let ph = resolve_perm(perm).map_err(|e| {
                anyhow!("{name:?}: {base:?} is a method, but the perm side is invalid: {e}")
            })?;
            m.name = name.to_string();
            m.perm = ph.spec();
            return Ok(m);
        }
    }
    Err(anyhow!(
        "{name:?} is not a sweep method ({}), a pattern spec, or a method+perm cross: \
         {pattern_err}",
        methods().iter().map(|m| m.name.as_str()).collect::<Vec<_>>().join("|")
    ))
}

/// Cross a method list with perm specs — the `--perms` grid axis.  Each
/// (method, perm) pair becomes one row named `"{method}+{spec}"`, keeping
/// the method's pattern/grow and replacing its perm treatment.  Specs
/// canonicalise through the registry, so `--perms learned:sinkhorn=12`
/// names and fingerprints identically to `--perms learned`, and the
/// crossed names re-resolve through [`resolve_method`] (journal resume).
pub fn cross_perms(methods: &[Method], perms: &[String]) -> Result<Vec<Method>> {
    // An empty perm list would silently erase the whole grid; refuse it
    // (an empty/`,`-only `--perms` value is a flag mistake, not a wish
    // for zero cells).
    if perms.is_empty() {
        bail!("--perms needs at least one perm spec (e.g. learned,none)");
    }
    if methods.is_empty() {
        bail!("--perms has no methods to cross with");
    }
    let mut out = Vec::with_capacity(methods.len() * perms.len());
    for m in methods {
        for spec in perms {
            let ph = resolve_perm(spec)
                .map_err(|e| anyhow!("--perms {spec:?}: {e}"))?;
            let mut c = m.clone();
            c.perm = ph.spec();
            c.name = format!("{}+{}", m.name, ph.spec());
            out.push(c);
        }
    }
    Ok(out)
}

/// [`resolve_method`] as an `Option` — for lookups where a missing name is
/// handled by the caller rather than reported.
pub fn method_by_name(name: &str) -> Option<Method> {
    resolve_method(name).ok()
}

#[derive(Clone, Debug)]
pub struct SweepCell {
    pub method: String,
    pub sparsity: f64,
    pub result: RunResult,
}

/// The flat (method, sparsity) cell list in sequential-sweep order: methods
/// outer, sparsities inner, dense contributing one cell.  Both execution
/// paths walk exactly this list, which is what makes their outputs merge
/// identically.  The expansion itself is `harness::shard::plan_cells` —
/// one source of truth for cell order shared with the executor tests.
pub fn plan_grid(methods: &[Method], sparsities: &[f64]) -> Vec<(Method, f64)> {
    let axes: Vec<(&str, bool)> = methods
        .iter()
        .map(|m| (m.name.as_str(), !m.is_dense()))
        .collect();
    plan_cells(&axes, sparsities)
        .into_iter()
        .map(|k| {
            // The name came out of `methods` one line up; the find is total.
            let m = methods.iter().find(|m| m.name == k.method).unwrap().clone();
            (m, k.sparsity)
        })
        .collect()
}

/// Train one grid cell.
#[allow(clippy::too_many_arguments)]
fn run_cell(
    rt: &mut Runtime,
    model: &str,
    m: &Method,
    sparsity: f64,
    steps: usize,
    seed: u64,
    verbose: bool,
    threads: usize,
    backend: Backend,
) -> Result<SweepCell> {
    let density = if m.is_dense() { 1.0 } else { 1.0 - sparsity };
    let cfg = RunConfig {
        model: model.to_string(),
        pattern: resolve_pattern(&m.pattern)?,
        density,
        perm: resolve_perm(&m.perm)?,
        steps,
        grow_mode: m.grow_mode,
        seed,
        verbose,
        threads,
        backend,
        ..Default::default()
    };
    let mut tr = Trainer::new(rt, cfg);
    let result = tr.run()?;
    if verbose {
        eprintln!(
            "[sweep] {:<14} s={:.0}% loss={:.4} acc={:.3} ppl={:.2} ({:.1}s)",
            m.name,
            sparsity * 100.0,
            result.final_eval_loss,
            result.final_eval_acc,
            result.final_ppl,
            result.train_seconds
        );
    }
    Ok(SweepCell { method: m.name.clone(), sparsity, result })
}

/// Run `methods` x `sparsities` on `model` sequentially against one shared
/// runtime; returns all cells.  `threads` is the per-run worker budget
/// (0 = auto) and `backend` the microkernel backend, recorded on every
/// cell's `RunConfig` and pushed to the shared `Runtime` so all cells
/// advertise the same budget.  Note: artifact execution currently runs
/// under PJRT's own thread pool (intra-op wiring is a ROADMAP item);
/// today the knobs govern the native parallel-kernel paths.
#[allow(clippy::too_many_arguments)]
pub fn run_sweep(
    rt: &mut Runtime,
    model: &str,
    methods: &[Method],
    sparsities: &[f64],
    steps: usize,
    seed: u64,
    verbose: bool,
    threads: usize,
    backend: Backend,
) -> Result<Vec<SweepCell>> {
    plan_grid(methods, sparsities)
        .into_iter()
        .map(|(m, sp)| run_cell(rt, model, &m, sp, steps, seed, verbose, threads, backend))
        .collect()
}

/// Options for the sharded sweep path.
#[derive(Clone, Debug)]
pub struct SweepShardOpts {
    /// Worker count: 0 = auto (min(cores, cells)), 1 = the sequential
    /// path on the calling thread.  Always clamped to the resolved
    /// `threads` budget so worker count alone can never oversubscribe it.
    pub workers: usize,
    /// Global native-kernel thread budget (0 = auto), divided across
    /// workers so total parallelism stays bounded at the budget.
    pub threads: usize,
    /// Microkernel backend recorded on every cell's `RunConfig`.
    pub backend: Backend,
    /// Process-level grid shard `(i, n)`: this invocation only runs cells
    /// whose grid slot satisfies `slot % n == i`.  Pair with `journal`
    /// (one path per shard) and `padst journal-merge` to fan a Fig. 2
    /// regeneration out across machines.
    pub shard: Option<(usize, usize)>,
    /// JSONL checkpoint: completed cells are appended as they finish and
    /// skipped on the next invocation (resume).
    pub journal: Option<PathBuf>,
    pub verbose: bool,
}

impl Default for SweepShardOpts {
    fn default() -> Self {
        SweepShardOpts {
            workers: 0,
            threads: 0,
            backend: Backend::default_backend(),
            shard: None,
            journal: None,
            verbose: false,
        }
    }
}

/// The sweep front door shared by the CLI and the fig2 example: one
/// worker with no journal takes the sequential shared-runtime fast path
/// (every cell reuses one compiled-program cache), anything else goes
/// through [`run_sweep_sharded`].  Returns the cells plus the model kind
/// (for [`print_table`]'s acc-vs-ppl choice).
pub fn run_sweep_auto(
    artifacts_dir: &Path,
    model: &str,
    methods: &[Method],
    sparsities: &[f64],
    steps: usize,
    seed: u64,
    opts: &SweepShardOpts,
) -> Result<(Vec<SweepCell>, String)> {
    let kind_of = |manifest: &crate::runtime::manifest::Manifest| -> Result<String> {
        Ok(manifest
            .models
            .get(model)
            .ok_or_else(|| anyhow!("model {model:?} not in manifest"))?
            .kind
            .clone())
    };
    if opts.workers == 1 && opts.journal.is_none() && opts.shard.is_none() {
        let mut rt = Runtime::open_with_threads(artifacts_dir, opts.threads)?;
        let kind = kind_of(&rt.manifest)?;
        let cells = run_sweep(
            &mut rt,
            model,
            methods,
            sparsities,
            steps,
            seed,
            opts.verbose,
            opts.threads,
            opts.backend,
        )?;
        Ok((cells, kind))
    } else {
        let cells = run_sweep_sharded(artifacts_dir, model, methods, sparsities, steps, seed, opts)?;
        let manifest =
            crate::runtime::manifest::Manifest::load(&artifacts_dir.join("manifest.json"))?;
        Ok((cells, kind_of(&manifest)?))
    }
}

/// [`run_sweep`] fanned out on the harness executor: same grid, same cell
/// order in the output, but each worker owns its own `Runtime` opened from
/// `artifacts_dir`.  Workers pay their own artifact compiles (amortised
/// across the cells they pull), which the wall-clock win across cells
/// dominates for any real grid.
pub fn run_sweep_sharded(
    artifacts_dir: &Path,
    model: &str,
    methods: &[Method],
    sparsities: &[f64],
    steps: usize,
    seed: u64,
    opts: &SweepShardOpts,
) -> Result<Vec<SweepCell>> {
    let cells = plan_grid(methods, sparsities);
    let keys: Vec<CellKey> = cells
        .iter()
        .map(|(m, sp)| CellKey { method: m.name.clone(), sparsity: *sp })
        .collect();

    // Resume: cells already journaled by a previous (interrupted) run are
    // deserialised instead of re-trained.  Cell ids are only
    // "method@sparsity", so the journal carries a metadata header and
    // refuses to resume a sweep with different (model, steps, seed) —
    // otherwise stale cells would silently masquerade as this run's.
    // The header is deliberately shard-blind: every shard of one sweep
    // writes the same header, which is what lets `padst journal-merge`
    // verify the shards belong together.
    let meta = sweep_meta(model, steps, seed);
    let mut done: HashMap<String, SweepCell> = HashMap::new();
    let journal = match &opts.journal {
        Some(path) => {
            let (j, prior) = open_sweep_journal(path, &meta)?;
            for (id, v) in &prior {
                done.insert(id.clone(), cell_from_json(v)?);
            }
            // Re-announce the plan on every (re)start: `padst watch` takes
            // the latest plan record as the denominator, and a resumed run
            // may have a different grid only if the meta check above let
            // it through (it didn't — same header, same grid).
            let _ = j.append_event(PLAN_KEY, &plan_event(&keys));
            Some(j)
        }
        None => None,
    };

    let pending: Vec<(usize, CellKey)> = keys
        .iter()
        .cloned()
        .enumerate()
        .filter(|(slot, k)| in_shard(*slot, opts.shard) && !done.contains_key(&k.id()))
        .collect();
    if let Some((i, n)) = opts.shard {
        if opts.verbose {
            eprintln!(
                "[sweep] shard {i}/{n}: {} of {} cells owned by this shard, {} pending",
                keys.iter().enumerate().filter(|(s, _)| in_shard(*s, opts.shard)).count(),
                keys.len(),
                pending.len()
            );
        }
    } else if opts.verbose && pending.len() < keys.len() {
        eprintln!(
            "[sweep] resuming: {}/{} cells restored from journal",
            keys.len() - pending.len(),
            keys.len()
        );
    }

    // Workers are capped by the resolved thread budget, and the budget is
    // divided across them, so (workers x per-cell threads) never exceeds
    // the budget the caller asked for.
    let budget = resolve_threads(opts.threads);
    let workers = executor::resolve_workers(opts.workers, pending.len()).min(budget).max(1);
    let cell_threads = (budget / workers).max(1);
    let journal_ref = journal.as_ref();
    let cells_ref = &cells;
    // Liveness for `padst watch`: start/done heartbeats per cell, written
    // best-effort (`let _ =`) — a full disk must not kill a sweep that
    // could still return its cells in memory.  `done_count` starts at the
    // resumed-cell count so progress reads cumulatively across restarts.
    let total_cells = keys.len();
    let done_count = AtomicUsize::new(done.len());
    let heartbeat = |wid: usize, event: &str, cell: &CellKey, dur_s: Option<f64>| {
        if let Some(j) = journal_ref {
            let hb = Heartbeat {
                worker: wid,
                event: event.to_string(),
                cell: cell.id(),
                // ordering: SeqCst so heartbeats never report a count
                // behind a completion this worker already published.
                done: done_count.load(Ordering::SeqCst),
                total: total_cells,
                t: now_unix(),
                dur_s,
            };
            let _ = j.append_event(HEARTBEAT_KEY, &hb.to_json());
        }
    };
    let fresh = executor::execute_sharded(
        &pending,
        workers,
        |wid| Ok((Runtime::open_with_threads(artifacts_dir, cell_threads)?, wid)),
        |ctx, _slot, (cell_i, key)| {
            let (rt, wid) = ctx;
            let (m, sp) = &cells_ref[*cell_i];
            heartbeat(*wid, "start", key, None);
            let t0 = Instant::now();
            let cell = run_cell(
                rt, model, m, *sp, steps, seed, opts.verbose, cell_threads, opts.backend,
            )?;
            if let Some(j) = journal_ref {
                j.record(&key.id(), &cell_to_json(&cell))?;
            }
            // ordering: SeqCst publish of the completion count, paired
            // with the heartbeat closure's load above.
            done_count.fetch_add(1, Ordering::SeqCst);
            heartbeat(*wid, "done", key, Some(t0.elapsed().as_secs_f64()));
            Ok(cell)
        },
    )?;

    // Merge journaled + fresh cells back into grid order.  Fresh results
    // key on the grid *slot*, not the cell id: a grid with duplicate
    // (method, sparsity) entries (the CLI doesn't forbid them) has
    // distinct slots but colliding ids, and each slot must get a result.
    // Under `--shard i/n` the slots owned by other shards are legitimately
    // absent (their journals get combined later via `padst
    // journal-merge`); without sharding a missing slot is a bug.
    let mut fresh_by_slot: HashMap<usize, SweepCell> =
        pending.iter().map(|&(slot, _)| slot).zip(fresh).collect();
    let mut out = Vec::with_capacity(keys.len());
    for (slot, k) in keys.iter().enumerate() {
        match fresh_by_slot.remove(&slot).or_else(|| done.get(&k.id()).cloned()) {
            Some(cell) => out.push(cell),
            None if opts.shard.is_some() => {}
            None => bail!("sweep cell {} missing after merge", k.id()),
        }
    }
    Ok(out)
}

/// The sweep's journal metadata header: a journal only resumes (or merges
/// with) a sweep whose (model, steps, seed) match this exactly.
pub fn sweep_meta(model: &str, steps: usize, seed: u64) -> Json {
    json::obj(vec![
        ("model", json::s(model)),
        ("steps", json::num(steps as f64)),
        ("seed", json::num(seed as f64)),
    ])
}

/// Open (or create) a sweep journal at `path`, enforcing the header
/// contract: a fresh journal gets `meta` written as its [`META_KEY`]
/// record; an existing one must carry an identical header.  Returns the
/// journal and the prior completed-cell records (header removed).
pub fn open_sweep_journal(path: &Path, meta: &Json) -> Result<(Journal, BTreeMap<String, Json>)> {
    let (j, mut prior) = Journal::open(path)?;
    match prior.remove(META_KEY) {
        Some(m) if m != *meta => bail!(
            "journal {} belongs to a different sweep ({}); this run is {} — \
             pass a fresh --journal path",
            path.display(),
            m.to_string_pretty(),
            meta.to_string_pretty()
        ),
        Some(_) => {}
        None if prior.is_empty() => j.record(META_KEY, meta)?,
        None => bail!(
            "journal {} has cells but no {META_KEY} header; refusing to resume",
            path.display()
        ),
    }
    Ok((j, prior))
}

/// The `{"plan": ...}` event payload announcing the full grid — `padst
/// watch` reads `total` as its progress denominator.
fn plan_event(keys: &[CellKey]) -> Json {
    json::obj(vec![
        ("total", json::num(keys.len() as f64)),
        ("cells", Json::Arr(keys.iter().map(|k| json::s(&k.id())).collect())),
    ])
}

/// Write a journal's header and plan record without running any cells —
/// what `padst sweep --dry-run --journal <path>` leaves behind, so `padst
/// watch` has a denominator (and CI a deterministic fixture) before the
/// real run starts.
pub fn seed_dry_run_journal(
    path: &Path,
    model: &str,
    steps: usize,
    seed: u64,
    keys: &[CellKey],
) -> Result<()> {
    let meta = sweep_meta(model, steps, seed);
    let (j, _prior) = open_sweep_journal(path, &meta)?;
    j.append_event(PLAN_KEY, &plan_event(keys))?;
    Ok(())
}

/// What a method *does* — the cell fingerprint carried by the journal.
/// The first two components are the pattern and perm *specs*, so
/// parameterised grid axes (`block:4` vs `block:8`, `learned` vs
/// `learned:sinkhorn=24`) fingerprint differently, and a zoo entry whose
/// definition changed between the run that wrote a journal and the run
/// resuming it is refused.  Bare-name specs render exactly as the
/// pre-registry strings did, so old journals still match.
pub fn method_fingerprint(m: &Method) -> String {
    format!("{}|{}|{:?}", m.pattern, m.perm, m.grow_mode)
}

/// Serialise one cell (full `RunResult` fidelity) for the resume journal.
pub fn cell_to_json(c: &SweepCell) -> Json {
    fn f32s(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| json::num(x as f64)).collect())
    }
    fn pairs(xs: &[(usize, f32)]) -> Json {
        Json::Arr(
            xs.iter()
                .map(|&(i, v)| json::arr([json::num(i as f64), json::num(v as f64)]))
                .collect(),
        )
    }
    let r = &c.result;
    let entry = method_by_name(&c.method);
    json::obj(vec![
        ("method", json::s(&c.method)),
        (
            "method_config",
            match &entry {
                Some(m) => json::s(&method_fingerprint(m)),
                None => Json::Null,
            },
        ),
        // The pattern / perm specs alone, for downstream tooling (the
        // fingerprint above is what resume integrity checks).
        (
            "pattern",
            match &entry {
                Some(m) => json::s(&m.pattern),
                None => Json::Null,
            },
        ),
        (
            "perm",
            match &entry {
                Some(m) => json::s(&m.perm),
                None => Json::Null,
            },
        ),
        ("sparsity", json::num(c.sparsity)),
        ("losses", f32s(&r.losses)),
        ("eval_losses", pairs(&r.eval_losses)),
        ("eval_accs", pairs(&r.eval_accs)),
        ("penalties", Json::Arr(r.penalties.iter().map(|p| f32s(p)).collect())),
        (
            "harden_step",
            Json::Arr(
                r.harden_step
                    .iter()
                    .map(|h| match h {
                        Some(s) => json::num(*s as f64),
                        None => Json::Null,
                    })
                    .collect(),
            ),
        ),
        (
            "identity_distance",
            Json::Arr(r.identity_distance.iter().map(|&d| json::num(d)).collect()),
        ),
        ("site_names", Json::Arr(r.site_names.iter().map(|s| json::s(s)).collect())),
        ("dst_rejected", json::num(r.dst_rejected as f64)),
        ("train_seconds", json::num(r.train_seconds)),
        ("final_eval_loss", json::num(r.final_eval_loss as f64)),
        ("final_eval_acc", json::num(r.final_eval_acc as f64)),
        ("final_ppl", json::num(r.final_ppl as f64)),
    ])
}

/// Inverse of [`cell_to_json`].  The method name must still resolve —
/// through the zoo or as a pattern spec — and the journaled
/// `method_config` fingerprint must match the current definition: a cell
/// trained under an edited method (different pattern spec/perm/grow) is
/// refused rather than silently merged into this run's results.
pub fn cell_from_json(v: &Json) -> Result<SweepCell> {
    // Non-finite values (a diverged run's ppl) serialise as JSON null and
    // come back as NaN; a missing key is still an error.
    let num = |k: &str| -> Result<f64> {
        let x = v.get(k).ok_or_else(|| anyhow!("journal cell: missing number {k:?}"))?;
        Ok(x.as_f64().unwrap_or(f64::NAN))
    };
    let arr = |k: &str| {
        v.get(k).and_then(Json::as_arr).ok_or_else(|| anyhow!("journal cell: missing array {k:?}"))
    };
    fn f32s(a: &[Json]) -> Vec<f32> {
        a.iter().map(|x| x.as_f64().unwrap_or(f64::NAN) as f32).collect()
    }
    fn pairs(a: &[Json]) -> Vec<(usize, f32)> {
        a.iter()
            .map(|p| {
                (
                    p.idx(0).and_then(Json::as_usize).unwrap_or(0),
                    p.idx(1).and_then(Json::as_f64).unwrap_or(f64::NAN) as f32,
                )
            })
            .collect()
    }

    let name = v
        .get("method")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("journal cell: missing method"))?;
    let entry =
        resolve_method(name).map_err(|e| anyhow!("journal cell: {e}"))?;
    if let Some(fp) = v.get("method_config").and_then(Json::as_str) {
        let want = method_fingerprint(&entry);
        if fp != want {
            bail!(
                "journal cell for {name:?} was trained under method config {fp:?} but the \
                 current zoo defines {want:?}; use a fresh journal"
            );
        }
    }
    let method = entry.name;
    let result = RunResult {
        losses: f32s(arr("losses")?),
        eval_losses: pairs(arr("eval_losses")?),
        eval_accs: pairs(arr("eval_accs")?),
        penalties: arr("penalties")?
            .iter()
            .map(|p| f32s(p.as_arr().unwrap_or(&[])))
            .collect(),
        harden_step: arr("harden_step")?
            .iter()
            .map(|h| h.as_usize())
            .collect(),
        identity_distance: arr("identity_distance")?
            .iter()
            .map(|d| d.as_f64().unwrap_or(f64::NAN))
            .collect(),
        site_names: arr("site_names")?
            .iter()
            .map(|s| s.as_str().unwrap_or("").to_string())
            .collect(),
        // Absent in pre-PR4 journals: those cells ran zoo methods whose
        // family-default DST never triggered the rollback counter.
        dst_rejected: v.get("dst_rejected").and_then(Json::as_usize).unwrap_or(0),
        train_seconds: num("train_seconds")?,
        final_eval_loss: num("final_eval_loss")? as f32,
        final_eval_acc: num("final_eval_acc")? as f32,
        final_ppl: num("final_ppl")? as f32,
    };
    Ok(SweepCell { method, sparsity: num("sparsity")?, result })
}

/// Print the Fig. 2 / Tbl. 11-style grid: rows = methods, cols = sparsity.
pub fn print_table(model: &str, kind: &str, cells: &[SweepCell], sparsities: &[f64]) {
    let metric = if kind == "gpt" { "ppl" } else { "acc" };
    println!("\n=== {model}: {metric} vs sparsity (paper Fig. 2 / Tbl. 11-12 analogue) ===");
    print!("{:<16}", "method");
    for &s in sparsities {
        print!("{:>10}", format!("{:.0}%", s * 100.0));
    }
    println!();
    // Rows in zoo declaration order, then any spec-synthesized methods in
    // first-encounter order: cell encounter order alone is not stable once
    // cells arrive shard-merged or journal-resumed.
    let mut rows: Vec<String> = methods()
        .iter()
        .map(|m| m.name.clone())
        .filter(|name| cells.iter().any(|c| &c.method == name))
        .collect();
    for c in cells {
        if !rows.contains(&c.method) {
            rows.push(c.method.clone());
        }
    }
    for m in rows {
        print!("{m:<16}");
        for &s in sparsities {
            let cell = cells
                .iter()
                .find(|c| c.method == m && (c.sparsity - s).abs() < 1e-9);
            match cell {
                Some(c) => {
                    let v = if kind == "gpt" { c.result.final_ppl } else { c.result.final_eval_acc };
                    print!("{v:>10.3}");
                }
                None => print!("{:>10}", "-"),
            }
        }
        println!();
    }
}

/// CSV dump of all cells for downstream plotting.  Written atomically
/// (temp + rename, parent dirs created) so an interrupted run never
/// leaves a truncated file.
pub fn write_csv(path: &std::path::Path, cells: &[SweepCell]) -> Result<()> {
    let mut s = String::from("method,sparsity,final_eval_loss,final_eval_acc,final_ppl,train_seconds\n");
    for c in cells {
        s.push_str(&format!(
            "{},{},{},{},{},{}\n",
            c.method,
            c.sparsity,
            c.result.final_eval_loss,
            c.result.final_eval_acc,
            c.result.final_ppl,
            c.result.train_seconds
        ));
    }
    crate::util::fs::write_atomic(path, &s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_matches_sequential_order() {
        let methods: Vec<Method> =
            ["RigL", "Dense", "DynaDiag+PA"].iter().map(|n| method_by_name(n).unwrap()).collect();
        let cells = plan_grid(&methods, &[0.6, 0.9]);
        let ids: Vec<(&str, f64)> = cells.iter().map(|(m, sp)| (m.name.as_str(), *sp)).collect();
        assert_eq!(
            ids,
            [
                ("RigL", 0.6),
                ("RigL", 0.9),
                ("Dense", 0.6),
                ("DynaDiag+PA", 0.6),
                ("DynaDiag+PA", 0.9)
            ]
        );
    }

    #[test]
    fn pattern_specs_are_first_class_methods() {
        // A spec string is a method: synthesized as structured DST without
        // permutation, fingerprinted by its canonical spec.
        let m = method_by_name("block:4").unwrap();
        assert_eq!(m.pattern, "block:4");
        assert_eq!(m.perm, "none");
        assert_eq!(method_fingerprint(&m), "block:4|none|RigL");
        // Defaults canonicalise: block:16 is the bare family.
        assert_eq!(method_by_name("block:16").unwrap().pattern, "block");
        // Zoo fingerprints keep the pre-registry bare-name form.
        let zoo = method_by_name("DynaDiag").unwrap();
        assert_eq!(method_fingerprint(&zoo), "diag|none|RigL");
        // Garbage still fails.
        assert!(method_by_name("nosuchmethod").is_none());
        assert!(method_by_name("block:0").is_none());
        // ... and keeps the registry's descriptive error: a bad spec of a
        // known family reports the actual constraint, not just "unknown".
        let err = resolve_method("nm:3:2").unwrap_err().to_string();
        assert!(err.contains("N <= M"), "{err}");
    }

    #[test]
    fn perm_specs_cross_into_grid_rows() {
        // The --perms axis: every (method, perm) pair becomes one row.
        let base = vec![method_by_name("RigL").unwrap(), method_by_name("block:4").unwrap()];
        let perms = vec!["learned".to_string(), "none".to_string()];
        let crossed = cross_perms(&base, &perms).unwrap();
        assert_eq!(crossed.len(), 4);
        assert_eq!(crossed[0].name, "RigL+learned");
        assert_eq!(crossed[0].perm, "learned");
        assert_eq!(crossed[0].pattern, "unstructured");
        assert_eq!(crossed[3].name, "block:4+none");
        assert_eq!(method_fingerprint(&crossed[2]), "block:4|learned|RigL");
        // Crossed names re-resolve (journal resume), including over zoo
        // names that themselves contain '+'.
        let back = resolve_method("block:4+learned").unwrap();
        assert_eq!(method_fingerprint(&back), method_fingerprint(&crossed[2]));
        let pa = resolve_method("DynaDiag+PA+random").unwrap();
        assert_eq!(method_fingerprint(&pa), "diag|random|RigL");
        // Parameterised perm specs canonicalise before naming.
        let canon = cross_perms(&base[..1], &["learned:sinkhorn=12".to_string()]).unwrap();
        assert_eq!(canon[0].name, "RigL+learned");
        // Bad perm specs keep their descriptive registry error — both via
        // cross_perms and via a crossed method name.
        let err = cross_perms(&base, &["learned:tau=0".to_string()]).unwrap_err().to_string();
        assert!(err.contains("tau"), "{err}");
        let err = resolve_method("block:4+learned:tau=0").unwrap_err().to_string();
        assert!(err.contains("tau"), "{err}");
        // An empty perm list must refuse rather than erase the grid.
        assert!(cross_perms(&base, &[]).is_err());
        assert!(cross_perms(&[], &perms).is_err());
    }

    #[test]
    fn zoo_fingerprints_unchanged_from_pre_registry_journals() {
        // Every zoo fingerprint is pinned: a journal written before the
        // perm registry must resume against today's definitions.
        let want = [
            ("RigL", "unstructured|none|RigL"),
            ("SET", "unstructured|none|Set"),
            ("MEST", "unstructured|none|Mest"),
            ("DynaDiag", "diag|none|RigL"),
            ("SRigL", "nm|none|RigL"),
            ("DSB", "block|none|RigL"),
            ("PixelatedBFly", "butterfly|none|RigL"),
            ("DynaDiag+Rand", "diag|random|RigL"),
            ("SRigL+Rand", "nm|random|RigL"),
            ("DSB+Rand", "block|random|RigL"),
            ("DynaDiag+PA", "diag|learned|RigL"),
            ("SRigL+PA", "nm|learned|RigL"),
            ("DSB+PA", "block|learned|RigL"),
            ("PBFly+PA", "butterfly|learned|RigL"),
            ("Dense", "dense|none|RigL"),
        ];
        for (name, fp) in want {
            assert_eq!(method_fingerprint(&method_by_name(name).unwrap()), fp, "{name}");
        }
    }

    #[test]
    fn crossed_cells_roundtrip_through_journal() {
        let cell = SweepCell {
            method: "block:4+learned".to_string(),
            sparsity: 0.9,
            result: RunResult::default(),
        };
        let j = cell_to_json(&cell);
        assert_eq!(j.get("perm").and_then(Json::as_str), Some("learned"));
        assert_eq!(j.get("pattern").and_then(Json::as_str), Some("block:4"));
        let back = cell_from_json(&Json::parse(&j.to_string_pretty()).unwrap()).unwrap();
        assert_eq!(back.method, "block:4+learned");
    }

    #[test]
    fn spec_method_cells_roundtrip_through_journal() {
        let cell = SweepCell {
            method: "nm:1:4".to_string(),
            sparsity: 0.75,
            result: RunResult::default(),
        };
        let j = cell_to_json(&cell);
        assert_eq!(j.get("pattern").and_then(Json::as_str), Some("nm:1:4"));
        let back = cell_from_json(&Json::parse(&j.to_string_pretty()).unwrap()).unwrap();
        assert_eq!(back.method, "nm:1:4");
    }

    #[test]
    fn cell_json_roundtrip_preserves_everything() {
        let cell = SweepCell {
            method: method_by_name("DynaDiag+PA").unwrap().name,
            sparsity: 0.95,
            result: RunResult {
                losses: vec![2.5, 1.25, 0.75],
                eval_losses: vec![(50, 1.5), (100, 1.0)],
                eval_accs: vec![(50, 0.25), (100, 0.5)],
                penalties: vec![vec![0.5, 0.25], vec![0.125]],
                harden_step: vec![Some(42), None],
                identity_distance: vec![0.75, 0.0],
                site_names: vec!["l0.fc1".into(), "l1.fc1".into()],
                dst_rejected: 3,
                train_seconds: 12.5,
                final_eval_loss: 1.0,
                final_eval_acc: 0.5,
                final_ppl: 2.71828,
            },
        };
        let j = cell_to_json(&cell);
        // Through text, as the journal stores it.
        let back = cell_from_json(&Json::parse(&j.to_string_pretty()).unwrap()).unwrap();
        assert_eq!(back.method, cell.method);
        assert_eq!(back.sparsity, cell.sparsity);
        assert_eq!(back.result.losses, cell.result.losses);
        assert_eq!(back.result.eval_losses, cell.result.eval_losses);
        assert_eq!(back.result.eval_accs, cell.result.eval_accs);
        assert_eq!(back.result.penalties, cell.result.penalties);
        assert_eq!(back.result.harden_step, cell.result.harden_step);
        assert_eq!(back.result.identity_distance, cell.result.identity_distance);
        assert_eq!(back.result.site_names, cell.result.site_names);
        assert_eq!(back.result.dst_rejected, cell.result.dst_rejected);
        assert_eq!(back.result.train_seconds, cell.result.train_seconds);
        assert_eq!(back.result.final_eval_loss, cell.result.final_eval_loss);
        assert_eq!(back.result.final_eval_acc, cell.result.final_eval_acc);
        assert_eq!(back.result.final_ppl, cell.result.final_ppl);
    }

    #[test]
    fn cell_from_json_rejects_unknown_method() {
        let j = json::obj(vec![("method", json::s("NotAMethod")), ("sparsity", json::num(0.5))]);
        assert!(cell_from_json(&j).is_err());
    }

    #[test]
    fn cell_from_json_rejects_changed_method_config() {
        let cell = SweepCell {
            method: method_by_name("DynaDiag").unwrap().name,
            sparsity: 0.9,
            result: RunResult::default(),
        };
        let mut j = cell_to_json(&cell);
        // A journal written before DynaDiag's definition was edited.
        if let Json::Obj(m) = &mut j {
            m.insert("method_config".into(), json::s("block|learned|Set"));
        }
        let err = cell_from_json(&j).unwrap_err();
        assert!(err.to_string().contains("method config"), "{err}");
    }
}
