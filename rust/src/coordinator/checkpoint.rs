//! Checkpointing: the full [`TrainState`] (params, Adam moments, masks,
//! permutation logits/index maps, hard flags, step counter) serialises to
//! a single `.tnz` bundle — the same format the Python compile path uses
//! for goldens — so runs can be stopped/resumed and trained models handed
//! to the compressed-inference path.

use std::path::Path;

use anyhow::{anyhow, Result};

use super::TrainState;
use crate::tensor::{read_tnz, write_tnz, Tensor};

/// Save the complete state.  Site order is recorded under a reserved key
/// so `load` restores it without consulting the manifest.
pub fn save(path: &Path, state: &TrainState) -> Result<()> {
    let mut entries: Vec<(String, &Tensor)> = state
        .vals
        .iter()
        .map(|(k, v)| (k.clone(), v))
        .collect();
    entries.sort_by(|a, b| a.0.cmp(&b.0));
    // Encode site order as an i32 tensor of indices into the sorted
    // mask.* keys (names themselves are recoverable from the keys).
    let order: Vec<i32> = state
        .site_names
        .iter()
        .map(|n| {
            let key = format!("mask.{n}");
            entries
                .iter()
                .position(|(k, _)| *k == key)
                .map(|p| p as i32)
                .unwrap_or(-1)
        })
        .collect();
    let order_t = Tensor::from_i32(&[order.len()], order);
    let mut all = entries;
    all.push(("__site_order__".to_string(), &order_t));
    write_tnz(path, &all)
}

/// Load a checkpoint saved by [`save`].
pub fn load(path: &Path) -> Result<TrainState> {
    let mut bundle = read_tnz(path)?;
    let order = bundle
        .remove("__site_order__")
        .ok_or_else(|| anyhow!("not a padst checkpoint (missing __site_order__)"))?;
    let keys: Vec<String> = bundle.keys().cloned().collect();
    let site_names: Vec<String> = order
        .i32s()
        .iter()
        .map(|&p| {
            let key = &keys[p as usize];
            key.strip_prefix("mask.")
                .ok_or_else(|| anyhow!("site-order entry {key:?} is not a mask"))
                .map(str::to_string)
        })
        .collect::<Result<_>>()?;
    let vals: std::collections::HashMap<_, _> = bundle.into_iter().collect();
    let budgets = site_names
        .iter()
        .map(|n| {
            vals[&format!("mask.{n}")]
                .f32s()
                .iter()
                .filter(|&&b| b > 0.5)
                .count()
        })
        .collect();
    Ok(TrainState { vals, site_names, budgets })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn roundtrip() {
        let mut vals = HashMap::new();
        vals.insert("param.a.w".to_string(), Tensor::from_f32(&[2, 2], vec![1., 2., 3., 4.]));
        vals.insert("mask.a".to_string(), Tensor::from_f32(&[2, 2], vec![1., 0., 0., 1.]));
        vals.insert("mask.b".to_string(), Tensor::from_f32(&[2, 2], vec![0., 1., 1., 0.]));
        vals.insert("perm_idx.a".to_string(), Tensor::from_i32(&[2], vec![1, 0]));
        vals.insert("step".to_string(), Tensor::scalar(42.0));
        let state = TrainState {
            vals,
            site_names: vec!["b".to_string(), "a".to_string()], // non-sorted order
            budgets: vec![2, 2],
        };
        let dir = std::env::temp_dir().join("padst_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("state.tnz");
        save(&p, &state).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back.site_names, state.site_names);
        assert_eq!(back.vals["step"].f32s(), &[42.0]);
        assert_eq!(back.vals["perm_idx.a"].i32s(), &[1, 0]);
        assert_eq!(back.vals.len(), state.vals.len());
    }

    #[test]
    fn rejects_non_checkpoint() {
        let dir = std::env::temp_dir().join("padst_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.tnz");
        let t = Tensor::scalar(1.0);
        crate::tensor::write_tnz(&p, &[("a".to_string(), &t)]).unwrap();
        assert!(load(&p).is_err());
    }
}
