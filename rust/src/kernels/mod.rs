//! Native CPU sparse GEMM kernels — the Fig. 3 substrate.
//!
//! The paper's inference-speedup claims (up to 2.9x at 90 % with DynaDiag,
//! 3.16–8.69 % permutation re-indexing overhead) are measured with vendor
//! kernels on A100s.  This testbed reproduces the *structural* argument on
//! CPU: structured layouts stream memory contiguously so time scales with
//! density, unstructured CSR pays per-element indirection, a permutation
//! *matmul* pays an extra full pass over the activations, and permutation
//! *re-indexing* (Eqn. 16/18) folds into the sparse GEMM's index stream at
//! near-zero cost.
//!
//! All kernels compute `y = x @ W^T + b` for row-major
//! `x: (batch, cols)`, `W: (rows, cols)`, matching the model's linears.
//! Each has a `*_permuted` variant taking the input permutation either as
//! a pre-composed index stream (re-indexing) or as an explicit shuffle
//! pass (the strawman the paper compares against), and a `*_mt` variant
//! (see [`parallel`]) that shards the output across scoped threads with
//! bit-identical results.
//!
//! Since the microkernel refactor every matmul is a thin *driver* over the
//! [`micro`] layer: the inner reductions are selected at runtime through
//! [`Backend`] (`scalar` reference loops, hand-tiled `tiled` default, or
//! `std::simd` behind `--features nightly-simd`).  Plain entry points run
//! [`Backend::default_backend`] (the `PADST_BACKEND` env knob); `_with` /
//! `_mt_with` variants take the backend explicitly.
//!
//! [`run_plan`] / [`run_plan_mt`] additionally consult the [`tune`]
//! autotuner: with a tuning table installed (`PADST_TUNE_TABLE`,
//! `--tune-table`, or `padst tune`) the per-shape winning variant —
//! backend, batched row driver, mt thread cap — replaces the defaults;
//! untuned keys, `PADST_TUNE=off`, and table-less processes dispatch
//! exactly as before.  A pinned backend (explicit `--backend` /
//! `PADST_BACKEND`) is never overridden by the table, and the non-backend
//! axes are bit-preserving, so the serial<->mt `to_bits` identity contract
//! survives tuning unchanged.

pub mod csr;
pub mod dense;
pub mod gather;
pub mod micro;
pub mod parallel;
pub mod tune;

pub use csr::{csr_from_mask, csr_matmul, csr_matmul_with, Csr};
pub use dense::{
    dense_matmul, dense_matmul_blocked, dense_matmul_blocked_with, shuffle_rows,
};
pub use gather::{
    block_matmul, block_matmul_with, gather_matmul, gather_matmul_batched,
    gather_matmul_batched_with, gather_matmul_with,
};
pub use micro::Backend;
pub use parallel::{
    available_threads, block_matmul_mt, block_matmul_mt_with, csr_matmul_mt, csr_matmul_mt_with,
    dense_matmul_blocked_mt, dense_matmul_blocked_mt_with, gather_matmul_batched_mt,
    gather_matmul_batched_mt_with, gather_matmul_mt, gather_matmul_mt_with, parallel_map,
    resolve_threads,
};

/// FLOPs of one sparse GEMM at the given geometry (2 * batch * nnz).
pub fn spmm_flops(batch: usize, nnz: usize) -> usize {
    2 * batch * nnz
}

/// Dispatch metric handles, lazily registered on `obs::global()` the
/// first time an *enabled* dispatch runs — a process that never turns
/// observability on never registers (or pays for) them.
struct KernelObs {
    run_plan: std::sync::Arc<crate::obs::Counter>,
    run_plan_mt: std::sync::Arc<crate::obs::Counter>,
    /// Dispatches whose variant came from the tuning table (subset of the
    /// two counters above) — the observable CI asserts on in `tune-smoke`.
    run_plan_tuned: std::sync::Arc<crate::obs::Counter>,
    /// Per-plan-kind dispatch timing, indexed by [`plan_kind_index`].
    plan_ns: [std::sync::Arc<crate::obs::Histogram>; 4],
}

fn kernel_obs() -> &'static KernelObs {
    static OBS: std::sync::OnceLock<KernelObs> = std::sync::OnceLock::new();
    OBS.get_or_init(|| {
        let reg = crate::obs::global();
        KernelObs {
            run_plan: reg.counter("kernels.run_plan"),
            run_plan_mt: reg.counter("kernels.run_plan_mt"),
            run_plan_tuned: reg.counter("kernels.run_plan_tuned"),
            plan_ns: [
                reg.histogram("kernels.plan_ns.rows"),
                reg.histogram("kernels.plan_ns.blocks"),
                reg.histogram("kernels.plan_ns.csr"),
                reg.histogram("kernels.plan_ns.dense"),
            ],
        }
    })
}

fn plan_kind_index(plan: &crate::sparsity::pattern::KernelPlan) -> usize {
    use crate::sparsity::pattern::KernelPlan;
    match plan {
        KernelPlan::Rows(_) => 0,
        KernelPlan::Blocks(_) => 1,
        KernelPlan::Csr(_) => 2,
        KernelPlan::Dense { .. } => 3,
    }
}

/// Execute a pattern's [`KernelPlan`](crate::sparsity::pattern::KernelPlan)
/// on the serial driver it selects — the single plan→driver dispatch
/// point (benches and tests must not hand-roll this match: a new plan
/// variant then only has one execution site to extend).
///
/// Sits inside training inner loops where an `Instant::now()` pair is
/// measurable against a tiny GEMM, so dispatch metrics hide behind
/// [`crate::obs::enabled`]: one relaxed atomic load when off.  The tuning
/// consult is equally cheap when no table is installed (one atomic load;
/// see [`tune::Tuner::choice_for`]), and allocation-free when one is.
pub fn run_plan(
    plan: &crate::sparsity::pattern::KernelPlan,
    x: &[f32],
    batch: usize,
    y: &mut [f32],
    backend: Backend,
) {
    let (choice, tuned) = tune::tuner().choice_for(plan, 1, backend);
    if !crate::obs::enabled() {
        return dispatch_plan_choice(plan, x, batch, y, &choice);
    }
    let ko = kernel_obs();
    ko.run_plan.inc();
    if tuned {
        ko.run_plan_tuned.inc();
    }
    let t0 = std::time::Instant::now();
    dispatch_plan_choice(plan, x, batch, y, &choice);
    ko.plan_ns[plan_kind_index(plan)].record_ns(t0.elapsed());
}

/// [`run_plan`] with an explicit, pre-resolved tuning [`tune::Choice`]
/// (no table lookup at all).  Callers that execute one plan many times —
/// serve sites, the tuned bench sections — resolve the choice once via
/// [`tune::Tuner::choice_for`] and dispatch through this.
// lint: no-alloc
pub fn run_plan_tuned(
    plan: &crate::sparsity::pattern::KernelPlan,
    x: &[f32],
    batch: usize,
    y: &mut [f32],
    choice: &tune::Choice,
) {
    if !crate::obs::enabled() {
        return dispatch_plan_choice(plan, x, batch, y, choice);
    }
    let ko = kernel_obs();
    ko.run_plan.inc();
    ko.run_plan_tuned.inc();
    let t0 = std::time::Instant::now();
    dispatch_plan_choice(plan, x, batch, y, choice);
    ko.plan_ns[plan_kind_index(plan)].record_ns(t0.elapsed());
}

fn dispatch_plan_choice(
    plan: &crate::sparsity::pattern::KernelPlan,
    x: &[f32],
    batch: usize,
    y: &mut [f32],
    c: &tune::Choice,
) {
    use crate::sparsity::pattern::KernelPlan;
    match plan {
        KernelPlan::Rows(rc) if c.batched => {
            gather_matmul_batched_with(x, rc, batch, y, c.backend)
        }
        KernelPlan::Rows(rc) => gather_matmul_with(x, rc, batch, y, c.backend),
        KernelPlan::Blocks(bc) => block_matmul_with(x, bc, batch, y, c.backend),
        KernelPlan::Csr(csr) => csr_matmul_with(x, csr, batch, y, c.backend),
        KernelPlan::Dense { rows, cols, w } => {
            dense_matmul_blocked_with(x, w, batch, *rows, *cols, y, c.backend)
        }
    }
}

/// Split a global kernel-thread budget across `conns` concurrent serve
/// connections, floor one thread each.  The split is bit-safe: the `_mt`
/// drivers are bit-identical at any thread count, so dividing (or
/// oversubscribing, when `total < conns`) never changes results — only
/// throughput.
pub fn threads_per_conn(total: usize, conns: usize) -> usize {
    (resolve_threads(total) / conns.max(1)).max(1)
}

/// [`run_plan`] on the scoped-thread `_mt` drivers, keyed in the tuning
/// table at the resolved thread count.
pub fn run_plan_mt(
    plan: &crate::sparsity::pattern::KernelPlan,
    x: &[f32],
    batch: usize,
    y: &mut [f32],
    threads: usize,
    backend: Backend,
) {
    let threads = resolve_threads(threads);
    let (choice, tuned) = tune::tuner().choice_for(plan, threads, backend);
    if !crate::obs::enabled() {
        return dispatch_plan_mt_choice(plan, x, batch, y, threads, &choice);
    }
    let ko = kernel_obs();
    ko.run_plan_mt.inc();
    if tuned {
        ko.run_plan_tuned.inc();
    }
    let t0 = std::time::Instant::now();
    dispatch_plan_mt_choice(plan, x, batch, y, threads, &choice);
    ko.plan_ns[plan_kind_index(plan)].record_ns(t0.elapsed());
}

/// [`run_plan_mt`] with an explicit, pre-resolved tuning [`tune::Choice`]
/// (no table lookup at all) — the serve warm path.
// lint: no-alloc
pub fn run_plan_mt_tuned(
    plan: &crate::sparsity::pattern::KernelPlan,
    x: &[f32],
    batch: usize,
    y: &mut [f32],
    threads: usize,
    choice: &tune::Choice,
) {
    if !crate::obs::enabled() {
        return dispatch_plan_mt_choice(plan, x, batch, y, threads, choice);
    }
    let ko = kernel_obs();
    ko.run_plan_mt.inc();
    ko.run_plan_tuned.inc();
    let t0 = std::time::Instant::now();
    dispatch_plan_mt_choice(plan, x, batch, y, threads, choice);
    ko.plan_ns[plan_kind_index(plan)].record_ns(t0.elapsed());
}

fn dispatch_plan_mt_choice(
    plan: &crate::sparsity::pattern::KernelPlan,
    x: &[f32],
    batch: usize,
    y: &mut [f32],
    threads: usize,
    c: &tune::Choice,
) {
    use crate::sparsity::pattern::KernelPlan;
    // Cap after resolving so `0` (auto) still expands before the min —
    // the cap axis is bit-preserving (sharding is bit-identical at any
    // thread count), it only limits oversubscription on small GEMMs.
    let threads = resolve_threads(threads);
    let threads = if c.max_threads > 0 { threads.min(c.max_threads as usize) } else { threads };
    match plan {
        KernelPlan::Rows(rc) if c.batched => {
            gather_matmul_batched_mt_with(x, rc, batch, y, threads, c.backend)
        }
        KernelPlan::Rows(rc) => gather_matmul_mt_with(x, rc, batch, y, threads, c.backend),
        KernelPlan::Blocks(bc) => block_matmul_mt_with(x, bc, batch, y, threads, c.backend),
        KernelPlan::Csr(csr) => csr_matmul_mt_with(x, csr, batch, y, threads, c.backend),
        KernelPlan::Dense { rows, cols, w } => {
            dense_matmul_blocked_mt_with(x, w, batch, *rows, *cols, y, threads, c.backend)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::compress::{compress_blocks, compress_rows};
    use crate::sparsity::patterns::{make_block_mask, make_diag_mask, Mask};
    use crate::util::Rng;

    /// Reference masked-dense oracle.
    fn oracle(x: &[f32], w: &[f32], mask: &Mask, batch: usize) -> Vec<f32> {
        let (rows, cols) = (mask.rows, mask.cols);
        let mut y = vec![0.0f32; batch * rows];
        for b in 0..batch {
            for i in 0..rows {
                let mut acc = 0.0;
                for j in 0..cols {
                    if mask.get(i, j) {
                        acc += w[i * cols + j] * x[b * cols + j];
                    }
                }
                y[b * rows + i] = acc;
            }
        }
        y
    }

    fn max_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn all_kernels_match_oracle_on_every_backend() {
        let mut rng = Rng::new(20);
        let (batch, rows, cols) = (4, 64, 96);
        let x: Vec<f32> = (0..batch * cols).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();

        let dm = make_diag_mask(rows, cols, 9, &mut rng);
        let want = oracle(&x, &w, &dm, batch);
        let rc = compress_rows(&w, &dm, 9, None);
        let wm: Vec<f32> = (0..rows * cols)
            .map(|p| if dm.bits[p] > 0.5 { w[p] } else { 0.0 })
            .collect();
        let csr = csr_from_mask(&wm, &dm);
        let bm = make_block_mask(rows, 96, 0.25, 16, &mut rng);
        let want_b = oracle(&x, &w, &bm, batch);
        let bc = compress_blocks(&w, &bm, 16);
        let ones = Mask::ones(rows, cols);
        let want_d = oracle(&x, &w, &ones, batch);

        for &backend in Backend::all() {
            let name = backend.name();
            let mut y = vec![0.0f32; batch * rows];
            gather_matmul_with(&x, &rc, batch, &mut y, backend);
            assert!(max_diff(&y, &want) < 1e-4, "gather kernel mismatch [{name}]");

            let mut y2 = vec![0.0f32; batch * rows];
            csr_matmul_with(&x, &csr, batch, &mut y2, backend);
            assert!(max_diff(&y2, &want) < 1e-4, "csr kernel mismatch [{name}]");

            let mut y3 = vec![0.0f32; batch * rows];
            block_matmul_with(&x, &bc, batch, &mut y3, backend);
            assert!(max_diff(&y3, &want_b) < 1e-4, "block kernel mismatch [{name}]");

            let mut y5 = vec![0.0f32; batch * rows];
            dense_matmul_blocked_with(&x, &w, batch, rows, cols, &mut y5, backend);
            assert!(max_diff(&y5, &want_d) < 1e-3, "blocked dense mismatch [{name}]");
        }

        // The naive dense oracle itself (backend-free).
        let mut y4 = vec![0.0f32; batch * rows];
        dense_matmul(&x, &w, batch, rows, cols, &mut y4);
        assert!(max_diff(&y4, &want_d) < 1e-3, "dense kernel mismatch");
    }

    #[test]
    fn reindex_equals_shuffle_then_matmul() {
        // The paper's equivalence: W (P x) computed by (a) explicit shuffle
        // pass then sparse GEMM, vs (b) pre-composing P into the index
        // stream.  Both must agree bit-for-bit reorder-tolerantly.
        let mut rng = Rng::new(21);
        let (batch, rows, cols) = (3, 32, 48);
        let x: Vec<f32> = (0..batch * cols).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
        let mask = make_diag_mask(rows, cols, 5, &mut rng);
        let perm: Vec<i32> = rng.permutation(cols).iter().map(|&p| p as i32).collect();

        // (a) shuffle x then plain compressed matmul
        let mut xp = vec![0.0f32; batch * cols];
        shuffle_rows(&x, &perm, batch, cols, &mut xp);
        let rc_plain = compress_rows(&w, &mask, 5, None);
        let mut ya = vec![0.0f32; batch * rows];
        gather_matmul(&xp, &rc_plain, batch, &mut ya);

        // (b) fold perm into idx
        let rc_fused = compress_rows(&w, &mask, 5, Some(&perm));
        let mut yb = vec![0.0f32; batch * rows];
        gather_matmul(&x, &rc_fused, batch, &mut yb);

        assert!(max_diff(&ya, &yb) < 1e-5);
    }
}
