//! Scoped-thread execution layer for the native kernels.
//!
//! The paper's speedup story ("trains up to 1.21x and infers up to 2.9x
//! faster") assumes the structured kernels exploit hardware parallelism;
//! the serial kernels in this module's siblings leave every core but one
//! idle.  This layer shards the four hot GEMMs —
//! [`gather_matmul`](super::gather_matmul),
//! [`csr_matmul`](super::csr_matmul),
//! [`block_matmul`](super::block_matmul) and
//! [`dense_matmul_blocked`](super::dense_matmul_blocked) — across
//! output rows x batch using `std::thread::scope` (no extra dependencies,
//! no persistent pool to manage).
//!
//! **Determinism contract:** every output element is a per-row reduction
//! whose accumulation order is fixed by the selected microkernel
//! ([`super::micro`]); the serial kernel and its `_mt` shard run the same
//! microkernel for every element.  Sharding only changes *which thread*
//! computes an element, never the order of the f32 additions inside it,
//! so the parallel results are bit-identical to the serial kernels for
//! any thread count and any [`Backend`].  `tests/parallel_kernels.rs`
//! pins this with `to_bits` equality per backend.
//!
//! Thread-count convention used across the crate (CLI `--threads`,
//! `RunConfig::threads`, `Runtime::threads`, `PADST_THREADS`): `0` means
//! "auto" (available parallelism), `1` forces the serial path, `n > 1`
//! spawns at most `n` workers (never more than there are shard units).
//! The backend convention mirrors it: the plain `_mt` entry points run
//! [`Backend::default_backend`], the `_mt_with` variants take it
//! explicitly.

use std::thread;

use crate::sparsity::compress::{BlockCompressed, RowCompressed};

use super::csr::{csr_matmul_with, csr_row_dot, Csr};
use super::dense::{dense_matmul_blocked_with, dense_rows_blocked};
use super::gather::{
    block_matmul_with, block_row_matmul, gather_matmul_batched_with, gather_matmul_with,
};
use super::micro::{self, Backend};

pub use crate::util::cli::{available_threads, resolve_threads};

/// Thread count for benches: `--threads N` argv (cargo bench forwards args
/// after `--`), else `PADST_THREADS`, else available parallelism.  The
/// scanning itself lives in [`crate::util::cli`], shared with the CLI and
/// the sweep executor's `--workers` flag.
pub fn threads_from_env_or_args() -> usize {
    resolve_threads(crate::util::cli::thread_knob())
}

/// Split `y` into at most `threads` contiguous chunks aligned to `unit`
/// elements and run `f(first_unit_index, chunk)` on scoped threads.  Unit
/// counts differ by at most one across chunks, so load stays balanced for
/// uniform-cost units (every kernel here has uniform per-unit cost).
fn shard_units<F>(y: &mut [f32], unit: usize, threads: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(y.len() % unit.max(1), 0);
    let n_units = y.len() / unit.max(1);
    let threads = threads.clamp(1, n_units.max(1));
    if threads == 1 {
        f(0, y);
        return;
    }
    let base = n_units / threads;
    let extra = n_units % threads;
    thread::scope(|scope| {
        let fref = &f;
        let mut rest = y;
        let mut u0 = 0usize;
        for t in 0..threads {
            let units = base + usize::from(t < extra);
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(units * unit);
            rest = tail;
            let first = u0;
            scope.spawn(move || fref(first, chunk));
            u0 += units;
        }
    });
}

/// Parallel [`gather_matmul`](super::gather_matmul): output elements
/// sharded across `batch * rows`, default backend.  Bit-identical to the
/// serial kernel.
pub fn gather_matmul_mt(
    x: &[f32],
    rc: &RowCompressed,
    batch: usize,
    y: &mut [f32],
    threads: usize,
) {
    gather_matmul_mt_with(x, rc, batch, y, threads, Backend::default_backend());
}

/// [`gather_matmul_mt`] with an explicit microkernel backend.
pub fn gather_matmul_mt_with(
    x: &[f32],
    rc: &RowCompressed,
    batch: usize,
    y: &mut [f32],
    threads: usize,
    backend: Backend,
) {
    let threads = resolve_threads(threads);
    if threads <= 1 {
        gather_matmul_with(x, rc, batch, y, backend);
        return;
    }
    let (rows, cols, k) = (rc.rows, rc.cols, rc.k);
    debug_assert_eq!(x.len(), batch * cols);
    debug_assert_eq!(y.len(), batch * rows);
    shard_units(y, 1, threads, |u0, chunk| {
        // Walk the chunk as (batch-row, row-range) panels so the division
        // and the x reslice happen once per panel, not per element.
        let mut p = u0;
        let mut off = 0;
        while off < chunk.len() {
            let (b, i0) = (p / rows, p % rows);
            let take = (rows - i0).min(chunk.len() - off);
            let xb = &x[b * cols..(b + 1) * cols];
            for (d, yv) in chunk[off..off + take].iter_mut().enumerate() {
                let i = i0 + d;
                *yv = micro::dot_gather(
                    &rc.vals[i * k..(i + 1) * k],
                    &rc.idx[i * k..(i + 1) * k],
                    xb,
                    backend,
                );
            }
            p += take;
            off += take;
        }
    });
}

/// Parallel batched gather driver
/// ([`gather_matmul_batched`](super::gather_matmul_batched)): whole batch
/// rows sharded across threads, default backend.
pub fn gather_matmul_batched_mt(
    x: &[f32],
    rc: &RowCompressed,
    batch: usize,
    y: &mut [f32],
    threads: usize,
) {
    gather_matmul_batched_mt_with(x, rc, batch, y, threads, Backend::default_backend());
}

/// [`gather_matmul_batched_mt`] with an explicit microkernel backend.
/// Bit-identical to the serial batched driver *and* to the plain gather
/// kernel at any thread count: a chunk boundary only changes which batch
/// rows share a `dot_gather4` group, and each group row is required to be
/// bit-identical to the single-row `dot_gather` (the microkernel row
/// contract pinned by `tests/microkernels.rs`) — so the tuner's batched
/// axis is always bit-safe to select.
pub fn gather_matmul_batched_mt_with(
    x: &[f32],
    rc: &RowCompressed,
    batch: usize,
    y: &mut [f32],
    threads: usize,
    backend: Backend,
) {
    let threads = resolve_threads(threads);
    if threads <= 1 {
        gather_matmul_batched_with(x, rc, batch, y, backend);
        return;
    }
    let (rows, cols) = (rc.rows, rc.cols);
    debug_assert_eq!(x.len(), batch * cols);
    debug_assert_eq!(y.len(), batch * rows);
    shard_units(y, rows, threads, |b0, chunk| {
        let nb = chunk.len() / rows;
        gather_matmul_batched_with(&x[b0 * cols..(b0 + nb) * cols], rc, nb, chunk, backend);
    });
}

/// Parallel [`csr_matmul`](super::csr_matmul): output elements sharded
/// across `batch * rows`, default backend.  Bit-identical to the serial
/// kernel.
pub fn csr_matmul_mt(x: &[f32], csr: &Csr, batch: usize, y: &mut [f32], threads: usize) {
    csr_matmul_mt_with(x, csr, batch, y, threads, Backend::default_backend());
}

/// [`csr_matmul_mt`] with an explicit microkernel backend.
pub fn csr_matmul_mt_with(
    x: &[f32],
    csr: &Csr,
    batch: usize,
    y: &mut [f32],
    threads: usize,
    backend: Backend,
) {
    let threads = resolve_threads(threads);
    if threads <= 1 {
        csr_matmul_with(x, csr, batch, y, backend);
        return;
    }
    let (rows, cols) = (csr.rows, csr.cols);
    debug_assert_eq!(x.len(), batch * cols);
    debug_assert_eq!(y.len(), batch * rows);
    shard_units(y, 1, threads, |u0, chunk| {
        let mut p = u0;
        let mut off = 0;
        while off < chunk.len() {
            let (b, i0) = (p / rows, p % rows);
            let take = (rows - i0).min(chunk.len() - off);
            let xb = &x[b * cols..(b + 1) * cols];
            for (d, yv) in chunk[off..off + take].iter_mut().enumerate() {
                *yv = csr_row_dot(csr, i0 + d, xb, backend);
            }
            p += take;
            off += take;
        }
    });
}

/// Parallel [`block_matmul`](super::block_matmul): sharded across
/// `batch * block_rows`, chunk boundaries aligned to whole block-rows,
/// default backend.  Bit-identical to the serial kernel (each block-row
/// accumulates its active blocks in storage order through the same
/// microkernel).
pub fn block_matmul_mt(
    x: &[f32],
    bc: &BlockCompressed,
    batch: usize,
    y: &mut [f32],
    threads: usize,
) {
    block_matmul_mt_with(x, bc, batch, y, threads, Backend::default_backend());
}

/// [`block_matmul_mt`] with an explicit microkernel backend.
pub fn block_matmul_mt_with(
    x: &[f32],
    bc: &BlockCompressed,
    batch: usize,
    y: &mut [f32],
    threads: usize,
    backend: Backend,
) {
    let threads = resolve_threads(threads);
    if threads <= 1 {
        block_matmul_with(x, bc, batch, y, backend);
        return;
    }
    let (rows, cols, bs) = (bc.rows, bc.cols, bc.bs);
    let br = rows / bs;
    debug_assert_eq!(x.len(), batch * cols);
    debug_assert_eq!(y.len(), batch * rows);
    shard_units(y, bs, threads, |u0, chunk| {
        for (d, ys) in chunk.chunks_mut(bs).enumerate() {
            let u = u0 + d;
            let (b, bi) = (u / br, u % br);
            block_row_matmul(&x[b * cols..(b + 1) * cols], bc, bi, ys, backend);
        }
    });
}

/// Parallel [`dense_matmul_blocked`](super::dense_matmul_blocked): output
/// elements sharded across `batch * rows`, default backend; each chunk is
/// decomposed into
/// per-batch row panels and handed to the same register-blocked driver as
/// the serial kernel, so results are bit-identical (the microkernel fixes
/// each element's summation order regardless of the blocking phase).
pub fn dense_matmul_blocked_mt(
    x: &[f32],
    w: &[f32],
    batch: usize,
    rows: usize,
    cols: usize,
    y: &mut [f32],
    threads: usize,
) {
    dense_matmul_blocked_mt_with(x, w, batch, rows, cols, y, threads, Backend::default_backend());
}

/// [`dense_matmul_blocked_mt`] with an explicit microkernel backend.
pub fn dense_matmul_blocked_mt_with(
    x: &[f32],
    w: &[f32],
    batch: usize,
    rows: usize,
    cols: usize,
    y: &mut [f32],
    threads: usize,
    backend: Backend,
) {
    let threads = resolve_threads(threads);
    if threads <= 1 {
        dense_matmul_blocked_with(x, w, batch, rows, cols, y, backend);
        return;
    }
    debug_assert_eq!(x.len(), batch * cols);
    debug_assert_eq!(w.len(), rows * cols);
    debug_assert_eq!(y.len(), batch * rows);
    shard_units(y, 1, threads, |u0, chunk| {
        let mut p = u0;
        let mut off = 0;
        while off < chunk.len() {
            let (b, i0) = (p / rows, p % rows);
            let take = (rows - i0).min(chunk.len() - off);
            let xb = &x[b * cols..(b + 1) * cols];
            dense_rows_blocked(
                xb,
                &w[i0 * cols..(i0 + take) * cols],
                cols,
                &mut chunk[off..off + take],
                backend,
            );
            p += take;
            off += take;
        }
    });
}

/// Order-preserving parallel map over owned items with at most `threads`
/// workers (0 = auto).  Used by the coordinator/CLI for embarrassingly
/// parallel host-side work (NLR table rows, per-site compression).
pub fn parallel_map<T, U, F>(items: Vec<T>, threads: usize, f: F) -> Vec<U>
where
    T: Send,
    U: Send,
    F: Fn(T) -> U + Sync,
{
    let n = items.len();
    let threads = resolve_threads(threads).clamp(1, n.max(1));
    if threads == 1 {
        return items.into_iter().map(f).collect();
    }
    let mut slots: Vec<Option<T>> = items.into_iter().map(Some).collect();
    let mut out: Vec<Option<U>> = (0..n).map(|_| None).collect();
    let base = n / threads;
    let extra = n % threads;
    thread::scope(|scope| {
        let fref = &f;
        let mut in_rest = slots.as_mut_slice();
        let mut out_rest = out.as_mut_slice();
        for t in 0..threads {
            let len = base + usize::from(t < extra);
            let (in_chunk, in_tail) = std::mem::take(&mut in_rest).split_at_mut(len);
            let (out_chunk, out_tail) = std::mem::take(&mut out_rest).split_at_mut(len);
            in_rest = in_tail;
            out_rest = out_tail;
            scope.spawn(move || {
                for (slot_in, slot_out) in in_chunk.iter_mut().zip(out_chunk) {
                    *slot_out = Some(fref(slot_in.take().expect("item taken twice")));
                }
            });
        }
    });
    out.into_iter().map(|u| u.expect("worker missed a slot")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::csr_from_mask;
    use crate::kernels::{block_matmul, csr_matmul, dense_matmul_blocked, gather_matmul};
    use crate::sparsity::compress::{compress_blocks, compress_rows};
    use crate::sparsity::patterns::{make_block_mask, make_diag_mask, make_unstructured_mask};
    use crate::util::Rng;

    #[test]
    fn resolve_zero_is_auto() {
        assert_eq!(resolve_threads(0), available_threads());
        assert_eq!(resolve_threads(3), 3);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..37).collect();
        for threads in [1, 2, 5, 64] {
            let got = parallel_map(items.clone(), threads, |i| i * i);
            let want: Vec<usize> = items.iter().map(|&i| i * i).collect();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn shard_units_covers_everything_once() {
        let mut y = vec![0.0f32; 103];
        shard_units(&mut y, 1, 7, |u0, chunk| {
            for (d, v) in chunk.iter_mut().enumerate() {
                *v += (u0 + d) as f32;
            }
        });
        for (i, &v) in y.iter().enumerate() {
            assert_eq!(v, i as f32);
        }
    }

    /// Smoke-level bitwise check on the default-backend entry points (the
    /// exhaustive per-backend sweep lives in tests/parallel_kernels.rs).
    #[test]
    fn mt_kernels_match_serial_bitwise() {
        let mut rng = Rng::new(77);
        let (batch, rows, cols) = (5, 64, 96);
        let x: Vec<f32> = (0..batch * cols).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();

        let dm = make_diag_mask(rows, cols, 7, &mut rng);
        let rc = compress_rows(&w, &dm, 7, None);
        let mut ys = vec![0.0f32; batch * rows];
        let mut ym = vec![0.0f32; batch * rows];
        gather_matmul(&x, &rc, batch, &mut ys);
        gather_matmul_mt(&x, &rc, batch, &mut ym, 3);
        assert!(ys.iter().zip(&ym).all(|(a, b)| a.to_bits() == b.to_bits()));

        let um = make_unstructured_mask(rows, cols, 0.2, &mut rng);
        let csr = csr_from_mask(&w, &um);
        csr_matmul(&x, &csr, batch, &mut ys);
        csr_matmul_mt(&x, &csr, batch, &mut ym, 3);
        assert!(ys.iter().zip(&ym).all(|(a, b)| a.to_bits() == b.to_bits()));

        let bm = make_block_mask(rows, cols, 0.25, 16, &mut rng);
        let bc = compress_blocks(&w, &bm, 16);
        block_matmul(&x, &bc, batch, &mut ys);
        block_matmul_mt(&x, &bc, batch, &mut ym, 3);
        assert!(ys.iter().zip(&ym).all(|(a, b)| a.to_bits() == b.to_bits()));

        dense_matmul_blocked(&x, &w, batch, rows, cols, &mut ys);
        dense_matmul_blocked_mt(&x, &w, batch, rows, cols, &mut ym, 3);
        assert!(ys.iter().zip(&ym).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    /// The tuner's batched axis: sharded 4-row-grouped batches must be
    /// bit-identical to the plain serial gather kernel at any chunk phase
    /// (batch 9 across 2/3/8 workers lands every group-boundary offset).
    #[test]
    fn batched_mt_matches_plain_gather_bitwise() {
        let mut rng = Rng::new(42);
        let (batch, rows, cols) = (9, 48, 64);
        let x: Vec<f32> = (0..batch * cols).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
        let dm = make_diag_mask(rows, cols, 5, &mut rng);
        let rc = compress_rows(&w, &dm, 5, None);
        let mut ys = vec![0.0f32; batch * rows];
        let mut ym = vec![0.0f32; batch * rows];
        gather_matmul(&x, &rc, batch, &mut ys);
        for threads in [1, 2, 3, 8] {
            ym.iter_mut().for_each(|v| *v = 0.0);
            gather_matmul_batched_mt(&x, &rc, batch, &mut ym, threads);
            assert!(
                ys.iter().zip(&ym).all(|(a, b)| a.to_bits() == b.to_bits()),
                "threads={threads}"
            );
        }
    }
}
