//! Dense GEMM baselines + the explicit permutation-shuffle pass.
//!
//! `dense_matmul` is the naive triple loop (kept as a backend-free
//! correctness oracle); `dense_matmul_blocked` is the production baseline
//! the sparse kernels must beat for the Fig. 3 speedup curves to be
//! honest: a thin driver blocking 4 output rows per
//! [`micro::dot_rows4`](super::micro::dot_rows4) call, with the inner
//! summation owned by the selected [`Backend`].

use super::micro::{self, Backend};

/// y[b, i] = sum_j w[i, j] * x[b, j]  — naive, correctness oracle.
pub fn dense_matmul(
    x: &[f32],
    w: &[f32],
    batch: usize,
    rows: usize,
    cols: usize,
    y: &mut [f32],
) {
    debug_assert_eq!(x.len(), batch * cols);
    debug_assert_eq!(w.len(), rows * cols);
    debug_assert_eq!(y.len(), batch * rows);
    for b in 0..batch {
        let xb = &x[b * cols..(b + 1) * cols];
        for i in 0..rows {
            let wi = &w[i * cols..(i + 1) * cols];
            let mut acc = 0.0f32;
            for j in 0..cols {
                acc += wi[j] * xb[j];
            }
            y[b * rows + i] = acc;
        }
    }
}

/// Register-blocked panel: `y_out[i] = dot(w_rows[i], xb)` for a contiguous
/// run of output rows, 4 rows per microkernel call.  Each output element's
/// summation order is fixed by the microkernel alone (row `i` of
/// `dot_rows4` == the single-row `dot`, bit-for-bit), so results do not
/// depend on the blocking phase — sharding a row range across threads and
/// re-running this panel on each chunk reproduces the serial numbers
/// bit-for-bit for any backend.
#[inline(always)]
pub(crate) fn dense_rows_blocked(
    xb: &[f32],
    w_rows: &[f32],
    cols: usize,
    y_out: &mut [f32],
    backend: Backend,
) {
    const RB: usize = 4;
    let rows = y_out.len();
    debug_assert_eq!(w_rows.len(), rows * cols);
    let mut i = 0;
    while i + RB <= rows {
        let [a0, a1, a2, a3] = micro::dot_rows4(
            &w_rows[i * cols..(i + 1) * cols],
            &w_rows[(i + 1) * cols..(i + 2) * cols],
            &w_rows[(i + 2) * cols..(i + 3) * cols],
            &w_rows[(i + 3) * cols..(i + 4) * cols],
            xb,
            backend,
        );
        y_out[i] = a0;
        y_out[i + 1] = a1;
        y_out[i + 2] = a2;
        y_out[i + 3] = a3;
        i += RB;
    }
    while i < rows {
        y_out[i] = micro::dot(&w_rows[i * cols..(i + 1) * cols], xb, backend);
        i += 1;
    }
}

/// Production dense baseline: 4-row register blocking over the selected
/// microkernel, default backend.
pub fn dense_matmul_blocked(
    x: &[f32],
    w: &[f32],
    batch: usize,
    rows: usize,
    cols: usize,
    y: &mut [f32],
) {
    dense_matmul_blocked_with(x, w, batch, rows, cols, y, Backend::default_backend());
}

/// [`dense_matmul_blocked`] with an explicit microkernel backend.
pub fn dense_matmul_blocked_with(
    x: &[f32],
    w: &[f32],
    batch: usize,
    rows: usize,
    cols: usize,
    y: &mut [f32],
    backend: Backend,
) {
    debug_assert_eq!(x.len(), batch * cols);
    debug_assert_eq!(w.len(), rows * cols);
    debug_assert_eq!(y.len(), batch * rows);
    for b in 0..batch {
        let xb = &x[b * cols..(b + 1) * cols];
        dense_rows_blocked(xb, w, cols, &mut y[b * rows..(b + 1) * rows], backend);
    }
}

/// Explicit permutation pass: out[b, i] = x[b, perm[i]] — the extra
/// memory sweep a permutation *multiply* costs (the strawman of Sec. 4.3;
/// a permutation matmul degenerates to exactly this gather once you skip
/// the zero multiplies, so this is its best case).
pub fn shuffle_rows(x: &[f32], perm: &[i32], batch: usize, cols: usize, out: &mut [f32]) {
    debug_assert_eq!(perm.len(), cols);
    debug_assert_eq!(x.len(), batch * cols);
    debug_assert_eq!(out.len(), batch * cols);
    for b in 0..batch {
        let xb = &x[b * cols..(b + 1) * cols];
        let ob = &mut out[b * cols..(b + 1) * cols];
        for i in 0..cols {
            ob[i] = xb[perm[i] as usize];
        }
    }
}

/// Dense permutation-matrix multiply (the truly naive strawman: N^2 MACs
/// per batch row).  Only used by the overhead benches for scale.
pub fn perm_matmul(x: &[f32], p: &[f32], batch: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(p.len(), n * n);
    for b in 0..batch {
        let xb = &x[b * n..(b + 1) * n];
        let ob = &mut out[b * n..(b + 1) * n];
        for i in 0..n {
            let pi = &p[i * n..(i + 1) * n];
            let mut acc = 0.0f32;
            for j in 0..n {
                acc += pi[j] * xb[j];
            }
            ob[i] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn blocked_matches_naive_per_backend() {
        let mut rng = Rng::new(30);
        for (b, r, c) in [(1, 7, 13), (3, 64, 96), (2, 33, 65)] {
            let x: Vec<f32> = (0..b * c).map(|_| rng.normal()).collect();
            let w: Vec<f32> = (0..r * c).map(|_| rng.normal()).collect();
            let mut y1 = vec![0.0; b * r];
            dense_matmul(&x, &w, b, r, c, &mut y1);
            for &backend in Backend::all() {
                let mut y2 = vec![0.0; b * r];
                dense_matmul_blocked_with(&x, &w, b, r, c, &mut y2, backend);
                let d = y1
                    .iter()
                    .zip(&y2)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(d < 1e-4, "({b},{r},{c}) {}: {d}", backend.name());
            }
        }
    }

    #[test]
    fn shuffle_equals_perm_matmul() {
        let mut rng = Rng::new(31);
        let n = 24;
        let batch = 2;
        let x: Vec<f32> = (0..batch * n).map(|_| rng.normal()).collect();
        let idx: Vec<i32> = rng.permutation(n).iter().map(|&i| i as i32).collect();
        let mut pmat = vec![0.0f32; n * n];
        for (i, &j) in idx.iter().enumerate() {
            pmat[i * n + j as usize] = 1.0;
        }
        let mut a = vec![0.0; batch * n];
        let mut b = vec![0.0; batch * n];
        shuffle_rows(&x, &idx, batch, n, &mut a);
        perm_matmul(&x, &pmat, batch, n, &mut b);
        assert_eq!(a, b);
    }
}
