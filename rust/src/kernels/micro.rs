//! Microkernel layer: the innermost reduction bodies every matmul driver
//! in this module tree is a thin loop over, selected at runtime through
//! [`Backend`].
//!
//! The split exists because the Fig. 3 speedup argument is only as strong
//! as the GFLOP/s of the inner loops: the drivers (`gather_matmul`,
//! `block_matmul`, `csr_matmul`, `dense_matmul_blocked` and their `_mt`
//! shards) own *which* dot products are computed, while a [`MicroKernel`]
//! owns *how one dot product is summed*.  Three implementations:
//!
//! * **Scalar** — single-accumulator loops in strict index order.  The
//!   reference: slow, but the summation every other backend is compared
//!   against (within tolerance) and the fallback CI keeps honest via
//!   `PADST_BACKEND=scalar`.
//! * **Tiled** (default) — hand-tiled 8-wide lane accumulators with
//!   explicit tail handling, on stable Rust.  The independent lanes break
//!   the f32 add dependency chain, which is what lets the compiler keep
//!   the loop in vector registers (and an out-of-order core overlap the
//!   multiplies even where it cannot vectorise the gather loads).
//! * **Simd** — the same shapes expressed in `std::simd` (`f32x8`),
//!   compiled only with `--features nightly-simd` on a nightly toolchain.
//!   Without the feature a Simd request degrades to Tiled.
//!
//! **Bit-identity contract.**  Each implementation fixes one summation
//! order per dot shape, and the multi-row shapes (`dot_rows4`,
//! `dot_gather4`) are required to reproduce the single-row shapes
//! *bit-for-bit* per row (pinned by `tests/microkernels.rs`).  Drivers
//! guarantee that a serial kernel and its `_mt` shard run the *same*
//! microkernel for every output element, so results are bit-identical
//! across thread counts for any backend — the contract
//! `tests/parallel_kernels.rs` enforces per backend.  Across *backends*
//! the summation order legitimately differs; equivalence is 1e-4-level,
//! not bitwise.

use std::sync::OnceLock;

/// Lane width of the tiled/SIMD microkernels (f32x8 = one AVX2 register).
pub const LANES: usize = 8;

/// Which microkernel implementation the drivers dispatch to.
///
/// Resolution order for the process default ([`Backend::default_backend`]):
/// the `PADST_BACKEND` env var (`scalar` | `tiled` | `simd`), else
/// [`Backend::Tiled`].  CLI front-ends layer an explicit `--backend` flag
/// on top via [`Backend::resolve`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Backend {
    /// Strict-order single-accumulator reference loops.
    Scalar,
    /// Hand-tiled 8-lane accumulators on stable Rust (the default).
    #[default]
    Tiled,
    /// `std::simd` f32x8 (requires the `nightly-simd` feature; degrades to
    /// Tiled otherwise).
    Simd,
}

impl Backend {
    /// Parse a knob value (case-insensitive); `None` for unknown names.
    pub fn parse(s: &str) -> Option<Backend> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Some(Backend::Scalar),
            "tiled" => Some(Backend::Tiled),
            "simd" => Some(Backend::Simd),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Tiled => "tiled",
            Backend::Simd => "simd",
        }
    }

    /// Whether the `std::simd` implementation was compiled in.
    pub fn simd_compiled() -> bool {
        cfg!(feature = "nightly-simd")
    }

    /// The backend that will actually run: Simd degrades to Tiled when the
    /// `nightly-simd` feature is not compiled in.
    pub fn effective(self) -> Backend {
        if self == Backend::Simd && !Self::simd_compiled() {
            Backend::Tiled
        } else {
            self
        }
    }

    /// Every backend runnable in this build, Scalar first.  Test sweeps
    /// and the bench backend matrix iterate this.
    pub fn all() -> &'static [Backend] {
        if Self::simd_compiled() {
            &[Backend::Scalar, Backend::Tiled, Backend::Simd]
        } else {
            &[Backend::Scalar, Backend::Tiled]
        }
    }

    /// Resolve the backend knob: an explicit value (CLI `--backend`) wins
    /// over `PADST_BACKEND`, else the default (Tiled).  Unknown names and
    /// a Simd request in a build without `nightly-simd` warn on stderr and
    /// degrade rather than abort — benches and env-driven test runs should
    /// not die over a knob.  CLI front-ends that prefer a hard error parse
    /// the flag themselves via [`Backend::parse`].
    pub fn resolve(explicit: Option<&str>) -> Backend {
        let src = match explicit {
            Some(s) => Some(s.to_string()),
            None => std::env::var("PADST_BACKEND").ok(),
        };
        match src {
            Some(s) if !s.is_empty() => match Backend::parse(&s) {
                Some(b) => {
                    let eff = b.effective();
                    if eff != b {
                        eprintln!(
                            "[padst] backend {s:?} needs a build with --features nightly-simd; \
                             using {}",
                            eff.name()
                        );
                    }
                    eff
                }
                None => {
                    eprintln!(
                        "[padst] unknown backend {s:?} (expected scalar|tiled|simd); using {}",
                        Backend::default().name()
                    );
                    Backend::default()
                }
            },
            _ => Backend::default(),
        }
    }

    /// `PADST_BACKEND`-resolved backend (uncached form of
    /// [`Backend::default_backend`]).
    pub fn from_env() -> Backend {
        Backend::resolve(None)
    }

    /// The process-wide default backend, resolved from `PADST_BACKEND`
    /// once and cached.  The plain kernel entry points (`gather_matmul`,
    /// `block_matmul`, ...) and `RunConfig::default` use this, which is
    /// what lets CI run the whole default test suite under
    /// `PADST_BACKEND=scalar`.
    ///
    /// Full resolution order across the crate: an explicit CLI `--backend`
    /// flag wins over a spec-level backend, which wins over
    /// `PADST_BACKEND`, which wins over a tuning-table choice
    /// ([`crate::kernels::tune`]), which wins over this default.  The
    /// first three sources *pin* the backend — the tuner then only varies
    /// bit-preserving dispatch axes (batching, thread caps), never the
    /// backend itself (see `tune::resolve_backend_precedence`).
    pub fn default_backend() -> Backend {
        static CACHE: OnceLock<Backend> = OnceLock::new();
        *CACHE.get_or_init(Backend::from_env)
    }
}

/// One microkernel implementation: a fixed summation order for each dot
/// shape the drivers need.
///
/// Invariant (pinned by `tests/microkernels.rs`): row `i` of
/// [`MicroKernel::dot_rows4`] / [`MicroKernel::dot_gather4`] is
/// bit-identical to the corresponding single-row call.  The `_mt` drivers
/// rely on this — a sharded chunk boundary may fall anywhere inside a
/// 4-row register block, and the split must not change any output bit.
pub trait MicroKernel {
    /// Contiguous dot product: `sum_j a[j] * b[j]` (lengths must match).
    fn dot(a: &[f32], b: &[f32]) -> f32;

    /// Gather dot product: `sum_s vals[s] * x[idx[s]]` (the row form of
    /// every index-stream kernel; any permutation is pre-composed into
    /// `idx`).
    fn dot_gather(vals: &[f32], idx: &[i32], x: &[f32]) -> f32;

    /// Four gather dots sharing one index stream (batch amortisation).
    /// Default: four independent [`MicroKernel::dot_gather`] calls.
    fn dot_gather4(
        vals: &[f32],
        idx: &[i32],
        x0: &[f32],
        x1: &[f32],
        x2: &[f32],
        x3: &[f32],
    ) -> [f32; 4] {
        [
            Self::dot_gather(vals, idx, x0),
            Self::dot_gather(vals, idx, x1),
            Self::dot_gather(vals, idx, x2),
            Self::dot_gather(vals, idx, x3),
        ]
    }

    /// Four contiguous dots against one shared `x` (register blocking
    /// over output rows).  Default: four independent [`MicroKernel::dot`]
    /// calls.
    fn dot_rows4(w0: &[f32], w1: &[f32], w2: &[f32], w3: &[f32], x: &[f32]) -> [f32; 4] {
        [Self::dot(w0, x), Self::dot(w1, x), Self::dot(w2, x), Self::dot(w3, x)]
    }
}

/// Pairwise reduction of the 8 lane accumulators — one fixed tree, shared
/// by every Tiled shape so multi-row and single-row results agree bitwise.
#[inline(always)]
fn reduce8(l: &[f32; LANES]) -> f32 {
    ((l[0] + l[1]) + (l[2] + l[3])) + ((l[4] + l[5]) + (l[6] + l[7]))
}

/// Strict-order reference loops.
pub struct ScalarKernel;

impl MicroKernel for ScalarKernel {
    #[inline(always)]
    fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = 0.0f32;
        for (x, y) in a.iter().zip(b) {
            acc += x * y;
        }
        acc
    }

    #[inline(always)]
    fn dot_gather(vals: &[f32], idx: &[i32], x: &[f32]) -> f32 {
        debug_assert_eq!(vals.len(), idx.len());
        let mut acc = 0.0f32;
        for (v, &j) in vals.iter().zip(idx) {
            acc += v * x[j as usize];
        }
        acc
    }
}

/// Hand-tiled stable-Rust implementation: 8 independent lane accumulators
/// walked over `chunks_exact(8)` panels, explicit scalar tail, pairwise
/// lane reduction ([`reduce8`]).
pub struct TiledKernel;

impl MicroKernel for TiledKernel {
    #[inline(always)]
    fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let mut lanes = [0.0f32; LANES];
        let mut ca = a.chunks_exact(LANES);
        let mut cb = b.chunks_exact(LANES);
        for (pa, pb) in (&mut ca).zip(&mut cb) {
            for s in 0..LANES {
                lanes[s] += pa[s] * pb[s];
            }
        }
        let mut tail = 0.0f32;
        for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
            tail += x * y;
        }
        reduce8(&lanes) + tail
    }

    #[inline(always)]
    fn dot_gather(vals: &[f32], idx: &[i32], x: &[f32]) -> f32 {
        debug_assert_eq!(vals.len(), idx.len());
        let mut lanes = [0.0f32; LANES];
        let mut cv = vals.chunks_exact(LANES);
        let mut ci = idx.chunks_exact(LANES);
        for (pv, pi) in (&mut cv).zip(&mut ci) {
            for s in 0..LANES {
                lanes[s] += pv[s] * x[pi[s] as usize];
            }
        }
        let mut tail = 0.0f32;
        for (v, &j) in cv.remainder().iter().zip(ci.remainder()) {
            tail += v * x[j as usize];
        }
        reduce8(&lanes) + tail
    }

    #[inline(always)]
    fn dot_gather4(
        vals: &[f32],
        idx: &[i32],
        x0: &[f32],
        x1: &[f32],
        x2: &[f32],
        x3: &[f32],
    ) -> [f32; 4] {
        debug_assert_eq!(vals.len(), idx.len());
        // Four batch rows share every index fetch; per row the lane walk
        // and tail are exactly `dot_gather`'s, so each output bit matches
        // the single-row call.
        let mut lanes = [[0.0f32; LANES]; 4];
        let mut cv = vals.chunks_exact(LANES);
        let mut ci = idx.chunks_exact(LANES);
        for (pv, pi) in (&mut cv).zip(&mut ci) {
            for s in 0..LANES {
                let j = pi[s] as usize;
                let v = pv[s];
                lanes[0][s] += v * x0[j];
                lanes[1][s] += v * x1[j];
                lanes[2][s] += v * x2[j];
                lanes[3][s] += v * x3[j];
            }
        }
        let mut tail = [0.0f32; 4];
        for (v, &ji) in cv.remainder().iter().zip(ci.remainder()) {
            let j = ji as usize;
            tail[0] += v * x0[j];
            tail[1] += v * x1[j];
            tail[2] += v * x2[j];
            tail[3] += v * x3[j];
        }
        [
            reduce8(&lanes[0]) + tail[0],
            reduce8(&lanes[1]) + tail[1],
            reduce8(&lanes[2]) + tail[2],
            reduce8(&lanes[3]) + tail[3],
        ]
    }

    #[inline(always)]
    fn dot_rows4(w0: &[f32], w1: &[f32], w2: &[f32], w3: &[f32], x: &[f32]) -> [f32; 4] {
        let n = x.len();
        debug_assert!(
            w0.len() == n && w1.len() == n && w2.len() == n && w3.len() == n,
            "dot_rows4: row length mismatch"
        );
        // Four rows share every x load; per row this is exactly `dot`'s
        // lane walk + tail, so splitting a 4-row block apart (as the `_mt`
        // shards may) cannot change any output bit.
        let mut lanes = [[0.0f32; LANES]; 4];
        let mut i = 0;
        while i + LANES <= n {
            for s in 0..LANES {
                let xv = x[i + s];
                lanes[0][s] += w0[i + s] * xv;
                lanes[1][s] += w1[i + s] * xv;
                lanes[2][s] += w2[i + s] * xv;
                lanes[3][s] += w3[i + s] * xv;
            }
            i += LANES;
        }
        let mut tail = [0.0f32; 4];
        while i < n {
            let xv = x[i];
            tail[0] += w0[i] * xv;
            tail[1] += w1[i] * xv;
            tail[2] += w2[i] * xv;
            tail[3] += w3[i] * xv;
            i += 1;
        }
        [
            reduce8(&lanes[0]) + tail[0],
            reduce8(&lanes[1]) + tail[1],
            reduce8(&lanes[2]) + tail[2],
            reduce8(&lanes[3]) + tail[3],
        ]
    }
}

#[cfg(feature = "nightly-simd")]
mod simd_impl {
    //! `std::simd` twin of [`TiledKernel`](super::TiledKernel): the lane
    //! accumulator array
    //! becomes one `f32x8`, the pairwise lane reduction becomes
    //! `reduce_sum()`.  Per shape the chunking and tail order mirror the
    //! tiled code exactly, so the rows4/gather4 == single-row bit contract
    //! holds here too (`reduce_sum`'s internal tree is fixed per type).

    use std::simd::f32x8;
    use std::simd::num::SimdFloat;

    use super::{MicroKernel, LANES};

    pub struct SimdKernel;

    impl MicroKernel for SimdKernel {
        #[inline(always)]
        fn dot(a: &[f32], b: &[f32]) -> f32 {
            debug_assert_eq!(a.len(), b.len());
            let mut acc = f32x8::splat(0.0);
            let mut ca = a.chunks_exact(LANES);
            let mut cb = b.chunks_exact(LANES);
            for (pa, pb) in (&mut ca).zip(&mut cb) {
                acc += f32x8::from_slice(pa) * f32x8::from_slice(pb);
            }
            let mut tail = 0.0f32;
            for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
                tail += x * y;
            }
            acc.reduce_sum() + tail
        }

        #[inline(always)]
        fn dot_gather(vals: &[f32], idx: &[i32], x: &[f32]) -> f32 {
            debug_assert_eq!(vals.len(), idx.len());
            let mut acc = f32x8::splat(0.0);
            let mut cv = vals.chunks_exact(LANES);
            let mut ci = idx.chunks_exact(LANES);
            for (pv, pi) in (&mut cv).zip(&mut ci) {
                let g = f32x8::from_array([
                    x[pi[0] as usize],
                    x[pi[1] as usize],
                    x[pi[2] as usize],
                    x[pi[3] as usize],
                    x[pi[4] as usize],
                    x[pi[5] as usize],
                    x[pi[6] as usize],
                    x[pi[7] as usize],
                ]);
                acc += f32x8::from_slice(pv) * g;
            }
            let mut tail = 0.0f32;
            for (v, &j) in cv.remainder().iter().zip(ci.remainder()) {
                tail += v * x[j as usize];
            }
            acc.reduce_sum() + tail
        }

        #[inline(always)]
        fn dot_gather4(
            vals: &[f32],
            idx: &[i32],
            x0: &[f32],
            x1: &[f32],
            x2: &[f32],
            x3: &[f32],
        ) -> [f32; 4] {
            debug_assert_eq!(vals.len(), idx.len());
            // Four batch rows share every index fetch, like the tiled
            // twin; per row the accumulation order is exactly
            // `dot_gather`'s, preserving the bitwise row contract.
            let mut acc = [f32x8::splat(0.0); 4];
            let mut cv = vals.chunks_exact(LANES);
            let mut ci = idx.chunks_exact(LANES);
            for (pv, pi) in (&mut cv).zip(&mut ci) {
                let vv = f32x8::from_slice(pv);
                let gather = |x: &[f32]| {
                    f32x8::from_array([
                        x[pi[0] as usize],
                        x[pi[1] as usize],
                        x[pi[2] as usize],
                        x[pi[3] as usize],
                        x[pi[4] as usize],
                        x[pi[5] as usize],
                        x[pi[6] as usize],
                        x[pi[7] as usize],
                    ])
                };
                acc[0] += vv * gather(x0);
                acc[1] += vv * gather(x1);
                acc[2] += vv * gather(x2);
                acc[3] += vv * gather(x3);
            }
            let mut tail = [0.0f32; 4];
            for (v, &ji) in cv.remainder().iter().zip(ci.remainder()) {
                let j = ji as usize;
                tail[0] += v * x0[j];
                tail[1] += v * x1[j];
                tail[2] += v * x2[j];
                tail[3] += v * x3[j];
            }
            [
                acc[0].reduce_sum() + tail[0],
                acc[1].reduce_sum() + tail[1],
                acc[2].reduce_sum() + tail[2],
                acc[3].reduce_sum() + tail[3],
            ]
        }

        #[inline(always)]
        fn dot_rows4(w0: &[f32], w1: &[f32], w2: &[f32], w3: &[f32], x: &[f32]) -> [f32; 4] {
            let n = x.len();
            debug_assert!(w0.len() == n && w1.len() == n && w2.len() == n && w3.len() == n);
            let mut acc = [f32x8::splat(0.0); 4];
            let mut i = 0;
            while i + LANES <= n {
                let xv = f32x8::from_slice(&x[i..i + LANES]);
                acc[0] += f32x8::from_slice(&w0[i..i + LANES]) * xv;
                acc[1] += f32x8::from_slice(&w1[i..i + LANES]) * xv;
                acc[2] += f32x8::from_slice(&w2[i..i + LANES]) * xv;
                acc[3] += f32x8::from_slice(&w3[i..i + LANES]) * xv;
                i += LANES;
            }
            let mut tail = [0.0f32; 4];
            while i < n {
                let xv = x[i];
                tail[0] += w0[i] * xv;
                tail[1] += w1[i] * xv;
                tail[2] += w2[i] * xv;
                tail[3] += w3[i] * xv;
                i += 1;
            }
            [
                acc[0].reduce_sum() + tail[0],
                acc[1].reduce_sum() + tail[1],
                acc[2].reduce_sum() + tail[2],
                acc[3].reduce_sum() + tail[3],
            ]
        }
    }
}

#[cfg(feature = "nightly-simd")]
pub use simd_impl::SimdKernel;

// ------------------------------------------------------------------ dispatch
//
// One `match` per dot shape; drivers call these with a `Backend` value.
// `effective()` maps Simd to Tiled in builds without the feature, so the
// `cfg(not(...))` arms below are unreachable but keep the match total.

/// Dispatching [`MicroKernel::dot`].
#[inline]
pub fn dot(a: &[f32], b: &[f32], backend: Backend) -> f32 {
    match backend.effective() {
        Backend::Scalar => ScalarKernel::dot(a, b),
        Backend::Tiled => TiledKernel::dot(a, b),
        #[cfg(feature = "nightly-simd")]
        Backend::Simd => SimdKernel::dot(a, b),
        #[cfg(not(feature = "nightly-simd"))]
        Backend::Simd => TiledKernel::dot(a, b),
    }
}

/// Dispatching [`MicroKernel::dot_gather`].
#[inline]
pub fn dot_gather(vals: &[f32], idx: &[i32], x: &[f32], backend: Backend) -> f32 {
    match backend.effective() {
        Backend::Scalar => ScalarKernel::dot_gather(vals, idx, x),
        Backend::Tiled => TiledKernel::dot_gather(vals, idx, x),
        #[cfg(feature = "nightly-simd")]
        Backend::Simd => SimdKernel::dot_gather(vals, idx, x),
        #[cfg(not(feature = "nightly-simd"))]
        Backend::Simd => TiledKernel::dot_gather(vals, idx, x),
    }
}

/// Dispatching [`MicroKernel::dot_gather4`].
#[inline]
pub fn dot_gather4(
    vals: &[f32],
    idx: &[i32],
    x0: &[f32],
    x1: &[f32],
    x2: &[f32],
    x3: &[f32],
    backend: Backend,
) -> [f32; 4] {
    match backend.effective() {
        Backend::Scalar => ScalarKernel::dot_gather4(vals, idx, x0, x1, x2, x3),
        Backend::Tiled => TiledKernel::dot_gather4(vals, idx, x0, x1, x2, x3),
        #[cfg(feature = "nightly-simd")]
        Backend::Simd => SimdKernel::dot_gather4(vals, idx, x0, x1, x2, x3),
        #[cfg(not(feature = "nightly-simd"))]
        Backend::Simd => TiledKernel::dot_gather4(vals, idx, x0, x1, x2, x3),
    }
}

/// Dispatching [`MicroKernel::dot_rows4`].
#[inline]
pub fn dot_rows4(
    w0: &[f32],
    w1: &[f32],
    w2: &[f32],
    w3: &[f32],
    x: &[f32],
    backend: Backend,
) -> [f32; 4] {
    match backend.effective() {
        Backend::Scalar => ScalarKernel::dot_rows4(w0, w1, w2, w3, x),
        Backend::Tiled => TiledKernel::dot_rows4(w0, w1, w2, w3, x),
        #[cfg(feature = "nightly-simd")]
        Backend::Simd => SimdKernel::dot_rows4(w0, w1, w2, w3, x),
        #[cfg(not(feature = "nightly-simd"))]
        Backend::Simd => TiledKernel::dot_rows4(w0, w1, w2, w3, x),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_name_roundtrip() {
        for &b in Backend::all() {
            assert_eq!(Backend::parse(b.name()), Some(b));
        }
        assert_eq!(Backend::parse("TILED"), Some(Backend::Tiled));
        assert_eq!(Backend::parse("avx512"), None);
    }

    #[test]
    fn resolve_explicit_wins_and_degrades() {
        assert_eq!(Backend::resolve(Some("scalar")), Backend::Scalar);
        assert_eq!(Backend::resolve(Some("nonsense")), Backend::Tiled);
        // Simd resolves to itself when compiled, Tiled otherwise.
        assert_eq!(Backend::resolve(Some("simd")), Backend::Simd.effective());
    }

    #[test]
    fn all_contains_scalar_and_tiled() {
        let all = Backend::all();
        assert!(all.contains(&Backend::Scalar));
        assert!(all.contains(&Backend::Tiled));
        assert_eq!(all.contains(&Backend::Simd), Backend::simd_compiled());
    }

    #[test]
    fn reduce8_is_a_fixed_tree() {
        let l = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        assert_eq!(reduce8(&l), 36.0);
    }

    #[test]
    fn tiled_dot_matches_scalar_closely() {
        // Deterministic non-trivial vectors covering a tail (len 19).
        let a: Vec<f32> = (0..19).map(|i| (i as f32 * 0.37).sin()).collect();
        let b: Vec<f32> = (0..19).map(|i| (i as f32 * 0.73).cos()).collect();
        let s = ScalarKernel::dot(&a, &b);
        let t = TiledKernel::dot(&a, &b);
        assert!((s - t).abs() < 1e-5, "{s} vs {t}");
    }
}
