//! Kernel autotuner: a persistent per-shape tuning table consulted by
//! [`run_plan`](super::run_plan) / [`run_plan_mt`](super::run_plan_mt).
//!
//! The fastest microkernel configuration varies with (plan kind, layer
//! geometry, backend availability, thread count) — a single global
//! [`Backend`] default leaves performance on the table for every pattern
//! family.  This module closes that gap without touching the numerics:
//!
//! * **Key** ([`TuneKey`]): `(plan kind, ceil-log2 buckets of
//!   rows/cols/panel, ceil-log2 thread bucket, simd-compiled bit)`.
//!   Bucketing by powers of two keeps the table tiny and lets one offline
//!   sweep cover every geometry a model will actually serve.  Keys pack
//!   into a single `u64` ([`TuneKey::pack`]) so the hot-path probe is an
//!   integer map lookup — no allocation, no string formatting.
//! * **Choice** ([`Choice`]): the variant triple `(backend, row-blocking
//!   batched `dot_gather4` vs plain `dot_gather`, mt thread cap)`.  The
//!   batched and thread-cap axes are *bit-preserving* per backend (pinned
//!   by `tests/microkernels.rs` / `tests/parallel_kernels.rs`), so a table
//!   hit never changes output bits unless it changes the backend — and it
//!   only changes the backend when the caller's backend is unpinned (see
//!   [`backend_pinned`]).
//! * **Measurement** ([`tune_plan`]): short calibrated reps per candidate,
//!   recorded through the obs histogram machinery (a local
//!   [`MetricRegistry`], so tuning never pollutes process metrics); the
//!   p50 bucket midpoint scores each candidate and a deterministic total
//!   order breaks ties, making winners reproducible run-to-run on a quiet
//!   machine.
//! * **Persistence** ([`TuningTable`]): schema-versioned JSON
//!   (`tune_schema`), written atomically via `util::fs`, mergeable like
//!   bench/obs snapshots (entry-wise min under the same total order, so
//!   merge is associative and commutative).  Loadable from
//!   `PADST_TUNE_TABLE` at process start or `--tune-table` / `padst tune`.
//! * **Dispatch** ([`tuner`]): a process-wide [`Tuner`].  With no table
//!   installed the consult is one relaxed atomic load — untuned processes
//!   pay nothing.  With a table it is an uncontended shared-lock probe of
//!   the packed-key map (no allocation).  Serve hoists the per-site lookup
//!   into `SessionCtx::rebuild`, keeping its zero-alloc warm path entirely
//!   lookup-free.  `PADST_TUNE=off` disables consultation, bit-reproducing
//!   untuned behaviour exactly.
//!
//! **Backend resolution order** (the cached-once chain pinned by
//! [`resolve_backend_precedence`]): explicit CLI `--backend` > a backend
//! required by a spec > `PADST_BACKEND` > tuning-table choice > the
//! built-in default (tiled).  The first three *pin* the backend
//! ([`note_backend_pinned`]): a pinned backend is never overridden by the
//! table, which is what keeps CI's `PADST_BACKEND=scalar` suite and
//! explicit `--backend` runs bit-stable with a table installed.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{OnceLock, RwLock};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::obs::MetricRegistry;
use crate::sparsity::pattern::KernelPlan;
use crate::util::cli::resolve_threads;
use crate::util::json::{self, Json};

use super::micro::Backend;

/// Schema version stamped into every serialized table; a mismatch is a
/// parse error (and [`TuningTable::load_lenient`] degrades it to a warning
/// plus an empty table, never a changed dispatch).
pub const TUNE_SCHEMA_VERSION: u32 = 1;

/// The four executable plan kinds, in [`KernelPlan`] declaration order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum PlanKind {
    /// Fixed-width index-stream panels ([`KernelPlan::Rows`]).
    Rows = 0,
    /// Dense bs x bs panels ([`KernelPlan::Blocks`]).
    Blocks = 1,
    /// Unstructured CSR ([`KernelPlan::Csr`]).
    Csr = 2,
    /// Dense fallback ([`KernelPlan::Dense`]).
    Dense = 3,
}

impl PlanKind {
    pub fn of(plan: &KernelPlan) -> PlanKind {
        match plan {
            KernelPlan::Rows(_) => PlanKind::Rows,
            KernelPlan::Blocks(_) => PlanKind::Blocks,
            KernelPlan::Csr(_) => PlanKind::Csr,
            KernelPlan::Dense { .. } => PlanKind::Dense,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PlanKind::Rows => "rows",
            PlanKind::Blocks => "blocks",
            PlanKind::Csr => "csr",
            PlanKind::Dense => "dense",
        }
    }

    pub fn parse(s: &str) -> Option<PlanKind> {
        match s {
            "rows" => Some(PlanKind::Rows),
            "blocks" => Some(PlanKind::Blocks),
            "csr" => Some(PlanKind::Csr),
            "dense" => Some(PlanKind::Dense),
            _ => None,
        }
    }

    pub fn all() -> [PlanKind; 4] {
        [PlanKind::Rows, PlanKind::Blocks, PlanKind::Csr, PlanKind::Dense]
    }

    fn from_bits(v: u64) -> PlanKind {
        match v & 0b11 {
            0 => PlanKind::Rows,
            1 => PlanKind::Blocks,
            2 => PlanKind::Csr,
            _ => PlanKind::Dense,
        }
    }
}

/// Ceil-log2 size bucket: 0 for n <= 1, else the smallest b with
/// `n <= 2^b`.  Geometries within a factor of two share a tuning entry.
pub fn bucket(n: usize) -> u8 {
    if n <= 1 {
        0
    } else {
        (usize::BITS - (n - 1).leading_zeros()) as u8
    }
}

/// One tuning key: what [`run_plan`](super::run_plan) hashes a dispatch
/// down to before consulting the table.  See the module docs for the axis
/// rationale; `simd` records backend *availability* (whether this build
/// compiled the `nightly-simd` kernels), so tables tuned on a nightly
/// build never mis-apply to a stable one.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct TuneKey {
    pub kind: PlanKind,
    /// Ceil-log2 bucket of output rows (m).
    pub rows_b: u8,
    /// Ceil-log2 bucket of input cols (k).
    pub cols_b: u8,
    /// Ceil-log2 bucket of the panel width: `RowCompressed::k`, block
    /// size, CSR mean row nnz, or 0 for dense.
    pub panel_b: u8,
    /// Ceil-log2 bucket of the resolved thread count (0 = serial).
    pub threads_b: u8,
    /// Whether the simd backend is compiled into this build.
    pub simd: bool,
}

impl TuneKey {
    /// Key a concrete plan at a resolved thread count.
    pub fn of_plan(plan: &KernelPlan, threads: usize) -> TuneKey {
        let (rows, cols, panel) = match plan {
            KernelPlan::Rows(rc) => (rc.rows, rc.cols, rc.k),
            KernelPlan::Blocks(bc) => (bc.rows, bc.cols, bc.bs),
            KernelPlan::Csr(csr) => (csr.rows, csr.cols, csr.vals.len() / csr.rows.max(1)),
            KernelPlan::Dense { rows, cols, .. } => (*rows, *cols, 0),
        };
        TuneKey {
            kind: PlanKind::of(plan),
            rows_b: bucket(rows),
            cols_b: bucket(cols),
            panel_b: bucket(panel),
            threads_b: bucket(threads),
            simd: Backend::simd_compiled(),
        }
    }

    /// Pack into the `u64` the in-memory table is keyed by (hot-path form:
    /// no allocation, total round-trip with [`TuneKey::unpack`]).
    pub fn pack(&self) -> u64 {
        (self.kind as u64)
            | (self.rows_b as u64) << 2
            | (self.cols_b as u64) << 10
            | (self.panel_b as u64) << 18
            | (self.threads_b as u64) << 26
            | u64::from(self.simd) << 34
    }

    pub fn unpack(v: u64) -> TuneKey {
        TuneKey {
            kind: PlanKind::from_bits(v),
            rows_b: (v >> 2 & 0xff) as u8,
            cols_b: (v >> 10 & 0xff) as u8,
            panel_b: (v >> 18 & 0xff) as u8,
            threads_b: (v >> 26 & 0xff) as u8,
            simd: v >> 34 & 1 == 1,
        }
    }

    /// Human/JSON spec form: `rows:r12:c10:p7:t1:s0`.
    pub fn spec(&self) -> String {
        format!(
            "{}:r{}:c{}:p{}:t{}:s{}",
            self.kind.name(),
            self.rows_b,
            self.cols_b,
            self.panel_b,
            self.threads_b,
            u8::from(self.simd)
        )
    }

    pub fn parse_spec(s: &str) -> Option<TuneKey> {
        let p: Vec<&str> = s.split(':').collect();
        if p.len() != 6 {
            return None;
        }
        let field = |part: &str, tag: &str| part.strip_prefix(tag).and_then(|v| v.parse().ok());
        Some(TuneKey {
            kind: PlanKind::parse(p[0])?,
            rows_b: field(p[1], "r")?,
            cols_b: field(p[2], "c")?,
            panel_b: field(p[3], "p")?,
            threads_b: field(p[4], "t")?,
            simd: field(p[5], "s")? != 0,
        })
    }
}

/// One dispatch variant: what a table hit resolves to.  Both non-backend
/// axes are bit-preserving, so selecting among [`Choice`]s with the same
/// backend never changes output bits (the tentpole safety property).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Choice {
    /// Microkernel backend (the only axis that may change bits — applied
    /// only when the caller's backend is unpinned; see
    /// [`Tuner::choice_for`]).
    pub backend: Backend,
    /// Row-blocking: batched `dot_gather4` driver instead of the plain
    /// per-row `dot_gather` driver (Rows plans only; bit-identical per
    /// the microkernel row contract).
    pub batched: bool,
    /// Mt chunking cap: shard across at most this many threads (0 = no
    /// cap).  Sharding is bit-identical at any thread count, so capping
    /// oversubscribed small GEMMs is free of numeric risk.
    pub max_threads: u32,
}

impl Choice {
    /// The untuned dispatch exactly as it behaves today: the caller's
    /// backend, plain row driver, no thread cap.
    pub fn default_for(backend: Backend) -> Choice {
        Choice { backend, batched: false, max_threads: 0 }
    }
}

fn backend_rank(b: Backend) -> u8 {
    match b {
        Backend::Scalar => 0,
        Backend::Tiled => 1,
        Backend::Simd => 2,
    }
}

/// A tuned winner plus its measurement provenance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TuneEntry {
    pub choice: Choice,
    /// p50 of the winning candidate's calibrated reps, in nanoseconds
    /// (obs histogram bucket midpoint, <= 6.25 % relative error).
    pub best_ns: u64,
    /// Reps behind `best_ns`.
    pub reps: u32,
}

impl TuneEntry {
    /// Deterministic total order: faster first, ties broken by backend
    /// rank, then the remaining fields.  Because this is total, keeping
    /// the minimum under insert/merge is associative and commutative —
    /// the same algebra as bench/obs snapshot merges.
    fn order_key(&self) -> (u64, u8, bool, u32, u32) {
        (
            self.best_ns,
            backend_rank(self.choice.backend),
            self.choice.batched,
            self.choice.max_threads,
            self.reps,
        )
    }

    fn to_json(self) -> Json {
        json::obj(vec![
            ("backend", json::s(self.choice.backend.name())),
            ("batched", Json::Bool(self.choice.batched)),
            ("best_ns", json::num(self.best_ns as f64)),
            ("max_threads", json::num(self.choice.max_threads as f64)),
            ("reps", json::num(self.reps as f64)),
        ])
    }

    fn from_json(v: &Json) -> Result<TuneEntry> {
        let backend_name = v
            .get("backend")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("tuning entry: missing backend"))?;
        let backend = Backend::parse(backend_name)
            .ok_or_else(|| anyhow!("tuning entry: unknown backend {backend_name:?}"))?;
        Ok(TuneEntry {
            choice: Choice {
                backend,
                batched: v.get("batched").and_then(Json::as_bool).unwrap_or(false),
                max_threads: v.get("max_threads").and_then(Json::as_usize).unwrap_or(0) as u32,
            },
            best_ns: v.get("best_ns").and_then(Json::as_usize).unwrap_or(0) as u64,
            reps: v.get("reps").and_then(Json::as_usize).unwrap_or(0) as u32,
        })
    }
}

/// The persistent winner map, keyed by packed [`TuneKey`]s in memory and
/// by key spec strings on disk.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TuningTable {
    pub schema: u32,
    entries: BTreeMap<u64, TuneEntry>,
}

impl Default for TuningTable {
    fn default() -> Self {
        Self::new()
    }
}

impl TuningTable {
    pub fn new() -> TuningTable {
        TuningTable { schema: TUNE_SCHEMA_VERSION, entries: BTreeMap::new() }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, key: &TuneKey) -> Option<&TuneEntry> {
        self.entries.get(&key.pack())
    }

    fn get_packed(&self, packed: u64) -> Option<&TuneEntry> {
        self.entries.get(&packed)
    }

    /// Insert, keeping the better entry (minimum under
    /// [`TuneEntry::order_key`]) when the key is already present.
    pub fn insert(&mut self, key: TuneKey, entry: TuneEntry) {
        let slot = self.entries.entry(key.pack()).or_insert(entry);
        if entry.order_key() < slot.order_key() {
            *slot = entry;
        }
    }

    /// Entry-wise merge (best entry per key wins).  Associative and
    /// commutative, so per-machine tables combine in any order — the same
    /// contract as bench/obs snapshot merges.
    pub fn merge(&mut self, other: &TuningTable) {
        for (&k, e) in &other.entries {
            self.insert(TuneKey::unpack(k), *e);
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = (TuneKey, &TuneEntry)> {
        self.entries.iter().map(|(&k, e)| (TuneKey::unpack(k), e))
    }

    pub fn to_json(&self) -> Json {
        let entries: BTreeMap<String, Json> =
            self.entries.iter().map(|(&k, e)| (TuneKey::unpack(k).spec(), e.to_json())).collect();
        json::obj(vec![
            ("tune_schema", json::num(self.schema as f64)),
            ("entries", Json::Obj(entries)),
        ])
    }

    /// Strict parse: a schema mismatch, malformed key, or malformed entry
    /// is an error (callers that prefer degradation use
    /// [`TuningTable::load_lenient`]).
    pub fn parse(src: &str) -> Result<TuningTable> {
        let v = Json::parse(src).context("parsing tuning table")?;
        let schema = v.get("tune_schema").and_then(Json::as_usize).unwrap_or(0);
        if schema != TUNE_SCHEMA_VERSION as usize {
            bail!("unsupported tune_schema {schema} (this build reads {TUNE_SCHEMA_VERSION})");
        }
        let mut table = TuningTable::new();
        if let Some(m) = v.get("entries").and_then(Json::as_obj) {
            for (spec, ev) in m {
                let key = TuneKey::parse_spec(spec)
                    .ok_or_else(|| anyhow!("bad tuning key {spec:?}"))?;
                table.insert(key, TuneEntry::from_json(ev)?);
            }
        }
        Ok(table)
    }

    /// Atomic write (temp sibling + rename), like every other snapshot.
    pub fn save(&self, path: &Path) -> Result<()> {
        crate::util::fs::write_atomic(path, &self.to_json().to_string_pretty())
    }

    pub fn load(path: &Path) -> Result<TuningTable> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading tuning table {}", path.display()))?;
        TuningTable::parse(&src).with_context(|| path.display().to_string())
    }

    /// Load for dispatch: a missing file is an empty table (silently), a
    /// corrupt or stale-schema file warns on stderr and falls back to an
    /// empty table — tuning must never turn a working run into a dead one.
    pub fn load_lenient(path: &Path) -> TuningTable {
        if !path.exists() {
            return TuningTable::new();
        }
        match TuningTable::load(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!(
                    "[padst] ignoring tuning table {} (falling back to default dispatch): {e}",
                    path.display()
                );
                TuningTable::new()
            }
        }
    }
}

// ------------------------------------------------------------- pinnedness

static BACKEND_PINNED: AtomicBool = AtomicBool::new(false);

/// Record that the process backend was pinned explicitly (CLI `--backend`,
/// `Runtime::set_backend`, a spec).  A pinned backend is never overridden
/// by the tuning table — only the bit-preserving axes still apply.
pub fn note_backend_pinned() {
    BACKEND_PINNED.store(true, Ordering::Relaxed);
}

fn env_backend_pinned() -> bool {
    static SET: OnceLock<bool> = OnceLock::new();
    *SET.get_or_init(|| std::env::var("PADST_BACKEND").map(|v| !v.is_empty()).unwrap_or(false))
}

/// Whether the backend axis is pinned for this process (explicit flag /
/// setter noted via [`note_backend_pinned`], or a non-empty
/// `PADST_BACKEND` — the same env the [`Backend::default_backend`] cache
/// reads, checked once).
pub fn backend_pinned() -> bool {
    BACKEND_PINNED.load(Ordering::Relaxed) || env_backend_pinned()
}

/// Where a resolved backend came from, for logs and the precedence test.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendSource {
    /// Explicit CLI `--backend`.
    CliFlag,
    /// A backend required by a pattern/run spec.
    Spec,
    /// The `PADST_BACKEND` environment variable.
    Env,
    /// A tuning-table entry.
    Tuned,
    /// The built-in default (tiled).
    Default,
}

/// The one documented backend resolution order:
/// `--backend` > spec > `PADST_BACKEND` > tuning table > default.
/// Pure so the precedence is unit-testable without touching process
/// globals; every layered resolver (CLI, benches, serve) must agree with
/// this chain, and `Backend::default_backend` documents it.
pub fn resolve_backend_precedence(
    cli: Option<Backend>,
    spec: Option<Backend>,
    env: Option<Backend>,
    tuned: Option<Backend>,
) -> (Backend, BackendSource) {
    let (b, src) = match (cli, spec, env, tuned) {
        (Some(b), _, _, _) => (b, BackendSource::CliFlag),
        (None, Some(b), _, _) => (b, BackendSource::Spec),
        (None, None, Some(b), _) => (b, BackendSource::Env),
        (None, None, None, Some(b)) => (b, BackendSource::Tuned),
        (None, None, None, None) => (Backend::default(), BackendSource::Default),
    };
    (b.effective(), src)
}

// ----------------------------------------------------------- global tuner

/// The process-wide dispatch consultant.  See the module docs for the
/// locking story; the short form: no table installed = one relaxed atomic
/// load, table installed = an uncontended shared read lock + ordered-map
/// probe, neither allocating.
pub struct Tuner {
    /// Entry count of the installed table (0 = none): the hot-path
    /// fast-out, so untuned processes never touch the lock.
    installed: AtomicUsize,
    off: AtomicBool,
    table: RwLock<Option<TuningTable>>,
}

impl Tuner {
    fn from_env() -> Tuner {
        let off = matches!(
            std::env::var("PADST_TUNE").ok().as_deref(),
            Some("off") | Some("0") | Some("false")
        );
        let tuner = Tuner {
            installed: AtomicUsize::new(0),
            off: AtomicBool::new(off),
            table: RwLock::new(None),
        };
        if let Ok(path) = std::env::var("PADST_TUNE_TABLE") {
            if !path.is_empty() {
                let t = TuningTable::load_lenient(Path::new(&path));
                if !t.is_empty() {
                    tuner.install(t);
                }
            }
        }
        tuner
    }

    /// Install (replacing any previous) the table consulted by every
    /// subsequent `run_plan` / `run_plan_mt` dispatch.
    pub fn install(&self, table: TuningTable) {
        let n = table.len();
        *self.table.write().unwrap_or_else(|e| e.into_inner()) = Some(table);
        // ordering: Release publishes the table write above to the
        // Acquire loads in len()/lookup()/choice_for().
        self.installed.store(n, Ordering::Release);
    }

    /// Drop the installed table (tests; `run_plan` returns to the
    /// untuned fast path).
    pub fn clear(&self) {
        *self.table.write().unwrap_or_else(|e| e.into_inner()) = None;
        // ordering: Release pairs with the same Acquire readers as
        // install(); a 0 count means the table drop is visible too.
        self.installed.store(0, Ordering::Release);
    }

    /// Runtime switch mirroring `PADST_TUNE=off`.
    pub fn set_enabled(&self, enabled: bool) {
        self.off.store(!enabled, Ordering::Relaxed);
    }

    pub fn enabled(&self) -> bool {
        !self.off.load(Ordering::Relaxed)
    }

    /// Entries in the installed table (0 = none installed).
    pub fn len(&self) -> usize {
        // ordering: Acquire pairs with install()/clear() Release stores.
        self.installed.load(Ordering::Acquire)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Raw keyed lookup (no pinning policy applied) — what the tests and
    /// `padst tune --dry-run` use to report coverage.
    pub fn lookup(&self, key: &TuneKey) -> Option<TuneEntry> {
        // ordering: Acquire — a nonzero count implies the table behind
        // the lock is the one install() published.
        if self.installed.load(Ordering::Acquire) == 0 {
            return None;
        }
        let guard = self.table.read().unwrap_or_else(|e| e.into_inner());
        guard.as_ref().and_then(|t| t.get_packed(key.pack())).copied()
    }

    /// Resolve the dispatch variant for one plan execution.  Returns the
    /// choice plus whether it came from the table.  Fallback rules:
    /// tuning off, no table, or no entry → exactly today's dispatch
    /// ([`Choice::default_for`] the caller's backend).  On a hit, the
    /// table's backend applies only when the caller's backend is unpinned
    /// *and* equal to the process default (an explicitly threaded-through
    /// non-default backend is as deliberate as a CLI flag); the
    /// bit-preserving axes apply either way.
    // lint: no-alloc
    pub fn choice_for(
        &self,
        plan: &KernelPlan,
        threads: usize,
        backend: Backend,
    ) -> (Choice, bool) {
        // ordering: Acquire pairs with install()'s Release, so the warm
        // path sees a fully-published table or skips entirely.
        if self.installed.load(Ordering::Acquire) == 0 || self.off.load(Ordering::Relaxed) {
            return (Choice::default_for(backend), false);
        }
        let packed = TuneKey::of_plan(plan, threads).pack();
        let entry = {
            let guard = self.table.read().unwrap_or_else(|e| e.into_inner());
            guard.as_ref().and_then(|t| t.get_packed(packed)).copied()
        };
        match entry {
            Some(e) => {
                let mut choice = e.choice;
                if backend_pinned() || backend != Backend::default_backend() {
                    choice.backend = backend;
                }
                (choice, true)
            }
            None => (Choice::default_for(backend), false),
        }
    }
}

/// The process-wide [`Tuner`], initialised once from `PADST_TUNE` /
/// `PADST_TUNE_TABLE` on first consult.
pub fn tuner() -> &'static Tuner {
    static TUNER: OnceLock<Tuner> = OnceLock::new();
    TUNER.get_or_init(Tuner::from_env)
}

// ------------------------------------------------------------ measurement

/// Rep budget for timing one candidate.
#[derive(Clone, Copy, Debug)]
pub struct TuneBudget {
    pub min_reps: u32,
    pub max_reps: u32,
    /// Target wall time per candidate in nanoseconds; one calibration
    /// call sizes the rep count to roughly fit it.
    pub budget_ns: u64,
}

impl Default for TuneBudget {
    fn default() -> Self {
        TuneBudget { min_reps: 3, max_reps: 64, budget_ns: 20_000_000 }
    }
}

/// The candidate variants for one key: every compiled backend, crossed
/// with the batched row driver (Rows plans only) and — above one thread —
/// a serialising thread cap (small GEMMs often lose to sharding overhead).
pub fn candidates(kind: PlanKind, threads: usize) -> Vec<Choice> {
    let batched_axis: &[bool] = if kind == PlanKind::Rows { &[false, true] } else { &[false] };
    let cap_axis: &[u32] = if threads > 1 { &[0, 1] } else { &[0] };
    let mut out = Vec::new();
    for &backend in Backend::all() {
        for &batched in batched_axis {
            for &cap in cap_axis {
                out.push(Choice { backend, batched, max_threads: cap });
            }
        }
    }
    out
}

/// Time every candidate for `plan` at `threads` and return the key plus
/// the winning entry.  One calibration call per candidate sizes the rep
/// count to the budget; reps are recorded into a local obs histogram and
/// scored by p50, with [`TuneEntry::order_key`] breaking ties
/// deterministically.  `x`/`y` are caller scratch of the plan's geometry
/// (contents are clobbered).
pub fn tune_plan(
    plan: &KernelPlan,
    x: &[f32],
    batch: usize,
    y: &mut [f32],
    threads: usize,
    budget: &TuneBudget,
) -> (TuneKey, TuneEntry) {
    let threads = resolve_threads(threads);
    let key = TuneKey::of_plan(plan, threads);
    let reg = MetricRegistry::new();
    let mut best: Option<TuneEntry> = None;
    for (i, choice) in candidates(key.kind, threads).into_iter().enumerate() {
        let t0 = Instant::now();
        super::dispatch_plan_mt_choice(plan, x, batch, y, threads, &choice);
        let est = (t0.elapsed().as_nanos() as u64).max(1);
        let reps =
            (budget.budget_ns / est).clamp(budget.min_reps as u64, budget.max_reps as u64) as u32;
        let hist = reg.histogram(&format!("tune.candidate.{i}"));
        for _ in 0..reps {
            let t = Instant::now();
            super::dispatch_plan_mt_choice(plan, x, batch, y, threads, &choice);
            hist.record_ns(t.elapsed());
        }
        let entry = TuneEntry { choice, best_ns: hist.snapshot().quantile(0.5), reps };
        let better = match best {
            Some(b) => entry.order_key() < b.order_key(),
            None => true,
        };
        if better {
            best = Some(entry);
        }
    }
    (key, best.expect("Backend::all() is never empty"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_is_ceil_log2() {
        assert_eq!(bucket(0), 0);
        assert_eq!(bucket(1), 0);
        assert_eq!(bucket(2), 1);
        assert_eq!(bucket(3), 2);
        assert_eq!(bucket(4), 2);
        assert_eq!(bucket(5), 3);
        assert_eq!(bucket(26), 5);
        assert_eq!(bucket(77), 7);
        assert_eq!(bucket(256), 8);
        assert_eq!(bucket(3072), 12);
    }

    #[test]
    fn key_pack_and_spec_round_trip() {
        for kind in PlanKind::all() {
            for simd in [false, true] {
                let key =
                    TuneKey { kind, rows_b: 12, cols_b: 10, panel_b: 7, threads_b: 1, simd };
                assert_eq!(TuneKey::unpack(key.pack()), key);
                assert_eq!(TuneKey::parse_spec(&key.spec()), Some(key));
            }
        }
        assert_eq!(TuneKey::parse_spec("rows:r1:c2:p3"), None);
        assert_eq!(TuneKey::parse_spec("nope:r1:c2:p3:t0:s0"), None);
        assert_eq!(TuneKey::parse_spec("rows:x1:c2:p3:t0:s0"), None);
    }

    #[test]
    fn precedence_chain_first_source_wins() {
        let (s, t) = (Backend::Scalar, Backend::Tiled);
        assert_eq!(
            resolve_backend_precedence(Some(s), Some(t), Some(t), Some(t)),
            (s, BackendSource::CliFlag)
        );
        assert_eq!(
            resolve_backend_precedence(None, Some(s), Some(t), Some(t)),
            (s, BackendSource::Spec)
        );
        assert_eq!(
            resolve_backend_precedence(None, None, Some(s), Some(t)),
            (s, BackendSource::Env)
        );
        assert_eq!(
            resolve_backend_precedence(None, None, None, Some(s)),
            (s, BackendSource::Tuned)
        );
        assert_eq!(
            resolve_backend_precedence(None, None, None, None),
            (Backend::Tiled, BackendSource::Default)
        );
        // The chain applies `effective()`: a Simd pick degrades in
        // builds without nightly-simd instead of dispatching a missing
        // kernel.
        let (eff, src) = resolve_backend_precedence(Some(Backend::Simd), None, None, None);
        assert_eq!(eff, Backend::Simd.effective());
        assert_eq!(src, BackendSource::CliFlag);
    }

    fn entry(backend: Backend, ns: u64) -> TuneEntry {
        let choice = Choice { backend, batched: false, max_threads: 0 };
        TuneEntry { choice, best_ns: ns, reps: 3 }
    }

    #[test]
    fn table_insert_keeps_the_better_entry() {
        let key = TuneKey::parse_spec("rows:r8:c8:p5:t0:s0").unwrap();
        let mut t = TuningTable::new();
        t.insert(key, entry(Backend::Tiled, 200));
        t.insert(key, entry(Backend::Scalar, 100));
        assert_eq!(t.get(&key).unwrap().best_ns, 100);
        // A slower late insert never regresses the stored winner.
        t.insert(key, entry(Backend::Tiled, 300));
        assert_eq!(t.get(&key).unwrap().best_ns, 100);
        // Equal time: lower backend rank wins deterministically.
        t.insert(key, entry(Backend::Scalar, 100));
        assert_eq!(t.get(&key).unwrap().choice.backend, Backend::Scalar);
    }

    #[test]
    fn merge_is_associative_and_commutative() {
        let k1 = TuneKey::parse_spec("rows:r8:c8:p5:t0:s0").unwrap();
        let k2 = TuneKey::parse_spec("csr:r10:c8:p5:t1:s0").unwrap();
        let k3 = TuneKey::parse_spec("dense:r12:c10:p0:t1:s0").unwrap();
        let mut a = TuningTable::new();
        a.insert(k1, entry(Backend::Tiled, 120));
        a.insert(k2, entry(Backend::Scalar, 900));
        let mut b = TuningTable::new();
        b.insert(k1, entry(Backend::Scalar, 80));
        b.insert(k3, entry(Backend::Tiled, 50));
        let mut c = TuningTable::new();
        c.insert(k2, entry(Backend::Tiled, 700));

        let mut ab_c = a.clone();
        ab_c.merge(&b);
        ab_c.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        assert_eq!(ab_c, a_bc, "merge must be associative");

        let mut ba = b.clone();
        ba.merge(&a);
        let mut ab = a.clone();
        ab.merge(&b);
        assert_eq!(ab, ba, "merge must be commutative");
        assert_eq!(ab.get(&k1).unwrap().best_ns, 80);
    }

    #[test]
    fn table_json_round_trips() {
        let mut t = TuningTable::new();
        t.insert(
            TuneKey::parse_spec("rows:r8:c8:p5:t1:s0").unwrap(),
            TuneEntry {
                choice: Choice { backend: Backend::Tiled, batched: true, max_threads: 1 },
                best_ns: 12345,
                reps: 20,
            },
        );
        let k2 = TuneKey::parse_spec("blocks:r10:c8:p4:t0:s0").unwrap();
        t.insert(k2, entry(Backend::Scalar, 7));
        let text = t.to_json().to_string_pretty();
        let re = TuningTable::parse(&text).unwrap();
        assert_eq!(t, re);
    }

    #[test]
    fn parse_rejects_stale_schema_and_garbage() {
        assert!(TuningTable::parse("{\"tune_schema\":99,\"entries\":{}}").is_err());
        assert!(TuningTable::parse("{\"entries\":{}}").is_err());
        assert!(TuningTable::parse("not json").is_err());
        let bad_key = "{\"tune_schema\":1,\"entries\":{\"huh\":{\"backend\":\"tiled\"}}}";
        assert!(TuningTable::parse(bad_key).is_err());
        let bad_backend =
            "{\"tune_schema\":1,\"entries\":{\"rows:r1:c1:p1:t0:s0\":{\"backend\":\"gpu\"}}}";
        assert!(TuningTable::parse(bad_backend).is_err());
    }

    #[test]
    fn candidate_axes_match_the_plan_kind() {
        let n_backends = Backend::all().len();
        assert_eq!(candidates(PlanKind::Rows, 1).len(), n_backends * 2);
        assert_eq!(candidates(PlanKind::Rows, 2).len(), n_backends * 4);
        assert_eq!(candidates(PlanKind::Csr, 1).len(), n_backends);
        assert_eq!(candidates(PlanKind::Dense, 2).len(), n_backends * 2);
        // Every candidate axis except the backend is bit-preserving, and
        // the serial axis never caps threads.
        assert!(candidates(PlanKind::Blocks, 1).iter().all(|c| c.max_threads == 0));
    }
}
