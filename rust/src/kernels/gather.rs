//! Structured sparse GEMM kernels over the compressed forms.
//!
//! [`gather_matmul`] is the CPU twin of the L1 Pallas `gather_spmm` kernel:
//! per output row, a fixed-width panel of (value, input-index) pairs —
//! covering Diagonal-K, N:M and butterfly layouts — with any permutation
//! already folded into the index stream (re-indexing, Eqn. 16/18).
//!
//! [`block_matmul`] is the DSB/Pixelated-Butterfly form: dense bs x bs
//! panels, contiguous in both W and x, which is the friendliest layout for
//! the CPU's vector units (as it is for tensor cores in the paper).

use crate::sparsity::compress::{BlockCompressed, RowCompressed};

/// One output row's gather dot product, 4-wide unrolled (the index stream
/// is the only indirection).  Shared by the serial and parallel paths so
/// their reduction order — and therefore their f32 results — are
/// bit-identical by construction.
#[inline(always)]
pub(crate) fn gather_row_dot(vals: &[f32], idx: &[i32], xb: &[f32]) -> f32 {
    let k = vals.len();
    debug_assert_eq!(idx.len(), k);
    let mut acc0 = 0.0f32;
    let mut acc1 = 0.0f32;
    let mut s = 0;
    while s + 4 <= k {
        acc0 += vals[s] * xb[idx[s] as usize] + vals[s + 1] * xb[idx[s + 1] as usize];
        acc1 += vals[s + 2] * xb[idx[s + 2] as usize] + vals[s + 3] * xb[idx[s + 3] as usize];
        s += 4;
    }
    while s < k {
        acc0 += vals[s] * xb[idx[s] as usize];
        s += 1;
    }
    acc0 + acc1
}

/// y[b, i] = sum_s vals[i, s] * x[b, idx[i, s]].
pub fn gather_matmul(x: &[f32], rc: &RowCompressed, batch: usize, y: &mut [f32]) {
    let (rows, cols, k) = (rc.rows, rc.cols, rc.k);
    debug_assert_eq!(x.len(), batch * cols);
    debug_assert_eq!(y.len(), batch * rows);
    for b in 0..batch {
        let xb = &x[b * cols..(b + 1) * cols];
        let yb = &mut y[b * rows..(b + 1) * rows];
        for (i, yv) in yb.iter_mut().enumerate() {
            *yv = gather_row_dot(&rc.vals[i * k..(i + 1) * k], &rc.idx[i * k..(i + 1) * k], xb);
        }
    }
}

/// Batch-major variant processing 4 batch rows per index fetch — amortises
/// the indirection across the batch (the CPU analogue of the paper's
/// "activation reuse across the batch" on GPU).  Preferred when batch >= 4.
pub fn gather_matmul_batched(x: &[f32], rc: &RowCompressed, batch: usize, y: &mut [f32]) {
    let (rows, cols, k) = (rc.rows, rc.cols, rc.k);
    debug_assert_eq!(x.len(), batch * cols);
    debug_assert_eq!(y.len(), batch * rows);
    let mut b = 0;
    while b + 4 <= batch {
        let x0 = &x[b * cols..(b + 1) * cols];
        let x1 = &x[(b + 1) * cols..(b + 2) * cols];
        let x2 = &x[(b + 2) * cols..(b + 3) * cols];
        let x3 = &x[(b + 3) * cols..(b + 4) * cols];
        for i in 0..rows {
            let vals = &rc.vals[i * k..(i + 1) * k];
            let idx = &rc.idx[i * k..(i + 1) * k];
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for s in 0..k {
                let j = idx[s] as usize;
                let v = vals[s];
                a0 += v * x0[j];
                a1 += v * x1[j];
                a2 += v * x2[j];
                a3 += v * x3[j];
            }
            y[b * rows + i] = a0;
            y[(b + 1) * rows + i] = a1;
            y[(b + 2) * rows + i] = a2;
            y[(b + 3) * rows + i] = a3;
        }
        b += 4;
    }
    if b < batch {
        let rem = batch - b;
        gather_matmul(&x[b * cols..], rc, rem, &mut y[b * rows..]);
    }
}

/// One block-row of the block-sparse product: `ys` (length `bs`) receives
/// the contributions of block-row `bi` against the single batch row `xb`.
/// Active blocks accumulate in storage order, so any scheduling that calls
/// this per (batch, block-row) unit — serial or sharded across threads —
/// produces bit-identical sums.
#[inline(always)]
pub(crate) fn block_row_matmul(xb: &[f32], bc: &BlockCompressed, bi: usize, ys: &mut [f32]) {
    let (bs, nab) = (bc.bs, bc.nab);
    debug_assert_eq!(ys.len(), bs);
    ys.fill(0.0);
    for a in 0..nab {
        let jb = bc.block_cols[bi * nab + a];
        if jb < 0 {
            continue;
        }
        let xs = &xb[jb as usize * bs..(jb as usize + 1) * bs];
        let blk = &bc.blocks[(bi * nab + a) * bs * bs..(bi * nab + a + 1) * bs * bs];
        for (r, yv) in ys.iter_mut().enumerate() {
            let wr = &blk[r * bs..(r + 1) * bs];
            let mut acc = 0.0f32;
            for (wv, xv) in wr.iter().zip(xs) {
                acc += wv * xv;
            }
            *yv += acc;
        }
    }
}

/// Block-sparse y = x @ W^T over [`BlockCompressed`].
pub fn block_matmul(x: &[f32], bc: &BlockCompressed, batch: usize, y: &mut [f32]) {
    let (rows, cols, bs) = (bc.rows, bc.cols, bc.bs);
    let br = rows / bs;
    debug_assert_eq!(x.len(), batch * cols);
    debug_assert_eq!(y.len(), batch * rows);
    for b in 0..batch {
        let xb = &x[b * cols..(b + 1) * cols];
        let yb = &mut y[b * rows..(b + 1) * rows];
        for bi in 0..br {
            block_row_matmul(xb, bc, bi, &mut yb[bi * bs..(bi + 1) * bs]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::compress::compress_rows;
    use crate::sparsity::patterns::make_nm_mask;
    use crate::util::Rng;

    #[test]
    fn batched_matches_plain() {
        let mut rng = Rng::new(40);
        let (batch, rows, cols) = (7, 32, 64); // odd batch exercises the tail
        let mask = make_nm_mask(rows, cols, 4, 16, &mut rng);
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
        let rc = compress_rows(&w, &mask, 16, None);
        let x: Vec<f32> = (0..batch * cols).map(|_| rng.normal()).collect();
        let mut y1 = vec![0.0; batch * rows];
        let mut y2 = vec![0.0; batch * rows];
        gather_matmul(&x, &rc, batch, &mut y1);
        gather_matmul_batched(&x, &rc, batch, &mut y2);
        let d = y1.iter().zip(&y2).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(d < 1e-4);
    }
}
