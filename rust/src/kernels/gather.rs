//! Structured sparse GEMM drivers over the compressed forms.
//!
//! [`gather_matmul`] is the CPU twin of the L1 Pallas `gather_spmm` kernel:
//! per output row, a fixed-width panel of (value, input-index) pairs —
//! covering Diagonal-K, N:M and butterfly layouts — with any permutation
//! already folded into the index stream (re-indexing, Eqn. 16/18).
//!
//! [`block_matmul`] is the DSB/Pixelated-Butterfly form: dense bs x bs
//! panels, contiguous in both W and x, which is the friendliest layout for
//! the CPU's vector units (as it is for tensor cores in the paper).
//!
//! Both are thin drivers: every reduction body lives in the
//! [`micro`](super::micro) layer and is selected by [`Backend`].  The
//! plain entry points run [`Backend::default_backend`]; the `_with`
//! variants take the backend explicitly (what the benches, tests, and the
//! `_mt` shards use).

use super::micro::{self, Backend};
use crate::sparsity::compress::{BlockCompressed, RowCompressed};

/// y[b, i] = sum_s vals[i, s] * x[b, idx[i, s]], on the default backend.
pub fn gather_matmul(x: &[f32], rc: &RowCompressed, batch: usize, y: &mut [f32]) {
    gather_matmul_with(x, rc, batch, y, Backend::default_backend());
}

/// [`gather_matmul`] with an explicit microkernel backend.
pub fn gather_matmul_with(
    x: &[f32],
    rc: &RowCompressed,
    batch: usize,
    y: &mut [f32],
    backend: Backend,
) {
    let (rows, cols, k) = (rc.rows, rc.cols, rc.k);
    debug_assert_eq!(x.len(), batch * cols);
    debug_assert_eq!(y.len(), batch * rows);
    for b in 0..batch {
        let xb = &x[b * cols..(b + 1) * cols];
        let yb = &mut y[b * rows..(b + 1) * rows];
        for (i, yv) in yb.iter_mut().enumerate() {
            *yv = micro::dot_gather(
                &rc.vals[i * k..(i + 1) * k],
                &rc.idx[i * k..(i + 1) * k],
                xb,
                backend,
            );
        }
    }
}

/// Batch-major variant processing 4 batch rows per index fetch — amortises
/// the indirection across the batch (the CPU analogue of the paper's
/// "activation reuse across the batch" on GPU).  Preferred when batch >= 4.
pub fn gather_matmul_batched(x: &[f32], rc: &RowCompressed, batch: usize, y: &mut [f32]) {
    gather_matmul_batched_with(x, rc, batch, y, Backend::default_backend());
}

/// [`gather_matmul_batched`] with an explicit microkernel backend.
pub fn gather_matmul_batched_with(
    x: &[f32],
    rc: &RowCompressed,
    batch: usize,
    y: &mut [f32],
    backend: Backend,
) {
    let (rows, cols, k) = (rc.rows, rc.cols, rc.k);
    debug_assert_eq!(x.len(), batch * cols);
    debug_assert_eq!(y.len(), batch * rows);
    let mut b = 0;
    while b + 4 <= batch {
        let x0 = &x[b * cols..(b + 1) * cols];
        let x1 = &x[(b + 1) * cols..(b + 2) * cols];
        let x2 = &x[(b + 2) * cols..(b + 3) * cols];
        let x3 = &x[(b + 3) * cols..(b + 4) * cols];
        for i in 0..rows {
            let vals = &rc.vals[i * k..(i + 1) * k];
            let idx = &rc.idx[i * k..(i + 1) * k];
            let [a0, a1, a2, a3] = micro::dot_gather4(vals, idx, x0, x1, x2, x3, backend);
            y[b * rows + i] = a0;
            y[(b + 1) * rows + i] = a1;
            y[(b + 2) * rows + i] = a2;
            y[(b + 3) * rows + i] = a3;
        }
        b += 4;
    }
    if b < batch {
        let rem = batch - b;
        gather_matmul_with(&x[b * cols..], rc, rem, &mut y[b * rows..], backend);
    }
}

/// One block-row of the block-sparse product: `ys` (length `bs`) receives
/// the contributions of block-row `bi` against the single batch row `xb`.
/// Active blocks accumulate in storage order and every per-row dot runs
/// the same microkernel, so any scheduling that calls this per
/// (batch, block-row) unit — serial or sharded across threads — produces
/// bit-identical sums for a given backend.
#[inline(always)]
pub(crate) fn block_row_matmul(
    xb: &[f32],
    bc: &BlockCompressed,
    bi: usize,
    ys: &mut [f32],
    backend: Backend,
) {
    let (bs, nab) = (bc.bs, bc.nab);
    debug_assert_eq!(ys.len(), bs);
    ys.fill(0.0);
    for a in 0..nab {
        let jb = bc.block_cols[bi * nab + a];
        if jb < 0 {
            continue;
        }
        let xs = &xb[jb as usize * bs..(jb as usize + 1) * bs];
        let blk = &bc.blocks[(bi * nab + a) * bs * bs..(bi * nab + a + 1) * bs * bs];
        // 4 block rows per microkernel call share the xs loads; the row
        // tail (bs % 4) goes through the single-row dot, which is
        // bit-identical per row by the microkernel contract.
        let mut r = 0;
        while r + 4 <= bs {
            let [d0, d1, d2, d3] = micro::dot_rows4(
                &blk[r * bs..(r + 1) * bs],
                &blk[(r + 1) * bs..(r + 2) * bs],
                &blk[(r + 2) * bs..(r + 3) * bs],
                &blk[(r + 3) * bs..(r + 4) * bs],
                xs,
                backend,
            );
            ys[r] += d0;
            ys[r + 1] += d1;
            ys[r + 2] += d2;
            ys[r + 3] += d3;
            r += 4;
        }
        while r < bs {
            ys[r] += micro::dot(&blk[r * bs..(r + 1) * bs], xs, backend);
            r += 1;
        }
    }
}

/// Block-sparse y = x @ W^T over [`BlockCompressed`], default backend.
pub fn block_matmul(x: &[f32], bc: &BlockCompressed, batch: usize, y: &mut [f32]) {
    block_matmul_with(x, bc, batch, y, Backend::default_backend());
}

/// [`block_matmul`] with an explicit microkernel backend.
pub fn block_matmul_with(
    x: &[f32],
    bc: &BlockCompressed,
    batch: usize,
    y: &mut [f32],
    backend: Backend,
) {
    let (rows, cols, bs) = (bc.rows, bc.cols, bc.bs);
    let br = rows / bs;
    debug_assert_eq!(x.len(), batch * cols);
    debug_assert_eq!(y.len(), batch * rows);
    for b in 0..batch {
        let xb = &x[b * cols..(b + 1) * cols];
        let yb = &mut y[b * rows..(b + 1) * rows];
        for bi in 0..br {
            block_row_matmul(xb, bc, bi, &mut yb[bi * bs..(bi + 1) * bs], backend);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::compress::compress_rows;
    use crate::sparsity::patterns::make_nm_mask;
    use crate::util::Rng;

    #[test]
    fn batched_matches_plain_bitwise_per_backend() {
        let mut rng = Rng::new(40);
        let (batch, rows, cols) = (7, 32, 64); // odd batch exercises the tail
        let mask = make_nm_mask(rows, cols, 4, 16, &mut rng);
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
        let rc = compress_rows(&w, &mask, 16, None);
        let x: Vec<f32> = (0..batch * cols).map(|_| rng.normal()).collect();
        for &backend in Backend::all() {
            let mut y1 = vec![0.0; batch * rows];
            let mut y2 = vec![0.0; batch * rows];
            gather_matmul_with(&x, &rc, batch, &mut y1, backend);
            gather_matmul_batched_with(&x, &rc, batch, &mut y2, backend);
            // dot_gather4 row i must reproduce dot_gather exactly, so the
            // batched driver is bit-identical to the plain one.
            for (a, b) in y1.iter().zip(&y2) {
                assert_eq!(a.to_bits(), b.to_bits(), "backend {}", backend.name());
            }
        }
    }
}
