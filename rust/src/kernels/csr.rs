//! CSR sparse GEMM — the *unstructured* comparator for Fig. 3.
//!
//! CSR is what cuSparse executes for RigL/SET-style free masks in the
//! paper's timing section.  Row lengths are ragged, the column stream has
//! no structure to exploit, and each nonzero pays a full indirection —
//! which is exactly why unstructured DST wins accuracy but loses the
//! speedup race, on GPU and CPU alike.

use super::micro::{self, Backend};
use crate::sparsity::patterns::Mask;

#[derive(Clone, Debug)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<i32>,
    pub vals: Vec<f32>,
}

impl Csr {
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }
}

pub fn csr_from_mask(w: &[f32], mask: &Mask) -> Csr {
    let (rows, cols) = (mask.rows, mask.cols);
    assert_eq!(w.len(), rows * cols);
    let mut row_ptr = Vec::with_capacity(rows + 1);
    let mut col_idx = Vec::new();
    let mut vals = Vec::new();
    row_ptr.push(0);
    for i in 0..rows {
        for j in 0..cols {
            if mask.get(i, j) {
                col_idx.push(j as i32);
                vals.push(w[i * cols + j]);
            }
        }
        row_ptr.push(col_idx.len());
    }
    Csr { rows, cols, row_ptr, col_idx, vals }
}

/// One CSR row's dot product — a ragged slice of the same gather
/// microkernel the structured kernels run.  Shared by the serial and
/// parallel paths so the reduction order — and the f32 result — is
/// identical in both for a given backend.
#[inline(always)]
pub(crate) fn csr_row_dot(csr: &Csr, i: usize, xb: &[f32], backend: Backend) -> f32 {
    let (s, e) = (csr.row_ptr[i], csr.row_ptr[i + 1]);
    micro::dot_gather(&csr.vals[s..e], &csr.col_idx[s..e], xb, backend)
}

/// y[b, i] = sum_{nz in row i} vals[nz] * x[b, col_idx[nz]], default
/// backend.
pub fn csr_matmul(x: &[f32], csr: &Csr, batch: usize, y: &mut [f32]) {
    csr_matmul_with(x, csr, batch, y, Backend::default_backend());
}

/// [`csr_matmul`] with an explicit microkernel backend.
pub fn csr_matmul_with(x: &[f32], csr: &Csr, batch: usize, y: &mut [f32], backend: Backend) {
    let (rows, cols) = (csr.rows, csr.cols);
    debug_assert_eq!(x.len(), batch * cols);
    debug_assert_eq!(y.len(), batch * rows);
    for b in 0..batch {
        let xb = &x[b * cols..(b + 1) * cols];
        let yb = &mut y[b * rows..(b + 1) * rows];
        for (i, yv) in yb.iter_mut().enumerate() {
            *yv = csr_row_dot(csr, i, xb, backend);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::patterns::make_unstructured_mask;
    use crate::util::Rng;

    #[test]
    fn csr_structure() {
        let mut rng = Rng::new(50);
        let mask = make_unstructured_mask(16, 32, 0.2, &mut rng);
        let w: Vec<f32> = (0..512).map(|_| rng.normal()).collect();
        let csr = csr_from_mask(&w, &mask);
        assert_eq!(csr.nnz(), mask.nnz());
        assert_eq!(csr.row_ptr.len(), 17);
        // Column indices strictly increasing within each row.
        for i in 0..16 {
            let s = &csr.col_idx[csr.row_ptr[i]..csr.row_ptr[i + 1]];
            assert!(s.windows(2).all(|w| w[0] < w[1]));
        }
    }
}
