//! CSR sparse GEMM — the *unstructured* comparator for Fig. 3.
//!
//! CSR is what cuSparse executes for RigL/SET-style free masks in the
//! paper's timing section.  Row lengths are ragged, the column stream has
//! no structure to exploit, and each nonzero pays a full indirection —
//! which is exactly why unstructured DST wins accuracy but loses the
//! speedup race, on GPU and CPU alike.

use super::micro::{self, Backend};
// The layout (and builder) live in the sparsity layer so the pattern
// objects can emit CSR kernel plans without importing upward; the drivers
// here re-export them for the historical `kernels::{Csr, csr_from_mask}`
// paths.
pub use crate::sparsity::compress::{csr_from_mask, Csr};

/// One CSR row's dot product — a ragged slice of the same gather
/// microkernel the structured kernels run.  Shared by the serial and
/// parallel paths so the reduction order — and the f32 result — is
/// identical in both for a given backend.
#[inline(always)]
pub(crate) fn csr_row_dot(csr: &Csr, i: usize, xb: &[f32], backend: Backend) -> f32 {
    let (s, e) = (csr.row_ptr[i], csr.row_ptr[i + 1]);
    micro::dot_gather(&csr.vals[s..e], &csr.col_idx[s..e], xb, backend)
}

/// y[b, i] = sum_{nz in row i} vals[nz] * x[b, col_idx[nz]], default
/// backend.
pub fn csr_matmul(x: &[f32], csr: &Csr, batch: usize, y: &mut [f32]) {
    csr_matmul_with(x, csr, batch, y, Backend::default_backend());
}

/// [`csr_matmul`] with an explicit microkernel backend.
pub fn csr_matmul_with(x: &[f32], csr: &Csr, batch: usize, y: &mut [f32], backend: Backend) {
    let (rows, cols) = (csr.rows, csr.cols);
    debug_assert_eq!(x.len(), batch * cols);
    debug_assert_eq!(y.len(), batch * rows);
    for b in 0..batch {
        let xb = &x[b * cols..(b + 1) * cols];
        let yb = &mut y[b * rows..(b + 1) * rows];
        for (i, yv) in yb.iter_mut().enumerate() {
            *yv = csr_row_dot(csr, i, xb, backend);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::patterns::make_unstructured_mask;
    use crate::util::Rng;

    #[test]
    fn csr_structure() {
        let mut rng = Rng::new(50);
        let mask = make_unstructured_mask(16, 32, 0.2, &mut rng);
        let w: Vec<f32> = (0..512).map(|_| rng.normal()).collect();
        let csr = csr_from_mask(&w, &mask);
        assert_eq!(csr.nnz(), mask.nnz());
        assert_eq!(csr.row_ptr.len(), 17);
        // Column indices strictly increasing within each row.
        for i in 0..16 {
            let s = &csr.col_idx[csr.row_ptr[i]..csr.row_ptr[i + 1]];
            assert!(s.windows(2).all(|w| w[0] < w[1]));
        }
    }
}
