//! Machine-readable bench telemetry: the `BENCH_<name>.json` schema.
//!
//! Every bench target emits one [`BenchReport`] alongside its human table
//! so perf trajectories can be tracked across PRs and regressions gate CI
//! (`padst bench-compare`, [`super::baseline`]).  Serialisation goes
//! through the in-tree `util::json` — no serde in this offline build.
//!
//! Schema (version 1):
//!
//! ```json
//! {
//!   "schema_version": 1,
//!   "bench": "kernels",
//!   "threads": 8,
//!   "backend": "tiled",
//!   "records": [
//!     {"group": "microbench", "name": "gather(64,768,768) d=0.1",
//!      "backend": "tiled",
//!      "n": 57, "mean_s": 1.1e-4, "p50_s": 1.0e-4, "p90_s": 1.2e-4,
//!      "p95_s": 1.3e-4, "min_s": 9.0e-5, "max_s": 2.0e-4,
//!      "metrics": {"gflops": 12.5, "vs_naive": 2.1}}
//!   ]
//! }
//! ```
//!
//! A record with `n == 0` is *value-only* (e.g. the memory tables): its
//! timing fields are zero, `metrics` carries the payload, and the
//! regression gate skips it.
//!
//! `backend` (report-level and per-record) names the microkernel backend
//! the numbers were measured under — what makes a before/after
//! `bench-compare` of `BENCH_kernels.json` across `--backend scalar` vs
//! `--backend tiled` self-describing.  It is *not* part of the record
//! identity, so reports from different backends still match
//! record-by-record.  Absent in pre-backend reports (read back as `""`).
//!
//! `pattern` (per-record) carries the structure-family spec string the row
//! was measured under (`"diag"`, `"block:8"`, ...), resolved through the
//! `PatternRegistry`.  Like `backend` it is provenance metadata, not
//! identity, and is absent (read back as `""`) when a row has no pattern.
//!
//! `perm` (per-record) is the permutation-mode spec the row was measured
//! under (`"learned"`, `"random:seed=7"`, ...), resolved through the
//! `PermRegistry` — same provenance-not-identity rules as `pattern`.
//!
//! `tuned` (per-record) marks a row whose dispatch went through the
//! kernel autotuner's tuning table (`kernels::tune`) rather than the
//! default dispatch.  Provenance only, never identity; serialised only
//! when true and absent rows read back as `false`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::kernels::micro::Backend;
use crate::obs::{HistSnapshot, OBS_SCHEMA_VERSION};
use crate::util::json::{self, Json};
use crate::util::stats::Summary;

pub const SCHEMA_VERSION: u32 = 1;

/// One bench row.  `(group, name)` must be unique within a report — it is
/// the identity the baseline comparison matches on.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRecord {
    pub group: String,
    pub name: String,
    /// Microkernel backend the row was measured under ("" = unknown /
    /// pre-backend report).  Metadata only — never part of [`BenchRecord::id`].
    pub backend: String,
    /// Structure-family spec the row was measured under ("" = not
    /// pattern-specific).  Metadata only — never part of [`BenchRecord::id`].
    pub pattern: String,
    /// Permutation-mode spec the row was measured under ("" = not
    /// perm-specific).  Metadata only — never part of [`BenchRecord::id`].
    pub perm: String,
    /// Whether the row's dispatch went through the kernel autotuner's
    /// tuning table (`kernels::tune`).  Metadata only — never part of
    /// [`BenchRecord::id`]; serialised only when true.
    pub tuned: bool,
    /// Timed samples behind the quantiles; 0 for value-only records.
    pub n: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    /// Tail quantile the obs layer added; 0.0 in pre-obs reports, and the
    /// baseline comparison treats it as warn-only (never a CI gate).
    pub p90_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    /// `obs::OBS_SCHEMA_VERSION` when the record's quantiles came from an
    /// obs histogram ([`BenchRecord::from_hist`]); 0 when they came from
    /// the sorted-sample path (or a pre-obs report).
    pub obs_schema: u32,
    /// Free-form numeric side channel (gflops, speedups, MB, ...).
    pub metrics: BTreeMap<String, f64>,
}

impl BenchRecord {
    /// A timed record from the offline bench harness's [`Summary`].
    pub fn from_summary(group: &str, name: &str, s: &Summary) -> BenchRecord {
        BenchRecord {
            group: group.to_string(),
            name: name.to_string(),
            backend: String::new(),
            pattern: String::new(),
            perm: String::new(),
            tuned: false,
            n: s.n,
            mean_s: s.mean,
            p50_s: s.p50,
            p90_s: s.p90,
            p95_s: s.p95,
            min_s: s.min,
            max_s: s.max,
            obs_schema: 0,
            metrics: BTreeMap::new(),
        }
    }

    /// A timed record whose quantiles come from an obs nanosecond
    /// [`HistSnapshot`] (bucket midpoints, ≤6.25 % relative error — fine
    /// for trajectory tracking, which is why `obs_schema` stamps the
    /// provenance).
    pub fn from_hist(group: &str, name: &str, h: &HistSnapshot) -> BenchRecord {
        let s = |ns: u64| ns as f64 * 1e-9;
        BenchRecord {
            group: group.to_string(),
            name: name.to_string(),
            backend: String::new(),
            pattern: String::new(),
            perm: String::new(),
            tuned: false,
            n: h.count as usize,
            mean_s: h.mean() * 1e-9,
            p50_s: s(h.quantile(0.5)),
            p90_s: s(h.quantile(0.9)),
            p95_s: s(h.quantile(0.95)),
            min_s: s(h.min),
            max_s: s(h.max),
            obs_schema: OBS_SCHEMA_VERSION,
            metrics: BTreeMap::new(),
        }
    }

    /// A value-only record (no timing): the payload goes in `metrics`.
    pub fn value(group: &str, name: &str) -> BenchRecord {
        BenchRecord {
            group: group.to_string(),
            name: name.to_string(),
            backend: String::new(),
            pattern: String::new(),
            perm: String::new(),
            tuned: false,
            n: 0,
            mean_s: 0.0,
            p50_s: 0.0,
            p90_s: 0.0,
            p95_s: 0.0,
            min_s: 0.0,
            max_s: 0.0,
            obs_schema: 0,
            metrics: BTreeMap::new(),
        }
    }

    /// Builder-style metric attachment.
    pub fn with_metric(mut self, key: &str, v: f64) -> BenchRecord {
        self.metrics.insert(key.to_string(), v);
        self
    }

    /// Builder-style backend stamp (rows measured under a backend other
    /// than the report's, e.g. the kernels bench backend matrix).
    pub fn with_backend(mut self, backend: Backend) -> BenchRecord {
        self.backend = backend.name().to_string();
        self
    }

    /// Builder-style pattern-spec stamp (rows measured under a specific
    /// structure family, e.g. the Fig. 3 structure sweep).
    pub fn with_pattern(mut self, spec: &str) -> BenchRecord {
        self.pattern = spec.to_string();
        self
    }

    /// Builder-style perm-spec stamp (rows measured under a specific
    /// permutation treatment, e.g. the Tbl. 5 overhead rows).
    pub fn with_perm(mut self, spec: &str) -> BenchRecord {
        self.perm = spec.to_string();
        self
    }

    /// Builder-style tuned-provenance stamp (rows whose dispatch went
    /// through the autotuner's tuning table).
    pub fn with_tuned(mut self, tuned: bool) -> BenchRecord {
        self.tuned = tuned;
        self
    }

    /// The identity the baseline comparison matches on.
    pub fn id(&self) -> String {
        format!("{}/{}", self.group, self.name)
    }

    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("group", json::s(&self.group)),
            ("name", json::s(&self.name)),
        ];
        if !self.backend.is_empty() {
            pairs.push(("backend", json::s(&self.backend)));
        }
        if !self.pattern.is_empty() {
            pairs.push(("pattern", json::s(&self.pattern)));
        }
        if !self.perm.is_empty() {
            pairs.push(("perm", json::s(&self.perm)));
        }
        if self.tuned {
            pairs.push(("tuned", Json::Bool(true)));
        }
        if self.obs_schema != 0 {
            pairs.push(("obs_schema", json::num(self.obs_schema as f64)));
        }
        pairs.extend(vec![
            ("n", json::num(self.n as f64)),
            ("mean_s", json::num(self.mean_s)),
            ("p50_s", json::num(self.p50_s)),
            ("p90_s", json::num(self.p90_s)),
            ("p95_s", json::num(self.p95_s)),
            ("min_s", json::num(self.min_s)),
            ("max_s", json::num(self.max_s)),
            (
                "metrics",
                Json::Obj(
                    self.metrics.iter().map(|(k, &v)| (k.clone(), json::num(v))).collect(),
                ),
            ),
        ]);
        json::obj(pairs)
    }

    fn from_json(v: &Json) -> Result<BenchRecord> {
        let str_field = |k: &str| {
            v.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("bench record: missing string {k:?}"))
        };
        // Non-finite values serialise as JSON null; read them back as NaN.
        let num_field = |k: &str| -> Result<f64> {
            let x = v.get(k).ok_or_else(|| anyhow!("bench record: missing number {k:?}"))?;
            Ok(x.as_f64().unwrap_or(f64::NAN))
        };
        let mut metrics = BTreeMap::new();
        if let Some(m) = v.get("metrics").and_then(Json::as_obj) {
            for (k, mv) in m {
                metrics.insert(k.clone(), mv.as_f64().unwrap_or(f64::NAN));
            }
        }
        Ok(BenchRecord {
            group: str_field("group")?,
            name: str_field("name")?,
            backend: v
                .get("backend")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            pattern: v
                .get("pattern")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            perm: v
                .get("perm")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            tuned: v.get("tuned").and_then(Json::as_bool).unwrap_or(false),
            n: num_field("n")? as usize,
            mean_s: num_field("mean_s")?,
            p50_s: num_field("p50_s")?,
            // Absent in pre-obs reports; 0.0 makes the p90 comparison
            // skip the row rather than fabricate a delta.
            p90_s: v.get("p90_s").and_then(Json::as_f64).unwrap_or(0.0),
            p95_s: num_field("p95_s")?,
            min_s: num_field("min_s")?,
            max_s: num_field("max_s")?,
            obs_schema: v.get("obs_schema").and_then(Json::as_usize).unwrap_or(0) as u32,
            metrics,
        })
    }
}

/// One bench target's full report.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchReport {
    pub schema_version: u32,
    /// Bench name — the `BENCH_<bench>.json` stem.
    pub bench: String,
    /// Resolved worker-thread ceiling the bench ran under.
    pub threads: usize,
    /// Microkernel backend the bench ran under ("" for pre-backend
    /// reports).  Defaults to [`Backend::default_backend`]; override with
    /// [`BenchReport::with_backend`] when a `--backend` flag was parsed.
    pub backend: String,
    /// Obs snapshot provenance (`ObsSnapshot::to_json`) from the run that
    /// produced the report.  Optional and never part of any record's
    /// identity: bench-compare ignores it entirely.
    pub obs: Option<Json>,
    pub records: Vec<BenchRecord>,
}

impl BenchReport {
    pub fn new(bench: &str, threads: usize) -> BenchReport {
        BenchReport {
            schema_version: SCHEMA_VERSION,
            bench: bench.to_string(),
            threads,
            backend: Backend::default_backend().name().to_string(),
            obs: None,
            records: Vec::new(),
        }
    }

    /// Builder-style backend stamp for the whole report.
    pub fn with_backend(mut self, backend: Backend) -> BenchReport {
        self.backend = backend.name().to_string();
        self
    }

    /// Builder-style obs-snapshot attachment (provenance only).
    pub fn with_obs(mut self, obs: Json) -> BenchReport {
        self.obs = Some(obs);
        self
    }

    /// Append a record, stamping the report's backend onto it unless the
    /// record already carries its own.
    pub fn push(&mut self, mut r: BenchRecord) {
        if r.backend.is_empty() {
            r.backend = self.backend.clone();
        }
        self.records.push(r);
    }

    pub fn find(&self, group: &str, name: &str) -> Option<&BenchRecord> {
        self.records.iter().find(|r| r.group == group && r.name == name)
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("schema_version", json::num(self.schema_version as f64)),
            ("bench", json::s(&self.bench)),
            ("threads", json::num(self.threads as f64)),
        ];
        if !self.backend.is_empty() {
            pairs.push(("backend", json::s(&self.backend)));
        }
        if let Some(obs) = &self.obs {
            pairs.push(("obs", obs.clone()));
        }
        pairs.push((
            "records",
            Json::Arr(self.records.iter().map(BenchRecord::to_json).collect()),
        ));
        json::obj(pairs)
    }

    pub fn parse(src: &str) -> Result<BenchReport> {
        let v = Json::parse(src).context("parsing bench report")?;
        let schema_version = v
            .get("schema_version")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("bench report: missing schema_version"))? as u32;
        if schema_version != SCHEMA_VERSION {
            return Err(anyhow!(
                "bench report schema v{schema_version} != supported v{SCHEMA_VERSION}"
            ));
        }
        let bench = v
            .get("bench")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("bench report: missing bench name"))?
            .to_string();
        let threads = v.get("threads").and_then(Json::as_usize).unwrap_or(0);
        let backend = v.get("backend").and_then(Json::as_str).unwrap_or("").to_string();
        let obs = v.get("obs").cloned().filter(|j| !matches!(j, Json::Null));
        let records = v
            .get("records")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("bench report: missing records"))?
            .iter()
            .map(BenchRecord::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(BenchReport { schema_version, bench, threads, backend, obs, records })
    }

    /// Atomic write (temp + rename, parent dirs created).
    pub fn write(&self, path: &Path) -> Result<()> {
        crate::util::fs::write_atomic(path, &self.to_json().to_string_pretty())
    }

    pub fn load(path: &Path) -> Result<BenchReport> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading bench report {}", path.display()))?;
        BenchReport::parse(&src).with_context(|| path.display().to_string())
    }
}
