//! Grid planning and the resume journal.
//!
//! [`plan_cells`] expands a (method x sparsity) grid into the flat,
//! deterministic cell list the executor shards — the same order the
//! sequential sweep walks, so merged results compare byte-for-byte.
//!
//! [`Journal`] is a JSONL checkpoint: one line per completed cell,
//! appended and flushed as cells finish (safe to call from any worker
//! thread).  Reopening the journal returns the completed cells so a killed
//! sweep resumes without recomputation; a line truncated by the kill is
//! detected, sealed, and skipped.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

/// One cell of a sweep grid.  The `id` string (`"method@sparsity"`) keys
/// the journal; `f64` Display round-trips exactly, so ids are stable
/// across runs.
#[derive(Clone, Debug, PartialEq)]
pub struct CellKey {
    pub method: String,
    pub sparsity: f64,
}

impl CellKey {
    pub fn id(&self) -> String {
        format!("{}@{}", self.method, self.sparsity)
    }
}

/// Expand (method x sparsity) into the flat cell list, in sequential-sweep
/// order.  Each method name is paired with whether it has a sparsity axis;
/// a method without one (Dense) contributes exactly one cell, at the first
/// sparsity.
pub fn plan_cells(methods: &[(&str, bool)], sparsities: &[f64]) -> Vec<CellKey> {
    let mut cells = Vec::new();
    for &(name, has_axis) in methods {
        for &sp in sparsities {
            cells.push(CellKey { method: name.to_string(), sparsity: sp });
            if !has_axis {
                break;
            }
        }
    }
    cells
}

/// Append-only JSONL checkpoint of completed cells.
///
/// Line format: `{"cell": <value>, "key": "<id>"}` — one line per cell,
/// flushed on write so at most the in-flight record is lost on a kill.
/// Shareable across worker threads (`record` locks internally).
pub struct Journal {
    path: PathBuf,
    file: Mutex<File>,
}

impl Journal {
    /// Open `path` (creating parent directories and the file as needed)
    /// and read back the cells completed by a previous — possibly
    /// interrupted — run.  A truncated trailing line is sealed with a
    /// newline so subsequent appends stay parseable, and skipped.
    pub fn open(path: &Path) -> Result<(Journal, BTreeMap<String, Json>)> {
        crate::util::fs::create_parent_dirs(path)?;
        let mut done = BTreeMap::new();
        let mut needs_seal = false;
        if path.exists() {
            let content = std::fs::read_to_string(path)
                .with_context(|| format!("reading journal {}", path.display()))?;
            needs_seal = !content.is_empty() && !content.ends_with('\n');
            for line in content.lines() {
                if line.trim().is_empty() {
                    continue;
                }
                // A line that doesn't parse is the torn tail of a killed
                // run; its cell simply re-runs.
                let Ok(v) = Json::parse(line) else { continue };
                if let (Some(k), Some(cell)) = (v.get("key").and_then(Json::as_str), v.get("cell"))
                {
                    done.insert(k.to_string(), cell.clone());
                }
            }
        }
        let mut file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening journal {} for append", path.display()))?;
        if needs_seal {
            writeln!(file).with_context(|| format!("sealing journal {}", path.display()))?;
        }
        Ok((Journal { path: path.to_path_buf(), file: Mutex::new(file) }, done))
    }

    /// Append one completed cell and flush.
    pub fn record(&self, key: &str, cell: &Json) -> Result<()> {
        // The compact serializer emits no newlines, so one value = one line.
        let line = json::obj(vec![("key", json::s(key)), ("cell", cell.clone())]);
        let mut f = self.file.lock().unwrap();
        writeln!(f, "{}", line.to_string_pretty())
            .and_then(|()| f.flush())
            .with_context(|| format!("appending to journal {}", self.path.display()))?;
        Ok(())
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_ids_are_stable() {
        let k = CellKey { method: "DynaDiag+PA".into(), sparsity: 0.95 };
        assert_eq!(k.id(), "DynaDiag+PA@0.95");
    }

    #[test]
    fn plan_cells_order_and_dense_break() {
        let cells = plan_cells(&[("A", true), ("Dense", false), ("B", true)], &[0.6, 0.9]);
        let ids: Vec<String> = cells.iter().map(CellKey::id).collect();
        assert_eq!(ids, ["A@0.6", "A@0.9", "Dense@0.6", "B@0.6", "B@0.9"]);
    }
}
