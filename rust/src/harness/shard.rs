//! Grid planning and the resume journal.
//!
//! [`plan_cells`] expands a (method x sparsity) grid into the flat,
//! deterministic cell list the executor shards — the same order the
//! sequential sweep walks, so merged results compare byte-for-byte.
//!
//! [`Journal`] is a JSONL checkpoint: one line per completed cell,
//! appended and flushed as cells finish (safe to call from any worker
//! thread).  Reopening the journal returns the completed cells so a killed
//! sweep resumes without recomputation; a line truncated by the kill is
//! detected, sealed, and skipped.
//!
//! [`parse_shard`] / [`in_shard`] and [`merge_journals`] turn the journal
//! format into a cluster fan-out mechanism: `padst sweep --shard i/n`
//! runs only the grid slots owned by shard `i`, each machine journals its
//! own cells under the same metadata header, and `padst journal-merge`
//! combines the shards into one journal a final `--journal` run resumes
//! from without recomputing anything.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// Journal line holding the sweep parameters; a journal only resumes (or
/// merges with) a sweep whose metadata matches this header exactly.
/// Defined in [`crate::obs::watch`], which owns the journal record-tag
/// namespace (`hb`, `plan`, `__meta__`); re-exported here for the
/// journal's own readers.
pub use crate::obs::watch::META_KEY;

/// Parse a `--shard i/n` value into (index, count): `i` zero-based,
/// `i < n`, `n >= 1`.
pub fn parse_shard(s: &str) -> Result<(usize, usize)> {
    let (i, n) = s
        .split_once('/')
        .ok_or_else(|| anyhow!("--shard wants i/n (e.g. 0/4), got {s:?}"))?;
    let i: usize =
        i.trim().parse().map_err(|_| anyhow!("--shard index {i:?} is not a number"))?;
    let n: usize =
        n.trim().parse().map_err(|_| anyhow!("--shard count {n:?} is not a number"))?;
    if n == 0 {
        bail!("--shard count must be >= 1");
    }
    if i >= n {
        bail!("--shard index {i} out of range 0..{n}");
    }
    Ok((i, n))
}

/// Whether grid slot `slot` belongs to shard `(i, n)` (`None` = no
/// sharding, every slot belongs).  Round-robin by slot id — simple and
/// deterministic, but note the alignment hazard: the grid is laid out
/// method-major with sparsities innermost, so a shard count equal to (or
/// sharing a factor with) the sparsity count assigns each shard a fixed
/// sparsity column, and cell cost correlates with density.  Pick `n`
/// coprime with the sparsity count when load balance matters.
pub fn in_shard(slot: usize, shard: Option<(usize, usize)>) -> bool {
    match shard {
        Some((i, n)) => slot % n == i,
        None => true,
    }
}

/// One cell of a sweep grid.  The `id` string (`"method@sparsity"`) keys
/// the journal; `f64` Display round-trips exactly, so ids are stable
/// across runs.
#[derive(Clone, Debug, PartialEq)]
pub struct CellKey {
    pub method: String,
    pub sparsity: f64,
}

impl CellKey {
    pub fn id(&self) -> String {
        format!("{}@{}", self.method, self.sparsity)
    }
}

/// Expand (method x sparsity) into the flat cell list, in sequential-sweep
/// order.  Each method name is paired with whether it has a sparsity axis;
/// a method without one (Dense) contributes exactly one cell, at the first
/// sparsity.
pub fn plan_cells(methods: &[(&str, bool)], sparsities: &[f64]) -> Vec<CellKey> {
    let mut cells = Vec::new();
    for &(name, has_axis) in methods {
        for &sp in sparsities {
            cells.push(CellKey { method: name.to_string(), sparsity: sp });
            if !has_axis {
                break;
            }
        }
    }
    cells
}

/// Append-only JSONL checkpoint of completed cells.
///
/// Line format: `{"cell": <value>, "key": "<id>"}` — one line per cell,
/// flushed on write so at most the in-flight record is lost on a kill.
/// Shareable across worker threads (`record` locks internally).
pub struct Journal {
    path: PathBuf,
    file: Mutex<File>,
}

impl Journal {
    /// Open `path` (creating parent directories and the file as needed)
    /// and read back the cells completed by a previous — possibly
    /// interrupted — run.  A truncated trailing line is sealed with a
    /// newline so subsequent appends stay parseable, and skipped.
    pub fn open(path: &Path) -> Result<(Journal, BTreeMap<String, Json>)> {
        crate::util::fs::create_parent_dirs(path)?;
        let mut done = BTreeMap::new();
        let mut needs_seal = false;
        if path.exists() {
            let content = std::fs::read_to_string(path)
                .with_context(|| format!("reading journal {}", path.display()))?;
            needs_seal = !content.is_empty() && !content.ends_with('\n');
            done = parse_journal_lines(&content);
        }
        let mut file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening journal {} for append", path.display()))?;
        if needs_seal {
            writeln!(file).with_context(|| format!("sealing journal {}", path.display()))?;
        }
        Ok((Journal { path: path.to_path_buf(), file: Mutex::new(file) }, done))
    }

    /// Append one completed cell and flush.
    pub fn record(&self, key: &str, cell: &Json) -> Result<()> {
        let line = journal_line(key, cell);
        let mut f = self.file.lock().unwrap();
        writeln!(f, "{line}")
            .and_then(|()| f.flush())
            .with_context(|| format!("appending to journal {}", self.path.display()))?;
        Ok(())
    }

    /// Append one tagged side-record (`{"<tag>": payload}`) and flush.
    /// Heartbeats (`obs::watch::HEARTBEAT_KEY`) and plan records ride
    /// this lane: resume and merge readers key on `"key"`/`"cell"` and
    /// skip anything else, so journals with events stay readable by
    /// pre-event tooling.  [`merge_journals`] drops them by design —
    /// they describe one run's liveness, not the sweep's results.
    pub fn append_event(&self, tag: &str, payload: &Json) -> Result<()> {
        let line = json::obj(vec![(tag, payload.clone())]).to_string_pretty();
        let mut f = self.file.lock().unwrap();
        writeln!(f, "{line}")
            .and_then(|()| f.flush())
            .with_context(|| format!("appending event to journal {}", self.path.display()))?;
        Ok(())
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Parse journal text into its record map.  A line that doesn't parse is
/// the torn tail of a killed run; its cell simply re-runs.
fn parse_journal_lines(content: &str) -> BTreeMap<String, Json> {
    let mut done = BTreeMap::new();
    for line in content.lines() {
        if line.trim().is_empty() {
            continue;
        }
        let Ok(v) = Json::parse(line) else { continue };
        if let (Some(k), Some(cell)) = (v.get("key").and_then(Json::as_str), v.get("cell")) {
            done.insert(k.to_string(), cell.clone());
        }
    }
    done
}

/// Read a journal without opening it for append (the file must exist).
/// Returns the full record map, [`META_KEY`] header included.
pub fn read_journal(path: &Path) -> Result<BTreeMap<String, Json>> {
    let content = std::fs::read_to_string(path)
        .with_context(|| format!("reading journal {}", path.display()))?;
    Ok(parse_journal_lines(&content))
}

/// One serialized journal line (what [`Journal::record`] appends): the
/// compact serializer emits no newlines, so one record = one line.
fn journal_line(key: &str, cell: &Json) -> String {
    json::obj(vec![("key", json::s(key)), ("cell", cell.clone())]).to_string_pretty()
}

/// Merge shard journals into one resumable journal.
///
/// Every input must carry a [`META_KEY`] header and all headers must be
/// identical — the cross-machine analogue of the resume check, refusing
/// to splice cells from different sweeps.  Cells are unioned; when the
/// same cell id appears in several inputs the first occurrence wins (two
/// completions of one cell differ only in wall-clock fields).  The merged
/// journal is written atomically: header first, then cells in sorted id
/// order.  Returns the number of distinct cells written.
pub fn merge_journals(inputs: &[PathBuf], out: &Path) -> Result<usize> {
    if inputs.is_empty() {
        bail!("journal-merge needs at least one input journal");
    }
    let mut meta: Option<Json> = None;
    let mut cells: BTreeMap<String, Json> = BTreeMap::new();
    for path in inputs {
        let mut records = read_journal(path)?;
        let this_meta = records.remove(META_KEY).ok_or_else(|| {
            anyhow!("journal {} has no {META_KEY} header; refusing to merge", path.display())
        })?;
        match &meta {
            Some(prev) if *prev != this_meta => bail!(
                "journal {} belongs to a different sweep ({}); the first input was {}",
                path.display(),
                this_meta.to_string_pretty(),
                prev.to_string_pretty()
            ),
            Some(_) => {}
            None => meta = Some(this_meta),
        }
        for (k, v) in records {
            cells.entry(k).or_insert(v);
        }
    }
    let meta = meta.expect("non-empty inputs always set meta");
    let mut text = String::new();
    text.push_str(&journal_line(META_KEY, &meta));
    text.push('\n');
    for (k, v) in &cells {
        text.push_str(&journal_line(k, v));
        text.push('\n');
    }
    crate::util::fs::write_atomic(out, &text)?;
    Ok(cells.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_ids_are_stable() {
        let k = CellKey { method: "DynaDiag+PA".into(), sparsity: 0.95 };
        assert_eq!(k.id(), "DynaDiag+PA@0.95");
    }

    #[test]
    fn plan_cells_order_and_dense_break() {
        let cells = plan_cells(&[("A", true), ("Dense", false), ("B", true)], &[0.6, 0.9]);
        let ids: Vec<String> = cells.iter().map(CellKey::id).collect();
        assert_eq!(ids, ["A@0.6", "A@0.9", "Dense@0.6", "B@0.6", "B@0.9"]);
    }

    #[test]
    fn parse_shard_accepts_and_rejects() {
        assert_eq!(parse_shard("0/4").unwrap(), (0, 4));
        assert_eq!(parse_shard("3/4").unwrap(), (3, 4));
        assert_eq!(parse_shard("0/1").unwrap(), (0, 1));
        assert!(parse_shard("4/4").is_err(), "index == count");
        assert!(parse_shard("0/0").is_err(), "zero count");
        assert!(parse_shard("1").is_err(), "no slash");
        assert!(parse_shard("a/b").is_err(), "not numbers");
    }

    #[test]
    fn shards_partition_every_slot_exactly_once() {
        let n = 3;
        for slot in 0..20 {
            let owners = (0..n).filter(|&i| in_shard(slot, Some((i, n)))).count();
            assert_eq!(owners, 1, "slot {slot}");
            assert!(in_shard(slot, None));
        }
    }
}
