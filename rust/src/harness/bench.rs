//! Bench-target knob parsing that needs crate types.
//!
//! The std-only argv helpers live in [`crate::util::cli`]; this module
//! layers the microkernel backend knob and the per-bench option bundle on
//! top.  It sits in `harness` because harness is the lowest layer the
//! manifest allows to see `kernels` *and* that the bench binaries already
//! depend on — keeping `util` a leaf (lint rule L1).

use std::path::PathBuf;

use crate::kernels::micro::Backend;
use crate::util::cli::{
    arg_value_in, argv, bench_json_path, has_flag_in, resolve_threads, thread_knob_in,
};

/// Resolve the microkernel backend from an argv slice: `--backend NAME`
/// wins, else the `PADST_BACKEND` env var, else Tiled.  Unknown names
/// warn and fall back (see [`Backend::resolve`]); the `padst` CLI parses
/// its own flag strictly instead.
pub fn backend_knob_in(args: &[String]) -> Backend {
    Backend::resolve(arg_value_in(args, "--backend").as_deref())
}

/// Options shared by every bench target, parsed from argv + environment in
/// one place.
#[derive(Clone, Debug)]
pub struct BenchOpts {
    /// Bench name (the `BENCH_<name>.json` stem).
    pub bench: String,
    /// Resolved worker-thread ceiling (>= 1).
    pub threads: usize,
    /// Resolved microkernel backend (`--backend` / `PADST_BACKEND`,
    /// default Tiled).
    pub backend: Backend,
    /// Short mode (`--short` or `PADST_BENCH_SHORT=1`): CI-sized sample
    /// budgets via [`BenchOpts::budget`].
    pub short: bool,
    /// Where the JSON report is written (`--json PATH` overrides
    /// [`bench_json_path`]).
    pub json_path: PathBuf,
}

impl BenchOpts {
    pub fn parse(bench: &str) -> BenchOpts {
        let args = argv();
        let short = has_flag_in(&args, "--short")
            || std::env::var("PADST_BENCH_SHORT")
                .map(|v| !v.is_empty() && v != "0")
                .unwrap_or(false);
        let json_path = arg_value_in(&args, "--json")
            .map(PathBuf::from)
            .unwrap_or_else(|| bench_json_path(bench));
        // An explicit --backend pins the backend for the whole bench run:
        // the tuning table may still select bit-preserving variants but
        // never another backend (see `kernels::tune`).
        if arg_value_in(&args, "--backend").is_some() {
            crate::kernels::tune::note_backend_pinned();
        }
        BenchOpts {
            bench: bench.to_string(),
            threads: resolve_threads(thread_knob_in(&args)),
            backend: backend_knob_in(&args),
            short,
            json_path,
        }
    }

    /// Scale a call site's `(warmup, min_iters, min_time_s)` budget down
    /// for short mode; identity otherwise.
    pub fn budget(&self, warmup: usize, min_iters: usize, min_time_s: f64) -> (usize, usize, f64) {
        if self.short {
            (warmup.min(1), min_iters.min(2), min_time_s.min(0.02))
        } else {
            (warmup, min_iters, min_time_s)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn backend_knob_explicit_flag_wins() {
        let a = args(&["bench", "--backend", "scalar"]);
        assert_eq!(backend_knob_in(&a), Backend::Scalar);
        // Unknown names warn and fall back instead of erroring (benches
        // should not die over a knob).
        let bad = args(&["bench", "--backend", "gpu"]);
        assert_eq!(backend_knob_in(&bad), Backend::Tiled);
    }

    #[test]
    fn short_budget_caps() {
        let mut o = BenchOpts {
            bench: "x".into(),
            threads: 1,
            backend: Backend::Tiled,
            short: true,
            json_path: PathBuf::from("BENCH_x.json"),
        };
        assert_eq!(o.budget(2, 5, 0.3), (1, 2, 0.02));
        o.short = false;
        assert_eq!(o.budget(2, 5, 0.3), (2, 5, 0.3));
    }
}
