//! Baseline comparison — the CI perf gate behind `padst bench-compare`.
//!
//! Two [`BenchReport`]s are matched record-by-record on `(group, name)`
//! and diffed on p50.  A record whose p50 grew by more than the threshold
//! is a regression; `padst bench-compare <old> <new>` exits non-zero if
//! any survive.  Value-only records (`n == 0`) and records present in only
//! one report are listed but never gate.
//!
//! p90 is also compared, *warn-only*: a tail regression prints but never
//! fails the gate (tails are noisier than medians, and pre-obs baselines
//! have no p90 at all — those rows are skipped).

use std::collections::{BTreeMap, BTreeSet};

use super::telemetry::{BenchRecord, BenchReport};
use crate::util::stats::fmt_time;

/// One matched record's p50 movement.
#[derive(Clone, Debug)]
pub struct Delta {
    pub id: String,
    pub old_p50_s: f64,
    pub new_p50_s: f64,
    /// Signed percent change of p50 (positive = slower).
    pub pct: f64,
}

#[derive(Clone, Debug)]
pub struct Comparison {
    pub threshold_pct: f64,
    /// p50 grew by more than the threshold — these gate.
    pub regressions: Vec<Delta>,
    /// p50 shrank by more than the threshold.
    pub improvements: Vec<Delta>,
    /// Matched timed records inside the threshold band.
    pub within: usize,
    /// Record ids only in the new report.
    pub added: Vec<String>,
    /// Record ids only in the old report.
    pub removed: Vec<String>,
    /// p90 grew past the threshold — warn-only, never gates.  Rows where
    /// either side lacks p90 (pre-obs baselines) are skipped.
    pub p90_warnings: Vec<Delta>,
}

impl Comparison {
    pub fn regressed(&self) -> bool {
        !self.regressions.is_empty()
    }
}

/// Diff `new` against `old` with a p50 regression threshold in percent.
pub fn compare(old: &BenchReport, new: &BenchReport, threshold_pct: f64) -> Comparison {
    let mut cmp = Comparison {
        threshold_pct,
        regressions: Vec::new(),
        improvements: Vec::new(),
        within: 0,
        added: Vec::new(),
        removed: Vec::new(),
        p90_warnings: Vec::new(),
    };
    let old_by: BTreeMap<String, &BenchRecord> = old.records.iter().map(|r| (r.id(), r)).collect();
    let new_ids: BTreeSet<String> = new.records.iter().map(|r| r.id()).collect();

    for r in &new.records {
        match old_by.get(&r.id()) {
            None => cmp.added.push(r.id()),
            // Value-only rows and degenerate timings carry no p50 signal.
            Some(o) if o.n == 0 || r.n == 0 || o.p50_s <= 0.0 => {}
            Some(o) => {
                let pct = (r.p50_s / o.p50_s - 1.0) * 100.0;
                let d = Delta { id: r.id(), old_p50_s: o.p50_s, new_p50_s: r.p50_s, pct };
                if pct > threshold_pct {
                    cmp.regressions.push(d);
                } else if pct < -threshold_pct {
                    cmp.improvements.push(d);
                } else {
                    cmp.within += 1;
                }
                if o.p90_s > 0.0 && r.p90_s > 0.0 {
                    let pct90 = (r.p90_s / o.p90_s - 1.0) * 100.0;
                    if pct90 > threshold_pct {
                        cmp.p90_warnings.push(Delta {
                            id: r.id(),
                            old_p50_s: o.p90_s,
                            new_p50_s: r.p90_s,
                            pct: pct90,
                        });
                    }
                }
            }
        }
    }
    for id in old_by.keys() {
        if !new_ids.contains(id) {
            cmp.removed.push(id.clone());
        }
    }
    cmp.regressions.sort_by(|a, b| b.pct.total_cmp(&a.pct));
    cmp.improvements.sort_by(|a, b| a.pct.total_cmp(&b.pct));
    cmp.p90_warnings.sort_by(|a, b| b.pct.total_cmp(&a.pct));
    cmp
}

/// Human rendering of a comparison (the `bench-compare` output).
pub fn print_comparison(c: &Comparison) {
    let row = |d: &Delta, tag: &str| {
        println!(
            "  {tag} {:<52} {:>10} -> {:>10}  {:>+7.1}%",
            d.id,
            fmt_time(d.old_p50_s),
            fmt_time(d.new_p50_s),
            d.pct
        );
    };
    println!(
        "# bench-compare: threshold ±{:.1}% on p50 ({} regressed, {} improved, {} within, \
         {} added, {} removed, {} p90-warned)",
        c.threshold_pct,
        c.regressions.len(),
        c.improvements.len(),
        c.within,
        c.added.len(),
        c.removed.len(),
        c.p90_warnings.len()
    );
    for d in &c.regressions {
        row(d, "REGRESSED");
    }
    for d in &c.improvements {
        row(d, "improved ");
    }
    for d in &c.p90_warnings {
        row(d, "p90-warn ");
    }
    for id in &c.added {
        println!("  added     {id}");
    }
    for id in &c.removed {
        println!("  removed   {id}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::summarize;

    fn report_with_p50(p50: f64) -> BenchReport {
        let mut r = BenchReport::new("kernels", 1);
        r.push(BenchRecord::from_summary("g", "hot", &summarize(&[p50, p50, p50])));
        r
    }

    #[test]
    fn threshold_splits_regressions_and_improvements() {
        let old = report_with_p50(1.0);
        assert!(!compare(&old, &report_with_p50(1.05), 10.0).regressed());
        let c = compare(&old, &report_with_p50(1.25), 10.0);
        assert!(c.regressed());
        assert_eq!(c.regressions[0].id, "g/hot");
        let c = compare(&old, &report_with_p50(0.5), 10.0);
        assert!(!c.regressed());
        assert_eq!(c.improvements.len(), 1);
    }

    #[test]
    fn p90_regression_warns_but_never_gates() {
        fn rep(p50: f64, p90: f64) -> BenchReport {
            let mut r = BenchReport::new("kernels", 1);
            let mut rec = BenchRecord::from_summary("g", "hot", &summarize(&[p50]));
            rec.p90_s = p90;
            r.push(rec);
            r
        }
        let c = compare(&rep(1.0, 1.0), &rep(1.0, 2.0), 10.0);
        assert!(!c.regressed(), "p90 movement alone must not gate");
        assert_eq!(c.p90_warnings.len(), 1);
        assert_eq!(c.p90_warnings[0].id, "g/hot");
        // Pre-obs baseline: no p90 on the old side, row skipped.
        let c = compare(&rep(1.0, 0.0), &rep(1.0, 2.0), 10.0);
        assert!(c.p90_warnings.is_empty());
    }

    #[test]
    fn value_only_records_never_gate() {
        let mut old = BenchReport::new("table5_overhead", 1);
        old.push(BenchRecord::value("memory", "vit_tiny/+PA-DST").with_metric("state_mb", 1.0));
        let mut new = BenchReport::new("table5_overhead", 1);
        new.push(BenchRecord::value("memory", "vit_tiny/+PA-DST").with_metric("state_mb", 99.0));
        assert!(!compare(&old, &new, 10.0).regressed());
    }

    #[test]
    fn added_and_removed_are_reported() {
        let mut old = BenchReport::new("kernels", 1);
        old.push(BenchRecord::value("g", "gone"));
        let mut new = BenchReport::new("kernels", 1);
        new.push(BenchRecord::value("g", "fresh"));
        let c = compare(&old, &new, 10.0);
        assert_eq!(c.added, ["g/fresh"]);
        assert_eq!(c.removed, ["g/gone"]);
    }
}
