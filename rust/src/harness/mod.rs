//! Execution-and-measurement harness — the layer between the coordinator
//! and the kernels that fans work out and writes structured results back.
//!
//! Two halves share one record model:
//!
//! * **Sharded execution** ([`shard`] + [`executor`]): a (method x
//!   sparsity) sweep grid is expanded into independent cells
//!   ([`shard::plan_cells`]), executed on a scoped-thread worker pool
//!   where every worker owns its own context — for sweeps, its own
//!   `Runtime`, created inside the worker thread because runtimes are not
//!   `Send` ([`executor::execute_sharded`]) — and merged back in grid
//!   order, so the output is identical to the sequential path no matter
//!   how the scheduler interleaved the cells.  Completed cells checkpoint
//!   to a JSONL [`shard::Journal`], so an interrupted sweep resumes
//!   without recomputation.
//!
//! * **Bench telemetry** ([`telemetry`] + [`baseline`]): every bench
//!   target serialises its rows as a [`telemetry::BenchReport`]
//!   (`BENCH_<name>.json`, via the in-tree `util::json` — no serde), and
//!   [`baseline::compare`] diffs two reports on p50 so `padst
//!   bench-compare` can gate CI on perf regressions.
//!
//! The executor is deliberately generic over the cell/result types: the
//! determinism, error-propagation, and resume behaviour are all testable
//! with synthetic cells (`tests/harness.rs`) — no artifacts or PJRT
//! backend required.

pub mod baseline;
pub mod bench;
pub mod executor;
pub mod shard;
pub mod telemetry;

pub use baseline::{compare, Comparison};
pub use executor::{execute_sharded, resolve_workers};
pub use shard::{plan_cells, CellKey, Journal};
pub use telemetry::{BenchRecord, BenchReport};
