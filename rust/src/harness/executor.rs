//! Scoped-thread worker pool for independent cells.
//!
//! Workers pull cell indices from a shared atomic cursor (dynamic load
//! balancing: cell costs vary wildly across a sweep — Dense at one step
//! count vs a 95 %-sparse butterfly run), execute them with a per-worker
//! context built *inside* the worker thread (the context type needs no
//! `Send`/`Sync` bounds, which is what lets each sweep worker own its own
//! `Runtime`), and write results into per-index slots.  Merging by index
//! makes the output order bit-identical to the sequential path regardless
//! of scheduling.
//!
//! Error policy: the first failing cell (or worker init) aborts the pool —
//! in-flight cells finish, queued cells are abandoned — and the error is
//! returned after all workers have joined.  With a journal upstream
//! (`shard::Journal`), cells completed before the failure are not lost.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::obs::{self, Counter, Histogram};
use crate::util::cli::available_threads;

/// Per-worker instrumentation handles, registered on the process-wide
/// registry (`obs::global()`).  Cells are macro operations (seconds to
/// minutes), so they record unconditionally — no `obs::enabled()` gate.
struct WorkerObs {
    /// Cells this worker pulled from the cursor (attempted, not finished).
    pulled: Arc<Counter>,
    /// Total nanoseconds this worker spent inside `work` — utilization is
    /// `busy_ns / wall_ns` per worker, and skew across workers exposes
    /// shard-alignment imbalance.
    busy_ns: Arc<Counter>,
    /// Pool-wide per-cell duration (successful cells only).
    cell_ns: Arc<Histogram>,
    /// Pool-wide completed-cell count.
    cells_done: Arc<Counter>,
}

impl WorkerObs {
    fn new(wid: usize) -> WorkerObs {
        let reg = obs::global();
        WorkerObs {
            pulled: reg.counter(&format!("harness.worker{wid}.pulled")),
            busy_ns: reg.counter(&format!("harness.worker{wid}.busy_ns")),
            cell_ns: reg.histogram("harness.cell_ns"),
            cells_done: reg.counter("harness.cells_done"),
        }
    }

    /// Run one cell under the pull/busy/done counters.
    fn observe<T>(&self, f: impl FnOnce() -> Result<T>) -> Result<T> {
        self.pulled.inc();
        let t0 = Instant::now();
        let out = f();
        let ns = u64::try_from(t0.elapsed().as_nanos()).unwrap_or(u64::MAX);
        self.busy_ns.add(ns);
        if out.is_ok() {
            self.cell_ns.record(ns);
            self.cells_done.inc();
        }
        out
    }
}

/// Resolve a worker knob against a cell count: 0 = auto (available
/// parallelism), and never more workers than cells.
pub fn resolve_workers(workers: usize, n_cells: usize) -> usize {
    let cap = n_cells.max(1);
    if workers == 0 {
        available_threads().min(cap)
    } else {
        workers.min(cap)
    }
}

/// Execute `work` over every key on a pool of `workers` scoped threads
/// (resolved via [`resolve_workers`]); returns results in key order.
///
/// `init(worker_id)` builds one context per worker, inside that worker's
/// thread.  `work(ctx, index, key)` runs one cell.  With one worker the
/// whole thing runs inline on the calling thread — that *is* the
/// sequential path, same context, same cell order.
// lint: no-panic
pub fn execute_sharded<K, W, T, I, F>(
    keys: &[K],
    workers: usize,
    init: I,
    work: F,
) -> Result<Vec<T>>
where
    K: Sync,
    T: Send,
    I: Fn(usize) -> Result<W> + Sync,
    F: Fn(&mut W, usize, &K) -> Result<T> + Sync,
{
    if keys.is_empty() {
        return Ok(Vec::new());
    }
    let workers = resolve_workers(workers, keys.len());
    if workers <= 1 {
        let mut ctx = init(0)?;
        let wobs = WorkerObs::new(0);
        return keys
            .iter()
            .enumerate()
            .map(|(i, k)| wobs.observe(|| work(&mut ctx, i, k)))
            .collect();
    }

    let cursor = AtomicUsize::new(0);
    let abort = AtomicBool::new(false);
    let slots: Mutex<Vec<Option<T>>> = Mutex::new((0..keys.len()).map(|_| None).collect());
    let first_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);

    let fail = |e: anyhow::Error| {
        // A poisoned lock just means another worker died mid-store; the
        // data (an Option slot) is still coherent, so keep the error.
        let mut fe = first_err.lock().unwrap_or_else(|p| p.into_inner());
        if fe.is_none() {
            *fe = Some(e);
        }
        // ordering: SeqCst publish of the abort flag so every worker's
        // next loop-top load observes it after the error is stored.
        abort.store(true, Ordering::SeqCst);
    };

    std::thread::scope(|scope| {
        for wid in 0..workers {
            let (init, work, fail) = (&init, &work, &fail);
            let (cursor, abort, slots) = (&cursor, &abort, &slots);
            scope.spawn(move || {
                let mut ctx = match init(wid) {
                    Ok(c) => c,
                    Err(e) => return fail(e.context(format!("initialising worker {wid}"))),
                };
                let wobs = WorkerObs::new(wid);
                loop {
                    // ordering: SeqCst pairs with fail()'s store — a set
                    // flag implies the first error is already recorded.
                    if abort.load(Ordering::SeqCst) {
                        return;
                    }
                    // ordering: SeqCst claim ticket; every index handed
                    // out exactly once across workers.
                    let i = cursor.fetch_add(1, Ordering::SeqCst);
                    if i >= keys.len() {
                        return;
                    }
                    match wobs.observe(|| work(&mut ctx, i, &keys[i])) {
                        Ok(t) => slots.lock().unwrap_or_else(|p| p.into_inner())[i] = Some(t),
                        Err(e) => return fail(e),
                    }
                }
            });
        }
    });

    if let Some(e) = first_err.into_inner().unwrap_or_else(|p| p.into_inner()) {
        return Err(e);
    }
    slots
        .into_inner()
        .unwrap_or_else(|p| p.into_inner())
        .into_iter()
        .enumerate()
        .map(|(i, s)| s.ok_or_else(|| anyhow!("cell {i} was never executed")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_workers_caps_and_autos() {
        assert_eq!(resolve_workers(4, 10), 4);
        assert_eq!(resolve_workers(16, 3), 3);
        assert_eq!(resolve_workers(0, 2), available_threads().min(2));
        assert_eq!(resolve_workers(0, 0), available_threads().min(1));
    }

    #[test]
    fn empty_grid_is_fine() {
        let keys: Vec<usize> = Vec::new();
        let out = execute_sharded(
            &keys,
            4,
            |_| Ok(()),
            |_: &mut (), _, _: &usize| -> Result<usize> { unreachable!() },
        )
        .unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn init_failure_surfaces() {
        let keys = vec![1usize, 2, 3];
        let err = execute_sharded(
            &keys,
            2,
            |wid| -> Result<()> { Err(anyhow!("no runtime for worker {wid}")) },
            |_: &mut (), _, k: &usize| -> Result<usize> { Ok(*k) },
        )
        .unwrap_err();
        assert!(err.to_string().contains("no runtime"), "{err}");
    }
}
