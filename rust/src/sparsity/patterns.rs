//! Mask builders for every structure family in the paper (Sec. 3.4, Apdx A):
//! diagonal-K, banded-b, block-B, N:M, butterfly (static), unstructured.
//!
//! These mirror `python/compile/sparsity.py` builder-for-builder; the
//! property tests in `rust/tests/prop_sparsity.rs` check the same
//! invariants hypothesis checks on the Python side.

use crate::util::Rng;

/// Structure families.  String forms match the manifest / Python side.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Structure {
    Diag,
    Banded,
    Block,
    NM,
    Butterfly,
    Unstructured,
    Dense,
}

impl Structure {
    pub fn parse(s: &str) -> Option<Structure> {
        Some(match s {
            "diag" => Structure::Diag,
            "banded" => Structure::Banded,
            "block" => Structure::Block,
            "nm" => Structure::NM,
            "butterfly" => Structure::Butterfly,
            "unstructured" => Structure::Unstructured,
            "dense" => Structure::Dense,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Structure::Diag => "diag",
            Structure::Banded => "banded",
            Structure::Block => "block",
            Structure::NM => "nm",
            Structure::Butterfly => "butterfly",
            Structure::Unstructured => "unstructured",
            Structure::Dense => "dense",
        }
    }

    /// Is the mask updated by DST? (butterfly/banded are static — SST.)
    pub fn is_dynamic(self) -> bool {
        matches!(
            self,
            Structure::Diag | Structure::Block | Structure::NM | Structure::Unstructured
        )
    }

    /// The paper's structural rank cap r_struct (Sec. 3.4) for a layer with
    /// `n_in` inputs at `density` — used by the NLR module.
    pub fn rank_cap(self, density: f64, n_in: usize) -> usize {
        let k = ((density * n_in as f64).round() as usize).max(1);
        match self {
            Structure::Diag | Structure::Banded | Structure::Block | Structure::Butterfly => k,
            // Tied N:M: r_struct = alpha * d0 with alpha = N/M = density.
            Structure::NM => ((density * n_in as f64).round() as usize).max(1),
            Structure::Unstructured | Structure::Dense => n_in,
        }
    }
}

/// Dense 0/1 mask, row-major `rows x cols`.
#[derive(Clone, Debug, PartialEq)]
pub struct Mask {
    pub rows: usize,
    pub cols: usize,
    pub bits: Vec<f32>,
}

impl Mask {
    pub fn zeros(rows: usize, cols: usize) -> Mask {
        Mask { rows, cols, bits: vec![0.0; rows * cols] }
    }

    pub fn ones(rows: usize, cols: usize) -> Mask {
        Mask { rows, cols, bits: vec![1.0; rows * cols] }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> bool {
        self.bits[i * self.cols + j] > 0.5
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: bool) {
        self.bits[i * self.cols + j] = if v { 1.0 } else { 0.0 };
    }

    pub fn nnz(&self) -> usize {
        self.bits.iter().filter(|&&b| b > 0.5).count()
    }

    pub fn row_nnz(&self, i: usize) -> usize {
        self.bits[i * self.cols..(i + 1) * self.cols]
            .iter()
            .filter(|&&b| b > 0.5)
            .count()
    }

    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }
}

/// Column the "main diagonal" passes through at each row (rectangular
/// generalisation): floor(i * cols / rows).
pub fn row_col_base(rows: usize, cols: usize) -> Vec<usize> {
    (0..rows).map(|i| i * cols / rows).collect()
}

/// Union of cyclic diagonals at the given offsets.
pub fn diag_mask_from_offsets(rows: usize, cols: usize, offsets: &[usize]) -> Mask {
    let base = row_col_base(rows, cols);
    let mut m = Mask::zeros(rows, cols);
    for i in 0..rows {
        for &o in offsets {
            m.set(i, (base[i] + o) % cols, true);
        }
    }
    m
}

/// K distinct initial offsets, evenly spread with a random rotation.
pub fn diag_offsets_init(cols: usize, k: usize, rng: &mut Rng) -> Vec<usize> {
    assert!(k <= cols, "K={k} exceeds cols={cols}");
    let start = rng.below(cols);
    (0..k).map(|i| (start + i * cols / k) % cols).collect()
}

pub fn make_diag_mask(rows: usize, cols: usize, k: usize, rng: &mut Rng) -> Mask {
    diag_mask_from_offsets(rows, cols, &diag_offsets_init(cols, k, rng))
}

pub fn make_banded_mask(rows: usize, cols: usize, band: usize) -> Mask {
    let half = (band / 2) as isize;
    let mut offs: Vec<usize> = (-half..=half)
        .map(|o| o.rem_euclid(cols as isize) as usize)
        .collect();
    offs.sort_unstable();
    offs.dedup();
    diag_mask_from_offsets(rows, cols, &offs)
}

pub fn make_block_mask(rows: usize, cols: usize, density: f64, bs: usize, rng: &mut Rng) -> Mask {
    let br = rows.div_ceil(bs);
    let bc = cols.div_ceil(bs);
    let per_row = ((density * bc as f64).round() as usize).clamp(1, bc);
    let mut m = Mask::zeros(rows, cols);
    for i in 0..br {
        for j in rng.choose(bc, per_row) {
            for r in i * bs..((i + 1) * bs).min(rows) {
                for c in j * bs..((j + 1) * bs).min(cols) {
                    m.set(r, c, true);
                }
            }
        }
    }
    m
}

pub fn make_nm_mask(rows: usize, cols: usize, n: usize, m_group: usize, rng: &mut Rng) -> Mask {
    assert_eq!(cols % m_group, 0, "cols={cols} not divisible by M={m_group}");
    let mut m = Mask::zeros(rows, cols);
    for i in 0..rows {
        for g in 0..cols / m_group {
            for c in rng.choose(m_group, n.min(m_group)) {
                m.set(i, g * m_group + c, true);
            }
        }
    }
    m
}

/// Pixelated-Butterfly style static support: power-of-two stride diagonals
/// up to the per-row budget.  Deterministic (no rng) — it is an SST pattern.
pub fn make_butterfly_mask(rows: usize, cols: usize, density: f64) -> Mask {
    let budget = ((density * cols as f64).round() as usize).clamp(1, cols);
    let mut offsets: Vec<usize> = vec![0];
    let mut stride = 1;
    while offsets.len() < budget && stride < cols {
        for off in [stride % cols, (cols - stride % cols) % cols] {
            if offsets.len() < budget && !offsets.contains(&off) {
                offsets.push(off);
            }
        }
        stride *= 2;
    }
    let mut extra = 1;
    while offsets.len() < budget {
        if !offsets.contains(&extra) {
            offsets.push(extra);
        }
        extra += 1;
    }
    offsets.sort_unstable();
    offsets.truncate(budget);
    diag_mask_from_offsets(rows, cols, &offsets)
}

pub fn make_unstructured_mask(rows: usize, cols: usize, density: f64, rng: &mut Rng) -> Mask {
    let total = rows * cols;
    let nnz = ((density * total as f64).round() as usize).clamp(1, total);
    let mut m = Mask::zeros(rows, cols);
    for p in rng.choose(total, nnz) {
        m.bits[p] = 1.0;
    }
    m
}

/// Dispatch matching `sparsity.make_mask` on the Python side.
pub fn make_mask(
    structure: Structure,
    rows: usize,
    cols: usize,
    density: f64,
    rng: &mut Rng,
) -> Mask {
    const BS: usize = 16;
    const M: usize = 16;
    match structure {
        Structure::Diag => {
            let k = ((density * cols as f64).round() as usize).clamp(1, cols);
            make_diag_mask(rows, cols, k, rng)
        }
        Structure::Banded => {
            let mut band = ((density * cols as f64).round() as usize).max(1);
            band += (band + 1) % 2;
            make_banded_mask(rows, cols, band.min(cols))
        }
        Structure::Block => make_block_mask(rows, cols, density, BS, rng),
        Structure::NM => {
            let n = ((density * M as f64).round() as usize).max(1);
            make_nm_mask(rows, cols, n, M, rng)
        }
        Structure::Butterfly => make_butterfly_mask(rows, cols, density),
        Structure::Unstructured => make_unstructured_mask(rows, cols, density, rng),
        Structure::Dense => Mask::ones(rows, cols),
    }
}

/// Check that `mask` belongs to the structure family — used by tests and by
/// the coordinator to validate DST-updated masks returned from the AOT
/// program (defence against compile-path regressions).
pub fn validate_structure(mask: &Mask, structure: Structure) -> Result<(), String> {
    match structure {
        Structure::Dense => Ok(()),
        Structure::Unstructured => Ok(()),
        Structure::Diag | Structure::Banded | Structure::Butterfly => {
            // Every row's nnz must sit at base(i)+o for a *row-independent*
            // offset set.
            let base = row_col_base(mask.rows, mask.cols);
            let offsets_of_row = |i: usize| -> Vec<usize> {
                (0..mask.cols)
                    .filter(|&j| mask.get(i, j))
                    .map(|j| (j + mask.cols - base[i] % mask.cols) % mask.cols)
                    .collect::<Vec<_>>()
            };
            let mut first = offsets_of_row(0);
            first.sort_unstable();
            for i in 1..mask.rows {
                let mut o = offsets_of_row(i);
                o.sort_unstable();
                if o != first {
                    return Err(format!("row {i} offsets differ from row 0"));
                }
            }
            Ok(())
        }
        Structure::Block => {
            const BS: usize = 16;
            for bi in 0..mask.rows.div_ceil(BS) {
                for bj in 0..mask.cols.div_ceil(BS) {
                    let mut any = false;
                    let mut all = true;
                    for i in bi * BS..((bi + 1) * BS).min(mask.rows) {
                        for j in bj * BS..((bj + 1) * BS).min(mask.cols) {
                            if mask.get(i, j) {
                                any = true;
                            } else {
                                all = false;
                            }
                        }
                    }
                    if any && !all {
                        return Err(format!("partial block at ({bi},{bj})"));
                    }
                }
            }
            Ok(())
        }
        Structure::NM => {
            const M: usize = 16;
            if mask.cols % M != 0 {
                return Err("cols not divisible by M".into());
            }
            let n0 = (0..M).filter(|&j| mask.get(0, j)).count();
            for i in 0..mask.rows {
                for g in 0..mask.cols / M {
                    let n = (g * M..(g + 1) * M).filter(|&j| mask.get(i, j)).count();
                    if n != n0 {
                        return Err(format!("group ({i},{g}) has {n} nnz, expected {n0}"));
                    }
                }
            }
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::new(42)
    }

    #[test]
    fn diag_exact_row_nnz() {
        let m = make_diag_mask(96, 64, 7, &mut rng());
        for i in 0..96 {
            assert_eq!(m.row_nnz(i), 7);
        }
        assert!(validate_structure(&m, Structure::Diag).is_ok());
    }

    #[test]
    fn banded_width() {
        let m = make_banded_mask(64, 64, 5);
        assert_eq!(m.row_nnz(0), 5);
        assert!(m.get(0, 0) && m.get(0, 1) && m.get(0, 2));
        assert!(m.get(0, 63) && m.get(0, 62)); // wrap-around
        assert!(validate_structure(&m, Structure::Banded).is_ok());
    }

    #[test]
    fn block_is_blocky() {
        let m = make_block_mask(64, 64, 0.25, 16, &mut rng());
        assert!(validate_structure(&m, Structure::Block).is_ok());
        assert_eq!(m.nnz(), 64 * 16); // 1 of 4 block-cols per block-row
    }

    #[test]
    fn nm_per_group() {
        let m = make_nm_mask(32, 64, 3, 16, &mut rng());
        assert!(validate_structure(&m, Structure::NM).is_ok());
        assert_eq!(m.nnz(), 32 * 4 * 3);
    }

    #[test]
    fn butterfly_deterministic() {
        let a = make_butterfly_mask(64, 64, 0.1);
        let b = make_butterfly_mask(64, 64, 0.1);
        assert_eq!(a, b);
        assert_eq!(a.row_nnz(0), 6); // round(0.1*64)=6
    }

    #[test]
    fn unstructured_budget() {
        let m = make_unstructured_mask(32, 64, 0.1, &mut rng());
        assert_eq!(m.nnz(), (0.1f64 * 32.0 * 64.0).round() as usize);
    }

    #[test]
    fn validate_rejects_partial_block() {
        let mut m = Mask::zeros(32, 32);
        m.set(0, 0, true); // lone element, not a full 16x16 block
        assert!(validate_structure(&m, Structure::Block).is_err());
    }

    #[test]
    fn dispatch_densities() {
        let mut r = rng();
        for st in [
            Structure::Diag,
            Structure::Block,
            Structure::NM,
            Structure::Butterfly,
            Structure::Unstructured,
        ] {
            let m = make_mask(st, 128, 128, 0.1, &mut r);
            let d = m.density();
            assert!(
                (d - 0.1).abs() < 0.06,
                "{}: density {d} too far from 0.1",
                st.name()
            );
            assert!(validate_structure(&m, st).is_ok(), "{}", st.name());
        }
    }
}
