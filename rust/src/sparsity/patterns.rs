//! Mask primitives: the dense 0/1 [`Mask`] plus the pure per-family
//! builders (diagonal-K, banded-b, block-B, N:M, butterfly, unstructured).
//!
//! These mirror `python/compile/sparsity.py` builder-for-builder.  Family
//! *dispatch* — which builder runs, with which parameters, and which
//! invariants the result must keep — lives one level up in
//! [`super::pattern`]: the builders here are deliberately parameter-explicit
//! and never inspect a family tag.

use crate::util::Rng;

/// Dense 0/1 mask, row-major `rows x cols`.
#[derive(Clone, Debug, PartialEq)]
pub struct Mask {
    pub rows: usize,
    pub cols: usize,
    pub bits: Vec<f32>,
}

impl Mask {
    pub fn zeros(rows: usize, cols: usize) -> Mask {
        Mask { rows, cols, bits: vec![0.0; rows * cols] }
    }

    pub fn ones(rows: usize, cols: usize) -> Mask {
        Mask { rows, cols, bits: vec![1.0; rows * cols] }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> bool {
        self.bits[i * self.cols + j] > 0.5
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: bool) {
        self.bits[i * self.cols + j] = if v { 1.0 } else { 0.0 };
    }

    pub fn nnz(&self) -> usize {
        self.bits.iter().filter(|&&b| b > 0.5).count()
    }

    pub fn row_nnz(&self, i: usize) -> usize {
        self.bits[i * self.cols..(i + 1) * self.cols]
            .iter()
            .filter(|&&b| b > 0.5)
            .count()
    }

    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }
}

/// Column the "main diagonal" passes through at each row (rectangular
/// generalisation): floor(i * cols / rows).
pub fn row_col_base(rows: usize, cols: usize) -> Vec<usize> {
    (0..rows).map(|i| i * cols / rows).collect()
}

/// Union of cyclic diagonals at the given offsets.
pub fn diag_mask_from_offsets(rows: usize, cols: usize, offsets: &[usize]) -> Mask {
    let base = row_col_base(rows, cols);
    let mut m = Mask::zeros(rows, cols);
    for i in 0..rows {
        for &o in offsets {
            m.set(i, (base[i] + o) % cols, true);
        }
    }
    m
}

/// K distinct initial offsets, evenly spread with a random rotation.
pub fn diag_offsets_init(cols: usize, k: usize, rng: &mut Rng) -> Vec<usize> {
    assert!(k <= cols, "K={k} exceeds cols={cols}");
    let start = rng.below(cols);
    (0..k).map(|i| (start + i * cols / k) % cols).collect()
}

pub fn make_diag_mask(rows: usize, cols: usize, k: usize, rng: &mut Rng) -> Mask {
    diag_mask_from_offsets(rows, cols, &diag_offsets_init(cols, k, rng))
}

pub fn make_banded_mask(rows: usize, cols: usize, band: usize) -> Mask {
    let half = (band / 2) as isize;
    let mut offs: Vec<usize> = (-half..=half)
        .map(|o| o.rem_euclid(cols as isize) as usize)
        .collect();
    offs.sort_unstable();
    offs.dedup();
    diag_mask_from_offsets(rows, cols, &offs)
}

pub fn make_block_mask(rows: usize, cols: usize, density: f64, bs: usize, rng: &mut Rng) -> Mask {
    let br = rows.div_ceil(bs);
    let bc = cols.div_ceil(bs);
    let per_row = ((density * bc as f64).round() as usize).clamp(1, bc);
    let mut m = Mask::zeros(rows, cols);
    for i in 0..br {
        for j in rng.choose(bc, per_row) {
            for r in i * bs..((i + 1) * bs).min(rows) {
                for c in j * bs..((j + 1) * bs).min(cols) {
                    m.set(r, c, true);
                }
            }
        }
    }
    m
}

pub fn make_nm_mask(rows: usize, cols: usize, n: usize, m_group: usize, rng: &mut Rng) -> Mask {
    assert_eq!(cols % m_group, 0, "cols={cols} not divisible by M={m_group}");
    let mut m = Mask::zeros(rows, cols);
    for i in 0..rows {
        for g in 0..cols / m_group {
            for c in rng.choose(m_group, n.min(m_group)) {
                m.set(i, g * m_group + c, true);
            }
        }
    }
    m
}

/// Pixelated-Butterfly style static support: power-of-two stride diagonals
/// up to the per-row budget.  Deterministic (no rng) — it is an SST pattern.
pub fn make_butterfly_mask(rows: usize, cols: usize, density: f64) -> Mask {
    let budget = ((density * cols as f64).round() as usize).clamp(1, cols);
    let mut offsets: Vec<usize> = vec![0];
    let mut stride = 1;
    while offsets.len() < budget && stride < cols {
        for off in [stride % cols, (cols - stride % cols) % cols] {
            if offsets.len() < budget && !offsets.contains(&off) {
                offsets.push(off);
            }
        }
        stride *= 2;
    }
    let mut extra = 1;
    while offsets.len() < budget {
        if !offsets.contains(&extra) {
            offsets.push(extra);
        }
        extra += 1;
    }
    offsets.sort_unstable();
    offsets.truncate(budget);
    diag_mask_from_offsets(rows, cols, &offsets)
}

pub fn make_unstructured_mask(rows: usize, cols: usize, density: f64, rng: &mut Rng) -> Mask {
    let total = rows * cols;
    let nnz = ((density * total as f64).round() as usize).clamp(1, total);
    let mut m = Mask::zeros(rows, cols);
    for p in rng.choose(total, nnz) {
        m.bits[p] = 1.0;
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::new(42)
    }

    #[test]
    fn diag_exact_row_nnz() {
        let m = make_diag_mask(96, 64, 7, &mut rng());
        for i in 0..96 {
            assert_eq!(m.row_nnz(i), 7);
        }
    }

    #[test]
    fn banded_width() {
        let m = make_banded_mask(64, 64, 5);
        assert_eq!(m.row_nnz(0), 5);
        assert!(m.get(0, 0) && m.get(0, 1) && m.get(0, 2));
        assert!(m.get(0, 63) && m.get(0, 62)); // wrap-around
    }

    #[test]
    fn block_is_blocky() {
        let m = make_block_mask(64, 64, 0.25, 16, &mut rng());
        assert_eq!(m.nnz(), 64 * 16); // 1 of 4 block-cols per block-row
        // Every 16x16 block is all-or-nothing.
        for bi in 0..4 {
            for bj in 0..4 {
                let cnt = (0..16)
                    .flat_map(|r| (0..16).map(move |c| (bi * 16 + r, bj * 16 + c)))
                    .filter(|&(r, c)| m.get(r, c))
                    .count();
                assert!(cnt == 0 || cnt == 256, "partial block at ({bi},{bj})");
            }
        }
    }

    #[test]
    fn nm_per_group() {
        let m = make_nm_mask(32, 64, 3, 16, &mut rng());
        assert_eq!(m.nnz(), 32 * 4 * 3);
        for i in 0..32 {
            for g in 0..4 {
                let n = (g * 16..(g + 1) * 16).filter(|&j| m.get(i, j)).count();
                assert_eq!(n, 3, "group ({i},{g})");
            }
        }
    }

    #[test]
    fn butterfly_deterministic() {
        let a = make_butterfly_mask(64, 64, 0.1);
        let b = make_butterfly_mask(64, 64, 0.1);
        assert_eq!(a, b);
        assert_eq!(a.row_nnz(0), 6); // round(0.1*64)=6
    }

    #[test]
    fn unstructured_budget() {
        let m = make_unstructured_mask(32, 64, 0.1, &mut rng());
        assert_eq!(m.nnz(), (0.1f64 * 32.0 * 64.0).round() as usize);
    }
}
