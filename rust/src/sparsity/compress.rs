//! Dense (W, mask) -> kernel-compressed forms, with the learned permutation
//! *folded into the index maps* (the paper's re-indexing trick, Eqn. 16/18).
//!
//! Three forms, matching the L1 kernels and the native CPU kernels:
//! * [`RowCompressed`] — per-row (vals, idx) panels, fixed nnz budget k;
//!   covers diagonal-K, N:M, butterfly, and padded unstructured rows.
//! * [`BlockCompressed`] — per-block-row active bs x bs blocks (DSB /
//!   Pixelated-Butterfly layouts).
//! * [`Csr`] — ragged compressed sparse rows, the unstructured comparator
//!   (the drivers live in `kernels::csr`; the *layout* lives here so the
//!   pattern layer can emit every kernel plan without importing upward).

use super::patterns::Mask;

/// Per-row gather form: `y[i] = sum_k vals[i*k_], x[idx[i*k_]]`.
#[derive(Clone, Debug)]
pub struct RowCompressed {
    pub rows: usize,
    pub cols: usize,
    /// Per-row nnz budget (panel width).
    pub k: usize,
    /// (rows * k) values, zero-padded.
    pub vals: Vec<f32>,
    /// (rows * k) input coordinates (post-permutation composition).
    pub idx: Vec<i32>,
}

/// Compress a dense masked weight into the row-gather form.
///
/// `perm`, if given, is the layer's input permutation index map
/// (`(P x)_i = x[perm[i]]`): the stored index becomes `perm[j]` so the
/// kernel reads pre-permutation coordinates directly — no shuffle pass.
/// Rows with more than `k` nnz keep their largest-|w| entries (only
/// possible for unstructured masks; structured rows fit exactly).
pub fn compress_rows(
    w: &[f32],
    mask: &Mask,
    k: usize,
    perm: Option<&[i32]>,
) -> RowCompressed {
    let (rows, cols) = (mask.rows, mask.cols);
    assert_eq!(w.len(), rows * cols);
    if let Some(p) = perm {
        assert_eq!(p.len(), cols, "perm length must equal cols");
    }
    let mut vals = vec![0.0f32; rows * k];
    let mut idx = vec![0i32; rows * k];
    for i in 0..rows {
        let mut entries: Vec<(usize, f32)> = (0..cols)
            .filter(|&j| mask.get(i, j))
            .map(|j| (j, w[i * cols + j]))
            .collect();
        if entries.len() > k {
            // Unstructured overflow: keep the largest-|w| k entries.
            entries.sort_by(|a, b| b.1.abs().partial_cmp(&a.1.abs()).unwrap());
            entries.truncate(k);
        }
        for (slot, (j, v)) in entries.into_iter().enumerate() {
            vals[i * k + slot] = v;
            idx[i * k + slot] = match perm {
                Some(p) => p[j],
                None => j as i32,
            };
        }
    }
    RowCompressed { rows, cols, k, vals, idx }
}

/// Block-sparse form: per block-row, `nab` active blocks of size bs x bs.
#[derive(Clone, Debug)]
pub struct BlockCompressed {
    pub rows: usize,
    pub cols: usize,
    pub bs: usize,
    /// Active blocks per block-row (padded; block_cols = -1 marks padding).
    pub nab: usize,
    /// (br * nab * bs * bs) block values.
    pub blocks: Vec<f32>,
    /// (br * nab) column-block index of each active block, -1 = pad.
    pub block_cols: Vec<i32>,
}

pub fn compress_blocks(w: &[f32], mask: &Mask, bs: usize) -> BlockCompressed {
    let (rows, cols) = (mask.rows, mask.cols);
    assert_eq!(w.len(), rows * cols);
    assert_eq!(rows % bs, 0, "rows must divide bs");
    assert_eq!(cols % bs, 0, "cols must divide bs");
    let (br, bc) = (rows / bs, cols / bs);
    let active: Vec<Vec<usize>> = (0..br)
        .map(|i| (0..bc).filter(|&j| mask.get(i * bs, j * bs)).collect())
        .collect();
    let nab = active.iter().map(Vec::len).max().unwrap_or(0).max(1);
    let mut blocks = vec![0.0f32; br * nab * bs * bs];
    let mut block_cols = vec![-1i32; br * nab];
    for (i, act) in active.iter().enumerate() {
        for (a, &j) in act.iter().enumerate() {
            block_cols[i * nab + a] = j as i32;
            for r in 0..bs {
                for c in 0..bs {
                    blocks[((i * nab + a) * bs + r) * bs + c] =
                        w[(i * bs + r) * cols + j * bs + c];
                }
            }
        }
    }
    BlockCompressed { rows, cols, bs, nab, blocks, block_cols }
}

/// Ragged CSR — the unstructured-mask layout (what cuSparse executes for
/// RigL/SET-style free masks in the paper's timing section).
#[derive(Clone, Debug)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<i32>,
    pub vals: Vec<f32>,
}

impl Csr {
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }
}

pub fn csr_from_mask(w: &[f32], mask: &Mask) -> Csr {
    let (rows, cols) = (mask.rows, mask.cols);
    assert_eq!(w.len(), rows * cols);
    let mut row_ptr = Vec::with_capacity(rows + 1);
    let mut col_idx = Vec::new();
    let mut vals = Vec::new();
    row_ptr.push(0);
    for i in 0..rows {
        for j in 0..cols {
            if mask.get(i, j) {
                col_idx.push(j as i32);
                vals.push(w[i * cols + j]);
            }
        }
        row_ptr.push(col_idx.len());
    }
    Csr { rows, cols, row_ptr, col_idx, vals }
}

/// Reconstruct the dense masked weight from a row-compressed form — test
/// oracle for the compression round-trip.
pub fn decompress_rows(rc: &RowCompressed, perm_inv: Option<&[i32]>) -> Vec<f32> {
    let mut w = vec![0.0f32; rc.rows * rc.cols];
    for i in 0..rc.rows {
        for s in 0..rc.k {
            let v = rc.vals[i * rc.k + s];
            if v != 0.0 {
                let stored = rc.idx[i * rc.k + s] as usize;
                let j = match perm_inv {
                    Some(pi) => pi[stored] as usize,
                    None => stored,
                };
                w[i * rc.cols + j] += v;
            }
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::patterns::{make_diag_mask, make_unstructured_mask};
    use crate::util::Rng;

    #[test]
    fn row_roundtrip_diag() {
        let mut rng = Rng::new(1);
        let mask = make_diag_mask(32, 64, 5, &mut rng);
        let w: Vec<f32> = (0..32 * 64).map(|_| rng.normal()).collect();
        let rc = compress_rows(&w, &mask, 5, None);
        let back = decompress_rows(&rc, None);
        for i in 0..32 {
            for j in 0..64 {
                let want = if mask.get(i, j) { w[i * 64 + j] } else { 0.0 };
                assert!((back[i * 64 + j] - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn perm_composition() {
        // With a permutation folded in, decompressing through the inverse
        // map must recover the same dense weight.
        let mut rng = Rng::new(2);
        let mask = make_diag_mask(16, 16, 3, &mut rng);
        let w: Vec<f32> = (0..256).map(|_| rng.normal()).collect();
        let perm: Vec<i32> = rng.permutation(16).iter().map(|&x| x as i32).collect();
        let mut inv = vec![0i32; 16];
        for (i, &p) in perm.iter().enumerate() {
            inv[p as usize] = i as i32;
        }
        let rc = compress_rows(&w, &mask, 3, Some(&perm));
        let back = decompress_rows(&rc, Some(&inv));
        for i in 0..16 {
            for j in 0..16 {
                let want = if mask.get(i, j) { w[i * 16 + j] } else { 0.0 };
                assert!((back[i * 16 + j] - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn unstructured_overflow_keeps_largest() {
        let mut rng = Rng::new(3);
        let mask = make_unstructured_mask(8, 32, 0.5, &mut rng);
        let w: Vec<f32> = (0..8 * 32).map(|i| i as f32 / 100.0).collect();
        let k = 4; // far below the ~16 nnz/row average
        let rc = compress_rows(&w, &mask, k, None);
        for i in 0..8 {
            // Count non-zero slots <= k.
            let n = (0..k).filter(|&s| rc.vals[i * k + s] != 0.0).count();
            assert!(n <= k);
        }
    }

    #[test]
    fn block_roundtrip() {
        let mut rng = Rng::new(4);
        let mask = crate::sparsity::patterns::make_block_mask(32, 32, 0.5, 16, &mut rng);
        let w: Vec<f32> = (0..32 * 32).map(|_| rng.normal()).collect();
        let bcfm = compress_blocks(&w, &mask, 16);
        assert_eq!(bcfm.nab, 1);
        // Each stored block matches the dense slice.
        for i in 0..2 {
            let j = bcfm.block_cols[i * bcfm.nab] as usize;
            for r in 0..16 {
                for c in 0..16 {
                    assert_eq!(
                        bcfm.blocks[((i * bcfm.nab) * 16 + r) * 16 + c],
                        w[(i * 16 + r) * 32 + j * 16 + c]
                    );
                }
            }
        }
    }
}
