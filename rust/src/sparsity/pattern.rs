//! The pattern layer: one first-class object per structure family.
//!
//! The paper's whole argument is that the *structure family* is the unit of
//! design (Sec. 3.4): each family carries its own mask init, DST prune/grow
//! rule, compressed kernel layout, structural rank cap, and memory
//! footprint.  [`SparsePattern`] makes that a trait — one impl per family,
//! each with a typed params struct instead of the old `density_to_params`
//! guesses — and [`PatternRegistry`] resolves parameterised spec strings
//! (`"block:8"`, `"nm:2:8"`, `"diag:4"`, `"banded:16"`) into trait objects.
//! Bare family names (`"block"`, `"nm"`, ...) keep the historical defaults,
//! so every CLI flag, manifest string, and sweep journal written before
//! this layer existed still parses — and produces bit-identical masks on
//! every geometry the family accepts.  Infeasible geometry (a block size
//! or M-group not dividing the layer dims, K or band wider than the
//! layer) is now a descriptive `Err` where the old builders panicked or
//! silently built ragged masks the compressed kernels could not execute.
//!
//! All family dispatch lives here.  The coordinator, sweep grid, CLI,
//! benches, and examples hold a [`PatternHandle`] and call trait methods;
//! none of them match on a family enum.  Adding a family means adding one
//! impl and one registry entry — every dispatch site picks it up for free.

use std::fmt;
use std::sync::{Arc, OnceLock};

use anyhow::{anyhow, bail, Result};

use super::compress::{
    compress_blocks, compress_rows, csr_from_mask, BlockCompressed, Csr, RowCompressed,
};
use super::dst::{block_prune_grow, diag_prune_grow, nm_prune_grow, unstructured_prune_grow};
use super::patterns::{
    make_banded_mask, make_block_mask, make_butterfly_mask, make_diag_mask, make_nm_mask,
    make_unstructured_mask, row_col_base, Mask,
};
use crate::util::Rng;

/// Family tag — one variant per [`SparsePattern`] impl.  String forms match
/// the manifest / Python side and name the family's `dst_update` artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Structure {
    Diag,
    Banded,
    Block,
    NM,
    Butterfly,
    Unstructured,
    Dense,
}

impl Structure {
    pub fn parse(s: &str) -> Option<Structure> {
        Some(match s {
            "diag" => Structure::Diag,
            "banded" => Structure::Banded,
            "block" => Structure::Block,
            "nm" => Structure::NM,
            "butterfly" => Structure::Butterfly,
            "unstructured" => Structure::Unstructured,
            "dense" => Structure::Dense,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Structure::Diag => "diag",
            Structure::Banded => "banded",
            Structure::Block => "block",
            Structure::NM => "nm",
            Structure::Butterfly => "butterfly",
            Structure::Unstructured => "unstructured",
            Structure::Dense => "dense",
        }
    }
}

/// What a pattern compresses into: the kernel-form the `Backend`-dispatched
/// native drivers execute (`gather_matmul*`, `block_matmul*`, `csr_matmul*`,
/// `dense_matmul_blocked*`).  Callers match on the *plan*, never on the
/// family.
#[derive(Clone, Debug)]
pub enum KernelPlan {
    /// Per-row (vals, idx) panels — the row-gather drivers.
    Rows(RowCompressed),
    /// Dense bs x bs panels — the block drivers.
    Blocks(BlockCompressed),
    /// Ragged CSR — the unstructured comparator drivers.
    Csr(Csr),
    /// No compression: the dense drivers run the weights as-is.
    Dense { rows: usize, cols: usize, w: Vec<f32> },
}

impl KernelPlan {
    /// Short driver name for telemetry/debug output.
    pub fn driver(&self) -> &'static str {
        match self {
            KernelPlan::Rows(_) => "gather",
            KernelPlan::Blocks(_) => "block",
            KernelPlan::Csr(_) => "csr",
            KernelPlan::Dense { .. } => "dense",
        }
    }
}

/// Everything a structure family knows, as one object (paper Sec. 3.4).
///
/// Contract shared by all impls:
/// * `init_mask` consumes the RNG exactly as the historical `make_mask`
///   dispatch did for bare-name specs, so seed masks are bit-identical.
/// * `prune_grow` preserves the nnz budget exactly and stays in-family
///   (`validate(prune_grow(..)) == Ok`); `None` marks a static SST family.
/// * `compress` expects a mask this pattern produced (same family,
///   divisibility already enforced by `init_mask`).
pub trait SparsePattern: fmt::Debug + Send + Sync {
    /// Family tag (one per impl).
    fn family(&self) -> Structure;

    /// Canonical spec string; [`PatternRegistry::resolve`] parses it back
    /// to an equal pattern.  Patterns at family defaults print the bare
    /// name, so journals/fingerprints written pre-registry still match.
    fn spec(&self) -> String;

    /// Is the mask updated by DST? (butterfly/banded are static — SST.)
    fn is_dynamic(&self) -> bool;

    /// Build the init mask for a `rows x cols` site at `density`.
    /// Descriptive `Err` on infeasible geometry (K > cols, band wider than
    /// the layer, block size or M-group not dividing the dims) instead of
    /// the old panics/silent rounding.
    fn init_mask(&self, rows: usize, cols: usize, density: f64, rng: &mut Rng) -> Result<Mask>;

    /// One host-side DST prune/grow step (the mirror of the family's
    /// `dst_update` artifact rule): score active units by |w|, candidates
    /// by the grow signal, move up to `frac` of the budget.  Families whose
    /// rule re-selects the full template each step (N:M) ignore `frac` —
    /// their churn is governed by the family's own score weighting.
    /// `None` = static family, mask never changes.
    fn prune_grow(&self, w: &[f32], mask: &Mask, grow: &[f32], frac: f64) -> Option<Mask>;

    /// Family-membership check — the defence the coordinator runs against
    /// every compiled DST update.
    fn validate(&self, mask: &Mask) -> std::result::Result<(), String>;

    /// Compress dense masked weights into this family's kernel plan.
    /// `perm`, if given, is folded into the index stream (Eqn. 16/18);
    /// families without an index stream (block panels) fall back to the
    /// row-gather form so the fold is still free.
    fn compress(&self, w: &[f32], mask: &Mask, perm: Option<&[i32]>) -> KernelPlan;

    /// The paper's structural rank cap r_struct (Sec. 3.4) for a layer
    /// with `n_in` inputs — typed params win over the density guess.
    fn rank_cap(&self, density: f64, n_in: usize) -> usize;

    /// Bytes of mask/pattern state one training run holds for a
    /// `rows x cols` site (the trainer stores the dense f32 mask tensor).
    fn memory_footprint(&self, rows: usize, cols: usize) -> usize {
        rows * cols * 4
    }
}

/// Shared, cheaply clonable pattern handle — what `RunConfig` and the
/// sweep grid carry.
pub type PatternHandle = Arc<dyn SparsePattern>;

/// Resolve a spec string against the global registry.
pub fn resolve_pattern(spec: &str) -> Result<PatternHandle> {
    registry().resolve(spec)
}

// ---------------------------------------------------------------------------
// Shared derivations + validation helpers
// ---------------------------------------------------------------------------

fn check_geometry(rows: usize, cols: usize, density: f64, spec: &str) -> Result<()> {
    if rows == 0 || cols == 0 {
        bail!("{spec}: degenerate layer {rows}x{cols}");
    }
    if !(density > 0.0 && density <= 1.0) {
        bail!("{spec}: density {density} out of (0, 1]");
    }
    Ok(())
}

/// Historical diagonal-count derivation: K = round(density * cols),
/// clamped into [1, cols].
fn derived_k(density: f64, cols: usize) -> usize {
    ((density * cols as f64).round() as usize).clamp(1, cols)
}

/// Historical band-width derivation: nearest odd >= round(density * cols),
/// capped at cols.
fn derived_band(density: f64, cols: usize) -> usize {
    let mut band = ((density * cols as f64).round() as usize).max(1);
    band += (band + 1) % 2;
    band.min(cols)
}

/// Offset-family membership: every row's nnz sits at base(i)+o for a
/// row-independent offset set (diag / banded / butterfly).
fn validate_offset_family(mask: &Mask) -> std::result::Result<(), String> {
    let base = row_col_base(mask.rows, mask.cols);
    let offsets_of_row = |i: usize| -> Vec<usize> {
        (0..mask.cols)
            .filter(|&j| mask.get(i, j))
            .map(|j| (j + mask.cols - base[i] % mask.cols) % mask.cols)
            .collect::<Vec<_>>()
    };
    let mut first = offsets_of_row(0);
    first.sort_unstable();
    for i in 1..mask.rows {
        let mut o = offsets_of_row(i);
        o.sort_unstable();
        if o != first {
            return Err(format!("row {i} offsets differ from row 0"));
        }
    }
    Ok(())
}

/// Widest row nnz — the panel width k of the row-gather form.
fn panel_k(mask: &Mask) -> usize {
    (0..mask.rows).map(|i| mask.row_nnz(i)).max().unwrap_or(1).max(1)
}

fn compress_to_rows(w: &[f32], mask: &Mask, perm: Option<&[i32]>) -> KernelPlan {
    KernelPlan::Rows(compress_rows(w, mask, panel_k(mask), perm))
}

// ---------------------------------------------------------------------------
// Family impls
// ---------------------------------------------------------------------------

/// DynaDiag-style union of K cyclic diagonals.  `k: None` derives K from
/// the density (the historical default).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DiagPattern {
    pub k: Option<usize>,
}

impl SparsePattern for DiagPattern {
    fn family(&self) -> Structure {
        Structure::Diag
    }

    fn spec(&self) -> String {
        match self.k {
            Some(k) => format!("diag:{k}"),
            None => "diag".into(),
        }
    }

    fn is_dynamic(&self) -> bool {
        true
    }

    fn init_mask(&self, rows: usize, cols: usize, density: f64, rng: &mut Rng) -> Result<Mask> {
        check_geometry(rows, cols, density, &self.spec())?;
        let k = self.k.unwrap_or_else(|| derived_k(density, cols));
        if k > cols {
            bail!("{}: K={k} exceeds layer cols={cols}", self.spec());
        }
        Ok(make_diag_mask(rows, cols, k, rng))
    }

    fn prune_grow(&self, w: &[f32], mask: &Mask, grow: &[f32], frac: f64) -> Option<Mask> {
        Some(diag_prune_grow(w, mask, grow, frac))
    }

    fn validate(&self, mask: &Mask) -> std::result::Result<(), String> {
        validate_offset_family(mask)
    }

    fn compress(&self, w: &[f32], mask: &Mask, perm: Option<&[i32]>) -> KernelPlan {
        compress_to_rows(w, mask, perm)
    }

    fn rank_cap(&self, density: f64, n_in: usize) -> usize {
        self.k.unwrap_or_else(|| ((density * n_in as f64).round() as usize).max(1))
    }
}

/// Static banded pattern of width 2b+1 cyclic diagonals.  `half: None`
/// derives the (odd) width from the density.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BandedPattern {
    /// Half-bandwidth b; total width 2b+1.
    pub half: Option<usize>,
}

impl BandedPattern {
    fn width(&self, density: f64, cols: usize) -> Result<usize> {
        match self.half {
            Some(b) => {
                let w = 2 * b + 1;
                if w > cols {
                    bail!("{}: band width {w} exceeds layer cols={cols}", self.spec());
                }
                Ok(w)
            }
            None => Ok(derived_band(density, cols)),
        }
    }
}

impl SparsePattern for BandedPattern {
    fn family(&self) -> Structure {
        Structure::Banded
    }

    fn spec(&self) -> String {
        match self.half {
            Some(b) => format!("banded:{b}"),
            None => "banded".into(),
        }
    }

    fn is_dynamic(&self) -> bool {
        false
    }

    fn init_mask(&self, rows: usize, cols: usize, density: f64, _rng: &mut Rng) -> Result<Mask> {
        check_geometry(rows, cols, density, &self.spec())?;
        Ok(make_banded_mask(rows, cols, self.width(density, cols)?))
    }

    fn prune_grow(&self, _w: &[f32], _mask: &Mask, _grow: &[f32], _frac: f64) -> Option<Mask> {
        None
    }

    fn validate(&self, mask: &Mask) -> std::result::Result<(), String> {
        validate_offset_family(mask)
    }

    fn compress(&self, w: &[f32], mask: &Mask, perm: Option<&[i32]>) -> KernelPlan {
        compress_to_rows(w, mask, perm)
    }

    fn rank_cap(&self, density: f64, n_in: usize) -> usize {
        match self.half {
            Some(b) => (2 * b + 1).min(n_in),
            None => ((density * n_in as f64).round() as usize).max(1),
        }
    }
}

/// DSB-style block sparsity with bs x bs panels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockPattern {
    pub bs: usize,
}

pub const DEFAULT_BLOCK_SIZE: usize = 16;

impl SparsePattern for BlockPattern {
    fn family(&self) -> Structure {
        Structure::Block
    }

    fn spec(&self) -> String {
        if self.bs == DEFAULT_BLOCK_SIZE {
            "block".into()
        } else {
            format!("block:{}", self.bs)
        }
    }

    fn is_dynamic(&self) -> bool {
        true
    }

    fn init_mask(&self, rows: usize, cols: usize, density: f64, rng: &mut Rng) -> Result<Mask> {
        check_geometry(rows, cols, density, &self.spec())?;
        if rows % self.bs != 0 || cols % self.bs != 0 {
            bail!(
                "{}: block size {} does not divide layer dims {rows}x{cols}",
                self.spec(),
                self.bs
            );
        }
        Ok(make_block_mask(rows, cols, density, self.bs, rng))
    }

    fn prune_grow(&self, w: &[f32], mask: &Mask, grow: &[f32], frac: f64) -> Option<Mask> {
        Some(block_prune_grow(w, mask, grow, self.bs, frac))
    }

    fn validate(&self, mask: &Mask) -> std::result::Result<(), String> {
        let bs = self.bs;
        for bi in 0..mask.rows.div_ceil(bs) {
            for bj in 0..mask.cols.div_ceil(bs) {
                let mut any = false;
                let mut all = true;
                for i in bi * bs..((bi + 1) * bs).min(mask.rows) {
                    for j in bj * bs..((bj + 1) * bs).min(mask.cols) {
                        if mask.get(i, j) {
                            any = true;
                        } else {
                            all = false;
                        }
                    }
                }
                if any && !all {
                    return Err(format!("partial {bs}x{bs} block at ({bi},{bj})"));
                }
            }
        }
        Ok(())
    }

    fn compress(&self, w: &[f32], mask: &Mask, perm: Option<&[i32]>) -> KernelPlan {
        match perm {
            // A permutation cannot fold into dense panels; fall back to the
            // row-gather form so re-indexing stays free (Fig. 3 methodology).
            Some(_) => compress_to_rows(w, mask, perm),
            None => KernelPlan::Blocks(compress_blocks(w, mask, self.bs)),
        }
    }

    fn rank_cap(&self, density: f64, n_in: usize) -> usize {
        ((density * n_in as f64).round() as usize).max(1)
    }
}

/// N:M sparsity — N survivors per group of M columns.  `n: None` derives
/// N from the density (tied template, alpha = N/M ~ density).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NMPattern {
    pub n: Option<usize>,
    pub m: usize,
    /// Grow-score weight in the SRigL-style update (|w| vs gamma * |grad|).
    pub gamma: f32,
}

pub const DEFAULT_NM_GROUP: usize = 16;

impl NMPattern {
    fn n_of(&self, density: f64) -> usize {
        self.n
            .unwrap_or_else(|| ((density * self.m as f64).round() as usize).max(1))
            .min(self.m)
    }
}

impl SparsePattern for NMPattern {
    fn family(&self) -> Structure {
        Structure::NM
    }

    fn spec(&self) -> String {
        match self.n {
            Some(n) => format!("nm:{n}:{}", self.m),
            None if self.m == DEFAULT_NM_GROUP => "nm".into(),
            // Density-derived N over a custom M-group: the empty-N spec
            // form, which `parse_nm` round-trips.
            None => format!("nm::{}", self.m),
        }
    }

    fn is_dynamic(&self) -> bool {
        true
    }

    fn init_mask(&self, rows: usize, cols: usize, density: f64, rng: &mut Rng) -> Result<Mask> {
        check_geometry(rows, cols, density, &self.spec())?;
        if cols % self.m != 0 {
            bail!(
                "{}: M-group {} does not divide layer cols={cols}",
                self.spec(),
                self.m
            );
        }
        Ok(make_nm_mask(rows, cols, self.n_of(density), self.m, rng))
    }

    fn prune_grow(&self, w: &[f32], mask: &Mask, grow: &[f32], _frac: f64) -> Option<Mask> {
        Some(nm_prune_grow(w, mask, grow, self.m, self.gamma))
    }

    fn validate(&self, mask: &Mask) -> std::result::Result<(), String> {
        let m = self.m;
        if mask.cols % m != 0 {
            return Err(format!("cols not divisible by M={m}"));
        }
        let n0 = (0..m).filter(|&j| mask.get(0, j)).count();
        for i in 0..mask.rows {
            for g in 0..mask.cols / m {
                let n = (g * m..(g + 1) * m).filter(|&j| mask.get(i, j)).count();
                if n != n0 {
                    return Err(format!("group ({i},{g}) has {n} nnz, expected {n0}"));
                }
            }
        }
        Ok(())
    }

    fn compress(&self, w: &[f32], mask: &Mask, perm: Option<&[i32]>) -> KernelPlan {
        compress_to_rows(w, mask, perm)
    }

    fn rank_cap(&self, density: f64, n_in: usize) -> usize {
        // Tied N:M: r_struct = alpha * d0 with alpha = N/M.
        let alpha = match self.n {
            Some(n) => n as f64 / self.m as f64,
            None => density,
        };
        ((alpha * n_in as f64).round() as usize).max(1)
    }
}

/// Pixelated-Butterfly style static support: power-of-two stride diagonals
/// up to the per-row budget.  Deterministic — an SST pattern.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ButterflyPattern;

impl SparsePattern for ButterflyPattern {
    fn family(&self) -> Structure {
        Structure::Butterfly
    }

    fn spec(&self) -> String {
        "butterfly".into()
    }

    fn is_dynamic(&self) -> bool {
        false
    }

    fn init_mask(&self, rows: usize, cols: usize, density: f64, _rng: &mut Rng) -> Result<Mask> {
        check_geometry(rows, cols, density, "butterfly")?;
        Ok(make_butterfly_mask(rows, cols, density))
    }

    fn prune_grow(&self, _w: &[f32], _mask: &Mask, _grow: &[f32], _frac: f64) -> Option<Mask> {
        None
    }

    fn validate(&self, mask: &Mask) -> std::result::Result<(), String> {
        validate_offset_family(mask)
    }

    fn compress(&self, w: &[f32], mask: &Mask, perm: Option<&[i32]>) -> KernelPlan {
        compress_to_rows(w, mask, perm)
    }

    fn rank_cap(&self, density: f64, n_in: usize) -> usize {
        ((density * n_in as f64).round() as usize).max(1)
    }
}

/// Free masks — the RigL/SET/MEST comparator family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct UnstructuredPattern;

impl SparsePattern for UnstructuredPattern {
    fn family(&self) -> Structure {
        Structure::Unstructured
    }

    fn spec(&self) -> String {
        "unstructured".into()
    }

    fn is_dynamic(&self) -> bool {
        true
    }

    fn init_mask(&self, rows: usize, cols: usize, density: f64, rng: &mut Rng) -> Result<Mask> {
        check_geometry(rows, cols, density, "unstructured")?;
        Ok(make_unstructured_mask(rows, cols, density, rng))
    }

    fn prune_grow(&self, w: &[f32], mask: &Mask, grow: &[f32], frac: f64) -> Option<Mask> {
        let scores: Vec<f32> = grow.iter().map(|x| x.abs()).collect();
        Some(unstructured_prune_grow(w, mask, &scores, frac))
    }

    fn validate(&self, _mask: &Mask) -> std::result::Result<(), String> {
        Ok(())
    }

    fn compress(&self, w: &[f32], mask: &Mask, perm: Option<&[i32]>) -> KernelPlan {
        let mut csr = csr_from_mask(w, mask);
        if let Some(p) = perm {
            for ci in csr.col_idx.iter_mut() {
                *ci = p[*ci as usize];
            }
        }
        KernelPlan::Csr(csr)
    }

    fn rank_cap(&self, _density: f64, n_in: usize) -> usize {
        n_in
    }
}

/// The dense reference — mask of ones, no compression.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DensePattern;

impl SparsePattern for DensePattern {
    fn family(&self) -> Structure {
        Structure::Dense
    }

    fn spec(&self) -> String {
        "dense".into()
    }

    fn is_dynamic(&self) -> bool {
        false
    }

    fn init_mask(&self, rows: usize, cols: usize, density: f64, _rng: &mut Rng) -> Result<Mask> {
        check_geometry(rows, cols, density, "dense")?;
        Ok(Mask::ones(rows, cols))
    }

    fn prune_grow(&self, _w: &[f32], _mask: &Mask, _grow: &[f32], _frac: f64) -> Option<Mask> {
        None
    }

    fn validate(&self, _mask: &Mask) -> std::result::Result<(), String> {
        Ok(())
    }

    fn compress(&self, w: &[f32], mask: &Mask, _perm: Option<&[i32]>) -> KernelPlan {
        // No index stream to fold a permutation into: the dense drivers
        // take the explicit-shuffle path (the Fig. 3 strawman) instead.
        KernelPlan::Dense { rows: mask.rows, cols: mask.cols, w: w.to_vec() }
    }

    fn rank_cap(&self, _density: f64, n_in: usize) -> usize {
        n_in
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// One registered family: spec grammar, defaults, and the parser that
/// turns spec arguments into a pattern object.  The `padst patterns`
/// subcommand renders exactly this table.
pub struct FamilyEntry {
    pub name: &'static str,
    /// Spec grammar, e.g. `block[:BS]`.
    pub grammar: &'static str,
    /// Defaults a bare name resolves to.
    pub defaults: &'static str,
    /// Whether DST updates the mask (pulled from the default instance).
    pub dynamic: bool,
    /// Human-readable r_struct formula (paper Sec. 3.4).
    pub rank_cap: &'static str,
    parse: fn(&[&str]) -> Result<PatternHandle>,
}

/// Named registry of every structure family.  `resolve` accepts both bare
/// family names (historical defaults) and parameterised specs.
pub struct PatternRegistry {
    families: Vec<FamilyEntry>,
}

impl PatternRegistry {
    pub fn families(&self) -> &[FamilyEntry] {
        &self.families
    }

    /// Resolve `"family[:arg[:arg]]"` into a pattern object.
    pub fn resolve(&self, spec: &str) -> Result<PatternHandle> {
        let mut parts = spec.split(':');
        let fam = parts.next().unwrap_or("");
        let args: Vec<&str> = parts.collect();
        let entry = self
            .families
            .iter()
            .find(|f| f.name == fam)
            .ok_or_else(|| {
                anyhow!(
                    "unknown structure family {fam:?} in spec {spec:?} (known: {})",
                    self.families.iter().map(|f| f.name).collect::<Vec<_>>().join("|")
                )
            })?;
        (entry.parse)(&args).map_err(|e| anyhow!("bad pattern spec {spec:?}: {e}"))
    }
}

fn parse_usize(what: &str, s: &str) -> Result<usize> {
    s.parse::<usize>().map_err(|_| anyhow!("{what} must be a non-negative integer, got {s:?}"))
}

fn parse_diag(args: &[&str]) -> Result<PatternHandle> {
    match args {
        [] => Ok(Arc::new(DiagPattern { k: None })),
        [k] => {
            let k = parse_usize("K", k)?;
            if k == 0 {
                bail!("diag:K needs K >= 1");
            }
            Ok(Arc::new(DiagPattern { k: Some(k) }))
        }
        _ => bail!("grammar is diag[:K]"),
    }
}

fn parse_banded(args: &[&str]) -> Result<PatternHandle> {
    match args {
        [] => Ok(Arc::new(BandedPattern { half: None })),
        [b] => {
            let b = parse_usize("B", b)?;
            Ok(Arc::new(BandedPattern { half: Some(b) }))
        }
        _ => bail!("grammar is banded[:B] (B = half-bandwidth, width 2B+1)"),
    }
}

fn parse_block(args: &[&str]) -> Result<PatternHandle> {
    match args {
        [] => Ok(Arc::new(BlockPattern { bs: DEFAULT_BLOCK_SIZE })),
        [bs] => {
            let bs = parse_usize("BS", bs)?;
            if bs == 0 {
                bail!("block:BS needs BS >= 1");
            }
            Ok(Arc::new(BlockPattern { bs }))
        }
        _ => bail!("grammar is block[:BS]"),
    }
}

fn parse_nm(args: &[&str]) -> Result<PatternHandle> {
    match args {
        [] => Ok(Arc::new(NMPattern { n: None, m: DEFAULT_NM_GROUP, gamma: 0.3 })),
        // Empty N ("nm::8") keeps the density-derived N over a custom
        // M-group — the form `NMPattern::spec` prints for that state.
        [n, m] => {
            let m = parse_usize("M", m)?;
            if m == 0 {
                bail!("nm:N:M needs M >= 1");
            }
            if n.is_empty() {
                return Ok(Arc::new(NMPattern { n: None, m, gamma: 0.3 }));
            }
            let n = parse_usize("N", n)?;
            if n == 0 {
                bail!("nm:N:M needs N >= 1");
            }
            if n > m {
                bail!("nm:N:M needs N <= M (got {n}:{m})");
            }
            Ok(Arc::new(NMPattern { n: Some(n), m, gamma: 0.3 }))
        }
        _ => bail!("grammar is nm[:N:M] (empty N = density-derived)"),
    }
}

fn parse_noargs<T: SparsePattern + 'static>(
    name: &str,
    args: &[&str],
    p: T,
) -> Result<PatternHandle> {
    if !args.is_empty() {
        bail!("{name} takes no parameters");
    }
    Ok(Arc::new(p))
}

fn parse_butterfly(args: &[&str]) -> Result<PatternHandle> {
    parse_noargs("butterfly", args, ButterflyPattern)
}

fn parse_unstructured(args: &[&str]) -> Result<PatternHandle> {
    parse_noargs("unstructured", args, UnstructuredPattern)
}

fn parse_dense(args: &[&str]) -> Result<PatternHandle> {
    parse_noargs("dense", args, DensePattern)
}

fn family_entry(
    name: &'static str,
    grammar: &'static str,
    defaults: &'static str,
    rank_cap: &'static str,
    parse: fn(&[&str]) -> Result<PatternHandle>,
) -> FamilyEntry {
    FamilyEntry {
        name,
        grammar,
        defaults,
        // The flag is a family property: read it off the default instance
        // so the table can never drift from the impls.
        dynamic: parse(&[]).expect("default spec must parse").is_dynamic(),
        rank_cap,
        parse,
    }
}

/// The global registry (built once).
pub fn registry() -> &'static PatternRegistry {
    static REG: OnceLock<PatternRegistry> = OnceLock::new();
    REG.get_or_init(|| PatternRegistry {
        families: vec![
            family_entry(
                "diag",
                "diag[:K]",
                "K = round(density*cols)",
                "K, else round(density*n_in)",
                parse_diag,
            ),
            family_entry(
                "banded",
                "banded[:B]",
                "width = odd round(density*cols)",
                "2B+1, else round(density*n_in)",
                parse_banded,
            ),
            family_entry("block", "block[:BS]", "BS = 16", "round(density*n_in)", parse_block),
            family_entry(
                "nm",
                "nm[:N:M]",
                "M = 16, N = round(density*M)",
                "round(N/M * n_in)",
                parse_nm,
            ),
            family_entry("butterfly", "butterfly", "-", "round(density*n_in)", parse_butterfly),
            family_entry("unstructured", "unstructured", "-", "n_in", parse_unstructured),
            family_entry("dense", "dense", "-", "n_in", parse_dense),
        ],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::new(42)
    }

    #[test]
    fn bare_names_resolve_and_roundtrip() {
        for name in ["diag", "banded", "block", "nm", "butterfly", "unstructured", "dense"] {
            let p = resolve_pattern(name).unwrap();
            assert_eq!(p.spec(), name, "bare spec must print back as itself");
            assert_eq!(p.family().name(), name);
            // Round-trip: parse(print(parse(s))) is the same pattern.
            let q = resolve_pattern(&p.spec()).unwrap();
            assert_eq!(q.spec(), p.spec());
        }
    }

    #[test]
    fn parameterised_specs_roundtrip() {
        for spec in ["diag:4", "banded:16", "block:8", "block:4", "nm:2:8", "nm:1:4", "nm::8"] {
            let p = resolve_pattern(spec).unwrap();
            assert_eq!(p.spec(), spec, "canonical spec must round-trip");
        }
        // Defaults canonicalise to the bare name.
        assert_eq!(resolve_pattern("block:16").unwrap().spec(), "block");
        assert_eq!(resolve_pattern("nm::16").unwrap().spec(), "nm");
        // Every impl state prints a spec that parses back (the trait's
        // round-trip contract) — including density-derived N over a
        // custom M-group.
        let hand_built = NMPattern { n: None, m: 8, gamma: 0.3 };
        assert_eq!(resolve_pattern(&hand_built.spec()).unwrap().spec(), hand_built.spec());
    }

    #[test]
    fn bad_specs_are_descriptive_errors() {
        for bad in [
            "diag:0",        // k = 0 diagonals
            "nm:3:2",        // n > m
            "nm:0:4",        // n = 0
            "block:0",       // zero block
            "nm:4",          // wrong arity
            "diag:2:3",      // wrong arity
            "butterfly:2",   // family takes no params
            "nosuchfamily",  // unknown family
            "diag:x",        // non-numeric
        ] {
            let err = resolve_pattern(bad).unwrap_err().to_string();
            assert!(!err.is_empty(), "{bad} must fail");
        }
    }

    #[test]
    fn infeasible_geometry_is_err_not_panic() {
        let mut r = rng();
        // K wider than the layer.
        assert!(resolve_pattern("diag:65").unwrap().init_mask(8, 64, 0.1, &mut r).is_err());
        // Band wider than the layer.
        assert!(resolve_pattern("banded:40").unwrap().init_mask(8, 64, 0.1, &mut r).is_err());
        // Block size not dividing the dims.
        assert!(resolve_pattern("block:5").unwrap().init_mask(32, 32, 0.25, &mut r).is_err());
        // M-group not dividing cols.
        assert!(resolve_pattern("nm:1:5").unwrap().init_mask(8, 32, 0.25, &mut r).is_err());
        // Degenerate density.
        assert!(resolve_pattern("diag").unwrap().init_mask(8, 8, 0.0, &mut r).is_err());
    }

    #[test]
    fn registry_masks_match_legacy_builders_bit_identically() {
        // The historical `make_mask` derivations, reproduced: bare-name
        // patterns must consume the RNG identically and emit the same bits.
        let (rows, cols, density) = (96usize, 128usize, 0.1f64);
        for (spec, legacy) in [
            ("diag", {
                let k = ((density * cols as f64).round() as usize).clamp(1, cols);
                make_diag_mask(rows, cols, k, &mut Rng::new(7))
            }),
            ("banded", {
                let mut band = ((density * cols as f64).round() as usize).max(1);
                band += (band + 1) % 2;
                make_banded_mask(rows, cols, band.min(cols))
            }),
            ("block", make_block_mask(rows, cols, density, 16, &mut Rng::new(7))),
            ("nm", {
                let n = ((density * 16.0).round() as usize).max(1);
                make_nm_mask(rows, cols, n, 16, &mut Rng::new(7))
            }),
            ("butterfly", make_butterfly_mask(rows, cols, density)),
            ("unstructured", make_unstructured_mask(rows, cols, density, &mut Rng::new(7))),
            ("dense", Mask::ones(rows, cols)),
        ] {
            let p = resolve_pattern(spec).unwrap();
            let got = p.init_mask(rows, cols, density, &mut Rng::new(7)).unwrap();
            assert_eq!(got, legacy, "{spec}: registry mask differs from legacy builder");
        }
    }

    #[test]
    fn validate_rejects_cross_family_masks() {
        let mut r = rng();
        let diag = resolve_pattern("diag").unwrap();
        let block = resolve_pattern("block").unwrap();
        let nm = resolve_pattern("nm").unwrap();

        let dmask = diag.init_mask(64, 64, 0.1, &mut r).unwrap();
        let bmask = block.init_mask(64, 64, 0.25, &mut r).unwrap();

        assert!(diag.validate(&dmask).is_ok());
        assert!(block.validate(&bmask).is_ok());
        // A diagonal mask is not blocky; a block mask is not a
        // row-independent offset union; neither is a valid 16-group N:M.
        assert!(block.validate(&dmask).is_err());
        assert!(diag.validate(&bmask).is_err());
        assert!(nm.validate(&dmask).is_err());
    }

    #[test]
    fn validate_respects_typed_params() {
        let mut r = rng();
        // A 4x4-blocky mask is valid for block:4 but not (generally) for
        // the 16-block default.
        let b4 = resolve_pattern("block:4").unwrap();
        let mask = b4.init_mask(32, 32, 0.25, &mut r).unwrap();
        assert!(b4.validate(&mask).is_ok());
        assert!(resolve_pattern("block").unwrap().validate(&mask).is_err());

        // nm:1:4 masks carry 1 nnz per 4-group; the 16-group default sees
        // uniform counts only by accident — build one that breaks it.
        let nm14 = resolve_pattern("nm:1:4").unwrap();
        let m = nm14.init_mask(8, 32, 0.25, &mut r).unwrap();
        assert!(nm14.validate(&m).is_ok());
        for i in 0..8 {
            assert_eq!(m.row_nnz(i), 8, "1 of every 4 columns");
        }
    }

    #[test]
    fn prune_grow_stays_in_family_for_parameterised_specs() {
        let mut r = rng();
        for spec in ["diag:4", "block:4", "block:8", "nm:1:4", "nm:2:8", "unstructured"] {
            let p = resolve_pattern(spec).unwrap();
            let (rows, cols) = (32usize, 64usize);
            let mask = p.init_mask(rows, cols, 0.25, &mut r).unwrap();
            let w: Vec<f32> = (0..rows * cols).map(|_| r.normal()).collect();
            let g: Vec<f32> = (0..rows * cols).map(|_| r.normal()).collect();
            let new = p.prune_grow(&w, &mask, &g, 0.3).expect("dynamic family");
            assert_eq!(new.nnz(), mask.nnz(), "{spec}: budget changed");
            assert!(p.validate(&new).is_ok(), "{spec}: left family");
        }
        // Static families report None.
        for spec in ["banded", "butterfly", "dense"] {
            let p = resolve_pattern(spec).unwrap();
            assert!(p.prune_grow(&[], &Mask::ones(4, 4), &[], 0.3).is_none(), "{spec}");
            assert!(!p.is_dynamic(), "{spec}");
        }
    }

    #[test]
    fn compress_plans_pick_the_right_driver() {
        let mut r = rng();
        let (rows, cols) = (32usize, 64usize);
        let w: Vec<f32> = (0..rows * cols).map(|_| r.normal()).collect();
        for (spec, driver) in [
            ("diag", "gather"),
            ("banded", "gather"),
            ("nm", "gather"),
            ("butterfly", "gather"),
            ("block", "block"),
            ("unstructured", "csr"),
            ("dense", "dense"),
        ] {
            let p = resolve_pattern(spec).unwrap();
            let mask = p.init_mask(rows, cols, 0.25, &mut r).unwrap();
            assert_eq!(p.compress(&w, &mask, None).driver(), driver, "{spec}");
        }
        // Folding a permutation into block panels falls back to row-gather.
        let block = resolve_pattern("block").unwrap();
        let mask = block.init_mask(rows, cols, 0.25, &mut r).unwrap();
        let perm: Vec<i32> = (0..cols as i32).rev().collect();
        assert_eq!(block.compress(&w, &mask, Some(&perm)).driver(), "gather");
    }

    #[test]
    fn rank_caps_follow_typed_params() {
        // Typed K wins over the density guess.
        assert_eq!(resolve_pattern("diag:51").unwrap().rank_cap(0.5, 1024), 51);
        assert_eq!(resolve_pattern("diag").unwrap().rank_cap(0.05, 1024), 51);
        // Tied N:M alpha = N/M.
        assert_eq!(resolve_pattern("nm:1:4").unwrap().rank_cap(0.9, 1024), 256);
        // Free families cap at n_in.
        assert_eq!(resolve_pattern("unstructured").unwrap().rank_cap(0.1, 1024), 1024);
        assert_eq!(resolve_pattern("dense").unwrap().rank_cap(0.1, 1024), 1024);
    }

    #[test]
    fn default_specs_hit_target_density() {
        let mut r = rng();
        for spec in ["diag", "block", "nm", "butterfly", "unstructured"] {
            let p = resolve_pattern(spec).unwrap();
            let m = p.init_mask(128, 128, 0.1, &mut r).unwrap();
            let d = m.density();
            assert!((d - 0.1).abs() < 0.06, "{spec}: density {d} too far from 0.1");
            assert!(p.validate(&m).is_ok(), "{spec}");
        }
    }

    #[test]
    fn registry_table_is_complete() {
        let reg = registry();
        assert_eq!(reg.families().len(), 7);
        for f in reg.families() {
            // Each family's default must resolve and agree on dynamics.
            let p = reg.resolve(f.name).unwrap();
            assert_eq!(p.is_dynamic(), f.dynamic, "{}", f.name);
            assert!(!f.grammar.is_empty() && !f.rank_cap.is_empty());
        }
    }
}
