//! Structured-sparsity substrate (Rust mirror of `python/compile/sparsity.py`).
//!
//! The coordinator needs masks host-side for three reasons: (i) initialising
//! runs with arbitrary (structure, density, seed) combinations without
//! round-tripping through Python, (ii) compressing trained dense weights
//! into the kernel forms used by the native Fig.-3 benches and the AOT
//! `infer` artifacts, and (iii) verifying — via unit + property tests —
//! the invariants the DST update programs must preserve (budget, family
//! membership).
//!
//! The module is layered: [`patterns`] holds the mask primitive and the
//! pure parameter-explicit builders, [`compress`] the kernel layouts,
//! [`dst`] the prune/grow rules — and [`pattern`] binds one of each into a
//! first-class [`pattern::SparsePattern`] object per family, resolved by
//! name or parameterised spec through [`pattern::PatternRegistry`].  All
//! family dispatch lives in `pattern`; everything else is family-blind.

pub mod compress;
pub mod dst;
pub mod pattern;
pub mod patterns;

pub use compress::{
    compress_blocks, compress_rows, csr_from_mask, BlockCompressed, Csr, RowCompressed,
};
pub use pattern::{
    registry, resolve_pattern, KernelPlan, PatternHandle, PatternRegistry, SparsePattern,
    Structure,
};
pub use patterns::Mask;

/// Apdx A: map a per-layer density to structural parameters.
///
/// This is the *paper's* worked mapping, kept for the expressivity
/// walkthrough (`examples/expressivity.rs`).  Runtime dispatch no longer
/// goes through it: each [`pattern::SparsePattern`] impl carries its own
/// typed params (spec-provided or density-derived) with validated edges.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PatternParams {
    /// Diagonal count K = round(density * n_in).
    pub k: usize,
    /// Per-row block budget (same magnitude as K).
    pub b: usize,
    /// Band width 2b+1 (nearest odd).
    pub band: usize,
    /// Tied N:M pair with N/M ~ density.
    pub n: usize,
    pub m: usize,
}

pub fn density_to_params(density: f64, n_in: usize, m: usize) -> PatternParams {
    assert!(density > 0.0 && density <= 1.0, "density out of range: {density}");
    let k = ((density * n_in as f64).round() as usize).max(1);
    let mut band = k;
    if band % 2 == 0 {
        band = if band + 1 <= n_in { band + 1 } else { band - 1 };
    }
    let n = ((density * m as f64).round() as usize).max(1);
    PatternParams { k, b: k, band, n, m }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn apdx_a_vitl_worked_example() {
        // Paper Apdx A: ViT-L/16 surrogate at density 0.05:
        //   n_in=1024 -> K=B=51, band=51;  n_in=4096 -> K'=B'=205.
        let p1 = density_to_params(0.05, 1024, 20);
        assert_eq!(p1.k, 51);
        assert_eq!(p1.band, 51);
        let p2 = density_to_params(0.05, 4096, 20);
        assert_eq!(p2.k, 205);
        assert_eq!(p2.n, 1); // alpha = N/M = 1/20 = 0.05
    }

    #[test]
    #[should_panic]
    fn density_zero_rejected() {
        density_to_params(0.0, 128, 16);
    }
}
