//! Host-side DST bookkeeping: the prune-and-grow rules mirrored from
//! `python/compile/sparsity.py` (the production updates run inside the AOT
//! `dst_update` artifact; these mirrors exist for unit/property testing of
//! the invariants and for the coordinator's mask validation), plus the
//! cosine update-fraction schedule of RigL.

use super::patterns::{row_col_base, Mask};

/// RigL's cosine-decayed drop fraction: alpha_t = f0/2 * (1 + cos(pi t/T)).
pub fn cosine_update_frac(step: usize, total_steps: usize, frac0: f64) -> f64 {
    let t = (step as f64 / total_steps.max(1) as f64).clamp(0.0, 1.0);
    frac0 * 0.5 * (1.0 + (std::f64::consts::PI * t).cos())
}

/// Unstructured RigL update: drop `frac` of the active weights by |w|,
/// grow the same count by the grow score.  Budget preserved exactly.
pub fn unstructured_prune_grow(
    w: &[f32],
    mask: &Mask,
    grow_scores: &[f32],
    frac: f64,
) -> Mask {
    let nnz = mask.nnz();
    let n_inactive = mask.rows * mask.cols - nnz;
    let n_move = ((frac * nnz as f64).floor() as usize).min(n_inactive);
    // Keep (nnz - n_move) largest-|w| active entries.
    let mut active: Vec<(usize, f32)> = mask
        .bits
        .iter()
        .enumerate()
        .filter(|(_, &b)| b > 0.5)
        .map(|(p, _)| (p, w[p].abs()))
        .collect();
    active.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let mut new = Mask::zeros(mask.rows, mask.cols);
    for &(p, _) in active.iter().take(nnz - n_move) {
        new.bits[p] = 1.0;
    }
    // Grow n_move inactive entries by grow score.
    let mut inactive: Vec<(usize, f32)> = mask
        .bits
        .iter()
        .enumerate()
        .filter(|(p, &b)| b < 0.5 && new.bits[*p] < 0.5)
        .map(|(p, _)| (p, grow_scores[p]))
        .collect();
    inactive.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    for &(p, _) in inactive.iter().take(n_move) {
        new.bits[p] = 1.0;
    }
    new
}

/// DynaDiag update: the structural unit is the cyclic diagonal; score
/// active offsets by sum|w| along the diagonal, inactive by sum|grad|.
pub fn diag_prune_grow(w: &[f32], mask: &Mask, grad: &[f32], frac: f64) -> Mask {
    let (rows, cols) = (mask.rows, mask.cols);
    let base = row_col_base(rows, cols);
    let offset_of = |i: usize, j: usize| (j + cols - base[i] % cols) % cols;

    let mut active = vec![false; cols];
    let mut keep_score = vec![0.0f64; cols];
    let mut grow_score = vec![0.0f64; cols];
    for i in 0..rows {
        for j in 0..cols {
            let o = offset_of(i, j);
            if mask.get(i, j) {
                active[o] = true;
                keep_score[o] += w[i * cols + j].abs() as f64;
            }
            grow_score[o] += grad[i * cols + j].abs() as f64;
        }
    }
    let k = active.iter().filter(|&&a| a).count();
    let n_move = ((frac * k as f64).floor() as usize).min(cols - k);

    let mut act: Vec<usize> = (0..cols).filter(|&o| active[o]).collect();
    act.sort_by(|&a, &b| keep_score[b].partial_cmp(&keep_score[a]).unwrap());
    let kept: Vec<usize> = act[..k - n_move].to_vec();

    let mut inact: Vec<usize> = (0..cols)
        .filter(|&o| !active[o] && !kept.contains(&o))
        .collect();
    inact.sort_by(|&a, &b| grow_score[b].partial_cmp(&grow_score[a]).unwrap());
    let mut offsets = kept;
    offsets.extend(inact.into_iter().take(n_move));

    super::patterns::diag_mask_from_offsets(rows, cols, &offsets)
}

/// SRigL-style N:M update: within each group of M, re-select N survivors by
/// score |w| (active) vs gamma*|grad| (candidates).
pub fn nm_prune_grow(w: &[f32], mask: &Mask, grad: &[f32], m_group: usize, gamma: f32) -> Mask {
    let (rows, cols) = (mask.rows, mask.cols);
    let mut new = Mask::zeros(rows, cols);
    for i in 0..rows {
        for g in 0..cols / m_group {
            let n = (g * m_group..(g + 1) * m_group)
                .filter(|&j| mask.get(i, j))
                .count();
            let mut scored: Vec<(usize, f32)> = (0..m_group)
                .map(|c| {
                    let j = g * m_group + c;
                    let s = if mask.get(i, j) {
                        w[i * cols + j].abs()
                    } else {
                        gamma * grad[i * cols + j].abs()
                    };
                    (j, s)
                })
                .collect();
            scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            for &(j, _) in scored.iter().take(n) {
                new.set(i, j, true);
            }
        }
    }
    new
}

/// DSB-style block update: move `frac` of the active blocks; score active
/// by sum|w|, inactive by sum|grad|.
pub fn block_prune_grow(w: &[f32], mask: &Mask, grad: &[f32], bs: usize, frac: f64) -> Mask {
    let (rows, cols) = (mask.rows, mask.cols);
    let (br, bc) = (rows / bs, cols / bs);
    let bsum = |x: &[f32], bi: usize, bj: usize| -> f64 {
        let mut s = 0.0f64;
        for r in bi * bs..(bi + 1) * bs {
            for c in bj * bs..(bj + 1) * bs {
                s += x[r * cols + c].abs() as f64;
            }
        }
        s
    };
    let mut act = Vec::new();
    let mut inact = Vec::new();
    for bi in 0..br {
        for bj in 0..bc {
            if mask.get(bi * bs, bj * bs) {
                act.push(((bi, bj), bsum(w, bi, bj)));
            } else {
                inact.push(((bi, bj), bsum(grad, bi, bj)));
            }
        }
    }
    let nblk = act.len();
    // Cannot move more blocks than there are inactive slots to grow into
    // (narrow layers can have every block active).
    let n_move = ((frac * nblk as f64).floor() as usize).min(inact.len());
    act.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    inact.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let mut new = Mask::zeros(rows, cols);
    let mut set_block = |bi: usize, bj: usize| {
        for r in bi * bs..(bi + 1) * bs {
            for c in bj * bs..(bj + 1) * bs {
                new.set(r, c, true);
            }
        }
    };
    for &((bi, bj), _) in act.iter().take(nblk - n_move) {
        set_block(bi, bj);
    }
    for &((bi, bj), _) in inact.iter().take(n_move) {
        set_block(bi, bj);
    }
    new
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::pattern::resolve_pattern;
    use crate::sparsity::patterns::{
        make_block_mask, make_diag_mask, make_nm_mask, make_unstructured_mask,
    };
    use crate::util::Rng;

    #[test]
    fn cosine_schedule_endpoints() {
        assert!((cosine_update_frac(0, 100, 0.3) - 0.3).abs() < 1e-12);
        assert!(cosine_update_frac(100, 100, 0.3) < 1e-12);
        let mid = cosine_update_frac(50, 100, 0.3);
        assert!((mid - 0.15).abs() < 1e-12);
    }

    #[test]
    fn unstructured_budget_preserved() {
        let mut rng = Rng::new(5);
        let mask = make_unstructured_mask(16, 32, 0.2, &mut rng);
        let w: Vec<f32> = (0..512).map(|_| rng.normal()).collect();
        let g: Vec<f32> = (0..512).map(|_| rng.normal().abs()).collect();
        let new = unstructured_prune_grow(&w, &mask, &g, 0.3);
        assert_eq!(new.nnz(), mask.nnz());
    }

    #[test]
    fn diag_stays_diag_and_budget() {
        let mut rng = Rng::new(6);
        let mask = make_diag_mask(32, 32, 4, &mut rng);
        let w: Vec<f32> = (0..1024).map(|_| rng.normal()).collect();
        let g: Vec<f32> = (0..1024).map(|_| rng.normal()).collect();
        let new = diag_prune_grow(&w, &mask, &g, 0.5);
        assert_eq!(new.nnz(), mask.nnz());
        assert!(resolve_pattern("diag").unwrap().validate(&new).is_ok());
    }

    #[test]
    fn nm_stays_nm() {
        let mut rng = Rng::new(7);
        let mask = make_nm_mask(8, 32, 3, 16, &mut rng);
        let w: Vec<f32> = (0..256).map(|_| rng.normal()).collect();
        let g: Vec<f32> = (0..256).map(|_| rng.normal()).collect();
        let new = nm_prune_grow(&w, &mask, &g, 16, 0.3);
        assert_eq!(new.nnz(), mask.nnz());
        assert!(resolve_pattern("nm").unwrap().validate(&new).is_ok());
    }

    #[test]
    fn block_stays_block() {
        let mut rng = Rng::new(8);
        let mask = make_block_mask(32, 64, 0.25, 16, &mut rng);
        let w: Vec<f32> = (0..2048).map(|_| rng.normal()).collect();
        let g: Vec<f32> = (0..2048).map(|_| rng.normal()).collect();
        let new = block_prune_grow(&w, &mask, &g, 16, 0.5);
        assert_eq!(new.nnz(), mask.nnz());
        assert!(resolve_pattern("block").unwrap().validate(&new).is_ok());
    }

    #[test]
    fn grow_targets_high_gradient() {
        // A diagonal with zero weight everywhere and one very hot gradient
        // diagonal must grow onto that diagonal.
        let mut rng = Rng::new(9);
        let mask = make_diag_mask(16, 16, 2, &mut rng);
        let w = vec![0.0f32; 256];
        let mut g = vec![0.0f32; 256];
        // Heat offset 7 (relative to base = identity for square).
        for i in 0..16 {
            g[i * 16 + (i + 7) % 16] = 10.0;
        }
        let new = diag_prune_grow(&w, &mask, &g, 0.5);
        // offset 7 must be active in the new mask.
        assert!(new.get(0, 7), "hot gradient diagonal was not grown");
    }
}
