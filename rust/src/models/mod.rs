//! Model-side host logic: parameter initialisation matching the manifest
//! layouts, paper-scale shape tables for the Fig. 3 benches, and memory
//! accounting for the Tbl. 2–5 overhead reports.

use crate::perm::model::PermModel;
use crate::runtime::manifest::ModelEntry;
use crate::sparsity::pattern::SparsePattern;
use crate::tensor::Tensor;
use crate::util::Rng;

/// Initialise a parameter tensor by name, mirroring the conventions of
/// `python/compile/model.py::init_params` (LeCun-uniform linears, zero
/// biases, unit LN gains, 0.02-std embeddings).  Exact bit-equality with
/// Python is *not* required (init is init); goldens pin the numerics.
pub fn init_param(name: &str, shape: &[usize], rng: &mut Rng) -> Tensor {
    let mut t = Tensor::zeros(shape);
    let leaf = name.rsplit('.').next().unwrap_or(name);
    match leaf {
        "w" if shape.len() == 2 => {
            let scale = 1.0 / (shape[1] as f32).sqrt();
            for v in t.f32s_mut() {
                *v = rng.range_f32(-scale, scale);
            }
        }
        "g" => t.f32s_mut().fill(1.0),
        "b" => {} // zero biases and LN shifts
        _ => {
            // embeddings / cls / pos tables
            for v in t.f32s_mut() {
                *v = 0.02 * rng.normal();
            }
        }
    }
    t
}

/// Initialise the full parameter list of a model in manifest order.
pub fn init_params(entry: &ModelEntry, seed: u64) -> Vec<(String, Tensor)> {
    let mut rng = Rng::new(seed);
    entry
        .params
        .iter()
        .map(|(name, shape)| (name.clone(), init_param(name, shape, &mut rng)))
        .collect()
}

/// One sparsified-layer geometry of the *paper-scale* models, used by the
/// native kernel benches to reproduce Fig. 3 at the true ViT-B/16 and
/// GPT-2 dimensions (we cannot train at that scale on this testbed, but we
/// can time GEMMs at it).
#[derive(Clone, Copy, Debug)]
pub struct PaperLayer {
    pub model: &'static str,
    pub site: &'static str,
    pub rows: usize,
    pub cols: usize,
}

/// The sparsified sites of ViT-B/16 (d=768, d_ff=3072) and GPT-2 Small
/// (d=768) per Apdx C.5.
pub const PAPER_LAYERS: &[PaperLayer] = &[
    PaperLayer { model: "vit_b16", site: "attn_out", rows: 768, cols: 768 },
    PaperLayer { model: "vit_b16", site: "fc1", rows: 3072, cols: 768 },
    PaperLayer { model: "vit_b16", site: "fc2", rows: 768, cols: 3072 },
    PaperLayer { model: "gpt2_s", site: "qkv", rows: 2304, cols: 768 },
    PaperLayer { model: "gpt2_s", site: "attn_out", rows: 768, cols: 768 },
    PaperLayer { model: "gpt2_s", site: "fc1", rows: 3072, cols: 768 },
    PaperLayer { model: "gpt2_s", site: "fc2", rows: 768, cols: 3072 },
];

/// Bytes of state a training run holds per method, for the Tbl. 2–5 memory
/// overhead analogue.  The mask term comes from the structure family's own
/// [`SparsePattern::memory_footprint`] accounting and the permutation term
/// from the mode's own [`PermModel::memory_bytes`]: learned soft perms
/// cost an N x N f32 logits matrix per site (collapsing to one index map
/// after hardening), kaleidoscope costs log2(N) x N angles, random costs
/// one index map, none costs nothing.
pub fn memory_footprint(
    entry: &ModelEntry,
    pattern: &dyn SparsePattern,
    perm: &dyn PermModel,
    hardened: bool,
) -> usize {
    let params: usize = entry.n_params() * 4;
    let adam = 2 * params;
    let masks: usize = entry
        .sites
        .iter()
        .map(|s| pattern.memory_footprint(s.rows, s.cols))
        .sum();
    let perm_bytes: usize = entry
        .sites
        .iter()
        .map(|s| perm.memory_bytes(s.cols, hardened))
        .sum();
    params + adam + masks + perm_bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::SiteSpec;

    fn toy_entry() -> ModelEntry {
        ModelEntry {
            kind: "vit".into(),
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            seq_len: 4,
            vocab: 0,
            n_classes: 4,
            image: 8,
            patch: 4,
            params: vec![
                ("a.w".into(), vec![16, 8]),
                ("a.b".into(), vec![16]),
                ("ln.g".into(), vec![16]),
            ],
            sites: vec![SiteSpec { name: "a".into(), rows: 16, cols: 8 }],
        }
    }

    #[test]
    fn init_conventions() {
        let e = toy_entry();
        let ps = init_params(&e, 3);
        assert_eq!(ps.len(), 3);
        let w = &ps[0].1;
        let scale = 1.0 / (8.0f32).sqrt();
        assert!(w.f32s().iter().all(|&v| v.abs() <= scale));
        assert!(ps[1].1.f32s().iter().all(|&v| v == 0.0)); // bias zero
        assert!(ps[2].1.f32s().iter().all(|&v| v == 1.0)); // gain one
    }

    #[test]
    fn perm_memory_ordering() {
        // Paper Tbl. 2–5 ordering: learned (PA-DST) > kaleidoscope >
        // random > none, and hardening collapses learned to ~random.
        let e = toy_entry();
        let p = crate::sparsity::pattern::resolve_pattern("diag").unwrap();
        let pm = |spec: &str| crate::perm::model::resolve_perm(spec).unwrap();
        let none = memory_footprint(&e, p.as_ref(), pm("none").as_ref(), false);
        let rand = memory_footprint(&e, p.as_ref(), pm("random").as_ref(), false);
        let kal = memory_footprint(&e, p.as_ref(), pm("kaleidoscope").as_ref(), false);
        let learned = memory_footprint(&e, p.as_ref(), pm("learned").as_ref(), false);
        let hard = memory_footprint(&e, p.as_ref(), pm("learned").as_ref(), true);
        assert!(none < rand && rand < kal && kal < learned);
        assert_eq!(hard, rand);
    }
}
