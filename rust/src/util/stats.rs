//! Summary statistics + a small timing harness (offline stand-in for
//! criterion).  Every bench target reports mean / median / p95 over a
//! warmed-up sample set, and the harness prints rows in a stable
//! machine-grepable format consumed by EXPERIMENTS.md.

use std::time::Instant;

#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub max: f64,
}

pub fn summarize(samples: &[f64]) -> Summary {
    assert!(!samples.is_empty());
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    let mean = v.iter().sum::<f64>() / n as f64;
    let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let q = |p: f64| v[((n as f64 - 1.0) * p).round() as usize];
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: v[0],
        p50: q(0.5),
        p90: q(0.90),
        p95: q(0.95),
        max: v[n - 1],
    }
}

/// Benchmark a closure: `warmup` untimed runs, then timed runs until both
/// `min_iters` and `min_time_s` are satisfied.  Returns per-iteration
/// seconds.
pub fn bench<F: FnMut()>(mut f: F, warmup: usize, min_iters: usize, min_time_s: f64) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || start.elapsed().as_secs_f64() < min_time_s {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if samples.len() >= 10_000 {
            break;
        }
    }
    summarize(&samples)
}

/// Print one bench row: `name  mean  p50  p95  [extra]` with units scaled.
pub fn report(name: &str, s: &Summary, extra: &str) {
    println!(
        "{:<44} mean {:>10}  p50 {:>10}  p95 {:>10}  n={:<5} {}",
        name,
        fmt_time(s.mean),
        fmt_time(s.p50),
        fmt_time(s.p95),
        s.n,
        extra
    );
}

pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3}ms", secs * 1e3)
    } else {
        format!("{:.3}s", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.p90, 5.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.mean - 3.0).abs() < 1e-12);
    }

    #[test]
    fn bench_runs() {
        let mut c = 0u64;
        let s = bench(|| c += 1, 2, 5, 0.0);
        assert!(s.n >= 5);
        assert!(c >= 7);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_time(2e-9).ends_with("ns"));
        assert!(fmt_time(2e-6).ends_with("us"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2.0).ends_with('s'));
    }
}
