//! Dependency-free utilities: deterministic RNG, summary statistics and a
//! lightweight timing harness used by the benches (this build is fully
//! offline, so `rand`/`criterion` are hand-rolled here).

pub mod cli;
pub mod fs;
pub mod json;
pub mod stats;

/// SplitMix64 + xoshiro256** — deterministic, seedable, fast.  Used for
/// data synthesis, mask init and the property-test case generators, so every
/// run of the pipeline is reproducible from a single `u64` seed.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-9);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffled identity — a uniform random permutation.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            p.swap(i, self.below(i + 1));
        }
        p
    }

    /// k distinct values sampled from [0, n) (partial Fisher–Yates).
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut p: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            p.swap(i, j);
        }
        p.truncate(k);
        p
    }

    /// Fork a child stream (hierarchical seeding, fold-in style).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }
}

/// Wall-clock timing of a closure, in seconds.
pub fn time_it<F: FnMut()>(mut f: F) -> f64 {
    let t0 = std::time::Instant::now();
    f();
    t0.elapsed().as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn permutation_is_bijection() {
        let mut r = Rng::new(3);
        for n in [1usize, 2, 7, 64] {
            let p = r.permutation(n);
            let mut seen = vec![false; n];
            for &i in &p {
                assert!(!seen[i]);
                seen[i] = true;
            }
        }
    }

    #[test]
    fn choose_distinct() {
        let mut r = Rng::new(9);
        let c = r.choose(50, 20);
        let mut s = c.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f32> = (0..20000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / xs.len() as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }
}
