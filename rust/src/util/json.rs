//! Minimal JSON parser/serializer (the build is offline; no serde_json).
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json` and
//! the CSV/JSON result files the coordinator writes: objects, arrays,
//! strings with escapes, numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style access with a helpful error message.
    pub fn at(&self, path: &[&str]) -> Result<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur
                .get(p)
                .ok_or_else(|| anyhow!("missing key {p:?} in JSON path {path:?}"))?;
        }
        Ok(cur)
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no Infinity/NaN literal; emit null so the
                    // output always re-parses (readers treat it as NaN).
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out, depth + 1);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out, depth + 1);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, got {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']' at byte {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Reassemble UTF-8 multibyte sequences.
                    let len = utf8_len(c);
                    if len == 1 {
                        s.push(c as char);
                    } else {
                        let bytes = &self.b[self.i - 1..self.i - 1 + len];
                        s.push_str(std::str::from_utf8(bytes)?);
                        self.i += len - 1;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number {s:?}: {e}"))?))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

/// Convenience constructors for result serialisation.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr<I: IntoIterator<Item = Json>>(it: I) -> Json {
    Json::Arr(it.into_iter().collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": true}, "e": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.at(&["b", "c"]).unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().as_f64().unwrap(), -300.0);
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{\"a\": }").is_err());
        assert!(Json::parse("[1, 2,]").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse(r#""café – ok""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café – ok");
    }

    #[test]
    fn nested_depth() {
        let src = "[".repeat(50) + &"]".repeat(50);
        assert!(Json::parse(&src).is_ok());
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        for bad in [f64::INFINITY, f64::NEG_INFINITY, f64::NAN] {
            let s = Json::Num(bad).to_string_pretty();
            assert_eq!(s, "null");
            assert_eq!(Json::parse(&s).unwrap(), Json::Null);
        }
    }
}
