//! Shared CLI/env knob parsing.
//!
//! One worker-thread convention flows through the whole crate (`0` = auto
//! = available parallelism, `1` = serial, `n` = at most n workers), and
//! before this module each bench target hand-rolled its own argv scanning
//! around `kernels::parallel::threads_from_env_or_args`.  The scanning
//! lives here now — the CLI, the five benches, the examples, and the sweep
//! executor's `--workers` flag all parse through the same helpers.
//!
//! This module is std-only by design: `util` sits at the bottom of the
//! layering manifest (`ci/lint/layers.toml`) and imports nothing from the
//! crate.  Knobs that need crate types — the microkernel backend knob and
//! the bench option bundle — live in [`crate::harness::bench`], which is
//! allowed to see `kernels`.

use std::path::PathBuf;

/// The machine's available parallelism (>= 1).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a thread knob: 0 = auto (available parallelism).
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        available_threads()
    } else {
        threads
    }
}

/// `--key value` scan over an argv slice; `None` if absent or value-less.
pub fn arg_value_in(args: &[String], key: &str) -> Option<String> {
    args.iter().position(|a| a == key).and_then(|i| args.get(i + 1)).cloned()
}

/// Presence check for a bare `--flag`.
pub fn has_flag_in(args: &[String], key: &str) -> bool {
    args.iter().any(|a| a == key)
}

/// The process argv as owned strings (cargo bench forwards arguments
/// after `--` to the bench binary).
pub fn argv() -> Vec<String> {
    std::env::args().collect()
}

/// Raw thread knob from an argv slice: `--threads N`, else the
/// `PADST_THREADS` env var, else 0 (= auto).  Unparseable values fall
/// through to the next source.
pub fn thread_knob_in(args: &[String]) -> usize {
    if let Some(n) = arg_value_in(args, "--threads").and_then(|v| v.parse().ok()) {
        return n;
    }
    if let Ok(v) = std::env::var("PADST_THREADS") {
        if let Ok(n) = v.parse() {
            return n;
        }
    }
    0
}

/// [`thread_knob_in`] over the process argv.
pub fn thread_knob() -> usize {
    thread_knob_in(&argv())
}

/// Where a bench's machine-readable report goes: `PADST_BENCH_DIR` if set,
/// else the current directory, always named `BENCH_<bench>.json`.
pub fn bench_json_path(bench: &str) -> PathBuf {
    let file = format!("BENCH_{bench}.json");
    match std::env::var("PADST_BENCH_DIR") {
        Ok(d) if !d.is_empty() => PathBuf::from(d).join(file),
        _ => PathBuf::from(file),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn arg_scanning() {
        let a = args(&["bench", "--threads", "4", "--short"]);
        assert_eq!(arg_value_in(&a, "--threads").as_deref(), Some("4"));
        assert_eq!(arg_value_in(&a, "--json"), None);
        assert!(has_flag_in(&a, "--short"));
        assert!(!has_flag_in(&a, "--full"));
        assert_eq!(thread_knob_in(&a), 4);
    }

    #[test]
    fn resolve_zero_is_auto() {
        assert_eq!(resolve_threads(0), available_threads());
        assert_eq!(resolve_threads(5), 5);
    }
}
