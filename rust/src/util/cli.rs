//! Shared CLI/env knob parsing.
//!
//! One worker-thread convention flows through the whole crate (`0` = auto
//! = available parallelism, `1` = serial, `n` = at most n workers), and
//! before this module each bench target hand-rolled its own argv scanning
//! around `kernels::parallel::threads_from_env_or_args`.  The scanning
//! lives here now — the CLI, the five benches, the examples, and the sweep
//! executor's `--workers` flag all parse through the same helpers.
//!
//! The microkernel backend knob (`--backend` / `PADST_BACKEND`) follows
//! the same pattern.  [`kernels::micro`](crate::kernels::micro) is a leaf
//! module (std only), so pulling its [`Backend`] type in here keeps the
//! layering acyclic.

use std::path::PathBuf;

use crate::kernels::micro::Backend;

/// The machine's available parallelism (>= 1).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a thread knob: 0 = auto (available parallelism).
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        available_threads()
    } else {
        threads
    }
}

/// `--key value` scan over an argv slice; `None` if absent or value-less.
pub fn arg_value_in(args: &[String], key: &str) -> Option<String> {
    args.iter().position(|a| a == key).and_then(|i| args.get(i + 1)).cloned()
}

/// Presence check for a bare `--flag`.
pub fn has_flag_in(args: &[String], key: &str) -> bool {
    args.iter().any(|a| a == key)
}

fn argv() -> Vec<String> {
    std::env::args().collect()
}

/// Raw thread knob from an argv slice: `--threads N`, else the
/// `PADST_THREADS` env var, else 0 (= auto).  Unparseable values fall
/// through to the next source.
pub fn thread_knob_in(args: &[String]) -> usize {
    if let Some(n) = arg_value_in(args, "--threads").and_then(|v| v.parse().ok()) {
        return n;
    }
    if let Ok(v) = std::env::var("PADST_THREADS") {
        if let Ok(n) = v.parse() {
            return n;
        }
    }
    0
}

/// [`thread_knob_in`] over the process argv (cargo bench forwards
/// arguments after `--` to the bench binary).
pub fn thread_knob() -> usize {
    thread_knob_in(&argv())
}

/// Resolve the microkernel backend from an argv slice: `--backend NAME`
/// wins, else the `PADST_BACKEND` env var, else Tiled.  Unknown names
/// warn and fall back (see [`Backend::resolve`]); the `padst` CLI parses
/// its own flag strictly instead.
pub fn backend_knob_in(args: &[String]) -> Backend {
    Backend::resolve(arg_value_in(args, "--backend").as_deref())
}

/// Where a bench's machine-readable report goes: `PADST_BENCH_DIR` if set,
/// else the current directory, always named `BENCH_<bench>.json`.
pub fn bench_json_path(bench: &str) -> PathBuf {
    let file = format!("BENCH_{bench}.json");
    match std::env::var("PADST_BENCH_DIR") {
        Ok(d) if !d.is_empty() => PathBuf::from(d).join(file),
        _ => PathBuf::from(file),
    }
}

/// Options shared by every bench target, parsed from argv + environment in
/// one place.
#[derive(Clone, Debug)]
pub struct BenchOpts {
    /// Bench name (the `BENCH_<name>.json` stem).
    pub bench: String,
    /// Resolved worker-thread ceiling (>= 1).
    pub threads: usize,
    /// Resolved microkernel backend (`--backend` / `PADST_BACKEND`,
    /// default Tiled).
    pub backend: Backend,
    /// Short mode (`--short` or `PADST_BENCH_SHORT=1`): CI-sized sample
    /// budgets via [`BenchOpts::budget`].
    pub short: bool,
    /// Where the JSON report is written (`--json PATH` overrides
    /// [`bench_json_path`]).
    pub json_path: PathBuf,
}

impl BenchOpts {
    pub fn parse(bench: &str) -> BenchOpts {
        let args = argv();
        let short = has_flag_in(&args, "--short")
            || std::env::var("PADST_BENCH_SHORT")
                .map(|v| !v.is_empty() && v != "0")
                .unwrap_or(false);
        let json_path = arg_value_in(&args, "--json")
            .map(PathBuf::from)
            .unwrap_or_else(|| bench_json_path(bench));
        // An explicit --backend pins the backend for the whole bench run:
        // the tuning table may still select bit-preserving variants but
        // never another backend (see `kernels::tune`).
        if arg_value_in(&args, "--backend").is_some() {
            crate::kernels::tune::note_backend_pinned();
        }
        BenchOpts {
            bench: bench.to_string(),
            threads: resolve_threads(thread_knob_in(&args)),
            backend: backend_knob_in(&args),
            short,
            json_path,
        }
    }

    /// Scale a call site's `(warmup, min_iters, min_time_s)` budget down
    /// for short mode; identity otherwise.
    pub fn budget(&self, warmup: usize, min_iters: usize, min_time_s: f64) -> (usize, usize, f64) {
        if self.short {
            (warmup.min(1), min_iters.min(2), min_time_s.min(0.02))
        } else {
            (warmup, min_iters, min_time_s)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn arg_scanning() {
        let a = args(&["bench", "--threads", "4", "--short"]);
        assert_eq!(arg_value_in(&a, "--threads").as_deref(), Some("4"));
        assert_eq!(arg_value_in(&a, "--json"), None);
        assert!(has_flag_in(&a, "--short"));
        assert!(!has_flag_in(&a, "--full"));
        assert_eq!(thread_knob_in(&a), 4);
    }

    #[test]
    fn resolve_zero_is_auto() {
        assert_eq!(resolve_threads(0), available_threads());
        assert_eq!(resolve_threads(5), 5);
    }

    #[test]
    fn backend_knob_explicit_flag_wins() {
        let a = args(&["bench", "--backend", "scalar"]);
        assert_eq!(backend_knob_in(&a), Backend::Scalar);
        // Unknown names warn and fall back instead of erroring (benches
        // should not die over a knob).
        let bad = args(&["bench", "--backend", "gpu"]);
        assert_eq!(backend_knob_in(&bad), Backend::Tiled);
    }

    #[test]
    fn short_budget_caps() {
        let mut o = BenchOpts {
            bench: "x".into(),
            threads: 1,
            backend: Backend::Tiled,
            short: true,
            json_path: PathBuf::from("BENCH_x.json"),
        };
        assert_eq!(o.budget(2, 5, 0.3), (1, 2, 0.02));
        o.short = false;
        assert_eq!(o.budget(2, 5, 0.3), (2, 5, 0.3));
    }
}
