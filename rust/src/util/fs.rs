//! Filesystem helpers for result writers: parent-directory creation and
//! atomic (temp-file + rename) writes.
//!
//! Every file the harness and coordinator emit — sweep CSVs, bench
//! telemetry JSON, journals — goes through here, so an interrupted run
//! never leaves a truncated file behind and writing into a not-yet-created
//! output directory just works.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// Create the missing parent directories of `path`, if any.
pub fn create_parent_dirs(path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating directory {}", parent.display()))?;
        }
    }
    Ok(())
}

/// Write `contents` to `path` atomically: the bytes land in a sibling temp
/// file which is then renamed over the target, so readers never observe a
/// half-written file and a mid-write crash leaves any previous content
/// intact.  Parent directories are created as needed.
pub fn write_atomic(path: &Path, contents: &str) -> Result<()> {
    create_parent_dirs(path)?;
    let tmp = tmp_sibling(path);
    std::fs::write(&tmp, contents).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
    Ok(())
}

/// A temp path in the same directory as `path` (rename must not cross a
/// filesystem boundary), unique per process.
fn tmp_sibling(path: &Path) -> PathBuf {
    let name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "out".into());
    path.with_file_name(format!(".{name}.tmp.{}", std::process::id()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("padst_fs_{tag}_{}", std::process::id()))
    }

    #[test]
    fn write_atomic_creates_parents_and_replaces() {
        let dir = scratch("atomic");
        let path = dir.join("a").join("b").join("out.csv");
        write_atomic(&path, "one\n").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "one\n");
        write_atomic(&path, "two\n").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "two\n");
        // No temp droppings left behind.
        let leftovers: Vec<_> = std::fs::read_dir(path.parent().unwrap())
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }
}
