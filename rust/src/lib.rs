//! # PA-DST — Permutation-Augmented Dynamic Structured Sparse Training
//!
//! Rust + JAX + Pallas reproduction of *"Efficient Dynamic Structured
//! Sparse Training with Learned Shuffles"* (Tyagi et al., 2025).
//!
//! Three layers, Python never on the hot path:
//! * **L1** — Pallas kernels (permuted structured-sparse matmuls), authored
//!   and verified in `python/compile/kernels/`.
//! * **L2** — JAX model fwd/bwd + DST updates, AOT-lowered to HLO text.
//! * **L3** — this crate: the training coordinator (DST schedule, per-layer
//!   permutation hardening, metrics), the PJRT runtime that executes the
//!   artifacts, the native CPU sparse kernels — with a scoped-thread
//!   parallel execution layer ([`kernels::parallel`]) — used to reproduce
//!   the paper's inference-speedup results, and the [`harness`] that
//!   shards sweep grids across per-worker runtimes and records bench
//!   telemetry (`BENCH_*.json`) for the CI perf gate.  Trained
//!   checkpoints are served by the [`serve`] layer (`padst serve`): a
//!   long-running node with per-session compiled-plan/scratch caching
//!   and request coalescing over an NDJSON protocol.  The [`obs`] layer
//!   (spans, metric registry, mergeable snapshots, `padst watch`)
//!   instruments all of the above without allocating on hot paths.
//!
//! See `docs/ARCHITECTURE.md` for the full layer stack and the README for
//! the paper-artifact ↔ command map.

// The whole crate is safe Rust; `padst lint` rule L6 checks this stays.
#![forbid(unsafe_code)]
// Numeric-kernel code indexes flat buffers by design; these style lints
// fight that idiom without improving it.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
// The std::simd microkernel backend (kernels::micro::SimdKernel) rides the
// portable-simd nightly feature; stable builds compile without it and the
// Simd backend degrades to Tiled at runtime.
#![cfg_attr(feature = "nightly-simd", feature(portable_simd))]

pub mod analysis;
pub mod tensor;
pub mod util;
pub mod obs;
pub mod runtime;
pub mod sparsity;
pub mod perm;
pub mod nlr;
pub mod kernels;
pub mod data;
pub mod models;
pub mod harness;
pub mod coordinator;
pub mod serve;
