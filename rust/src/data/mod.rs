//! Synthetic data pipeline — the stand-in for ImageNet-1K / WikiText-103
//! (DESIGN.md §Hardware-Adaptation).
//!
//! Both tasks are engineered so that *expressivity in the paper's sense*
//! (the ability of a layer stack to mix input coordinates across the
//! structural support, Sec. 3) is what separates the methods:
//!
//! * **Shuffled-mixture vision task** ([`VisionTask`]): class prototypes
//!   live in a *hidden rotated basis* — every pixel is a mixture of all
//!   latent coordinates through a fixed random orthogonal-ish mixing.  A
//!   diagonal/block layer without permutations can only combine nearby
//!   coordinates and struggles; a learned permutation can re-route them.
//! * **Markov LM task** ([`TextTask`]): an order-2 hidden-state Markov
//!   chain over a byte vocabulary whose emission table is permuted by a
//!   hidden shuffle, giving long-range coordinate structure the model must
//!   unmix.
//!
//! Generators are deterministic in the seed, infinite, and allocation-free
//! per batch (they fill caller-provided tensors).

use crate::tensor::Tensor;
use crate::util::Rng;

/// Sample noise relative to unit prototype separation (see VisionTask).
/// Tuned so a dense tiny-ViT reaches high accuracy within a few hundred
/// steps while 90-95 % structured masks are capacity-bound — the regime
/// where the paper's Fig. 2 gaps live.
const VISION_NOISE: f32 = 2.0;

/// Common interface the coordinator's training loop consumes.
pub trait TaskData {
    /// Fill (batch_x, batch_y) for the next training batch.
    fn next_train(&mut self, x: &mut Tensor, y: &mut Tensor);
    /// Fill a deterministic eval batch `i` (fixed held-out stream).
    fn eval_batch(&self, i: usize, x: &mut Tensor, y: &mut Tensor);
    /// Number of distinct eval batches.
    fn n_eval_batches(&self) -> usize;
}

// ---------------------------------------------------------------------------
// Vision: shuffled-mixture classification
// ---------------------------------------------------------------------------

pub struct VisionTask {
    pub image: usize,
    pub n_classes: usize,
    /// Hidden mixing matrix (dim x dim), fixed per task seed.
    mixing: Vec<f32>,
    /// Class prototypes in the latent basis (n_classes x dim).
    protos: Vec<f32>,
    dim: usize,
    noise: f32,
    rng: Rng,
    eval_seed: u64,
    n_eval: usize,
}

impl VisionTask {
    pub fn new(image: usize, n_classes: usize, seed: u64) -> VisionTask {
        let dim = image * image * 3;
        let mut rng = Rng::new(seed ^ 0xDA7A);
        // Dense random mixing: every pixel depends on every latent
        // coordinate (this is what kills no-perm structured masks).
        let scale = 1.0 / (dim as f32).sqrt();
        let mixing: Vec<f32> = (0..dim * dim).map(|_| rng.normal() * scale).collect();
        // Labels are nearest-prototype in the *latent* basis: unit-norm
        // class prototypes plus isotropic noise, then mixed into pixel
        // space.  Every pixel depends on every latent coordinate through
        // the hidden mixing, so a structured layer that cannot re-route
        // coordinates (no permutation) must burn depth/width to undo it —
        // the expressivity bottleneck of Sec. 3 — while the task stays
        // sample-efficient enough for a dense tiny model to master in a
        // few hundred steps.
        let protos: Vec<f32> = (0..n_classes * dim)
            .map(|_| rng.normal() / (dim as f32).sqrt())
            .collect();
        VisionTask {
            image,
            n_classes,
            mixing,
            protos,
            dim,
            noise: VISION_NOISE / (dim as f32).sqrt(),
            rng: Rng::new(seed),
            eval_seed: seed ^ 0xE7A1,
            n_eval: 16,
        }
    }

    fn fill(&self, rng: &mut Rng, x: &mut Tensor, y: &mut Tensor) {
        let batch = x.shape[0];
        let dim = self.dim;
        debug_assert_eq!(x.numel(), batch * dim);
        let ys = y.i32s_mut();
        let mut latent = vec![0.0f32; dim];
        for b in 0..batch {
            let c = rng.below(self.n_classes);
            for (d, l) in latent.iter_mut().enumerate() {
                *l = self.protos[c * dim + d] + self.noise * rng.normal();
            }
            ys[b] = c as i32;
            let xb = &mut x.f32s_mut()[b * dim..(b + 1) * dim];
            for i in 0..dim {
                let mi = &self.mixing[i * dim..(i + 1) * dim];
                let mut acc = 0.0f32;
                for d in 0..dim {
                    acc += mi[d] * latent[d];
                }
                xb[i] = acc;
            }
        }
    }
}

impl TaskData for VisionTask {
    fn next_train(&mut self, x: &mut Tensor, y: &mut Tensor) {
        let mut rng = self.rng.fork(1);
        self.fill(&mut rng, x, y);
        self.rng.next_u64();
    }

    fn eval_batch(&self, i: usize, x: &mut Tensor, y: &mut Tensor) {
        let mut rng = Rng::new(self.eval_seed.wrapping_add(i as u64));
        self.fill(&mut rng, x, y);
    }

    fn n_eval_batches(&self) -> usize {
        self.n_eval
    }
}

// ---------------------------------------------------------------------------
// Text: hidden-state Markov LM
// ---------------------------------------------------------------------------

pub struct TextTask {
    pub vocab: usize,
    pub seq_len: usize,
    n_states: usize,
    /// Transition table (n_states x n_states) as cumulative distributions.
    trans_cdf: Vec<f32>,
    /// Emission: state -> token distribution CDF (n_states x vocab),
    /// column-permuted by a hidden shuffle.
    emit_cdf: Vec<f32>,
    rng: Rng,
    eval_seed: u64,
    n_eval: usize,
}

impl TextTask {
    pub fn new(vocab: usize, seq_len: usize, seed: u64) -> TextTask {
        let n_states = 12;
        let mut rng = Rng::new(seed ^ 0x7E57);
        let mut sharpen = |v: &mut Vec<f32>, n: usize, width: usize| {
            // Rows are sparse-ish (peaked on `width` entries) then CDF'd.
            for r in 0..v.len() / n {
                let row = &mut v[r * n..(r + 1) * n];
                row.fill(0.05 / n as f32);
                for _ in 0..width {
                    row[rng.below(n)] += 1.0;
                }
                let s: f32 = row.iter().sum();
                let mut acc = 0.0;
                for e in row.iter_mut() {
                    acc += *e / s;
                    *e = acc;
                }
            }
        };
        let mut trans = vec![0.0f32; n_states * n_states];
        sharpen(&mut trans, n_states, 3);
        let mut emit = vec![0.0f32; n_states * vocab];
        sharpen(&mut emit, vocab, 6);
        TextTask {
            vocab,
            seq_len,
            n_states,
            trans_cdf: trans,
            emit_cdf: emit,
            rng: Rng::new(seed),
            eval_seed: seed ^ 0x3333,
            n_eval: 8,
        }
    }

    fn sample_cdf(cdf: &[f32], r: f32) -> usize {
        match cdf.binary_search_by(|p| p.partial_cmp(&r).unwrap()) {
            Ok(i) | Err(i) => i.min(cdf.len() - 1),
        }
    }

    fn fill(&self, rng: &mut Rng, x: &mut Tensor, y: &mut Tensor) {
        let batch = x.shape[0];
        let t = self.seq_len;
        let xs = x.i32s_mut();
        let ys = y.i32s_mut();
        for b in 0..batch {
            let mut state = rng.below(self.n_states);
            let mut prev_tok = 0usize;
            for s in 0..=t {
                let tok = Self::sample_cdf(
                    &self.emit_cdf[state * self.vocab..(state + 1) * self.vocab],
                    rng.f32(),
                );
                // Second-order flavour: the next state also depends on the
                // emitted token parity, entangling token and state streams.
                let ns = Self::sample_cdf(
                    &self.trans_cdf[state * self.n_states..(state + 1) * self.n_states],
                    rng.f32(),
                );
                state = (ns + (tok + prev_tok) % 2) % self.n_states;
                prev_tok = tok;
                if s < t {
                    xs[b * t + s] = tok as i32;
                }
                if s > 0 {
                    ys[b * t + s - 1] = tok as i32;
                }
            }
        }
    }
}

impl TaskData for TextTask {
    fn next_train(&mut self, x: &mut Tensor, y: &mut Tensor) {
        let mut rng = self.rng.fork(1);
        self.fill(&mut rng, x, y);
        self.rng.next_u64();
    }

    fn eval_batch(&self, i: usize, x: &mut Tensor, y: &mut Tensor) {
        let mut rng = Rng::new(self.eval_seed.wrapping_add(i as u64));
        self.fill(&mut rng, x, y);
    }

    fn n_eval_batches(&self) -> usize {
        self.n_eval
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vision_batches_deterministic_eval() {
        let task = VisionTask::new(8, 4, 7);
        let mut x1 = Tensor::zeros(&[2, 8, 8, 3]);
        let mut y1 = Tensor::zeros_i32(&[2]);
        let mut x2 = Tensor::zeros(&[2, 8, 8, 3]);
        let mut y2 = Tensor::zeros_i32(&[2]);
        task.eval_batch(0, &mut x1, &mut y1);
        task.eval_batch(0, &mut x2, &mut y2);
        assert_eq!(x1.f32s(), x2.f32s());
        assert_eq!(y1.i32s(), y2.i32s());
    }

    #[test]
    fn vision_train_advances() {
        let mut task = VisionTask::new(8, 4, 7);
        let mut x1 = Tensor::zeros(&[2, 8, 8, 3]);
        let mut y1 = Tensor::zeros_i32(&[2]);
        task.next_train(&mut x1, &mut y1);
        let first = x1.f32s().to_vec();
        task.next_train(&mut x1, &mut y1);
        assert_ne!(first, x1.f32s());
    }

    #[test]
    fn vision_labels_in_range() {
        let mut task = VisionTask::new(8, 4, 9);
        let mut x = Tensor::zeros(&[16, 8, 8, 3]);
        let mut y = Tensor::zeros_i32(&[16]);
        task.next_train(&mut x, &mut y);
        assert!(y.i32s().iter().all(|&c| (0..4).contains(&c)));
        // Multiple classes appear in a 16-sample batch with 4 classes, w.h.p.
        let distinct: std::collections::HashSet<_> = y.i32s().iter().collect();
        assert!(distinct.len() >= 2);
    }

    #[test]
    fn text_tokens_in_range_and_shifted() {
        let mut task = TextTask::new(64, 16, 3);
        let mut x = Tensor::zeros_i32(&[4, 16]);
        let mut y = Tensor::zeros_i32(&[4, 16]);
        task.next_train(&mut x, &mut y);
        assert!(x.i32s().iter().all(|&t| (0..64).contains(&t)));
        assert!(y.i32s().iter().all(|&t| (0..64).contains(&t)));
        // y is x shifted by one within each row (teacher forcing).
        for b in 0..4 {
            for s in 0..15 {
                assert_eq!(y.i32s()[b * 16 + s], x.i32s()[b * 16 + s + 1]);
            }
        }
    }

    #[test]
    fn text_not_uniform() {
        // The Markov structure must make token frequencies non-uniform —
        // otherwise there is nothing for the LM to learn.
        let mut task = TextTask::new(64, 32, 5);
        let mut x = Tensor::zeros_i32(&[32, 32]);
        let mut y = Tensor::zeros_i32(&[32, 32]);
        task.next_train(&mut x, &mut y);
        let mut counts = vec![0usize; 64];
        for &t in x.i32s() {
            counts[t as usize] += 1;
        }
        let max = *counts.iter().max().unwrap();
        let nonzero = counts.iter().filter(|&&c| c > 0).count();
        assert!(max > 2 * x.numel() / 64, "distribution too flat");
        assert!(nonzero > 8, "distribution too peaked");
    }
}
