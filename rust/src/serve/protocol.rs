//! Wire protocol of `padst serve`: newline-delimited JSON frames parsed
//! with the in-tree [`crate::util::json`] (the build is offline; no serde).
//!
//! One request per line, one response line per request, in request order.
//! Every frame carries the schema version (`"v"`) and a caller-chosen
//! request id (`"id"`); responses echo the id — including error frames,
//! whenever the id survives parsing.  A malformed frame is answered with
//! a structured error frame, never a process exit; only EOF (or a
//! transport I/O error) ends a session.
//!
//! Requests:
//!
//! ```json
//! {"v":1,"op":"infer","id":"r1","site":"fc1","batch":2,"x":[0.5,...],"more":true}
//! {"v":1,"op":"info","id":"r2"}
//! {"v":1,"op":"reload","id":"r3","checkpoint":"run.tnz"}
//! {"v":1,"op":"stats","id":"r4"}
//! ```
//!
//! `"more":true` marks an infer frame as part of a coalescible burst: the
//! node holds it and answers the whole burst after executing it as one
//! batched GEMM (see [`crate::serve::node`]).  Responses mirror the op
//! and add `"ok"`:
//!
//! ```json
//! {"batch":2,"id":"r1","ok":true,"op":"infer","v":1,"y":[...]}
//! {"error":"unknown op \"warp\" ...","id":"r9","ok":false,"op":"error","v":1}
//! ```
//!
//! Activations travel as JSON numbers.  f32 → f64 widening is exact and
//! the serializer emits shortest-round-trip f64, so wire transport
//! preserves f32 value bits (the one flattening: `-0.0` prints as `0`;
//! both sides flatten identically, so batched-vs-singles comparisons stay
//! bitwise).  Pinned by `rust/tests/serve_protocol.rs`.

use anyhow::{anyhow, bail, Result};

use crate::util::json::{self, Json};

/// Wire schema version.  Frames carrying any other `"v"` are rejected
/// with a structured error frame naming both versions.
pub const PROTOCOL_VERSION: u32 = 1;

/// One decoded request frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Run `batch` rows (`x`, row-major, length `batch * cols`) through
    /// `site`'s compiled plan.  `more` marks a coalescible burst.
    Infer { id: String, site: String, batch: usize, x: Vec<f32>, more: bool },
    /// Describe the loaded session: sites, geometry, drivers, generation.
    Info { id: String },
    /// Recompile every plan from a checkpoint (the given path, or the
    /// session's own checkpoint when omitted), evicting cached plans.
    Reload { id: String, checkpoint: Option<String> },
    /// Full health poll: live `ServeStats` counters plus a merged
    /// `obs_schema`-versioned metric snapshot (per-site infer
    /// histograms, frame latency, batch fill, queue depth, ...).
    Stats { id: String },
}

impl Request {
    /// The caller-chosen request id (echoed by the response).
    pub fn id(&self) -> &str {
        match self {
            Request::Infer { id, .. }
            | Request::Info { id }
            | Request::Reload { id, .. }
            | Request::Stats { id } => id,
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            Request::Infer { id, site, batch, x, more } => {
                let mut pairs = vec![
                    ("v", json::num(f64::from(PROTOCOL_VERSION))),
                    ("op", json::s("infer")),
                    ("id", json::s(id)),
                    ("site", json::s(site)),
                    ("batch", json::num(*batch as f64)),
                    ("x", json::arr(x.iter().map(|&v| json::num(f64::from(v))))),
                ];
                if *more {
                    pairs.push(("more", Json::Bool(true)));
                }
                json::obj(pairs)
            }
            Request::Info { id } => json::obj(vec![
                ("v", json::num(f64::from(PROTOCOL_VERSION))),
                ("op", json::s("info")),
                ("id", json::s(id)),
            ]),
            Request::Reload { id, checkpoint } => {
                let mut pairs = vec![
                    ("v", json::num(f64::from(PROTOCOL_VERSION))),
                    ("op", json::s("reload")),
                    ("id", json::s(id)),
                ];
                if let Some(p) = checkpoint {
                    pairs.push(("checkpoint", json::s(p)));
                }
                json::obj(pairs)
            }
            Request::Stats { id } => json::obj(vec![
                ("v", json::num(f64::from(PROTOCOL_VERSION))),
                ("op", json::s("stats")),
                ("id", json::s(id)),
            ]),
        }
    }

    /// Serialise as one NDJSON line (no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().to_string_pretty()
    }

    /// Parse one NDJSON line.
    pub fn parse_line(line: &str) -> Result<Request> {
        let v = Json::parse(line).map_err(|e| anyhow!("bad frame: {e}"))?;
        Request::from_json(&v)
    }

    /// Decode an already-parsed frame.  Error messages are descriptive
    /// and safe to echo back verbatim in an error frame.
    pub fn from_json(v: &Json) -> Result<Request> {
        check_version(v)?;
        let op = v
            .get("op")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("frame has no \"op\" string"))?;
        let id = str_field(v, "id")?;
        match op {
            "infer" => {
                let site = str_field(v, "site")?;
                let batch = num_field(v, "batch")? as usize;
                let x = f32_array(v, "x")?;
                let more = matches!(v.get("more"), Some(Json::Bool(true)));
                Ok(Request::Infer { id, site, batch, x, more })
            }
            "info" => Ok(Request::Info { id }),
            "reload" => {
                let checkpoint = v.get("checkpoint").and_then(Json::as_str).map(str::to_string);
                Ok(Request::Reload { id, checkpoint })
            }
            "stats" => Ok(Request::Stats { id }),
            other => bail!("unknown op {other:?} (known: infer|info|reload|stats)"),
        }
    }
}

/// Per-site description inside an info response.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SiteInfo {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    /// Kernel driver of the compiled plan: gather | block | csr | dense.
    pub driver: String,
    /// Whether a hard permutation is folded into the plan's index stream.
    pub permuted: bool,
}

impl SiteInfo {
    fn to_json(&self) -> Json {
        json::obj(vec![
            ("name", json::s(&self.name)),
            ("rows", json::num(self.rows as f64)),
            ("cols", json::num(self.cols as f64)),
            ("nnz", json::num(self.nnz as f64)),
            ("driver", json::s(&self.driver)),
            ("permuted", Json::Bool(self.permuted)),
        ])
    }

    fn from_json(v: &Json) -> Result<SiteInfo> {
        Ok(SiteInfo {
            name: str_field(v, "name")?,
            rows: num_field(v, "rows")? as usize,
            cols: num_field(v, "cols")? as usize,
            nnz: num_field(v, "nnz")? as usize,
            driver: str_field(v, "driver")?,
            permuted: matches!(v.get("permuted"), Some(Json::Bool(true))),
        })
    }
}

/// Live session counters on the wire — the serve loop's `ServeStats`
/// as carried by `info` and `stats` responses.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeWireStats {
    pub requests: usize,
    pub responses: usize,
    pub errors: usize,
    pub batches: usize,
    pub widest_batch: usize,
}

impl ServeWireStats {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("requests", json::num(self.requests as f64)),
            ("responses", json::num(self.responses as f64)),
            ("errors", json::num(self.errors as f64)),
            ("batches", json::num(self.batches as f64)),
            ("widest_batch", json::num(self.widest_batch as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ServeWireStats> {
        Ok(ServeWireStats {
            requests: num_field(v, "requests")? as usize,
            responses: num_field(v, "responses")? as usize,
            errors: num_field(v, "errors")? as usize,
            batches: num_field(v, "batches")? as usize,
            widest_batch: num_field(v, "widest_batch")? as usize,
        })
    }
}

/// One response frame; `Error` is the only `"ok":false` variant.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Infer { id: String, batch: usize, y: Vec<f32> },
    Info {
        id: String,
        model: String,
        generation: u64,
        sites: Vec<SiteInfo>,
        /// Live counters (always sent by this node; `None` only when
        /// decoding a pre-stats peer's frame).
        stats: Option<ServeWireStats>,
    },
    Reloaded { id: String, generation: u64 },
    /// Health poll: counters plus the merged metric snapshot as raw
    /// JSON (schema-versioned via its own `obs_schema` field).
    Stats { id: String, stats: ServeWireStats, obs: Json },
    /// `id` is `None` only when the offending frame was not parseable
    /// enough to recover one.
    Error { id: Option<String>, error: String },
}

impl Response {
    pub fn to_json(&self) -> Json {
        match self {
            Response::Infer { id, batch, y } => json::obj(vec![
                ("v", json::num(f64::from(PROTOCOL_VERSION))),
                ("op", json::s("infer")),
                ("ok", Json::Bool(true)),
                ("id", json::s(id)),
                ("batch", json::num(*batch as f64)),
                ("y", json::arr(y.iter().map(|&v| json::num(f64::from(v))))),
            ]),
            Response::Info { id, model, generation, sites, stats } => {
                let mut pairs = vec![
                    ("v", json::num(f64::from(PROTOCOL_VERSION))),
                    ("op", json::s("info")),
                    ("ok", Json::Bool(true)),
                    ("id", json::s(id)),
                    ("model", json::s(model)),
                    ("generation", json::num(*generation as f64)),
                    ("sites", json::arr(sites.iter().map(|s| s.to_json()))),
                ];
                if let Some(s) = stats {
                    pairs.push(("stats", s.to_json()));
                }
                json::obj(pairs)
            }
            Response::Reloaded { id, generation } => json::obj(vec![
                ("v", json::num(f64::from(PROTOCOL_VERSION))),
                ("op", json::s("reload")),
                ("ok", Json::Bool(true)),
                ("id", json::s(id)),
                ("generation", json::num(*generation as f64)),
            ]),
            Response::Stats { id, stats, obs } => json::obj(vec![
                ("v", json::num(f64::from(PROTOCOL_VERSION))),
                ("op", json::s("stats")),
                ("ok", Json::Bool(true)),
                ("id", json::s(id)),
                ("stats", stats.to_json()),
                ("obs", obs.clone()),
            ]),
            Response::Error { id, error } => json::obj(vec![
                ("v", json::num(f64::from(PROTOCOL_VERSION))),
                ("op", json::s("error")),
                ("ok", Json::Bool(false)),
                ("id", id.as_deref().map_or(Json::Null, json::s)),
                ("error", json::s(error)),
            ]),
        }
    }

    /// Serialise as one NDJSON line (no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().to_string_pretty()
    }

    /// Parse one NDJSON line (the client-side decoder; also what the
    /// round-trip tests drive).
    pub fn parse_line(line: &str) -> Result<Response> {
        let v = Json::parse(line).map_err(|e| anyhow!("bad frame: {e}"))?;
        Response::from_json(&v)
    }

    pub fn from_json(v: &Json) -> Result<Response> {
        check_version(v)?;
        if !matches!(v.get("ok"), Some(Json::Bool(true))) {
            let id = v.get("id").and_then(Json::as_str).map(str::to_string);
            return Ok(Response::Error { id, error: str_field(v, "error")? });
        }
        let id = str_field(v, "id")?;
        match v.get("op").and_then(Json::as_str) {
            Some("infer") => Ok(Response::Infer {
                id,
                batch: num_field(v, "batch")? as usize,
                y: f32_array(v, "y")?,
            }),
            Some("info") => {
                let sites = v
                    .get("sites")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("info response has no \"sites\" array"))?
                    .iter()
                    .map(SiteInfo::from_json)
                    .collect::<Result<Vec<_>>>()?;
                let stats = match v.get("stats") {
                    Some(s) => Some(ServeWireStats::from_json(s)?),
                    None => None,
                };
                Ok(Response::Info {
                    id,
                    model: str_field(v, "model")?,
                    generation: num_field(v, "generation")? as u64,
                    sites,
                    stats,
                })
            }
            Some("reload") => {
                Ok(Response::Reloaded { id, generation: num_field(v, "generation")? as u64 })
            }
            Some("stats") => {
                let stats = v
                    .get("stats")
                    .ok_or_else(|| anyhow!("stats response has no \"stats\" object"))?;
                Ok(Response::Stats {
                    id,
                    stats: ServeWireStats::from_json(stats)?,
                    obs: v.get("obs").cloned().unwrap_or(Json::Null),
                })
            }
            other => bail!("unknown response op {other:?}"),
        }
    }
}

fn check_version(v: &Json) -> Result<()> {
    match v.get("v").and_then(Json::as_f64) {
        Some(n) if n == f64::from(PROTOCOL_VERSION) => Ok(()),
        Some(n) => {
            bail!("unsupported protocol version {n} (this node speaks v{PROTOCOL_VERSION})")
        }
        None => {
            bail!("frame has no \"v\" protocol version (this node speaks v{PROTOCOL_VERSION})")
        }
    }
}

fn str_field(v: &Json, key: &str) -> Result<String> {
    v.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| anyhow!("frame has no {key:?} string"))
}

fn num_field(v: &Json, key: &str) -> Result<f64> {
    v.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("frame has no {key:?} number"))
}

fn f32_array(v: &Json, key: &str) -> Result<Vec<f32>> {
    v.get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("frame has no {key:?} array"))?
        .iter()
        .map(|e| e.as_f64().map(|f| f as f32))
        .collect::<Option<Vec<f32>>>()
        .ok_or_else(|| anyhow!("{key:?} has a non-numeric element"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn infer_wire_layout_is_stable() {
        // Key order is the BTreeMap's alphabetical order — the CI golden
        // transcript (`ci/golden/serve_smoke.out`) depends on it.
        let r = Response::Infer { id: "a".into(), batch: 1, y: vec![4.0, 4.0] };
        assert_eq!(r.to_line(), r#"{"batch":1,"id":"a","ok":true,"op":"infer","v":1,"y":[4,4]}"#);
        let e = Response::Error { id: None, error: "bad frame: unexpected end of JSON".into() };
        assert_eq!(
            e.to_line(),
            r#"{"error":"bad frame: unexpected end of JSON","id":null,"ok":false,"op":"error","v":1}"#
        );
    }

    #[test]
    fn stats_wire_layout_is_stable() {
        // The serve-smoke golden carries a stats frame; its key order
        // (alphabetical, nested objects included) is pinned here.
        let r = Response::Stats {
            id: "s".into(),
            stats: ServeWireStats {
                requests: 5,
                responses: 4,
                errors: 1,
                batches: 2,
                widest_batch: 2,
            },
            obs: Json::Null,
        };
        assert_eq!(
            r.to_line(),
            r#"{"id":"s","obs":null,"ok":true,"op":"stats","stats":{"batches":2,"errors":1,"requests":5,"responses":4,"widest_batch":2},"v":1}"#
        );
    }

    #[test]
    fn version_gate_runs_before_op_dispatch() {
        let line = r#"{"v":2,"op":"infer","id":"x","site":"fc","batch":1,"x":[1]}"#;
        let err = Request::parse_line(line).unwrap_err().to_string();
        assert!(err.contains("unsupported protocol version 2"), "{err}");
    }
}
