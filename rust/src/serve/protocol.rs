//! Wire protocol of `padst serve`: newline-delimited JSON frames parsed
//! with the in-tree [`crate::util::json`] (the build is offline; no serde).
//!
//! One request per line, one response line per request, in request order.
//! Every frame carries the schema version (`"v"`) and a caller-chosen
//! request id (`"id"`); responses echo the id — including error frames,
//! whenever the id survives parsing.  A malformed frame is answered with
//! a structured error frame, never a process exit; only EOF (or a
//! transport I/O error) ends a session.
//!
//! Requests:
//!
//! ```json
//! {"v":1,"op":"infer","id":"r1","site":"fc1","batch":2,"x":[0.5,...],"more":true}
//! {"v":1,"op":"info","id":"r2"}
//! {"v":1,"op":"reload","id":"r3","checkpoint":"run.tnz"}
//! ```
//!
//! `"more":true` marks an infer frame as part of a coalescible burst: the
//! node holds it and answers the whole burst after executing it as one
//! batched GEMM (see [`crate::serve::node`]).  Responses mirror the op
//! and add `"ok"`:
//!
//! ```json
//! {"batch":2,"id":"r1","ok":true,"op":"infer","v":1,"y":[...]}
//! {"error":"unknown op \"warp\" ...","id":"r9","ok":false,"op":"error","v":1}
//! ```
//!
//! Activations travel as JSON numbers.  f32 → f64 widening is exact and
//! the serializer emits shortest-round-trip f64, so wire transport
//! preserves f32 value bits (the one flattening: `-0.0` prints as `0`;
//! both sides flatten identically, so batched-vs-singles comparisons stay
//! bitwise).  Pinned by `rust/tests/serve_protocol.rs`.

use anyhow::{anyhow, bail, Result};

use crate::util::json::{self, Json};

/// Wire schema version.  Frames carrying any other `"v"` are rejected
/// with a structured error frame naming both versions.
pub const PROTOCOL_VERSION: u32 = 1;

/// One decoded request frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Run `batch` rows (`x`, row-major, length `batch * cols`) through
    /// `site`'s compiled plan.  `more` marks a coalescible burst.
    Infer { id: String, site: String, batch: usize, x: Vec<f32>, more: bool },
    /// Describe the loaded session: sites, geometry, drivers, generation.
    Info { id: String },
    /// Recompile every plan from a checkpoint (the given path, or the
    /// session's own checkpoint when omitted), evicting cached plans.
    Reload { id: String, checkpoint: Option<String> },
}

impl Request {
    /// The caller-chosen request id (echoed by the response).
    pub fn id(&self) -> &str {
        match self {
            Request::Infer { id, .. } | Request::Info { id } | Request::Reload { id, .. } => id,
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            Request::Infer { id, site, batch, x, more } => {
                let mut pairs = vec![
                    ("v", json::num(f64::from(PROTOCOL_VERSION))),
                    ("op", json::s("infer")),
                    ("id", json::s(id)),
                    ("site", json::s(site)),
                    ("batch", json::num(*batch as f64)),
                    ("x", json::arr(x.iter().map(|&v| json::num(f64::from(v))))),
                ];
                if *more {
                    pairs.push(("more", Json::Bool(true)));
                }
                json::obj(pairs)
            }
            Request::Info { id } => json::obj(vec![
                ("v", json::num(f64::from(PROTOCOL_VERSION))),
                ("op", json::s("info")),
                ("id", json::s(id)),
            ]),
            Request::Reload { id, checkpoint } => {
                let mut pairs = vec![
                    ("v", json::num(f64::from(PROTOCOL_VERSION))),
                    ("op", json::s("reload")),
                    ("id", json::s(id)),
                ];
                if let Some(p) = checkpoint {
                    pairs.push(("checkpoint", json::s(p)));
                }
                json::obj(pairs)
            }
        }
    }

    /// Serialise as one NDJSON line (no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().to_string_pretty()
    }

    /// Parse one NDJSON line.
    pub fn parse_line(line: &str) -> Result<Request> {
        let v = Json::parse(line).map_err(|e| anyhow!("bad frame: {e}"))?;
        Request::from_json(&v)
    }

    /// Decode an already-parsed frame.  Error messages are descriptive
    /// and safe to echo back verbatim in an error frame.
    pub fn from_json(v: &Json) -> Result<Request> {
        check_version(v)?;
        let op = v
            .get("op")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("frame has no \"op\" string"))?;
        let id = str_field(v, "id")?;
        match op {
            "infer" => {
                let site = str_field(v, "site")?;
                let batch = num_field(v, "batch")? as usize;
                let x = f32_array(v, "x")?;
                let more = matches!(v.get("more"), Some(Json::Bool(true)));
                Ok(Request::Infer { id, site, batch, x, more })
            }
            "info" => Ok(Request::Info { id }),
            "reload" => {
                let checkpoint = v.get("checkpoint").and_then(Json::as_str).map(str::to_string);
                Ok(Request::Reload { id, checkpoint })
            }
            other => bail!("unknown op {other:?} (known: infer|info|reload)"),
        }
    }
}

/// Per-site description inside an info response.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SiteInfo {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    /// Kernel driver of the compiled plan: gather | block | csr | dense.
    pub driver: String,
    /// Whether a hard permutation is folded into the plan's index stream.
    pub permuted: bool,
}

impl SiteInfo {
    fn to_json(&self) -> Json {
        json::obj(vec![
            ("name", json::s(&self.name)),
            ("rows", json::num(self.rows as f64)),
            ("cols", json::num(self.cols as f64)),
            ("nnz", json::num(self.nnz as f64)),
            ("driver", json::s(&self.driver)),
            ("permuted", Json::Bool(self.permuted)),
        ])
    }

    fn from_json(v: &Json) -> Result<SiteInfo> {
        Ok(SiteInfo {
            name: str_field(v, "name")?,
            rows: num_field(v, "rows")? as usize,
            cols: num_field(v, "cols")? as usize,
            nnz: num_field(v, "nnz")? as usize,
            driver: str_field(v, "driver")?,
            permuted: matches!(v.get("permuted"), Some(Json::Bool(true))),
        })
    }
}

/// One response frame; `Error` is the only `"ok":false` variant.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Infer { id: String, batch: usize, y: Vec<f32> },
    Info { id: String, model: String, generation: u64, sites: Vec<SiteInfo> },
    Reloaded { id: String, generation: u64 },
    /// `id` is `None` only when the offending frame was not parseable
    /// enough to recover one.
    Error { id: Option<String>, error: String },
}

impl Response {
    pub fn to_json(&self) -> Json {
        match self {
            Response::Infer { id, batch, y } => json::obj(vec![
                ("v", json::num(f64::from(PROTOCOL_VERSION))),
                ("op", json::s("infer")),
                ("ok", Json::Bool(true)),
                ("id", json::s(id)),
                ("batch", json::num(*batch as f64)),
                ("y", json::arr(y.iter().map(|&v| json::num(f64::from(v))))),
            ]),
            Response::Info { id, model, generation, sites } => json::obj(vec![
                ("v", json::num(f64::from(PROTOCOL_VERSION))),
                ("op", json::s("info")),
                ("ok", Json::Bool(true)),
                ("id", json::s(id)),
                ("model", json::s(model)),
                ("generation", json::num(*generation as f64)),
                ("sites", json::arr(sites.iter().map(|s| s.to_json()))),
            ]),
            Response::Reloaded { id, generation } => json::obj(vec![
                ("v", json::num(f64::from(PROTOCOL_VERSION))),
                ("op", json::s("reload")),
                ("ok", Json::Bool(true)),
                ("id", json::s(id)),
                ("generation", json::num(*generation as f64)),
            ]),
            Response::Error { id, error } => json::obj(vec![
                ("v", json::num(f64::from(PROTOCOL_VERSION))),
                ("op", json::s("error")),
                ("ok", Json::Bool(false)),
                ("id", id.as_deref().map_or(Json::Null, json::s)),
                ("error", json::s(error)),
            ]),
        }
    }

    /// Serialise as one NDJSON line (no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().to_string_pretty()
    }

    /// Parse one NDJSON line (the client-side decoder; also what the
    /// round-trip tests drive).
    pub fn parse_line(line: &str) -> Result<Response> {
        let v = Json::parse(line).map_err(|e| anyhow!("bad frame: {e}"))?;
        Response::from_json(&v)
    }

    pub fn from_json(v: &Json) -> Result<Response> {
        check_version(v)?;
        if !matches!(v.get("ok"), Some(Json::Bool(true))) {
            let id = v.get("id").and_then(Json::as_str).map(str::to_string);
            return Ok(Response::Error { id, error: str_field(v, "error")? });
        }
        let id = str_field(v, "id")?;
        match v.get("op").and_then(Json::as_str) {
            Some("infer") => Ok(Response::Infer {
                id,
                batch: num_field(v, "batch")? as usize,
                y: f32_array(v, "y")?,
            }),
            Some("info") => {
                let sites = v
                    .get("sites")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("info response has no \"sites\" array"))?
                    .iter()
                    .map(SiteInfo::from_json)
                    .collect::<Result<Vec<_>>>()?;
                Ok(Response::Info {
                    id,
                    model: str_field(v, "model")?,
                    generation: num_field(v, "generation")? as u64,
                    sites,
                })
            }
            Some("reload") => {
                Ok(Response::Reloaded { id, generation: num_field(v, "generation")? as u64 })
            }
            other => bail!("unknown response op {other:?}"),
        }
    }
}

fn check_version(v: &Json) -> Result<()> {
    match v.get("v").and_then(Json::as_f64) {
        Some(n) if n == f64::from(PROTOCOL_VERSION) => Ok(()),
        Some(n) => {
            bail!("unsupported protocol version {n} (this node speaks v{PROTOCOL_VERSION})")
        }
        None => {
            bail!("frame has no \"v\" protocol version (this node speaks v{PROTOCOL_VERSION})")
        }
    }
}

fn str_field(v: &Json, key: &str) -> Result<String> {
    v.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| anyhow!("frame has no {key:?} string"))
}

fn num_field(v: &Json, key: &str) -> Result<f64> {
    v.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("frame has no {key:?} number"))
}

fn f32_array(v: &Json, key: &str) -> Result<Vec<f32>> {
    v.get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("frame has no {key:?} array"))?
        .iter()
        .map(|e| e.as_f64().map(|f| f as f32))
        .collect::<Option<Vec<f32>>>()
        .ok_or_else(|| anyhow!("{key:?} has a non-numeric element"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn infer_wire_layout_is_stable() {
        // Key order is the BTreeMap's alphabetical order — the CI golden
        // transcript (`ci/golden/serve_smoke.out`) depends on it.
        let r = Response::Infer { id: "a".into(), batch: 1, y: vec![4.0, 4.0] };
        assert_eq!(r.to_line(), r#"{"batch":1,"id":"a","ok":true,"op":"infer","v":1,"y":[4,4]}"#);
        let e = Response::Error { id: None, error: "bad frame: unexpected end of JSON".into() };
        assert_eq!(
            e.to_line(),
            r#"{"error":"bad frame: unexpected end of JSON","id":null,"ok":false,"op":"error","v":1}"#
        );
    }

    #[test]
    fn version_gate_runs_before_op_dispatch() {
        let line = r#"{"v":2,"op":"infer","id":"x","site":"fc","batch":1,"x":[1]}"#;
        let err = Request::parse_line(line).unwrap_err().to_string();
        assert!(err.contains("unsupported protocol version 2"), "{err}");
    }
}
