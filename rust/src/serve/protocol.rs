//! Wire protocol of `padst serve`: newline-delimited JSON control frames
//! parsed with the in-tree [`crate::util::json`] (the build is offline;
//! no serde), plus — since protocol v2 — a length-prefixed **binary
//! activation frame** for bulk f32 payloads.
//!
//! One request per frame, one response per request, in request order.
//! Every text frame carries the schema version (`"v"`) and a
//! caller-chosen request id (`"id"`); responses echo the id — including
//! error frames, whenever the id survives parsing.  A malformed frame is
//! answered with a structured error frame, never a process exit; only
//! EOF (or a transport I/O error) ends a session.
//!
//! Requests (the node accepts v1 frames unchanged; it emits v2):
//!
//! ```json
//! {"v":2,"op":"infer","id":"r1","site":"fc1","batch":2,"x":[0.5,...],"more":true}
//! {"v":2,"op":"info","id":"r2"}
//! {"v":2,"op":"reload","id":"r3","checkpoint":"run.tnz"}
//! {"v":2,"op":"stats","id":"r4"}
//! {"v":2,"op":"hello","id":"r5","wire":"binary"}
//! ```
//!
//! `"more":true` marks an infer frame as part of a coalescible burst: the
//! node holds it and answers the whole burst after executing it as one
//! batched GEMM (see [`crate::serve::node`]).  Responses mirror the op
//! and add `"ok"`:
//!
//! ```json
//! {"batch":2,"id":"r1","ok":true,"op":"infer","v":2,"y":[...]}
//! {"error":"unknown op \"warp\" ...","id":"r9","ok":false,"op":"error","v":2}
//! ```
//!
//! # Wire formats
//!
//! Text activations travel as JSON numbers.  f32 → f64 widening is exact
//! and the serializer emits shortest-round-trip f64, so text transport
//! preserves f32 value bits (the one flattening: `-0.0` prints as `0`;
//! both sides flatten identically, so batched-vs-singles comparisons stay
//! bitwise) — at ~13 bytes per value.  The v2 binary activation frame
//! ([`encode_binary_infer`], [`decode_binary_body`]) carries the same
//! payload as raw little-endian f32 at ~4 bytes per value, `to_bits`
//! exact by construction.  A client discovers the formats with a `hello`
//! handshake frame and switches by simply sending binary frames — the
//! node tells them apart per frame by the first byte ([`read_frame`]):
//! [`BINARY_MAGIC`] starts with `0xBF`, a UTF-8 continuation byte that
//! can never begin a text line.  Pinned by `rust/tests/serve_protocol.rs`.

use anyhow::{anyhow, bail, Result};

use crate::util::json::{self, Json};

/// Wire schema version this node speaks (and stamps on every response).
/// Frames carrying any `"v"` outside
/// [`MIN_PROTOCOL_VERSION`]`..=`[`PROTOCOL_VERSION`] are rejected with a
/// structured error frame naming the supported range.
pub const PROTOCOL_VERSION: u32 = 2;

/// Oldest request version still accepted: v1 text frames decode
/// unchanged, so pre-binary clients keep working against a v2 node.
pub const MIN_PROTOCOL_VERSION: u32 = 1;

/// Wire-format name of the newline-delimited JSON frames (the default).
pub const WIRE_NDJSON: &str = "ndjson";

/// Wire-format name of the length-prefixed binary activation frames.
pub const WIRE_BINARY: &str = "binary";

/// Formats a `hello` response advertises, preference order.
pub const SUPPORTED_WIRES: [&str; 2] = [WIRE_NDJSON, WIRE_BINARY];

/// Leading magic of a binary frame.  The first byte (`0xBF`) is a UTF-8
/// continuation byte, so it can never start a text line — the per-frame
/// format detector in [`read_frame`] keys on it.  The last byte encodes
/// the protocol major version that introduced the layout (`b'2'`,
/// tied to [`PROTOCOL_VERSION`] by unit test).
pub const BINARY_MAGIC: [u8; 4] = [0xBF, b'P', b'A', b'2'];

/// Sanity cap on a binary frame body.  A length prefix beyond this is
/// answered with an error frame and the connection is closed (the
/// stream cannot be re-synchronised past an untrusted length).
pub const MAX_BINARY_BODY: usize = 1 << 30;

/// Binary frame body kind: an infer request (id, site, batch, x, more).
pub const BIN_INFER_REQUEST: u8 = 1;

/// Binary frame body kind: an infer response (id, batch, y).
pub const BIN_INFER_RESPONSE: u8 = 2;

/// One decoded request frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Run `batch` rows (`x`, row-major, length `batch * cols`) through
    /// `site`'s compiled plan.  `more` marks a coalescible burst.
    Infer { id: String, site: String, batch: usize, x: Vec<f32>, more: bool },
    /// Describe the loaded session: sites, geometry, drivers, generation.
    Info { id: String },
    /// Recompile every plan from a checkpoint (the given path, or the
    /// session's own checkpoint when omitted), evicting cached plans.
    Reload { id: String, checkpoint: Option<String> },
    /// Full health poll: live `ServeStats` counters plus a merged
    /// `obs_schema`-versioned metric snapshot (per-site infer
    /// histograms, frame latency, batch fill, queue depth, ...).
    Stats { id: String },
    /// Wire-format handshake (v2): the node answers with its protocol
    /// version and supported formats; `wire` (optional) asks it to emit
    /// infer responses in that format from here on.  Binary *requests*
    /// need no handshake — they are self-describing per frame.
    Hello { id: String, wire: Option<String> },
}

impl Request {
    /// The caller-chosen request id (echoed by the response).
    pub fn id(&self) -> &str {
        match self {
            Request::Infer { id, .. }
            | Request::Info { id }
            | Request::Reload { id, .. }
            | Request::Stats { id }
            | Request::Hello { id, .. } => id,
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            Request::Infer { id, site, batch, x, more } => {
                let mut pairs = vec![
                    ("v", json::num(f64::from(PROTOCOL_VERSION))),
                    ("op", json::s("infer")),
                    ("id", json::s(id)),
                    ("site", json::s(site)),
                    ("batch", json::num(*batch as f64)),
                    ("x", json::arr(x.iter().map(|&v| json::num(f64::from(v))))),
                ];
                if *more {
                    pairs.push(("more", Json::Bool(true)));
                }
                json::obj(pairs)
            }
            Request::Info { id } => json::obj(vec![
                ("v", json::num(f64::from(PROTOCOL_VERSION))),
                ("op", json::s("info")),
                ("id", json::s(id)),
            ]),
            Request::Reload { id, checkpoint } => {
                let mut pairs = vec![
                    ("v", json::num(f64::from(PROTOCOL_VERSION))),
                    ("op", json::s("reload")),
                    ("id", json::s(id)),
                ];
                if let Some(p) = checkpoint {
                    pairs.push(("checkpoint", json::s(p)));
                }
                json::obj(pairs)
            }
            Request::Stats { id } => json::obj(vec![
                ("v", json::num(f64::from(PROTOCOL_VERSION))),
                ("op", json::s("stats")),
                ("id", json::s(id)),
            ]),
            Request::Hello { id, wire } => {
                let mut pairs = vec![
                    ("v", json::num(f64::from(PROTOCOL_VERSION))),
                    ("op", json::s("hello")),
                    ("id", json::s(id)),
                ];
                if let Some(w) = wire {
                    pairs.push(("wire", json::s(w)));
                }
                json::obj(pairs)
            }
        }
    }

    /// Serialise as one NDJSON line (no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().to_string_pretty()
    }

    /// Parse one NDJSON line.
    pub fn parse_line(line: &str) -> Result<Request> {
        let v = Json::parse(line).map_err(|e| anyhow!("bad frame: {e}"))?;
        Request::from_json(&v)
    }

    /// Decode an already-parsed frame.  Error messages are descriptive
    /// and safe to echo back verbatim in an error frame.
    pub fn from_json(v: &Json) -> Result<Request> {
        check_version(v)?;
        let op = v
            .get("op")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("frame has no \"op\" string"))?;
        let id = str_field(v, "id")?;
        match op {
            "infer" => {
                let site = str_field(v, "site")?;
                let batch = num_field(v, "batch")? as usize;
                let x = f32_array(v, "x")?;
                let more = matches!(v.get("more"), Some(Json::Bool(true)));
                Ok(Request::Infer { id, site, batch, x, more })
            }
            "info" => Ok(Request::Info { id }),
            "reload" => {
                let checkpoint = v.get("checkpoint").and_then(Json::as_str).map(str::to_string);
                Ok(Request::Reload { id, checkpoint })
            }
            "stats" => Ok(Request::Stats { id }),
            "hello" => {
                let wire = v.get("wire").and_then(Json::as_str).map(str::to_string);
                Ok(Request::Hello { id, wire })
            }
            other => bail!("unknown op {other:?} (known: infer|info|reload|stats|hello)"),
        }
    }
}

/// Per-site description inside an info response.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SiteInfo {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    /// Kernel driver of the compiled plan: gather | block | csr | dense.
    pub driver: String,
    /// Whether a hard permutation is folded into the plan's index stream.
    pub permuted: bool,
}

impl SiteInfo {
    fn to_json(&self) -> Json {
        json::obj(vec![
            ("name", json::s(&self.name)),
            ("rows", json::num(self.rows as f64)),
            ("cols", json::num(self.cols as f64)),
            ("nnz", json::num(self.nnz as f64)),
            ("driver", json::s(&self.driver)),
            ("permuted", Json::Bool(self.permuted)),
        ])
    }

    fn from_json(v: &Json) -> Result<SiteInfo> {
        Ok(SiteInfo {
            name: str_field(v, "name")?,
            rows: num_field(v, "rows")? as usize,
            cols: num_field(v, "cols")? as usize,
            nnz: num_field(v, "nnz")? as usize,
            driver: str_field(v, "driver")?,
            permuted: matches!(v.get("permuted"), Some(Json::Bool(true))),
        })
    }
}

/// Live session counters on the wire — the serve loop's `ServeStats`
/// as carried by `info` and `stats` responses.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeWireStats {
    pub requests: usize,
    pub responses: usize,
    pub errors: usize,
    pub batches: usize,
    pub widest_batch: usize,
}

impl ServeWireStats {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("requests", json::num(self.requests as f64)),
            ("responses", json::num(self.responses as f64)),
            ("errors", json::num(self.errors as f64)),
            ("batches", json::num(self.batches as f64)),
            ("widest_batch", json::num(self.widest_batch as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ServeWireStats> {
        Ok(ServeWireStats {
            requests: num_field(v, "requests")? as usize,
            responses: num_field(v, "responses")? as usize,
            errors: num_field(v, "errors")? as usize,
            batches: num_field(v, "batches")? as usize,
            widest_batch: num_field(v, "widest_batch")? as usize,
        })
    }
}

/// One response frame; `Error` is the only `"ok":false` variant.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Infer { id: String, batch: usize, y: Vec<f32> },
    Info {
        id: String,
        model: String,
        generation: u64,
        sites: Vec<SiteInfo>,
        /// Live counters (always sent by this node; `None` only when
        /// decoding a pre-stats peer's frame).
        stats: Option<ServeWireStats>,
    },
    Reloaded { id: String, generation: u64 },
    /// Health poll: counters plus the merged metric snapshot as raw
    /// JSON (schema-versioned via its own `obs_schema` field).
    Stats { id: String, stats: ServeWireStats, obs: Json },
    /// Handshake ack: the node's protocol version and the wire format
    /// it will use for this connection's infer responses (the response
    /// also advertises every supported format under `"formats"`).
    Hello { id: String, proto: u32, wire: String },
    /// `id` is `None` only when the offending frame was not parseable
    /// enough to recover one.
    Error { id: Option<String>, error: String },
}

impl Response {
    pub fn to_json(&self) -> Json {
        match self {
            Response::Infer { id, batch, y } => json::obj(vec![
                ("v", json::num(f64::from(PROTOCOL_VERSION))),
                ("op", json::s("infer")),
                ("ok", Json::Bool(true)),
                ("id", json::s(id)),
                ("batch", json::num(*batch as f64)),
                ("y", json::arr(y.iter().map(|&v| json::num(f64::from(v))))),
            ]),
            Response::Info { id, model, generation, sites, stats } => {
                let mut pairs = vec![
                    ("v", json::num(f64::from(PROTOCOL_VERSION))),
                    ("op", json::s("info")),
                    ("ok", Json::Bool(true)),
                    ("id", json::s(id)),
                    ("model", json::s(model)),
                    ("generation", json::num(*generation as f64)),
                    ("sites", json::arr(sites.iter().map(|s| s.to_json()))),
                ];
                if let Some(s) = stats {
                    pairs.push(("stats", s.to_json()));
                }
                json::obj(pairs)
            }
            Response::Reloaded { id, generation } => json::obj(vec![
                ("v", json::num(f64::from(PROTOCOL_VERSION))),
                ("op", json::s("reload")),
                ("ok", Json::Bool(true)),
                ("id", json::s(id)),
                ("generation", json::num(*generation as f64)),
            ]),
            Response::Stats { id, stats, obs } => json::obj(vec![
                ("v", json::num(f64::from(PROTOCOL_VERSION))),
                ("op", json::s("stats")),
                ("ok", Json::Bool(true)),
                ("id", json::s(id)),
                ("stats", stats.to_json()),
                ("obs", obs.clone()),
            ]),
            Response::Hello { id, proto, wire } => json::obj(vec![
                ("v", json::num(f64::from(PROTOCOL_VERSION))),
                ("op", json::s("hello")),
                ("ok", Json::Bool(true)),
                ("id", json::s(id)),
                ("proto", json::num(f64::from(*proto))),
                ("wire", json::s(wire)),
                ("formats", json::arr(SUPPORTED_WIRES.iter().map(|w| json::s(w)))),
            ]),
            Response::Error { id, error } => json::obj(vec![
                ("v", json::num(f64::from(PROTOCOL_VERSION))),
                ("op", json::s("error")),
                ("ok", Json::Bool(false)),
                ("id", id.as_deref().map_or(Json::Null, json::s)),
                ("error", json::s(error)),
            ]),
        }
    }

    /// Serialise as one NDJSON line (no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().to_string_pretty()
    }

    /// Parse one NDJSON line (the client-side decoder; also what the
    /// round-trip tests drive).
    pub fn parse_line(line: &str) -> Result<Response> {
        let v = Json::parse(line).map_err(|e| anyhow!("bad frame: {e}"))?;
        Response::from_json(&v)
    }

    pub fn from_json(v: &Json) -> Result<Response> {
        check_version(v)?;
        if !matches!(v.get("ok"), Some(Json::Bool(true))) {
            let id = v.get("id").and_then(Json::as_str).map(str::to_string);
            return Ok(Response::Error { id, error: str_field(v, "error")? });
        }
        let id = str_field(v, "id")?;
        match v.get("op").and_then(Json::as_str) {
            Some("infer") => Ok(Response::Infer {
                id,
                batch: num_field(v, "batch")? as usize,
                y: f32_array(v, "y")?,
            }),
            Some("info") => {
                let sites = v
                    .get("sites")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("info response has no \"sites\" array"))?
                    .iter()
                    .map(SiteInfo::from_json)
                    .collect::<Result<Vec<_>>>()?;
                let stats = match v.get("stats") {
                    Some(s) => Some(ServeWireStats::from_json(s)?),
                    None => None,
                };
                Ok(Response::Info {
                    id,
                    model: str_field(v, "model")?,
                    generation: num_field(v, "generation")? as u64,
                    sites,
                    stats,
                })
            }
            Some("reload") => {
                Ok(Response::Reloaded { id, generation: num_field(v, "generation")? as u64 })
            }
            Some("stats") => {
                let stats = v
                    .get("stats")
                    .ok_or_else(|| anyhow!("stats response has no \"stats\" object"))?;
                Ok(Response::Stats {
                    id,
                    stats: ServeWireStats::from_json(stats)?,
                    obs: v.get("obs").cloned().unwrap_or(Json::Null),
                })
            }
            Some("hello") => Ok(Response::Hello {
                id,
                proto: num_field(v, "proto")? as u32,
                wire: str_field(v, "wire")?,
            }),
            other => bail!("unknown response op {other:?}"),
        }
    }
}

fn check_version(v: &Json) -> Result<()> {
    let lo = f64::from(MIN_PROTOCOL_VERSION);
    let hi = f64::from(PROTOCOL_VERSION);
    match v.get("v").and_then(Json::as_f64) {
        Some(n) if n >= lo && n <= hi && n.fract() == 0.0 => Ok(()),
        Some(n) => bail!(
            "unsupported protocol version {n} (this node speaks \
             v{MIN_PROTOCOL_VERSION}..v{PROTOCOL_VERSION})"
        ),
        None => bail!(
            "frame has no \"v\" protocol version (this node speaks \
             v{MIN_PROTOCOL_VERSION}..v{PROTOCOL_VERSION})"
        ),
    }
}

fn str_field(v: &Json, key: &str) -> Result<String> {
    v.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| anyhow!("frame has no {key:?} string"))
}

fn num_field(v: &Json, key: &str) -> Result<f64> {
    v.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("frame has no {key:?} number"))
}

fn f32_array(v: &Json, key: &str) -> Result<Vec<f32>> {
    v.get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("frame has no {key:?} array"))?
        .iter()
        .map(|e| e.as_f64().map(|f| f as f32))
        .collect::<Option<Vec<f32>>>()
        .ok_or_else(|| anyhow!("{key:?} has a non-numeric element"))
}

// ---------------------------------------------------------------------------
// Binary activation frames (protocol v2)
// ---------------------------------------------------------------------------
//
// Layout, all integers little-endian:
//
// ```text
// [0..4)   magic        BINARY_MAGIC (0xBF 'P' 'A' '2')
// [4..8)   u32 body_len length of everything after this field
// body:
//   u8       kind       BIN_INFER_REQUEST | BIN_INFER_RESPONSE
//   u8       flags      bit0 = "more" (coalescible burst); 0 in responses
//   u16+..   id         length-prefixed UTF-8 request id
//   u16+..   site       length-prefixed UTF-8 site name (requests only)
//   u32      batch      rows in this request/response
//   u32      nvals      f32 count that follows
//   nvals*4  payload    raw little-endian f32 activations
// ```
//
// The payload is carried bit-for-bit (`f32::to_le_bytes` /
// `from_le_bytes`), so NaN payload bits and signed zeros survive the
// wire exactly — stronger than the text path, which flattens `-0.0`.

/// One decoded binary frame body.
#[derive(Clone, Debug, PartialEq)]
pub enum BinaryFrame {
    /// kind [`BIN_INFER_REQUEST`]: semantically identical to a text
    /// `infer` frame.
    InferRequest { id: String, site: String, batch: usize, x: Vec<f32>, more: bool },
    /// kind [`BIN_INFER_RESPONSE`]: semantically identical to a text
    /// `infer` response.
    InferResponse { id: String, batch: usize, y: Vec<f32> },
}

/// Encode a complete binary infer-request frame (magic + length prefix
/// + body).  Fails only on an id/site longer than a u16 length prefix
/// can carry.
pub fn encode_binary_infer(
    id: &str,
    site: &str,
    batch: usize,
    x: &[f32],
    more: bool,
) -> Result<Vec<u8>> {
    let body_len = 1 + 1 + (2 + id.len()) + (2 + site.len()) + 4 + 4 + 4 * x.len();
    let mut f = frame_header(body_len)?;
    f.push(BIN_INFER_REQUEST);
    f.push(u8::from(more));
    push_str16(&mut f, id)?;
    push_str16(&mut f, site)?;
    push_u32(&mut f, batch)?;
    push_u32(&mut f, x.len())?;
    for v in x {
        f.extend_from_slice(&v.to_le_bytes());
    }
    Ok(f)
}

/// Encode a complete binary infer-response frame (magic + length prefix
/// + body).
pub fn encode_binary_infer_response(id: &str, batch: usize, y: &[f32]) -> Result<Vec<u8>> {
    let body_len = 1 + 1 + (2 + id.len()) + 4 + 4 + 4 * y.len();
    let mut f = frame_header(body_len)?;
    f.push(BIN_INFER_RESPONSE);
    f.push(0); // flags: none defined for responses
    push_str16(&mut f, id)?;
    push_u32(&mut f, batch)?;
    push_u32(&mut f, y.len())?;
    for v in y {
        f.extend_from_slice(&v.to_le_bytes());
    }
    Ok(f)
}

fn frame_header(body_len: usize) -> Result<Vec<u8>> {
    if body_len > MAX_BINARY_BODY {
        bail!("binary frame body of {body_len} bytes exceeds the {MAX_BINARY_BODY}-byte cap");
    }
    let mut f = Vec::with_capacity(8 + body_len);
    f.extend_from_slice(&BINARY_MAGIC);
    f.extend_from_slice(&(body_len as u32).to_le_bytes());
    Ok(f)
}

fn push_str16(f: &mut Vec<u8>, s: &str) -> Result<()> {
    let len = u16::try_from(s.len())
        .map_err(|_| anyhow!("string of {} bytes exceeds the u16 length prefix", s.len()))?;
    f.extend_from_slice(&len.to_le_bytes());
    f.extend_from_slice(s.as_bytes());
    Ok(())
}

fn push_u32(f: &mut Vec<u8>, n: usize) -> Result<()> {
    let n = u32::try_from(n).map_err(|_| anyhow!("value {n} exceeds the u32 wire field"))?;
    f.extend_from_slice(&n.to_le_bytes());
    Ok(())
}

/// Decode a binary frame *body* (magic and length prefix already
/// consumed by [`read_frame`]).  Because the body arrived length-
/// delimited, a decode error here leaves the stream in sync: the node
/// answers an error frame and keeps serving.  Error messages are
/// descriptive and safe to echo.
pub fn decode_binary_body(body: &[u8]) -> Result<BinaryFrame> {
    let mut c = ByteCursor { b: body, off: 0 };
    let kind = c.u8("kind")?;
    match kind {
        BIN_INFER_REQUEST => {
            let flags = c.u8("flags")?;
            let id = c.str16("id")?;
            let site = c.str16("site")?;
            let batch = c.u32("batch")? as usize;
            let n = c.u32("nvals")? as usize;
            let x = c.f32s(n)?;
            c.done()?;
            Ok(BinaryFrame::InferRequest { id, site, batch, x, more: flags & 1 != 0 })
        }
        BIN_INFER_RESPONSE => {
            let _flags = c.u8("flags")?;
            let id = c.str16("id")?;
            let batch = c.u32("batch")? as usize;
            let n = c.u32("nvals")? as usize;
            let y = c.f32s(n)?;
            c.done()?;
            Ok(BinaryFrame::InferResponse { id, batch, y })
        }
        other => bail!(
            "unknown binary frame kind {other} (known: {BIN_INFER_REQUEST}=infer request, \
             {BIN_INFER_RESPONSE}=infer response)"
        ),
    }
}

/// Bounds-checked little-endian reader over a binary frame body.
struct ByteCursor<'a> {
    b: &'a [u8],
    off: usize,
}

impl ByteCursor<'_> {
    fn take(&mut self, n: usize, what: &str) -> Result<&[u8]> {
        let end = self.off.checked_add(n).filter(|&e| e <= self.b.len()).ok_or_else(|| {
            anyhow!(
                "binary frame body truncated: wanted {n} bytes for {what} at offset {} of {}",
                self.off,
                self.b.len()
            )
        })?;
        let s = &self.b[self.off..end];
        self.off = end;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u16(&mut self, what: &str) -> Result<u16> {
        let s = self.take(2, what)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let s = self.take(4, what)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn str16(&mut self, what: &str) -> Result<String> {
        let len = self.u16(what)? as usize;
        let s = self.take(len, what)?;
        String::from_utf8(s.to_vec()).map_err(|_| anyhow!("binary frame {what} is not UTF-8"))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let nbytes = n.checked_mul(4).ok_or_else(|| anyhow!("binary frame nvals overflows"))?;
        let s = self.take(nbytes, "f32 payload")?;
        Ok(s.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    fn done(&self) -> Result<()> {
        if self.off != self.b.len() {
            bail!("binary frame body has {} trailing bytes", self.b.len() - self.off);
        }
        Ok(())
    }
}

/// One frame off the wire, as read by [`read_frame`].
#[derive(Debug)]
pub enum WireFrame {
    /// End of stream (clean shutdown).
    Eof,
    /// One NDJSON text line, trailing newline stripped.
    Text(String),
    /// The body of a binary frame (magic + length prefix already
    /// consumed and validated; decode with [`decode_binary_body`]).
    Binary(Vec<u8>),
    /// Unrecoverable framing corruption: bad magic, an oversized length
    /// prefix, a frame truncated by EOF, or non-UTF-8 text.  The stream
    /// cannot be re-synchronised, so the node answers one structured
    /// error frame and closes the *connection* — never the process.
    Corrupt(String),
}

/// Read the next frame off a mixed text/binary stream.  The formats are
/// distinguished per frame by the first byte: [`BINARY_MAGIC`] starts
/// with `0xBF` (a UTF-8 continuation byte, never a text-line start);
/// anything else is read as an NDJSON line.  Blank separator lines are
/// skipped.  I/O errors (transport death) propagate; framing corruption
/// is reported in-band as [`WireFrame::Corrupt`].
pub fn read_frame<R: std::io::BufRead>(input: &mut R) -> std::io::Result<WireFrame> {
    let first = loop {
        let buf = input.fill_buf()?;
        if buf.is_empty() {
            return Ok(WireFrame::Eof);
        }
        let b = buf[0];
        if b == b'\n' || b == b'\r' {
            input.consume(1);
            continue;
        }
        break b;
    };
    if first != BINARY_MAGIC[0] {
        let mut raw = Vec::new();
        input.read_until(b'\n', &mut raw)?;
        return Ok(match String::from_utf8(raw) {
            Ok(mut s) => {
                while s.ends_with('\n') || s.ends_with('\r') {
                    s.pop();
                }
                WireFrame::Text(s)
            }
            Err(_) => WireFrame::Corrupt("text frame is not valid UTF-8".to_string()),
        });
    }
    let mut magic = [0u8; 4];
    if hit_eof(input, &mut magic)? {
        return Ok(WireFrame::Corrupt("binary frame truncated inside the magic".to_string()));
    }
    if magic != BINARY_MAGIC {
        return Ok(WireFrame::Corrupt(format!(
            "bad binary frame magic {magic:02x?} (expected {BINARY_MAGIC:02x?})"
        )));
    }
    let mut len4 = [0u8; 4];
    if hit_eof(input, &mut len4)? {
        return Ok(WireFrame::Corrupt(
            "binary frame truncated inside the length prefix".to_string(),
        ));
    }
    let len = u32::from_le_bytes(len4) as usize;
    if len > MAX_BINARY_BODY {
        return Ok(WireFrame::Corrupt(format!(
            "binary frame length prefix {len} exceeds the {MAX_BINARY_BODY}-byte cap"
        )));
    }
    let mut body = vec![0u8; len];
    if hit_eof(input, &mut body)? {
        return Ok(WireFrame::Corrupt(format!(
            "binary frame truncated: length prefix promised {len} body bytes"
        )));
    }
    Ok(WireFrame::Binary(body))
}

/// `read_exact`, with early EOF reported as `Ok(true)` instead of an
/// error so the caller can answer it as framing corruption.
fn hit_eof<R: std::io::Read>(input: &mut R, buf: &mut [u8]) -> std::io::Result<bool> {
    match input.read_exact(buf) {
        Ok(()) => Ok(false),
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => Ok(true),
        Err(e) => Err(e),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn infer_wire_layout_is_stable() {
        // Key order is the BTreeMap's alphabetical order — the CI golden
        // transcript (`ci/golden/serve_smoke.out`) depends on it.
        let r = Response::Infer { id: "a".into(), batch: 1, y: vec![4.0, 4.0] };
        assert_eq!(r.to_line(), r#"{"batch":1,"id":"a","ok":true,"op":"infer","v":2,"y":[4,4]}"#);
        let e = Response::Error { id: None, error: "bad frame: unexpected end of JSON".into() };
        assert_eq!(
            e.to_line(),
            r#"{"error":"bad frame: unexpected end of JSON","id":null,"ok":false,"op":"error","v":2}"#
        );
    }

    #[test]
    fn stats_wire_layout_is_stable() {
        // The serve-smoke golden carries a stats frame; its key order
        // (alphabetical, nested objects included) is pinned here.
        let r = Response::Stats {
            id: "s".into(),
            stats: ServeWireStats {
                requests: 5,
                responses: 4,
                errors: 1,
                batches: 2,
                widest_batch: 2,
            },
            obs: Json::Null,
        };
        assert_eq!(
            r.to_line(),
            r#"{"id":"s","obs":null,"ok":true,"op":"stats","stats":{"batches":2,"errors":1,"requests":5,"responses":4,"widest_batch":2},"v":2}"#
        );
    }

    #[test]
    fn hello_wire_layout_is_stable() {
        // The binary-smoke golden parses this ack; key order pinned.
        let r = Response::Hello { id: "h".into(), proto: PROTOCOL_VERSION, wire: "binary".into() };
        assert_eq!(
            r.to_line(),
            r#"{"formats":["ndjson","binary"],"id":"h","ok":true,"op":"hello","proto":2,"v":2,"wire":"binary"}"#
        );
    }

    #[test]
    fn version_gate_accepts_the_range_and_runs_before_op_dispatch() {
        // v1 requests decode unchanged (back-compat leg of the v2 bump).
        let v1 = r#"{"v":1,"op":"infer","id":"x","site":"fc","batch":1,"x":[1]}"#;
        assert!(Request::parse_line(v1).is_ok());
        // Out-of-range versions are rejected before the op is looked at.
        let line = r#"{"v":9,"op":"warp","id":"x"}"#;
        let err = Request::parse_line(line).unwrap_err().to_string();
        assert!(err.contains("unsupported protocol version 9"), "{err}");
        assert!(err.contains("v1..v2"), "{err}");
        // Fractional versions are not a thing.
        let frac = r#"{"v":1.5,"op":"info","id":"x"}"#;
        let err = Request::parse_line(frac).unwrap_err().to_string();
        assert!(err.contains("unsupported protocol version 1.5"), "{err}");
    }

    #[test]
    fn binary_magic_is_tied_to_the_protocol_version() {
        // The magic's last byte names the protocol major version that
        // introduced the layout; a future v3 with a changed layout must
        // mint a new magic.
        assert_eq!(BINARY_MAGIC[3], b'0' + PROTOCOL_VERSION as u8);
        // And the first byte can never start a UTF-8 text line.
        assert!(BINARY_MAGIC[0] >= 0x80);
    }
}
