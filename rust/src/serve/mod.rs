//! `padst serve` — a batched sparse-inference node (ISSUE 6).
//!
//! The paper's headline efficiency claim is inference-side (structure +
//! learned permutation infers up to 2.9x faster than unstructured DST);
//! this layer is where a trained checkpoint actually serves.  Three
//! pieces, layered strictly on top of the existing subsystems:
//!
//! * [`protocol`] — the NDJSON wire format ([`Request`]/[`Response`]),
//!   versioned frames, structured error responses.  Pure codec; knows
//!   nothing about kernels.
//! * [`session`] — [`SessionCtx`], the per-session plan/scratch cache: a
//!   checkpoint is loaded ONCE, Hard-state perms decoded and every
//!   layer's `KernelPlan` compiled at startup; requests then reuse the
//!   compiled plans and a grow-only activation scratch with zero warm
//!   allocations (the `SinkhornScratch` pattern, one layer up).
//! * [`node`] — the serving loop: coalesces `"more":true` bursts into
//!   single batched `run_plan_mt` dispatches sized to the microkernel
//!   panel widths, answers in request order, contains every frame error.
//!
//! The boundary with the kernel layer is exactly one function:
//! [`crate::kernels::run_plan_mt`].  Plans are opaque to serve, so a new
//! `KernelPlan` variant needs no serving changes.
//!
//! Wire format, batching bit-identity (batch-of-N == N singles,
//! `to_bits`-exact per backend) and the warm-path allocation guard are
//! pinned by `rust/tests/serve_protocol.rs`; CI's `serve-smoke` job pipes
//! a golden transcript through the real binary.

pub mod node;
pub mod protocol;
pub mod session;

#[cfg(unix)]
pub use node::serve_unix_socket;
pub use node::{latency_summary, serve, NodeOpts, ServeStats};
pub use protocol::{Request, Response, ServeWireStats, SiteInfo, PROTOCOL_VERSION};
pub use session::{SessionCtx, SiteRuntime};
