//! `padst serve` — a batched sparse-inference node (ISSUE 6, made
//! concurrent + binary-wire in ISSUE 10).
//!
//! The paper's headline efficiency claim is inference-side (structure +
//! learned permutation infers up to 2.9x faster than unstructured DST);
//! this layer is where a trained checkpoint actually serves.  Three
//! pieces, layered strictly on top of the existing subsystems:
//!
//! * [`protocol`] — the wire formats: NDJSON control frames
//!   ([`Request`]/[`Response`], versioned, structured error responses)
//!   and, since protocol v2, length-prefixed binary activation frames
//!   (~4 bytes/value instead of ~13, `to_bits`-exact) negotiated via a
//!   `hello` handshake and auto-detected per frame by the first byte.
//!   Pure codec; knows nothing about kernels.
//! * [`session`] — the per-checkpoint plan cache, split for concurrency:
//!   [`session::SharedState`] loads a checkpoint ONCE (Hard-state perms
//!   decoded, every layer's `KernelPlan` compiled) behind a read-write
//!   lock, and each connection holds a [`SessionCtx`] view with private
//!   grow-only activation scratch — zero warm allocations per
//!   connection (the `SinkhornScratch` pattern, one layer up).
//!   [`session::CheckpointWatch`] hot-reloads the shared plans on
//!   checkpoint mtime change (`--watch-checkpoint`).
//! * [`node`] — the serving loop: coalesces `"more":true` bursts into
//!   single batched `run_plan_mt` dispatches sized to the microkernel
//!   panel widths, answers in request order (each response in its
//!   request's wire format), contains every frame error; plus the
//!   concurrent Unix-socket listener (one scoped worker per connection,
//!   up to `--max-conns`, kernel threads split per connection).
//!
//! The boundary with the kernel layer is exactly one function:
//! [`crate::kernels::run_plan_mt`] (plus the `threads_per_conn` budget
//! split).  Plans are opaque to serve, so a new `KernelPlan` variant
//! needs no serving changes.
//!
//! Wire formats, batching bit-identity (batch-of-N == N singles,
//! `to_bits`-exact per backend, across text/binary and any connection
//! interleaving) and the warm-path allocation guard are pinned by
//! `rust/tests/serve_protocol.rs` and `rust/tests/serve_concurrent.rs`;
//! CI's `serve-smoke` job pipes golden transcripts (text, binary, and a
//! two-connection socket run) through the real binary.

pub mod node;
pub mod protocol;
pub mod session;

#[cfg(unix)]
pub use node::serve_unix_socket;
pub use node::{latency_summary, serve, serve_with_watch, NodeOpts, ServeStats, SocketOpts};
pub use protocol::{
    decode_binary_body, encode_binary_infer, encode_binary_infer_response, read_frame,
    BinaryFrame, Request, Response, ServeWireStats, SiteInfo, WireFrame, BINARY_MAGIC,
    MIN_PROTOCOL_VERSION, PROTOCOL_VERSION, WIRE_BINARY, WIRE_NDJSON,
};
pub use session::{CheckpointWatch, PlanSet, SessionCtx, SharedState, SiteRuntime};
