//! Per-session plan cache, shared across connections.
//!
//! [`SessionCtx`] is the forcing-function refactor behind `padst serve`:
//! everything a request needs that does not depend on the request —
//! compiled [`KernelPlan`]s, decoded hard perm index maps, activation
//! scratch — is built once per checkpoint load and reused across calls.
//! This extends the `SinkhornScratch` no-alloc pattern one layer up: after
//! the first (cold) request against a site, serving again with the same
//! or a smaller batch performs zero allocations, observable through
//! [`SessionCtx::fingerprint`] exactly like
//! [`SinkhornScratch::buffer_fingerprint`].
//!
//! Since the concurrent-serve refactor the state splits in two:
//!
//! - [`SharedState`] — one per loaded checkpoint, behind an `Arc`: the
//!   pattern/perm drivers, the metric registry, and the compiled
//!   [`PlanSet`] behind a read-write lock.  Checkpoints load and compile
//!   **once**, no matter how many connections serve them; a reload (or
//!   the `--watch-checkpoint` poller, via [`CheckpointWatch`]) swaps the
//!   whole `Arc<PlanSet>` under the write lock and bumps the generation.
//! - [`SessionCtx`] — one per connection: its own activation scratch
//!   (no cross-connection contention on the warm path) plus a cached
//!   `Arc<PlanSet>` view refreshed from the shared lock at each burst,
//!   so a hot reload reaches every live connection at its next frame.
//!
//! Lifecycle:
//!
//! ```text
//! load(.tnz) -> TrainState -> rebuild(): sites_from_vals decodes perms
//!                             (Hard -> index map, Soft -> Sinkhorn+
//!                             Hungarian via the owned scratch), then
//!                             pattern.compress folds each map into the
//!                             site's index stream  ==> Arc<PlanSet>
//! connection():          cheap per-connection view — clones the Arc,
//!                        fresh scratch, get-or-create metric handles
//!                        (zero new registrations on an unchanged site
//!                        set — the NodeObs dedup contract)
//! run()/run_coalesced(): refresh the plan view (read lock, generation
//!                        compare), validate geometry, copy rows into
//!                        the owned x-scratch, ONE run_plan_mt dispatch,
//!                        answer from the owned y-scratch
//! reload()/poll():       rebuild() again under the write lock — plans
//!                        evicted, generation bumped, every connection
//!                        picks the swap up at its next burst
//! ```
//!
//! The serve layer never touches kernels below [`run_plan_mt`]: plans are
//! opaque here, and a new `KernelPlan` variant needs no serve changes.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, RwLock};
use std::time::SystemTime;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::{checkpoint, TrainState};
use crate::kernels::micro::Backend;
use crate::kernels::{run_plan_mt, run_plan_mt_tuned, tune};
use crate::obs::{self, Histogram, MetricRegistry, ObsSnapshot};
use crate::perm::model::{resolve_perm, sites_from_vals, PermHandle, PermState};
use crate::perm::SinkhornScratch;
use crate::sparsity::pattern::{resolve_pattern, KernelPlan, PatternHandle};
use crate::sparsity::patterns::Mask;
use crate::tensor::Tensor;
use crate::util::cli::resolve_threads;
use crate::util::Rng;

/// One site's compiled serving state: geometry for request validation
/// plus the plan the kernels execute.
#[derive(Clone, Debug)]
pub struct SiteRuntime {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    /// Whether a hard (non-identity-decoded) permutation was folded into
    /// the plan's index stream at compile time.
    pub permuted: bool,
    /// Dispatch variant resolved from the tuning table at (re)build time —
    /// the per-site cache that keeps the warm request path free of table
    /// lookups (see [`SessionCtx::run_coalesced`]).
    pub choice: tune::Choice,
    /// Whether `choice` came from the tuning table (`false` = the plain
    /// default dispatch; reported in the per-site startup log).
    pub tuned: bool,
    pub plan: KernelPlan,
}

/// One immutable generation of compiled plans.  Connections hold it by
/// `Arc`, so a reload never invalidates an in-flight burst: the old
/// generation stays alive until the last connection refreshes past it.
pub struct PlanSet {
    sites: Vec<SiteRuntime>,
    /// Bumped on every (re)build; responses carry it so clients can tell
    /// which compiled plans answered them.
    generation: u64,
}

impl PlanSet {
    pub fn sites(&self) -> &[SiteRuntime] {
        &self.sites
    }

    pub fn generation(&self) -> u64 {
        self.generation
    }
}

/// Everything one loaded checkpoint shares across its connections:
/// drivers, the metric registry, and the current [`PlanSet`] behind a
/// read-write lock.  Checkpoints load once per process, not once per
/// connection; see the module docs for the split.
pub struct SharedState {
    label: String,
    checkpoint: Mutex<Option<PathBuf>>,
    pattern: PatternHandle,
    perm: PermHandle,
    threads: usize,
    backend: Backend,
    plans: RwLock<Arc<PlanSet>>,
    /// Sinkhorn/Hungarian decode scratch for Soft-state checkpoints;
    /// only rebuild touches it, serialized by the lock.
    sinkhorn: Mutex<SinkhornScratch>,
    /// Per-session metric registry: node-level frame metrics plus one
    /// `serve.infer_ns.<site>` histogram per site.  Owned (not the
    /// process-global registry) so concurrent sessions — and parallel
    /// tests — never see each other's counters.  Get-or-create keyed by
    /// metric name: every connection resolves the *same* handles, which
    /// is what lets per-connection recording roll up into one `stats`
    /// frame without double registration.
    obs: MetricRegistry,
}

impl SharedState {
    /// Recompile every site from `state` and swap the plan set under the
    /// write lock: decode perms (Soft states go through the shared
    /// Sinkhorn scratch), fold the index maps into fresh plans, bump the
    /// generation.  Returns the new generation.  Old plans are dropped
    /// when the last connection refreshes past them — this is also the
    /// reload eviction path.
    pub fn rebuild(&self, state: &TrainState) -> Result<u64> {
        let mut widths = Vec::with_capacity(state.site_names.len());
        for name in &state.site_names {
            let mask = state
                .vals
                .get(&format!("mask.{name}"))
                .ok_or_else(|| anyhow!("state has no mask for site {name:?}"))?;
            if mask.shape.len() != 2 {
                bail!("mask.{name} is not 2-D (shape {:?})", mask.shape);
            }
            widths.push(mask.shape[1]);
        }
        let perm_sites =
            sites_from_vals(self.perm.as_ref(), &state.site_names, &widths, &state.vals)?;

        let mut sites = Vec::with_capacity(perm_sites.len());
        for site in &perm_sites {
            let name = &site.name;
            let mask_t = &state.vals[&format!("mask.{name}")];
            let (rows, cols) = (mask_t.shape[0], mask_t.shape[1]);
            let w = state
                .vals
                .get(&format!("param.{name}.w"))
                .ok_or_else(|| anyhow!("state has no weights for site {name:?}"))?;
            if w.shape != mask_t.shape {
                bail!("param.{name}.w shape {:?} != mask shape {:?}", w.shape, mask_t.shape);
            }
            let mask = Mask { rows, cols, bits: mask_t.f32s().to_vec() };
            // Hard states carry their index map; Soft states decode
            // through Sinkhorn + Hungarian right here, once, so requests
            // never pay for projection.
            let index_map: Option<Vec<usize>> = match &site.state {
                PermState::Identity => None,
                PermState::Hard { index_map } => Some(index_map.clone()),
                PermState::Soft { logits, .. } => {
                    let mut sink = self.sinkhorn.lock().unwrap_or_else(|p| p.into_inner());
                    self.perm.decode_logits(logits.f32s(), cols, &mut sink)
                }
            };
            let permuted = index_map
                .as_ref()
                .is_some_and(|m| m.iter().enumerate().any(|(i, &p)| i != p));
            let perm_i32: Option<Vec<i32>> =
                index_map.map(|m| m.into_iter().map(|p| p as i32).collect());
            let plan = self.pattern.compress(w.f32s(), &mask, perm_i32.as_deref());
            // One tuning-table consult per site per (re)build: the warm
            // request path dispatches the cached choice and never probes
            // the table again.
            let (choice, tuned) = tune::tuner().choice_for(&plan, self.threads, self.backend);
            sites.push(SiteRuntime {
                name: name.clone(),
                rows,
                cols,
                nnz: mask.nnz(),
                permuted,
                choice,
                tuned,
                plan,
            });
        }
        // Pre-register the per-site infer histograms so a connection
        // view's refresh resolves existing handles.  Get-or-create: a
        // reload over the same site names re-uses them, so the
        // registration count only moves when the site set changes.
        for s in &sites {
            let _ = self.obs.histogram(&format!("serve.infer_ns.{}", s.name));
        }
        let mut plans = self.plans.write().unwrap_or_else(|p| p.into_inner());
        let generation = plans.generation + 1;
        *plans = Arc::new(PlanSet { sites, generation });
        Ok(generation)
    }

    /// The current plan set (cheap: read lock + `Arc` clone).
    pub fn plans(&self) -> Arc<PlanSet> {
        Arc::clone(&self.plans.read().unwrap_or_else(|p| p.into_inner()))
    }

    /// Current plan generation without cloning the set.
    pub fn generation(&self) -> u64 {
        self.plans.read().unwrap_or_else(|p| p.into_inner()).generation
    }

    /// The shared metric registry (see the field docs for why every
    /// connection resolves the same handles).
    pub fn obs(&self) -> &MetricRegistry {
        &self.obs
    }

    pub fn label(&self) -> &str {
        &self.label
    }

    /// The checkpoint path this session was loaded from (what
    /// `--watch-checkpoint` polls); `None` for in-memory / synthetic
    /// sessions.
    pub fn checkpoint_path(&self) -> Option<PathBuf> {
        self.checkpoint.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }

    fn hists_for(&self, plans: &PlanSet) -> Vec<Arc<Histogram>> {
        plans
            .sites
            .iter()
            .map(|s| self.obs.histogram(&format!("serve.infer_ns.{}", s.name)))
            .collect()
    }
}

/// One connection's serving view: private activation scratch plus a
/// cached `Arc` of the shared plan set, refreshed at each burst.  See
/// the module docs for the lifecycle; `rust/tests/serve_protocol.rs`
/// pins the no-alloc warm path and the reload eviction semantics, and
/// `rust/tests/serve_concurrent.rs` pins the cross-connection ones.
pub struct SessionCtx {
    shared: Arc<SharedState>,
    /// Cached plan view; [`SessionCtx::refresh`] re-resolves it when the
    /// shared generation moves.
    plans: Arc<PlanSet>,
    /// Per-site infer histograms, index-aligned with `plans.sites`;
    /// looked up at refresh so the warm path never takes the registry
    /// lock or allocates a metric name.
    site_hists: Vec<Arc<Histogram>>,
    /// Request activations, grown once per high-water batch, never shrunk.
    scratch_x: Vec<f32>,
    /// Response activations, same policy.
    scratch_y: Vec<f32>,
    /// Kernel threads this view dispatches with — the connection's slice
    /// of the global budget (see `kernels::threads_per_conn`).
    threads: usize,
}

impl SessionCtx {
    /// Build a session from an in-memory `TrainState` (what `load` and
    /// the tests share).  `threads == 0` means auto, as everywhere else.
    pub fn from_state(
        label: &str,
        state: &TrainState,
        pattern: PatternHandle,
        perm: PermHandle,
        threads: usize,
        backend: Backend,
    ) -> Result<SessionCtx> {
        let threads = resolve_threads(threads);
        let shared = Arc::new(SharedState {
            label: label.to_string(),
            checkpoint: Mutex::new(None),
            pattern,
            perm,
            threads,
            backend,
            plans: RwLock::new(Arc::new(PlanSet { sites: Vec::new(), generation: 0 })),
            sinkhorn: Mutex::new(SinkhornScratch::new()),
            obs: MetricRegistry::new(),
        });
        shared.rebuild(state)?;
        let plans = shared.plans();
        let site_hists = shared.hists_for(&plans);
        Ok(SessionCtx { shared, plans, site_hists, scratch_x: Vec::new(), scratch_y: Vec::new(), threads })
    }

    /// Load a checkpoint from disk and compile every site once.  The
    /// path is remembered so a `reload` frame without an explicit
    /// checkpoint re-reads it.
    pub fn load_checkpoint(
        path: &Path,
        pattern: PatternHandle,
        perm: PermHandle,
        threads: usize,
        backend: Backend,
    ) -> Result<SessionCtx> {
        let state = checkpoint::load(path)?;
        let label = path.display().to_string();
        let ctx = SessionCtx::from_state(&label, &state, pattern, perm, threads, backend)?;
        *ctx.shared.checkpoint.lock().unwrap_or_else(|p| p.into_inner()) =
            Some(path.to_path_buf());
        Ok(ctx)
    }

    /// A one-site session with all-1.0 weights and no permutation — the
    /// CI smoke target: on `diag:K` every row has exactly K nnz, so an
    /// all-ones input row maps to the integer K on every backend and the
    /// golden transcript is platform-stable.
    pub fn synthetic(
        spec: &str,
        rows: usize,
        cols: usize,
        density: f64,
        threads: usize,
        backend: Backend,
    ) -> Result<SessionCtx> {
        let pattern = resolve_pattern(spec)?;
        let mask = pattern.init_mask(rows, cols, density, &mut Rng::new(0))?;
        let mut vals = HashMap::new();
        vals.insert("mask.demo".to_string(), Tensor::from_f32(&[rows, cols], mask.bits.clone()));
        let ones = Tensor::from_f32(&[rows, cols], vec![1.0; rows * cols]);
        vals.insert("param.demo.w".to_string(), ones);
        vals.insert("hard_flags".to_string(), Tensor::from_f32(&[1], vec![1.0]));
        let state = TrainState {
            vals,
            site_names: vec!["demo".to_string()],
            budgets: vec![mask.nnz()],
        };
        SessionCtx::from_state(
            &format!("synthetic:{spec}"),
            &state,
            pattern,
            resolve_perm("none")?,
            threads,
            backend,
        )
    }

    /// A fresh view over the same shared state for another connection:
    /// clones the plan `Arc`, resolves the *existing* metric handles
    /// (get-or-create by name — zero new registrations on an unchanged
    /// site set, the NodeObs dedup contract), and starts with empty
    /// scratch so connections never contend on the warm path.
    pub fn connection(&self) -> SessionCtx {
        let plans = self.shared.plans();
        let site_hists = self.shared.hists_for(&plans);
        SessionCtx {
            shared: Arc::clone(&self.shared),
            plans,
            site_hists,
            scratch_x: Vec::new(),
            scratch_y: Vec::new(),
            threads: self.threads,
        }
    }

    /// Cap this view's kernel-thread budget (a connection's slice of the
    /// global `--threads`; bit-safe because `run_plan_mt` is
    /// bit-identical at any thread count).
    pub fn with_threads(mut self, threads: usize) -> SessionCtx {
        self.threads = threads.max(1);
        self
    }

    /// The shared per-checkpoint state (what the `--watch-checkpoint`
    /// poller holds on to).
    pub fn shared(&self) -> &Arc<SharedState> {
        &self.shared
    }

    /// Re-resolve the cached plan view if the shared generation moved
    /// (another connection's reload, or the checkpoint watcher).  Warm
    /// path cost when nothing moved: one read lock, one integer compare —
    /// no allocation, so the fingerprint holds.  Returns whether the
    /// view changed.
    pub fn refresh(&mut self) -> bool {
        if self.shared.generation() == self.plans.generation {
            return false;
        }
        self.plans = self.shared.plans();
        self.site_hists = self.shared.hists_for(&self.plans);
        true
    }

    /// Recompile every site from `state` (shared write-lock swap) and
    /// refresh this view.  Other connections pick the swap up at their
    /// next burst.
    pub fn rebuild(&mut self, state: &TrainState) -> Result<()> {
        self.shared.rebuild(state)?;
        self.refresh();
        Ok(())
    }

    /// Reload from `state`, evicting every cached plan (alias of
    /// [`SessionCtx::rebuild`], named for the serving-path intent).
    pub fn reload(&mut self, state: &TrainState) -> Result<()> {
        self.rebuild(state)
    }

    /// Reload from a checkpoint path (the session's own when `path` is
    /// `None`).  Returns the new generation.
    pub fn reload_from(&mut self, path: Option<&str>) -> Result<u64> {
        let path: PathBuf = {
            let cp = self.shared.checkpoint.lock().unwrap_or_else(|p| p.into_inner());
            match (path, cp.as_ref()) {
                (Some(p), _) => PathBuf::from(p),
                (None, Some(p)) => p.clone(),
                (None, None) => bail!(
                    "session {:?} was not loaded from a checkpoint; reload needs a \
                     \"checkpoint\" path",
                    self.shared.label
                ),
            }
        };
        let state = checkpoint::load(&path)?;
        self.shared.rebuild(&state)?;
        *self.shared.checkpoint.lock().unwrap_or_else(|p| p.into_inner()) = Some(path);
        self.refresh();
        Ok(self.plans.generation)
    }

    /// The sites of this view's plan generation (call
    /// [`SessionCtx::refresh`] first when staleness matters).
    pub fn sites(&self) -> &[SiteRuntime] {
        &self.plans.sites
    }

    pub fn site(&self, name: &str) -> Result<&SiteRuntime> {
        self.site_index(name).map(|i| &self.plans.sites[i])
    }

    fn site_index(&self, name: &str) -> Result<usize> {
        self.plans.sites.iter().position(|s| s.name == name).ok_or_else(|| {
            let known: Vec<&str> = self.plans.sites.iter().map(|s| s.name.as_str()).collect();
            anyhow!(
                "unknown site {name:?} in this session (known: {}) — requests must target the \
                 loaded checkpoint's sites",
                known.join("|")
            )
        })
    }

    pub fn label(&self) -> &str {
        &self.shared.label
    }

    pub fn generation(&self) -> u64 {
        self.plans.generation
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn backend(&self) -> Backend {
        self.shared.backend
    }

    /// Validate one request's geometry against the compiled site — the
    /// serving-path answer to infeasible shapes, phrased like the
    /// registry errors so the message can ship verbatim in an error frame.
    pub fn check_request(&self, site: &str, batch: usize, x_len: usize) -> Result<()> {
        let s = self.site(site)?;
        if batch == 0 {
            bail!("infeasible request geometry for site {site:?}: batch must be >= 1");
        }
        if x_len != batch * s.cols {
            bail!(
                "infeasible request geometry for site {site:?}: x has {x_len} values for \
                 batch={batch} (expected batch x cols = {batch} x {} = {}; the site is {}x{} in \
                 the loaded checkpoint)",
                s.cols,
                batch * s.cols,
                s.rows,
                s.cols
            );
        }
        Ok(())
    }

    /// Execute a coalesced burst against one site: the parts (each a
    /// row-major `(x, batch)` slice pair) are packed into the owned
    /// x-scratch and dispatched as ONE batched [`run_plan_mt`] call; the
    /// returned slice is the concatenated rows in part order, living in
    /// the owned y-scratch until the next call.  The plan view is
    /// refreshed first, so a hot reload reaches this connection here.
    ///
    /// Because every kernel row `y[b][i]` depends only on input row `b`,
    /// the coalesced result is bitwise the concatenation of the parts run
    /// singly — the identity `serve_protocol.rs` sweeps across backends.
    // lint: no-alloc (grow-only `resize` of the owned scratch is the one
    // sanctioned exception; warm requests never reach it, and refresh()
    // only re-resolves the plan view on a generation change)
    pub fn run_coalesced(&mut self, site: &str, parts: &[(&[f32], usize)]) -> Result<&[f32]> {
        self.refresh();
        let si = self.site_index(site)?;
        // Timed span over the whole coalesced dispatch (validation +
        // scratch pack + kernel); the Arc clone and the thread-local
        // label push are the only costs — no allocation, so the warm
        // fingerprint holds with metrics recording enabled.
        let _span = obs::span::timed("serve.infer", &self.site_hists[si]);
        let (rows, cols) = (self.plans.sites[si].rows, self.plans.sites[si].cols);
        let mut total = 0usize;
        for (x, batch) in parts {
            self.check_request(site, *batch, x.len())?;
            total += batch;
        }
        if total == 0 {
            bail!("empty burst for site {site:?}");
        }
        // Grow-only scratch: warm requests at or below the high-water
        // batch must not allocate (fingerprint-pinned).
        if self.scratch_x.len() < total * cols {
            self.scratch_x.resize(total * cols, 0.0);
        }
        if self.scratch_y.len() < total * rows {
            self.scratch_y.resize(total * rows, 0.0);
        }
        let mut off = 0usize;
        for (x, batch) in parts {
            self.scratch_x[off..off + batch * cols].copy_from_slice(x);
            off += batch * cols;
        }
        // Tuned sites dispatch their (re)build-cached choice with no
        // table lookup; untuned sites keep the exact pre-tuner call.
        // Both are allocation-free — the fingerprint contract holds
        // either way.
        let (tuned, choice) = (self.plans.sites[si].tuned, self.plans.sites[si].choice);
        if tuned {
            run_plan_mt_tuned(
                &self.plans.sites[si].plan,
                &self.scratch_x[..total * cols],
                total,
                &mut self.scratch_y[..total * rows],
                self.threads,
                &choice,
            );
        } else {
            run_plan_mt(
                &self.plans.sites[si].plan,
                &self.scratch_x[..total * cols],
                total,
                &mut self.scratch_y[..total * rows],
                self.threads,
                self.shared.backend,
            );
        }
        Ok(&self.scratch_y[..total * rows])
    }

    /// Single-request convenience over [`SessionCtx::run_coalesced`].
    pub fn run(&mut self, site: &str, x: &[f32], batch: usize) -> Result<&[f32]> {
        self.run_coalesced(site, &[(x, batch)])
    }

    /// The shared metric registry (frame/batch metrics recorded by the
    /// serve loop, per-site infer histograms recorded here).  Every
    /// connection resolves the same handles, so per-connection recording
    /// rolls up into one `stats` frame.
    pub fn obs(&self) -> &MetricRegistry {
        &self.shared.obs
    }

    /// Session metrics merged with the process-global registry (kernel
    /// dispatch counters, harness metrics) — what `stats` frames carry.
    pub fn obs_snapshot(&self) -> ObsSnapshot {
        let mut snap = self.shared.obs.snapshot();
        snap.merge(&obs::global().snapshot());
        snap
    }

    /// Warm-path allocation fingerprint: scratch pointers + capacities +
    /// the plan generation + the metric registration count.  Stable
    /// across warm requests at or below the high-water batch (nothing
    /// allocated, nothing newly registered — recording into existing
    /// handles is atomic-only); changes when a cold call grows the
    /// scratch or a reload evicts the plans — the same technique as
    /// [`SinkhornScratch::buffer_fingerprint`].
    pub fn fingerprint(&self) -> (usize, usize, usize, usize, u64, usize) {
        (
            self.scratch_x.as_ptr() as usize,
            self.scratch_x.capacity(),
            self.scratch_y.as_ptr() as usize,
            self.scratch_y.capacity(),
            self.plans.generation,
            self.shared.obs.registrations(),
        )
    }
}

/// Mtime poller behind `--watch-checkpoint`: when the checkpoint file's
/// modification time moves, reload it into the shared state (write-lock
/// swap), so every live connection picks the new plans up at its next
/// burst.  A load error (e.g. the trainer mid-write) leaves the old
/// plans serving and the watermark unchanged, so the next poll retries.
pub struct CheckpointWatch {
    path: PathBuf,
    last: Option<SystemTime>,
}

impl CheckpointWatch {
    /// Start watching `path`.  The current mtime (if the file exists) is
    /// the baseline: only *subsequent* modifications trigger a reload.
    pub fn new(path: &Path) -> CheckpointWatch {
        let last = std::fs::metadata(path).and_then(|m| m.modified()).ok();
        CheckpointWatch { path: path.to_path_buf(), last }
    }

    /// One poll: `Ok(Some(generation))` after a successful hot reload,
    /// `Ok(None)` when the mtime has not moved, `Err` when the file is
    /// unreadable or fails to compile (old plans keep serving).
    pub fn poll(&mut self, shared: &SharedState) -> Result<Option<u64>> {
        let mtime = std::fs::metadata(&self.path)
            .and_then(|m| m.modified())
            .map_err(|e| anyhow!("watch {}: {e}", self.path.display()))?;
        if self.last == Some(mtime) {
            return Ok(None);
        }
        let state = checkpoint::load(&self.path)?;
        let generation = shared.rebuild(&state)?;
        self.last = Some(mtime);
        Ok(Some(generation))
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}
