//! Per-session plan/scratch cache.
//!
//! [`SessionCtx`] is the forcing-function refactor behind `padst serve`:
//! everything a request needs that does not depend on the request —
//! compiled [`KernelPlan`]s, decoded hard perm index maps, activation
//! scratch — is built once per checkpoint load and reused across calls.
//! This extends the `SinkhornScratch` no-alloc pattern one layer up: after
//! the first (cold) request against a site, serving again with the same
//! or a smaller batch performs zero allocations, observable through
//! [`SessionCtx::fingerprint`] exactly like
//! [`SinkhornScratch::buffer_fingerprint`].
//!
//! Lifecycle:
//!
//! ```text
//! load(.tnz) -> TrainState -> rebuild(): sites_from_vals decodes perms
//!                             (Hard -> index map, Soft -> Sinkhorn+
//!                             Hungarian via the owned scratch), then
//!                             pattern.compress folds each map into the
//!                             site's index stream  ==> Vec<SiteRuntime>
//! run()/run_coalesced(): validate geometry, copy rows into the owned
//!                        x-scratch, ONE run_plan_mt dispatch, answer
//!                        from the owned y-scratch
//! reload(): rebuild() again — plans evicted, generation bumped
//! ```
//!
//! The serve layer never touches kernels below [`run_plan_mt`]: plans are
//! opaque here, and a new `KernelPlan` variant needs no serve changes.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::{checkpoint, TrainState};
use crate::kernels::micro::Backend;
use crate::kernels::{run_plan_mt, run_plan_mt_tuned, tune};
use crate::obs::{self, Histogram, MetricRegistry, ObsSnapshot};
use crate::perm::model::{resolve_perm, sites_from_vals, PermHandle, PermState};
use crate::perm::SinkhornScratch;
use crate::sparsity::pattern::{resolve_pattern, KernelPlan, PatternHandle};
use crate::sparsity::patterns::Mask;
use crate::tensor::Tensor;
use crate::util::cli::resolve_threads;
use crate::util::Rng;

/// One site's compiled serving state: geometry for request validation
/// plus the plan the kernels execute.
#[derive(Clone, Debug)]
pub struct SiteRuntime {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    /// Whether a hard (non-identity-decoded) permutation was folded into
    /// the plan's index stream at compile time.
    pub permuted: bool,
    /// Dispatch variant resolved from the tuning table at (re)build time —
    /// the per-site cache that keeps the warm request path free of table
    /// lookups (see [`SessionCtx::run_coalesced`]).
    pub choice: tune::Choice,
    /// Whether `choice` came from the tuning table (`false` = the plain
    /// default dispatch; reported in the per-site startup log).
    pub tuned: bool,
    pub plan: KernelPlan,
}

/// A serving session: compiled plans, perm index maps and activation
/// scratch for one loaded checkpoint.  See the module docs for the
/// lifecycle; `rust/tests/serve_protocol.rs` pins the no-alloc warm path
/// and the reload eviction semantics.
pub struct SessionCtx {
    label: String,
    checkpoint: Option<PathBuf>,
    pattern: PatternHandle,
    perm: PermHandle,
    sites: Vec<SiteRuntime>,
    /// Request activations, grown once per high-water batch, never shrunk.
    scratch_x: Vec<f32>,
    /// Response activations, same policy.
    scratch_y: Vec<f32>,
    /// Sinkhorn/Hungarian decode scratch for Soft-state checkpoints.
    sinkhorn: SinkhornScratch,
    threads: usize,
    backend: Backend,
    /// Bumped on every (re)build; responses carry it so clients can tell
    /// which compiled plans answered them.
    generation: u64,
    /// Per-session metric registry: node-level frame metrics plus one
    /// `serve.infer_ns.<site>` histogram per site.  Owned (not the
    /// process-global registry) so concurrent sessions — and parallel
    /// tests — never see each other's counters.
    obs: MetricRegistry,
    /// Pre-registered per-site infer histograms, index-aligned with
    /// `sites`; looked up here so the warm path never takes the
    /// registry lock or allocates a metric name.
    site_hists: Vec<Arc<Histogram>>,
}

impl SessionCtx {
    /// Build a session from an in-memory `TrainState` (what `load` and
    /// the tests share).  `threads == 0` means auto, as everywhere else.
    pub fn from_state(
        label: &str,
        state: &TrainState,
        pattern: PatternHandle,
        perm: PermHandle,
        threads: usize,
        backend: Backend,
    ) -> Result<SessionCtx> {
        let mut ctx = SessionCtx {
            label: label.to_string(),
            checkpoint: None,
            pattern,
            perm,
            sites: Vec::new(),
            scratch_x: Vec::new(),
            scratch_y: Vec::new(),
            sinkhorn: SinkhornScratch::new(),
            threads: resolve_threads(threads),
            backend,
            generation: 0,
            obs: MetricRegistry::new(),
            site_hists: Vec::new(),
        };
        ctx.rebuild(state)?;
        Ok(ctx)
    }

    /// Load a checkpoint from disk and compile every site once.  The
    /// path is remembered so a `reload` frame without an explicit
    /// checkpoint re-reads it.
    pub fn load_checkpoint(
        path: &Path,
        pattern: PatternHandle,
        perm: PermHandle,
        threads: usize,
        backend: Backend,
    ) -> Result<SessionCtx> {
        let state = checkpoint::load(path)?;
        let label = path.display().to_string();
        let mut ctx = SessionCtx::from_state(&label, &state, pattern, perm, threads, backend)?;
        ctx.checkpoint = Some(path.to_path_buf());
        Ok(ctx)
    }

    /// A one-site session with all-1.0 weights and no permutation — the
    /// CI smoke target: on `diag:K` every row has exactly K nnz, so an
    /// all-ones input row maps to the integer K on every backend and the
    /// golden transcript is platform-stable.
    pub fn synthetic(
        spec: &str,
        rows: usize,
        cols: usize,
        density: f64,
        threads: usize,
        backend: Backend,
    ) -> Result<SessionCtx> {
        let pattern = resolve_pattern(spec)?;
        let mask = pattern.init_mask(rows, cols, density, &mut Rng::new(0))?;
        let mut vals = HashMap::new();
        vals.insert("mask.demo".to_string(), Tensor::from_f32(&[rows, cols], mask.bits.clone()));
        let ones = Tensor::from_f32(&[rows, cols], vec![1.0; rows * cols]);
        vals.insert("param.demo.w".to_string(), ones);
        vals.insert("hard_flags".to_string(), Tensor::from_f32(&[1], vec![1.0]));
        let state = TrainState {
            vals,
            site_names: vec!["demo".to_string()],
            budgets: vec![mask.nnz()],
        };
        SessionCtx::from_state(
            &format!("synthetic:{spec}"),
            &state,
            pattern,
            resolve_perm("none")?,
            threads,
            backend,
        )
    }

    /// Recompile every site from `state`: decode perms (Soft states go
    /// through the owned Sinkhorn scratch), fold the index maps into
    /// fresh plans, bump the generation.  Old plans are dropped here —
    /// this is also the reload eviction path.
    pub fn rebuild(&mut self, state: &TrainState) -> Result<()> {
        let mut widths = Vec::with_capacity(state.site_names.len());
        for name in &state.site_names {
            let mask = state
                .vals
                .get(&format!("mask.{name}"))
                .ok_or_else(|| anyhow!("state has no mask for site {name:?}"))?;
            if mask.shape.len() != 2 {
                bail!("mask.{name} is not 2-D (shape {:?})", mask.shape);
            }
            widths.push(mask.shape[1]);
        }
        let perm_sites =
            sites_from_vals(self.perm.as_ref(), &state.site_names, &widths, &state.vals)?;

        let mut sites = Vec::with_capacity(perm_sites.len());
        for site in &perm_sites {
            let name = &site.name;
            let mask_t = &state.vals[&format!("mask.{name}")];
            let (rows, cols) = (mask_t.shape[0], mask_t.shape[1]);
            let w = state
                .vals
                .get(&format!("param.{name}.w"))
                .ok_or_else(|| anyhow!("state has no weights for site {name:?}"))?;
            if w.shape != mask_t.shape {
                bail!("param.{name}.w shape {:?} != mask shape {:?}", w.shape, mask_t.shape);
            }
            let mask = Mask { rows, cols, bits: mask_t.f32s().to_vec() };
            // Hard states carry their index map; Soft states decode
            // through Sinkhorn + Hungarian right here, once, so requests
            // never pay for projection.
            let index_map: Option<Vec<usize>> = match &site.state {
                PermState::Identity => None,
                PermState::Hard { index_map } => Some(index_map.clone()),
                PermState::Soft { logits, .. } => {
                    self.perm.decode_logits(logits.f32s(), cols, &mut self.sinkhorn)
                }
            };
            let permuted = index_map
                .as_ref()
                .is_some_and(|m| m.iter().enumerate().any(|(i, &p)| i != p));
            let perm_i32: Option<Vec<i32>> =
                index_map.map(|m| m.into_iter().map(|p| p as i32).collect());
            let plan = self.pattern.compress(w.f32s(), &mask, perm_i32.as_deref());
            // One tuning-table consult per site per (re)build: the warm
            // request path dispatches the cached choice and never probes
            // the table again.
            let (choice, tuned) = tune::tuner().choice_for(&plan, self.threads, self.backend);
            sites.push(SiteRuntime {
                name: name.clone(),
                rows,
                cols,
                nnz: mask.nnz(),
                permuted,
                choice,
                tuned,
                plan,
            });
        }
        self.sites = sites;
        // Per-site infer histograms, registered once per (re)build.
        // Get-or-create: a reload over the same site names re-uses the
        // existing handles, so the registration count only moves when
        // the site set actually changes.
        self.site_hists = self
            .sites
            .iter()
            .map(|s| self.obs.histogram(&format!("serve.infer_ns.{}", s.name)))
            .collect();
        self.generation += 1;
        Ok(())
    }

    /// Reload from `state`, evicting every cached plan (alias of
    /// [`SessionCtx::rebuild`], named for the serving-path intent).
    pub fn reload(&mut self, state: &TrainState) -> Result<()> {
        self.rebuild(state)
    }

    /// Reload from a checkpoint path (the session's own when `path` is
    /// `None`).  Returns the new generation.
    pub fn reload_from(&mut self, path: Option<&str>) -> Result<u64> {
        let path: PathBuf = match (path, &self.checkpoint) {
            (Some(p), _) => PathBuf::from(p),
            (None, Some(p)) => p.clone(),
            (None, None) => bail!(
                "session {:?} was not loaded from a checkpoint; reload needs a \"checkpoint\" path",
                self.label
            ),
        };
        let state = checkpoint::load(&path)?;
        self.rebuild(&state)?;
        self.checkpoint = Some(path);
        Ok(self.generation)
    }

    pub fn sites(&self) -> &[SiteRuntime] {
        &self.sites
    }

    pub fn site(&self, name: &str) -> Result<&SiteRuntime> {
        self.site_index(name).map(|i| &self.sites[i])
    }

    fn site_index(&self, name: &str) -> Result<usize> {
        self.sites.iter().position(|s| s.name == name).ok_or_else(|| {
            let known: Vec<&str> = self.sites.iter().map(|s| s.name.as_str()).collect();
            anyhow!(
                "unknown site {name:?} in this session (known: {}) — requests must target the \
                 loaded checkpoint's sites",
                known.join("|")
            )
        })
    }

    pub fn label(&self) -> &str {
        &self.label
    }

    pub fn generation(&self) -> u64 {
        self.generation
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// Validate one request's geometry against the compiled site — the
    /// serving-path answer to infeasible shapes, phrased like the
    /// registry errors so the message can ship verbatim in an error frame.
    pub fn check_request(&self, site: &str, batch: usize, x_len: usize) -> Result<()> {
        let s = self.site(site)?;
        if batch == 0 {
            bail!("infeasible request geometry for site {site:?}: batch must be >= 1");
        }
        if x_len != batch * s.cols {
            bail!(
                "infeasible request geometry for site {site:?}: x has {x_len} values for \
                 batch={batch} (expected batch x cols = {batch} x {} = {}; the site is {}x{} in \
                 the loaded checkpoint)",
                s.cols,
                batch * s.cols,
                s.rows,
                s.cols
            );
        }
        Ok(())
    }

    /// Execute a coalesced burst against one site: the parts (each a
    /// row-major `(x, batch)` slice pair) are packed into the owned
    /// x-scratch and dispatched as ONE batched [`run_plan_mt`] call; the
    /// returned slice is the concatenated rows in part order, living in
    /// the owned y-scratch until the next call.
    ///
    /// Because every kernel row `y[b][i]` depends only on input row `b`,
    /// the coalesced result is bitwise the concatenation of the parts run
    /// singly — the identity `serve_protocol.rs` sweeps across backends.
    // lint: no-alloc (grow-only `resize` of the owned scratch is the one
    // sanctioned exception; warm requests never reach it)
    pub fn run_coalesced(&mut self, site: &str, parts: &[(&[f32], usize)]) -> Result<&[f32]> {
        let si = self.site_index(site)?;
        // Timed span over the whole coalesced dispatch (validation +
        // scratch pack + kernel); the Arc clone and the thread-local
        // label push are the only costs — no allocation, so the warm
        // fingerprint holds with metrics recording enabled.
        let _span = obs::span::timed("serve.infer", &self.site_hists[si]);
        let (rows, cols) = (self.sites[si].rows, self.sites[si].cols);
        let mut total = 0usize;
        for (x, batch) in parts {
            self.check_request(site, *batch, x.len())?;
            total += batch;
        }
        if total == 0 {
            bail!("empty burst for site {site:?}");
        }
        // Grow-only scratch: warm requests at or below the high-water
        // batch must not allocate (fingerprint-pinned).
        if self.scratch_x.len() < total * cols {
            self.scratch_x.resize(total * cols, 0.0);
        }
        if self.scratch_y.len() < total * rows {
            self.scratch_y.resize(total * rows, 0.0);
        }
        let mut off = 0usize;
        for (x, batch) in parts {
            self.scratch_x[off..off + batch * cols].copy_from_slice(x);
            off += batch * cols;
        }
        // Tuned sites dispatch their (re)build-cached choice with no
        // table lookup; untuned sites keep the exact pre-tuner call.
        // Both are allocation-free — the fingerprint contract holds
        // either way.
        let (tuned, choice) = (self.sites[si].tuned, self.sites[si].choice);
        if tuned {
            run_plan_mt_tuned(
                &self.sites[si].plan,
                &self.scratch_x[..total * cols],
                total,
                &mut self.scratch_y[..total * rows],
                self.threads,
                &choice,
            );
        } else {
            run_plan_mt(
                &self.sites[si].plan,
                &self.scratch_x[..total * cols],
                total,
                &mut self.scratch_y[..total * rows],
                self.threads,
                self.backend,
            );
        }
        Ok(&self.scratch_y[..total * rows])
    }

    /// Single-request convenience over [`SessionCtx::run_coalesced`].
    pub fn run(&mut self, site: &str, x: &[f32], batch: usize) -> Result<&[f32]> {
        self.run_coalesced(site, &[(x, batch)])
    }

    /// This session's metric registry (frame/batch metrics recorded by
    /// the serve loop, per-site infer histograms recorded here).
    pub fn obs(&self) -> &MetricRegistry {
        &self.obs
    }

    /// Session metrics merged with the process-global registry (kernel
    /// dispatch counters, harness metrics) — what `stats` frames carry.
    pub fn obs_snapshot(&self) -> ObsSnapshot {
        let mut snap = self.obs.snapshot();
        snap.merge(&obs::global().snapshot());
        snap
    }

    /// Warm-path allocation fingerprint: scratch pointers + capacities +
    /// the plan generation + the metric registration count.  Stable
    /// across warm requests at or below the high-water batch (nothing
    /// allocated, nothing newly registered — recording into existing
    /// handles is atomic-only); changes when a cold call grows the
    /// scratch or a reload evicts the plans — the same technique as
    /// [`SinkhornScratch::buffer_fingerprint`].
    pub fn fingerprint(&self) -> (usize, usize, usize, usize, u64, usize) {
        (
            self.scratch_x.as_ptr() as usize,
            self.scratch_x.capacity(),
            self.scratch_y.as_ptr() as usize,
            self.scratch_y.capacity(),
            self.generation,
            self.obs.registrations(),
        )
    }
}
