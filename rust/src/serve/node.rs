//! The serving loop: read request frames (NDJSON text or, since
//! protocol v2, length-prefixed binary activation frames — distinguished
//! per frame by the first byte), coalesce `"more":true` infer bursts
//! into one batched GEMM each, write response frames in request order.
//!
//! Error containment is the invariant the corrupt-frame tests pin: a bad
//! frame (truncated, non-JSON, unknown op, wrong version, infeasible
//! geometry, undecodable binary body) produces exactly one structured
//! NDJSON error frame — echoing the request id whenever one survived
//! parsing — and the loop keeps serving.  Framing corruption that
//! desynchronises the byte stream (bad magic, oversized or truncated
//! length prefix, non-UTF-8 text) is answered with one error frame and
//! then closes the *connection*; only EOF (clean shutdown, after
//! flushing any held burst), framing corruption, or a transport I/O
//! error ends a session — never the process.
//!
//! Batching policy: consecutive same-site infer frames marked
//! `"more":true` are held; the burst flushes when a frame arrives without
//! the flag, when the pending rows reach [`NodeOpts::max_batch`], when a
//! non-infer frame needs the line, or at EOF.  Responses always come back
//! in request order.  Text and binary infer frames coalesce together —
//! each response mirrors its request's wire format (or the connection
//! preference set by a `hello` frame), so the batched dispatch is
//! format-blind and batch-of-N ≡ N singles holds across any mix.
//!
//! The socket listener ([`serve_unix_socket`]) accepts up to
//! `--max-conns` concurrent connections, each served by a scoped worker
//! thread over its own [`SessionCtx`] view (private scratch, shared
//! compiled plans, kernel threads split via
//! [`crate::kernels::threads_per_conn`]).  All workers resolve the same
//! metric handles from the shared registry, so per-connection recording
//! rolls up into one `stats` frame.

use std::io::{BufRead, Write};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::kernels::micro::LANES;
use crate::obs::{Counter, Gauge, Histogram, MetricRegistry};
use crate::serve::protocol::{
    decode_binary_body, encode_binary_infer_response, read_frame, BinaryFrame, Request, Response,
    ServeWireStats, SiteInfo, WireFrame, PROTOCOL_VERSION, WIRE_BINARY, WIRE_NDJSON,
};
use crate::serve::session::{CheckpointWatch, SessionCtx};
use crate::util::json::Json;
use crate::util::stats::fmt_time;

/// Serving-loop knobs.
#[derive(Clone, Copy, Debug)]
pub struct NodeOpts {
    /// Flush a held burst once this many rows are pending.  Default
    /// `4 * LANES`: four 8-wide register panels — past this the batched
    /// GEMM is panel-saturated and latency wins over more coalescing.
    pub max_batch: usize,
}

impl Default for NodeOpts {
    fn default() -> Self {
        NodeOpts { max_batch: 4 * LANES }
    }
}

/// Socket-listener knobs (see [`serve_unix_socket`]).
#[derive(Clone, Copy, Debug)]
pub struct SocketOpts {
    /// Concurrent connection cap; accepts past it wait for a slot.
    pub max_conns: usize,
    /// Hot-reload the session's checkpoint when its mtime changes.
    pub watch_checkpoint: bool,
    /// Watcher poll interval.
    pub watch_interval_ms: u64,
}

impl Default for SocketOpts {
    fn default() -> Self {
        SocketOpts { max_conns: 4, watch_checkpoint: false, watch_interval_ms: 500 }
    }
}

/// End-of-session accounting (the CLI logs it at EOF; `info` and
/// `stats` frames carry it live via [`ServeStats::wire`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeStats {
    pub requests: usize,
    pub responses: usize,
    /// Error frames emitted (counted inside `responses` too).
    pub errors: usize,
    /// Coalesced GEMM dispatches.
    pub batches: usize,
    /// Widest burst, in requests.
    pub widest_batch: usize,
}

impl ServeStats {
    pub fn wire(&self) -> ServeWireStats {
        ServeWireStats {
            requests: self.requests,
            responses: self.responses,
            errors: self.errors,
            batches: self.batches,
            widest_batch: self.widest_batch,
        }
    }
}

/// Node-level metric handles, resolved once per [`serve`] call from the
/// session's registry.  Get-or-create keyed by metric name is the
/// de-duplication contract: a second (or fiftieth) connection resolves
/// the *same* handles instead of double-registering or clobbering them,
/// so the registration count stays flat across connections (pinned by
/// `node_obs_dedup_across_connections` in `serve_concurrent.rs`) and
/// per-connection recording aggregates into one `stats` frame.
struct NodeObs {
    /// Handling latency per frame (decode + dispatch + response write).
    frame_ns: Arc<Histogram>,
    /// Rows per coalesced dispatch.
    batch_rows: Arc<Histogram>,
    /// Dispatch rows as a percentage of `max_batch`.
    batch_fill_pct: Arc<Histogram>,
    /// High-water pending rows while a burst was held.
    queue_rows: Arc<Gauge>,
    /// Error frames emitted.
    errors: Arc<Counter>,
    /// Binary frames handled, both directions (v2 wire adoption).
    binary_frames: Arc<Counter>,
    max_batch: usize,
}

impl NodeObs {
    fn new(reg: &MetricRegistry, max_batch: usize) -> NodeObs {
        NodeObs {
            frame_ns: reg.histogram("serve.frame_ns"),
            batch_rows: reg.histogram("serve.batch_rows"),
            batch_fill_pct: reg.histogram("serve.batch_fill_pct"),
            queue_rows: reg.gauge("serve.queue_rows_max"),
            errors: reg.counter("serve.error_frames"),
            binary_frames: reg.counter("serve.binary_frames"),
            max_batch: max_batch.max(1),
        }
    }
}

/// An infer frame held for coalescing.
struct PendingInfer {
    id: String,
    site: String,
    batch: usize,
    x: Vec<f32>,
    /// Whether the request arrived as a binary frame (its response
    /// mirrors the format unless a `hello` preference overrides).
    binary: bool,
}

/// Serve one session: `input` to EOF, responses on `out`.  Frame errors
/// never end the loop; framing corruption ends the connection (after
/// one error frame); transport errors propagate.
// lint: no-panic
pub fn serve<R: BufRead, W: Write>(
    ctx: &mut SessionCtx,
    input: R,
    out: &mut W,
    opts: &NodeOpts,
) -> Result<ServeStats> {
    let mut input = input;
    let mut stats = ServeStats::default();
    let nobs = NodeObs::new(ctx.obs(), opts.max_batch);
    let mut pending: Vec<PendingInfer> = Vec::new();
    // Connection wire preference, set by a `hello` frame: when true,
    // even text infer requests are answered with binary frames.
    let mut prefer_binary = false;
    loop {
        let frame = read_frame(&mut input)?;
        let (request, arrived_binary) = match frame {
            WireFrame::Eof => break,
            WireFrame::Corrupt(msg) => {
                // The byte stream cannot be re-synchronised: answer the
                // held burst, emit one structured error frame, close
                // this connection (the process keeps serving others).
                stats.requests += 1;
                flush(ctx, &mut pending, out, &mut stats, &nobs, prefer_binary)?;
                respond(out, &mut stats, &nobs, &Response::Error { id: None, error: msg })?;
                break;
            }
            WireFrame::Text(line) => {
                if line.trim().is_empty() {
                    continue;
                }
                (decode(&line), false)
            }
            WireFrame::Binary(body) => {
                nobs.binary_frames.inc();
                (decode_binary(&body), true)
            }
        };
        stats.requests += 1;
        // Per-frame handling latency: decode + any dispatch this frame
        // triggered + response writes.  Held burst frames are cheap here
        // (enqueue only); the flush cost lands on the frame that flushes.
        let t0 = Instant::now();
        match request {
            Err((id, error)) => {
                flush(ctx, &mut pending, out, &mut stats, &nobs, prefer_binary)?;
                respond(out, &mut stats, &nobs, &Response::Error { id, error })?;
            }
            Ok(Request::Infer { id, site, batch, x, more }) => {
                // Geometry is checked at enqueue so one infeasible
                // request cannot poison a coalesced burst, and its error
                // frame echoes exactly its own id.
                ctx.refresh();
                if let Err(e) = ctx.check_request(&site, batch, x.len()) {
                    flush(ctx, &mut pending, out, &mut stats, &nobs, prefer_binary)?;
                    let err = Response::Error { id: Some(id), error: e.to_string() };
                    respond(out, &mut stats, &nobs, &err)?;
                } else {
                    // Only same-site frames coalesce (one plan per
                    // dispatch).
                    if pending.last().is_some_and(|p| p.site != site) {
                        flush(ctx, &mut pending, out, &mut stats, &nobs, prefer_binary)?;
                    }
                    pending.push(PendingInfer { id, site, batch, x, binary: arrived_binary });
                    let rows: usize = pending.iter().map(|p| p.batch).sum();
                    nobs.queue_rows.set_max(rows as u64);
                    if !more || rows >= opts.max_batch {
                        flush(ctx, &mut pending, out, &mut stats, &nobs, prefer_binary)?;
                    }
                }
            }
            Ok(Request::Hello { id, wire }) => {
                flush(ctx, &mut pending, out, &mut stats, &nobs, prefer_binary)?;
                let resp = match wire.as_deref() {
                    None => None,
                    Some(WIRE_NDJSON) => {
                        prefer_binary = false;
                        None
                    }
                    Some(WIRE_BINARY) => {
                        prefer_binary = true;
                        None
                    }
                    Some(other) => Some(Response::Error {
                        id: Some(id.clone()),
                        error: format!(
                            "unknown wire format {other:?} (known: {WIRE_NDJSON}|{WIRE_BINARY})"
                        ),
                    }),
                };
                let resp = resp.unwrap_or_else(|| Response::Hello {
                    id,
                    proto: PROTOCOL_VERSION,
                    wire: if prefer_binary { WIRE_BINARY } else { WIRE_NDJSON }.to_string(),
                });
                respond(out, &mut stats, &nobs, &resp)?;
            }
            Ok(Request::Info { id }) => {
                flush(ctx, &mut pending, out, &mut stats, &nobs, prefer_binary)?;
                ctx.refresh();
                let resp = info_response(ctx, id, &stats);
                respond(out, &mut stats, &nobs, &resp)?;
            }
            Ok(Request::Reload { id, checkpoint }) => {
                flush(ctx, &mut pending, out, &mut stats, &nobs, prefer_binary)?;
                let resp = match ctx.reload_from(checkpoint.as_deref()) {
                    Ok(generation) => Response::Reloaded { id, generation },
                    Err(e) => Response::Error { id: Some(id), error: e.to_string() },
                };
                respond(out, &mut stats, &nobs, &resp)?;
            }
            Ok(Request::Stats { id }) => {
                flush(ctx, &mut pending, out, &mut stats, &nobs, prefer_binary)?;
                ctx.refresh();
                let resp = Response::Stats {
                    id,
                    stats: stats.wire(),
                    obs: ctx.obs_snapshot().to_json(),
                };
                respond(out, &mut stats, &nobs, &resp)?;
            }
        }
        nobs.frame_ns.record_ns(t0.elapsed());
    }
    // EOF (or corruption close): answer any held burst, then shut down
    // this connection cleanly.
    flush(ctx, &mut pending, out, &mut stats, &nobs, prefer_binary)?;
    Ok(stats)
}

/// One-line latency digest from the session's frame histogram — the
/// shutdown summary `padst serve` prints at EOF / connection close.
pub fn latency_summary(ctx: &SessionCtx) -> String {
    let snap = ctx.obs().histogram("serve.frame_ns").snapshot();
    if snap.count == 0 {
        return "no frames timed".to_string();
    }
    let t = |ns: u64| fmt_time(ns as f64 * 1e-9);
    format!(
        "frame latency p50 {} p90 {} p99 {} max {} over {} frames",
        t(snap.quantile(0.5)),
        t(snap.quantile(0.9)),
        t(snap.quantile(0.99)),
        t(snap.max),
        snap.count
    )
}

/// Serve a session on stdin/stdout-style streams while a scoped watcher
/// thread polls the checkpoint mtime and hot-reloads the shared plans
/// (what `--watch-checkpoint` without `--socket` runs).
pub fn serve_with_watch<R: BufRead, W: Write>(
    ctx: &mut SessionCtx,
    input: R,
    out: &mut W,
    opts: &NodeOpts,
    interval_ms: u64,
) -> Result<ServeStats> {
    use std::sync::atomic::{AtomicBool, Ordering};
    let Some(watch) = checkpoint_watch(ctx) else {
        anyhow::bail!(
            "--watch-checkpoint needs a session loaded from a checkpoint (synthetic sessions \
             have no file to watch)"
        );
    };
    let shared = Arc::clone(ctx.shared());
    let done = AtomicBool::new(false);
    std::thread::scope(|s| {
        s.spawn(|| {
            let mut watch = watch;
            while !done.load(Ordering::Relaxed) {
                std::thread::sleep(std::time::Duration::from_millis(interval_ms.max(1)));
                log_watch_poll(watch.poll(&shared), watch.path());
            }
        });
        let stats = serve(ctx, input, out, opts);
        done.store(true, Ordering::Relaxed);
        stats
    })
}

fn checkpoint_watch(ctx: &SessionCtx) -> Option<CheckpointWatch> {
    ctx.shared().checkpoint_path().map(|p| CheckpointWatch::new(&p))
}

fn log_watch_poll(poll: Result<Option<u64>>, path: &std::path::Path) {
    match poll {
        Ok(Some(generation)) => eprintln!(
            "[padst serve] checkpoint {} changed on disk -> hot-reloaded as generation {}",
            path.display(),
            generation
        ),
        Ok(None) => {}
        // The old plans keep serving; the watcher retries next poll
        // (e.g. the trainer was mid-write).
        Err(e) => eprintln!("[padst serve] watch: reload failed, keeping old plans: {e:#}"),
    }
}

/// Serve connections from a Unix socket concurrently: up to
/// `sopts.max_conns` scoped worker threads, each over its own
/// [`SessionCtx::connection`] view with a `threads_per_conn` slice of
/// the kernel-thread budget (bit-safe: `run_plan_mt` is bit-identical
/// at any thread count).  Worker failures are logged, never fatal to
/// the listener.  Runs until the process is killed.
#[cfg(unix)]
pub fn serve_unix_socket(
    ctx: &SessionCtx,
    path: &std::path::Path,
    opts: &NodeOpts,
    sopts: &SocketOpts,
) -> Result<()> {
    use std::sync::atomic::{AtomicUsize, Ordering};

    use anyhow::Context as _;
    use std::os::unix::net::UnixListener;

    let max_conns = sopts.max_conns.max(1);
    let watch = if sopts.watch_checkpoint {
        let Some(w) = checkpoint_watch(ctx) else {
            anyhow::bail!(
                "--watch-checkpoint needs a session loaded from a checkpoint (synthetic \
                 sessions have no file to watch)"
            );
        };
        Some(w)
    } else {
        None
    };
    // A dead node leaves its socket file behind; rebinding wants it gone.
    let _ = std::fs::remove_file(path);
    let listener = UnixListener::bind(path)
        .with_context(|| format!("binding unix socket {}", path.display()))?;
    eprintln!(
        "[padst serve] listening on {} (up to {} concurrent connections, {} kernel threads each)",
        path.display(),
        max_conns,
        crate::kernels::threads_per_conn(ctx.threads(), max_conns)
    );
    let active = AtomicUsize::new(0);
    let conns = ctx.obs().counter("serve.connections");
    std::thread::scope(|s| -> Result<()> {
        if let Some(watch) = watch {
            let shared = Arc::clone(ctx.shared());
            let interval = sopts.watch_interval_ms.max(1);
            s.spawn(move || {
                let mut watch = watch;
                loop {
                    std::thread::sleep(std::time::Duration::from_millis(interval));
                    log_watch_poll(watch.poll(&shared), watch.path());
                }
            });
        }
        for (conn_no, stream) in listener.incoming().enumerate() {
            let stream = match stream {
                Ok(s) => s,
                Err(e) => {
                    // Never fatal: one failed accept must not take down
                    // the listener (or hang joining the watcher thread).
                    eprintln!("[padst serve] accept failed: {e}");
                    continue;
                }
            };
            // Admission gate: hold the accept loop until a worker slot
            // frees up.  Relaxed suffices — the gate only bounds the
            // worker count, it orders nothing.
            while active.load(Ordering::Relaxed) >= max_conns {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            active.fetch_add(1, Ordering::Relaxed);
            conns.inc();
            let conn = ctx
                .connection()
                .with_threads(crate::kernels::threads_per_conn(ctx.threads(), max_conns));
            let active = &active;
            s.spawn(move || {
                let mut conn = conn;
                serve_worker(&mut conn, stream, opts, conn_no);
                active.fetch_sub(1, Ordering::Relaxed);
            });
        }
        Ok(())
    })
}

/// One socket connection, errors contained: a worker failure closes its
/// connection and is logged — the listener and the other workers keep
/// serving.
#[cfg(unix)]
fn serve_worker(
    conn: &mut SessionCtx,
    stream: std::os::unix::net::UnixStream,
    opts: &NodeOpts,
    conn_no: usize,
) {
    let reader = match stream.try_clone() {
        Ok(s) => std::io::BufReader::new(s),
        Err(e) => {
            eprintln!("[padst serve] conn {conn_no}: socket clone failed: {e}");
            return;
        }
    };
    let mut writer = stream;
    match serve(conn, reader, &mut writer, opts) {
        Ok(stats) => {
            eprintln!(
                "[padst serve] conn {conn_no} closed: {} requests -> {} responses ({} errors), \
                 {} batches",
                stats.requests, stats.responses, stats.errors, stats.batches
            );
            eprintln!("[padst serve] {}", latency_summary(conn));
        }
        Err(e) => eprintln!("[padst serve] conn {conn_no}: transport error: {e:#}"),
    }
}

/// Two-stage decode so error frames can echo the request id whenever the
/// line was at least JSON.
// lint: no-panic
fn decode(line: &str) -> std::result::Result<Request, (Option<String>, String)> {
    let v = Json::parse(line).map_err(|e| (None, format!("bad frame: {e}")))?;
    let id = v.get("id").and_then(Json::as_str).map(str::to_string);
    Request::from_json(&v).map_err(|e| (id, e.to_string()))
}

/// Decode a binary frame body into the common [`Request`] shape.  The
/// body arrived length-delimited, so a decode failure leaves the stream
/// in sync — it maps to one error frame, same as a bad text line.
// lint: no-panic
fn decode_binary(body: &[u8]) -> std::result::Result<Request, (Option<String>, String)> {
    match decode_binary_body(body) {
        Ok(BinaryFrame::InferRequest { id, site, batch, x, more }) => {
            Ok(Request::Infer { id, site, batch, x, more })
        }
        Ok(BinaryFrame::InferResponse { id, .. }) => Err((
            Some(id),
            "unexpected binary infer-response frame from client (kind 2 is server->client)"
                .to_string(),
        )),
        Err(e) => Err((None, e.to_string())),
    }
}

/// Execute the held burst as one batched dispatch and answer each pending
/// request with its own rows, in order — each response in its request's
/// wire format (or binary when the connection preference says so).
// lint: no-panic
fn flush<W: Write>(
    ctx: &mut SessionCtx,
    pending: &mut Vec<PendingInfer>,
    out: &mut W,
    stats: &mut ServeStats,
    nobs: &NodeObs,
    prefer_binary: bool,
) -> Result<()> {
    if pending.is_empty() {
        return Ok(());
    }
    let rows_total: usize = pending.iter().map(|p| p.batch).sum();
    let site = pending[0].site.clone();
    match ctx.site(&site).map(|s| s.rows) {
        Ok(rows) => {
            let parts: Vec<(&[f32], usize)> =
                pending.iter().map(|p| (p.x.as_slice(), p.batch)).collect();
            match ctx.run_coalesced(&site, &parts) {
                Ok(y) => {
                    stats.batches += 1;
                    stats.widest_batch = stats.widest_batch.max(pending.len());
                    nobs.batch_rows.record(rows_total as u64);
                    nobs.batch_fill_pct.record((100 * rows_total / nobs.max_batch) as u64);
                    let mut off = 0usize;
                    for p in pending.iter() {
                        let n = p.batch * rows;
                        let part = &y[off..off + n];
                        off += n;
                        if p.binary || prefer_binary {
                            respond_binary_infer(out, stats, nobs, &p.id, p.batch, part)?;
                        } else {
                            let resp = Response::Infer {
                                id: p.id.clone(),
                                batch: p.batch,
                                y: part.to_vec(),
                            };
                            respond(out, stats, nobs, &resp)?;
                        }
                    }
                }
                // Enqueue-time validation makes this unreachable in
                // practice, but a kernel-layer refusal still answers
                // every held request instead of killing the node.
                Err(e) => {
                    let msg = e.to_string();
                    for r in per_request_errors(pending, &msg) {
                        respond(out, stats, nobs, &r)?;
                    }
                }
            }
        }
        Err(e) => {
            let msg = e.to_string();
            for r in per_request_errors(pending, &msg) {
                respond(out, stats, nobs, &r)?;
            }
        }
    }
    pending.clear();
    Ok(())
}

// lint: no-panic
fn per_request_errors(pending: &[PendingInfer], msg: &str) -> Vec<Response> {
    pending
        .iter()
        .map(|p| Response::Error { id: Some(p.id.clone()), error: msg.to_string() })
        .collect()
}

// lint: no-panic
fn respond<W: Write>(
    out: &mut W,
    stats: &mut ServeStats,
    nobs: &NodeObs,
    resp: &Response,
) -> Result<()> {
    out.write_all(resp.to_line().as_bytes())?;
    out.write_all(b"\n")?;
    out.flush()?;
    stats.responses += 1;
    if matches!(resp, Response::Error { .. }) {
        stats.errors += 1;
        nobs.errors.inc();
    }
    Ok(())
}

/// Write one infer response as a binary frame.  An id too long for the
/// u16 length prefix degrades to a structured text error frame rather
/// than a malformed binary one.
// lint: no-panic
fn respond_binary_infer<W: Write>(
    out: &mut W,
    stats: &mut ServeStats,
    nobs: &NodeObs,
    id: &str,
    batch: usize,
    y: &[f32],
) -> Result<()> {
    match encode_binary_infer_response(id, batch, y) {
        Ok(frame) => {
            out.write_all(&frame)?;
            out.flush()?;
            stats.responses += 1;
            nobs.binary_frames.inc();
            Ok(())
        }
        Err(e) => {
            let resp =
                Response::Error { id: Some(id.to_string()), error: e.to_string() };
            respond(out, stats, nobs, &resp)
        }
    }
}

fn info_response(ctx: &SessionCtx, id: String, stats: &ServeStats) -> Response {
    let sites = ctx
        .sites()
        .iter()
        .map(|s| SiteInfo {
            name: s.name.clone(),
            rows: s.rows,
            cols: s.cols,
            nnz: s.nnz,
            driver: s.plan.driver().to_string(),
            permuted: s.permuted,
        })
        .collect();
    Response::Info {
        id,
        model: ctx.label().to_string(),
        generation: ctx.generation(),
        sites,
        stats: Some(stats.wire()),
    }
}
