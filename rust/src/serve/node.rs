//! The serving loop: read NDJSON request frames, coalesce `"more":true`
//! infer bursts into one batched GEMM each, write response frames in
//! request order.
//!
//! Error containment is the invariant the corrupt-frame tests pin: a bad
//! frame (truncated, non-JSON, unknown op, wrong version, infeasible
//! geometry) produces exactly one structured error frame — echoing the
//! request id whenever the line was at least JSON — and the loop keeps
//! serving.  Only EOF (clean shutdown, after flushing any held burst) or
//! a transport I/O error ends a session.
//!
//! Batching policy: consecutive same-site infer frames marked
//! `"more":true` are held; the burst flushes when a frame arrives without
//! the flag, when the pending rows reach [`NodeOpts::max_batch`], when a
//! non-infer frame needs the line, or at EOF.  Responses always come back
//! in request order.

use std::io::{BufRead, Write};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::kernels::micro::LANES;
use crate::obs::{Counter, Gauge, Histogram, MetricRegistry};
use crate::serve::protocol::{Request, Response, ServeWireStats, SiteInfo};
use crate::serve::session::SessionCtx;
use crate::util::json::Json;
use crate::util::stats::fmt_time;

/// Serving-loop knobs.
#[derive(Clone, Copy, Debug)]
pub struct NodeOpts {
    /// Flush a held burst once this many rows are pending.  Default
    /// `4 * LANES`: four 8-wide register panels — past this the batched
    /// GEMM is panel-saturated and latency wins over more coalescing.
    pub max_batch: usize,
}

impl Default for NodeOpts {
    fn default() -> Self {
        NodeOpts { max_batch: 4 * LANES }
    }
}

/// End-of-session accounting (the CLI logs it at EOF; `info` and
/// `stats` frames carry it live via [`ServeStats::wire`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeStats {
    pub requests: usize,
    pub responses: usize,
    /// Error frames emitted (counted inside `responses` too).
    pub errors: usize,
    /// Coalesced GEMM dispatches.
    pub batches: usize,
    /// Widest burst, in requests.
    pub widest_batch: usize,
}

impl ServeStats {
    pub fn wire(&self) -> ServeWireStats {
        ServeWireStats {
            requests: self.requests,
            responses: self.responses,
            errors: self.errors,
            batches: self.batches,
            widest_batch: self.widest_batch,
        }
    }
}

/// Node-level metric handles, registered once per [`serve`] call in the
/// session's registry (get-or-create: a socket node serving many
/// sequential connections re-uses the same handles, so warm frames
/// never re-register — part of the session fingerprint contract).
struct NodeObs {
    /// Handling latency per frame (decode + dispatch + response write).
    frame_ns: Arc<Histogram>,
    /// Rows per coalesced dispatch.
    batch_rows: Arc<Histogram>,
    /// Dispatch rows as a percentage of `max_batch`.
    batch_fill_pct: Arc<Histogram>,
    /// High-water pending rows while a burst was held.
    queue_rows: Arc<Gauge>,
    /// Error frames emitted.
    errors: Arc<Counter>,
    max_batch: usize,
}

impl NodeObs {
    fn new(reg: &MetricRegistry, max_batch: usize) -> NodeObs {
        NodeObs {
            frame_ns: reg.histogram("serve.frame_ns"),
            batch_rows: reg.histogram("serve.batch_rows"),
            batch_fill_pct: reg.histogram("serve.batch_fill_pct"),
            queue_rows: reg.gauge("serve.queue_rows_max"),
            errors: reg.counter("serve.error_frames"),
            max_batch: max_batch.max(1),
        }
    }
}

/// An infer frame held for coalescing.
struct PendingInfer {
    id: String,
    site: String,
    batch: usize,
    x: Vec<f32>,
}

/// Serve one NDJSON session: `input` to EOF, responses on `out`.  Frame
/// errors never end the loop; transport errors do.
// lint: no-panic
pub fn serve<R: BufRead, W: Write>(
    ctx: &mut SessionCtx,
    input: R,
    out: &mut W,
    opts: &NodeOpts,
) -> Result<ServeStats> {
    let mut stats = ServeStats::default();
    let nobs = NodeObs::new(ctx.obs(), opts.max_batch);
    let mut pending: Vec<PendingInfer> = Vec::new();
    for line in input.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        stats.requests += 1;
        // Per-frame handling latency: decode + any dispatch this frame
        // triggered + response writes.  Held burst frames are cheap here
        // (enqueue only); the flush cost lands on the frame that flushes.
        let t0 = Instant::now();
        match decode(&line) {
            Err((id, error)) => {
                flush(ctx, &mut pending, out, &mut stats, &nobs)?;
                respond(out, &mut stats, &nobs, &Response::Error { id, error })?;
            }
            Ok(Request::Infer { id, site, batch, x, more }) => {
                // Geometry is checked at enqueue so one infeasible
                // request cannot poison a coalesced burst, and its error
                // frame echoes exactly its own id.
                if let Err(e) = ctx.check_request(&site, batch, x.len()) {
                    flush(ctx, &mut pending, out, &mut stats, &nobs)?;
                    let err = Response::Error { id: Some(id), error: e.to_string() };
                    respond(out, &mut stats, &nobs, &err)?;
                } else {
                    // Only same-site frames coalesce (one plan per
                    // dispatch).
                    if pending.last().is_some_and(|p| p.site != site) {
                        flush(ctx, &mut pending, out, &mut stats, &nobs)?;
                    }
                    pending.push(PendingInfer { id, site, batch, x });
                    let rows: usize = pending.iter().map(|p| p.batch).sum();
                    nobs.queue_rows.set_max(rows as u64);
                    if !more || rows >= opts.max_batch {
                        flush(ctx, &mut pending, out, &mut stats, &nobs)?;
                    }
                }
            }
            Ok(Request::Info { id }) => {
                flush(ctx, &mut pending, out, &mut stats, &nobs)?;
                let resp = info_response(ctx, id, &stats);
                respond(out, &mut stats, &nobs, &resp)?;
            }
            Ok(Request::Reload { id, checkpoint }) => {
                flush(ctx, &mut pending, out, &mut stats, &nobs)?;
                let resp = match ctx.reload_from(checkpoint.as_deref()) {
                    Ok(generation) => Response::Reloaded { id, generation },
                    Err(e) => Response::Error { id: Some(id), error: e.to_string() },
                };
                respond(out, &mut stats, &nobs, &resp)?;
            }
            Ok(Request::Stats { id }) => {
                flush(ctx, &mut pending, out, &mut stats, &nobs)?;
                let resp = Response::Stats {
                    id,
                    stats: stats.wire(),
                    obs: ctx.obs_snapshot().to_json(),
                };
                respond(out, &mut stats, &nobs, &resp)?;
            }
        }
        nobs.frame_ns.record_ns(t0.elapsed());
    }
    // EOF: answer any held burst, then shut down cleanly.
    flush(ctx, &mut pending, out, &mut stats, &nobs)?;
    Ok(stats)
}

/// One-line latency digest from the session's frame histogram — the
/// shutdown summary `padst serve` prints at EOF / connection close.
pub fn latency_summary(ctx: &SessionCtx) -> String {
    let snap = ctx.obs().histogram("serve.frame_ns").snapshot();
    if snap.count == 0 {
        return "no frames timed".to_string();
    }
    let t = |ns: u64| fmt_time(ns as f64 * 1e-9);
    format!(
        "frame latency p50 {} p90 {} p99 {} max {} over {} frames",
        t(snap.quantile(0.5)),
        t(snap.quantile(0.9)),
        t(snap.quantile(0.99)),
        t(snap.max),
        snap.count
    )
}

/// Serve connections from a Unix socket, sequentially: one NDJSON
/// session per connection, per-connection stats to stderr.  Runs until
/// the process is killed.
#[cfg(unix)]
pub fn serve_unix_socket(
    ctx: &mut SessionCtx,
    path: &std::path::Path,
    opts: &NodeOpts,
) -> Result<()> {
    use anyhow::Context as _;
    use std::os::unix::net::UnixListener;
    // A dead node leaves its socket file behind; rebinding wants it gone.
    let _ = std::fs::remove_file(path);
    let listener = UnixListener::bind(path)
        .with_context(|| format!("binding unix socket {}", path.display()))?;
    eprintln!("[padst serve] listening on {}", path.display());
    for stream in listener.incoming() {
        let stream = stream?;
        let reader = std::io::BufReader::new(stream.try_clone()?);
        let mut writer = stream;
        let stats = serve(ctx, reader, &mut writer, opts)?;
        eprintln!(
            "[padst serve] connection closed: {} requests -> {} responses ({} errors), {} batches",
            stats.requests, stats.responses, stats.errors, stats.batches
        );
        eprintln!("[padst serve] {}", latency_summary(ctx));
    }
    Ok(())
}

/// Two-stage decode so error frames can echo the request id whenever the
/// line was at least JSON.
// lint: no-panic
fn decode(line: &str) -> std::result::Result<Request, (Option<String>, String)> {
    let v = Json::parse(line).map_err(|e| (None, format!("bad frame: {e}")))?;
    let id = v.get("id").and_then(Json::as_str).map(str::to_string);
    Request::from_json(&v).map_err(|e| (id, e.to_string()))
}

/// Execute the held burst as one batched dispatch and answer each pending
/// request with its own rows, in order.
// lint: no-panic
fn flush<W: Write>(
    ctx: &mut SessionCtx,
    pending: &mut Vec<PendingInfer>,
    out: &mut W,
    stats: &mut ServeStats,
    nobs: &NodeObs,
) -> Result<()> {
    if pending.is_empty() {
        return Ok(());
    }
    let rows_total: usize = pending.iter().map(|p| p.batch).sum();
    let site = pending[0].site.clone();
    let responses: Vec<Response> = match ctx.site(&site).map(|s| s.rows) {
        Ok(rows) => {
            let parts: Vec<(&[f32], usize)> =
                pending.iter().map(|p| (p.x.as_slice(), p.batch)).collect();
            match ctx.run_coalesced(&site, &parts) {
                Ok(y) => {
                    stats.batches += 1;
                    stats.widest_batch = stats.widest_batch.max(pending.len());
                    nobs.batch_rows.record(rows_total as u64);
                    nobs.batch_fill_pct.record((100 * rows_total / nobs.max_batch) as u64);
                    let mut off = 0usize;
                    pending
                        .iter()
                        .map(|p| {
                            let n = p.batch * rows;
                            let resp = Response::Infer {
                                id: p.id.clone(),
                                batch: p.batch,
                                y: y[off..off + n].to_vec(),
                            };
                            off += n;
                            resp
                        })
                        .collect()
                }
                // Enqueue-time validation makes this unreachable in
                // practice, but a kernel-layer refusal still answers
                // every held request instead of killing the node.
                Err(e) => per_request_errors(pending, &e.to_string()),
            }
        }
        Err(e) => per_request_errors(pending, &e.to_string()),
    };
    pending.clear();
    for r in &responses {
        respond(out, stats, nobs, r)?;
    }
    Ok(())
}

// lint: no-panic
fn per_request_errors(pending: &[PendingInfer], msg: &str) -> Vec<Response> {
    pending
        .iter()
        .map(|p| Response::Error { id: Some(p.id.clone()), error: msg.to_string() })
        .collect()
}

// lint: no-panic
fn respond<W: Write>(
    out: &mut W,
    stats: &mut ServeStats,
    nobs: &NodeObs,
    resp: &Response,
) -> Result<()> {
    out.write_all(resp.to_line().as_bytes())?;
    out.write_all(b"\n")?;
    out.flush()?;
    stats.responses += 1;
    if matches!(resp, Response::Error { .. }) {
        stats.errors += 1;
        nobs.errors.inc();
    }
    Ok(())
}

fn info_response(ctx: &SessionCtx, id: String, stats: &ServeStats) -> Response {
    let sites = ctx
        .sites()
        .iter()
        .map(|s| SiteInfo {
            name: s.name.clone(),
            rows: s.rows,
            cols: s.cols,
            nnz: s.nnz,
            driver: s.plan.driver().to_string(),
            permuted: s.permuted,
        })
        .collect();
    Response::Info {
        id,
        model: ctx.label().to_string(),
        generation: ctx.generation(),
        sites,
        stats: Some(stats.wire()),
    }
}
