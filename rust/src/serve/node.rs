//! The serving loop: read NDJSON request frames, coalesce `"more":true`
//! infer bursts into one batched GEMM each, write response frames in
//! request order.
//!
//! Error containment is the invariant the corrupt-frame tests pin: a bad
//! frame (truncated, non-JSON, unknown op, wrong version, infeasible
//! geometry) produces exactly one structured error frame — echoing the
//! request id whenever the line was at least JSON — and the loop keeps
//! serving.  Only EOF (clean shutdown, after flushing any held burst) or
//! a transport I/O error ends a session.
//!
//! Batching policy: consecutive same-site infer frames marked
//! `"more":true` are held; the burst flushes when a frame arrives without
//! the flag, when the pending rows reach [`NodeOpts::max_batch`], when a
//! non-infer frame needs the line, or at EOF.  Responses always come back
//! in request order.

use std::io::{BufRead, Write};

use anyhow::Result;

use crate::kernels::micro::LANES;
use crate::serve::protocol::{Request, Response, SiteInfo};
use crate::serve::session::SessionCtx;
use crate::util::json::Json;

/// Serving-loop knobs.
#[derive(Clone, Copy, Debug)]
pub struct NodeOpts {
    /// Flush a held burst once this many rows are pending.  Default
    /// `4 * LANES`: four 8-wide register panels — past this the batched
    /// GEMM is panel-saturated and latency wins over more coalescing.
    pub max_batch: usize,
}

impl Default for NodeOpts {
    fn default() -> Self {
        NodeOpts { max_batch: 4 * LANES }
    }
}

/// End-of-session accounting (the CLI logs it at EOF).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeStats {
    pub requests: usize,
    pub responses: usize,
    /// Error frames emitted (counted inside `responses` too).
    pub errors: usize,
    /// Coalesced GEMM dispatches.
    pub batches: usize,
    /// Widest burst, in requests.
    pub widest_batch: usize,
}

/// An infer frame held for coalescing.
struct PendingInfer {
    id: String,
    site: String,
    batch: usize,
    x: Vec<f32>,
}

/// Serve one NDJSON session: `input` to EOF, responses on `out`.  Frame
/// errors never end the loop; transport errors do.
pub fn serve<R: BufRead, W: Write>(
    ctx: &mut SessionCtx,
    input: R,
    out: &mut W,
    opts: &NodeOpts,
) -> Result<ServeStats> {
    let mut stats = ServeStats::default();
    let mut pending: Vec<PendingInfer> = Vec::new();
    for line in input.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        stats.requests += 1;
        match decode(&line) {
            Err((id, error)) => {
                flush(ctx, &mut pending, out, &mut stats)?;
                respond(out, &mut stats, &Response::Error { id, error })?;
            }
            Ok(Request::Infer { id, site, batch, x, more }) => {
                // Geometry is checked at enqueue so one infeasible
                // request cannot poison a coalesced burst, and its error
                // frame echoes exactly its own id.
                if let Err(e) = ctx.check_request(&site, batch, x.len()) {
                    flush(ctx, &mut pending, out, &mut stats)?;
                    let err = Response::Error { id: Some(id), error: e.to_string() };
                    respond(out, &mut stats, &err)?;
                    continue;
                }
                // Only same-site frames coalesce (one plan per dispatch).
                if pending.last().is_some_and(|p| p.site != site) {
                    flush(ctx, &mut pending, out, &mut stats)?;
                }
                pending.push(PendingInfer { id, site, batch, x });
                let rows: usize = pending.iter().map(|p| p.batch).sum();
                if !more || rows >= opts.max_batch {
                    flush(ctx, &mut pending, out, &mut stats)?;
                }
            }
            Ok(Request::Info { id }) => {
                flush(ctx, &mut pending, out, &mut stats)?;
                respond(out, &mut stats, &info_response(ctx, id))?;
            }
            Ok(Request::Reload { id, checkpoint }) => {
                flush(ctx, &mut pending, out, &mut stats)?;
                let resp = match ctx.reload_from(checkpoint.as_deref()) {
                    Ok(generation) => Response::Reloaded { id, generation },
                    Err(e) => Response::Error { id: Some(id), error: e.to_string() },
                };
                respond(out, &mut stats, &resp)?;
            }
        }
    }
    // EOF: answer any held burst, then shut down cleanly.
    flush(ctx, &mut pending, out, &mut stats)?;
    Ok(stats)
}

/// Serve connections from a Unix socket, sequentially: one NDJSON
/// session per connection, per-connection stats to stderr.  Runs until
/// the process is killed.
#[cfg(unix)]
pub fn serve_unix_socket(
    ctx: &mut SessionCtx,
    path: &std::path::Path,
    opts: &NodeOpts,
) -> Result<()> {
    use anyhow::Context as _;
    use std::os::unix::net::UnixListener;
    // A dead node leaves its socket file behind; rebinding wants it gone.
    let _ = std::fs::remove_file(path);
    let listener = UnixListener::bind(path)
        .with_context(|| format!("binding unix socket {}", path.display()))?;
    eprintln!("[padst serve] listening on {}", path.display());
    for stream in listener.incoming() {
        let stream = stream?;
        let reader = std::io::BufReader::new(stream.try_clone()?);
        let mut writer = stream;
        let stats = serve(ctx, reader, &mut writer, opts)?;
        eprintln!(
            "[padst serve] connection closed: {} requests -> {} responses ({} errors), {} batches",
            stats.requests, stats.responses, stats.errors, stats.batches
        );
    }
    Ok(())
}

/// Two-stage decode so error frames can echo the request id whenever the
/// line was at least JSON.
fn decode(line: &str) -> std::result::Result<Request, (Option<String>, String)> {
    let v = Json::parse(line).map_err(|e| (None, format!("bad frame: {e}")))?;
    let id = v.get("id").and_then(Json::as_str).map(str::to_string);
    Request::from_json(&v).map_err(|e| (id, e.to_string()))
}

/// Execute the held burst as one batched dispatch and answer each pending
/// request with its own rows, in order.
fn flush<W: Write>(
    ctx: &mut SessionCtx,
    pending: &mut Vec<PendingInfer>,
    out: &mut W,
    stats: &mut ServeStats,
) -> Result<()> {
    if pending.is_empty() {
        return Ok(());
    }
    let site = pending[0].site.clone();
    let responses: Vec<Response> = match ctx.site(&site).map(|s| s.rows) {
        Ok(rows) => {
            let parts: Vec<(&[f32], usize)> =
                pending.iter().map(|p| (p.x.as_slice(), p.batch)).collect();
            match ctx.run_coalesced(&site, &parts) {
                Ok(y) => {
                    stats.batches += 1;
                    stats.widest_batch = stats.widest_batch.max(pending.len());
                    let mut off = 0usize;
                    pending
                        .iter()
                        .map(|p| {
                            let n = p.batch * rows;
                            let resp = Response::Infer {
                                id: p.id.clone(),
                                batch: p.batch,
                                y: y[off..off + n].to_vec(),
                            };
                            off += n;
                            resp
                        })
                        .collect()
                }
                // Enqueue-time validation makes this unreachable in
                // practice, but a kernel-layer refusal still answers
                // every held request instead of killing the node.
                Err(e) => per_request_errors(pending, &e.to_string()),
            }
        }
        Err(e) => per_request_errors(pending, &e.to_string()),
    };
    pending.clear();
    for r in &responses {
        respond(out, stats, r)?;
    }
    Ok(())
}

fn per_request_errors(pending: &[PendingInfer], msg: &str) -> Vec<Response> {
    pending
        .iter()
        .map(|p| Response::Error { id: Some(p.id.clone()), error: msg.to_string() })
        .collect()
}

fn respond<W: Write>(out: &mut W, stats: &mut ServeStats, resp: &Response) -> Result<()> {
    out.write_all(resp.to_line().as_bytes())?;
    out.write_all(b"\n")?;
    out.flush()?;
    stats.responses += 1;
    if matches!(resp, Response::Error { .. }) {
        stats.errors += 1;
    }
    Ok(())
}

fn info_response(ctx: &SessionCtx, id: String) -> Response {
    let sites = ctx
        .sites()
        .iter()
        .map(|s| SiteInfo {
            name: s.name.clone(),
            rows: s.rows,
            cols: s.cols,
            nnz: s.nnz,
            driver: s.plan.driver().to_string(),
            permuted: s.permuted,
        })
        .collect();
    Response::Info { id, model: ctx.label().to_string(), generation: ctx.generation(), sites }
}
